//! Minimal shared bench harness (criterion is unavailable offline).
//!
//! Each bench target is a `harness = false` binary that times closures with
//! warmup + repeated measurement and prints mean/min/max per iteration —
//! the format EXPERIMENTS.md records.
//!
//! For CI regression tracking, a [`Reporter`] collects per-bench samples
//! and, when the `BENCH_JSON` environment variable names a file, writes
//! (or merges into) a JSON object `{"commit": …, "date": …, "entries":
//! [{"name": …, "mean_ns": …, "p50": …, "p99": …}, …]}` — the artifact
//! the bench workflow uploads and gates against a checked-in baseline
//! (commit from `BENCH_COMMIT` else `GITHUB_SHA`, date from `BENCH_DATE`;
//! both "unknown" when unset, keeping local runs deterministic).
//! `BENCH_QUICK=1` asks bench mains for their reduced CI workload.

#![allow(dead_code)]

use std::time::Instant;

use imcnoc::util::{mean, percentile};

/// Time `f` for `iters` iterations after `warmup` runs; prints a row.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    println!("bench {name:<42} mean {mean:>10.3} ms  min {min:>10.3}  max {max:>10.3}  (n={iters})");
    mean
}

/// Black-box helper to keep results alive.
#[inline]
pub fn observe<T>(value: &T) {
    std::hint::black_box(value);
}

/// Is the reduced CI workload requested (`BENCH_QUICK=1`)?
pub fn quick() -> bool {
    std::env::var("BENCH_QUICK").is_ok_and(|v| v != "0")
}

/// One recorded bench result, all times in nanoseconds.
#[derive(Clone, Debug)]
pub struct BenchEntry {
    pub name: String,
    pub mean_ns: f64,
    pub p50: f64,
    pub p99: f64,
}

/// Collects bench results and serializes them for the CI bench gate.
#[derive(Default)]
pub struct Reporter {
    entries: Vec<BenchEntry>,
}

impl Reporter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Like [`bench`], additionally recording mean/p50/p99 (ns).
    pub fn bench<F: FnMut()>(&mut self, name: &str, warmup: usize, iters: usize, mut f: F) {
        for _ in 0..warmup {
            f();
        }
        let mut samples_ns = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_secs_f64() * 1e9);
        }
        let entry = BenchEntry {
            name: name.to_string(),
            mean_ns: mean(&samples_ns),
            p50: percentile(&samples_ns, 50.0),
            p99: percentile(&samples_ns, 99.0),
        };
        let ms = entry.mean_ns / 1e6;
        println!("bench {name:<42} mean {ms:>10.3} ms  (n={iters})");
        self.entries.push(entry);
    }

    /// Write (or merge into) the `BENCH_JSON` file, if requested. Entries
    /// with the same name are replaced, so several bench binaries can
    /// share one artifact; the result is sorted by name and wrapped with
    /// commit/date metadata so uploaded artifacts are self-describing.
    pub fn finish(self) {
        let Ok(path) = std::env::var("BENCH_JSON") else {
            return;
        };
        let mut merged = match std::fs::read_to_string(&path) {
            Ok(text) => parse_entries(&text),
            Err(_) => Vec::new(),
        };
        for e in self.entries {
            merged.retain(|m| m.name != e.name);
            merged.push(e);
        }
        merged.sort_by(|a, b| a.name.cmp(&b.name));
        // The `parse_entries` brace-scanner skips the wrapper fragment
        // (it lacks the four entry fields), so re-merging keeps working.
        let commit = std::env::var("BENCH_COMMIT")
            .or_else(|_| std::env::var("GITHUB_SHA"))
            .unwrap_or_else(|_| "unknown".to_string());
        let date = std::env::var("BENCH_DATE").unwrap_or_else(|_| "unknown".to_string());
        let mut out = format!(
            "{{\"commit\": \"{commit}\", \"date\": \"{date}\", \"entries\": [\n"
        );
        for (i, e) in merged.iter().enumerate() {
            let sep = if i + 1 == merged.len() { "" } else { "," };
            out.push_str(&format!(
                "  {{\"name\": \"{}\", \"mean_ns\": {:.1}, \"p50\": {:.1}, \"p99\": {:.1}}}{}\n",
                e.name, e.mean_ns, e.p50, e.p99, sep
            ));
        }
        out.push_str("]}\n");
        if let Err(e) = std::fs::write(&path, out) {
            eprintln!("bench: failed to write {path}: {e}");
        } else {
            println!("bench: wrote {path}");
        }
    }
}

/// Tolerant reader for the JSON this harness writes (no serde offline):
/// scans `{…}` objects for the four known fields.
fn parse_entries(text: &str) -> Vec<BenchEntry> {
    let mut out = Vec::new();
    for obj in text.split('{').skip(1) {
        let obj = obj.split('}').next().unwrap_or("");
        let name = extract_str(obj, "name");
        let mean_ns = extract_num(obj, "mean_ns");
        let p50 = extract_num(obj, "p50");
        let p99 = extract_num(obj, "p99");
        if let (Some(name), Some(mean_ns), Some(p50), Some(p99)) = (name, mean_ns, p50, p99) {
            out.push(BenchEntry {
                name,
                mean_ns,
                p50,
                p99,
            });
        }
    }
    out
}

fn extract_str(obj: &str, key: &str) -> Option<String> {
    let rest = field_value(obj, key)?;
    let rest = rest.strip_prefix('"')?;
    Some(rest[..rest.find('"')?].to_string())
}

fn extract_num(obj: &str, key: &str) -> Option<f64> {
    let rest = field_value(obj, key)?;
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || "+-.eE".contains(c)))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// The text right after `"key":` (whitespace skipped).
fn field_value<'a>(obj: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\"");
    let after = &obj[obj.find(&pat)? + pat.len()..];
    let after = after.trim_start();
    let after = after.strip_prefix(':')?;
    Some(after.trim_start())
}
