//! Minimal shared bench harness (criterion is unavailable offline).
//!
//! Each bench target is a `harness = false` binary that times closures with
//! warmup + repeated measurement and prints mean/min/max per iteration —
//! the format EXPERIMENTS.md records.

use std::time::Instant;

/// Time `f` for `iters` iterations after `warmup` runs; prints a row.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    println!("bench {name:<42} mean {mean:>10.3} ms  min {min:>10.3}  max {max:>10.3}  (n={iters})");
    mean
}

/// Black-box helper to keep results alive.
#[inline]
pub fn observe<T>(value: &T) {
    std::hint::black_box(value);
}
