//! Analytical-model benchmarks: the Fig. 12 speed-up measurement (full
//! Algorithm 2 vs full Algorithm 1 per DNN) plus the queueing-solver
//! micro-benchmark.

#[path = "harness.rs"]
mod harness;

use harness::{bench, observe};
use imcnoc::config::{ArchConfig, NocConfig, SimConfig};
use imcnoc::dnn::models;
use imcnoc::mapping::{InjectionMatrix, Mapping};
use imcnoc::noc::latency::{estimate_dnn, simulate_dnn};
use imcnoc::noc::sim::uniform_random_flows;
use imcnoc::noc::topology::{Network, Topology};
use imcnoc::noc::AnalyticalModel;

fn main() {
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    let sim_cfg = SimConfig::default();

    // Queueing solver micro-bench (per-router matrices on a 64-node mesh).
    let net = Network::build(Topology::Mesh, 64);
    let flows = uniform_random_flows(64, 0.10);
    bench("algorithm2_64n_uniform", 2, 10, || {
        let model = AnalyticalModel::new(&net, &noc);
        let est = model.layer_latency(&flows);
        observe(&est.avg_latency);
    });

    // Fig. 12: per-DNN analytical vs cycle-accurate wall-clock (mesh).
    for g in [models::mlp(), models::lenet5(), models::nin()] {
        let mapping = Mapping::build(&g, &arch);
        let inj = InjectionMatrix::build(&g, &mapping, &arch, &noc);
        let ana = bench(&format!("analytical_{}", g.name), 1, 5, || {
            let est = estimate_dnn(&inj, Topology::Mesh, &arch, &noc);
            observe(&est.total_latency);
        });
        let sim = bench(&format!("cycle_accurate_{}", g.name), 0, 3, || {
            let r = simulate_dnn(&inj, Topology::Mesh, &arch, &noc, &sim_cfg, true, false);
            observe(&r.total_cycles);
        });
        println!(
            "  -> Fig. 12 speed-up for {}: {:.1}x",
            g.name,
            sim / ana.max(1e-9)
        );
    }
}
