//! Multi-model workload benchmarks: mix-model construction (two replica
//! pricings + placement search + NoP saturation sweep), arrival-trace
//! generation per shape, and the multi-model serving simulation per
//! admission control. `BENCH_QUICK=1` runs the reduced CI workload;
//! `BENCH_JSON=<path>` records the results for the bench regression gate.

#[path = "harness.rs"]
mod harness;

use harness::{observe, quick, Reporter};
use imcnoc::config::{
    Admission, ArchConfig, NocConfig, NopConfig, NopMode, ServingConfig, SimConfig, WorkloadConfig,
};
use imcnoc::coordinator::mix::{MixScheduler, MixServingModel};
use imcnoc::nop::topology::NopTopology;
use imcnoc::workload::{ArrivalKind, PlacementPolicy, WorkloadMix};

fn main() {
    let mut r = Reporter::new();
    let quick = quick();
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    let sim = SimConfig::default();
    let mix = WorkloadMix::parse("SqueezeNet:1:0,MLP:3:0").unwrap();
    let nop = NopConfig {
        topology: NopTopology::Mesh,
        chiplets: 8,
        ..NopConfig::default()
    };
    let requests = if quick { 128 } else { 1024 };
    let iters = if quick { 3 } else { 10 };

    // Mix-model construction (dominated by the NoP saturation sweep).
    r.bench("workload_model_build_sq+mlp_k8_mesh", 0, 2, || {
        let model = MixServingModel::build(
            &mix,
            PlacementPolicy::NopAware,
            &arch,
            &noc,
            &nop,
            &sim,
        )
        .unwrap();
        observe(&model.sat_link_util);
    });

    // Same build with surrogate ingress pricing: the first iteration pays
    // the anchor fit, later ones hit the process-wide curve cache, so the
    // mean tracks the near-analytical steady cost the mode is for.
    let nop_sur = NopConfig {
        mode: NopMode::Surrogate,
        ..nop.clone()
    };
    r.bench("workload_model_build_sq+mlp_k8_mesh_surrogate", 0, 2, || {
        let model = MixServingModel::build(
            &mix,
            PlacementPolicy::NopAware,
            &arch,
            &noc,
            &nop_sur,
            &sim,
        )
        .unwrap();
        observe(&model.sat_link_util);
    });

    let model =
        MixServingModel::build(&mix, PlacementPolicy::NopAware, &arch, &noc, &nop, &sim).unwrap();

    // Arrival generation per shape (heavy-tailed frames on).
    let wl = WorkloadConfig {
        mix: mix.clone(),
        frames_alpha: 1.5,
        ..WorkloadConfig::default()
    };
    let rate = 0.85 * model.capacity_rps(wl.arrival_process().mean_frames());
    for kind in ArrivalKind::all() {
        let shaped = WorkloadConfig {
            arrival: kind,
            ..wl.clone()
        };
        let name = format!("workload_gen_{}", kind.name());
        r.bench(&name, 1, iters, || {
            let events = shaped.arrival_process().generate(&mix, rate, requests, 42);
            observe(&events.len());
        });
    }

    // The multi-model serving simulation per admission control.
    let events = wl.arrival_process().generate(&mix, rate, requests, 42);
    for admission in Admission::all() {
        let cfg = ServingConfig {
            requests,
            ..ServingConfig::default()
        };
        let name = format!("workload_sim_sq+mlp_k8_mesh_{}", admission.name());
        r.bench(&name, 1, iters, || {
            let mut sched = MixScheduler::new(model.clone(), &cfg, admission);
            let report = sched.run(&events);
            observe(&report.deadline_hits);
        });
    }

    r.finish();
}
