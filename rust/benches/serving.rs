//! Chiplet-aware serving benchmarks: model construction (including the
//! NoP saturation sweep) and the discrete-event serving simulation per
//! routing policy. `BENCH_QUICK=1` runs the reduced CI workload;
//! `BENCH_JSON=<path>` records the results for the bench regression gate.

#[path = "harness.rs"]
mod harness;

use harness::{observe, quick, Reporter};
use imcnoc::config::{ArchConfig, NocConfig, NopConfig, ServingConfig, SimConfig};
use imcnoc::coordinator::scheduler::{ChipletScheduler, Policy, ServingModel};
use imcnoc::dnn::models;
use imcnoc::nop::topology::NopTopology;

fn main() {
    let mut r = Reporter::new();
    let quick = quick();
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    let sim = SimConfig::default();
    let g = models::squeezenet();
    let requests = if quick { 128 } else { 1024 };
    let iters = if quick { 3 } else { 10 };

    // Model construction cost (dominated by the NoP saturation sweep).
    let nop = NopConfig {
        topology: NopTopology::Mesh,
        chiplets: 8,
        ..NopConfig::default()
    };
    r.bench("serve_model_build_squeezenet_k8_mesh", 0, 2, || {
        let built = ServingModel::build(&g, &arch, &noc, &nop, &sim);
        observe(&built.0.sat_link_util);
    });

    // The serving simulation per policy, reusing one built model.
    let (model, part) = ServingModel::build(&g, &arch, &noc, &nop, &sim);
    for policy in Policy::all() {
        let cfg = ServingConfig {
            policy,
            requests,
            ..ServingConfig::default()
        };
        let name = format!("serve_sim_squeezenet_k8_mesh_{}", policy.name());
        r.bench(&name, 1, iters, || {
            let mut sched = ChipletScheduler::new(model.clone(), part.clone(), &cfg);
            let report = sched.run(&cfg, 42);
            observe(&report.p99_ms);
        });
    }

    // A larger package point for the congestion-aware policy only.
    if !quick {
        let nop16 = NopConfig {
            topology: NopTopology::Mesh,
            chiplets: 16,
            ..NopConfig::default()
        };
        let (m16, p16) = ServingModel::build(&g, &arch, &noc, &nop16, &sim);
        let cfg = ServingConfig {
            policy: Policy::CongestionAware,
            requests,
            ..ServingConfig::default()
        };
        r.bench("serve_sim_squeezenet_k16_mesh_congestion-aware", 1, iters, || {
            let mut sched = ChipletScheduler::new(m16.clone(), p16.clone(), &cfg);
            let report = sched.run(&cfg, 42);
            observe(&report.p99_ms);
        });
    }

    r.finish();
}
