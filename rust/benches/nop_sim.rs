//! Flit-level NoP simulator benchmarks: steady-state uniform traffic at
//! low and near-saturation load, a saturation-point search, and the full
//! hierarchical co-simulation (`mode = sim`) against the analytical
//! package leg it replaces.

#[path = "harness.rs"]
mod harness;

use harness::{bench, observe};
use imcnoc::arch::CommBackend;
use imcnoc::config::{ArchConfig, NocConfig, NopConfig, NopMode, SimConfig};
use imcnoc::dnn::models;
use imcnoc::noc::sim::Mode;
use imcnoc::nop::evaluator::evaluate_package;
use imcnoc::nop::sim::{saturation_rate, uniform_nop_flows, NopSim};
use imcnoc::nop::topology::NopTopology;

fn main() {
    let nop = NopConfig::default();

    // Steady-state simulation cost across package sizes and load points.
    for topo in NopTopology::all() {
        for k in [8usize, 16, 25] {
            for rate in [0.05f64, 0.5] {
                let flows = uniform_nop_flows(k, rate);
                bench(
                    &format!("nop_steady_{}_k{k}_r{rate}", topo.name()),
                    1,
                    5,
                    || {
                        let stats = NopSim::new(
                            topo,
                            k,
                            &nop,
                            &flows,
                            Mode::Steady {
                                warmup: 500,
                                measure: 5_000,
                            },
                            42,
                        )
                        .run();
                        observe(&stats.avg_latency);
                    },
                );
            }
        }
    }

    // The saturation sweep the congestion experiment runs per point.
    bench("nop_saturation_search_mesh_k16", 0, 3, || {
        let sat = saturation_rate(NopTopology::Mesh, 16, &nop, 7);
        observe(&sat);
    });

    // Hierarchical co-simulation vs the analytical package leg.
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    let sim = SimConfig::default();
    let g = models::resnet(50);
    for (label, mode) in [
        ("analytical", NopMode::Analytical),
        ("sim", NopMode::Sim),
    ] {
        let cfg = NopConfig {
            chiplets: 8,
            mode,
            ..NopConfig::default()
        };
        bench(&format!("package_resnet50_k8_nop_{label}"), 1, 3, || {
            let e = evaluate_package(&g, &arch, &noc, &cfg, &sim, CommBackend::Analytical);
            observe(&e.edap());
        });
    }
}
