//! Flit-level NoP simulator benchmarks: steady-state uniform traffic at
//! low and near-saturation load, a saturation-point search, and the full
//! hierarchical co-simulation (`mode = sim`) and its sim-anchored
//! surrogate (`mode = surrogate`) against the analytical package leg
//! they replace. `BENCH_QUICK=1` runs the reduced CI workload;
//! `BENCH_JSON=<path>` records results for the bench regression gate.

#[path = "harness.rs"]
mod harness;

use harness::{observe, quick, Reporter};
use imcnoc::arch::CommBackend;
use imcnoc::config::{ArchConfig, NocConfig, NopConfig, NopMode, SimConfig};
use imcnoc::dnn::models;
use imcnoc::noc::sim::Mode;
use imcnoc::nop::evaluator::evaluate_package;
use imcnoc::nop::sim::{saturation_rate, uniform_nop_flows, NopSim};
use imcnoc::nop::topology::NopTopology;

fn main() {
    let mut r = Reporter::new();
    let quick = quick();
    let nop = NopConfig::default();
    let ks: &[usize] = if quick { &[8] } else { &[8, 16, 25] };
    let rates: &[f64] = if quick { &[0.05] } else { &[0.05, 0.5] };
    let measure: u64 = if quick { 2_000 } else { 5_000 };
    let iters = if quick { 3 } else { 5 };

    // Steady-state simulation cost across package sizes and load points.
    for topo in NopTopology::all() {
        for &k in ks {
            for &rate in rates {
                let flows = uniform_nop_flows(k, rate);
                let name = format!("nop_steady_{}_k{k}_r{rate}", topo.name());
                r.bench(&name, 1, iters, || {
                    let stats = NopSim::new(
                        topo,
                        k,
                        &nop,
                        &flows,
                        Mode::Steady {
                            warmup: 500,
                            measure,
                        },
                        42,
                    )
                    .run();
                    observe(&stats.avg_latency);
                });
            }
        }
    }

    // The saturation sweep the congestion experiment runs per point.
    let sat_k = if quick { 8 } else { 16 };
    let sat_name = format!("nop_saturation_search_mesh_k{sat_k}");
    r.bench(&sat_name, 0, 3, || {
        let sat = saturation_rate(NopTopology::Mesh, sat_k, &nop, 7);
        observe(&sat);
    });

    // Hierarchical co-simulation and its surrogate vs the analytical
    // package leg. The surrogate's first iteration pays the anchor fit;
    // later iterations hit the process-wide curve cache, so its mean sits
    // between analytical and sim — exactly the trade the mode buys.
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    let sim = SimConfig::default();
    let g = models::resnet(50);
    for (label, mode) in [
        ("analytical", NopMode::Analytical),
        ("sim", NopMode::Sim),
        ("surrogate", NopMode::Surrogate),
    ] {
        let cfg = NopConfig {
            chiplets: 8,
            mode,
            ..NopConfig::default()
        };
        let name = format!("package_resnet50_k8_nop_{label}");
        r.bench(&name, 1, 3, || {
            let e = evaluate_package(&g, &arch, &noc, &cfg, &sim, CommBackend::Analytical);
            observe(&e.edap());
        });
    }

    r.finish();
}
