//! End-to-end evaluation benchmarks: one per paper table.
//!
//! * Table 3 pipeline — steady per-pair latency stats for one DNN.
//! * Table 4 pipeline — the full VGG-19 architecture evaluation (both
//!   backends) that produces the headline comparison.
//! * Whole-framework sweep — the 6-DNN × 2-topology evaluation behind
//!   Fig. 16/17 (the paper's "8× overall analysis speed-up" context).

#[path = "harness.rs"]
mod harness;

use harness::{bench, observe};
use imcnoc::arch::{evaluate, CommBackend};
use imcnoc::config::{ArchConfig, NocConfig, SimConfig};
use imcnoc::dnn::{eval_set, models};
use imcnoc::mapping::{InjectionMatrix, Mapping};
use imcnoc::noc::latency::simulate_dnn;
use imcnoc::noc::topology::Topology;

fn main() {
    let sim_cfg = SimConfig::default();

    // Table 3 pipeline: steady per-pair stats on LeNet-5 (mesh).
    {
        let g = models::lenet5();
        let arch = ArchConfig::reram();
        let noc = NocConfig::default();
        let mapping = Mapping::build(&g, &arch);
        let inj = InjectionMatrix::build(&g, &mapping, &arch, &noc);
        bench("table3_pipeline_lenet5", 1, 5, || {
            let r = simulate_dnn(&inj, Topology::Mesh, &arch, &noc, &sim_cfg, false, true);
            observe(&r.avg_flit_latency);
        });
    }

    // Table 4 pipeline: VGG-19 full evaluation.
    let vgg = models::vgg(19);
    for (name, backend) in [
        ("table4_vgg19_analytical", CommBackend::Analytical),
        ("table4_vgg19_cycle_accurate", CommBackend::Simulate),
    ] {
        let arch = ArchConfig::reram();
        let noc = NocConfig::default();
        let iters = if backend == CommBackend::Analytical { 5 } else { 2 };
        bench(name, 0, iters, || {
            let e = evaluate(&vgg, Topology::Mesh, &arch, &noc, &sim_cfg, backend);
            observe(&e.comm_cycles);
        });
    }

    // Fig. 16/17 sweep: 6 DNNs x {tree, mesh}, analytical backend.
    bench("fig16_17_sweep_analytical", 0, 3, || {
        for g in eval_set() {
            for topo in [Topology::Tree, Topology::Mesh] {
                let arch = ArchConfig::sram();
                let e = evaluate(
                    &g,
                    topo,
                    &arch,
                    &NocConfig::with_topology(topo),
                    &sim_cfg,
                    CommBackend::Analytical,
                );
                observe(&e.comm_cycles);
            }
        }
    });
}
