//! Multi-chiplet scale-out benchmarks: hierarchical NoC+NoP evaluation
//! cost (analytical vs cycle-accurate per-chiplet backends) and the joint
//! (chiplets, NoP, NoC) advisor sweep.

#[path = "harness.rs"]
mod harness;

use harness::{bench, observe};
use imcnoc::arch::{recommend_scaleout, CommBackend};
use imcnoc::config::{ArchConfig, NocConfig, NopConfig, SimConfig};
use imcnoc::dnn::models;
use imcnoc::nop::evaluator::evaluate_package;
use imcnoc::nop::topology::NopTopology;

fn main() {
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    let sim = SimConfig::default();

    // Hierarchical evaluation, analytical per-chiplet backend.
    for (name, g) in [
        ("lenet5", models::lenet5()),
        ("resnet50", models::resnet(50)),
        ("vgg19", models::vgg(19)),
    ] {
        for k in [2usize, 4, 8] {
            let nop = NopConfig {
                topology: NopTopology::Mesh,
                chiplets: k,
                ..NopConfig::default()
            };
            bench(&format!("package_analytical_{name}_k{k}"), 1, 5, || {
                let e = evaluate_package(&g, &arch, &noc, &nop, &sim, CommBackend::Analytical);
                observe(&e.edap());
            });
        }
    }

    // Cycle-accurate per-chiplet backend (small DNN only).
    let g = models::lenet5();
    let nop = NopConfig {
        chiplets: 4,
        ..NopConfig::default()
    };
    bench("package_simulate_lenet5_k4", 1, 3, || {
        let e = evaluate_package(&g, &arch, &noc, &nop, &sim, CommBackend::Simulate);
        observe(&e.edap());
    });

    // Joint advisor: the full (chiplets x NoP x NoC) EDAP search.
    let nop = NopConfig::default();
    for (name, g) in [("nin", models::nin()), ("resnet50", models::resnet(50))] {
        bench(&format!("recommend_scaleout_{name}"), 0, 3, || {
            let rec = recommend_scaleout(&g, &arch, &noc, &nop);
            observe(&rec.chiplets);
        });
    }
}
