//! Cycle-accurate NoC simulator benchmarks — the L3 hot path (the paper:
//! NoC simulation takes up to 80% of total analysis time). Covers the
//! Fig. 5 configuration (64-node uniform random) and DNN-derived traffic.

#[path = "harness.rs"]
mod harness;

use harness::{bench, observe};
use imcnoc::config::{ArchConfig, NocConfig};
use imcnoc::dnn::models;
use imcnoc::mapping::{InjectionMatrix, Mapping};
use imcnoc::noc::latency::layer_flows;
use imcnoc::noc::sim::{uniform_random_flows, Mode, NocSim};
use imcnoc::noc::topology::Topology;

fn main() {
    let cfg = NocConfig::default();

    // Fig. 5 point: 8x8 mesh, uniform random at moderate load.
    for topo in [Topology::Mesh, Topology::Tree, Topology::P2P] {
        let flows = uniform_random_flows(64, 0.10);
        bench(&format!("steady_64n_rate0.10_{}", topo.name()), 1, 5, || {
            let stats = NocSim::new(
                topo,
                64,
                &cfg,
                &flows,
                Mode::Steady {
                    warmup: 1_000,
                    measure: 10_000,
                },
                7,
            )
            .run();
            observe(&stats.avg_latency);
        });
    }

    // DNN-derived drain workloads (Algorithm 1 inner loop).
    let arch = ArchConfig::default();
    for g in [models::lenet5(), models::nin()] {
        let mapping = Mapping::build(&g, &arch);
        let inj = InjectionMatrix::build(&g, &mapping, &arch, &cfg);
        // Busiest layer (most flits).
        let layer = inj
            .flows
            .iter()
            .map(|f| f.dst_layer)
            .max_by_key(|&l| {
                layer_flows(&inj, l, &arch, &cfg, true)
                    .iter()
                    .map(|f| f.flits)
                    .sum::<u64>()
            })
            .unwrap();
        let flows = layer_flows(&inj, layer, &arch, &cfg, true);
        let total: u64 = flows.iter().map(|f| f.flits).sum();
        bench(
            &format!("drain_{}_busiest_layer_{}flits", g.name, total),
            1,
            5,
            || {
                let stats = NocSim::new(
                    Topology::Mesh,
                    inj.total_tiles,
                    &cfg,
                    &flows,
                    Mode::Drain {
                        max_cycles: 1_000 + total * 64,
                    },
                    3,
                )
                .run();
                assert!(stats.drained);
                observe(&stats.makespan);
            },
        );
    }
}
