//! PJRT serving-path benchmarks: artifact compile time and per-batch
//! execution latency for the IMC-quantized and float MLP artifacts.
//! Skips (exit 0) when `make artifacts` has not been run.

#[path = "harness.rs"]
mod harness;

use harness::{bench, observe};
use imcnoc::coordinator::server::synthetic_requests;
use imcnoc::runtime::{artifact_available, artifact_path, pjrt_enabled, Runtime};

fn main() {
    if !pjrt_enabled() {
        println!("runtime_pjrt: built without the `pjrt` feature (skipping)");
        return;
    }
    if !artifact_available("mlp") || !artifact_available("mlp_float") {
        println!("runtime_pjrt: artifacts missing, run `make artifacts` (skipping)");
        return;
    }
    let batch = 8usize;
    let in_dim = 784usize;
    let reqs = synthetic_requests(batch, in_dim, 11);
    let flat: Vec<f32> = reqs.iter().flatten().copied().collect();
    let dims = [batch as i64, in_dim as i64];

    for name in ["mlp_float", "mlp"] {
        let path = artifact_path(name);
        // Compile (load) cost.
        bench(&format!("pjrt_compile_{name}"), 0, 3, || {
            let mut rt = Runtime::cpu().expect("client");
            let m = rt.load(&path).expect("load");
            observe(&m.path);
        });
        // Hot-path execute cost.
        let mut rt = Runtime::cpu().expect("client");
        rt.load(&path).expect("load");
        bench(&format!("pjrt_execute_{name}_b{batch}"), 2, 10, || {
            let m = rt.load(&path).expect("cached");
            let out = m.run_f32(&[(&flat, &dims)]).expect("run");
            observe(&out[0][0]);
        });
    }
}
