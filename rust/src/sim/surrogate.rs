//! Sim-anchored surrogate latency models: sim-fidelity pricing at
//! near-analytical cost.
//!
//! The analytical NoP model (`nop_transfer_cycles` and friends) is cheap
//! but load-blind; the flit simulator sees queueing and saturation but
//! dominates sweep wall-clock even after memoization. This module sits in
//! between: per (topology, k, sim-relevant config knobs, seed) it runs
//! the flit sim at a handful of injection rates between low load and the
//! measured saturation rate, fits a monotone correction of sim latency
//! versus offered rate, and answers every subsequent query from the
//! fitted curve. One fit (≈ [`ANCHOR_FRACS`].len() short steady sims plus
//! the memoized saturation search) is amortized across an entire sweep
//! grid, which is how `[nop] mode = surrogate` reaches sim-level fidelity
//! at a fraction of `mode = sim`'s cost.
//!
//! # Anchor selection and fit form
//!
//! **Steady latency.** Anchors are placed at fixed fractions of the
//! measured [`crate::nop::sim::saturation_rate`] — denser toward the
//! saturation knee where curvature concentrates — and each one records
//! the average latency of a short uniform-traffic steady run with the
//! same warmup/measure window the saturation probe uses. Anchors that
//! break monotonicity (sim noise at indistinguishable loads) are dropped
//! keep-first, so the stored curve is non-decreasing by construction. A
//! query below the first anchor returns the first anchor's latency
//! (low-load latency is flat in rate); between anchors it interpolates
//! linearly; between the last anchor and saturation it follows a
//! log-barrier tail `L(r) = Lₙ + β·ln((s − rₙ)/(s − r))` whose strength
//! `β` continues the last segment's slope — monotone, exact at `rₙ`, and
//! diverging at the saturation rate `s` like the queueing curve it
//! stands in for.
//!
//! **Drain makespan.** The analytical lower bound for a drain is the
//! bottleneck directed-link flit load plus the worst per-flow zero-load
//! fill ([`drain_bound`]). Anchors record the ratio of the memoized sim
//! makespan to that bound for a canonical scatter pattern at a ladder of
//! total flit counts; a query interpolates the ratio in log-total-flits
//! and scales its own analytical bound by it.
//!
//! # Fallback to full sim
//!
//! Every entry point returns `None` — and bumps the
//! [`crate::telemetry::profile`] fallback counter — when the surrogate
//! cannot stand behind a number: `k < 2` (no network), an unmeasurable
//! saturation rate, fewer than two usable anchors, or a steady query at
//! or beyond the saturation rate (where the fitted tail diverges).
//! Callers then price via the full simulator exactly as `mode = sim`
//! would.
//!
//! Fitted curves are cached process-wide in an [`super::memo::LruCache`]
//! next to the drain/saturation caches, and the fit itself runs under the
//! `surrogate.fit` profile phase so `--profile` attributes its cost.
//! Everything is deterministic per seed: anchors come from deterministic
//! sims at derived rates, so two fits of the same key produce
//! byte-identical curves ([`SurrogateModel::curve_bytes`]).

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::engine::{FlowSpec, Mode};
use super::memo::LruCache;
use crate::config::NopConfig;
use crate::nop::sim::{
    analytical_latency, saturation_rate, uniform_nop_flows, zero_load_cycles, NopSim,
};
use crate::nop::topology::{NopNetwork, NopTopology};
use crate::telemetry::profile;

/// Steady-anchor positions as fractions of the measured saturation rate.
/// Denser toward the knee, where the latency curve bends hardest.
pub const ANCHOR_FRACS: [f64; 8] = [0.08, 0.20, 0.35, 0.50, 0.62, 0.72, 0.81, 0.90];

/// Total-flit ladder for the drain-ratio anchors.
pub const DRAIN_ANCHOR_TOTALS: [u64; 4] = [128, 512, 2048, 8192];

/// Steady-anchor warmup window, matching the saturation probe so anchor
/// runs and the search that scales them see the same transient handling.
const STEADY_WARMUP: u64 = 500;

/// Steady-anchor measurement window (see [`STEADY_WARMUP`]).
const STEADY_MEASURE: u64 = 2_000;

/// Maximum resident fitted curves (shared-LRU bound, like the memo
/// caches). Failed fits are cached too, so unfittable keys do not re-pay
/// the probe on every query.
const SUR_CACHE_CAP: usize = 256;

/// A fitted surrogate for one (topology, k, sim-knob, seed) key.
///
/// `steady_anchors` is strictly increasing in rate and non-decreasing in
/// latency; `drain_anchors` is increasing in log-total-flits. Both are
/// exactly reproducible from the key (see [`SurrogateModel::curve_bytes`]).
#[derive(Clone, Debug)]
pub struct SurrogateModel {
    /// Package topology the curve was fit on.
    pub topology: NopTopology,
    /// Chiplet count the curve was fit on.
    pub k: usize,
    /// Measured saturation rate (flits/chiplet/cycle); the steady curve's
    /// vertical asymptote.
    pub sat_rate: f64,
    /// Analytical zero-load latency baseline (cycles) under uniform
    /// traffic — the load-independent floor the correction bends away
    /// from.
    pub zero_load: f64,
    /// Monotone (offered rate, sim average latency in cycles) anchors.
    pub steady_anchors: Vec<(f64, f64)>,
    /// (ln total flits, makespan / analytical bound) drain anchors.
    pub drain_anchors: Vec<(f64, f64)>,
}

/// Cache key: the exact inputs `NopSim` dynamics read (topology, k,
/// `hop_latency_cycles`, `buffer_flits`) plus the seed — mirroring the
/// saturation memo key. Link width, frequency and energy are applied by
/// callers after the fact and deliberately excluded.
type SurKey = (u8, usize, u64, usize, u64);

static SUR_CACHE: OnceLock<Mutex<LruCache<SurKey, Option<Arc<SurrogateModel>>>>> = OnceLock::new();

fn sur_cache() -> &'static Mutex<LruCache<SurKey, Option<Arc<SurrogateModel>>>> {
    SUR_CACHE.get_or_init(|| Mutex::new(LruCache::new(SUR_CACHE_CAP)))
}

fn sur_key(topology: NopTopology, k: usize, cfg: &NopConfig, seed: u64) -> SurKey {
    (
        topology as u8,
        k,
        cfg.hop_latency_cycles,
        cfg.buffer_flits,
        seed,
    )
}

/// Linear interpolation through (x0, y0)–(x1, y1) at `x`.
fn lerp(x0: f64, y0: f64, x1: f64, y1: f64, x: f64) -> f64 {
    y0 + (y1 - y0) * ((x - x0) / (x1 - x0))
}

/// Analytical drain lower bound (cycles): bottleneck directed-link flit
/// load plus the worst per-flow zero-load pipeline fill. Self-loops and
/// empty flows are ignored, matching the drain memo's filter.
pub fn drain_bound(net: &NopNetwork, cfg: &NopConfig, flows: &[FlowSpec]) -> f64 {
    let mut link_load: HashMap<(usize, usize), u64> = HashMap::new();
    let mut fill = 0.0_f64;
    for f in flows {
        if f.src == f.dst || f.flits == 0 {
            continue;
        }
        let path = net.route_path(f.src, f.dst);
        for w in path.windows(2) {
            *link_load.entry((w[0], w[1])).or_insert(0) += f.flits;
        }
        fill = fill.max(zero_load_cycles(net, cfg, f.src, f.dst));
    }
    let bottleneck = link_load.values().copied().max().unwrap_or(0);
    bottleneck as f64 + fill
}

/// Fit a surrogate for (topology, k, cfg, seed), uncached: run the
/// saturation search (memoized), then one short steady sim per
/// [`ANCHOR_FRACS`] entry and one memoized scatter drain per
/// [`DRAIN_ANCHOR_TOTALS`] entry. `None` when the key is unfittable
/// (`k < 2`, unmeasurable saturation, or fewer than two monotone steady
/// anchors survive).
pub fn fit_model(
    topology: NopTopology,
    k: usize,
    cfg: &NopConfig,
    seed: u64,
) -> Option<SurrogateModel> {
    if k < 2 {
        return None;
    }
    let sat = saturation_rate(topology, k, cfg, seed)?;
    if !(sat.is_finite() && sat > 0.0) {
        return None;
    }
    let net = NopNetwork::build(topology, k);
    let zero_load = analytical_latency(&net, cfg, &uniform_nop_flows(k, 0.01));

    // Steady anchors: keep-first monotone filter over the raw sim points.
    let mut steady_anchors: Vec<(f64, f64)> = Vec::new();
    for frac in ANCHOR_FRACS {
        let rate = frac * sat;
        let stats = NopSim::new(
            topology,
            k,
            cfg,
            &uniform_nop_flows(k, rate),
            Mode::Steady {
                warmup: STEADY_WARMUP,
                measure: STEADY_MEASURE,
            },
            seed,
        )
        .run();
        if stats.delivered == 0 || !stats.avg_latency.is_finite() || stats.avg_latency <= 0.0 {
            continue;
        }
        match steady_anchors.last() {
            Some(&(_, prev)) if stats.avg_latency < prev => {}
            _ => steady_anchors.push((rate, stats.avg_latency)),
        }
    }
    if steady_anchors.len() < 2 {
        return None;
    }

    // Drain anchors: canonical scatter (chiplet 0 to every other chiplet,
    // equal split) at a ladder of total flit counts; each anchor stores
    // the sim-over-analytical-bound ratio in log-total-flits space.
    let mut drain_anchors: Vec<(f64, f64)> = Vec::new();
    for total in DRAIN_ANCHOR_TOTALS {
        let per = (total / (k as u64 - 1)).max(1);
        let flows: Vec<FlowSpec> = (1..k)
            .map(|c| FlowSpec {
                src: 0,
                dst: c,
                rate: 0.0,
                flits: per,
            })
            .collect();
        let actual_total = per * (k as u64 - 1);
        let budget =
            10_000 + actual_total.saturating_mul(4).saturating_mul(cfg.hop_latency_cycles + 2);
        let stats = super::memo::drain_makespan(topology, k, cfg, &flows, budget, seed);
        if !stats.drained {
            continue;
        }
        let bound = drain_bound(&net, cfg, &flows);
        if bound <= 0.0 {
            continue;
        }
        drain_anchors.push(((actual_total as f64).ln(), stats.makespan as f64 / bound));
    }

    Some(SurrogateModel {
        topology,
        k,
        sat_rate: sat,
        zero_load,
        steady_anchors,
        drain_anchors,
    })
}

/// Fetch (or fit and cache) the surrogate for this key. Lookups feed the
/// surrogate hit/miss profile counters; a miss fits under the
/// `surrogate.fit` phase timer and caches the outcome — including `None`,
/// so unfittable keys fail fast on every later query.
pub fn model_for(
    topology: NopTopology,
    k: usize,
    cfg: &NopConfig,
    seed: u64,
) -> Option<Arc<SurrogateModel>> {
    let key = sur_key(topology, k, cfg, seed);
    if let Some(hit) = sur_cache().lock().unwrap().get(&key).cloned() {
        profile::note_surrogate(true);
        return hit;
    }
    profile::note_surrogate(false);
    // Fit outside the lock (never hold it across a simulation); racing
    // workers may both fit, but the fits are deterministic and identical.
    let fitted = {
        let _t = profile::phase("surrogate.fit");
        fit_model(topology, k, cfg, seed)
    };
    let val = fitted.map(Arc::new);
    if val.is_some() {
        profile::note_surrogate_fit();
    }
    sur_cache().lock().unwrap().insert(key, val.clone());
    val
}

impl SurrogateModel {
    /// Steady average latency (cycles) at `rate` flits/chiplet/cycle.
    /// Exact at anchor rates, monotone non-decreasing everywhere, `None`
    /// at or beyond the saturation rate.
    pub fn steady_at(&self, rate: f64) -> Option<f64> {
        if !rate.is_finite() || rate >= self.sat_rate {
            return None;
        }
        let a = &self.steady_anchors;
        let (first_r, first_l) = a[0];
        if rate <= first_r {
            return Some(first_l);
        }
        for w in a.windows(2) {
            let (r0, l0) = w[0];
            let (r1, l1) = w[1];
            if rate == r1 {
                return Some(l1);
            }
            if rate < r1 {
                return Some(lerp(r0, l0, r1, l1, rate));
            }
        }
        // Past the last anchor: log-barrier tail continuing the last
        // segment's slope, diverging at the saturation rate.
        let (rm, lm) = a[a.len() - 2];
        let (rn, ln_) = a[a.len() - 1];
        let slope = ((ln_ - lm) / (rn - rm)).max(0.0);
        let beta = slope * (self.sat_rate - rn);
        Some(ln_ + beta * ((self.sat_rate - rn) / (self.sat_rate - rate)).ln())
    }

    /// Drain makespan estimate (cycles) for `flows`: the analytical bound
    /// scaled by the fitted sim/bound ratio at this total flit count.
    /// `Some(0)` for an empty (or all-self-loop) flow list, `None` when
    /// fewer than two drain anchors were usable.
    pub fn drain_at(&self, cfg: &NopConfig, flows: &[FlowSpec]) -> Option<u64> {
        let total: u64 = flows
            .iter()
            .filter(|f| f.src != f.dst)
            .map(|f| f.flits)
            .sum();
        if total == 0 {
            return Some(0);
        }
        if self.drain_anchors.len() < 2 {
            return None;
        }
        let net = NopNetwork::build(self.topology, self.k);
        let bound = drain_bound(&net, cfg, flows);
        let x = (total as f64).ln();
        let a = &self.drain_anchors;
        let ratio = if x <= a[0].0 {
            a[0].1
        } else if x >= a[a.len() - 1].0 {
            a[a.len() - 1].1
        } else {
            let w = a.windows(2).find(|w| x < w[1].0).unwrap_or(&a[a.len() - 2..]);
            lerp(w[0].0, w[0].1, w[1].0, w[1].1, x)
        };
        Some((ratio * bound).round().max(0.0) as u64)
    }

    /// Bit-exact serialization of the fitted curve (hex `f64::to_bits`),
    /// for determinism checks: two fits of the same key must match
    /// byte-for-byte.
    pub fn curve_bytes(&self) -> String {
        let mut out = format!(
            "{:016x}:{:016x}",
            self.sat_rate.to_bits(),
            self.zero_load.to_bits()
        );
        for (r, l) in &self.steady_anchors {
            out.push_str(&format!(";{:016x},{:016x}", r.to_bits(), l.to_bits()));
        }
        for (x, p) in &self.drain_anchors {
            out.push_str(&format!("|{:016x},{:016x}", x.to_bits(), p.to_bits()));
        }
        out
    }
}

/// Surrogate steady latency (cycles) for uniform traffic at `rate`, or
/// `None` (with a fallback count) when the key is unfittable or the rate
/// is at/past saturation — callers then run the full simulator.
pub fn steady_latency(
    topology: NopTopology,
    k: usize,
    cfg: &NopConfig,
    rate: f64,
    seed: u64,
) -> Option<f64> {
    let out = model_for(topology, k, cfg, seed).and_then(|m| m.steady_at(rate));
    if out.is_none() {
        profile::note_surrogate_fallback();
    }
    out
}

/// Surrogate drain makespan (cycles) for `flows`, or `None` (with a
/// fallback count) when the key or flow set is outside the fitted range —
/// callers then run the memoized full drain.
pub fn drain_estimate(
    topology: NopTopology,
    k: usize,
    cfg: &NopConfig,
    flows: &[FlowSpec],
    seed: u64,
) -> Option<u64> {
    let out = model_for(topology, k, cfg, seed).and_then(|m| m.drain_at(cfg, flows));
    if out.is_none() {
        profile::note_surrogate_fallback();
    }
    out
}

/// One held-out comparison point in a [`SurrogateCheck`].
#[derive(Clone, Debug)]
pub struct HoldoutPoint {
    /// Offered rate (flits/chiplet/cycle).
    pub rate: f64,
    /// Full-sim steady average latency (cycles).
    pub sim: f64,
    /// Surrogate steady latency (cycles).
    pub surrogate: f64,
    /// |surrogate − sim| / sim.
    pub rel_err: f64,
}

/// Sim-vs-surrogate validation record for one (topology, k) config:
/// held-out accuracy, anchor/fallback accounting and wall-clock for both
/// paths. Consumed by `repro chiplet --surrogate-check-out` and gated by
/// `scripts/check_surrogate.py`.
#[derive(Clone, Debug)]
pub struct SurrogateCheck {
    /// Config topology.
    pub topology: NopTopology,
    /// Config chiplet count.
    pub k: usize,
    /// Measured saturation rate the holdout grid is scaled by.
    pub sat_rate: f64,
    /// Surviving steady anchors in the fitted curve.
    pub steady_anchors: usize,
    /// Surviving drain anchors in the fitted curve.
    pub drain_anchors: usize,
    /// Holdout queries the surrogate refused (each one fell back to sim).
    pub fallbacks: usize,
    /// Wall-clock of the full-sim holdout runs (ns).
    pub sim_ns: u128,
    /// Wall-clock of the surrogate fit plus all holdout queries (ns).
    pub surrogate_ns: u128,
    /// Per-rate comparison points.
    pub holdout: Vec<HoldoutPoint>,
}

/// Number of held-out rates per config in [`check`].
pub const HOLDOUT_POINTS: usize = 40;

/// Run the sim-vs-surrogate comparison for one config: fit an uncached
/// surrogate, query it at [`HOLDOUT_POINTS`] rates spread over
/// `[0.10, 0.85] ×` saturation (none of which is an anchor), and time
/// both paths. The saturation search runs first, outside both timers —
/// it is memoized and shared by both paths, so charging it to either
/// would skew the ratio. `None` when saturation is unmeasurable.
pub fn check(
    topology: NopTopology,
    k: usize,
    cfg: &NopConfig,
    seed: u64,
) -> Option<SurrogateCheck> {
    let sat = saturation_rate(topology, k, cfg, seed)?;
    let rates: Vec<f64> = (0..HOLDOUT_POINTS)
        .map(|i| (0.10 + 0.75 * i as f64 / (HOLDOUT_POINTS - 1) as f64) * sat)
        .collect();

    let sur_start = std::time::Instant::now();
    let model = fit_model(topology, k, cfg, seed)?;
    let mut fallbacks = 0usize;
    let sur: Vec<Option<f64>> = rates
        .iter()
        .map(|&r| {
            let v = model.steady_at(r);
            if v.is_none() {
                fallbacks += 1;
            }
            v
        })
        .collect();
    let surrogate_ns = sur_start.elapsed().as_nanos();

    let sim_start = std::time::Instant::now();
    let sim: Vec<f64> = rates
        .iter()
        .map(|&r| {
            NopSim::new(
                topology,
                k,
                cfg,
                &uniform_nop_flows(k, r),
                Mode::Steady {
                    warmup: STEADY_WARMUP,
                    measure: STEADY_MEASURE,
                },
                seed,
            )
            .run()
            .avg_latency
        })
        .collect();
    let sim_ns = sim_start.elapsed().as_nanos();

    let holdout: Vec<HoldoutPoint> = rates
        .iter()
        .zip(sim.iter().zip(sur.iter()))
        .filter_map(|(&rate, (&s, &u))| {
            let u = u?;
            Some(HoldoutPoint {
                rate,
                sim: s,
                surrogate: u,
                rel_err: if s > 0.0 { (u - s).abs() / s } else { 0.0 },
            })
        })
        .collect();

    Some(SurrogateCheck {
        topology,
        k,
        sat_rate: sat,
        steady_anchors: model.steady_anchors.len(),
        drain_anchors: model.drain_anchors.len(),
        fallbacks,
        sim_ns,
        surrogate_ns,
        holdout,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> NopConfig {
        NopConfig::default()
    }

    #[test]
    fn surrogate_latency_is_monotone_in_offered_rate() {
        let model = fit_model(NopTopology::Mesh, 4, &cfg(), 0x5EED).expect("fittable");
        let mut prev = 0.0_f64;
        for i in 0..64 {
            let rate = model.sat_rate * (0.99 * i as f64 / 63.0);
            let lat = model.steady_at(rate).expect("below saturation");
            assert!(
                lat + 1e-9 >= prev,
                "latency fell from {prev} to {lat} at rate {rate}"
            );
            prev = lat;
        }
    }

    #[test]
    fn surrogate_matches_sim_exactly_at_anchor_rates() {
        let model = fit_model(NopTopology::Ring, 4, &cfg(), 0x5EED).expect("fittable");
        for &(rate, lat) in &model.steady_anchors {
            // Exact (bitwise) agreement with the stored anchor...
            assert_eq!(model.steady_at(rate), Some(lat));
            // ...which itself is the deterministic sim's own number.
            let direct = NopSim::new(
                NopTopology::Ring,
                4,
                &cfg(),
                &uniform_nop_flows(4, rate),
                Mode::Steady {
                    warmup: 500,
                    measure: 2_000,
                },
                0x5EED,
            )
            .run();
            assert_eq!(lat, direct.avg_latency, "anchor at rate {rate}");
        }
    }

    #[test]
    fn surrogate_holdout_error_within_5pct_k4_k16_ring_mesh() {
        for topo in [NopTopology::Ring, NopTopology::Mesh] {
            for k in [4usize, 16] {
                let model = fit_model(topo, k, &cfg(), 0x5EED)
                    .unwrap_or_else(|| panic!("{} k={k} must fit", topo.name()));
                for frac in [0.2, 0.5, 0.7] {
                    let rate = frac * model.sat_rate;
                    let sur = model.steady_at(rate).expect("below saturation");
                    let sim = NopSim::new(
                        topo,
                        k,
                        &cfg(),
                        &uniform_nop_flows(k, rate),
                        Mode::Steady {
                            warmup: 500,
                            measure: 2_000,
                        },
                        0x5EED,
                    )
                    .run()
                    .avg_latency;
                    let err = (sur - sim).abs() / sim;
                    assert!(
                        err <= 0.05,
                        "{} k={k} frac={frac}: surrogate {sur} vs sim {sim} ({:.1}% off)",
                        topo.name(),
                        100.0 * err
                    );
                }
            }
        }
    }

    #[test]
    fn fitted_curves_are_byte_identical_per_seed() {
        let a = fit_model(NopTopology::Mesh, 4, &cfg(), 0xD00D).expect("fittable");
        let b = fit_model(NopTopology::Mesh, 4, &cfg(), 0xD00D).expect("fittable");
        assert_eq!(a.curve_bytes(), b.curve_bytes());
        // The serialization is total: anchors, saturation and baseline.
        assert!(a.curve_bytes().len() > 32);
    }

    #[test]
    fn drain_estimate_tracks_memoized_sim() {
        let model = fit_model(NopTopology::Mesh, 4, &cfg(), 0x5EED).expect("fittable");
        // A non-anchor pattern: two disjoint transfers.
        let flows = [
            FlowSpec {
                src: 0,
                dst: 1,
                rate: 0.0,
                flits: 120,
            },
            FlowSpec {
                src: 2,
                dst: 3,
                rate: 0.0,
                flits: 77,
            },
        ];
        let est = model.drain_at(&cfg(), &flows).expect("anchored") as f64;
        let budget = 10_000 + 197 * 4 * (cfg().hop_latency_cycles + 2);
        let sim = crate::sim::memo::drain_makespan(
            NopTopology::Mesh,
            4,
            &cfg(),
            &flows,
            budget,
            0x5EED,
        );
        assert!(sim.drained);
        let ratio = est / sim.makespan as f64;
        assert!(
            (0.5..=2.0).contains(&ratio),
            "drain estimate {est} vs sim {} (ratio {ratio})",
            sim.makespan
        );
        // Empty flow lists price to zero without falling back.
        assert_eq!(model.drain_at(&cfg(), &[]), Some(0));
    }

    #[test]
    fn unfittable_configs_fall_back() {
        // k = 1: no network to saturate.
        assert!(steady_latency(NopTopology::Mesh, 1, &cfg(), 0.1, 1).is_none());
        assert!(drain_estimate(NopTopology::Mesh, 1, &cfg(), &[], 1).is_none());
        // At or past saturation the steady curve refuses.
        let model = fit_model(NopTopology::Ring, 4, &cfg(), 0x5EED).expect("fittable");
        assert!(model.steady_at(model.sat_rate).is_none());
        assert!(model.steady_at(model.sat_rate * 1.5).is_none());
    }

    #[test]
    fn model_for_caches_process_wide() {
        let cfg = cfg();
        // Distinct seed to avoid cross-test interference on the shared
        // cache; first call misses and fits, second hits.
        let a = model_for(NopTopology::Mesh, 4, &cfg, 0xCAC4E).expect("fittable");
        let b = model_for(NopTopology::Mesh, 4, &cfg, 0xCAC4E).expect("fittable");
        assert_eq!(a.curve_bytes(), b.curve_bytes());
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
    }

    #[test]
    fn check_produces_gateable_record() {
        let rec = check(NopTopology::Mesh, 4, &cfg(), 0x5EED).expect("measurable");
        assert_eq!(rec.holdout.len(), HOLDOUT_POINTS);
        assert_eq!(rec.fallbacks, 0, "holdout grid stays below saturation");
        assert!(rec.steady_anchors >= 2);
        assert!(rec.sat_rate > 0.0);
        for p in &rec.holdout {
            assert!(p.rate < rec.sat_rate);
            assert!(p.sim > 0.0 && p.surrogate > 0.0);
        }
    }
}
