//! The generic flit-level event engine.
//!
//! [`EngineCore`] owns every piece of simulator state that is independent
//! of the network fabric: the operating [`Mode`], the per-source traffic
//! generators, the PCG32 stream, the simulation clock, warm-up gating,
//! in-flight accounting, the aggregated [`SimStats`] and the optional
//! telemetry sink. A fabric (the NoC router mesh or the NoP SerDes graph)
//! implements [`Fabric`] and is stepped by [`run_engine`], which provides
//! the two canonical run loops:
//!
//! * **Steady** — warm up, then measure for a fixed window, one cycle per
//!   iteration.
//! * **Drain** — run until every generated flit is delivered (or the cycle
//!   budget is exhausted), jumping the clock straight to the next
//!   scheduled arrival whenever all traffic is mid-flight
//!   ([`Fabric::queued_work`] / [`Fabric::next_arrival`] — the
//!   event-skipping idiom that makes long-latency package hops cheap).
//!
//! In-flight messages carry their origin (`src`, `dst`, `born`); the
//! route-progress state (cursor, per-hop countdown) lives in the fabric,
//! which knows its own link geometry. Both adapters feed deliveries back
//! through [`EngineCore::deliver`] so latency, makespan and per-pair
//! statistics are computed in exactly one place.

use std::collections::{HashMap, VecDeque};

use crate::telemetry::SimTelemetry;
use crate::util::Pcg32;

/// One source→destination traffic specification.
#[derive(Clone, Copy, Debug)]
pub struct FlowSpec {
    /// Source terminal (tile or chiplet id).
    pub src: usize,
    /// Destination terminal (tile or chiplet id).
    pub dst: usize,
    /// Injection rate in flits/cycle (steady mode).
    pub rate: f64,
    /// Total flits to send (drain mode); ignored in steady mode.
    pub flits: u64,
}

/// Simulation mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Bernoulli injection; warm up, then measure for a fixed window.
    Steady {
        /// Warm-up cycles excluded from statistics.
        warmup: u64,
        /// Measured cycles after warm-up.
        measure: u64,
    },
    /// Inject `FlowSpec::flits` per pair, run until drained (or `max_cycles`).
    Drain {
        /// Cycle budget after which an undrained run is abandoned.
        max_cycles: u64,
    },
}

impl Mode {
    /// Is this the Bernoulli steady-state mode?
    #[inline]
    pub fn is_steady(&self) -> bool {
        matches!(self, Mode::Steady { .. })
    }
}

/// Aggregated results of one simulation run.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    /// Cycles simulated.
    pub cycles: u64,
    /// Flits injected into source FIFOs.
    pub injected: u64,
    /// Flits delivered to their destination terminal.
    pub delivered: u64,
    /// Mean flit latency (generation → ejection), cycles.
    pub avg_latency: f64,
    /// Worst flit latency, cycles.
    pub max_latency: u64,
    /// Drain mode: cycle at which the last flit ejected.
    pub makespan: u64,
    /// Drain mode: did the network fully drain within the cycle budget?
    pub drained: bool,
    /// Router-buffer arrivals observed (occupancy sampling, Fig. 13).
    pub arrivals: u64,
    /// Arrivals that found the target queue empty.
    pub arrivals_zero: u64,
    /// Sum of occupancies for arrivals at non-empty queues (Fig. 14).
    pub nonzero_occ_sum: f64,
    /// Count of arrivals at non-empty queues (Fig. 14).
    pub nonzero_occ_count: u64,
    /// Per-pair latency stats, keyed by `(src << 32) | dst` (Fig. 15 /
    /// Table 3). Only filled when `track_pairs` is enabled.
    pub per_pair: HashMap<u64, PairStat>,
    /// Head-of-line blocked flit-cycles per flow, keyed like `per_pair`:
    /// cycles a flow's head flit sat ready-to-move but stalled on a busy
    /// link or full downstream buffer. Only filled when the attribution
    /// hook is armed (`.attribute(true)` on the simulator builders);
    /// purely observational — never feeds back into simulated outcomes.
    pub flow_waits: HashMap<u64, u64>,
}

/// Latency statistics for one source–destination pair.
#[derive(Clone, Copy, Debug, Default)]
pub struct PairStat {
    /// Flits delivered for this pair.
    pub count: u64,
    /// Sum of per-flit latencies, cycles.
    pub sum_latency: u64,
    /// Worst per-flit latency, cycles.
    pub max_latency: u64,
}

impl PairStat {
    /// Mean flit latency for this pair, cycles.
    pub fn avg(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_latency as f64 / self.count as f64
        }
    }
}

impl SimStats {
    /// Fraction of buffer arrivals that found the queue empty (Fig. 13).
    pub fn zero_occupancy_fraction(&self) -> f64 {
        if self.arrivals == 0 {
            1.0
        } else {
            self.arrivals_zero as f64 / self.arrivals as f64
        }
    }

    /// Mean occupancy of non-empty queues at arrival (Fig. 14).
    pub fn mean_nonzero_occupancy(&self) -> f64 {
        if self.nonzero_occ_count == 0 {
            0.0
        } else {
            self.nonzero_occ_sum / self.nonzero_occ_count as f64
        }
    }
}

/// Per-source injection state: either a Bernoulli process over a dst
/// distribution (steady) or a finite interleaved flit list (drain).
#[derive(Default)]
pub(crate) struct SourceState {
    /// Aggregate injection rate (steady).
    pub(crate) rate: f64,
    /// Destination CDF for steady mode: (cumulative rate, dst).
    pub(crate) dst_cdf: Vec<(f64, u32)>,
    /// Remaining (dst, count) entries for drain mode, drawn round-robin.
    pub(crate) pending: Vec<(u32, u64)>,
    pub(crate) next_pending: usize,
    /// Generated-but-not-yet-injected flits (unbounded source FIFO),
    /// stored as (dst, born).
    pub(crate) fifo: VecDeque<(u32, u64)>,
}

/// Fabric-independent simulator state: mode, clock, RNG, traffic sources,
/// statistics and telemetry. Both `NocSim` and `NopSim` embed one of these
/// and keep only topology/link state of their own.
pub(crate) struct EngineCore {
    pub(crate) mode: Mode,
    pub(crate) sources: Vec<SourceState>,
    pub(crate) rng: Pcg32,
    pub(crate) track_pairs: bool,
    /// Arm the per-flow head-of-line blocking attribution hook
    /// ([`EngineCore::note_blocked`]); off by default so the hot switching
    /// loops pay one branch per stalled head flit and allocate nothing.
    pub(crate) attrib: bool,
    pub(crate) stats: SimStats,
    pub(crate) now: u64,
    pub(crate) in_warmup: bool,
    /// Flits generated but not yet delivered.
    pub(crate) in_flight: u64,
    /// Drain mode: flits not yet generated.
    pub(crate) ungenerated: u64,
    /// Telemetry sink, collected only when instrumented (boxed so the
    /// disabled path stays one pointer wide).
    pub(crate) telem: Option<Box<SimTelemetry>>,
}

impl EngineCore {
    /// Group `flows` by source, apply the saturation guard (a terminal
    /// injects at most one flit per cycle — rates above 1.0 are clamped
    /// and the destination CDF rescaled), and seed the PCG32 stream.
    /// Self-flows never enter the network.
    pub(crate) fn new(terminals: usize, flows: &[FlowSpec], mode: Mode, seed: u64) -> Self {
        let mut sources: Vec<SourceState> =
            (0..terminals).map(|_| SourceState::default()).collect();
        for f in flows {
            assert!(
                f.src < terminals && f.dst < terminals,
                "flow endpoint out of range"
            );
            if f.src == f.dst {
                continue; // intra-terminal traffic never enters the network
            }
            let s = &mut sources[f.src];
            s.rate += f.rate;
            s.dst_cdf.push((s.rate, f.dst as u32));
            if f.flits > 0 {
                s.pending.push((f.dst as u32, f.flits));
            }
        }
        // Saturation guard: clamp aggregate per-source rate at 1 flit/cycle.
        for s in &mut sources {
            if s.rate > 1.0 {
                let scale = 1.0 / s.rate;
                for e in &mut s.dst_cdf {
                    e.0 *= scale;
                }
                s.rate = 1.0;
            }
        }
        let ungenerated: u64 = sources
            .iter()
            .flat_map(|s| s.pending.iter().map(|&(_, c)| c))
            .sum();
        let steady = mode.is_steady();
        Self {
            mode,
            sources,
            rng: Pcg32::seeded(seed),
            track_pairs: false,
            attrib: false,
            stats: SimStats::default(),
            now: 0,
            in_warmup: steady,
            in_flight: 0,
            ungenerated,
            telem: None,
        }
    }

    /// Steady-mode generation for terminal `t`: one Bernoulli trial at the
    /// aggregate source rate, destination drawn from the per-source CDF by
    /// binary search. Generated flits land in the source FIFO.
    pub(crate) fn generate_steady(&mut self, t: usize) {
        let s = &mut self.sources[t];
        if s.rate > 0.0 && self.rng.bernoulli(s.rate) {
            let u = self.rng.next_f64() * s.rate;
            let dst = match s
                .dst_cdf
                .binary_search_by(|probe| probe.0.partial_cmp(&u).unwrap())
            {
                Ok(i) => s.dst_cdf[(i + 1).min(s.dst_cdf.len() - 1)].1,
                Err(i) => s.dst_cdf[i.min(s.dst_cdf.len() - 1)].1,
            };
            s.fifo.push_back((dst, self.now));
            self.stats.injected += 1;
            self.in_flight += 1;
            if let Some(tm) = &mut self.telem {
                tm.injected[t] += 1;
            }
        }
    }

    /// Drain-mode generation for terminal `t`: keep the source FIFO primed
    /// with the next flit, round-robin across the pending destination
    /// entries. No-op while the FIFO holds a flit or nothing remains.
    pub(crate) fn generate_drain(&mut self, t: usize) {
        if !self.sources[t].fifo.is_empty() || self.sources[t].pending.is_empty() {
            return;
        }
        let s = &mut self.sources[t];
        let k = s.next_pending % s.pending.len();
        let (dst, remaining) = s.pending[k];
        s.fifo.push_back((dst, self.now));
        self.stats.injected += 1;
        self.in_flight += 1;
        self.ungenerated -= 1;
        if let Some(tm) = &mut self.telem {
            tm.injected[t] += 1;
        }
        if remaining <= 1 {
            s.pending.swap_remove(k);
        } else {
            s.pending[k].1 = remaining - 1;
        }
        s.next_pending = s.next_pending.wrapping_add(1);
    }

    /// Record a delivery: latency (generation → ejection, inclusive),
    /// makespan, telemetry ejection counters and optional per-pair stats.
    /// Warm-up deliveries only settle the in-flight accounting.
    pub(crate) fn deliver(&mut self, src: u32, dst: u32, born: u64) {
        let latency = self.now - born + 1;
        self.in_flight -= 1;
        if self.in_warmup {
            return;
        }
        self.stats.delivered += 1;
        if let Some(tm) = &mut self.telem {
            tm.ejected[dst as usize] += 1;
        }
        self.stats.avg_latency += latency as f64; // running sum; divided at end
        self.stats.max_latency = self.stats.max_latency.max(latency);
        self.stats.makespan = self.now + 1;
        if self.track_pairs {
            let key = ((src as u64) << 32) | dst as u64;
            let p = self.stats.per_pair.entry(key).or_default();
            p.count += 1;
            p.sum_latency += latency;
            p.max_latency = p.max_latency.max(latency);
        }
    }

    /// Attribution hook: flow `src → dst`'s head flit was ready to move
    /// this cycle but blocked on a busy link or full downstream buffer.
    /// No-op unless armed via the simulator builders' `.attribute(true)`
    /// (and never during warm-up), so the default path is one branch.
    pub(crate) fn note_blocked(&mut self, src: u32, dst: u32) {
        if !self.attrib || self.in_warmup {
            return;
        }
        let key = ((src as u64) << 32) | dst as u64;
        *self.stats.flow_waits.entry(key).or_insert(0) += 1;
    }

    /// Arrival-time occupancy sampling (Fig. 13/14) — no-op during warm-up.
    pub(crate) fn sample_occupancy(&mut self, occ: usize) {
        if self.in_warmup {
            return;
        }
        self.stats.arrivals += 1;
        if occ == 0 {
            self.stats.arrivals_zero += 1;
        } else {
            self.stats.nonzero_occ_sum += occ as f64;
            self.stats.nonzero_occ_count += 1;
        }
        if let Some(tm) = &mut self.telem {
            tm.occupancy.record(occ as f64);
        }
    }

    /// Any flits anywhere (source FIFOs, pending lists, fabric buffers)?
    #[inline]
    pub(crate) fn busy(&self) -> bool {
        self.in_flight > 0 || self.ungenerated > 0
    }

    /// Extract the telemetry sink (empty unless instrumented), stamping the
    /// final cycle count. Call after [`run_engine`].
    pub(crate) fn take_telem(&mut self) -> SimTelemetry {
        let mut telem = match self.telem.take() {
            Some(b) => *b,
            None => SimTelemetry::default(),
        };
        telem.cycles = self.stats.cycles;
        telem
    }
}

/// What a network fabric must provide to be driven by [`run_engine`].
/// The fabric owns buffers, links and routing; the core owns everything
/// else and is handed in mutably each cycle.
pub(crate) trait Fabric {
    /// Simulate one cycle at `core.now`: deliver due arrivals, generate and
    /// inject traffic, switch/forward flits. Deliveries go through
    /// [`EngineCore::deliver`].
    fn step(&mut self, core: &mut EngineCore);

    /// Is any flit sitting in a buffer or source queue (i.e. work may be
    /// possible next cycle, as opposed to everything being mid-flight)?
    /// Fabrics with single-cycle links never idle-wait and keep the
    /// default.
    fn queued_work(&self, core: &EngineCore) -> bool {
        let _ = core;
        true
    }

    /// Next scheduled in-flight arrival cycle, if any — the drain clock
    /// jumps straight to it when no queued work remains.
    fn next_arrival(&self) -> Option<u64> {
        None
    }

    /// Report a head-of-line blocked flit to the attribution hook.
    /// Fabrics call this from their switching loops when a head flit
    /// cannot advance; the default forwards to
    /// [`EngineCore::note_blocked`], which is gated on the arm flag.
    fn note_blocked(&self, core: &mut EngineCore, src: u32, dst: u32) {
        core.note_blocked(src, dst);
    }
}

/// Run `fab` to completion per `core.mode`, then finalize the statistics
/// (cycle count, latency mean). This is the one event loop both simulators
/// share.
pub(crate) fn run_engine<F: Fabric>(core: &mut EngineCore, fab: &mut F) {
    match core.mode {
        Mode::Steady { warmup, measure } => {
            let end = warmup + measure;
            while core.now < end {
                core.in_warmup = core.now < warmup;
                fab.step(core);
                core.now += 1;
            }
        }
        Mode::Drain { max_cycles } => {
            core.in_warmup = false;
            while core.busy() && core.now < max_cycles {
                fab.step(core);
                if fab.queued_work(core) {
                    core.now += 1;
                } else if let Some(t) = fab.next_arrival() {
                    // Everything is mid-flight: jump to the next event.
                    core.now = t.max(core.now + 1);
                } else {
                    break;
                }
            }
            core.stats.drained = !core.busy();
        }
    }
    core.stats.cycles = core.now;
    if core.stats.delivered > 0 {
        core.stats.avg_latency /= core.stats.delivered as f64;
    }
    crate::telemetry::profile::note_engine_run(core.stats.cycles);
}

/// Uniform-random all-to-all traffic at `rate_per_terminal` flits per
/// terminal per cycle, split evenly over the other terminals.
pub(crate) fn uniform_flows(terminals: usize, rate_per_terminal: f64) -> Vec<FlowSpec> {
    let mut flows = Vec::new();
    if terminals < 2 {
        return flows;
    }
    let pair_rate = rate_per_terminal / (terminals - 1) as f64;
    for s in 0..terminals {
        for d in 0..terminals {
            if s != d {
                flows.push(FlowSpec {
                    src: s,
                    dst: d,
                    rate: pair_rate,
                    flits: 0,
                });
            }
        }
    }
    flows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn source_grouping_and_saturation_guard() {
        let flows = [
            FlowSpec {
                src: 0,
                dst: 1,
                rate: 0.9,
                flits: 5,
            },
            FlowSpec {
                src: 0,
                dst: 2,
                rate: 0.9,
                flits: 7,
            },
            FlowSpec {
                src: 1,
                dst: 1, // self-flow: ignored
                rate: 0.5,
                flits: 10,
            },
        ];
        let core = EngineCore::new(3, &flows, Mode::Drain { max_cycles: 10 }, 1);
        // Source 0: rate clamped to 1.0, CDF rescaled, both drain entries.
        assert!((core.sources[0].rate - 1.0).abs() < 1e-12);
        let cdf = &core.sources[0].dst_cdf;
        assert_eq!(cdf.len(), 2);
        assert!((cdf[0].0 - 0.5).abs() < 1e-12);
        assert!((cdf[1].0 - 1.0).abs() < 1e-12);
        assert_eq!(core.sources[0].pending, vec![(1, 5), (2, 7)]);
        // Self-flow contributed nothing.
        assert!(core.sources[1].pending.is_empty());
        assert_eq!(core.ungenerated, 12);
    }

    #[test]
    fn drain_generation_round_robins_destinations() {
        let flows = [
            FlowSpec {
                src: 0,
                dst: 1,
                rate: 0.0,
                flits: 2,
            },
            FlowSpec {
                src: 0,
                dst: 2,
                rate: 0.0,
                flits: 1,
            },
        ];
        let mut core = EngineCore::new(3, &flows, Mode::Drain { max_cycles: 10 }, 1);
        let mut order = Vec::new();
        for _ in 0..3 {
            core.generate_drain(0);
            let (dst, _) = core.sources[0].fifo.pop_back().unwrap();
            order.push(dst);
        }
        assert_eq!(order, vec![1, 2, 1]);
        assert_eq!(core.ungenerated, 0);
        assert_eq!(core.stats.injected, 3);
        // Nothing left: further calls are no-ops.
        core.generate_drain(0);
        assert_eq!(core.stats.injected, 3);
    }

    #[test]
    fn note_blocked_is_gated_on_arm_flag_and_warmup() {
        let flows = [FlowSpec {
            src: 0,
            dst: 1,
            rate: 0.5,
            flits: 0,
        }];
        let mode = Mode::Steady {
            warmup: 10,
            measure: 10,
        };
        let mut core = EngineCore::new(2, &flows, mode, 1);
        // Disarmed (the default): hook is a no-op.
        core.in_warmup = false;
        core.note_blocked(0, 1);
        assert!(core.stats.flow_waits.is_empty());
        // Armed but warming up: still a no-op.
        core.attrib = true;
        core.in_warmup = true;
        core.note_blocked(0, 1);
        assert!(core.stats.flow_waits.is_empty());
        // Armed and measuring: flit-cycles accumulate per flow key.
        core.in_warmup = false;
        core.note_blocked(0, 1);
        core.note_blocked(0, 1);
        core.note_blocked(1, 0);
        assert_eq!(core.stats.flow_waits.get(&1), Some(&2));
        assert_eq!(core.stats.flow_waits.get(&(1u64 << 32)), Some(&1));
    }

    #[test]
    fn deliver_skips_statistics_during_warmup() {
        let mut core = EngineCore::new(
            2,
            &[FlowSpec {
                src: 0,
                dst: 1,
                rate: 0.5,
                flits: 0,
            }],
            Mode::Steady {
                warmup: 10,
                measure: 10,
            },
            1,
        );
        core.in_flight = 2;
        core.now = 3;
        core.deliver(0, 1, 1);
        assert_eq!(core.stats.delivered, 0, "warm-up delivery must not count");
        core.in_warmup = false;
        core.now = 7;
        core.deliver(0, 1, 2);
        assert_eq!(core.stats.delivered, 1);
        assert_eq!(core.stats.max_latency, 6);
        assert_eq!(core.stats.makespan, 8);
        assert_eq!(core.in_flight, 0);
    }
}
