//! Process-wide memo caches for simulator-backed sweeps.
//!
//! Both caches exploit the same fact: a simulation result is a pure
//! function of the handful of inputs the simulator actually reads.
//! `NopSim` dynamics depend only on the topology, the chiplet count,
//! `hop_latency_cycles`, `buffer_flits`, the flow list and the seed —
//! every other `NopConfig` field (link width, frequency, energy) is
//! applied by callers after the fact. Sweeps, the advisor, serving-model
//! builds and the benches repeatedly evaluate identical points; keying on
//! exactly those inputs lets every repeat hit a `HashMap` instead of
//! re-simulating thousands of cycles.
//!
//! The caches live behind `OnceLock<Mutex<…>>` so concurrent
//! [`crate::coordinator::par_map`] workers share them. The lock is never
//! held across a simulation: two workers racing on the same key may both
//! compute it (identical results — the sims are deterministic), but
//! neither ever blocks behind a multi-millisecond run.
//!
//! Both caches are bounded ([`DRAIN_CACHE_CAP`] / [`SAT_CACHE_CAP`]) by
//! an [`LruCache`]: every hit promotes its entry, and an insertion at
//! capacity evicts the least-recently-used resident, so the hot keys of a
//! sweep survive even when the sweep's total working set exceeds the
//! bound. Eviction is deterministic (oldest access stamp loses; ties are
//! impossible because stamps are a monotone counter), and safe because a
//! cache hit and a re-simulation are identical by the identity contract
//! below. Lookups, insertions and evictions feed the
//! [`crate::telemetry::profile`] counters (`repro … --profile`).

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Mutex, OnceLock};

use super::engine::{FlowSpec, Mode, SimStats};
use crate::config::NopConfig;
use crate::nop::topology::NopTopology;
use crate::telemetry::profile;

/// Maximum resident drain-run results; the least-recently-used entry is
/// evicted per insertion beyond this.
pub(crate) const DRAIN_CACHE_CAP: usize = 256;

/// Maximum resident saturation-search results.
pub(crate) const SAT_CACHE_CAP: usize = 256;

/// A bounded map with least-recently-used eviction.
///
/// Entries carry an access stamp from a monotone counter; `get` promotes
/// (re-stamps) its entry and `insert` at capacity scans for the minimum
/// stamp and evicts it. The linear victim scan is O(len) but the caches
/// are small (≤ 256 entries) and insertions already paid for a
/// multi-millisecond simulation, so a list-based O(1) LRU would be
/// complexity without measurable payoff.
pub(crate) struct LruCache<K, V> {
    map: HashMap<K, (V, u64)>,
    cap: usize,
    tick: u64,
}

impl<K: Hash + Eq + Clone, V> LruCache<K, V> {
    /// An empty cache holding at most `cap` entries.
    pub(crate) fn new(cap: usize) -> Self {
        assert!(cap > 0, "LRU capacity must be positive");
        LruCache {
            map: HashMap::new(),
            cap,
            tick: 0,
        }
    }

    fn next_stamp(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Look up `key`, promoting it to most-recently-used on a hit.
    pub(crate) fn get(&mut self, key: &K) -> Option<&V> {
        let stamp = self.next_stamp();
        let (val, at) = self.map.get_mut(key)?;
        *at = stamp;
        Some(val)
    }

    /// Insert `(key, val)`; when `key` is absent and the cache is full,
    /// evict the least-recently-used resident first. Returns whether an
    /// eviction happened (so callers can bump the profile counter for
    /// their cache).
    pub(crate) fn insert(&mut self, key: K, val: V) -> bool {
        let mut evicted = false;
        if self.map.len() >= self.cap && !self.map.contains_key(&key) {
            if let Some(victim) = self
                .map
                .iter()
                .min_by_key(|(_, (_, at))| *at)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&victim);
                evicted = true;
            }
        }
        let stamp = self.next_stamp();
        self.map.insert(key, (val, stamp));
        evicted
    }

    /// Resident entry count.
    #[cfg(test)]
    pub(crate) fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether `key` is resident, without promoting it.
    #[cfg(test)]
    pub(crate) fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }
}

/// Drain-run cache key: (topology, chiplets, hop latency, buffer depth,
/// cycle budget, seed, cross-chiplet flow list in caller order). The flow
/// list is kept **in order** — drain sources round-robin over their
/// pending entries in insertion order, so reordered flow lists are
/// genuinely different workloads and must not collide.
type DrainKey = (u8, usize, u64, usize, u64, u64, Vec<(u32, u32, u64)>);

static DRAIN_CACHE: OnceLock<Mutex<LruCache<DrainKey, SimStats>>> = OnceLock::new();

fn drain_cache() -> &'static Mutex<LruCache<DrainKey, SimStats>> {
    DRAIN_CACHE.get_or_init(|| Mutex::new(LruCache::new(DRAIN_CACHE_CAP)))
}

/// Run (or recall) an uninstrumented `NopSim` drain of `flows` on
/// `topology` × `k` and return its [`SimStats`]. Results are memoized
/// process-wide on everything the simulator reads, so sweeping the same
/// (partition, topology) point across experiments, the advisor and the
/// benches pays for the simulation once.
pub fn drain_makespan(
    topology: NopTopology,
    k: usize,
    cfg: &NopConfig,
    flows: &[FlowSpec],
    max_cycles: u64,
    seed: u64,
) -> SimStats {
    let fl: Vec<(u32, u32, u64)> = flows
        .iter()
        .filter(|f| f.src != f.dst && f.flits > 0)
        .map(|f| (f.src as u32, f.dst as u32, f.flits))
        .collect();
    let key = (
        topology as u8,
        k,
        cfg.hop_latency_cycles,
        cfg.buffer_flits,
        max_cycles,
        seed,
        fl,
    );
    if let Some(hit) = drain_cache().lock().unwrap().get(&key).cloned() {
        profile::note_drain(true);
        return hit;
    }
    profile::note_drain(false);
    // Attribution is always armed here: it only fills `flow_waits`
    // (observational), so the memoized result stays bit-identical to an
    // unattributed run on every simulated outcome.
    let stats = crate::nop::sim::NopSim::new(
        topology,
        k,
        cfg,
        flows,
        Mode::Drain { max_cycles },
        seed,
    )
    .attribute(true)
    .run();
    if drain_cache().lock().unwrap().insert(key, stats.clone()) {
        profile::note_drain_eviction();
    }
    stats
}

/// Saturation-search cache key: (topology, chiplets, hop latency, buffer
/// depth, seed) — the full input set of
/// [`crate::nop::sim::saturation_rate`].
type SatKey = (u8, usize, u64, usize, u64);

static SAT_CACHE: OnceLock<Mutex<LruCache<SatKey, Option<f64>>>> = OnceLock::new();

fn sat_cache() -> &'static Mutex<LruCache<SatKey, Option<f64>>> {
    SAT_CACHE.get_or_init(|| Mutex::new(LruCache::new(SAT_CACHE_CAP)))
}

/// Memoize a saturation search: return the cached rate for this
/// (topology, k, cfg, seed) point or run `compute` and remember it.
pub(crate) fn memo_saturation(
    topology: NopTopology,
    k: usize,
    cfg: &NopConfig,
    seed: u64,
    compute: impl FnOnce() -> Option<f64>,
) -> Option<f64> {
    let key = (
        topology as u8,
        k,
        cfg.hop_latency_cycles,
        cfg.buffer_flits,
        seed,
    );
    if let Some(&hit) = sat_cache().lock().unwrap().get(&key) {
        profile::note_sat(true);
        return hit;
    }
    profile::note_sat(false);
    let val = compute();
    if sat_cache().lock().unwrap().insert(key, val) {
        profile::note_sat_eviction();
    }
    val
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memoized_drain_is_identical_to_direct_simulation() {
        // Identity contract: the cache must be invisible — first call
        // (miss), second call (hit) and a direct `NopSim` run all agree
        // on every statistic.
        let cfg = NopConfig::default();
        let flows = [
            FlowSpec {
                src: 0,
                dst: 3,
                rate: 0.0,
                flits: 90,
            },
            FlowSpec {
                src: 2,
                dst: 1,
                rate: 0.0,
                flits: 41,
            },
        ];
        let budget = 200_000;
        let first = drain_makespan(NopTopology::Mesh, 4, &cfg, &flows, budget, 0xA5);
        let second = drain_makespan(NopTopology::Mesh, 4, &cfg, &flows, budget, 0xA5);
        let direct = crate::nop::sim::NopSim::new(
            NopTopology::Mesh,
            4,
            &cfg,
            &flows,
            Mode::Drain { max_cycles: budget },
            0xA5,
        )
        .run();
        assert!(direct.drained);
        for s in [&first, &second] {
            assert_eq!(s.makespan, direct.makespan);
            assert_eq!(s.injected, direct.injected);
            assert_eq!(s.delivered, direct.delivered);
            assert_eq!(s.drained, direct.drained);
            assert_eq!(s.cycles, direct.cycles);
            assert_eq!(s.avg_latency, direct.avg_latency);
            assert_eq!(s.max_latency, direct.max_latency);
        }
    }

    #[test]
    fn reordered_flow_lists_do_not_collide() {
        // Drain priming round-robins over pending entries in insertion
        // order, so [a, b] and [b, a] are different workloads; the cache
        // must key on the ordered list.
        let cfg = NopConfig::default();
        let ab = [
            FlowSpec {
                src: 0,
                dst: 1,
                rate: 0.0,
                flits: 30,
            },
            FlowSpec {
                src: 0,
                dst: 2,
                rate: 0.0,
                flits: 60,
            },
        ];
        let ba = [ab[1], ab[0]];
        let budget = 100_000;
        let fwd = drain_makespan(NopTopology::Ring, 3, &cfg, &ab, budget, 7);
        let rev = drain_makespan(NopTopology::Ring, 3, &cfg, &ba, budget, 7);
        let rev_direct = crate::nop::sim::NopSim::new(
            NopTopology::Ring,
            3,
            &cfg,
            &ba,
            Mode::Drain { max_cycles: budget },
            7,
        )
        .run();
        assert_eq!(fwd.injected, rev.injected);
        assert_eq!(rev.makespan, rev_direct.makespan);
        assert_eq!(rev.avg_latency, rev_direct.avg_latency);
    }

    #[test]
    fn bounded_insert_evicts_at_capacity_only() {
        let mut lru: LruCache<u32, u32> = LruCache::new(3);
        assert!(!lru.insert(1, 10));
        assert!(!lru.insert(2, 20));
        assert!(!lru.insert(3, 30));
        assert_eq!(lru.len(), 3);
        // Overwriting a resident key at capacity evicts nothing.
        assert!(!lru.insert(2, 21));
        assert_eq!(lru.len(), 3);
        assert_eq!(lru.get(&2), Some(&21));
        // A fresh key at capacity evicts exactly one resident entry, and
        // the victim is the least recently used: key 1 was inserted first
        // and never touched since (2 and 3 were both used after it).
        assert!(lru.insert(4, 40));
        assert_eq!(lru.len(), 3);
        assert_eq!(lru.get(&4), Some(&40));
        assert!(!lru.contains(&1), "LRU victim must be the cold key");
        assert!(lru.contains(&3));
    }

    #[test]
    fn lru_keeps_hot_keys_through_capacity_churn() {
        // The sweep pattern the LRU exists for: one hot key is re-read
        // between bursts of one-shot keys. Under churn far past capacity
        // the hot key must stay resident the whole time, and exactly the
        // overflow count must have been evicted.
        let mut lru: LruCache<u32, u32> = LruCache::new(8);
        let hot = 9999;
        assert!(!lru.insert(hot, 1));
        let mut evictions = 0u32;
        for cold in 0..64 {
            if lru.insert(cold, cold) {
                evictions += 1;
            }
            assert_eq!(
                lru.get(&hot),
                Some(&1),
                "hot key evicted after {cold} cold inserts"
            );
        }
        assert_eq!(lru.len(), 8);
        // 65 distinct keys through an 8-slot cache: 64 - 7 cold
        // evictions (the hot key is never the minimum stamp).
        assert_eq!(evictions, 64 - 7);
        assert!(lru.contains(&hot));
    }

    #[test]
    fn saturation_memo_returns_cached_value() {
        let cfg = NopConfig::default();
        let mut calls = 0;
        let probe = |calls: &mut usize| {
            *calls += 1;
            Some(0.42)
        };
        // Unlikely-to-collide key for this test: k = 0 never occurs in
        // real searches (saturation_rate returns None below k = 2).
        let a = memo_saturation(NopTopology::P2p, 0, &cfg, u64::MAX, || probe(&mut calls));
        let b = memo_saturation(NopTopology::P2p, 0, &cfg, u64::MAX, || probe(&mut calls));
        assert_eq!(a, Some(0.42));
        assert_eq!(b, Some(0.42));
        assert_eq!(calls, 1, "second lookup must hit the cache");
    }
}
