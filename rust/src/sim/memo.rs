//! Process-wide memo caches for simulator-backed sweeps.
//!
//! Both caches exploit the same fact: a simulation result is a pure
//! function of the handful of inputs the simulator actually reads.
//! `NopSim` dynamics depend only on the topology, the chiplet count,
//! `hop_latency_cycles`, `buffer_flits`, the flow list and the seed —
//! every other `NopConfig` field (link width, frequency, energy) is
//! applied by callers after the fact. Sweeps, the advisor, serving-model
//! builds and the benches repeatedly evaluate identical points; keying on
//! exactly those inputs lets every repeat hit a `HashMap` instead of
//! re-simulating thousands of cycles.
//!
//! The caches live behind `OnceLock<Mutex<…>>` so concurrent
//! [`crate::coordinator::par_map`] workers share them. The lock is never
//! held across a simulation: two workers racing on the same key may both
//! compute it (identical results — the sims are deterministic), but
//! neither ever blocks behind a multi-millisecond run.
//!
//! Both caches are bounded ([`DRAIN_CACHE_CAP`] / [`SAT_CACHE_CAP`]): at
//! capacity an arbitrary resident entry is evicted before insertion, so a
//! long sweep session cannot grow them without bound. Eviction order is
//! nondeterministic (`HashMap` iteration), which is safe because a cache
//! hit and a re-simulation are identical by the identity contract below.
//! Lookups, insertions and evictions feed the
//! [`crate::telemetry::profile`] counters (`repro … --profile`).

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use super::engine::{FlowSpec, Mode, SimStats};
use crate::config::NopConfig;
use crate::nop::topology::NopTopology;
use crate::telemetry::profile;

/// Maximum resident drain-run results; one arbitrary entry is evicted
/// per insertion beyond this.
pub(crate) const DRAIN_CACHE_CAP: usize = 256;

/// Maximum resident saturation-search results.
pub(crate) const SAT_CACHE_CAP: usize = 256;

/// Insert `(key, val)` into a bounded cache map: when `key` is absent and
/// the map is at `cap`, evict one arbitrary resident entry first. Returns
/// whether an eviction happened (so callers can bump the profile counter
/// for their cache).
fn insert_bounded<K: std::hash::Hash + Eq + Clone, V>(
    map: &mut HashMap<K, V>,
    cap: usize,
    key: K,
    val: V,
) -> bool {
    let mut evicted = false;
    if map.len() >= cap && !map.contains_key(&key) {
        if let Some(victim) = map.keys().next().cloned() {
            map.remove(&victim);
            evicted = true;
        }
    }
    map.insert(key, val);
    evicted
}

/// Drain-run cache key: (topology, chiplets, hop latency, buffer depth,
/// cycle budget, seed, cross-chiplet flow list in caller order). The flow
/// list is kept **in order** — drain sources round-robin over their
/// pending entries in insertion order, so reordered flow lists are
/// genuinely different workloads and must not collide.
type DrainKey = (u8, usize, u64, usize, u64, u64, Vec<(u32, u32, u64)>);

static DRAIN_CACHE: OnceLock<Mutex<HashMap<DrainKey, SimStats>>> = OnceLock::new();

fn drain_cache() -> &'static Mutex<HashMap<DrainKey, SimStats>> {
    DRAIN_CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Run (or recall) an uninstrumented `NopSim` drain of `flows` on
/// `topology` × `k` and return its [`SimStats`]. Results are memoized
/// process-wide on everything the simulator reads, so sweeping the same
/// (partition, topology) point across experiments, the advisor and the
/// benches pays for the simulation once.
pub fn drain_makespan(
    topology: NopTopology,
    k: usize,
    cfg: &NopConfig,
    flows: &[FlowSpec],
    max_cycles: u64,
    seed: u64,
) -> SimStats {
    let fl: Vec<(u32, u32, u64)> = flows
        .iter()
        .filter(|f| f.src != f.dst && f.flits > 0)
        .map(|f| (f.src as u32, f.dst as u32, f.flits))
        .collect();
    let key = (
        topology as u8,
        k,
        cfg.hop_latency_cycles,
        cfg.buffer_flits,
        max_cycles,
        seed,
        fl,
    );
    if let Some(hit) = drain_cache().lock().unwrap().get(&key) {
        profile::note_drain(true);
        return hit.clone();
    }
    profile::note_drain(false);
    // Attribution is always armed here: it only fills `flow_waits`
    // (observational), so the memoized result stays bit-identical to an
    // unattributed run on every simulated outcome.
    let stats = crate::nop::sim::NopSim::new(
        topology,
        k,
        cfg,
        flows,
        Mode::Drain { max_cycles },
        seed,
    )
    .attribute(true)
    .run();
    if insert_bounded(
        &mut drain_cache().lock().unwrap(),
        DRAIN_CACHE_CAP,
        key,
        stats.clone(),
    ) {
        profile::note_drain_eviction();
    }
    stats
}

/// Saturation-search cache key: (topology, chiplets, hop latency, buffer
/// depth, seed) — the full input set of
/// [`crate::nop::sim::saturation_rate`].
type SatKey = (u8, usize, u64, usize, u64);

static SAT_CACHE: OnceLock<Mutex<HashMap<SatKey, Option<f64>>>> = OnceLock::new();

fn sat_cache() -> &'static Mutex<HashMap<SatKey, Option<f64>>> {
    SAT_CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Memoize a saturation search: return the cached rate for this
/// (topology, k, cfg, seed) point or run `compute` and remember it.
pub(crate) fn memo_saturation(
    topology: NopTopology,
    k: usize,
    cfg: &NopConfig,
    seed: u64,
    compute: impl FnOnce() -> Option<f64>,
) -> Option<f64> {
    let key = (
        topology as u8,
        k,
        cfg.hop_latency_cycles,
        cfg.buffer_flits,
        seed,
    );
    if let Some(&hit) = sat_cache().lock().unwrap().get(&key) {
        profile::note_sat(true);
        return hit;
    }
    profile::note_sat(false);
    let val = compute();
    if insert_bounded(&mut sat_cache().lock().unwrap(), SAT_CACHE_CAP, key, val) {
        profile::note_sat_eviction();
    }
    val
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memoized_drain_is_identical_to_direct_simulation() {
        // Identity contract: the cache must be invisible — first call
        // (miss), second call (hit) and a direct `NopSim` run all agree
        // on every statistic.
        let cfg = NopConfig::default();
        let flows = [
            FlowSpec {
                src: 0,
                dst: 3,
                rate: 0.0,
                flits: 90,
            },
            FlowSpec {
                src: 2,
                dst: 1,
                rate: 0.0,
                flits: 41,
            },
        ];
        let budget = 200_000;
        let first = drain_makespan(NopTopology::Mesh, 4, &cfg, &flows, budget, 0xA5);
        let second = drain_makespan(NopTopology::Mesh, 4, &cfg, &flows, budget, 0xA5);
        let direct = crate::nop::sim::NopSim::new(
            NopTopology::Mesh,
            4,
            &cfg,
            &flows,
            Mode::Drain { max_cycles: budget },
            0xA5,
        )
        .run();
        assert!(direct.drained);
        for s in [&first, &second] {
            assert_eq!(s.makespan, direct.makespan);
            assert_eq!(s.injected, direct.injected);
            assert_eq!(s.delivered, direct.delivered);
            assert_eq!(s.drained, direct.drained);
            assert_eq!(s.cycles, direct.cycles);
            assert_eq!(s.avg_latency, direct.avg_latency);
            assert_eq!(s.max_latency, direct.max_latency);
        }
    }

    #[test]
    fn reordered_flow_lists_do_not_collide() {
        // Drain priming round-robins over pending entries in insertion
        // order, so [a, b] and [b, a] are different workloads; the cache
        // must key on the ordered list.
        let cfg = NopConfig::default();
        let ab = [
            FlowSpec {
                src: 0,
                dst: 1,
                rate: 0.0,
                flits: 30,
            },
            FlowSpec {
                src: 0,
                dst: 2,
                rate: 0.0,
                flits: 60,
            },
        ];
        let ba = [ab[1], ab[0]];
        let budget = 100_000;
        let fwd = drain_makespan(NopTopology::Ring, 3, &cfg, &ab, budget, 7);
        let rev = drain_makespan(NopTopology::Ring, 3, &cfg, &ba, budget, 7);
        let rev_direct = crate::nop::sim::NopSim::new(
            NopTopology::Ring,
            3,
            &cfg,
            &ba,
            Mode::Drain { max_cycles: budget },
            7,
        )
        .run();
        assert_eq!(fwd.injected, rev.injected);
        assert_eq!(rev.makespan, rev_direct.makespan);
        assert_eq!(rev.avg_latency, rev_direct.avg_latency);
    }

    #[test]
    fn bounded_insert_evicts_at_capacity_only() {
        let mut map: HashMap<u32, u32> = HashMap::new();
        assert!(!insert_bounded(&mut map, 3, 1, 10));
        assert!(!insert_bounded(&mut map, 3, 2, 20));
        assert!(!insert_bounded(&mut map, 3, 3, 30));
        assert_eq!(map.len(), 3);
        // Overwriting a resident key at capacity evicts nothing.
        assert!(!insert_bounded(&mut map, 3, 2, 21));
        assert_eq!(map.len(), 3);
        assert_eq!(map.get(&2), Some(&21));
        // A fresh key at capacity evicts exactly one resident entry.
        assert!(insert_bounded(&mut map, 3, 4, 40));
        assert_eq!(map.len(), 3);
        assert_eq!(map.get(&4), Some(&40));
    }

    #[test]
    fn saturation_memo_returns_cached_value() {
        let cfg = NopConfig::default();
        let mut calls = 0;
        let probe = |calls: &mut usize| {
            *calls += 1;
            Some(0.42)
        };
        // Unlikely-to-collide key for this test: k = 0 never occurs in
        // real searches (saturation_rate returns None below k = 2).
        let a = memo_saturation(NopTopology::P2p, 0, &cfg, u64::MAX, || probe(&mut calls));
        let b = memo_saturation(NopTopology::P2p, 0, &cfg, u64::MAX, || probe(&mut calls));
        assert_eq!(a, Some(0.42));
        assert_eq!(b, Some(0.42));
        assert_eq!(calls, 1, "second lookup must hit the cache");
    }
}
