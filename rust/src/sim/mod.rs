//! The shared flit-level event engine behind both cycle simulators.
//!
//! The on-chip NoC simulator ([`crate::noc::sim::NocSim`]) and the
//! Network-on-Package simulator ([`crate::nop::sim::NopSim`]) grew up as
//! near-identical siblings: both carry Bernoulli/drain traffic sources, a
//! warm-up/measure or drain-until-empty run loop, per-pair latency
//! tracking, occupancy sampling and optional telemetry. This module is the
//! single home for everything the two engines share:
//!
//! * [`engine`] — the traffic vocabulary ([`FlowSpec`], [`Mode`],
//!   [`SimStats`], [`PairStat`]), the per-source generator state, the
//!   engine core that owns clocks/RNG/statistics, and the unified run loop
//!   (with drain-clock event skipping) that both simulators drive through
//!   the `Fabric` trait.
//! * [`memo`] — process-wide keyed caches for simulator-backed sweeps:
//!   drain makespans and saturation rates are pure functions of a small
//!   configuration key, so repeated sweep points (experiments, the
//!   advisor, serving-model builds, benches) hit the cache instead of
//!   re-simulating.
//! * [`surrogate`] — sim-anchored correction models: per
//!   (topology, k, sim-knob, seed) key, a handful of sim anchors between
//!   low load and the measured saturation rate pin a monotone latency
//!   curve (and a drain-makespan ratio), so `[nop] mode = surrogate`
//!   answers sweep queries at near-analytical cost with sim-level
//!   fidelity — falling back to the full simulator outside the fitted
//!   range.
//!
//! The fabric adapters stay in `noc::sim` / `nop::sim` and hold only what
//! is genuinely topology-specific: router pipelines, port claims and
//! store-and-forward P2P rules below; SerDes links, credit/bubble flow
//! control and the arrival event queue above.

pub mod engine;
pub mod memo;
pub mod surrogate;

pub use engine::{FlowSpec, Mode, PairStat, SimStats};
pub use memo::drain_makespan;
