//! Whole-architecture evaluation: maps a DNN, costs the compute fabric,
//! runs (or estimates) the interconnect, and rolls everything up into the
//! paper's reporting metrics.

use crate::circuit::ChipCost;
use crate::config::{ArchConfig, NocConfig, SimConfig};
use crate::dnn::DnnGraph;
use crate::mapping::{InjectionMatrix, Mapping};
use crate::noc::analytical::AnalyticalModel;
use crate::noc::latency::{flits_per_pair, layer_flows};
use crate::noc::sim::{FlowSpec, Mode, NocSim};
use crate::noc::topology::{Network, Topology};
use crate::noc::NocPower;

/// Interconnect evaluation backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommBackend {
    /// Cycle-accurate drain-mode simulation (Algorithm 1). Slow, exact.
    Simulate,
    /// Analytical bandwidth/queueing estimate (Algorithm 2 + makespan
    /// bound). 100–2000× faster (paper Fig. 12).
    Analytical,
}

/// Full evaluation result for one (DNN, technology, topology) point.
#[derive(Clone, Debug)]
pub struct ArchEvaluation {
    /// Zoo model name.
    pub dnn: String,
    /// Tile-level topology the point was priced under.
    pub topology: Topology,
    /// Tiles the mapping occupies.
    pub tiles: usize,
    /// Crossbars the mapping occupies.
    pub crossbars: usize,
    /// Compute latency per frame, seconds (circuit model).
    pub compute_latency_s: f64,
    /// Compute energy per frame, joules.
    pub compute_energy_j: f64,
    /// Compute area, mm².
    pub compute_area_mm2: f64,
    /// Interconnect-side numbers. `comm_cycles` is the raw per-layer sum;
    /// `comm_latency_s` is the *exposed* (non-overlapped with compute)
    /// communication time that actually extends the frame.
    pub comm_cycles: u64,
    /// Exposed communication latency per frame, seconds.
    pub comm_latency_s: f64,
    /// Interconnect energy per frame, joules.
    pub comm_energy_j: f64,
    /// NoC router + link area, mm².
    pub noc_area_mm2: f64,
    /// Per-layer communication cycles (for Fig. 3-style breakdowns).
    pub comm_per_layer: Vec<(usize, u64)>,
}

impl ArchEvaluation {
    /// End-to-end inference latency per frame, seconds (layer-by-layer:
    /// compute and communication serialize, paper §5).
    pub fn latency_s(&self) -> f64 {
        self.compute_latency_s + self.comm_latency_s
    }

    /// Total energy per frame, J.
    pub fn energy_j(&self) -> f64 {
        self.compute_energy_j + self.comm_energy_j
    }

    /// Total area, mm².
    pub fn area_mm2(&self) -> f64 {
        self.compute_area_mm2 + self.noc_area_mm2
    }

    /// Frames per second.
    pub fn fps(&self) -> f64 {
        1.0 / self.latency_s()
    }

    /// Average power per frame, W.
    pub fn power_w(&self) -> f64 {
        self.energy_j() / self.latency_s()
    }

    /// Energy-delay-area product, J·ms·mm² (the paper's headline metric).
    pub fn edap(&self) -> f64 {
        self.energy_j() * (self.latency_s() * 1e3) * self.area_mm2()
    }

    /// Routing latency share of end-to-end latency (Fig. 3).
    pub fn routing_fraction(&self) -> f64 {
        self.comm_latency_s / self.latency_s()
    }
}

/// Evaluate `graph` on the IMC architecture with the given interconnect.
///
/// Communication model (see DESIGN.md §Comm-model): layer-by-layer, but a
/// layer's input transfer overlaps its producers' compute (outputs stream
/// as they are produced — the paper's tile output buffers exist for exactly
/// this). Each layer therefore contributes
/// `max(compute_cycles, comm_cycles)` to the frame, where
///
/// ```text
/// comm_cycles = bottleneck_flits + avg_flit_latency
/// ```
///
/// `bottleneck_flits` is the heaviest per-frame load on any link/ejection
/// port (tree root links, mesh dst-region perimeter, half-duplex P2P
/// nodes), and `avg_latency` is the average flit residence time at
/// production-rate injection, taken from the cycle-accurate simulator
/// (`Simulate`) or the Algorithm-2 queueing model (`Analytical`).
pub fn evaluate(
    graph: &DnnGraph,
    topology: Topology,
    arch: &ArchConfig,
    noc: &NocConfig,
    sim: &SimConfig,
    backend: CommBackend,
) -> ArchEvaluation {
    let mapping = Mapping::build(graph, arch);
    let chip = ChipCost::evaluate(graph, &mapping, arch);
    let inj = InjectionMatrix::build(graph, &mapping, arch, noc);

    let net = Network::build(topology, inj.total_tiles);
    let model = AnalyticalModel::new(&net, noc);

    let mut comm_per_layer: Vec<(usize, u64)> = Vec::new();
    let mut comm_cycles: u64 = 0;
    let mut frame_cycles: f64 = 0.0;
    for (li, lt) in mapping.layers.iter().enumerate() {
        let compute_cycles = chip.per_layer[li].cycles as f64;
        let dflows = layer_flows(&inj, lt.layer, arch, noc, true);
        if dflows.is_empty() {
            frame_cycles += compute_cycles;
            continue;
        }
        // The tile's local port drains the router into `ces_per_tile`
        // parallel H-tree lanes (Fig. 10), so ejection-bound transfers run
        // at that multiple of the link bandwidth. P2P tiles have no router
        // buffer to fan out from: their half-duplex forwarding latch ingests
        // one flit every other cycle.
        let eject_cap = if topology.has_routers() {
            arch.ces_per_tile as f64
        } else {
            0.5
        };
        let (bottleneck, _) = model.layer_bottleneck_with_eject(&dflows, eject_cap);
        let zero_load = model.zero_load(&dflows).max(1.0);
        // Production-rate injection: the transfer window equals the
        // consumer's compute window, so each pair offers flits/window.
        let window = compute_cycles.max(1.0);
        let pflows: Vec<FlowSpec> = dflows
            .iter()
            .map(|f| FlowSpec {
                src: f.src,
                dst: f.dst,
                rate: (f.flits as f64 / window).min(1.0),
                flits: 0,
            })
            .collect();
        let avg_latency = match backend {
            CommBackend::Analytical => model.layer_latency(&pflows).avg_latency,
            CommBackend::Simulate => {
                NocSim::new(
                    topology,
                    inj.total_tiles,
                    noc,
                    &pflows,
                    Mode::Steady {
                        warmup: sim.warmup_cycles,
                        measure: sim.measure_cycles,
                    },
                    sim.seed ^ lt.layer as u64,
                )
                .run()
                .avg_latency
            }
        };
        // Makespan model: the bandwidth bound plus the (possibly congested)
        // residence time of the last flit. Saturated networks report very
        // large average latencies; cap at 100× zero-load so a single layer
        // cannot dominate un-physically.
        let comm = bottleneck + avg_latency.max(zero_load).min(zero_load * 100.0);
        comm_per_layer.push((lt.layer, comm.ceil() as u64));
        comm_cycles += comm.ceil() as u64;
        frame_cycles += compute_cycles.max(comm);
    }
    // Exposed (non-overlapped) communication latency.
    let compute_cycles_total = chip.latency_s * arch.freq_hz;
    let comm_latency_s = (frame_cycles - compute_cycles_total).max(0.0) / arch.freq_hz;

    // --- Communication energy & NoC area (route-exact flit·hop counts) ---
    let tile_edge_mm = (chip.area_mm2 / mapping.total_tiles.max(1) as f64).sqrt();
    let power = NocPower::new(&net, noc, arch.tech_nm, tile_edge_mm.max(0.1));
    let mut comm_energy_j = 0.0;
    for f in &inj.flows {
        let pairs = f.src_tiles.len() * f.dst_tiles.len();
        let flits = flits_per_pair(f.activations, arch.n_bits, pairs, noc.bus_width) as f64;
        for s in f.src_tiles.clone() {
            for d in f.dst_tiles.clone() {
                if s == d {
                    continue;
                }
                let hops = net.hops(s, d);
                comm_energy_j += flits * power.flit_energy_j(hops);
            }
        }
    }
    comm_energy_j += power.leakage_w * comm_latency_s;

    ArchEvaluation {
        dnn: graph.name.clone(),
        topology,
        tiles: mapping.total_tiles,
        crossbars: mapping.total_crossbars,
        compute_latency_s: chip.latency_s,
        compute_energy_j: chip.energy_j,
        compute_area_mm2: chip.area_mm2,
        comm_cycles,
        comm_latency_s,
        comm_energy_j,
        noc_area_mm2: power.area_mm2,
        comm_per_layer,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;

    fn eval(
        g: &DnnGraph,
        topo: Topology,
        arch: &ArchConfig,
        backend: CommBackend,
    ) -> ArchEvaluation {
        evaluate(
            g,
            topo,
            arch,
            &NocConfig::with_topology(topo),
            &SimConfig::default(),
            backend,
        )
    }

    #[test]
    fn analytical_and_sim_agree_on_lenet() {
        let g = models::lenet5();
        let arch = ArchConfig::default();
        let sim = eval(&g, Topology::Mesh, &arch, CommBackend::Simulate);
        let ana = eval(&g, Topology::Mesh, &arch, CommBackend::Analytical);
        assert!(sim.comm_cycles > 0 && ana.comm_cycles > 0);
        let ratio = ana.comm_cycles as f64 / sim.comm_cycles as f64;
        assert!((0.3..3.0).contains(&ratio), "ratio {ratio}");
        // Shared parts identical.
        assert_eq!(sim.compute_area_mm2, ana.compute_area_mm2);
        assert_eq!(sim.tiles, ana.tiles);
    }

    #[test]
    fn metrics_are_positive_and_consistent() {
        let g = models::mlp();
        let arch = ArchConfig::default();
        let e = eval(&g, Topology::Tree, &arch, CommBackend::Analytical);
        assert!(e.latency_s() > 0.0);
        assert!(e.energy_j() > 0.0);
        assert!(e.area_mm2() > 0.0);
        assert!(e.edap() > 0.0);
        assert!((e.fps() - 1.0 / e.latency_s()).abs() < 1e-9);
        assert!(e.routing_fraction() > 0.0 && e.routing_fraction() < 1.0);
    }

    #[test]
    fn p2p_routing_dominates_dense_nets() {
        // Paper Fig. 3: routing latency reaches up to 94% of end-to-end
        // latency on P2P for dense DNNs, and P2P is always worse than the
        // NoC on the same workload. (Batch-1 MLP is communication-bound on
        // any spatial fabric, so we assert dominance + NoC superiority
        // rather than strict density-monotonicity — the paper's own Fig. 3
        // is non-monotone at VGG-19.)
        let arch = ArchConfig::default();
        let dense_p2p = eval(
            &models::densenet(40),
            Topology::P2P,
            &arch,
            CommBackend::Analytical,
        );
        let dense_mesh = eval(
            &models::densenet(40),
            Topology::Mesh,
            &arch,
            CommBackend::Analytical,
        );
        assert!(
            dense_p2p.routing_fraction() > 0.6,
            "dense P2P share {}",
            dense_p2p.routing_fraction()
        );
        assert!(
            dense_p2p.routing_fraction() > dense_mesh.routing_fraction(),
            "P2P {} must exceed mesh {}",
            dense_p2p.routing_fraction(),
            dense_mesh.routing_fraction()
        );
    }

    #[test]
    fn mesh_area_energy_exceed_tree() {
        let g = models::nin();
        let arch = ArchConfig::default();
        let mesh = eval(&g, Topology::Mesh, &arch, CommBackend::Analytical);
        let tree = eval(&g, Topology::Tree, &arch, CommBackend::Analytical);
        assert!(mesh.noc_area_mm2 > tree.noc_area_mm2);
    }
}
