//! The proposed NoC-based heterogeneous-interconnect IMC architecture
//! (paper Fig. 10 + §5.2): NoC (tree or mesh, chosen per DNN) at the tile
//! level, P2P H-tree at the CE level, bus at the PE level. The intra-tile
//! levels are already folded into the circuit model ([`crate::circuit::tile`]);
//! this module picks the tile-level topology and assembles the headline
//! numbers used in Table 4.

use super::evaluator::{evaluate, ArchEvaluation, CommBackend};
use super::optimizer::recommend_topology;
use crate::config::{ArchConfig, NocConfig, SimConfig};
use crate::dnn::DnnGraph;
use crate::noc::topology::Topology;

/// The proposed architecture: per-DNN optimal tile-level NoC.
#[derive(Clone, Debug)]
pub struct HeteroArchitecture {
    /// Architecture (crossbar / tile) parameters.
    pub arch: ArchConfig,
    /// Base NoC parameters; the topology is chosen per DNN.
    pub noc: NocConfig,
    /// Simulation-control parameters.
    pub sim: SimConfig,
}

impl HeteroArchitecture {
    /// Wrap `arch` with default NoC and sim parameters.
    pub fn new(arch: ArchConfig) -> Self {
        Self {
            arch,
            noc: NocConfig::default(),
            sim: SimConfig::default(),
        }
    }

    /// Pick the tile-level topology for `graph` with the analytical model
    /// (§6.4 guidance) and evaluate end to end.
    pub fn evaluate(&self, graph: &DnnGraph, backend: CommBackend) -> ArchEvaluation {
        let rec = recommend_topology(graph, &self.arch, &self.noc);
        let noc = NocConfig {
            topology: rec.topology,
            ..self.noc.clone()
        };
        evaluate(graph, rec.topology, &self.arch, &noc, &self.sim, backend)
    }

    /// Evaluate with a forced topology (for comparison studies).
    pub fn evaluate_with(
        &self,
        graph: &DnnGraph,
        topology: Topology,
        backend: CommBackend,
    ) -> ArchEvaluation {
        let noc = NocConfig {
            topology,
            ..self.noc.clone()
        };
        evaluate(graph, topology, &self.arch, &noc, &self.sim, backend)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;

    #[test]
    fn hetero_never_loses_to_both_fixed_choices() {
        // The advisor-selected topology must match the better of {tree,
        // mesh} on EDAP for each eval-set DNN (within estimation noise).
        let hw = HeteroArchitecture::new(ArchConfig::reram());
        for g in [models::mlp(), models::densenet(40)] {
            let auto = hw.evaluate(&g, CommBackend::Analytical);
            let tree = hw.evaluate_with(&g, Topology::Tree, CommBackend::Analytical);
            let mesh = hw.evaluate_with(&g, Topology::Mesh, CommBackend::Analytical);
            let best = tree.edap().min(mesh.edap());
            // Within the Fig. 20 overlap band the rule may pick mesh while
            // the EDAP estimate marginally favors tree (documented
            // deviation for single-tile-per-layer DenseNets); allow 15%.
            assert!(
                auto.edap() <= best * 1.15,
                "{}: auto {} vs best {}",
                g.name,
                auto.edap(),
                best
            );
        }
    }

    #[test]
    fn sram_and_reram_variants_build() {
        let g = models::lenet5();
        let s = HeteroArchitecture::new(ArchConfig::sram()).evaluate(&g, CommBackend::Analytical);
        let r = HeteroArchitecture::new(ArchConfig::reram()).evaluate(&g, CommBackend::Analytical);
        assert!(s.latency_s() < r.latency_s(), "SRAM must be faster");
    }
}
