//! End-to-end architecture evaluation: compute fabric (circuit model) +
//! interconnect (NoC simulation or analytical model) composed into the
//! latency / energy / area / EDAP / FPS numbers every paper figure uses,
//! plus the heterogeneous-interconnect architecture of Fig. 10, the
//! optimal-topology advisor of Fig. 20, and the joint multi-chiplet
//! (chiplets, NoP, NoC) scale-out advisor.

pub mod evaluator;
pub mod hetero;
pub mod optimizer;

pub use evaluator::{evaluate, ArchEvaluation, CommBackend};
pub use hetero::HeteroArchitecture;
pub use optimizer::{
    recommend_scaleout, recommend_topology, Recommendation, ScaleoutRecommendation,
};
