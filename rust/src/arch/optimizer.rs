//! Optimal-topology guidance (paper §6.4 + Fig. 20): pick NoC-tree or
//! NoC-mesh for a DNN from the analytical model, and expose the paper's
//! closed-form rule (Eq. 16: injection load ∝ ρ/μ — synaptic density over
//! neurons — with density thresholds around 1–2 × 10³).

use super::evaluator::{evaluate, CommBackend};
use crate::config::{ArchConfig, NocConfig, SimConfig};
use crate::dnn::DnnGraph;
use crate::noc::topology::Topology;

/// Advisor output.
#[derive(Clone, Debug)]
pub struct Recommendation {
    pub topology: Topology,
    /// EDAP of tree and mesh under the analytical backend (J·ms·mm²).
    pub edap_tree: f64,
    pub edap_mesh: f64,
    /// The Fig. 20 closed-form classification for reference.
    pub rule_of_thumb: Topology,
    /// Synaptic connection density ρ (Fig. 20 x-axis magnitude).
    pub density: f64,
    /// Neurons μ.
    pub neurons: usize,
}

/// Fig. 20 thresholds on synaptic connection density.
pub const DENSITY_MESH_THRESHOLD: f64 = 2.0e3;
pub const DENSITY_TREE_THRESHOLD: f64 = 1.0e3;

/// The paper's closed-form guidance: mesh above 2×10³ connections/neuron,
/// tree below 1×10³; in between, both are acceptable (we return the one the
/// analytical model prefers via [`recommend_topology`]).
pub fn rule_of_thumb(density: f64) -> Option<Topology> {
    if density > DENSITY_MESH_THRESHOLD {
        Some(Topology::Mesh)
    } else if density < DENSITY_TREE_THRESHOLD {
        Some(Topology::Tree)
    } else {
        None
    }
}

/// Full advisor: apply the Fig. 20 closed-form rule first; inside the
/// overlap band (1–2 × 10³), fall back to comparing tree and mesh EDAP
/// with the analytical backend.
pub fn recommend_topology(
    graph: &DnnGraph,
    arch: &ArchConfig,
    noc: &NocConfig,
) -> Recommendation {
    let sim = SimConfig::default();
    let tree = evaluate(
        graph,
        Topology::Tree,
        arch,
        &NocConfig {
            topology: Topology::Tree,
            ..noc.clone()
        },
        &sim,
        CommBackend::Analytical,
    );
    let mesh = evaluate(
        graph,
        Topology::Mesh,
        arch,
        &NocConfig {
            topology: Topology::Mesh,
            ..noc.clone()
        },
        &sim,
        CommBackend::Analytical,
    );
    let report = graph.density_report();
    let density = report.connection_density();
    let rule = rule_of_thumb(density);
    let edap_choice = if tree.edap() <= mesh.edap() {
        Topology::Tree
    } else {
        Topology::Mesh
    };
    let topology = rule.unwrap_or(edap_choice);
    Recommendation {
        topology,
        edap_tree: tree.edap(),
        edap_mesh: mesh.edap(),
        rule_of_thumb: rule.unwrap_or(edap_choice),
        density,
        neurons: report.neurons,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;

    #[test]
    fn compact_nets_get_tree() {
        let arch = ArchConfig::default();
        let noc = NocConfig::default();
        for g in [models::mlp(), models::lenet5()] {
            let r = recommend_topology(&g, &arch, &noc);
            assert_eq!(r.topology, Topology::Tree, "{}: {r:?}", g.name);
        }
    }

    #[test]
    fn rule_thresholds() {
        assert_eq!(rule_of_thumb(5.0e3), Some(Topology::Mesh));
        assert_eq!(rule_of_thumb(0.5e3), Some(Topology::Tree));
        assert_eq!(rule_of_thumb(1.5e3), None);
    }

    #[test]
    fn vgg19_density_in_mesh_band() {
        // VGG-19's connection density (~2-4.5k) must land in the paper's
        // mesh region of Fig. 20.
        let d = models::vgg(19).density_report().connection_density();
        assert!(d > DENSITY_MESH_THRESHOLD, "VGG-19 density {d}");
    }

    #[test]
    fn lenet_density_in_tree_band() {
        let d = models::lenet5().density_report().connection_density();
        assert!(d < DENSITY_TREE_THRESHOLD, "LeNet-5 density {d}");
    }

    #[test]
    fn dense_nets_get_mesh_from_rule() {
        // The paper places DenseNet-100 and ResNet-50 in the mesh region.
        for g in [models::densenet(100), models::resnet(50)] {
            let r = recommend_topology(&g, &ArchConfig::default(), &NocConfig::default());
            assert_eq!(r.topology, Topology::Mesh, "{}: density {}", g.name, r.density);
        }
    }
}
