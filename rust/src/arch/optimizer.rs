//! Optimal-topology guidance (paper §6.4 + Fig. 20): pick NoC-tree or
//! NoC-mesh for a DNN from the analytical model, and expose the paper's
//! closed-form rule (Eq. 16: injection load ∝ ρ/μ — synaptic density over
//! neurons — with density thresholds around 1–2 × 10³).
//!
//! The scale-out extension ([`recommend_scaleout`]) lifts the advisor to
//! the package level: it jointly searches (chiplet count, NoP topology,
//! per-chiplet NoC topology) with the hierarchical evaluator and returns
//! the EDAP-optimal design point.

use std::collections::HashMap;

use super::evaluator::{evaluate, CommBackend};
use crate::config::{ArchConfig, NocConfig, NopConfig, NopMode, SimConfig};
use crate::dnn::DnnGraph;
use crate::noc::topology::Topology;
use crate::nop::evaluator::{evaluate_package, NopEvaluation};
use crate::nop::sim::saturation_rate;
use crate::nop::topology::NopTopology;

/// Advisor output.
#[derive(Clone, Debug)]
pub struct Recommendation {
    /// The recommended tile-level topology.
    pub topology: Topology,
    /// EDAP of tree under the analytical backend (J·ms·mm²).
    pub edap_tree: f64,
    /// EDAP of mesh under the analytical backend (J·ms·mm²).
    pub edap_mesh: f64,
    /// The Fig. 20 closed-form classification for reference.
    pub rule_of_thumb: Topology,
    /// Synaptic connection density ρ (Fig. 20 x-axis magnitude).
    pub density: f64,
    /// Neurons μ.
    pub neurons: usize,
}

/// Fig. 20 upper threshold: mesh above this density.
pub const DENSITY_MESH_THRESHOLD: f64 = 2.0e3;
/// Fig. 20 lower threshold: tree below this density.
pub const DENSITY_TREE_THRESHOLD: f64 = 1.0e3;

/// The paper's closed-form guidance: mesh above 2×10³ connections/neuron,
/// tree below 1×10³; in between, both are acceptable (we return the one the
/// analytical model prefers via [`recommend_topology`]).
pub fn rule_of_thumb(density: f64) -> Option<Topology> {
    if density > DENSITY_MESH_THRESHOLD {
        Some(Topology::Mesh)
    } else if density < DENSITY_TREE_THRESHOLD {
        Some(Topology::Tree)
    } else {
        None
    }
}

/// Full advisor: apply the Fig. 20 closed-form rule first; inside the
/// overlap band (1–2 × 10³), fall back to comparing tree and mesh EDAP
/// with the analytical backend.
pub fn recommend_topology(
    graph: &DnnGraph,
    arch: &ArchConfig,
    noc: &NocConfig,
) -> Recommendation {
    let sim = SimConfig::default();
    let tree = evaluate(
        graph,
        Topology::Tree,
        arch,
        &NocConfig {
            topology: Topology::Tree,
            ..noc.clone()
        },
        &sim,
        CommBackend::Analytical,
    );
    let mesh = evaluate(
        graph,
        Topology::Mesh,
        arch,
        &NocConfig {
            topology: Topology::Mesh,
            ..noc.clone()
        },
        &sim,
        CommBackend::Analytical,
    );
    let report = graph.density_report();
    let density = report.connection_density();
    let rule = rule_of_thumb(density);
    let edap_choice = if tree.edap() <= mesh.edap() {
        Topology::Tree
    } else {
        Topology::Mesh
    };
    let topology = rule.unwrap_or(edap_choice);
    Recommendation {
        topology,
        edap_tree: tree.edap(),
        edap_mesh: mesh.edap(),
        rule_of_thumb: rule.unwrap_or(edap_choice),
        density,
        neurons: report.neurons,
    }
}

/// The joint scale-out advisor's output.
#[derive(Clone, Debug)]
pub struct ScaleoutRecommendation {
    /// The EDAP-optimal design point's evaluation.
    pub best: NopEvaluation,
    /// The winner's *ranking* EDAP: equals `best.edap()` in analytical
    /// mode, but under sim calibration it is the saturation-derated value
    /// the search actually minimized (report this one next to
    /// `candidates`).
    pub best_edap: f64,
    /// Chiplet count of the winner (1 = single chip).
    pub chiplets: usize,
    /// Package-level topology of the winner.
    pub nop_topology: NopTopology,
    /// Tile-level topology of the winner.
    pub noc_topology: Topology,
    /// Every candidate evaluated, as (chiplets, NoP, NoC, EDAP), in search
    /// order — for reporting the full design-space slice. Under sim
    /// calibration the EDAP is the saturation-derated ranking value.
    pub candidates: Vec<(usize, NopTopology, Topology, f64)>,
    /// True when the ranking folded in `nop::sim` measured saturation
    /// rates (`[nop] mode = sim` or `= surrogate` on the advisor's base
    /// config — both are backed by the same memoized saturation search).
    pub sim_calibrated: bool,
}

/// Derate a candidate's frame latency by the measured package saturation:
/// when the per-frame NoP injection the analytical evaluation implies
/// (cut flits spread over the package at the candidate's frame rate)
/// exceeds the saturation rate measured by
/// [`crate::nop::sim::saturation_rate`], the package actually sustains the
/// measured rate — scale the frame latency by the overload factor. Below
/// saturation (or with no measurement) the analytical latency stands.
pub fn saturation_derated_latency_s(
    e: &NopEvaluation,
    nop: &NopConfig,
    sat_rate: Option<f64>,
) -> f64 {
    let lat = e.latency_s();
    let Some(rate) = sat_rate else {
        return lat;
    };
    if e.chiplets < 2 || e.cross_bits == 0 || rate <= 0.0 {
        return lat;
    }
    let flits = (e.cross_bits as f64 / nop.link_width as f64).ceil();
    let offered = flits / (e.chiplets as f64 * lat * nop.freq_hz);
    if offered > rate {
        lat * offered / rate
    } else {
        lat
    }
}

/// Chiplet counts the joint advisor explores (1 = stay on a single chip).
pub const SCALEOUT_CHIPLET_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Per-chiplet NoC topologies the joint advisor explores (the two the
/// paper's single-chip guidance ever picks).
pub const SCALEOUT_NOC_CHOICES: [Topology; 2] = [Topology::Tree, Topology::Mesh];

/// Jointly recommend (chiplet count, NoP topology, per-chiplet NoC
/// topology) for `graph` by exhaustive EDAP search over the (small)
/// hierarchical design space. `base_nop` supplies the SerDes link
/// parameters; its `topology`/`chiplets` fields are overridden by the
/// search.
///
/// Candidate evaluation always uses the fast analytical package model, but
/// when `base_nop.mode` is `sim` or `surrogate` the ranking folds in the
/// *measured* saturation rate of each (NoP topology, k) from the
/// flit-level package simulator: candidates whose per-frame NoP injection
/// exceeds the measured rate have their latency derated before EDAP ranking
/// ([`saturation_derated_latency_s`]), closing the ROADMAP gap where the
/// advisor ranked purely analytically.
pub fn recommend_scaleout(
    graph: &DnnGraph,
    arch: &ArchConfig,
    base_noc: &NocConfig,
    base_nop: &NopConfig,
) -> ScaleoutRecommendation {
    let sim = SimConfig::default();
    // Surrogate mode is sim-anchored — its saturation rates come from the
    // same memoized search — so it calibrates the ranking like `sim`.
    let sim_calibrated = base_nop.mode != NopMode::Analytical;
    let mut sat_cache: HashMap<(NopTopology, usize), Option<f64>> = HashMap::new();
    let mut best: Option<(f64, NopEvaluation)> = None;
    let mut candidates = Vec::new();
    let all_nops = NopTopology::all();
    let single_chip = [NopTopology::P2p];
    for &k in &SCALEOUT_CHIPLET_COUNTS {
        // NoP topology is irrelevant on a single chip; evaluate once.
        let nop_choices: &[NopTopology] = if k == 1 { &single_chip } else { &all_nops };
        for &nop_topo in nop_choices {
            for &noc_topo in &SCALEOUT_NOC_CHOICES {
                let noc = NocConfig {
                    topology: noc_topo,
                    ..base_noc.clone()
                };
                let nop = NopConfig {
                    topology: nop_topo,
                    chiplets: k,
                    mode: NopMode::Analytical,
                    ..base_nop.clone()
                };
                let e = evaluate_package(graph, arch, &noc, &nop, &sim, CommBackend::Analytical);
                let edap = if sim_calibrated && k > 1 {
                    let sat = *sat_cache
                        .entry((nop_topo, k))
                        .or_insert_with(|| saturation_rate(nop_topo, k, &nop, sim.seed));
                    let lat = saturation_derated_latency_s(&e, &nop, sat);
                    e.edap_with_latency(lat)
                } else {
                    e.edap()
                };
                candidates.push((k, nop_topo, noc_topo, edap));
                if best.as_ref().map_or(true, |(b, _)| edap < *b) {
                    best = Some((edap, e));
                }
            }
        }
    }
    let (best_edap, best) = best.expect("non-empty search space");
    ScaleoutRecommendation {
        chiplets: best.chiplets,
        nop_topology: best.nop_topology,
        noc_topology: best.noc_topology,
        best,
        best_edap,
        candidates,
        sim_calibrated,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;

    #[test]
    fn compact_nets_get_tree() {
        let arch = ArchConfig::default();
        let noc = NocConfig::default();
        for g in [models::mlp(), models::lenet5()] {
            let r = recommend_topology(&g, &arch, &noc);
            assert_eq!(r.topology, Topology::Tree, "{}: {r:?}", g.name);
        }
    }

    #[test]
    fn rule_thresholds() {
        assert_eq!(rule_of_thumb(5.0e3), Some(Topology::Mesh));
        assert_eq!(rule_of_thumb(0.5e3), Some(Topology::Tree));
        assert_eq!(rule_of_thumb(1.5e3), None);
    }

    #[test]
    fn vgg19_density_in_mesh_band() {
        // VGG-19's connection density (~2-4.5k) must land in the paper's
        // mesh region of Fig. 20.
        let d = models::vgg(19).density_report().connection_density();
        assert!(d > DENSITY_MESH_THRESHOLD, "VGG-19 density {d}");
    }

    #[test]
    fn lenet_density_in_tree_band() {
        let d = models::lenet5().density_report().connection_density();
        assert!(d < DENSITY_TREE_THRESHOLD, "LeNet-5 density {d}");
    }

    #[test]
    fn scaleout_advisor_covers_the_space_and_picks_the_min() {
        let rec = recommend_scaleout(
            &models::lenet5(),
            &ArchConfig::default(),
            &NocConfig::default(),
            &NopConfig::default(),
        );
        // 1 chiplet x 1 NoP x 2 NoCs + 3 counts x 3 NoPs x 2 NoCs = 20.
        assert_eq!(rec.candidates.len(), 2 + 3 * 3 * 2);
        let min = rec
            .candidates
            .iter()
            .map(|&(_, _, _, edap)| edap)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(rec.best.edap(), min);
        assert_eq!(rec.chiplets, rec.best.chiplets);
        assert_eq!(rec.nop_topology, rec.best.nop_topology);
        assert_eq!(rec.noc_topology, rec.best.noc_topology);
    }

    #[test]
    fn scaleout_advisor_runs_on_every_zoo_model() {
        // The acceptance bar: a (chiplets, NoP, NoC) recommendation exists
        // for every model in the zoo. Keep the sweep cheap by reusing the
        // default SerDes parameters.
        let arch = ArchConfig::default();
        let noc = NocConfig::default();
        let nop = NopConfig::default();
        for g in crate::dnn::model_zoo() {
            let rec = recommend_scaleout(&g, &arch, &noc, &nop);
            assert!(rec.best.edap().is_finite() && rec.best.edap() > 0.0, "{}", g.name);
            assert!(SCALEOUT_CHIPLET_COUNTS.contains(&rec.chiplets), "{}", g.name);
        }
    }

    fn synthetic_eval(chiplets: usize, cross_bits: u64, latency_s: f64) -> NopEvaluation {
        NopEvaluation {
            dnn: "synthetic".into(),
            noc_topology: Topology::Mesh,
            nop_topology: NopTopology::Ring,
            chiplets,
            populated: chiplets,
            tiles: 4,
            tiles_per_chiplet: vec![1; chiplets.max(1)],
            cross_bits,
            compute_latency_s: latency_s,
            compute_energy_j: 1e-6,
            compute_area_mm2: 10.0,
            noc_latency_s: 0.0,
            noc_energy_j: 0.0,
            noc_area_mm2: 1.0,
            nop_latency_s: 0.0,
            nop_energy_j: 0.0,
            nop_area_mm2: 1.0,
        }
    }

    #[test]
    fn saturation_derating_engages_only_above_the_measured_rate() {
        let nop = NopConfig::default(); // 32-bit flits, 0.5 GHz
        // 4 chiplets, 1 Mbit cut, 10 us frame: offered = 31250 flits /
        // (4 x 1e-5 s x 0.5e9) = 1.5625 flits/chiplet/cycle.
        let hot = synthetic_eval(4, 1_000_000, 1e-5);
        let lat = hot.latency_s();
        // Measured saturation below the offered rate: latency scales by
        // offered/rate.
        let derated = saturation_derated_latency_s(&hot, &nop, Some(0.5));
        assert!((derated - lat * (1.5625 / 0.5)).abs() / derated < 1e-9);
        // At or above the offered rate: analytical latency stands.
        assert_eq!(saturation_derated_latency_s(&hot, &nop, Some(2.0)), lat);
        // No measurement (topology never saturated): unchanged.
        assert_eq!(saturation_derated_latency_s(&hot, &nop, None), lat);
        // Single chip or no cut traffic: unchanged.
        let solo = synthetic_eval(1, 0, 1e-5);
        assert_eq!(
            saturation_derated_latency_s(&solo, &nop, Some(0.1)),
            solo.latency_s()
        );
    }

    #[test]
    fn scaleout_advisor_sim_mode_folds_in_measured_saturation() {
        // `[nop] mode = sim`: the advisor measures saturation per (NoP, k)
        // and derates saturating candidates. Structural contracts: the
        // flag is set, the space is unchanged, ranking still picks the
        // minimum, and derating can only *raise* a candidate's ranking
        // EDAP relative to the analytical run (k = 1 rows are identical).
        let arch = ArchConfig::default();
        let noc = NocConfig::default();
        let g = models::lenet5();
        let ana = recommend_scaleout(&g, &arch, &noc, &NopConfig::default());
        let cal = recommend_scaleout(
            &g,
            &arch,
            &noc,
            &NopConfig {
                mode: crate::config::NopMode::Sim,
                ..NopConfig::default()
            },
        );
        assert!(!ana.sim_calibrated);
        assert!(cal.sim_calibrated);
        assert_eq!(ana.candidates.len(), cal.candidates.len());
        let min = cal
            .candidates
            .iter()
            .map(|&(_, _, _, edap)| edap)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(cal.best_edap, min);
        // Analytical mode: the ranking EDAP is exactly the winner's EDAP.
        assert_eq!(ana.best_edap, ana.best.edap());
        for (a, c) in ana.candidates.iter().zip(&cal.candidates) {
            assert_eq!((a.0, a.1, a.2), (c.0, c.1, c.2));
            assert!(c.3 >= a.3 - 1e-12 * a.3.abs(), "derating lowered EDAP");
            if a.0 == 1 {
                assert_eq!(a.3, c.3, "k=1 must be untouched by calibration");
            }
        }
    }

    #[test]
    fn dense_nets_get_mesh_from_rule() {
        // The paper places DenseNet-100 and ResNet-50 in the mesh region.
        for g in [models::densenet(100), models::resnet(50)] {
            let r = recommend_topology(&g, &ArchConfig::default(), &NocConfig::default());
            assert_eq!(r.topology, Topology::Mesh, "{}: density {}", g.name, r.density);
        }
    }
}
