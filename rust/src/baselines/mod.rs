//! State-of-the-art comparators for Table 4 and the P2P baseline of
//! Fig. 3 / Fig. 8.
//!
//! As in the paper, ISAAC / PipeLayer / AtomLayer are compared through
//! their *published* VGG-19 numbers (the paper quotes latency values from
//! AtomLayer's table — the entries marked `*`); the proposed-SRAM and
//! proposed-ReRAM rows come from our own evaluator. The P2P-interconnect
//! IMC architecture (paper ref. [32]-style) is fully modeled.

use crate::arch::{CommBackend, HeteroArchitecture};
use crate::config::ArchConfig;
use crate::dnn::models;

/// One row of the Table 4 comparison.
#[derive(Clone, Debug)]
pub struct BaselineRow {
    /// Accelerator name as printed in Table 4.
    pub name: &'static str,
    /// Inference latency for VGG-19, ms.
    pub latency_ms: f64,
    /// Dynamic power per frame, W.
    pub power_w: f64,
    /// Throughput, frames/s.
    pub fps: f64,
    /// Energy-delay-area product, J·ms·mm².
    pub edap: f64,
    /// True for rows quoted from the literature (paper Table 4 `*`).
    pub published: bool,
}

/// AtomLayer (Qiao et al., DAC'18) published VGG-19 numbers.
pub fn atomlayer() -> BaselineRow {
    BaselineRow {
        name: "AtomLayer",
        latency_ms: 6.92,
        power_w: 4.8,
        fps: 145.0,
        edap: 1.58,
        published: true,
    }
}

/// PipeLayer (Song et al., HPCA'17) published VGG-19 numbers
/// (latency as reported in AtomLayer).
pub fn pipelayer() -> BaselineRow {
    BaselineRow {
        name: "PipeLayer",
        latency_ms: 2.6,
        power_w: 168.6,
        fps: 385.0,
        edap: 94.17,
        published: true,
    }
}

/// ISAAC (Shafiee et al., ISCA'16) published VGG-19 numbers
/// (latency as reported in AtomLayer).
pub fn isaac() -> BaselineRow {
    BaselineRow {
        name: "ISAAC",
        latency_ms: 8.0,
        power_w: 65.8,
        fps: 125.0,
        edap: 359.64,
        published: true,
    }
}

/// Our proposed architecture evaluated on VGG-19 (Table 4 rows 1–2).
pub fn proposed(arch: ArchConfig, backend: CommBackend) -> BaselineRow {
    let tech = arch.tech;
    let hw = HeteroArchitecture::new(arch);
    let e = hw.evaluate(&models::vgg(19), backend);
    BaselineRow {
        name: match tech {
            crate::config::MemTech::Sram => "Proposed-SRAM",
            crate::config::MemTech::Reram => "Proposed-ReRAM",
        },
        latency_ms: e.latency_s() * 1e3,
        power_w: e.power_w(),
        fps: e.fps(),
        edap: e.edap(),
        published: false,
    }
}

/// All Table 4 rows in the paper's order.
pub fn table4_rows(backend: CommBackend) -> Vec<BaselineRow> {
    vec![
        proposed(ArchConfig::sram(), backend),
        proposed(ArchConfig::reram(), backend),
        atomlayer(),
        pipelayer(),
        isaac(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn published_rows_match_paper_table4() {
        let a = atomlayer();
        assert_eq!(a.latency_ms, 6.92);
        assert_eq!(a.edap, 1.58);
        let p = pipelayer();
        assert_eq!(p.power_w, 168.6);
        let i = isaac();
        assert_eq!(i.fps, 125.0);
        assert!(a.published && p.published && i.published);
    }

    #[test]
    fn proposed_beats_baselines_on_edap() {
        // The paper's headline: proposed ReRAM achieves ~6x EDAP vs
        // AtomLayer (and orders of magnitude vs PipeLayer/ISAAC). Our model
        // must reproduce the *direction* and a >2x margin.
        let ours = proposed(ArchConfig::reram(), CommBackend::Analytical);
        assert!(
            ours.edap < atomlayer().edap / 2.0,
            "proposed EDAP {} vs AtomLayer {}",
            ours.edap,
            atomlayer().edap
        );
        assert!(ours.edap < pipelayer().edap);
        assert!(ours.edap < isaac().edap);
        // Power per frame should be far below PipeLayer's 168.6 W.
        assert!(ours.power_w < pipelayer().power_w / 10.0);
    }

    #[test]
    fn table_has_five_rows_in_order() {
        let rows = table4_rows(CommBackend::Analytical);
        let names: Vec<_> = rows.iter().map(|r| r.name).collect();
        assert_eq!(
            names,
            vec![
                "Proposed-SRAM",
                "Proposed-ReRAM",
                "AtomLayer",
                "PipeLayer",
                "ISAAC"
            ]
        );
    }
}
