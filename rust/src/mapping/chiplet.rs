//! Sharding a mapped DNN across the chiplets of a 2.5D package.
//!
//! A [`ChipletPartition`] assigns every *weight layer* (and therefore its
//! whole tile range — layers are never split across chiplets, mirroring the
//! no-layer-splitting rule of [`super::Mapping`]) to one of `k` chiplets:
//!
//! 1. **Greedy contiguous split** — layers stay in topological order and
//!    each chiplet receives a contiguous run targeting an equal share of
//!    the package's tiles (pipeline-friendly, like the paper's Fig. 7
//!    sequential placement one level up).
//! 2. **Communication-minimizing refinement** — boundary layers are moved
//!    between adjacent chiplets whenever that strictly reduces the
//!    cross-chiplet traffic (bits/frame over the cut) without blowing the
//!    tile-balance budget. This is what keeps DenseNet-style skip fan-out
//!    from straddling a package link.
//!
//! The partition also derives the **inter-chiplet injection matrix**
//! (bits/frame between every chiplet pair) that drives the NoP evaluation
//! in [`crate::nop::evaluator`].

use super::injection::resolve_producers;
use super::Mapping;
use crate::config::ArchConfig;
use crate::dnn::DnnGraph;

/// Tile-balance slack: a chiplet may exceed the ideal equal share by this
/// factor during refinement (a single huge layer may exceed it regardless —
/// layers are atomic).
const BALANCE_SLACK: f64 = 1.25;

/// One directed inter-layer edge of the mapped DNN, in mapping-index space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerEdge {
    /// Producer index into `Mapping::layers`.
    pub src: usize,
    /// Consumer index into `Mapping::layers`.
    pub dst: usize,
    /// Activation payload per frame, bits.
    pub bits: u64,
}

/// A layer→chiplet assignment for one mapped DNN.
#[derive(Clone, Debug)]
pub struct ChipletPartition {
    /// Chiplets in the package (`assignment` values are `< chiplets`).
    pub chiplets: usize,
    /// `assignment[i]` = chiplet of `mapping.layers[i]`; contiguous and
    /// non-decreasing.
    pub assignment: Vec<usize>,
    /// Local tile count per chiplet (some may be 0 when `chiplets` exceeds
    /// the layer count).
    pub tiles_per_chiplet: Vec<usize>,
    /// Global tile id → (chiplet, local tile id).
    pub tile_home: Vec<(usize, usize)>,
    /// All mapped inter-layer edges (producer and consumer both on-chip).
    pub edges: Vec<LayerEdge>,
}

impl ChipletPartition {
    /// Partition `mapping` over `k` chiplets (greedy split + refinement).
    pub fn build(graph: &DnnGraph, mapping: &Mapping, arch: &ArchConfig, k: usize) -> Self {
        assert!(k > 0, "package needs at least one chiplet");
        let n = mapping.layers.len();
        assert!(n > 0, "cannot partition a DNN with no weight layers");
        let k_eff = k.min(n);
        let edges = layer_edges(graph, mapping, arch);

        // Pass 1: greedy contiguous split on the tile shares.
        let total = mapping.total_tiles;
        let mut assignment = vec![0usize; n];
        let mut chiplet = 0usize;
        let mut acc_tiles = 0usize;
        let mut layers_in_current = 0usize;
        for i in 0..n {
            if chiplet + 1 < k_eff && layers_in_current > 0 {
                // Cut when the remaining layers are exactly enough to give
                // every still-empty chiplet one, or when the current
                // chiplet reached its cumulative tile share.
                let must_cut = n - i == k_eff - chiplet - 1;
                let share_full =
                    acc_tiles as f64 >= (chiplet + 1) as f64 * total as f64 / k_eff as f64;
                if must_cut || share_full {
                    chiplet += 1;
                    layers_in_current = 0;
                }
            }
            assignment[i] = chiplet;
            acc_tiles += mapping.layers[i].count;
            layers_in_current += 1;
        }

        // Pass 2: boundary refinement — move a layer across an adjacent cut
        // when it strictly reduces cut bits and keeps the balance budget.
        let cap = balance_cap(mapping, k_eff);
        let mut improved = true;
        let mut guard = 0usize;
        let mut current_cut = cut_bits(&edges, &assignment);
        while improved && guard < 4 * n {
            improved = false;
            guard += 1;
            for i in 0..n {
                let c = assignment[i];
                // First layer of chiplet c>0 may move back to c-1; last
                // layer of chiplet c<k-1 may move forward to c+1.
                for target in [c.wrapping_sub(1), c + 1] {
                    if target >= k_eff || !is_boundary_move(&assignment, i, target) {
                        continue;
                    }
                    if !move_keeps_invariants(mapping, &assignment, i, target, cap) {
                        continue;
                    }
                    let mut trial = assignment.clone();
                    trial[i] = target;
                    let after = cut_bits(&edges, &trial);
                    if after < current_cut {
                        assignment = trial;
                        current_cut = after;
                        improved = true;
                    }
                }
            }
        }

        Self::from_assignment(mapping, k, assignment, edges)
    }

    /// Build directly from an assignment (used by `build` and by tests).
    pub fn from_assignment(
        mapping: &Mapping,
        chiplets: usize,
        assignment: Vec<usize>,
        edges: Vec<LayerEdge>,
    ) -> Self {
        assert_eq!(assignment.len(), mapping.layers.len());
        let mut tiles_per_chiplet = vec![0usize; chiplets];
        let mut tile_home = vec![(0usize, 0usize); mapping.total_tiles];
        for (i, lt) in mapping.layers.iter().enumerate() {
            let c = assignment[i];
            for t in lt.tiles() {
                tile_home[t] = (c, tiles_per_chiplet[c]);
                tiles_per_chiplet[c] += 1;
            }
        }
        Self {
            chiplets,
            assignment,
            tiles_per_chiplet,
            tile_home,
            edges,
        }
    }

    /// Chiplet that owns global tile `t`.
    pub fn chiplet_of_tile(&self, t: usize) -> usize {
        self.tile_home[t].0
    }

    /// Local tile id of global tile `t` within its chiplet.
    pub fn local_tile(&self, t: usize) -> usize {
        self.tile_home[t].1
    }

    /// Chiplet of the mapping-layer with index `mi`.
    pub fn chiplet_of_layer(&self, mi: usize) -> usize {
        self.assignment[mi]
    }

    /// Total bits/frame crossing chiplet boundaries.
    pub fn cut_bits(&self) -> u64 {
        cut_bits(&self.edges, &self.assignment)
    }

    /// The inter-chiplet injection matrix: `m[src][dst]` = bits/frame the
    /// chiplet `src` must deliver to chiplet `dst` over the NoP.
    pub fn cross_traffic(&self) -> Vec<Vec<u64>> {
        let mut m = vec![vec![0u64; self.chiplets]; self.chiplets];
        for e in &self.edges {
            let (cs, cd) = (self.assignment[e.src], self.assignment[e.dst]);
            if cs != cd {
                m[cs][cd] += e.bits;
            }
        }
        m
    }

    /// The inter-chiplet injection matrix lowered to package drain flows:
    /// one `(src_chiplet, dst_chiplet, flits)` entry per directed chiplet
    /// pair with traffic, the bits/frame serialized into `link_width`-bit
    /// NoP flits. This is the bridge from the partition to the flit-level
    /// package simulator ([`crate::nop::sim::NopSim`]).
    pub fn nop_flows(&self, link_width: usize) -> Vec<(usize, usize, u64)> {
        assert!(link_width > 0, "link_width must be positive");
        let mut flows = Vec::new();
        for (s, row) in self.cross_traffic().iter().enumerate() {
            for (d, &bits) in row.iter().enumerate() {
                if bits > 0 {
                    flows.push((s, d, bits.div_ceil(link_width as u64)));
                }
            }
        }
        flows
    }

    /// Invariants used by unit and property tests.
    pub fn validate(&self, mapping: &Mapping) -> Result<(), String> {
        if self.assignment.len() != mapping.layers.len() {
            return Err("assignment length mismatch".into());
        }
        // Contiguous, non-decreasing, starting at 0, no gaps.
        let mut prev = 0usize;
        for (i, &c) in self.assignment.iter().enumerate() {
            if c >= self.chiplets {
                return Err(format!("layer {i} assigned to out-of-range chiplet {c}"));
            }
            if i == 0 && c != 0 {
                return Err("first layer must sit on chiplet 0".into());
            }
            if c < prev || c > prev + 1 {
                return Err(format!(
                    "assignment not contiguous at layer {i}: {prev} -> {c}"
                ));
            }
            prev = c;
        }
        // Tile accounting closes.
        let sum: usize = self.tiles_per_chiplet.iter().sum();
        if sum != mapping.total_tiles {
            return Err(format!(
                "tiles_per_chiplet sums to {sum}, expected {}",
                mapping.total_tiles
            ));
        }
        for (t, &(c, l)) in self.tile_home.iter().enumerate() {
            if c >= self.chiplets || l >= self.tiles_per_chiplet[c] {
                return Err(format!("tile {t} has invalid home ({c}, {l})"));
            }
        }
        Ok(())
    }

    /// Chiplets that actually hold at least one layer.
    pub fn populated_chiplets(&self) -> usize {
        self.tiles_per_chiplet.iter().filter(|&&t| t > 0).count()
    }

    /// The package I/O gateway: the chiplet owning the first mapped layer
    /// (contiguity pins it to chiplet 0). Request inputs enter the
    /// package here — the serving scheduler's NoP ingress routes start at
    /// this chiplet.
    pub fn gateway_chiplet(&self) -> usize {
        self.assignment.first().copied().unwrap_or(0)
    }
}

/// All on-chip inter-layer edges in mapping-index space, with bits/frame.
pub fn layer_edges(graph: &DnnGraph, mapping: &Mapping, arch: &ArchConfig) -> Vec<LayerEdge> {
    // graph layer index -> mapping index.
    let mut midx = vec![usize::MAX; graph.layers.len()];
    for (i, lt) in mapping.layers.iter().enumerate() {
        midx[lt.layer] = i;
    }
    let mut edges = Vec::new();
    for (di, lt) in mapping.layers.iter().enumerate() {
        for (producer, activations) in resolve_producers(graph, lt.layer) {
            let si = midx[producer];
            if si == usize::MAX {
                continue; // network input -> off-package
            }
            edges.push(LayerEdge {
                src: si,
                dst: di,
                bits: activations as u64 * arch.n_bits as u64,
            });
        }
    }
    edges
}

/// Bits/frame crossing the cut induced by `assignment`.
fn cut_bits(edges: &[LayerEdge], assignment: &[usize]) -> u64 {
    edges
        .iter()
        .filter(|e| assignment[e.src] != assignment[e.dst])
        .map(|e| e.bits)
        .sum()
}

/// Per-chiplet tile budget for refinement: the ideal share with slack, but
/// never below the largest single layer (layers are atomic).
fn balance_cap(mapping: &Mapping, k_eff: usize) -> usize {
    let ideal = mapping.total_tiles.div_ceil(k_eff);
    let largest = mapping.layers.iter().map(|lt| lt.count).max().unwrap_or(1);
    ((ideal as f64 * BALANCE_SLACK).ceil() as usize).max(largest)
}

/// Is moving layer `i` to `target` a boundary move that keeps the
/// assignment contiguous? (`target` must be the adjacent chiplet and `i`
/// must be the first/last layer of its current run.)
fn is_boundary_move(assignment: &[usize], i: usize, target: usize) -> bool {
    let c = assignment[i];
    if target + 1 == c {
        // Move back: `i` must be the first layer of chiplet c.
        i > 0 && assignment[i - 1] == target
    } else if target == c + 1 {
        // Move forward: `i` must be the last layer of chiplet c, and the
        // next layer must already sit on `target`.
        i + 1 < assignment.len() && assignment[i + 1] == target
    } else {
        false
    }
}

/// Does moving layer `i` to `target` keep every chiplet non-empty and the
/// balance acceptable? A move is balance-acceptable when the target stays
/// within the tile budget, or when it does not worsen the package's
/// worst-loaded chiplet (moves that *improve* balance are always allowed).
fn move_keeps_invariants(
    mapping: &Mapping,
    assignment: &[usize],
    i: usize,
    target: usize,
    cap: usize,
) -> bool {
    let c = assignment[i];
    let count_c = assignment.iter().filter(|&&a| a == c).count();
    if count_c <= 1 {
        return false; // would empty chiplet c
    }
    let tiles_of = |ch: usize, asg: &[usize]| -> usize {
        asg.iter()
            .enumerate()
            .filter(|&(_, &a)| a == ch)
            .map(|(j, _)| mapping.layers[j].count)
            .sum()
    };
    let moved = mapping.layers[i].count;
    let target_after = tiles_of(target, assignment) + moved;
    if target_after <= cap {
        return true;
    }
    // Over budget, but still allowed if the worst-loaded chiplet does not
    // get worse (the move shifts load off an even heavier chiplet).
    let old_max = tiles_of(c, assignment).max(tiles_of(target, assignment));
    let new_max = (tiles_of(c, assignment) - moved).max(target_after);
    new_max <= old_max
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::{models, Dataset, DnnGraph};

    fn part(g: &DnnGraph, k: usize) -> (Mapping, ChipletPartition) {
        let arch = ArchConfig::default();
        let m = Mapping::build(g, &arch);
        let p = ChipletPartition::build(g, &m, &arch, k);
        (m, p)
    }

    #[test]
    fn two_fc_hand_computed_cut() {
        // fc1: 784->512 = 64 crossbars -> 4 tiles; fc2: 512->256 -> 1 tile.
        // k=2 puts fc1 on chiplet 0, fc2 on chiplet 1; the only cut edge
        // carries 512 activations x 8 bits = 4096 bits/frame.
        let mut g = DnnGraph::new("two-fc", Dataset::Mnist);
        let f1 = g.fc("fc1", 0, 512);
        g.fc("fc2", f1, 256);
        let (m, p) = part(&g, 2);
        p.validate(&m).unwrap();
        assert_eq!(p.assignment, vec![0, 1]);
        assert_eq!(p.gateway_chiplet(), 0);
        assert_eq!(p.tiles_per_chiplet, vec![4, 1]);
        assert_eq!(p.cut_bits(), 512 * 8);
        let x = p.cross_traffic();
        assert_eq!(x[0][1], 512 * 8);
        assert_eq!(x[1][0], 0);
        assert_eq!(x[0][0], 0);
    }

    #[test]
    fn local_tile_ids_are_dense_per_chiplet() {
        let (m, p) = part(&models::vgg(19), 4);
        p.validate(&m).unwrap();
        // Every chiplet's local ids are 0..tiles_per_chiplet[c], each used
        // exactly once.
        for c in 0..4 {
            let mut seen = vec![false; p.tiles_per_chiplet[c]];
            for t in 0..m.total_tiles {
                if p.chiplet_of_tile(t) == c {
                    let l = p.local_tile(t);
                    assert!(!seen[l], "duplicate local id {l} on chiplet {c}");
                    seen[l] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "chiplet {c} local ids not dense");
        }
    }

    #[test]
    fn refinement_never_worse_than_greedy_on_zoo() {
        let arch = ArchConfig::default();
        for g in [models::resnet(50), models::densenet(40), models::vgg(16)] {
            let m = Mapping::build(&g, &arch);
            let edges = layer_edges(&g, &m, &arch);
            for k in [2usize, 4, 8] {
                let p = ChipletPartition::build(&g, &m, &arch, k);
                p.validate(&m).unwrap_or_else(|e| panic!("{} k={k}: {e}", g.name));
                // Reconstruct the pure greedy cut by disabling refinement:
                // greedy is the starting point, so the refined cut can only
                // be <= any contiguous-prefix split with the same k... at
                // minimum it must not exceed the total edge volume.
                let total: u64 = edges.iter().map(|e| e.bits).sum();
                assert!(p.cut_bits() <= total);
                assert_eq!(p.populated_chiplets(), k.min(m.layers.len()));
            }
        }
    }

    #[test]
    fn refinement_moves_fat_edge_off_the_cut() {
        // fc1 784->512 (4 tiles), fc2 512->4096 (16 tiles), fc3 4096->64
        // (2 tiles). The tile-balanced greedy split cuts after fc2 ([0,0,1],
        // 20|2), putting the fat 4096-activation fc2->fc3 edge on the NoP.
        // Refinement must move fc2 forward ([0,1,1], 4|18 — better balanced
        // AND cheaper), leaving only the thin 512-activation edge cut.
        let mut g = DnnGraph::new("chain", Dataset::Mnist);
        let f1 = g.fc("fc1", 0, 512);
        let f2 = g.fc("fc2", f1, 4096);
        g.fc("fc3", f2, 64);
        let arch = ArchConfig::default();
        let m = Mapping::build(&g, &arch);
        let p = ChipletPartition::build(&g, &m, &arch, 2);
        p.validate(&m).unwrap();
        assert_eq!(
            p.assignment,
            vec![0, 1, 1],
            "refinement should move fc2 across the cut"
        );
        assert_eq!(p.cut_bits(), 512 * 8);
    }

    #[test]
    fn nop_flows_serialize_cut_bits() {
        // two-fc at k=2 cuts one 4096-bit edge: with 32-bit NoP flits that
        // is exactly one 0->1 flow of 128 flits.
        let mut g = DnnGraph::new("two-fc", Dataset::Mnist);
        let f1 = g.fc("fc1", 0, 512);
        g.fc("fc2", f1, 256);
        let (_, p) = part(&g, 2);
        assert_eq!(p.nop_flows(32), vec![(0, 1, 128)]);
        // Partial flits round up; a single chiplet has no flows at all.
        assert_eq!(p.nop_flows(4096), vec![(0, 1, 1)]);
        let (_, p1) = part(&g, 1);
        assert!(p1.nop_flows(32).is_empty());
    }

    #[test]
    fn one_chiplet_means_no_cross_traffic() {
        let (m, p) = part(&models::resnet(50), 1);
        p.validate(&m).unwrap();
        assert_eq!(p.cut_bits(), 0);
        assert!(p.cross_traffic()[0][0] == 0);
    }

    #[test]
    fn more_chiplets_than_layers_leaves_spares_empty() {
        let mut g = DnnGraph::new("tiny", Dataset::Mnist);
        let f1 = g.fc("fc1", 0, 32);
        g.fc("fc2", f1, 16);
        let (m, p) = part(&g, 8);
        p.validate(&m).unwrap();
        assert_eq!(p.populated_chiplets(), 2);
        assert_eq!(p.tiles_per_chiplet.iter().filter(|&&t| t == 0).count(), 6);
    }

    #[test]
    fn dense_skips_accounted_in_edges() {
        let arch = ArchConfig::default();
        let g = models::densenet(40);
        let m = Mapping::build(&g, &arch);
        let edges = layer_edges(&g, &m, &arch);
        // DenseNet has far more edges than layers (concat fan-in).
        assert!(edges.len() > 2 * m.layers.len(), "{} edges", edges.len());
        // Every edge stays within mapped indices and carries bits.
        for e in &edges {
            assert!(e.src < m.layers.len() && e.dst < m.layers.len());
            assert!(e.bits > 0);
        }
    }
}
