//! Injection-rate matrix construction (paper Eq. 3 / Algorithm 1 lines
//! 3–10): for every consumer weight layer `i` and every producer weight
//! layer `p` feeding it (resolved through weight-less pool/add/concat
//! nodes), traffic flows from every tile of `p` to every tile of `i` at
//!
//! ```text
//! λ = A_(p→i) · N_bits · FPS / (T_p · T_i · W · freq)      [flits/cycle]
//! ```
//!
//! where `A_(p→i)` is the number of activation elements `p` delivers to `i`
//! per frame. The first weight layer receives the input image from outside
//! the NoC (Algorithm 1 guards `i > 0`), so it generates no on-chip flows.

use super::Mapping;
use crate::config::{ArchConfig, NocConfig};
use crate::dnn::DnnGraph;

/// One all-pairs flow bundle between two layers' tile ranges.
#[derive(Clone, Debug, PartialEq)]
pub struct TrafficFlow {
    /// Producer weight-layer graph index.
    pub src_layer: usize,
    /// Consumer weight-layer graph index.
    pub dst_layer: usize,
    /// Activation elements delivered per frame.
    pub activations: usize,
    /// Per-(src-tile, dst-tile) injection rate in flits/cycle.
    pub rate: f64,
    /// Source tile ids.
    pub src_tiles: std::ops::Range<usize>,
    /// Destination tile ids.
    pub dst_tiles: std::ops::Range<usize>,
}

impl TrafficFlow {
    /// Total bits transferred per frame for this flow bundle.
    pub fn bits_per_frame(&self, n_bits: usize) -> usize {
        self.activations * n_bits
    }
}

/// The full injection specification for one DNN on one mapping.
#[derive(Clone, Debug)]
pub struct InjectionMatrix {
    /// Every inter-layer flow bundle.
    pub flows: Vec<TrafficFlow>,
    /// Tiles the mapping occupies (the network size).
    pub total_tiles: usize,
}

impl InjectionMatrix {
    /// Build from a graph + mapping (Eq. 3).
    pub fn build(
        graph: &DnnGraph,
        mapping: &Mapping,
        arch: &ArchConfig,
        noc: &NocConfig,
    ) -> Self {
        let mut flows = Vec::new();
        for lt in &mapping.layers {
            let consumer = lt.layer;
            for (producer, activations) in resolve_producers(graph, consumer) {
                let Some(pt) = mapping.tiles_of(producer) else {
                    continue; // producer is the network input -> off-NoC
                };
                let t_src = pt.count;
                let t_dst = lt.count;
                let rate = (activations as f64 * arch.n_bits as f64 * arch.fps)
                    / (t_src as f64 * t_dst as f64 * noc.bus_width as f64 * arch.freq_hz);
                flows.push(TrafficFlow {
                    src_layer: producer,
                    dst_layer: consumer,
                    activations,
                    rate,
                    src_tiles: pt.tiles(),
                    dst_tiles: lt.tiles(),
                });
            }
        }
        Self {
            flows,
            total_tiles: mapping.total_tiles,
        }
    }

    /// Flows whose destination is weight layer `li`.
    pub fn flows_into(&self, li: usize) -> impl Iterator<Item = &TrafficFlow> {
        self.flows.iter().filter(move |f| f.dst_layer == li)
    }

    /// Aggregate injection rate per source tile (flits/cycle), used for
    /// saturation checks and the analytical model's Λ diagonal.
    pub fn node_injection_rates(&self) -> Vec<f64> {
        let mut rates = vec![0.0; self.total_tiles];
        for f in &self.flows {
            for s in f.src_tiles.clone() {
                rates[s] += f.rate * f.dst_tiles.len() as f64;
            }
        }
        rates
    }

    /// Sum of all pairwise rates (network load in flits/cycle).
    pub fn total_rate(&self) -> f64 {
        self.flows
            .iter()
            .map(|f| f.rate * (f.src_tiles.len() * f.dst_tiles.len()) as f64)
            .sum()
    }
}

/// Resolve the producers of weight layer `li` through weight-less nodes.
/// Returns `(producer_graph_index, activation_elements)` pairs; producers
/// that resolve to the network input are reported with index 0 (the Input
/// node — callers treat it as off-chip).
pub fn resolve_producers(graph: &DnnGraph, li: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    // Walk each direct predecessor; descend through weight-less layers.
    fn descend(graph: &DnnGraph, node: usize, out: &mut Vec<(usize, usize)>) {
        let layer = &graph.layers[node];
        if layer.kind.has_weights() || node == 0 {
            out.push((node, layer.output_elems()));
            return;
        }
        for &p in &layer.inputs {
            descend(graph, p, out);
        }
    }
    for &p in &graph.layers[li].inputs {
        descend(graph, p, &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::{models, Dataset, DnnGraph};

    fn build_all(g: &DnnGraph) -> (Mapping, InjectionMatrix) {
        let arch = ArchConfig::default();
        let noc = NocConfig::default();
        let m = Mapping::build(g, &arch);
        let inj = InjectionMatrix::build(g, &m, &arch, &noc);
        (m, inj)
    }

    #[test]
    fn eq3_worked_example() {
        // Two FC layers: fc1 (784->512, 64 xbars -> 4 tiles),
        // fc2 (512->256, 2*8=16 xbars -> 1 tile).
        // A = 512 activations into fc2; rate = 512*8*60/(4*1*32*1e9).
        let mut g = DnnGraph::new("two-fc", Dataset::Mnist);
        let f1 = g.fc("fc1", 0, 512);
        g.fc("fc2", f1, 256);
        let (m, inj) = build_all(&g);
        assert_eq!(m.total_tiles, 4 + 1);
        assert_eq!(inj.flows.len(), 1);
        let f = &inj.flows[0];
        assert_eq!(f.activations, 512);
        let expect = 512.0 * 8.0 * 60.0 / (4.0 * 1.0 * 32.0 * 1.0e9);
        assert!((f.rate - expect).abs() < 1e-18);
    }

    #[test]
    fn first_layer_generates_no_onchip_flow() {
        let g = models::mlp();
        let (_, inj) = build_all(&g);
        // 3 FC layers -> flows fc1->fc2 and fc2->fc3 only.
        assert_eq!(inj.flows.len(), 2);
    }

    #[test]
    fn residual_creates_skip_flows() {
        let g = models::resnet(50);
        let (_, inj) = build_all(&g);
        // Every Add joins two producers, so some consumers have >1 inbound flow.
        let multi = g
            .weight_layers()
            .iter()
            .filter(|&&li| inj.flows_into(li).count() > 1)
            .count();
        assert!(multi > 10, "expected many multi-producer consumers, got {multi}");
    }

    #[test]
    fn densenet_fanout_dominates() {
        // DenseNet flows-per-weight-layer must exceed VGG's (connectivity).
        let d = models::densenet(100);
        let v = models::vgg(19);
        let (_, id) = build_all(&d);
        let (_, iv) = build_all(&v);
        let fd = id.flows.len() as f64 / d.num_weight_layers() as f64;
        let fv = iv.flows.len() as f64 / v.num_weight_layers() as f64;
        assert!(fd > 2.0 * fv, "DenseNet {fd} vs VGG {fv}");
    }

    #[test]
    fn rates_scale_inversely_with_bus_width() {
        let g = models::lenet5();
        let arch = ArchConfig::default();
        let m = Mapping::build(&g, &arch);
        let w32 = InjectionMatrix::build(&g, &m, &arch, &NocConfig::default());
        let w64 = InjectionMatrix::build(
            &g,
            &m,
            &arch,
            &NocConfig {
                bus_width: 64,
                ..NocConfig::default()
            },
        );
        for (a, b) in w32.flows.iter().zip(&w64.flows) {
            assert!((a.rate - 2.0 * b.rate).abs() < 1e-15);
        }
    }

    #[test]
    fn node_rates_cover_all_sources(){
        let g = models::vgg(19);
        let (m, inj) = build_all(&g);
        let rates = inj.node_injection_rates();
        assert_eq!(rates.len(), m.total_tiles);
        // Last layer's tiles send nothing; early tiles send something.
        assert!(rates.iter().any(|&r| r > 0.0));
        let total: f64 = rates.iter().sum();
        assert!((total - inj.total_rate()).abs() < 1e-9);
    }
}
