//! Mapping a DNN onto the multi-tiled IMC architecture:
//!
//! * Eq. 2 — crossbars per layer from kernel/channel shapes and PE size,
//! * tiles per layer (no layer split across tiles, tiles not shared),
//! * Fig. 7 — sequential tile numbering/placement,
//! * Eq. 3 — per source–destination injection-rate matrix,
//! * chiplet sharding — layer→chiplet partition + inter-chiplet injection
//!   matrix for the NoP scale-out path ([`chiplet`]).

pub mod chiplet;
pub mod injection;
pub mod placement;

pub use chiplet::{ChipletPartition, LayerEdge};
pub use injection::{InjectionMatrix, TrafficFlow};
pub use placement::Placement;

use crate::config::ArchConfig;
use crate::dnn::{DnnGraph, LayerKind};

/// Crossbar arrays needed by one weight layer (paper Eq. 2):
/// `ceil(Kx·Ky·C_in / PE_x) × ceil(C_out·N_bits / PE_y)`.
pub fn crossbars_for_layer(graph: &DnnGraph, li: usize, cfg: &ArchConfig) -> usize {
    let layer = &graph.layers[li];
    let (rows, cols) = match layer.kind {
        LayerKind::Conv {
            kx, ky, c_in, c_out, ..
        } => (kx * ky * c_in, c_out),
        LayerKind::Fc { inputs, outputs } => (inputs, outputs),
        _ => return 0,
    };
    // n_bits of weight precision spread over cells holding cell_bits each.
    let bit_cols = cols * cfg.n_bits.div_ceil(cfg.cell_bits);
    rows.div_ceil(cfg.pe_size) * bit_cols.div_ceil(cfg.pe_size)
}

/// The tile assignment of one weight layer: tiles `[start, start+count)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerTiles {
    /// Index into `DnnGraph::layers`.
    pub layer: usize,
    /// First global tile id owned by this layer.
    pub start: usize,
    /// Number of tiles (≥ 1).
    pub count: usize,
    /// Crossbars occupied (for utilization reporting).
    pub crossbars: usize,
}

impl LayerTiles {
    /// Global tile-id range owned by this layer.
    pub fn tiles(&self) -> std::ops::Range<usize> {
        self.start..self.start + self.count
    }
}

/// Full mapping of a DNN to tiles.
#[derive(Clone, Debug)]
pub struct Mapping {
    /// One entry per weight layer, in topological order.
    pub layers: Vec<LayerTiles>,
    /// Tiles the whole DNN occupies.
    pub total_tiles: usize,
    /// Crossbars the whole DNN occupies.
    pub total_crossbars: usize,
}

impl Mapping {
    /// Map `graph` onto tiles under `cfg` (Fig. 7 sequential placement:
    /// tiles are numbered layer by layer; a layer never shares a tile).
    pub fn build(graph: &DnnGraph, cfg: &ArchConfig) -> Self {
        let per_tile = cfg.pes_per_tile();
        let mut layers = Vec::new();
        let mut next_tile = 0usize;
        let mut total_crossbars = 0usize;
        for li in graph.weight_layers() {
            let xbars = crossbars_for_layer(graph, li, cfg);
            let count = xbars.div_ceil(per_tile).max(1);
            layers.push(LayerTiles {
                layer: li,
                start: next_tile,
                count,
                crossbars: xbars,
            });
            next_tile += count;
            total_crossbars += xbars;
        }
        Mapping {
            layers,
            total_tiles: next_tile,
            total_crossbars,
        }
    }

    /// Tile range of the weight layer with graph index `li`.
    pub fn tiles_of(&self, li: usize) -> Option<&LayerTiles> {
        self.layers.iter().find(|lt| lt.layer == li)
    }

    /// Crossbar utilization: fraction of allocated crossbar slots that hold
    /// weights (paper §1 notes VGG-19's high PE utilization).
    pub fn utilization(&self, cfg: &ArchConfig) -> f64 {
        let slots = self.total_tiles * cfg.pes_per_tile();
        if slots == 0 {
            0.0
        } else {
            self.total_crossbars as f64 / slots as f64
        }
    }

    /// Invariants used by property tests.
    pub fn validate(&self, cfg: &ArchConfig) -> Result<(), String> {
        let mut expected_start = 0usize;
        for lt in &self.layers {
            if lt.start != expected_start {
                return Err(format!(
                    "layer {} tiles not contiguous: start {} expected {}",
                    lt.layer, lt.start, expected_start
                ));
            }
            if lt.count == 0 {
                return Err(format!("layer {} has zero tiles", lt.layer));
            }
            if lt.crossbars > lt.count * cfg.pes_per_tile() {
                return Err(format!(
                    "layer {} crossbars {} exceed tile capacity {}",
                    lt.layer,
                    lt.crossbars,
                    lt.count * cfg.pes_per_tile()
                ));
            }
            expected_start += lt.count;
        }
        if expected_start != self.total_tiles {
            return Err("total_tiles mismatch".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;

    #[test]
    fn eq2_worked_example() {
        // VGG-19 conv3_1: 3x3x128 -> 256, 8-bit weights, 256x256 PEs:
        // rows = ceil(1152/256) = 5; cols = ceil(256*8/256) = 8 -> 40.
        let g = models::vgg(19);
        let cfg = ArchConfig::default();
        let li = g
            .layers
            .iter()
            .position(|l| l.name == "conv3_1")
            .unwrap();
        assert_eq!(crossbars_for_layer(&g, li, &cfg), 5 * 8);
    }

    #[test]
    fn eq2_fc_example() {
        // MLP fc1: 784x512, 8-bit: ceil(784/256)*ceil(4096/256) = 4*16 = 64.
        let g = models::mlp();
        let cfg = ArchConfig::default();
        let li = g.weight_layers()[0];
        assert_eq!(crossbars_for_layer(&g, li, &cfg), 4 * 16);
    }

    #[test]
    fn mapping_invariants_on_zoo() {
        let cfg = ArchConfig::default();
        for g in crate::dnn::model_zoo() {
            let m = Mapping::build(&g, &cfg);
            m.validate(&cfg).unwrap_or_else(|e| panic!("{}: {e}", g.name));
            assert_eq!(m.layers.len(), g.num_weight_layers());
            assert!(m.utilization(&cfg) <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn vgg19_scale_sanity() {
        // VGG-19 at 256x256/8-bit needs thousands of crossbars and >100 tiles.
        let cfg = ArchConfig::default();
        let m = Mapping::build(&models::vgg(19), &cfg);
        assert!(m.total_crossbars > 2_000, "{}", m.total_crossbars);
        assert!(m.total_tiles > 100, "{}", m.total_tiles);
    }

    #[test]
    fn smaller_pe_needs_more_crossbars() {
        let g = models::lenet5();
        let big = ArchConfig {
            pe_size: 256,
            ..ArchConfig::default()
        };
        let small = ArchConfig {
            pe_size: 64,
            ..ArchConfig::default()
        };
        let cb_big = Mapping::build(&g, &big).total_crossbars;
        let cb_small = Mapping::build(&g, &small).total_crossbars;
        assert!(cb_small > cb_big);
    }
}
