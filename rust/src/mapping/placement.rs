//! Tile placement (paper Fig. 7): tiles are numbered sequentially, layer by
//! layer, and placed row-major on a near-square grid. The injection matrix
//! incorporates placement through per-pair hop counts, so any placement
//! plugs in here.

/// Physical positions of `n` tiles on a `cols`-wide row-major grid.
#[derive(Clone, Debug)]
pub struct Placement {
    /// Number of placed tiles.
    pub n: usize,
    /// Grid width.
    pub cols: usize,
    /// Grid height.
    pub rows: usize,
}

impl Placement {
    /// Near-square grid: `cols = ceil(sqrt(n))`.
    pub fn square(n: usize) -> Self {
        assert!(n > 0);
        let cols = (n as f64).sqrt().ceil() as usize;
        let rows = n.div_ceil(cols);
        Self { n, cols, rows }
    }

    /// Grid coordinates (x = column, y = row) of tile `t`.
    #[inline]
    pub fn coords(&self, t: usize) -> (usize, usize) {
        debug_assert!(t < self.n);
        (t % self.cols, t / self.cols)
    }

    /// Tile id at (x, y), if occupied.
    pub fn at(&self, x: usize, y: usize) -> Option<usize> {
        if x >= self.cols || y >= self.rows {
            return None;
        }
        let t = y * self.cols + x;
        (t < self.n).then_some(t)
    }

    /// Manhattan hop distance between two tiles (the X-Y route length).
    pub fn hops(&self, a: usize, b: usize) -> usize {
        let (ax, ay) = self.coords(a);
        let (bx, by) = self.coords(b);
        ax.abs_diff(bx) + ay.abs_diff(by)
    }

    /// Average hop count over a set of (src, dst) pairs.
    pub fn mean_hops(&self, pairs: &[(usize, usize)]) -> f64 {
        if pairs.is_empty() {
            return 0.0;
        }
        pairs.iter().map(|&(a, b)| self.hops(a, b) as f64).sum::<f64>() / pairs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_grid_shapes() {
        let p = Placement::square(16);
        assert_eq!((p.cols, p.rows), (4, 4));
        let p = Placement::square(17);
        assert_eq!((p.cols, p.rows), (5, 4));
        let p = Placement::square(1);
        assert_eq!((p.cols, p.rows), (1, 1));
    }

    #[test]
    fn coords_roundtrip() {
        let p = Placement::square(12);
        for t in 0..12 {
            let (x, y) = p.coords(t);
            assert_eq!(p.at(x, y), Some(t));
        }
        assert_eq!(p.at(99, 0), None);
    }

    #[test]
    fn hops_manhattan() {
        let p = Placement::square(16); // 4x4
        assert_eq!(p.hops(0, 0), 0);
        assert_eq!(p.hops(0, 3), 3); // same row
        assert_eq!(p.hops(0, 15), 6); // corner to corner
        assert_eq!(p.hops(5, 10), p.hops(10, 5)); // symmetric
    }

    #[test]
    fn mean_hops_basic() {
        let p = Placement::square(4); // 2x2
        let pairs = [(0, 1), (0, 3)];
        assert!((p.mean_hops(&pairs) - 1.5).abs() < 1e-12);
        assert_eq!(p.mean_hops(&[]), 0.0);
    }
}
