//! End-to-end DNN communication latency — the paper's Algorithm 1.
//!
//! For every weight layer, the flows computed by Eq. 3 are run through the
//! interconnect and the per-layer results are accumulated (Eq. 4/5). Two
//! backends share the same flow construction:
//!
//! * [`simulate_dnn`] — cycle-accurate (drain mode gives the makespan of
//!   one frame's transfers; steady mode gives per-flit latency stats),
//! * [`estimate_dnn`] — the analytical model of Algorithm 2.

use super::analytical::AnalyticalModel;
use super::sim::{FlowSpec, Mode, NocSim, SimStats};
use super::topology::{Network, Topology};
use crate::config::{ArchConfig, NocConfig, SimConfig};
use crate::mapping::InjectionMatrix;

/// Per-layer result from the cycle-accurate backend.
#[derive(Clone, Debug)]
pub struct LayerSim {
    /// Graph index of the consumer weight layer.
    pub layer: usize,
    /// Cycles to deliver one frame's transfers into this layer (drain).
    pub makespan: u64,
    /// Average per-flit latency, cycles.
    pub avg_latency: f64,
    /// Full simulator statistics.
    pub stats: SimStats,
}

/// Whole-DNN result from the cycle-accurate backend.
#[derive(Clone, Debug)]
pub struct DnnCommSim {
    /// Per-layer simulation results, in layer order.
    pub per_layer: Vec<LayerSim>,
    /// End-to-end communication cycles per frame (Σ makespans, Eq. 5).
    pub total_cycles: u64,
    /// Rate-weighted average per-flit latency over all layers.
    pub avg_flit_latency: f64,
}

impl DnnCommSim {
    /// Communication latency per frame in seconds.
    pub fn latency_s(&self, arch: &ArchConfig) -> f64 {
        self.total_cycles as f64 / arch.freq_hz
    }
}

/// Flits each (source, destination) pair carries per frame when
/// `activations` elements of `n_bits` each are spread over `pairs`
/// tile pairs on a `bus_width`-bit fabric: `ceil(A·N_bits / (pairs·W))`,
/// floored at one flit. Shared by the single-chip evaluator
/// ([`layer_flows`]) and the per-chiplet legs of
/// [`crate::nop::evaluator::evaluate_package`].
pub fn flits_per_pair(activations: usize, n_bits: usize, pairs: usize, bus_width: usize) -> u64 {
    let per_pair = (activations as f64 * n_bits as f64 / (pairs as f64 * bus_width as f64))
        .ceil() as u64;
    per_pair.max(1)
}

/// Build the per-pair flow list for one consumer layer. `drain` decides
/// whether Eq.-3 rates (steady) or per-frame flit counts (drain) are set.
pub fn layer_flows(
    inj: &InjectionMatrix,
    layer: usize,
    arch: &ArchConfig,
    noc: &NocConfig,
    drain: bool,
) -> Vec<FlowSpec> {
    let mut flows = Vec::new();
    for f in inj.flows_into(layer) {
        let pairs = f.src_tiles.len() * f.dst_tiles.len();
        let flits_per_pair = flits_per_pair(f.activations, arch.n_bits, pairs, noc.bus_width);
        for s in f.src_tiles.clone() {
            for d in f.dst_tiles.clone() {
                flows.push(FlowSpec {
                    src: s,
                    dst: d,
                    rate: if drain { 0.0 } else { f.rate },
                    flits: if drain { flits_per_pair } else { 0 },
                });
            }
        }
    }
    flows
}

/// Cycle-accurate Algorithm 1. `drain = true` reproduces per-frame
/// makespans (used for throughput/EDAP); `drain = false` measures steady
/// per-flit latency at the Eq.-3 rates (used for Fig. 11/13/14/15).
pub fn simulate_dnn(
    inj: &InjectionMatrix,
    topology: Topology,
    arch: &ArchConfig,
    noc: &NocConfig,
    sim_cfg: &SimConfig,
    drain: bool,
    track_pairs: bool,
) -> DnnCommSim {
    let mut per_layer = Vec::new();
    let mut total_cycles = 0u64;
    let mut lat_weighted = 0.0;
    let mut lat_weight = 0.0;
    let layers: Vec<usize> = {
        let mut ls: Vec<usize> = inj.flows.iter().map(|f| f.dst_layer).collect();
        ls.sort_unstable();
        ls.dedup();
        ls
    };
    for layer in layers {
        let flows = layer_flows(inj, layer, arch, noc, drain);
        if flows.is_empty() {
            continue;
        }
        let mode = if drain {
            // Generous budget: total flits × a large constant covers even a
            // fully serialized P2P chain; saturation is reported, not hung.
            let total_flits: u64 = flows.iter().map(|f| f.flits).sum();
            Mode::Drain {
                max_cycles: 1_000 + total_flits.saturating_mul(64),
            }
        } else {
            Mode::Steady {
                warmup: sim_cfg.warmup_cycles,
                measure: sim_cfg.measure_cycles,
            }
        };
        let stats = NocSim::new(
            topology,
            inj.total_tiles,
            noc,
            &flows,
            mode,
            sim_cfg.seed ^ layer as u64,
        )
        .track_pairs(track_pairs)
        .run();
        total_cycles += stats.makespan;
        if stats.delivered > 0 {
            lat_weighted += stats.avg_latency * stats.delivered as f64;
            lat_weight += stats.delivered as f64;
        }
        per_layer.push(LayerSim {
            layer,
            makespan: stats.makespan,
            avg_latency: stats.avg_latency,
            stats,
        });
    }
    DnnCommSim {
        per_layer,
        total_cycles,
        avg_flit_latency: if lat_weight > 0.0 {
            lat_weighted / lat_weight
        } else {
            0.0
        },
    }
}

/// Per-layer + total estimate from the analytical model (Algorithm 2).
#[derive(Clone, Debug)]
pub struct DnnCommEstimate {
    /// (layer index, estimated cycles) pairs, in layer order.
    pub per_layer: Vec<(usize, f64)>,
    /// Rate-weighted average per-flit latency over all layers (compare
    /// with [`DnnCommSim::avg_flit_latency`], Fig. 11).
    pub avg_flit_latency: f64,
    /// Σ_l L_avg^l (Eq. 11).
    pub total_latency: f64,
    /// True when any layer's offered load exceeded a link's capacity.
    pub saturated: bool,
}

/// Analytical Algorithm 2 over the whole DNN.
pub fn estimate_dnn(
    inj: &InjectionMatrix,
    topology: Topology,
    arch: &ArchConfig,
    noc: &NocConfig,
) -> DnnCommEstimate {
    let net = Network::build(topology, inj.total_tiles);
    let model = AnalyticalModel::new(&net, noc);
    let mut per_layer = Vec::new();
    let mut total = 0.0;
    let mut weighted = 0.0;
    let mut weight = 0.0;
    let mut saturated = false;
    let layers: Vec<usize> = {
        let mut ls: Vec<usize> = inj.flows.iter().map(|f| f.dst_layer).collect();
        ls.sort_unstable();
        ls.dedup();
        ls
    };
    for layer in layers {
        let flows = layer_flows(inj, layer, arch, noc, false);
        if flows.is_empty() {
            continue;
        }
        let est = model.layer_latency(&flows);
        saturated |= est.saturated;
        total += est.avg_latency;
        let rate: f64 = flows.iter().map(|f| f.rate).sum();
        weighted += est.avg_latency * rate;
        weight += rate;
        per_layer.push((layer, est.avg_latency));
    }
    DnnCommEstimate {
        per_layer,
        avg_flit_latency: if weight > 0.0 { weighted / weight } else { 0.0 },
        total_latency: total,
        saturated,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;
    use crate::mapping::Mapping;

    fn setup(g: &crate::dnn::DnnGraph) -> (ArchConfig, NocConfig, InjectionMatrix) {
        let arch = ArchConfig::default();
        let noc = NocConfig::default();
        let m = Mapping::build(g, &arch);
        let inj = InjectionMatrix::build(g, &m, &arch, &noc);
        (arch, noc, inj)
    }

    #[test]
    fn lenet_drain_all_topologies() {
        let g = models::lenet5();
        let (arch, noc, inj) = setup(&g);
        let sim_cfg = SimConfig::default();
        for topo in [Topology::Mesh, Topology::Tree, Topology::P2P] {
            let r = simulate_dnn(&inj, topo, &arch, &noc, &sim_cfg, true, false);
            assert!(r.total_cycles > 0, "{topo:?}");
            assert_eq!(r.per_layer.len(), 4); // 5 weight layers, first is off-NoC
            for l in &r.per_layer {
                assert!(l.stats.drained, "{topo:?} layer {} not drained", l.layer);
            }
        }
    }

    #[test]
    fn mesh_beats_p2p_on_dense_net() {
        let g = models::densenet(40);
        let (arch, noc, inj) = setup(&g);
        let sim_cfg = SimConfig::default();
        let mesh = simulate_dnn(&inj, Topology::Mesh, &arch, &noc, &sim_cfg, true, false);
        let p2p = simulate_dnn(&inj, Topology::P2P, &arch, &noc, &sim_cfg, true, false);
        assert!(
            p2p.total_cycles > mesh.total_cycles,
            "P2P {} must exceed mesh {}",
            p2p.total_cycles,
            mesh.total_cycles
        );
    }

    #[test]
    fn analytical_tracks_sim_on_mlp() {
        let g = models::mlp();
        let (arch, noc, inj) = setup(&g);
        let sim_cfg = SimConfig {
            measure_cycles: 20_000,
            ..SimConfig::default()
        };
        let sim = simulate_dnn(&inj, Topology::Mesh, &arch, &noc, &sim_cfg, false, false);
        let est = estimate_dnn(&inj, Topology::Mesh, &arch, &noc);
        // At DNN-realistic (low) loads the model must land within 25%.
        if sim.avg_flit_latency > 0.0 {
            let err = (est.avg_flit_latency - sim.avg_flit_latency).abs() / sim.avg_flit_latency;
            assert!(
                err < 0.25,
                "analytical {} vs sim {}",
                est.avg_flit_latency,
                sim.avg_flit_latency
            );
        }
    }

    #[test]
    fn steady_mode_produces_latency_stats() {
        let g = models::lenet5();
        let (arch, noc, inj) = setup(&g);
        let sim_cfg = SimConfig::default();
        let r = simulate_dnn(&inj, Topology::Mesh, &arch, &noc, &sim_cfg, false, true);
        // Injection rates are tiny; some layers may see few flits, but the
        // aggregate must be positive.
        assert!(r.avg_flit_latency >= 0.0);
    }
}
