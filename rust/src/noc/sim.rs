//! Cycle-accurate NoC simulation (the customized-BookSim substrate,
//! paper §3.2) — a thin fabric adapter over the shared
//! [`crate::sim::engine`] event core.
//!
//! Two operating modes (see [`Mode`]):
//!
//! * **Steady** — every source–destination pair injects with an independent
//!   Bernoulli process at its Eq.-3 rate; statistics (average/worst flit
//!   latency, queue occupancy at arrival) are collected after warm-up.
//!   Used for Fig. 5, Fig. 11, Fig. 13/14/15 and Table 3.
//! * **Drain** — each pair injects a fixed number of flits (one frame's
//!   worth) as fast as flow control allows; the simulator runs until the
//!   network is empty and reports the makespan. Used for the end-to-end
//!   per-layer communication latency of Algorithm 1 (Eq. 4/5).
//!
//! Traffic generation, the run loops, warm-up gating and all statistics
//! live in the engine core; this module contributes only what is
//! on-chip-specific: flit-level routers with single-cycle links,
//! credit-based backpressure, round-robin arbitration, and a configurable
//! router pipeline depth. P2P "networks" are modeled on the same grid but
//! without routers: every tile advances at most one flit per cycle across
//! all of its ports (store-and-forward over a shared medium), which is
//! what makes P2P collapse under high connection density.

use super::router::{Flit, RouterState};
use super::topology::{Network, Topology, NONE};
use crate::config::NocConfig;
use crate::sim::engine::{run_engine, EngineCore, Fabric};
use crate::telemetry::SimTelemetry;

pub use crate::sim::engine::{FlowSpec, Mode, PairStat, SimStats};

/// The on-chip fabric: routers, ports and the switching state the shared
/// engine core knows nothing about.
struct NocFabric {
    net: Network,
    cfg: NocConfig,
    routers: Vec<RouterState>,
    /// Routers with queued flits (worklist).
    active: Vec<usize>,
    active_flag: Vec<bool>,
    /// reverse[r][slot] = input port index on the neighbor reached via slot.
    reverse: Vec<Vec<usize>>,
    /// Terminals that still generate or hold traffic (worklist).
    live_sources: Vec<usize>,
    /// P2P only: earliest cycle each node may forward again (store-and-
    /// forward is half-duplex: receive cycle + transmit cycle, so a node
    /// sustains at most one flit every 2 cycles).
    node_free: Vec<u64>,
    /// Reusable per-cycle move buffer: (router, in_port, vc, out_port).
    /// Kept across cycles to avoid one allocation per simulated cycle.
    moves: Vec<(u32, u8, u8, u8)>,
    /// Spare worklist buffer swapped with `active` each cycle (allocation
    /// reuse for the same reason).
    spare: Vec<usize>,
    /// Earliest cycle at which router r can have a ready head flit — lets
    /// the switch loop skip routers whose flits are all mid-pipeline with
    /// one compare instead of a 5-port queue scan.
    next_ready: Vec<u64>,
    /// link_ids[r][slot] = telemetry link index for the (r, slot) hop
    /// (`NONE` for absent slots). Empty unless instrumented.
    link_ids: Vec<Vec<usize>>,
}

/// The cycle-accurate simulator: a shared [`EngineCore`] plus the on-chip
/// [`NocFabric`].
pub struct NocSim {
    core: EngineCore,
    fab: NocFabric,
}

impl NocSim {
    /// Build a simulator for `terminals` tiles on `topology`. Flow
    /// endpoints are tile ids; self-flows never enter the NoC.
    pub fn new(
        topology: Topology,
        terminals: usize,
        cfg: &NocConfig,
        flows: &[FlowSpec],
        mode: Mode,
        seed: u64,
    ) -> Self {
        let net = Network::build(topology, terminals);
        let routers: Vec<RouterState> = (0..net.routers)
            .map(|r| {
                RouterState::new(
                    net.ports(r),
                    cfg.virtual_channels,
                    cfg.buffer_depth.div_ceil(cfg.virtual_channels).max(1),
                )
            })
            .collect();

        // Build reverse port map: slot k of r leads to neighbor n; find the
        // slot on n that points back to r.
        let reverse: Vec<Vec<usize>> = (0..net.routers)
            .map(|r| {
                net.neighbors[r]
                    .iter()
                    .map(|&n| {
                        if n == NONE {
                            NONE
                        } else {
                            let back = net.neighbors[n]
                                .iter()
                                .position(|&m| m == r)
                                .expect("asymmetric link");
                            net.local_ports + back
                        }
                    })
                    .collect()
            })
            .collect();

        let core = EngineCore::new(terminals, flows, mode, seed);
        let steady = mode.is_steady();
        let live_sources: Vec<usize> = (0..terminals)
            .filter(|&t| {
                if steady {
                    core.sources[t].rate > 0.0
                } else {
                    !core.sources[t].pending.is_empty()
                }
            })
            .collect();

        let net_routers = net.routers;
        Self {
            core,
            fab: NocFabric {
                active: Vec::with_capacity(net.routers),
                active_flag: vec![false; net.routers],
                routers,
                reverse,
                net,
                cfg: cfg.clone(),
                live_sources,
                node_free: vec![0; net_routers],
                moves: Vec::with_capacity(256),
                spare: Vec::with_capacity(64),
                next_ready: vec![0; net_routers],
                link_ids: Vec::new(),
            },
        }
    }

    /// Enable per-pair latency tracking (Fig. 15 / Table 3).
    pub fn track_pairs(mut self, on: bool) -> Self {
        self.core.track_pairs = on;
        self
    }

    /// Arm the per-flow attribution hook: count head-of-line blocked
    /// flit-cycles per (src, dst) flow into
    /// [`SimStats::flow_waits`]. Purely observational — simulated
    /// outcomes (makespan, latency, delivery) are identical either way.
    pub fn attribute(mut self, on: bool) -> Self {
        self.core.attrib = on;
        self
    }

    /// Collect per-link flit counters, per-terminal injection/ejection
    /// counters and buffer-occupancy telemetry while running (returned by
    /// [`NocSim::run_instrumented`]). Off by default: the disabled path
    /// costs one branch per hook site and allocates nothing.
    pub fn instrument(mut self, on: bool) -> Self {
        if !on {
            self.core.telem = None;
            self.fab.link_ids = Vec::new();
            return self;
        }
        // Enumerate directed links in deterministic (router, slot) order.
        let mut links = Vec::new();
        let mut link_ids = Vec::with_capacity(self.fab.net.routers);
        for r in 0..self.fab.net.routers {
            let mut ids = Vec::with_capacity(self.fab.net.neighbors[r].len());
            for &n in &self.fab.net.neighbors[r] {
                if n == NONE {
                    ids.push(NONE);
                } else {
                    ids.push(links.len());
                    links.push((r, n));
                }
            }
            link_ids.push(ids);
        }
        self.core.telem = Some(Box::new(SimTelemetry::sized(
            links,
            self.core.sources.len(),
        )));
        self.fab.link_ids = link_ids;
        self
    }

    /// Run to completion per the configured mode.
    pub fn run(self) -> SimStats {
        self.run_instrumented().0
    }

    /// Run to completion, also returning the collected telemetry (empty
    /// unless built with [`NocSim::instrument`]).
    pub fn run_instrumented(mut self) -> (SimStats, SimTelemetry) {
        run_engine(&mut self.core, &mut self.fab);
        let telem = self.core.take_telem();
        (self.core.stats, telem)
    }
}

impl Fabric for NocFabric {
    fn step(&mut self, core: &mut EngineCore) {
        self.inject(core);
        self.switch(core);
    }
    // Single-cycle links: the NoC never idle-waits, so the default
    // `queued_work`/`next_arrival` (step one cycle at a time) apply.
}

impl NocFabric {
    #[inline]
    fn mark_active(&mut self, r: usize) {
        if !self.active_flag[r] {
            self.active_flag[r] = true;
            self.active.push(r);
        }
    }

    /// Push a flit into router `r` input port `port`, sampling occupancy.
    /// Returns false when the buffer is full.
    fn push_router(
        &mut self,
        core: &mut EngineCore,
        r: usize,
        port: usize,
        mut flit: Flit,
        sample: bool,
    ) -> bool {
        let occ = self.routers[r].inputs[port].occupancy();
        flit.ready = core.now + self.pipeline_delay();
        if !self.routers[r].inputs[port].push(flit) {
            return false;
        }
        if flit.ready < self.next_ready[r] {
            self.next_ready[r] = flit.ready;
        }
        if sample {
            core.sample_occupancy(occ);
        }
        self.mark_active(r);
        true
    }

    #[inline]
    fn pipeline_delay(&self) -> u64 {
        if self.net.topology.has_routers() {
            self.cfg.pipeline_stages as u64
        } else {
            0 // P2P: store-and-forward latch, no router pipeline
        }
    }

    /// Injection phase: generate per-mode traffic (delegated to the engine
    /// core) and move source-FIFO heads into the attached router's local
    /// input port. Only terminals on the `live_sources` worklist are
    /// visited; a terminal retires once it has nothing left to generate or
    /// inject (drain mode).
    fn inject(&mut self, core: &mut EngineCore) {
        let steady = core.mode.is_steady();
        let mut i = 0;
        while i < self.live_sources.len() {
            let t = self.live_sources[i];
            // Generate.
            if steady {
                core.generate_steady(t);
            } else {
                core.generate_drain(t);
            }
            // Inject FIFO head into the router if there is buffer space.
            if let Some(&(dst, born)) = core.sources[t].fifo.front() {
                let r = self.net.attach[t];
                let port = self.net.attach_port[t];
                if self.routers[r].inputs[port].has_space() {
                    let flit = Flit {
                        src: t as u32,
                        dst,
                        born,
                        ready: 0,
                    };
                    let ok = self.push_router(core, r, port, flit, false);
                    debug_assert!(ok);
                    core.sources[t].fifo.pop_front();
                }
            }
            // Retire exhausted drain-mode sources.
            if !steady
                && core.sources[t].fifo.is_empty()
                && core.sources[t].pending.is_empty()
            {
                self.live_sources.swap_remove(i);
            } else {
                i += 1;
            }
        }
    }

    /// One switching cycle over all active routers (two-phase).
    fn switch(&mut self, core: &mut EngineCore) {
        // Phase A: collect moves (router, in_port, vc, out_port) into the
        // reusable buffer; claims live in a fixed stack array (no per-router
        // heap allocation — this path dominates whole-framework runtime).
        self.moves.clear();
        let p2p = !self.net.topology.has_routers();
        let now = core.now;
        // Swap in the spare buffer so `mark_active` pushes reuse capacity.
        let old_active = std::mem::replace(&mut self.active, std::mem::take(&mut self.spare));
        for &r in &old_active {
            self.active_flag[r] = false;
            if p2p && self.node_free[r] > now {
                // Half-duplex P2P node still busy with the previous flit.
                self.mark_active(r);
                continue;
            }
            if self.next_ready[r] > now {
                // All heads still in the router pipeline: skip the scan.
                self.mark_active(r);
                continue;
            }
            let ports = self.routers[r].inputs.len();
            debug_assert!(ports <= 16, "claim buffer sized for <=16 ports");
            // claims: (out, in, vc), first-come round-robin, one per output.
            let mut claims = [(0u8, 0u8, 0u8); 16];
            let mut n_claims = 0usize;
            let mut occupied = false;
            let mut min_unready = u64::MAX;
            let rr_base = self.routers[r].rr[0];
            for k in 0..ports {
                let ip = (rr_base + k) % ports;
                let port = &self.routers[r].inputs[ip];
                // Pick the first ready VC head (round-robin start).
                let nvc = port.vcs.len();
                for dv in 0..nvc {
                    let vc = (port.next_vc + dv) % nvc;
                    if let Some(head) = port.vcs[vc].front() {
                        occupied = true;
                        if head.ready <= now {
                            let out = self.net.route(r, head.dst as usize);
                            if !claims[..n_claims].iter().any(|&(o, _, _)| o as usize == out)
                            {
                                claims[n_claims] = (out as u8, ip as u8, vc as u8);
                                n_claims += 1;
                            }
                            break;
                        } else if head.ready < min_unready {
                            min_unready = head.ready;
                        }
                    }
                }
                if p2p && n_claims > 0 {
                    break; // P2P: one flit per node per cycle, full stop
                }
            }
            // Advance output RR pointer so ports take turns winning; while
            // anything moved (or might move next cycle), rescan next cycle,
            // otherwise sleep until the earliest pipeline exit.
            if n_claims > 0 {
                self.routers[r].rr[0] = (rr_base + 1) % ports;
                if p2p {
                    self.node_free[r] = now + 2;
                }
                self.next_ready[r] = now; // moved: rescan next cycle
            } else if occupied {
                self.next_ready[r] = min_unready;
            }
            for &(out, ip, vc) in &claims[..n_claims] {
                self.moves.push((r as u32, ip, vc, out));
            }
            // Keep occupied routers on the worklist even if no head was
            // ready this cycle (pipeline delay) or no move was possible.
            if occupied || self.routers[r].total_occupancy() > 0 {
                self.mark_active(r);
            }
        }
        // Phase B: apply moves.
        let moves = std::mem::take(&mut self.moves);
        for &(r, ip, vc, out) in &moves {
            let (r, ip, vc, out) = (r as usize, ip as usize, vc as usize, out as usize);
            // Ejection?
            if out < self.net.local_ports {
                let flit = self.routers[r].inputs[ip].vcs[vc].pop_front().unwrap();
                self.routers[r].inputs[ip].next_vc = (vc + 1) % self.cfg.virtual_channels;
                core.deliver(flit.src, flit.dst, flit.born);
                if self.routers[r].total_occupancy() > 0 {
                    self.mark_active(r);
                }
                continue;
            }
            let slot = out - self.net.local_ports;
            let next = self.net.neighbors[r][slot];
            debug_assert_ne!(next, NONE);
            let in_port = self.reverse[r][slot];
            if self.routers[next].inputs[in_port].has_space() {
                let mut flit = self.routers[r].inputs[ip].vcs[vc].pop_front().unwrap();
                self.routers[r].inputs[ip].next_vc = (vc + 1) % self.cfg.virtual_channels;
                flit.ready = 0; // set by push_router
                // +1 cycle link traversal is folded into arrival at now+pipe.
                let ok = self.push_router(core, next, in_port, flit, true);
                debug_assert!(ok);
                if let Some(tm) = &mut core.telem {
                    tm.link_flits[self.link_ids[r][slot]] += 1;
                }
            } else if let Some(head) = self.routers[r].inputs[ip].vcs[vc].front() {
                // Attribution: the claimed move lost to a full downstream
                // buffer — this head flit stalls one more cycle.
                self.note_blocked(core, head.src, head.dst);
            }
            if self.routers[r].total_occupancy() > 0 {
                self.mark_active(r);
            }
        }
        self.moves = moves;
        let mut spare = old_active;
        spare.clear();
        self.spare = spare;
    }
}

/// Convenience: uniform-random traffic at a given per-node injection rate
/// (flits/node/cycle) — the classic BookSim benchmark behind Fig. 5.
pub fn uniform_random_flows(terminals: usize, rate_per_node: f64) -> Vec<FlowSpec> {
    crate::sim::engine::uniform_flows(terminals, rate_per_node)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> NocConfig {
        NocConfig::default()
    }

    #[test]
    fn single_flit_zero_load_latency() {
        // One flit across a 4x4 mesh, 0 -> 15 (6 hops): latency must be
        // hops * (pipeline + 1) + small constant, deterministic.
        let flows = [FlowSpec {
            src: 0,
            dst: 15,
            rate: 0.0,
            flits: 1,
        }];
        let stats = NocSim::new(
            Topology::Mesh,
            16,
            &cfg(),
            &flows,
            Mode::Drain { max_cycles: 1000 },
            1,
        )
        .run();
        assert!(stats.drained);
        assert_eq!(stats.delivered, 1);
        // 7 routers traversed, each adds pipeline(3); plus ejection.
        let lat = stats.avg_latency;
        assert!(
            (20.0..40.0).contains(&lat),
            "zero-load latency {lat} out of expected band"
        );
    }

    #[test]
    fn neighbor_delivery_fast() {
        let flows = [FlowSpec {
            src: 0,
            dst: 1,
            rate: 0.0,
            flits: 1,
        }];
        let s = NocSim::new(
            Topology::Mesh,
            4,
            &cfg(),
            &flows,
            Mode::Drain { max_cycles: 100 },
            1,
        )
        .run();
        assert_eq!(s.delivered, 1);
        assert!(s.avg_latency <= 12.0, "{}", s.avg_latency);
    }

    #[test]
    fn drain_conserves_flits() {
        let flows = [
            FlowSpec {
                src: 0,
                dst: 5,
                rate: 0.0,
                flits: 100,
            },
            FlowSpec {
                src: 3,
                dst: 1,
                rate: 0.0,
                flits: 57,
            },
        ];
        let s = NocSim::new(
            Topology::Mesh,
            9,
            &cfg(),
            &flows,
            Mode::Drain { max_cycles: 100_000 },
            7,
        )
        .run();
        assert!(s.drained);
        assert_eq!(s.injected, 157);
        assert_eq!(s.delivered, 157);
        assert!(s.makespan >= 100);
    }

    #[test]
    fn steady_latency_grows_with_rate() {
        let run = |rate: f64| {
            let flows = uniform_random_flows(16, rate);
            NocSim::new(
                Topology::Mesh,
                16,
                &cfg(),
                &flows,
                Mode::Steady {
                    warmup: 500,
                    measure: 3_000,
                },
                42,
            )
            .run()
        };
        let lo = run(0.01);
        let hi = run(0.30);
        assert!(lo.delivered > 0 && hi.delivered > lo.delivered);
        assert!(
            hi.avg_latency > lo.avg_latency,
            "latency must grow with load: {} vs {}",
            lo.avg_latency,
            hi.avg_latency
        );
    }

    #[test]
    fn p2p_slower_than_mesh_under_load() {
        let flows = |_n: usize| {
            // All-to-one hotspot: classic P2P killer.
            (1..16)
                .map(|s| FlowSpec {
                    src: s,
                    dst: 0,
                    rate: 0.0,
                    flits: 50,
                })
                .collect::<Vec<_>>()
        };
        let mesh = NocSim::new(
            Topology::Mesh,
            16,
            &cfg(),
            &flows(16),
            Mode::Drain { max_cycles: 1_000_000 },
            3,
        )
        .run();
        let p2p = NocSim::new(
            Topology::P2P,
            16,
            &cfg(),
            &flows(16),
            Mode::Drain { max_cycles: 1_000_000 },
            3,
        )
        .run();
        assert!(mesh.drained && p2p.drained);
        assert!(
            p2p.makespan > mesh.makespan,
            "P2P {} should exceed mesh {}",
            p2p.makespan,
            mesh.makespan
        );
    }

    #[test]
    fn tree_root_bottleneck_vs_mesh() {
        // Cross-subtree all-to-all: the tree root serializes everything.
        let mut flows = Vec::new();
        for s in 0..8 {
            for d in 56..64 {
                flows.push(FlowSpec {
                    src: s,
                    dst: d,
                    rate: 0.0,
                    flits: 20,
                });
            }
        }
        let mesh = NocSim::new(
            Topology::Mesh,
            64,
            &cfg(),
            &flows,
            Mode::Drain { max_cycles: 1_000_000 },
            9,
        )
        .run();
        let tree = NocSim::new(
            Topology::Tree,
            64,
            &cfg(),
            &flows,
            Mode::Drain { max_cycles: 1_000_000 },
            9,
        )
        .run();
        assert!(mesh.drained && tree.drained);
        assert!(
            tree.makespan > mesh.makespan,
            "tree {} vs mesh {}",
            tree.makespan,
            mesh.makespan
        );
    }

    #[test]
    fn per_pair_tracking() {
        let flows = [
            FlowSpec {
                src: 0,
                dst: 3,
                rate: 0.0,
                flits: 10,
            },
            FlowSpec {
                src: 1,
                dst: 2,
                rate: 0.0,
                flits: 5,
            },
        ];
        let s = NocSim::new(
            Topology::Mesh,
            4,
            &cfg(),
            &flows,
            Mode::Drain { max_cycles: 10_000 },
            5,
        )
        .track_pairs(true)
        .run();
        assert_eq!(s.per_pair.len(), 2);
        let p03 = &s.per_pair[&3u64];
        assert_eq!(p03.count, 10);
        assert!(p03.max_latency >= p03.avg() as u64);
    }

    #[test]
    fn occupancy_stats_mostly_empty_at_low_load() {
        let flows = uniform_random_flows(16, 0.02);
        let s = NocSim::new(
            Topology::Mesh,
            16,
            &cfg(),
            &flows,
            Mode::Steady {
                warmup: 500,
                measure: 5_000,
            },
            11,
        )
        .run();
        // Paper Fig. 13: 64-100% of queues empty at arrival; at 2% load it
        // must be near the top of that band.
        assert!(
            s.zero_occupancy_fraction() > 0.8,
            "{}",
            s.zero_occupancy_fraction()
        );
    }

    #[test]
    fn all_topologies_drain_small_workload() {
        let flows = [
            FlowSpec {
                src: 0,
                dst: 7,
                rate: 0.0,
                flits: 25,
            },
            FlowSpec {
                src: 5,
                dst: 2,
                rate: 0.0,
                flits: 25,
            },
        ];
        for topo in Topology::all() {
            let s = NocSim::new(
                topo,
                8,
                &cfg(),
                &flows,
                Mode::Drain { max_cycles: 100_000 },
                13,
            )
            .run();
            assert!(s.drained, "{topo:?} failed to drain");
            assert_eq!(s.delivered, 50, "{topo:?}");
        }
    }

    #[test]
    fn self_flows_are_ignored() {
        let flows = [FlowSpec {
            src: 2,
            dst: 2,
            rate: 0.5,
            flits: 10,
        }];
        let s = NocSim::new(
            Topology::Mesh,
            4,
            &cfg(),
            &flows,
            Mode::Drain { max_cycles: 1000 },
            1,
        )
        .run();
        assert_eq!(s.injected, 0);
        assert!(s.drained);
    }

    #[test]
    fn attribution_records_waits_without_changing_outcomes() {
        // All-to-one hotspot on a 4x4 mesh: buffers at the hotspot fill,
        // so downstream-full stalls must be recorded when armed — and
        // every simulated outcome must match the disarmed run exactly.
        let flows: Vec<FlowSpec> = (1..16)
            .map(|s| FlowSpec {
                src: s,
                dst: 0,
                rate: 0.0,
                flits: 50,
            })
            .collect();
        let build = || {
            NocSim::new(
                Topology::Mesh,
                16,
                &cfg(),
                &flows,
                Mode::Drain {
                    max_cycles: 1_000_000,
                },
                3,
            )
        };
        let off = build().run();
        let on = build().attribute(true).run();
        assert!(off.drained && on.drained);
        assert_eq!(off.makespan, on.makespan);
        assert_eq!(off.delivered, on.delivered);
        assert_eq!(off.avg_latency, on.avg_latency);
        assert!(off.flow_waits.is_empty(), "disarmed run must not allocate");
        assert!(!on.flow_waits.is_empty(), "hotspot must record waits");
        // Every recorded key is one of the offered flows (dst == 0).
        for key in on.flow_waits.keys() {
            assert_eq!(key & 0xFFFF_FFFF, 0, "unexpected flow key {key:#x}");
        }
    }

    #[test]
    fn golden_determinism_same_seed_same_stats() {
        // Golden equivalence anchor for the engine refactor: a fixed seed
        // must reproduce every statistic bit-for-bit, in both modes, with
        // and without instrumentation.
        let drain_flows = [
            FlowSpec {
                src: 0,
                dst: 5,
                rate: 0.0,
                flits: 60,
            },
            FlowSpec {
                src: 7,
                dst: 2,
                rate: 0.0,
                flits: 33,
            },
        ];
        let run_drain = |instrument: bool| {
            NocSim::new(
                Topology::Mesh,
                9,
                &cfg(),
                &drain_flows,
                Mode::Drain { max_cycles: 100_000 },
                0xD00D,
            )
            .track_pairs(true)
            .instrument(instrument)
            .run()
        };
        let a = run_drain(false);
        let b = run_drain(false);
        let c = run_drain(true);
        for other in [&b, &c] {
            assert_eq!(a.injected, other.injected);
            assert_eq!(a.delivered, other.delivered);
            assert_eq!(a.makespan, other.makespan);
            assert_eq!(a.cycles, other.cycles);
            assert_eq!(a.avg_latency, other.avg_latency);
            assert_eq!(a.max_latency, other.max_latency);
            assert_eq!(a.per_pair[&5u64].sum_latency, other.per_pair[&5u64].sum_latency);
        }

        let run_steady = || {
            NocSim::new(
                Topology::Torus,
                16,
                &cfg(),
                &uniform_random_flows(16, 0.1),
                Mode::Steady {
                    warmup: 300,
                    measure: 2_000,
                },
                0xBEE5,
            )
            .run()
        };
        let s1 = run_steady();
        let s2 = run_steady();
        assert!(s1.delivered > 0);
        assert_eq!(s1.injected, s2.injected);
        assert_eq!(s1.delivered, s2.delivered);
        assert_eq!(s1.avg_latency, s2.avg_latency);
        assert_eq!(s1.arrivals, s2.arrivals);
        assert_eq!(s1.arrivals_zero, s2.arrivals_zero);
        assert_eq!(s1.nonzero_occ_sum, s2.nonzero_occ_sum);
    }

    #[test]
    fn instrumented_totals_match_stats() {
        let flows = [
            FlowSpec {
                src: 0,
                dst: 5,
                rate: 0.0,
                flits: 40,
            },
            FlowSpec {
                src: 3,
                dst: 1,
                rate: 0.0,
                flits: 17,
            },
        ];
        let (s, t) = NocSim::new(
            Topology::Mesh,
            9,
            &cfg(),
            &flows,
            Mode::Drain { max_cycles: 100_000 },
            7,
        )
        .instrument(true)
        .run_instrumented();
        assert!(s.drained);
        assert_eq!(t.injected_total(), s.injected);
        assert_eq!(t.ejected_total(), s.delivered);
        assert_eq!(t.injected[0], 40);
        assert_eq!(t.ejected[1], 17);
        assert_eq!(t.cycles, s.cycles);
        // Every delivered flit crossed at least one inter-router link.
        assert!(t.transit_total() >= s.delivered);
        assert!(t.peak_link().is_some());

        // Uninstrumented runs return empty telemetry and identical stats.
        let (s2, empty) = NocSim::new(
            Topology::Mesh,
            9,
            &cfg(),
            &flows,
            Mode::Drain { max_cycles: 100_000 },
            7,
        )
        .run_instrumented();
        assert_eq!(s2.delivered, s.delivered);
        assert_eq!(s2.makespan, s.makespan);
        assert!(empty.links.is_empty());
        assert_eq!(empty.injected_total(), 0);
    }
}
