//! Router microarchitecture state for the cycle-accurate simulator.
//!
//! Input-buffered routers: each input port has `virtual_channels` FIFO
//! queues of `buffer_depth` flits. The 3-stage pipeline (route compute /
//! VC+switch allocation / switch traversal, paper Table 2) is modeled as a
//! per-hop readiness delay; credit-based flow control is modeled by
//! checking downstream queue space before switch traversal.

use std::collections::VecDeque;

/// One flit in flight. Single-flit packets by default (BookSim's default);
/// multi-flit packets are modeled by `flits_per_packet` consecutive flits.
#[derive(Clone, Copy, Debug)]
pub struct Flit {
    /// Source terminal id.
    pub src: u32,
    /// Destination terminal id.
    pub dst: u32,
    /// Cycle the flit entered the network (left its source FIFO).
    pub born: u64,
    /// Earliest cycle the flit may leave the current router (pipeline).
    pub ready: u64,
}

/// Per-input-port buffer: `vcs` FIFOs of `depth` flits each.
#[derive(Clone, Debug)]
pub struct InputPort {
    /// The per-VC FIFO queues.
    pub vcs: Vec<VecDeque<Flit>>,
    /// Capacity of each VC FIFO, flits.
    pub depth: usize,
    /// Round-robin pointer for VC selection at this port.
    pub next_vc: usize,
}

impl InputPort {
    /// An empty port with `num_vcs` FIFOs of `depth` flits.
    pub fn new(num_vcs: usize, depth: usize) -> Self {
        Self {
            vcs: (0..num_vcs).map(|_| VecDeque::new()).collect(),
            depth,
            next_vc: 0,
        }
    }

    /// Total flits buffered across VCs.
    pub fn occupancy(&self) -> usize {
        self.vcs.iter().map(|q| q.len()).sum()
    }

    /// Can one more flit be accepted (into its round-robin VC)?
    pub fn has_space(&self) -> bool {
        self.vcs.iter().any(|q| q.len() < self.depth)
    }

    /// Accept a flit into the least-loaded VC (BookSim's default VC
    /// assignment for single-VC configs degenerates to the one FIFO).
    pub fn push(&mut self, flit: Flit) -> bool {
        if let Some(q) = self
            .vcs
            .iter_mut()
            .min_by_key(|q| q.len())
            .filter(|q| q.len() < self.depth)
        {
            q.push_back(flit);
            true
        } else {
            false
        }
    }
}

/// Full router state: one [`InputPort`] per port plus round-robin
/// arbitration pointers per output port.
#[derive(Clone, Debug)]
pub struct RouterState {
    /// One buffered input per port.
    pub inputs: Vec<InputPort>,
    /// Last input (port, vc) served per output port, for round-robin.
    pub rr: Vec<usize>,
}

impl RouterState {
    /// An empty router with `ports` input ports.
    pub fn new(ports: usize, vcs: usize, depth: usize) -> Self {
        Self {
            inputs: (0..ports).map(|_| InputPort::new(vcs, depth)).collect(),
            rr: vec![0; ports],
        }
    }

    /// Total flits buffered across all input ports.
    pub fn total_occupancy(&self) -> usize {
        self.inputs.iter().map(|p| p.occupancy()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flit() -> Flit {
        Flit {
            src: 0,
            dst: 1,
            born: 0,
            ready: 0,
        }
    }

    #[test]
    fn input_port_capacity() {
        let mut p = InputPort::new(2, 2);
        assert!(p.has_space());
        for _ in 0..4 {
            assert!(p.push(flit()));
        }
        assert!(!p.has_space());
        assert!(!p.push(flit()));
        assert_eq!(p.occupancy(), 4);
    }

    #[test]
    fn push_balances_vcs() {
        let mut p = InputPort::new(2, 8);
        p.push(flit());
        p.push(flit());
        assert_eq!(p.vcs[0].len(), 1);
        assert_eq!(p.vcs[1].len(), 1);
    }

    #[test]
    fn router_state_shape() {
        let r = RouterState::new(5, 1, 8);
        assert_eq!(r.inputs.len(), 5);
        assert_eq!(r.rr.len(), 5);
        assert_eq!(r.total_occupancy(), 0);
    }
}
