//! Interconnect topologies and deterministic routing.
//!
//! A [`Network`] is built for `n` *terminals* (IMC tiles). Depending on the
//! topology there may be additional internal routers (NoC-tree junctions).
//! Every router exposes up to [`Network::MAX_PORTS`] ports; port 0 is always
//! the local/self port (injection + ejection for the attached terminal).
//! Routing is deterministic and minimal, returning the output port a flit
//! at router `r` destined for terminal `dst` must take.

/// Topology of the tile-level interconnect.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Topology {
    /// Point-to-point neighbor links, no routers (Fig. 4a): tiles forward
    /// flits themselves, one flit per tile per cycle (shared medium).
    P2P,
    /// NoC-tree (Fig. 4b): 4-ary tree with routers at junctions, tiles at
    /// leaves.
    Tree,
    /// NoC-mesh (Fig. 4c): 2-D mesh, one router per tile, X-Y routing.
    Mesh,
    /// Concentrated mesh: 4 tiles per router, higher-radix routers and
    /// doubled (express) links — used only in the Fig. 9 EDAP study.
    CMesh,
    /// 2-D torus (topology exploration, §2.3).
    Torus,
    /// Hypercube (topology exploration, §2.3).
    Hypercube,
}

impl Topology {
    /// Display name as printed in the paper's tables.
    pub fn name(self) -> &'static str {
        match self {
            Topology::P2P => "P2P",
            Topology::Tree => "NoC-tree",
            Topology::Mesh => "NoC-mesh",
            Topology::CMesh => "c-mesh",
            Topology::Torus => "torus",
            Topology::Hypercube => "hypercube",
        }
    }

    /// Parse a case-insensitive topology name (`noc-` prefix optional).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().replace("noc-", "").as_str() {
            "p2p" => Some(Topology::P2P),
            "tree" => Some(Topology::Tree),
            "mesh" => Some(Topology::Mesh),
            "cmesh" | "c-mesh" => Some(Topology::CMesh),
            "torus" => Some(Topology::Torus),
            "hypercube" | "cube" => Some(Topology::Hypercube),
            _ => None,
        }
    }

    /// Does this topology use pipelined routers (vs. raw tile forwarding)?
    pub fn has_routers(self) -> bool {
        !matches!(self, Topology::P2P)
    }

    /// Every topology, in sweep order.
    pub fn all() -> [Topology; 6] {
        [
            Topology::P2P,
            Topology::Tree,
            Topology::Mesh,
            Topology::CMesh,
            Topology::Torus,
            Topology::Hypercube,
        ]
    }

    /// The valid `parse` spellings, for CLI error messages.
    pub fn valid_names() -> &'static str {
        "P2P, tree (NoC-tree), mesh (NoC-mesh), c-mesh, torus, hypercube"
    }
}

/// A built network: routers, links, and a routing function.
#[derive(Clone, Debug)]
pub struct Network {
    /// The topology this network was built as.
    pub topology: Topology,
    /// Number of terminals (tiles).
    pub terminals: usize,
    /// Number of routers (= terminals for mesh/torus/P2P/hypercube; more
    /// for tree; fewer for c-mesh).
    pub routers: usize,
    /// Router each terminal attaches to.
    pub attach: Vec<usize>,
    /// Local port used by each terminal at its router (0 unless several
    /// terminals share a router, as in c-mesh).
    pub attach_port: Vec<usize>,
    /// neighbors[r][p] = router reached from router r via port p
    /// (`usize::MAX` = unconnected / local port).
    pub neighbors: Vec<Vec<usize>>,
    /// Mesh-like dimensions when applicable (cols, rows) over routers.
    pub dims: (usize, usize),
    /// Number of local ports on each router (1, or 4 for c-mesh).
    pub local_ports: usize,
}

/// Sentinel for an unconnected / local port in `neighbors`.
pub const NONE: usize = usize::MAX;

impl Network {
    /// Build a network of `n` terminals with the given topology.
    pub fn build(topology: Topology, n: usize) -> Self {
        assert!(n > 0, "network needs at least one terminal");
        match topology {
            Topology::Mesh | Topology::Torus | Topology::P2P => Self::grid(topology, n),
            Topology::Tree => Self::tree(n),
            Topology::CMesh => Self::cmesh(n),
            Topology::Hypercube => Self::hypercube(n),
        }
    }

    /// Ports on router `r` (including local port(s)).
    pub fn ports(&self, r: usize) -> usize {
        self.local_ports + self.neighbors[r].len()
    }

    /// Map a neighbor index to its port id (ports [0, local_ports) are
    /// local; neighbor k uses port local_ports + k).
    #[inline]
    pub fn neighbor_port(&self, k: usize) -> usize {
        self.local_ports + k
    }

    /// 2-D grid used by mesh/torus/P2P: routers on a near-square grid.
    fn grid(topology: Topology, n: usize) -> Self {
        let cols = (n as f64).sqrt().ceil() as usize;
        let rows = n.div_ceil(cols);
        let rn = cols * rows; // grid positions; routers beyond n-1 are unused
        let mut neighbors = vec![vec![NONE; 4]; rn];
        let idx = |x: usize, y: usize| y * cols + x;
        for y in 0..rows {
            for x in 0..cols {
                let r = idx(x, y);
                // ports: 0=local (implicit), neighbor slots: 0=N,1=E,2=S,3=W
                let wrap = topology == Topology::Torus;
                neighbors[r][0] = if y > 0 {
                    idx(x, y - 1)
                } else if wrap && rows > 1 {
                    idx(x, rows - 1)
                } else {
                    NONE
                };
                neighbors[r][1] = if x + 1 < cols {
                    idx(x + 1, y)
                } else if wrap && cols > 1 {
                    idx(0, y)
                } else {
                    NONE
                };
                neighbors[r][2] = if y + 1 < rows {
                    idx(x, y + 1)
                } else if wrap && rows > 1 {
                    idx(x, 0)
                } else {
                    NONE
                };
                neighbors[r][3] = if x > 0 {
                    idx(x - 1, y)
                } else if wrap && cols > 1 {
                    idx(cols - 1, y)
                } else {
                    NONE
                };
            }
        }
        Self {
            topology,
            terminals: n,
            routers: rn,
            attach: (0..n).collect(),
            attach_port: vec![0; n],
            neighbors,
            dims: (cols, rows),
            local_ports: 1,
        }
    }

    /// 4-ary tree: terminals at leaves, routers at junctions. Router ids:
    /// leaves' parents first (level above tiles), then upward to the root.
    /// Terminal t attaches to leaf-router t/4... built level by level.
    fn tree(n: usize) -> Self {
        // Level sizes: l0 = ceil(n/4) routers over terminals, then /4 up to 1.
        let mut level_sizes = vec![n.div_ceil(4).max(1)];
        while *level_sizes.last().unwrap() > 1 {
            level_sizes.push(level_sizes.last().unwrap().div_ceil(4));
        }
        let routers: usize = level_sizes.iter().sum();
        // Router layout: level 0 (closest to tiles) occupies [0, l0), level 1
        // next, etc. Each router's neighbor slot 0..3 = children, 4 = parent.
        let mut neighbors = vec![vec![NONE; 5]; routers];
        let mut level_start = vec![0usize; level_sizes.len()];
        for i in 1..level_sizes.len() {
            level_start[i] = level_start[i - 1] + level_sizes[i - 1];
        }
        for lvl in 0..level_sizes.len() {
            for i in 0..level_sizes[lvl] {
                let r = level_start[lvl] + i;
                if lvl + 1 < level_sizes.len() {
                    let parent = level_start[lvl + 1] + i / 4;
                    neighbors[r][4] = parent;
                    neighbors[parent][i % 4] = r;
                }
            }
        }
        // Level-0 routers' child slots connect to terminals, not routers —
        // they stay NONE in `neighbors` (terminals are not routers); the
        // terminal attach table captures them.
        let attach: Vec<usize> = (0..n).map(|t| t / 4).collect();
        let attach_port: Vec<usize> = (0..n).map(|t| t % 4).collect();
        Self {
            topology: Topology::Tree,
            terminals: n,
            routers,
            attach,
            attach_port,
            neighbors,
            dims: (0, 0),
            local_ports: 4, // up to 4 terminals per leaf router
        }
    }

    /// Concentrated mesh: 4 terminals per router on a near-square grid.
    fn cmesh(n: usize) -> Self {
        let rn = n.div_ceil(4).max(1);
        let base = Self::grid(Topology::Mesh, rn);
        Self {
            topology: Topology::CMesh,
            terminals: n,
            routers: base.routers,
            attach: (0..n).map(|t| t / 4).collect(),
            attach_port: (0..n).map(|t| t % 4).collect(),
            neighbors: base.neighbors,
            dims: base.dims,
            local_ports: 4,
        }
    }

    /// Hypercube over the next power of two ≥ n.
    fn hypercube(n: usize) -> Self {
        let size = n.next_power_of_two();
        let dim = size.trailing_zeros() as usize;
        let mut neighbors = vec![vec![NONE; dim.max(1)]; size];
        for r in 0..size {
            for d in 0..dim {
                neighbors[r][d] = r ^ (1 << d);
            }
        }
        Self {
            topology: Topology::Hypercube,
            terminals: n,
            routers: size,
            attach: (0..n).collect(),
            attach_port: vec![0; n],
            neighbors,
            dims: (size, 1),
            local_ports: 1,
        }
    }

    /// Deterministic minimal route: output port (see port numbering in
    /// [`Network::neighbor_port`]) for a flit at router `r` destined for
    /// terminal `dst`. Returns the local/ejection port if `dst` attaches
    /// here.
    pub fn route(&self, r: usize, dst: usize) -> usize {
        let dr = self.attach[dst];
        if dr == r {
            return self.attach_port[dst]; // eject on the terminal's local port
        }
        match self.topology {
            Topology::Mesh | Topology::P2P | Topology::CMesh => {
                // X-Y routing on the grid.
                let cols = self.dims.0;
                let (x, y) = (r % cols, r / cols);
                let (dx, dy) = (dr % cols, dr / cols);
                let slot = if x < dx {
                    1 // E
                } else if x > dx {
                    3 // W
                } else if y < dy {
                    2 // S
                } else {
                    0 // N
                };
                self.neighbor_port(slot)
            }
            Topology::Torus => {
                let (cols, rows) = self.dims;
                let (x, y) = (r % cols, r / cols);
                let (dx, dy) = (dr % cols, dr / cols);
                let slot = if x != dx {
                    // shortest wrap-aware direction in X
                    let right = (dx + cols - x) % cols;
                    let left = (x + cols - dx) % cols;
                    if right <= left {
                        1
                    } else {
                        3
                    }
                } else {
                    let down = (dy + rows - y) % rows;
                    let up = (y + rows - dy) % rows;
                    if down <= up {
                        2
                    } else {
                        0
                    }
                };
                self.neighbor_port(slot)
            }
            Topology::Tree => {
                // Up-down: descend if dst is in this subtree, else go up.
                if let Some(child_slot) = self.tree_descend_slot(r, dr) {
                    self.neighbor_port(child_slot)
                } else {
                    self.neighbor_port(4) // parent
                }
            }
            Topology::Hypercube => {
                // Dimension-order: fix the lowest differing bit.
                let diff = r ^ dr;
                let d = diff.trailing_zeros() as usize;
                self.neighbor_port(d)
            }
        }
    }

    /// For tree routing: the child slot (0..4) leading toward router `dr`,
    /// or `None` if `dr` is not in `r`'s subtree.
    fn tree_descend_slot(&self, r: usize, dr: usize) -> Option<usize> {
        // Walk up from dr; if we reach r, the previous router tells the slot.
        let mut cur = dr;
        loop {
            let parent = self.neighbors[cur][4];
            if parent == NONE {
                return None;
            }
            if parent == r {
                return self.neighbors[r][..4].iter().position(|&c| c == cur);
            }
            cur = parent;
        }
    }

    /// Full route as a router list from terminal `src` to terminal `dst`
    /// (inclusive of both attach routers).
    pub fn route_path(&self, src: usize, dst: usize) -> Vec<usize> {
        let mut path = vec![self.attach[src]];
        let mut guard = 0;
        while *path.last().unwrap() != self.attach[dst] {
            let r = *path.last().unwrap();
            let port = self.route(r, dst);
            let next = self.neighbors[r][port - self.local_ports];
            assert_ne!(next, NONE, "route hit unconnected port");
            path.push(next);
            guard += 1;
            assert!(guard <= 4 * self.routers, "routing loop {src}->{dst}");
        }
        path
    }

    /// Hop count between two terminals (router-to-router links traversed).
    pub fn hops(&self, src: usize, dst: usize) -> usize {
        self.route_path(src, dst).len() - 1
    }

    /// Total unidirectional router-to-router links (for the power model).
    pub fn link_count(&self) -> usize {
        let inter: usize = self
            .neighbors
            .iter()
            .map(|ns| ns.iter().filter(|&&n| n != NONE).count())
            .sum();
        // c-mesh express links double the fabric (paper §1: "more links").
        if self.topology == Topology::CMesh {
            inter * 2
        } else {
            inter
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(Topology::parse("mesh"), Some(Topology::Mesh));
        assert_eq!(Topology::parse("NoC-tree"), Some(Topology::Tree));
        assert_eq!(Topology::parse("C-MESH"), Some(Topology::CMesh));
        assert_eq!(Topology::parse("ring"), None);
    }

    #[test]
    fn mesh_routing_is_xy_and_minimal() {
        let net = Network::build(Topology::Mesh, 16); // 4x4
        // 0 -> 15: 3 east + 3 south = 6 hops.
        assert_eq!(net.hops(0, 15), 6);
        let path = net.route_path(0, 15);
        // X first: 0,1,2,3 then 7,11,15.
        assert_eq!(path, vec![0, 1, 2, 3, 7, 11, 15]);
        assert_eq!(net.hops(5, 5), 0);
    }

    #[test]
    fn torus_uses_wraparound() {
        let mesh = Network::build(Topology::Mesh, 16);
        let torus = Network::build(Topology::Torus, 16);
        // 0 -> 3 on a 4-wide row: mesh 3 hops, torus 1 hop (wrap W).
        assert_eq!(mesh.hops(0, 3), 3);
        assert_eq!(torus.hops(0, 3), 1);
    }

    #[test]
    fn tree_routes_through_common_ancestor() {
        let net = Network::build(Topology::Tree, 16);
        // 16 terminals -> 4 leaf routers + 1 root = 5 routers.
        assert_eq!(net.routers, 5);
        // Terminals 0 and 3 share leaf router 0: 0 hops between routers.
        assert_eq!(net.hops(0, 3), 0);
        // Terminals 0 and 15 are under different leaves: up to root, down.
        assert_eq!(net.hops(0, 15), 2);
        let p = net.route_path(0, 15);
        assert_eq!(p, vec![0, 4, 3]);
    }

    #[test]
    fn tree_deep_hierarchy() {
        let net = Network::build(Topology::Tree, 64);
        // 16 leaves + 4 + 1 = 21 routers.
        assert_eq!(net.routers, 21);
        assert_eq!(net.hops(0, 63), 4); // leaf -> l1 -> root -> l1 -> leaf
    }

    #[test]
    fn cmesh_concentrates() {
        let net = Network::build(Topology::CMesh, 16);
        assert_eq!(net.routers, 4); // 2x2 of concentration-4 routers
        assert_eq!(net.local_ports, 4);
        // Terminals 0..3 share router 0.
        assert_eq!(net.hops(0, 3), 0);
        assert_eq!(net.hops(0, 15), 2);
        // Express links double the count.
        let mesh4 = Network::build(Topology::Mesh, 4);
        assert_eq!(net.link_count(), 2 * mesh4.link_count());
    }

    #[test]
    fn hypercube_dimension_routing() {
        let net = Network::build(Topology::Hypercube, 8);
        assert_eq!(net.routers, 8);
        assert_eq!(net.hops(0, 7), 3); // 3 differing bits
        assert_eq!(net.hops(0, 4), 1);
    }

    #[test]
    fn p2p_same_grid_as_mesh() {
        let p2p = Network::build(Topology::P2P, 16);
        let mesh = Network::build(Topology::Mesh, 16);
        assert_eq!(p2p.hops(0, 15), mesh.hops(0, 15));
        assert!(!Topology::P2P.has_routers());
    }

    #[test]
    fn all_pairs_route_on_all_topologies() {
        for topo in Topology::all() {
            for n in [1usize, 3, 7, 16, 33] {
                let net = Network::build(topo, n);
                for s in 0..n {
                    for d in 0..n {
                        let path = net.route_path(s, d);
                        assert_eq!(*path.first().unwrap(), net.attach[s]);
                        assert_eq!(*path.last().unwrap(), net.attach[d]);
                    }
                }
            }
        }
    }

    #[test]
    fn non_square_grid_routes() {
        // 7 terminals -> 3x3 grid with 2 unused positions.
        let net = Network::build(Topology::Mesh, 7);
        assert_eq!(net.dims, (3, 3));
        for s in 0..7 {
            for d in 0..7 {
                net.route_path(s, d);
            }
        }
    }
}
