//! Interconnect area and energy macro-models (Orion-class), replacing the
//! interconnect estimates the paper strips out of NeuroSim (§3.1).
//!
//! Router cost scales with radix, virtual channels, buffer depth and flit
//! width; link cost with width and length. Constants are 32 nm-calibrated
//! and follow the same F-scaling as [`crate::circuit::device`].

use super::topology::{Network, Topology};
use crate::config::NocConfig;

/// Per-network interconnect cost model.
#[derive(Clone, Copy, Debug)]
pub struct NocPower {
    /// Total interconnect area, mm².
    pub area_mm2: f64,
    /// Energy per flit per router hop, J.
    pub energy_per_hop_j: f64,
    /// Energy per flit per link traversal, J.
    pub energy_per_link_j: f64,
    /// Static/leakage power of the whole fabric, W.
    pub leakage_w: f64,
    /// Routers in the fabric (for reporting).
    pub routers: usize,
    /// Links in the fabric (for reporting).
    pub links: usize,
}

/// 32 nm base constants.
const BUFFER_AREA_PER_BIT_UM2: f64 = 0.45; // FIFO cell + control
const XBAR_AREA_PER_BIT_UM2: f64 = 0.12; // per port² bit
const ALLOC_AREA_UM2: f64 = 400.0; // VC + switch allocators per VC
const BUFFER_ENERGY_PER_BIT_J: f64 = 12.0e-15; // write + read
const XBAR_ENERGY_PER_BIT_J: f64 = 5.0e-15;
const ARB_ENERGY_J: f64 = 80.0e-15;
const LINK_ENERGY_PER_BIT_MM_J: f64 = 60.0e-15;
const LINK_AREA_PER_BIT_MM_UM2: f64 = 1.8; // repeated wire + repeaters
const ROUTER_LEAKAGE_PER_BIT_W: f64 = 0.9e-9; // buffer-dominated
/// P2P per-tile forwarding latch (no router): latch + mux per bit.
const P2P_NODE_AREA_PER_BIT_UM2: f64 = 0.9;
const P2P_NODE_ENERGY_PER_BIT_J: f64 = 8.0e-15;

impl NocPower {
    /// Build the cost model for `net` under `cfg`, with `link_mm` average
    /// link length (≈ tile edge for mesh/tree at tile pitch).
    pub fn new(net: &Network, cfg: &NocConfig, tech_nm: f64, link_mm: f64) -> Self {
        let f1 = tech_nm / 32.0;
        let f2 = f1 * f1;
        let w = cfg.bus_width as f64;
        let links = net.link_count();

        if !net.topology.has_routers() {
            // P2P: forwarding latches at every tile + neighbor links.
            let node_area = P2P_NODE_AREA_PER_BIT_UM2 * w * 4.0 * f2; // 4 directions
            let area_mm2 = (net.routers as f64 * node_area
                + links as f64 * LINK_AREA_PER_BIT_MM_UM2 * w * link_mm * f2)
                / 1e6;
            return Self {
                area_mm2,
                energy_per_hop_j: P2P_NODE_ENERGY_PER_BIT_J * w * f1,
                energy_per_link_j: LINK_ENERGY_PER_BIT_MM_J * w * link_mm * f1,
                leakage_w: net.routers as f64 * ROUTER_LEAKAGE_PER_BIT_W * w * 0.25 * f1,
                routers: 0,
                links,
            };
        }

        // Average radix over routers.
        let radix: f64 = (0..net.routers).map(|r| net.ports(r) as f64).sum::<f64>()
            / net.routers as f64;
        let vcs = cfg.virtual_channels as f64;
        let depth = cfg.buffer_depth as f64;

        // Per-router components.
        let buffer_bits = radix * vcs * depth * w;
        let buf_area = buffer_bits * BUFFER_AREA_PER_BIT_UM2;
        let xbar_area = radix * radix * w * XBAR_AREA_PER_BIT_UM2;
        let alloc_area = ALLOC_AREA_UM2 * vcs;
        let cmesh_factor = if net.topology == Topology::CMesh { 6.0 } else { 1.0 };
        let router_area_um2 = (buf_area + xbar_area + alloc_area) * f2 * cmesh_factor;

        let link_area_um2 = LINK_AREA_PER_BIT_MM_UM2 * w * link_mm * f2;
        // c-mesh: express links span 2 tiles AND the fabric is replicated
        // (express + local planes with wide double-pumped datapaths) — the
        // paper finds its EDAP orders of magnitude above mesh/tree.
        let link_len_factor = if net.topology == Topology::CMesh { 6.0 } else { 1.0 };

        let area_mm2 = (net.routers as f64 * router_area_um2
            + links as f64 * link_area_um2 * link_len_factor)
            / 1e6;

        // Per-flit dynamic energy.
        let energy_per_hop_j = (BUFFER_ENERGY_PER_BIT_J * w
            + XBAR_ENERGY_PER_BIT_J * w * (radix / 5.0)
            + ARB_ENERGY_J)
            * f1
            * cmesh_factor;
        let energy_per_link_j = LINK_ENERGY_PER_BIT_MM_J * w * link_mm * link_len_factor * f1;

        let leakage_w =
            net.routers as f64 * buffer_bits * ROUTER_LEAKAGE_PER_BIT_W * f1 * cmesh_factor;

        Self {
            area_mm2,
            energy_per_hop_j,
            energy_per_link_j,
            leakage_w,
            routers: net.routers,
            links,
        }
    }

    /// Dynamic energy for a flit traversing `hops` routers (+hops links).
    pub fn flit_energy_j(&self, hops: usize) -> f64 {
        // hops router traversals + hops links + final ejection ≈ hops+1 hops.
        (hops + 1) as f64 * self.energy_per_hop_j + hops as f64 * self.energy_per_link_j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn power(topo: Topology, n: usize, cfg: &NocConfig) -> NocPower {
        let net = Network::build(topo, n);
        NocPower::new(&net, cfg, 32.0, 1.0)
    }

    #[test]
    fn mesh_costs_more_than_tree() {
        let cfg = NocConfig::default();
        let mesh = power(Topology::Mesh, 64, &cfg);
        let tree = power(Topology::Tree, 64, &cfg);
        // 64 mesh routers vs 21 tree routers.
        assert!(mesh.area_mm2 > 2.0 * tree.area_mm2);
        assert!(mesh.leakage_w > tree.leakage_w);
    }

    #[test]
    fn cmesh_costs_more_than_mesh_per_router() {
        let cfg = NocConfig::default();
        let mesh = power(Topology::Mesh, 64, &cfg);
        let cmesh = power(Topology::CMesh, 64, &cfg);
        // Fewer routers but much higher radix (8 ports) + doubled, longer
        // express links: per-flit energy must be higher.
        assert!(cmesh.energy_per_hop_j > mesh.energy_per_hop_j);
        assert!(cmesh.energy_per_link_j > mesh.energy_per_link_j);
    }

    #[test]
    fn p2p_cheap_fabric() {
        let cfg = NocConfig::default();
        let p2p = power(Topology::P2P, 64, &cfg);
        let mesh = power(Topology::Mesh, 64, &cfg);
        assert!(p2p.area_mm2 < mesh.area_mm2);
        assert_eq!(p2p.routers, 0);
    }

    #[test]
    fn area_scales_with_vcs_and_width() {
        let base = NocConfig::default();
        let wide = NocConfig {
            bus_width: 64,
            ..base.clone()
        };
        let vc4 = NocConfig {
            virtual_channels: 4,
            ..base.clone()
        };
        let b = power(Topology::Mesh, 64, &base);
        let w = power(Topology::Mesh, 64, &wide);
        let v = power(Topology::Mesh, 64, &vc4);
        assert!(w.area_mm2 > 1.5 * b.area_mm2);
        assert!(v.area_mm2 > 1.5 * b.area_mm2);
        assert!(w.energy_per_hop_j > b.energy_per_hop_j);
    }

    #[test]
    fn flit_energy_grows_with_hops() {
        let cfg = NocConfig::default();
        let p = power(Topology::Mesh, 64, &cfg);
        assert!(p.flit_energy_j(6) > p.flit_energy_j(1));
        assert!(p.flit_energy_j(0) > 0.0); // injection+ejection still costs
    }
}
