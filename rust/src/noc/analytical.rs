//! Analytical NoC performance model — the paper's Algorithm 2.
//!
//! Per router `r` we build the port-to-port injection matrix `Λʳ` from the
//! deterministic routes of all flows, derive the forwarding-probability
//! matrix `Fʳ` (Eq. 7), the contention matrix `Cʳ` (`c_ij = Σ_k f_ik f_jk`),
//! and solve the queueing fixed point
//!
//! ```text
//! Nʳ = (I − t·Λʳ·Cʳ)⁻¹ · Λʳ · R           (Eq. 8)
//! Wʳ = Nʳ (Λʳ)⁻¹                           (per-port waiting, Little)
//! ```
//!
//! with deterministic unit service time `t = 1` and the discrete-time
//! residual `R = 1/2` (packets arrive on clock edges — the correction of
//! the paper's ref. [21]). Per-flit end-to-end latency adds the pipeline
//! transit along the route; per-layer latency is the rate-weighted mean,
//! and `L_comm` sums layers (Eq. 9–11).

use std::collections::HashMap;

use super::sim::FlowSpec;
use super::topology::Network;
use crate::config::NocConfig;
use crate::util::Matrix;

/// Result of evaluating one layer's flow set.
#[derive(Clone, Debug)]
pub struct LayerEstimate {
    /// Rate-weighted average per-flit latency, cycles.
    pub avg_latency: f64,
    /// Sum of average waiting times across routers (Eq. 10, reported for
    /// comparison with the paper's aggregate form).
    pub total_waiting: f64,
    /// True when some router is past its stability point (ρ ≥ 1); latency
    /// is then a lower bound.
    pub saturated: bool,
}

/// Analytical model over a fixed network.
pub struct AnalyticalModel<'a> {
    net: &'a Network,
    cfg: &'a NocConfig,
}

/// Per-router accumulated port-to-port rates.
struct RouterTraffic {
    /// lambda[in][out] in flits/cycle.
    lambda: Matrix,
}

impl<'a> AnalyticalModel<'a> {
    /// A model over an already-built network.
    pub fn new(net: &'a Network, cfg: &'a NocConfig) -> Self {
        Self { net, cfg }
    }

    /// Router service time in cycles: 1 for pipelined NoC routers, 2 for
    /// the half-duplex P2P store-and-forward nodes.
    fn service_time(&self) -> f64 {
        if self.net.topology.has_routers() {
            1.0
        } else {
            2.0
        }
    }

    /// Zero-load transit latency of a route with `hops` links (calibrated
    /// against the cycle-accurate router model: each of the `hops + 1`
    /// routers on the path costs its pipeline depth, plus one ejection
    /// cycle; P2P nodes cost one store-and-forward cycle each).
    fn transit(&self, hops: usize) -> f64 {
        let per_router = if self.net.topology.has_routers() {
            self.cfg.pipeline_stages as f64
        } else {
            1.0
        };
        (hops as f64 + 1.0) * per_router + 1.0
    }

    /// Rate-weighted zero-load latency over a flow set (denominator of the
    /// congestion factor used by the architecture evaluator).
    pub fn zero_load(&self, flows: &[FlowSpec]) -> f64 {
        let mut weighted = 0.0;
        let mut total = 0.0;
        for f in flows {
            if f.src == f.dst {
                continue;
            }
            let hops = self.net.hops(f.src, f.dst);
            let w = if f.rate > 0.0 { f.rate } else { f.flits as f64 };
            weighted += w * self.transit(hops);
            total += w;
        }
        if total > 0.0 {
            weighted / total
        } else {
            0.0
        }
    }

    /// Accumulate every flow's route into per-router Λ matrices. Returns
    /// the traffic map and, per flow, its route as (router, in_port) steps.
    fn build_traffic(
        &self,
        flows: &[FlowSpec],
    ) -> (HashMap<usize, RouterTraffic>, Vec<Vec<(usize, usize)>>) {
        let mut traffic: HashMap<usize, RouterTraffic> = HashMap::new();
        let mut flow_steps = Vec::with_capacity(flows.len());
        for f in flows {
            let mut steps = Vec::new();
            if f.src == f.dst {
                flow_steps.push(steps);
                continue;
            }
            let path = self.net.route_path(f.src, f.dst);
            // Input port at the first router is the terminal's local port.
            let mut in_port = self.net.attach_port[f.src];
            for (k, &r) in path.iter().enumerate() {
                let out_port = if k + 1 < path.len() {
                    self.net.route(r, f.dst)
                } else {
                    self.net.attach_port[f.dst] // ejection
                };
                let ports = self.net.ports(r);
                let t = traffic.entry(r).or_insert_with(|| RouterTraffic {
                    lambda: Matrix::zeros(ports, ports),
                });
                t.lambda[(in_port, out_port)] += f.rate;
                steps.push((r, in_port));
                if k + 1 < path.len() {
                    // Find the input port on the next router.
                    let slot = out_port - self.net.local_ports;
                    let next = self.net.neighbors[r][slot];
                    in_port = self.net.local_ports
                        + self.net.neighbors[next]
                            .iter()
                            .position(|&m| m == r)
                            .expect("asymmetric link");
                }
            }
            flow_steps.push(steps);
        }
        (traffic, flow_steps)
    }

    /// Solve the per-router queueing model; returns per-(router, in_port)
    /// expected waiting time and a saturation flag.
    fn solve_waiting(
        &self,
        traffic: &HashMap<usize, RouterTraffic>,
    ) -> (HashMap<(usize, usize), f64>, bool, f64) {
        let t_service = self.service_time();
        let mut waiting = HashMap::new();
        let mut saturated = false;
        let mut total_waiting = 0.0;

        for (&r, tr) in traffic {
            let ports = tr.lambda.rows();
            // Port arrival rates λ_i = Σ_j λ_ij.
            let lam: Vec<f64> = (0..ports).map(|i| tr.lambda.row(i).iter().sum()).collect();
            // Forwarding probabilities F (Eq. 7).
            let mut f = Matrix::zeros(ports, ports);
            for i in 0..ports {
                if lam[i] > 0.0 {
                    for j in 0..ports {
                        f[(i, j)] = tr.lambda[(i, j)] / lam[i];
                    }
                }
            }
            // Contention matrix C: c_ij = Σ_k f_ik · f_jk.
            let ft = f.transpose();
            let c = &f * &ft;
            // N = (I - t·diag(λ)·C)^{-1} · diag(λ) · R   (Eq. 8)
            // Discrete-time deterministic service (paper ref. [21]): the
            // mean residual service seen by an arrival is R_i = λ_i·t²/2,
            // which vanishes at zero load (M/D/1 behaviour).
            let lam_diag = Matrix::diag(&lam);
            let a = &Matrix::identity(ports) - &(&lam_diag * &c).scale(t_service);
            let rhs: Vec<f64> = lam
                .iter()
                .map(|l| l * (l * t_service * t_service / 2.0))
                .collect();
            let n = match a.solve(&rhs) {
                Some(n) if n.iter().all(|v| v.is_finite() && *v >= -1e-9) => n,
                _ => {
                    saturated = true;
                    // Fall back to a large-but-finite waiting estimate.
                    vec![self.cfg.buffer_depth as f64; ports]
                }
            };
            // Per-port waiting W_i = N_i / λ_i (Little's law). Also check
            // the utilization stability condition.
            let mut w_sum = 0.0;
            let mut active = 0usize;
            for i in 0..ports {
                let w = if lam[i] > 0.0 { (n[i] / lam[i]).max(0.0) } else { 0.0 };
                if lam[i] * t_service >= 1.0 {
                    saturated = true;
                }
                if lam[i] > 0.0 {
                    w_sum += w;
                    active += 1;
                }
                waiting.insert((r, i), w);
            }
            // Eq. 9: average over ports; Eq. 10 accumulates over routers.
            if active > 0 {
                total_waiting += w_sum / ports as f64;
            }
        }
        (waiting, saturated, total_waiting)
    }

    /// Estimate one layer's average per-flit communication latency.
    pub fn layer_latency(&self, flows: &[FlowSpec]) -> LayerEstimate {
        let (traffic, flow_steps) = self.build_traffic(flows);
        if traffic.is_empty() {
            return LayerEstimate {
                avg_latency: 0.0,
                total_waiting: 0.0,
                saturated: false,
            };
        }
        let (waiting, saturated, total_waiting) = self.solve_waiting(&traffic);

        let mut weighted = 0.0;
        let mut total_rate = 0.0;
        for (f, steps) in flows.iter().zip(&flow_steps) {
            if f.src == f.dst || steps.is_empty() {
                continue;
            }
            let hops = steps.len() - 1;
            let mut lat = self.transit(hops);
            for &(r, p) in steps {
                lat += waiting.get(&(r, p)).copied().unwrap_or(0.0);
            }
            let rate = if f.rate > 0.0 { f.rate } else { f.flits as f64 };
            weighted += rate * lat;
            total_rate += rate;
        }
        LayerEstimate {
            avg_latency: if total_rate > 0.0 { weighted / total_rate } else { 0.0 },
            total_waiting,
            saturated,
        }
    }
}

impl<'a> AnalyticalModel<'a> {
    /// Fast analytical estimate of the *makespan* (cycles to complete one
    /// frame's transfers, cf. drain-mode simulation): the busiest resource
    /// — a directed link or an ejection port — bounds the transfer, plus
    /// the zero-load transit of the average route and the queueing wait.
    ///
    /// This is the model behind the optimal-topology guidance (Fig. 20):
    /// it captures exactly the ρ/μ dependence of Eq. 16 (flits per
    /// bottleneck resource ∝ ρ·μ / (tiles per layer)).
    pub fn layer_makespan(&self, flows: &[FlowSpec]) -> f64 {
        let (bottleneck, transit) = self.layer_bottleneck(flows);
        if bottleneck == 0.0 && transit == 0.0 {
            return 0.0;
        }
        bottleneck + transit
    }

    /// Bandwidth-bound analysis: returns `(bottleneck_load, mean_transit)`
    /// where `bottleneck_load` is the heaviest per-frame load (in flits, or
    /// in flits/cycle when rates are given) on any directed link, ejection
    /// port, injection port — or whole node for half-duplex P2P.
    pub fn layer_bottleneck(&self, flows: &[FlowSpec]) -> (f64, f64) {
        self.layer_bottleneck_with_eject(flows, 1.0)
    }

    /// Like [`AnalyticalModel::layer_bottleneck`], with ejection/injection
    /// ports draining at `eject_capacity` flits/cycle (wide tile-local
    /// ports feeding several CE lanes in parallel, Fig. 10).
    pub fn layer_bottleneck_with_eject(
        &self,
        flows: &[FlowSpec],
        eject_capacity: f64,
    ) -> (f64, f64) {
        // flits through each directed link (router, slot) and ejection port.
        let mut link_load: HashMap<(usize, usize), f64> = HashMap::new();
        let mut eject_load: HashMap<(usize, usize), f64> = HashMap::new();
        let mut inject_load: HashMap<usize, f64> = HashMap::new();
        let mut transit_weighted = 0.0;
        let mut total_flits = 0.0;
        for f in flows {
            if f.src == f.dst {
                continue;
            }
            let flits = if f.flits > 0 { f.flits as f64 } else { f.rate };
            let path = self.net.route_path(f.src, f.dst);
            for (k, &r) in path.iter().enumerate() {
                if k + 1 < path.len() {
                    let out = self.net.route(r, f.dst);
                    *link_load.entry((r, out)).or_default() += flits;
                }
            }
            let last = *path.last().unwrap();
            *eject_load
                .entry((last, self.net.attach_port[f.dst]))
                .or_default() += flits;
            *inject_load.entry(f.src).or_default() += flits;
            transit_weighted += flits * self.transit(path.len() - 1);
            total_flits += flits;
        }
        if total_flits == 0.0 {
            return (0.0, 0.0);
        }
        let cap = eject_capacity.max(1.0);
        let mut max_load = link_load.values().fold(0.0f64, |m, &v| m.max(v));
        for &v in eject_load.values().chain(inject_load.values()) {
            max_load = max_load.max(v / cap);
        }
        // P2P shares one half-duplex switch slot per node across all
        // ports: the node's total forwarded traffic serializes at 2
        // cycles/flit.
        if !self.net.topology.has_routers() {
            let mut node_load: HashMap<usize, f64> = HashMap::new();
            for ((r, _), v) in &link_load {
                *node_load.entry(*r).or_default() += v;
            }
            for ((r, _), v) in &eject_load {
                *node_load.entry(*r).or_default() += v;
            }
            let node_max = node_load.values().fold(0.0f64, |m, &v| m.max(v));
            max_load = max_load.max(node_max * self.service_time());
        }
        (max_load, transit_weighted / total_flits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::sim::{uniform_random_flows, Mode, NocSim};
    use crate::noc::topology::Topology;

    #[test]
    fn zero_load_matches_transit() {
        let net = Network::build(Topology::Mesh, 16);
        let cfg = NocConfig::default();
        let model = AnalyticalModel::new(&net, &cfg);
        let flows = [FlowSpec {
            src: 0,
            dst: 15,
            rate: 1e-6,
            flits: 0,
        }];
        let est = model.layer_latency(&flows);
        // 6 hops, 7 routers x 3 pipeline stages + eject -> 22 cycles,
        // negligible waiting at 1e-6 load.
        assert!(
            (21.5..23.5).contains(&est.avg_latency),
            "{}",
            est.avg_latency
        );
        assert!(!est.saturated);
    }

    #[test]
    fn waiting_grows_with_load() {
        let net = Network::build(Topology::Mesh, 16);
        let cfg = NocConfig::default();
        let model = AnalyticalModel::new(&net, &cfg);
        let lo = model.layer_latency(&uniform_random_flows(16, 0.02));
        let hi = model.layer_latency(&uniform_random_flows(16, 0.30));
        assert!(hi.avg_latency > lo.avg_latency);
        assert!(hi.total_waiting > lo.total_waiting);
    }

    #[test]
    fn saturation_detected() {
        let net = Network::build(Topology::Mesh, 16);
        let cfg = NocConfig::default();
        let model = AnalyticalModel::new(&net, &cfg);
        // Hotspot at 4 flits/cycle into one node: far past capacity.
        let flows: Vec<FlowSpec> = (1..16)
            .map(|s| FlowSpec {
                src: s,
                dst: 0,
                rate: 0.3,
                flits: 0,
            })
            .collect();
        let est = model.layer_latency(&flows);
        assert!(est.saturated);
    }

    #[test]
    fn accuracy_against_cycle_accurate_low_load() {
        // Paper Fig. 11: accuracy > 85% vs BookSim. Check at a low,
        // DNN-realistic load on a 64-node mesh.
        let cfg = NocConfig::default();
        let flows = uniform_random_flows(64, 0.05);
        let net = Network::build(Topology::Mesh, 64);
        let est = AnalyticalModel::new(&net, &cfg).layer_latency(&flows);
        let sim = NocSim::new(
            Topology::Mesh,
            64,
            &cfg,
            &flows,
            Mode::Steady {
                warmup: 1_000,
                measure: 10_000,
            },
            21,
        )
        .run();
        let acc = 1.0 - (est.avg_latency - sim.avg_latency).abs() / sim.avg_latency;
        assert!(
            acc > 0.8,
            "analytical {} vs sim {} (accuracy {acc})",
            est.avg_latency,
            sim.avg_latency
        );
    }

    #[test]
    fn makespan_tracks_drain_sim() {
        // The bandwidth-bound estimate must land within 2x of the
        // cycle-accurate drain makespan for a hotspot transfer.
        let cfg = NocConfig::default();
        let net = Network::build(Topology::Mesh, 16);
        let flows: Vec<FlowSpec> = (1..8)
            .map(|s| FlowSpec {
                src: s,
                dst: 0,
                rate: 0.0,
                flits: 100,
            })
            .collect();
        let est = AnalyticalModel::new(&net, &cfg).layer_makespan(&flows);
        let sim = NocSim::new(
            Topology::Mesh,
            16,
            &cfg,
            &flows,
            Mode::Drain {
                max_cycles: 1_000_000,
            },
            31,
        )
        .run();
        assert!(sim.drained);
        let ratio = est / sim.makespan as f64;
        assert!(
            (0.5..2.0).contains(&ratio),
            "estimate {est} vs sim {} (ratio {ratio})",
            sim.makespan
        );
    }

    #[test]
    fn tree_estimates_work() {
        let cfg = NocConfig::default();
        let net = Network::build(Topology::Tree, 64);
        let flows = [FlowSpec {
            src: 0,
            dst: 63,
            rate: 0.01,
            flits: 0,
        }];
        let est = AnalyticalModel::new(&net, &cfg).layer_latency(&flows);
        // 4 hops, 5 routers x 3 stages + eject -> 16 cycles transit.
        assert!((15.0..19.0).contains(&est.avg_latency), "{}", est.avg_latency);
    }
}
