//! On-chip interconnect: a BookSim-class cycle-accurate simulator plus the
//! analytical performance model of the paper's Algorithm 2.
//!
//! * [`topology`] — P2P, NoC-tree, NoC-mesh, c-mesh, torus, hypercube link
//!   graphs with deterministic routing (X-Y on mesh/c-mesh/torus, up-down on
//!   tree, dimension-order on hypercube, neighbor-forwarding on P2P).
//! * [`router`] — 5-port input-buffered router with virtual channels,
//!   credit-based flow control and a 3-stage pipeline (paper Table 2).
//! * [`sim`] — the cycle-accurate event loop with non-uniform per-pair
//!   injection (the paper's BookSim customization, §3.2), queue-occupancy
//!   and worst-case-latency statistics (§6.3).
//! * [`power`] — router/link area and energy macro-models (Orion-class).
//! * [`analytical`] — Algorithm 2: per-router injection matrix, forwarding
//!   and contention matrices, queue lengths `N = (I − tΛC)⁻¹ΛR`, end-to-end
//!   per-layer latency.
//! * [`latency`] — Algorithm 1: end-to-end communication latency of a DNN
//!   by per-layer simulation (Eq. 4/5).

pub mod analytical;
pub mod latency;
pub mod power;
pub mod router;
pub mod sim;
pub mod topology;

pub use analytical::AnalyticalModel;
pub use power::NocPower;
pub use sim::{NocSim, SimStats};
pub use topology::{Network, Topology};
