//! Hand-rolled CLI (no clap in the offline environment).
//!
//! ```text
//! repro figure <id> [--exact] [--fast] [--csv] [--seed N]
//! repro table <id>  [--exact] [--fast] [--csv]
//! repro all         [--exact] [--fast] [--csv]
//! repro eval <dnn> [--tech sram|reram] [--topology mesh|tree|p2p|cmesh] [--exact]
//! repro advise <dnn>
//! repro chiplet [--model <dnn>] [--chiplets N] [--noc t] [--nop t] [--advise] [--heatmap]
//! repro serve <artifact> [--requests N] [--batch N] [--in-dim N] [--trace-out f]
//! repro serve --model <dnn> | --mix [spec] | --trace <file>    (modeled serving)
//! repro sweep [--tech sram|reram] [--exact]
//! repro config [--load path]
//! repro list
//! ```
//!
//! `repro help` prints the full per-flag reference (see `usage()` below —
//! kept in sync with the subcommand dispatch; `cli_integration` tests pin
//! the behavior).

use anyhow::{anyhow, bail, Result};

use crate::arch::{evaluate, recommend_scaleout, recommend_topology, CommBackend};
use crate::config::{
    Admission, ArchConfig, Config, MemTech, NocConfig, NopConfig, NopMode, ServingConfig,
    SimConfig, WorkloadConfig,
};
use crate::coordinator::mix::{replay_mix_metrics, serve_mix_metrics, MixServingModel};
use crate::coordinator::scheduler::{serve_modeled_metrics, Policy};
use crate::coordinator::server::{synthetic_requests, InferenceServer, ServeReport};
use crate::dnn::{by_name, DnnGraph};
use crate::experiments::{find, registry, Options};
use crate::noc::sim::Mode;
use crate::noc::topology::Topology;
use crate::nop::evaluator::{evaluate_package, package_flows};
use crate::nop::sim::NopSim;
use crate::nop::topology::{NopNetwork, NopTopology};
use crate::telemetry::span::RequestSpan;
use crate::telemetry::{
    heatmap_json, heatmap_text, profile, spans_to_trace, BlameReport, IngressTrace, LayerBlame,
    TimeSeries,
};
use crate::util::{fmt_sig, log, Table};
use crate::workload::{ArrivalKind, PlacementPolicy, Trace, WorkloadMix};

/// Parsed flag set: positionals + `--key value` / `--flag` options.
#[derive(Debug, Default)]
pub struct Args {
    /// Non-flag tokens in order (subcommand, then its arguments).
    pub positional: Vec<String>,
    /// `--name [value]` pairs in order of appearance.
    pub flags: Vec<(String, Option<String>)>,
}

impl Args {
    /// Split raw argv into positionals and flags. Only flags named in
    /// `flag_takes_value` consume a following value token.
    pub fn parse(argv: &[String]) -> Self {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // Value-flags take the next token unless it is another flag.
                let value = argv
                    .get(i + 1)
                    .filter(|v| !v.starts_with("--"))
                    .cloned();
                let consumed = value.is_some() && flag_takes_value(name);
                args.flags.push((
                    name.to_string(),
                    if consumed { value } else { None },
                ));
                i += if consumed { 2 } else { 1 };
            } else {
                args.positional.push(a.clone());
                i += 1;
            }
        }
        args
    }

    /// Was `--name` passed (with or without a value)?
    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    /// The value of `--name`, if the flag was passed with one.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }

    /// Integer value of `--name`, or `default` when absent.
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Float value of `--name`, or `default` when absent.
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got '{v}'")),
        }
    }
}

fn flag_takes_value(name: &str) -> bool {
    matches!(
        name,
        "seed"
            | "tech"
            | "topology"
            | "requests"
            | "batch"
            | "in-dim"
            | "load"
            | "threads"
            | "model"
            | "chiplets"
            | "noc"
            | "nop"
            | "policy"
            | "rate"
            | "queue-depth"
            | "mix"
            | "placement"
            | "admission"
            | "arrival"
            | "trace"
            | "record-trace"
            | "trace-out"
            | "heatmap-out"
            | "metrics-out"
            | "metrics-format"
            | "metrics-window-ms"
            | "explain-out"
            | "surrogate-check-out"
    )
}

/// Resolve the package-leg pricing mode from the `--sim` / `--surrogate`
/// flags (mutually exclusive; default analytical).
fn nop_mode_from(args: &Args) -> Result<NopMode> {
    match (args.has("sim"), args.has("surrogate")) {
        (true, true) => bail!("--sim and --surrogate are mutually exclusive (pick one NoP mode)"),
        (true, false) => Ok(NopMode::Sim),
        (false, true) => Ok(NopMode::Surrogate),
        (false, false) => Ok(NopMode::Analytical),
    }
}

/// Parse a tile-level NoC topology, listing the valid names on failure.
fn parse_noc_topology(s: &str) -> Result<Topology> {
    Topology::parse(s).ok_or_else(|| {
        anyhow!(
            "unknown NoC topology '{s}' (valid: {})",
            Topology::valid_names()
        )
    })
}

/// Parse a package-level NoP topology, listing the valid names on failure.
fn parse_nop_topology(s: &str) -> Result<NopTopology> {
    NopTopology::parse(s).ok_or_else(|| {
        anyhow!(
            "unknown NoP topology '{s}' (valid: {})",
            NopTopology::valid_names()
        )
    })
}

/// Hand-rolled JSON dump for `repro chiplet --surrogate-check-out`: one
/// record per (topology, k) point from [`crate::sim::surrogate::check`].
/// The grid covers ring and mesh packages at k ∈ {4, 8} (`--fast`) plus
/// k = 16 on the full tier; `scripts/check_surrogate.py` enforces the
/// held-out error bound and the wall-clock ratio on this file.
fn surrogate_check_json(fast: bool, seed: u64) -> Result<String> {
    let ks: &[usize] = if fast { &[4, 8] } else { &[4, 8, 16] };
    let mut configs = Vec::new();
    for &k in ks {
        for topo in [NopTopology::Ring, NopTopology::Mesh] {
            let nop = NopConfig {
                topology: topo,
                chiplets: k,
                mode: NopMode::Surrogate,
                ..NopConfig::default()
            };
            let c = crate::sim::surrogate::check(topo, k, &nop, seed).ok_or_else(|| {
                anyhow!(
                    "surrogate check: {} k={k} has no measurable saturation",
                    topo.name()
                )
            })?;
            configs.push(c);
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{{\"seed\": {seed}, \"configs\": [\n"));
    for (i, c) in configs.iter().enumerate() {
        let holdout: Vec<String> = c
            .holdout
            .iter()
            .map(|h| {
                format!(
                    "{{\"rate\": {}, \"sim\": {}, \"surrogate\": {}, \"rel_err\": {}}}",
                    h.rate, h.sim, h.surrogate, h.rel_err
                )
            })
            .collect();
        out.push_str(&format!(
            "  {{\"topology\": \"{}\", \"k\": {}, \"sat_rate\": {}, \
             \"steady_anchors\": {}, \"drain_anchors\": {}, \"fallbacks\": {}, \
             \"sim_ns\": {}, \"surrogate_ns\": {}, \"holdout\": [{}]}}{}\n",
            c.topology.name(),
            c.k,
            c.sat_rate,
            c.steady_anchors,
            c.drain_anchors,
            c.fallbacks,
            c.sim_ns,
            c.surrogate_ns,
            holdout.join(", "),
            if i + 1 == configs.len() { "" } else { "," },
        ));
    }
    out.push_str("]}\n");
    Ok(out)
}

/// One-line winner summary shared by every `chiplet` view. The EDAP shown
/// is the *ranking* value (saturation-derated under `--sim`), so it always
/// agrees with the candidates table.
fn print_scaleout_recommendation(rec: &crate::arch::ScaleoutRecommendation, dnn: &str) {
    println!(
        "joint recommendation for {}: {} chiplet(s){} with per-chiplet {} (EDAP {}{})",
        dnn,
        rec.chiplets,
        if rec.chiplets == 1 {
            String::new()
        } else {
            format!(" over NoP-{}", rec.nop_topology.name())
        },
        rec.noc_topology.name(),
        fmt_sig(rec.best_edap, 4),
        if rec.sim_calibrated {
            ", sim-calibrated"
        } else {
            ""
        },
    );
}

fn options_from(args: &Args) -> Result<Options> {
    Ok(Options {
        backend: if args.has("exact") {
            CommBackend::Simulate
        } else {
            CommBackend::Analytical
        },
        fast: args.has("fast"),
        nop_mode: nop_mode_from(args)?,
        seed: args.get_usize("seed", 0x1AC5_EED)? as u64,
    })
}

fn print_tables(tables: &[Table], csv: bool) {
    for t in tables {
        if csv {
            println!("# {}", t.title);
            print!("{}", t.to_csv());
        } else {
            print!("{}", t.render());
        }
        println!();
    }
}

/// Entry point; returns the process exit code.
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv);
    if args.has("verbose") {
        // Compose with REPRO_LOG rather than overriding it: the flag
        // raises the level to at least Debug but never silences a more
        // verbose REPRO_LOG=trace.
        log::set_level(log::level().max(log::Level::Debug));
    }
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    match cmd {
        "figure" | "table" => {
            let id = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("usage: repro {cmd} <id>"))?;
            let prefix = if cmd == "figure" { "fig" } else { "table" };
            let full_id = if id.chars().all(|c| c.is_ascii_digit()) {
                format!("{prefix}{id}")
            } else {
                id.clone()
            };
            let exp = find(&full_id)
                .ok_or_else(|| anyhow!("unknown experiment '{full_id}' (try `repro list`)"))?;
            let opts = options_from(&args)?;
            log::info!("== {} — {} ==", exp.id, exp.title);
            let tables = {
                let _t = profile::phase(&format!("experiment.{}", exp.id));
                (exp.run)(&opts).map_err(|e| anyhow!(e))?
            };
            print_tables(&tables, args.has("csv"));
        }
        "all" => {
            let opts = options_from(&args)?;
            for exp in registry() {
                log::info!("== {} — {} ==", exp.id, exp.title);
                let tables = {
                    let _t = profile::phase(&format!("experiment.{}", exp.id));
                    (exp.run)(&opts).map_err(|e| anyhow!(e))?
                };
                print_tables(&tables, args.has("csv"));
            }
        }
        "eval" => {
            let name = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("usage: repro eval <dnn>"))?;
            let g = by_name(name).ok_or_else(|| anyhow!("unknown DNN '{name}'"))?;
            let tech = match args.get("tech") {
                None => MemTech::Reram,
                Some(t) => MemTech::parse(t).ok_or_else(|| anyhow!("bad --tech '{t}'"))?,
            };
            let topo = match args.get("topology") {
                None => recommend_topology(&g, &ArchConfig::default(), &NocConfig::default())
                    .topology,
                Some(t) => parse_noc_topology(t)?,
            };
            let arch = ArchConfig {
                tech,
                ..ArchConfig::default()
            };
            let backend = if args.has("exact") {
                CommBackend::Simulate
            } else {
                CommBackend::Analytical
            };
            let e = evaluate(
                &g,
                topo,
                &arch,
                &NocConfig::with_topology(topo),
                &SimConfig::default(),
                backend,
            );
            let mut t = Table::new(
                format!("{} on {} IMC with {}", g.name, tech.name(), topo.name()),
                &["metric", "value"],
            );
            t.add_row(vec!["tiles".into(), e.tiles.to_string()]);
            t.add_row(vec!["crossbars".into(), e.crossbars.to_string()]);
            t.add_row(vec![
                "latency_ms".into(),
                fmt_sig(e.latency_s() * 1e3, 4),
            ]);
            t.add_row(vec![
                "  compute_ms".into(),
                fmt_sig(e.compute_latency_s * 1e3, 4),
            ]);
            t.add_row(vec![
                "  routing_ms".into(),
                fmt_sig(e.comm_latency_s * 1e3, 4),
            ]);
            t.add_row(vec!["power_W".into(), fmt_sig(e.power_w(), 4)]);
            t.add_row(vec!["area_mm2".into(), fmt_sig(e.area_mm2(), 4)]);
            t.add_row(vec!["FPS".into(), fmt_sig(e.fps(), 4)]);
            t.add_row(vec!["EDAP_J.ms.mm2".into(), fmt_sig(e.edap(), 4)]);
            print_tables(&[t], args.has("csv"));
            if args.has("verbose") {
                let mut pl = Table::new(
                    "per-layer communication (cycles)",
                    &["layer", "name", "comm_cycles"],
                );
                for (layer, cycles) in &e.comm_per_layer {
                    pl.add_row(vec![
                        layer.to_string(),
                        g.layers[*layer].name.clone(),
                        cycles.to_string(),
                    ]);
                }
                print_tables(&[pl], args.has("csv"));
            }
        }
        "advise" => {
            let name = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("usage: repro advise <dnn>"))?;
            let g = by_name(name).ok_or_else(|| anyhow!("unknown DNN '{name}'"))?;
            let rec = recommend_topology(&g, &ArchConfig::default(), &NocConfig::default());
            println!(
                "{}: use {} (rho={:.1}, mu={}, EDAP tree={:.4} mesh={:.4}, rule-of-thumb={})",
                g.name,
                rec.topology.name(),
                rec.density,
                rec.neurons,
                rec.edap_tree,
                rec.edap_mesh,
                rec.rule_of_thumb.name(),
            );
        }
        "chiplet" => {
            let base_noc = NocConfig::default();
            let nop_mode = nop_mode_from(&args)?;
            let sim_mode = nop_mode != NopMode::Analytical;
            let base_nop = NopConfig {
                mode: nop_mode,
                ..NopConfig::default()
            };
            let arch = ArchConfig {
                tech: match args.get("tech") {
                    None => MemTech::Reram,
                    Some(t) => MemTech::parse(t).ok_or_else(|| anyhow!("bad --tech '{t}'"))?,
                },
                ..ArchConfig::default()
            };
            let backend = if args.has("exact") {
                CommBackend::Simulate
            } else {
                CommBackend::Analytical
            };
            if let Some(path) = args.get("surrogate-check-out") {
                // Sim-vs-surrogate comparison dump over a (topology, k)
                // grid; `scripts/check_surrogate.py` gates the JSON in CI.
                let seed = args.get_usize("seed", 0x1AC5_EED)? as u64;
                let json = surrogate_check_json(args.has("fast"), seed)?;
                std::fs::write(path, &json).map_err(|e| anyhow!("write {path}: {e}"))?;
                log::info!("wrote surrogate check JSON to {path}");
                return Ok(());
            }
            if args.has("advise") && args.get("model").is_none() {
                // Joint recommendation for the whole zoo.
                for conflicting in ["chiplets", "noc", "nop", "exact", "sim", "surrogate"] {
                    if args.has(conflicting) {
                        bail!(
                            "--advise searches the full (chiplets x NoP x NoC) space; \
                             drop --{conflicting} or drop --advise"
                        );
                    }
                }
                if args.has("heatmap") || args.has("heatmap-out") {
                    bail!("--advise conflicts with --heatmap/--heatmap-out; drop one side");
                }
                let mut t = Table::new(
                    "Joint scale-out recommendation per zoo model",
                    &["dnn", "chiplets", "NoP", "NoC", "latency_ms", "EDAP"],
                );
                for g in crate::dnn::model_zoo() {
                    let rec = recommend_scaleout(&g, &arch, &base_noc, &base_nop);
                    t.add_row(vec![
                        g.name.clone(),
                        rec.chiplets.to_string(),
                        if rec.chiplets == 1 {
                            "-".into()
                        } else {
                            rec.nop_topology.name().into()
                        },
                        rec.noc_topology.name().into(),
                        fmt_sig(rec.best.latency_s() * 1e3, 4),
                        fmt_sig(rec.best.edap(), 3),
                    ]);
                }
                print_tables(&[t], args.has("csv"));
                return Ok(());
            }
            let name = args
                .get("model")
                .ok_or_else(|| anyhow!("usage: repro chiplet --model <dnn> [--chiplets N] (or `repro chiplet --advise` for the whole zoo)"))?;
            let g = by_name(name).ok_or_else(|| anyhow!("unknown DNN '{name}'"))?;
            if args.has("advise") {
                // Joint advise view scoped to one model: the search covers
                // the full (chiplets x NoP x NoC) space, so point-fixing
                // flags contradict it.
                for conflicting in ["chiplets", "noc", "nop", "exact", "sim", "surrogate"] {
                    if args.has(conflicting) {
                        bail!(
                            "--advise searches the full (chiplets x NoP x NoC) space; \
                             drop --{conflicting} or drop --advise"
                        );
                    }
                }
                if args.has("heatmap") || args.has("heatmap-out") {
                    bail!("--advise conflicts with --heatmap/--heatmap-out; drop one side");
                }
                let rec = recommend_scaleout(&g, &arch, &base_noc, &base_nop);
                let mut t = Table::new(
                    format!("Scale-out design space for {}", g.name),
                    &["chiplets", "NoP", "NoC", "EDAP_J.ms.mm2"],
                );
                for &(k, nop_topo, noc_topo, edap) in &rec.candidates {
                    t.add_row(vec![
                        k.to_string(),
                        if k == 1 { "-".into() } else { nop_topo.name().into() },
                        noc_topo.name().into(),
                        fmt_sig(edap, 4),
                    ]);
                }
                print_tables(&[t], args.has("csv"));
                print_scaleout_recommendation(&rec, &g.name);
                return Ok(());
            }
            let chiplets = args.get_usize("chiplets", base_nop.chiplets)?;
            NopConfig {
                chiplets,
                ..base_nop.clone()
            }
            .validate()
            .map_err(|e| anyhow!("--chiplets: {e}"))?;
            let noc_topo = match args.get("noc") {
                None => recommend_topology(&g, &arch, &base_noc).topology,
                Some(t) => parse_noc_topology(t)?,
            };
            let noc = NocConfig {
                topology: noc_topo,
                ..base_noc.clone()
            };
            let nop_choices: Vec<NopTopology> = match args.get("nop") {
                None => NopTopology::all().to_vec(),
                Some(t) => vec![parse_nop_topology(t)?],
            };
            let heatmap_out = args.get("heatmap-out");
            let cfg_heatmap = Config::default().telemetry.heatmap;
            let want_heatmap = args.has("heatmap") || heatmap_out.is_some() || cfg_heatmap;
            if heatmap_out.is_some() && nop_choices.len() > 1 {
                bail!("--heatmap-out writes one topology; pin it with --nop <p2p|ring|mesh>");
            }
            let mut cols = vec![
                "NoP",
                "latency_ms",
                "energy_mJ",
                "area_mm2",
                "EDAP_J.ms.mm2",
                "FPS",
                "cross_kbits",
            ];
            if sim_mode {
                // Flit-level co-simulation also measures where each package
                // topology saturates under uniform injection.
                cols.push("sat_rate_flit/chiplet/cyc");
            }
            let mut t = Table::new(
                format!(
                    "{} on {} chiplets ({} IMC, per-chiplet {}{})",
                    g.name,
                    chiplets,
                    arch.tech.name(),
                    noc_topo.name(),
                    match nop_mode {
                        NopMode::Analytical => "",
                        NopMode::Sim => ", NoP flit-level sim",
                        NopMode::Surrogate => ", NoP surrogate",
                    }
                ),
                &cols,
            );
            let mut heatmaps = Vec::new();
            for nop_topo in nop_choices {
                let nop = NopConfig {
                    topology: nop_topo,
                    chiplets,
                    ..base_nop.clone()
                };
                if want_heatmap {
                    heatmaps.push(chiplet_heatmap(&g, &arch, &noc, &nop));
                }
                let e = evaluate_package(&g, &arch, &noc, &nop, &SimConfig::default(), backend);
                let mut row = vec![
                    nop_topo.name().into(),
                    fmt_sig(e.latency_s() * 1e3, 4),
                    fmt_sig(e.energy_j() * 1e3, 4),
                    fmt_sig(e.area_mm2(), 4),
                    fmt_sig(e.edap(), 4),
                    fmt_sig(e.fps(), 4),
                    fmt_sig(e.cross_bits as f64 / 1e3, 4),
                ];
                if sim_mode {
                    let sat = crate::nop::sim::saturation_rate(
                        nop_topo,
                        chiplets,
                        &nop,
                        SimConfig::default().seed,
                    );
                    row.push(match sat {
                        Some(rate) => fmt_sig(rate, 3),
                        None => ">1.0".into(),
                    });
                }
                t.add_row(row);
            }
            print_tables(&[t], args.has("csv"));
            for (text, _) in &heatmaps {
                println!("{text}");
            }
            if let Some(path) = heatmap_out {
                let (_, json) = heatmaps.first().expect("one topology pinned");
                std::fs::write(path, json).map_err(|e| anyhow!("write {path}: {e}"))?;
                log::info!("wrote NoP heatmap JSON to {path}");
            }
            // The joint recommendation sweep evaluates analytically, but
            // under --sim / --surrogate its ranking folds in the measured
            // (NoP, k) saturation rates (see `recommend_scaleout`).
            let rec = recommend_scaleout(&g, &arch, &base_noc, &base_nop);
            print_scaleout_recommendation(&rec, &g.name);
        }
        "serve" => {
            let fast = args.has("fast");
            if args.has("mix") || args.has("trace") {
                // Multi-model serving: a workload mix (or a recorded
                // trace) over one package with per-model replica sets.
                serve_mix_cmd(&args, fast)?;
            } else {
                // Mirror the mix path's strictness: mix-only flags on the
                // single-model/PJRT paths would be silent no-ops.
                for mix_only in ["record-trace", "placement", "admission", "arrival"] {
                    if args.has(mix_only) {
                        bail!("--{mix_only} requires --mix (or --trace)");
                    }
                }
                let model_flag = args.get("model").map(str::to_string).or_else(|| {
                    // `repro serve --fast` alone is the CI smoke run: the
                    // modeled path with its default small configuration.
                    (fast && args.positional.get(1).is_none()).then(|| "SqueezeNet".to_string())
                });
                if let Some(name) = model_flag {
                    serve_modeled_cmd(&args, &name, fast)?;
                } else {
                    serve_pjrt_cmd(&args)?;
                }
            }
        }
        "config" => {
            if let Some(path) = args.get("load") {
                let cfg = Config::from_file(path).map_err(|e| anyhow!(e))?;
                println!("{}", cfg.to_ini());
            } else {
                println!("{}", Config::default().to_ini());
            }
        }
        "sweep" => {
            // Parallel sweep over the whole zoo x {tree, mesh} x tech via
            // the coordinator driver (demonstrates the parallel runtime).
            let tech = match args.get("tech") {
                None => MemTech::Reram,
                Some(t) => MemTech::parse(t).ok_or_else(|| anyhow!("bad --tech '{t}'"))?,
            };
            let backend = if args.has("exact") {
                CommBackend::Simulate
            } else {
                CommBackend::Analytical
            };
            let points: Vec<_> = crate::dnn::model_zoo()
                .iter()
                .flat_map(|g| {
                    [Topology::Tree, Topology::Mesh].into_iter().map(|t| {
                        (
                            g.name.clone(),
                            ArchConfig { tech, ..ArchConfig::default() },
                            NocConfig::with_topology(t),
                            backend,
                        )
                    })
                })
                .collect();
            let driver = crate::coordinator::Driver::new();
            let results = driver.evaluate_many(&points).map_err(|e| anyhow!(e))?;
            let mut t = Table::new(
                format!("Sweep: zoo x {{tree, mesh}} on {} IMC", tech.name()),
                &["dnn", "topology", "latency_ms", "FPS", "EDAP"],
            );
            for r in &results {
                t.add_row(vec![
                    r.dnn.clone(),
                    r.topology.name().into(),
                    fmt_sig(r.latency_s() * 1e3, 4),
                    fmt_sig(r.fps(), 4),
                    fmt_sig(r.edap(), 3),
                ]);
            }
            print_tables(&[t], args.has("csv"));
        }
        "list" => {
            for exp in registry() {
                println!("{:8} {}", exp.id, exp.title);
            }
        }
        "help" | "--help" | "-h" => {
            println!("{}", usage());
        }
        other => bail!("unknown command '{other}'\n{}", usage()),
    }
    if args.has("profile") {
        // Self-profiling dump: memo-cache hit rates, engine event counts
        // and wall-clock phase timers accumulated during this invocation.
        print!("{}", profile::text());
    }
    Ok(())
}

/// The modeled serving path: route synthetic requests over a chiplet
/// package with the scheduler of [`crate::coordinator::scheduler`] and
/// report per-chiplet queue utilization plus modeled p50/p99.
fn serve_modeled_cmd(args: &Args, name: &str, fast: bool) -> Result<()> {
    let g = by_name(name).ok_or_else(|| anyhow!("unknown DNN '{name}'"))?;
    let defaults = ServingConfig::default();
    let chiplets = args.get_usize("chiplets", 4)?;
    let topo = match args.get("topology") {
        None => NopTopology::Mesh,
        Some(t) => parse_nop_topology(t)?,
    };
    let policy = match args.get("policy") {
        None => defaults.policy,
        Some(p) => Policy::parse(p)
            .ok_or_else(|| anyhow!("unknown policy '{p}' (valid: {})", Policy::valid_names()))?,
    };
    let mut requests = args.get_usize("requests", defaults.requests)?;
    if fast {
        requests = requests.min(96);
    }
    let cfg = ServingConfig {
        policy,
        queue_depth: args.get_usize("queue-depth", defaults.queue_depth)?,
        arrival_rps: args.get_f64("rate", defaults.arrival_rps)?,
        requests,
        batch: args.get_usize("batch", defaults.batch)?,
        seed: args.get_usize("seed", defaults.seed as usize)? as u64,
    };
    cfg.validate().map_err(|e| anyhow!("serving config: {e}"))?;
    let nop = NopConfig {
        topology: topo,
        chiplets,
        mode: nop_mode_from(args)?,
        ..NopConfig::default()
    };
    nop.validate().map_err(|e| anyhow!("--chiplets: {e}"))?;
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    let sim = SimConfig::default();
    let window_ms = args.get_f64("metrics-window-ms", Config::default().telemetry.window_ms)?;
    let (model, report, spans, traces, ts) = {
        let _t = profile::phase("serve.modeled");
        serve_modeled_metrics(&g, &arch, &noc, &nop, &sim, &cfg, window_ms)
    };

    let mut t = Table::new(
        format!(
            "{} serving on {} chiplet(s) (NoP-{}, {} policy)",
            g.name,
            chiplets,
            topo.name(),
            policy.name()
        ),
        &["chiplet", "served", "utilization", "peak_queue"],
    );
    for s in &report.per_chiplet {
        t.add_row(vec![
            s.chiplet.to_string(),
            s.served.to_string(),
            fmt_sig(s.utilization, 3),
            s.peak_queue.to_string(),
        ]);
    }
    print_tables(&[t], args.has("csv"));
    let ingress_max = model.ingress_s.iter().copied().fold(0.0f64, f64::max);
    println!(
        "served {}/{} requests ({} dropped) in {} batches of <= {}: modeled p50 {:.3} ms, p99 {:.3} ms, {:.1} req/s (offered {:.1})",
        report.completed,
        report.requests,
        report.dropped,
        report.batches,
        report.batch_size,
        report.p50_ms,
        report.p99_ms,
        report.throughput_rps,
        report.offered_rps
    );
    println!(
        "model: service {:.3} ms/frame, pipeline stage {:.4} ms, worst ingress {:.4} ms, partitioned alternative {:.3} ms, sat-link util {:.2}",
        model.service_s * 1e3,
        model.stage_s * 1e3,
        ingress_max * 1e3,
        model.partitioned_latency_s * 1e3,
        model.sat_link_util
    );
    println!(
        "lifecycle breakdown (completed means): ingress {:.4} + queue {:.4} + service {:.4} = {:.4} ms",
        report.mean_ingress_ms,
        report.mean_queue_ms,
        report.mean_service_ms,
        report.mean_ms
    );
    if let Some(path) = trace_out_path(args) {
        write_trace(&path, &spans, &[g.name.as_str()], &report, &ts)?;
    }
    write_metrics_if_requested(args, &ts, &report)?;
    write_explain_if_requested(
        args,
        &spans,
        &traces,
        &[g.name.clone()],
        &[f64::INFINITY],
        &model.layer_blame,
    )?;
    serve_heatmap(args, topo, chiplets, &ts)?;
    Ok(())
}

/// `repro serve … --explain[-out f]`: extract each request's critical
/// path from its causal ingress trace + lifecycle span, aggregate into
/// the ranked blame report, print the text table and (with
/// `--explain-out`) write the byte-deterministic JSON artifact.
fn write_explain_if_requested(
    args: &Args,
    spans: &[RequestSpan],
    traces: &[IngressTrace],
    names: &[String],
    deadlines: &[f64],
    layers: &[LayerBlame],
) -> Result<()> {
    if !args.has("explain") && !args.has("explain-out") {
        return Ok(());
    }
    let report = BlameReport::build(spans, traces, names, deadlines, layers);
    println!("{}", report.to_text());
    if let Some(path) = args.get("explain-out") {
        std::fs::write(path, report.to_json()).map_err(|e| anyhow!("write {path}: {e}"))?;
        log::info!("wrote critical-path blame report to {path}");
    }
    Ok(())
}

/// `--trace-out` path, falling back to the `[telemetry] trace_out`
/// config default (empty = no trace).
fn trace_out_path(args: &Args) -> Option<String> {
    args.get("trace-out").map(str::to_string).or_else(|| {
        let t = Config::default().telemetry.trace_out;
        (!t.is_empty()).then_some(t)
    })
}

/// `--metrics-out` path, falling back to the `[telemetry] metrics_out`
/// config default (empty = no metrics file).
fn metrics_out_path(args: &Args) -> Option<String> {
    args.get("metrics-out").map(str::to_string).or_else(|| {
        let m = Config::default().telemetry.metrics_out;
        (!m.is_empty()).then_some(m)
    })
}

/// Export the windowed serving metrics when `--metrics-out` (or the
/// config default) names a file: deterministic JSON by default,
/// Prometheus text exposition with `--metrics-format prom`.
fn write_metrics_if_requested(args: &Args, ts: &TimeSeries, report: &ServeReport) -> Result<()> {
    let Some(path) = metrics_out_path(args) else {
        if args.has("metrics-format") {
            bail!("--metrics-format requires --metrics-out (or [telemetry] metrics_out)");
        }
        return Ok(());
    };
    let text = match args.get("metrics-format").unwrap_or("json") {
        "json" => ts.to_json(report.requests, report.completed, report.dropped, report.shed),
        "prom" | "prometheus" => {
            ts.to_prom(report.requests, report.completed, report.dropped, report.shed)
        }
        other => bail!("unknown --metrics-format '{other}' (valid: json, prom)"),
    };
    std::fs::write(&path, text).map_err(|e| anyhow!("write {path}: {e}"))?;
    log::info!(
        "wrote {} metric window(s), {} drift event(s) to {path}",
        ts.windows().len(),
        ts.drift_events().len()
    );
    Ok(())
}

/// `repro serve … --heatmap[-out f]`: render the end-of-run NoP link
/// heatmap from the time series' cumulative per-link busy seconds (the
/// serving counterpart of `repro chiplet --heatmap`).
fn serve_heatmap(
    args: &Args,
    topology: NopTopology,
    chiplets: usize,
    ts: &TimeSeries,
) -> Result<()> {
    let heatmap_out = args.get("heatmap-out");
    if !args.has("heatmap") && heatmap_out.is_none() {
        return Ok(());
    }
    let net = NopNetwork::build(topology, chiplets);
    let telem = ts.to_sim_telemetry();
    println!("{}", heatmap_text(&net, &telem));
    if let Some(path) = heatmap_out {
        std::fs::write(path, heatmap_json(&net, &telem))
            .map_err(|e| anyhow!("write {path}: {e}"))?;
        log::info!("wrote NoP heatmap JSON to {path}");
    }
    Ok(())
}

/// Write serving spans as Chrome trace-event JSON (Perfetto-loadable),
/// stamped with the offered-request total so downstream checkers can
/// reconcile the trace against the report, plus the time series'
/// counter tracks (cumulative totals, queue depth, per-link NoP
/// utilization) so Perfetto shows windowed load next to the slices.
fn write_trace(
    path: &str,
    spans: &[RequestSpan],
    names: &[&str],
    report: &ServeReport,
    ts: &TimeSeries,
) -> Result<()> {
    let mut tr = spans_to_trace(spans, names);
    ts.counter_tracks(&mut tr);
    tr.set_meta("requests", report.requests as u64);
    tr.set_meta("completed", report.completed as u64);
    tr.set_meta("dropped", report.dropped as u64);
    tr.set_meta("shed", report.shed as u64);
    std::fs::write(path, tr.to_json()).map_err(|e| anyhow!("write {path}: {e}"))?;
    log::info!("wrote {} trace events to {path}", tr.len());
    Ok(())
}

/// Drain the model's aggregated package flows through an instrumented
/// flit-level NoP simulation and render the link heatmap (text + JSON).
fn chiplet_heatmap(
    g: &DnnGraph,
    arch: &ArchConfig,
    noc: &NocConfig,
    nop: &NopConfig,
) -> (String, String) {
    let flows = package_flows(g, arch, noc, nop);
    let total: u64 = flows.iter().map(|f| f.flits).sum();
    if total == 0 {
        log::warn!(
            "{} has no cross-chiplet traffic on {} chiplet(s); heatmap is empty",
            g.name,
            nop.chiplets
        );
    }
    // Same generous drain budget as the evaluator's sim mode: full
    // serialization over the worst route still fits.
    let slack = total
        .saturating_mul(4)
        .saturating_mul(nop.hop_latency_cycles + 2);
    let (_, telem) = NopSim::new(
        nop.topology,
        nop.chiplets,
        nop,
        &flows,
        Mode::Drain {
            max_cycles: 10_000 + slack,
        },
        SimConfig::default().seed,
    )
    .instrument(true)
    .run_instrumented();
    let net = NopNetwork::build(nop.topology, nop.chiplets);
    (heatmap_text(&net, &telem), heatmap_json(&net, &telem))
}

/// The multi-model serving path (`repro serve --mix [spec]` /
/// `repro serve --trace <file>`): a workload mix over one package, with
/// per-model replica placement, deadline-aware admission, and optional
/// trace record/replay.
fn serve_mix_cmd(args: &Args, fast: bool) -> Result<()> {
    // Flags that take a file must actually carry one: a bare `--trace`
    // would otherwise silently fall through to generating a fresh
    // workload, and a bare `--record-trace` would record nothing.
    for file_flag in ["trace", "record-trace"] {
        if args.has(file_flag) && args.get(file_flag).is_none() {
            bail!("--{file_flag} requires a file path");
        }
    }
    // Single-model flags are meaningless here; reject rather than ignore.
    if args.has("model") {
        bail!("--model conflicts with --mix/--trace (name models in the mix spec instead)");
    }
    if args.has("batch") {
        bail!("--batch has no effect on the mix path (request frame counts come from the arrival process; see [workload] frames_alpha)");
    }
    let config = Config::default();
    let mut wl: WorkloadConfig = config.workload.clone();
    if let Some(spec) = args.get("mix") {
        wl.mix = WorkloadMix::parse(spec).map_err(|e| anyhow!(e))?;
    }
    if let Some(p) = args.get("placement") {
        wl.placement = PlacementPolicy::parse(p).ok_or_else(|| {
            anyhow!(
                "unknown placement '{p}' (valid: {})",
                PlacementPolicy::valid_names()
            )
        })?;
    }
    if let Some(a) = args.get("admission") {
        wl.admission = Admission::parse(a).ok_or_else(|| {
            anyhow!("unknown admission '{a}' (valid: {})", Admission::valid_names())
        })?;
    }
    if let Some(a) = args.get("arrival") {
        wl.arrival = ArrivalKind::parse(a).ok_or_else(|| {
            anyhow!("unknown arrival '{a}' (valid: {})", ArrivalKind::valid_names())
        })?;
    }
    let chiplets = args.get_usize("chiplets", 8)?;
    let topo = match args.get("topology") {
        None => NopTopology::Mesh,
        Some(t) => parse_nop_topology(t)?,
    };
    let policy = match args.get("policy") {
        None => config.serving.policy,
        Some(p) => Policy::parse(p)
            .ok_or_else(|| anyhow!("unknown policy '{p}' (valid: {})", Policy::valid_names()))?,
    };
    let mut requests = args.get_usize("requests", config.serving.requests)?;
    if fast {
        requests = requests.min(96);
    }
    let serving = ServingConfig {
        policy,
        queue_depth: args.get_usize("queue-depth", config.serving.queue_depth)?,
        arrival_rps: args.get_f64("rate", config.serving.arrival_rps)?,
        requests,
        batch: config.serving.batch,
        seed: args.get_usize("seed", config.serving.seed as usize)? as u64,
    };
    serving.validate().map_err(|e| anyhow!("serving config: {e}"))?;
    let nop = NopConfig {
        topology: topo,
        chiplets,
        // `--sim` / `--surrogate` switch the per-model ingress pricing the
        // mix scheduler ranks replicas by; its link contention is always
        // simulated by the scheduler itself.
        mode: nop_mode_from(args)?,
        ..NopConfig::default()
    };
    nop.validate().map_err(|e| anyhow!("--chiplets: {e}"))?;
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    let sim = SimConfig::default();

    let window_ms = args.get_f64("metrics-window-ms", config.telemetry.window_ms)?;
    let _serve_phase = profile::phase("serve.mix");
    let (model, report, spans, traces, ts) = if let Some(path) = args.get("trace") {
        // Replay: the trace pins the mix, the rate, and every event —
        // reject flags that would silently change nothing (scheduler
        // knobs like --placement/--admission/--policy legitimately vary).
        for conflicting in ["mix", "record-trace", "arrival", "rate", "requests", "seed"] {
            if args.has(conflicting) {
                bail!(
                    "--{conflicting} has no effect when replaying a trace \
                     (the trace pins the workload); drop --{conflicting} or drop --trace"
                );
            }
        }
        let trace = Trace::load(path).map_err(|e| anyhow!(e))?;
        log::info!(
            "replaying {} events ({} models) from {path}",
            trace.events.len(),
            trace.mix.models.len()
        );
        replay_mix_metrics(&trace, &arch, &noc, &nop, &sim, &serving, &wl, window_ms)
            .map_err(|e| anyhow!(e))?
    } else {
        let (model, trace, report, spans, traces, ts) =
            serve_mix_metrics(&arch, &noc, &nop, &sim, &serving, &wl, window_ms)
                .map_err(|e| anyhow!(e))?;
        if let Some(path) = args.get("record-trace") {
            trace.save(path).map_err(|e| anyhow!(e))?;
            log::info!("recorded {} events to {path}", trace.events.len());
        }
        (model, report, spans, traces, ts)
    };
    drop(_serve_phase);
    print_mix_report(&model, &report, args.has("csv"));
    if let Some(path) = trace_out_path(args) {
        let names: Vec<&str> = model.models.iter().map(|m| m.name.as_str()).collect();
        write_trace(&path, &spans, &names, &report, &ts)?;
    }
    write_metrics_if_requested(args, &ts, &report)?;
    let names: Vec<String> = model.models.iter().map(|m| m.name.clone()).collect();
    let deadlines: Vec<f64> = model.models.iter().map(|m| m.deadline_s).collect();
    let layers: Vec<LayerBlame> = model
        .models
        .iter()
        .flat_map(|m| m.layers.iter().cloned())
        .collect();
    write_explain_if_requested(args, &spans, &traces, &names, &deadlines, &layers)?;
    serve_heatmap(args, model.topology, model.chiplets, &ts)?;
    Ok(())
}

/// Per-model table + headline line shared by the mix serve/replay paths.
fn print_mix_report(model: &MixServingModel, report: &ServeReport, csv: bool) {
    let mut t = Table::new(
        format!(
            "Mix serving on {} chiplet(s) (NoP-{}, {} placement, {} requests)",
            model.chiplets,
            model.topology.name(),
            model.placement_policy.name(),
            report.requests,
        ),
        &[
            "model",
            "replicas",
            "deadline_ms",
            "offered",
            "completed",
            "shed",
            "dropped",
            "hit_rate",
            "p50_ms",
            "p99_ms",
            "ingress_ms",
            "queue_ms",
            "service_ms",
        ],
    );
    for (pm, costs) in report.per_model.iter().zip(&model.models) {
        t.add_row(vec![
            pm.model.clone(),
            pm.replicas.to_string(),
            if costs.deadline_s.is_finite() {
                fmt_sig(costs.deadline_s * 1e3, 4)
            } else {
                "-".into()
            },
            pm.offered.to_string(),
            pm.completed.to_string(),
            pm.shed.to_string(),
            pm.dropped.to_string(),
            fmt_sig(pm.hit_rate(), 3),
            fmt_sig(pm.p50_ms, 4),
            fmt_sig(pm.p99_ms, 4),
            fmt_sig(pm.mean_ingress_ms, 3),
            fmt_sig(pm.mean_queue_ms, 3),
            fmt_sig(pm.mean_service_ms, 3),
        ]);
    }
    print_tables(&[t], csv);
    println!(
        "deadline hit-rate {:.3}: {}/{} requests completed ({} shed, {} dropped) at {:.1} req/s offered, {:.1} served",
        report.hit_rate(),
        report.completed,
        report.requests,
        report.shed,
        report.dropped,
        report.offered_rps,
        report.throughput_rps,
    );
    println!(
        "lifecycle breakdown (completed means): ingress {:.3} + queue {:.3} + service {:.3} = {:.3} ms",
        report.mean_ingress_ms,
        report.mean_queue_ms,
        report.mean_service_ms,
        report.mean_ms,
    );
}

/// The PJRT-measured serving path (`repro serve <artifact.hlo.txt>`).
fn serve_pjrt_cmd(args: &Args) -> Result<()> {
    let artifact = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: repro serve <artifact> | repro serve --model <dnn>"))?;
    let requests = args.get_usize("requests", 256)?;
    let batch = args.get_usize("batch", 8)?;
    let in_dim = args.get_usize("in-dim", 784)?;
    let mut server = InferenceServer::new(batch)?;
    log::info!("platform: {}", server.platform());
    let reqs = synthetic_requests(requests, in_dim, 42);
    let report = server.serve(artifact, &reqs, in_dim)?;
    println!(
        "served {} requests in {} batches of {}: mean {:.3} ms/batch, p50 {:.3}, p99 {:.3}, {:.1} req/s",
        report.requests,
        report.batches,
        report.batch_size,
        report.mean_ms,
        report.p50_ms,
        report.p99_ms,
        report.throughput_rps
    );
    Ok(())
}

fn usage() -> &'static str {
    "imcnoc repro — interconnect-aware IMC accelerator study (JETC'21 reproduction)

USAGE:
  repro figure <id> [--exact] [--fast] [--csv] [--seed N]   regenerate a paper figure
  repro table <id>  [--exact] [--fast] [--csv]              regenerate a paper table
  repro all [--fast] [--csv]                                run every experiment
  repro eval <dnn> [--tech sram|reram] [--topology ...]     evaluate one design point
  repro advise <dnn>                                        optimal-topology advisor
  repro chiplet --model <dnn> [--chiplets N] [--noc t]      multi-chiplet NoC+NoP evaluation
               [--nop p2p|ring|mesh] [--exact]              (all NoP topologies by default)
               [--sim | --surrogate]                        package leg: flit sim / fitted
               [--heatmap] [--heatmap-out f]                surrogate; NoP link heatmaps
  repro chiplet --surrogate-check-out <f> [--fast] [--seed N]  sim-vs-surrogate validation
                                                            JSON (gated in CI)
  repro chiplet --advise [--model <dnn>]                    joint (chiplets, NoP, NoC)
                                                            recommendation: whole zoo, or the
                                                            full design space of one model
  repro serve <artifact> [--requests N] [--batch N]         serve inference via PJRT
  repro serve --model <dnn> [--chiplets N] [--topology t]   modeled chiplet-aware serving:
              [--policy round-robin|least-latency|          per-chiplet queues, NoP-priced
               congestion-aware] [--rate RPS] [--batch N]   routing, modeled p50/p99
              [--queue-depth N] [--requests N] [--seed N]   (--fast: small smoke config)
              [--sim | --surrogate] [--trace-out f]
              [--metrics-out f]
              [--explain] [--explain-out f]
              [--heatmap] [--heatmap-out f]
  repro serve --mix [name[:weight[:deadline_ms]],...]       multi-model serving: replica
              [--placement round-robin|nop-aware]           placement per model, deadline
              [--admission drop-on-full|deadline-aware]     hit-rate headline, shed/drop
              [--arrival poisson|bursty|diurnal]            accounting (deadline 0 = auto,
              [--record-trace f] [--chiplets N] [--seed N]  inf = none; default mix
              [--topology t] [--rate RPS] [--requests N]    VGG-19 + SqueezeNet)
              [--sim | --surrogate]
              [--trace-out f] [--metrics-out f]
              [--explain] [--explain-out f]
              [--heatmap] [--heatmap-out f]
  repro serve --trace <file> [--placement p] [--admission a] replay a recorded trace
                                                            bit-exactly
  repro sweep [--tech sram|reram] [--exact]                 parallel zoo sweep
  repro config [--load path]                                show/parse configuration
  repro list                                                list experiments

FLAGS:
  --exact   use the cycle-accurate NoC simulator (default: analytical model)
  --sim     chiplet/serve: price the package leg through the flit-level
            NoP co-simulation (chiplet also reports per-topology
            saturation rates)
  --surrogate  chiplet/serve: price the package leg from sim-anchored
            fitted curves — sim-level fidelity at near-analytical cost
            (falls back to the full simulator where the fit refuses)
  --surrogate-check-out <f>  chiplet: fit the surrogate over a
            (topology, k) grid, grade it against held-out simulator
            runs and write the comparison JSON
  --fast    restrict sweeps to the small-DNN subset
  --csv     emit CSV instead of ASCII tables
  --verbose debug-level logging (REPRO_LOG=warn|info|debug sets the default)
  --trace-out <f>    serve: write request lifecycle spans + windowed
            counter tracks as Chrome trace-event JSON (load in
            Perfetto / chrome://tracing)
  --metrics-out <f>  serve: write windowed serving metrics (per-window
            arrivals/completions/drops/sheds, queue depth, per-model
            p50/p99, NoP link utilization, drift events);
            --metrics-format json (default, byte-deterministic) or prom
  --metrics-window-ms <w>  serve: metrics window width (default 0 =
            auto: run horizon / 32; also [telemetry] window_ms)
  --explain[-out f]  serve: per-request critical-path attribution —
            ranked blame report (links / chiplets / models / layers by
            critical-path ms, deadline-miss attribution); --explain-out
            writes the byte-deterministic JSON artifact
  --profile any command: dump simulator self-profiling counters at exit
            (memo-cache hit rates, engine events simulated, wall-clock
            phase timers; timings vary run to run, counters do not)
  --heatmap[-out f]  chiplet/serve: per-link NoP utilization heatmap
            (text/JSON); serve renders the end-of-run serving traffic"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_positionals_and_flags() {
        let argv: Vec<String> = ["figure", "16", "--fast", "--seed", "7", "--csv"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&argv);
        assert_eq!(a.positional, vec!["figure", "16"]);
        assert!(a.has("fast"));
        assert!(a.has("csv"));
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get_usize("seed", 0).unwrap(), 7);
    }

    #[test]
    fn boolean_flag_does_not_eat_positional() {
        let argv: Vec<String> = ["figure", "--fast", "16"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&argv);
        assert_eq!(a.positional, vec!["figure", "16"]);
    }

    #[test]
    fn run_list_and_config() {
        run(&["list".to_string()]).unwrap();
        run(&["config".to_string()]).unwrap();
    }

    #[test]
    fn run_unknown_command_errors() {
        assert!(run(&["frobnicate".to_string()]).is_err());
    }

    #[test]
    fn run_small_figure() {
        run(&["figure".into(), "1".into()]).unwrap();
        run(&["advise".into(), "MLP".into()]).unwrap();
    }

    #[test]
    fn run_chiplet_eval() {
        run(&[
            "chiplet".into(),
            "--model".into(),
            "lenet5".into(),
            "--chiplets".into(),
            "2".into(),
        ])
        .unwrap();
        run(&[
            "chiplet".into(),
            "--model".into(),
            "MLP".into(),
            "--nop".into(),
            "ring".into(),
        ])
        .unwrap();
        // --advise scoped to one model prints its design-space slice.
        run(&[
            "chiplet".into(),
            "--model".into(),
            "MLP".into(),
            "--advise".into(),
        ])
        .unwrap();
        // Flit-level NoP co-simulation with saturation reporting.
        run(&[
            "chiplet".into(),
            "--model".into(),
            "lenet5".into(),
            "--chiplets".into(),
            "2".into(),
            "--sim".into(),
        ])
        .unwrap();
        // Surrogate-priced package leg: same view, fitted-curve pricing.
        run(&[
            "chiplet".into(),
            "--model".into(),
            "lenet5".into(),
            "--chiplets".into(),
            "2".into(),
            "--surrogate".into(),
        ])
        .unwrap();
        // --sim contradicts the (analytical) design-space search.
        assert!(run(&[
            "chiplet".into(),
            "--model".into(),
            "MLP".into(),
            "--advise".into(),
            "--sim".into(),
        ])
        .is_err());
        // The two NoP pricing modes are mutually exclusive.
        let err = run(&[
            "chiplet".into(),
            "--model".into(),
            "MLP".into(),
            "--sim".into(),
            "--surrogate".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("mutually exclusive"), "{err}");
        assert!(run(&["chiplet".into()]).is_err()); // needs --model or --advise
        // Out-of-range chiplet counts error cleanly instead of panicking.
        assert!(run(&[
            "chiplet".into(),
            "--model".into(),
            "MLP".into(),
            "--chiplets".into(),
            "0".into(),
        ])
        .is_err());
    }

    #[test]
    fn run_serve_modeled() {
        // The CI smoke configuration: SqueezeNet, 4 chiplets, mesh,
        // congestion-aware — all defaults under --fast.
        run(&["serve".into(), "--fast".into()]).unwrap();
        // Explicit flags, small request count to stay quick.
        run(&[
            "serve".into(),
            "--model".into(),
            "MLP".into(),
            "--chiplets".into(),
            "2".into(),
            "--topology".into(),
            "ring".into(),
            "--policy".into(),
            "round-robin".into(),
            "--requests".into(),
            "64".into(),
            "--batch".into(),
            "1".into(),
        ])
        .unwrap();
        // Bad policy / topology / chiplet count error cleanly.
        let err = run(&[
            "serve".into(),
            "--model".into(),
            "MLP".into(),
            "--policy".into(),
            "fifo".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("least-latency"), "{err}");
        assert!(run(&[
            "serve".into(),
            "--model".into(),
            "MLP".into(),
            "--topology".into(),
            "torus".into(),
        ])
        .is_err());
        assert!(run(&[
            "serve".into(),
            "--model".into(),
            "MLP".into(),
            "--chiplets".into(),
            "0".into(),
        ])
        .is_err());
        assert!(run(&["serve".into(), "--model".into(), "NoSuchNet".into()]).is_err());
    }

    #[test]
    fn run_serve_mix() {
        // Explicit spec + knobs on a cheap two-model mix (the default
        // VGG-19 + SqueezeNet smoke configuration is exercised by the CLI
        // integration test and the CI `serve --mix --fast` step).
        run(&[
            "serve".into(),
            "--mix".into(),
            "MLP:1:0,LeNet-5:2:0".into(),
            "--chiplets".into(),
            "4".into(),
            "--topology".into(),
            "ring".into(),
            "--placement".into(),
            "round-robin".into(),
            "--admission".into(),
            "drop-on-full".into(),
            "--arrival".into(),
            "bursty".into(),
            "--requests".into(),
            "48".into(),
            "--seed".into(),
            "9".into(),
        ])
        .unwrap();
        // Bad mix / placement / admission / arrival error cleanly.
        assert!(run(&["serve".into(), "--mix".into(), "NoSuchNet:1:0".into()]).is_err());
        let err = run(&[
            "serve".into(),
            "--mix".into(),
            "--placement".into(),
            "magic".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("nop-aware"), "{err}");
        assert!(run(&[
            "serve".into(),
            "--mix".into(),
            "--admission".into(),
            "never".into(),
        ])
        .is_err());
        assert!(run(&[
            "serve".into(),
            "--mix".into(),
            "--arrival".into(),
            "chaotic".into(),
        ])
        .is_err());
        // A 1-chiplet package cannot host a two-model mix.
        assert!(run(&[
            "serve".into(),
            "--mix".into(),
            "--chiplets".into(),
            "1".into(),
        ])
        .is_err());
        // The mix path accepts both non-analytical ingress pricing modes
        // but rejects combining them.
        run(&[
            "serve".into(),
            "--mix".into(),
            "--fast".into(),
            "--surrogate".into(),
        ])
        .unwrap();
        let err = run(&[
            "serve".into(),
            "--mix".into(),
            "--sim".into(),
            "--surrogate".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("mutually exclusive"), "{err}");
        // And mix-only flags are rejected on the single-model path.
        let err = run(&[
            "serve".into(),
            "--model".into(),
            "MLP".into(),
            "--placement".into(),
            "nop-aware".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("--mix"), "{err}");
        // A bare --trace (no file) errors instead of silently generating
        // a fresh workload.
        assert!(run(&["serve".into(), "--trace".into()]).is_err());
    }

    #[test]
    fn run_serve_trace_out_writes_chrome_trace() {
        let path = std::env::temp_dir().join("imcnoc_cli_serve_trace.json");
        let path = path.to_str().unwrap().to_string();
        run(&[
            "serve".into(),
            "--fast".into(),
            "--trace-out".into(),
            path.clone(),
        ])
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"traceEvents\""), "{text}");
        assert!(text.contains("\"completed\""), "{text}");
        assert!(text.contains("\"requests\""), "{text}");
        // The mix path exports traces too.
        let mix_path = std::env::temp_dir().join("imcnoc_cli_mix_trace.json");
        let mix_path = mix_path.to_str().unwrap().to_string();
        run(&[
            "serve".into(),
            "--mix".into(),
            "MLP:1:0,LeNet-5:1:0".into(),
            "--chiplets".into(),
            "2".into(),
            "--requests".into(),
            "32".into(),
            "--trace-out".into(),
            mix_path.clone(),
        ])
        .unwrap();
        let text = std::fs::read_to_string(&mix_path).unwrap();
        assert!(text.contains("\"displayTimeUnit\""), "{text}");
        assert!(text.contains("MLP"), "{text}");
    }

    #[test]
    fn run_serve_explain_out_writes_blame_report() {
        let path = std::env::temp_dir().join("imcnoc_cli_serve_explain.json");
        let path = path.to_str().unwrap().to_string();
        run(&[
            "serve".into(),
            "--fast".into(),
            "--explain-out".into(),
            path.clone(),
        ])
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("imcnoc-explain-v1"), "{text}");
        assert!(text.contains("\"links\""), "{text}");
        assert!(text.contains("\"layers\""), "{text}");
        // Same seed → byte-identical artifact.
        let path2 = std::env::temp_dir().join("imcnoc_cli_serve_explain2.json");
        let path2 = path2.to_str().unwrap().to_string();
        run(&[
            "serve".into(),
            "--fast".into(),
            "--explain-out".into(),
            path2.clone(),
        ])
        .unwrap();
        assert_eq!(text, std::fs::read_to_string(&path2).unwrap());
        // The mix path explains too (text table only, no file).
        run(&[
            "serve".into(),
            "--mix".into(),
            "MLP:1:0,LeNet-5:1:0".into(),
            "--chiplets".into(),
            "2".into(),
            "--requests".into(),
            "32".into(),
            "--explain".into(),
        ])
        .unwrap();
    }

    #[test]
    fn run_with_profile_dumps_counters() {
        // --profile composes with any command; the dump itself goes to
        // stdout, so here we just pin that the flag is accepted.
        run(&["serve".into(), "--fast".into(), "--profile".into()]).unwrap();
    }

    #[test]
    fn run_serve_metrics_out_writes_windows() {
        let path = std::env::temp_dir().join("imcnoc_cli_serve_metrics.json");
        let path = path.to_str().unwrap().to_string();
        run(&[
            "serve".into(),
            "--fast".into(),
            "--metrics-out".into(),
            path.clone(),
        ])
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"windows\""), "{text}");
        assert!(text.contains("\"totals\""), "{text}");
        assert!(text.contains("\"drift_events\""), "{text}");
        // Prometheus text exposition.
        let prom = std::env::temp_dir().join("imcnoc_cli_serve_metrics.prom");
        let prom = prom.to_str().unwrap().to_string();
        run(&[
            "serve".into(),
            "--fast".into(),
            "--metrics-out".into(),
            prom.clone(),
            "--metrics-format".into(),
            "prom".into(),
        ])
        .unwrap();
        let text = std::fs::read_to_string(&prom).unwrap();
        assert!(text.contains("imcnoc_requests_total"), "{text}");
        // The mix path exports metrics too.
        let mix = std::env::temp_dir().join("imcnoc_cli_mix_metrics.json");
        let mix = mix.to_str().unwrap().to_string();
        run(&[
            "serve".into(),
            "--mix".into(),
            "MLP:1:0,LeNet-5:1:0".into(),
            "--chiplets".into(),
            "2".into(),
            "--requests".into(),
            "32".into(),
            "--metrics-out".into(),
            mix.clone(),
        ])
        .unwrap();
        let text = std::fs::read_to_string(&mix).unwrap();
        assert!(text.contains("\"models\""), "{text}");
        assert!(text.contains("MLP"), "{text}");
        // Bad format / orphaned --metrics-format error cleanly.
        let err = run(&[
            "serve".into(),
            "--fast".into(),
            "--metrics-out".into(),
            path.clone(),
            "--metrics-format".into(),
            "yaml".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("json, prom"), "{err}");
        let err = run(&[
            "serve".into(),
            "--fast".into(),
            "--metrics-format".into(),
            "prom".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("--metrics-out"), "{err}");
    }

    #[test]
    fn run_serve_heatmap_renders_serving_traffic() {
        run(&[
            "serve".into(),
            "--fast".into(),
            "--heatmap".into(),
        ])
        .unwrap();
        let path = std::env::temp_dir().join("imcnoc_cli_serve_heatmap.json");
        let path = path.to_str().unwrap().to_string();
        run(&[
            "serve".into(),
            "--mix".into(),
            "MLP:1:0,LeNet-5:1:0".into(),
            "--chiplets".into(),
            "2".into(),
            "--requests".into(),
            "32".into(),
            "--heatmap-out".into(),
            path.clone(),
        ])
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"links\""), "{text}");
    }

    #[test]
    fn run_chiplet_heatmap() {
        run(&[
            "chiplet".into(),
            "--model".into(),
            "MLP".into(),
            "--chiplets".into(),
            "2".into(),
            "--nop".into(),
            "ring".into(),
            "--heatmap".into(),
        ])
        .unwrap();
        let path = std::env::temp_dir().join("imcnoc_cli_heatmap.json");
        let path = path.to_str().unwrap().to_string();
        run(&[
            "chiplet".into(),
            "--model".into(),
            "lenet5".into(),
            "--chiplets".into(),
            "2".into(),
            "--nop".into(),
            "mesh".into(),
            "--heatmap-out".into(),
            path.clone(),
        ])
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"links\""), "{text}");
        // JSON export needs one pinned topology, not the default sweep.
        assert!(run(&[
            "chiplet".into(),
            "--model".into(),
            "MLP".into(),
            "--heatmap-out".into(),
            "/tmp/imcnoc_ambiguous.json".into(),
        ])
        .is_err());
        // --advise contradicts the single-point heatmap view.
        assert!(run(&[
            "chiplet".into(),
            "--model".into(),
            "MLP".into(),
            "--advise".into(),
            "--heatmap".into(),
        ])
        .is_err());
    }

    #[test]
    fn run_serve_mix_record_and_replay() {
        let path = std::env::temp_dir().join("imcnoc_cli_mix.trace");
        let path = path.to_str().unwrap().to_string();
        run(&[
            "serve".into(),
            "--mix".into(),
            "MLP:1:0,LeNet-5:1:0".into(),
            "--chiplets".into(),
            "2".into(),
            "--topology".into(),
            "ring".into(),
            "--requests".into(),
            "40".into(),
            "--record-trace".into(),
            path.clone(),
        ])
        .unwrap();
        run(&[
            "serve".into(),
            "--trace".into(),
            path,
            "--chiplets".into(),
            "2".into(),
            "--topology".into(),
            "ring".into(),
        ])
        .unwrap();
        assert!(run(&["serve".into(), "--trace".into(), "/nonexistent.trace".into()]).is_err());
        // Workload-shaping flags conflict with replay (the trace pins
        // the workload).
        let err = run(&[
            "serve".into(),
            "--trace".into(),
            "/nonexistent.trace".into(),
            "--requests".into(),
            "10".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("no effect"), "{err}");
    }

    #[test]
    fn topology_errors_list_valid_names() {
        let err = run(&[
            "eval".into(),
            "MLP".into(),
            "--topology".into(),
            "star".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("hypercube"), "{err}");
        let err = run(&[
            "chiplet".into(),
            "--model".into(),
            "MLP".into(),
            "--nop".into(),
            "star".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("ring"), "{err}");
    }
}
