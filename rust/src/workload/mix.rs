//! Workload mixes: named DNNs with arrival weights and latency deadlines.

/// One model of a serving mix.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    /// Zoo DNN name, resolved via [`crate::dnn::by_name`] when the mix is
    /// priced (so a `WorkloadMix` can be parsed without touching the zoo).
    pub model: String,
    /// Relative arrival-rate weight: this model's share of the mix's
    /// traffic is `weight / Σ weights`.
    pub weight: f64,
    /// Latency deadline in ms. `0` = auto (a fixed multiple of the modeled
    /// replica service time, see
    /// [`crate::coordinator::mix::DEADLINE_AUTO_FACTOR`]); `inf` = no
    /// deadline.
    pub deadline_ms: f64,
}

/// A mix of named DNNs served concurrently on one package.
///
/// Text form (the `[workload] mix` config key and `repro serve --mix`):
/// comma-separated `name[:weight[:deadline_ms]]` entries, e.g.
/// `"VGG-19:1:0,SqueezeNet:1:0"`. Weight defaults to 1, deadline to 0
/// (auto).
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadMix {
    /// The mix entries, in spec order.
    pub models: Vec<ModelSpec>,
}

impl WorkloadMix {
    /// Parse the `name[:weight[:deadline_ms]],...` spec form.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut models = Vec::new();
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let mut fields = entry.split(':');
            let name = fields.next().unwrap_or("").trim();
            if name.is_empty() {
                return Err(format!("empty model name in mix entry '{entry}'"));
            }
            let weight = match fields.next() {
                None => 1.0,
                Some(w) => w
                    .trim()
                    .parse::<f64>()
                    .map_err(|_| format!("bad weight '{w}' in mix entry '{entry}'"))?,
            };
            let deadline_ms = match fields.next() {
                None => 0.0,
                Some(d) => d
                    .trim()
                    .parse::<f64>()
                    .map_err(|_| format!("bad deadline '{d}' in mix entry '{entry}'"))?,
            };
            if fields.next().is_some() {
                return Err(format!(
                    "mix entry '{entry}' has too many fields (want name[:weight[:deadline_ms]])"
                ));
            }
            models.push(ModelSpec {
                model: name.to_string(),
                weight,
                deadline_ms,
            });
        }
        let mix = Self { models };
        mix.validate()?;
        Ok(mix)
    }

    /// The default two-model mix the paper's contrast suggests: one dense
    /// network (NoC-mesh territory) and one compact one (NoC-tree
    /// territory), equal traffic shares, auto deadlines.
    pub fn default_mix() -> Self {
        Self {
            models: vec![
                ModelSpec {
                    model: "VGG-19".to_string(),
                    weight: 1.0,
                    deadline_ms: 0.0,
                },
                ModelSpec {
                    model: "SqueezeNet".to_string(),
                    weight: 1.0,
                    deadline_ms: 0.0,
                },
            ],
        }
    }

    /// Serialize back to the spec form (`parse` round-trips it).
    pub fn spec_string(&self) -> String {
        self.models
            .iter()
            .map(|m| format!("{}:{}:{}", m.model, m.weight, m.deadline_ms))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Structural validation (zoo-name resolution happens at pricing time).
    pub fn validate(&self) -> Result<(), String> {
        if self.models.is_empty() {
            return Err("workload mix must name at least one model".into());
        }
        if self.models.len() > 16 {
            return Err("workload mix is limited to 16 models".into());
        }
        for m in &self.models {
            if !(m.weight.is_finite() && m.weight > 0.0) {
                return Err(format!("mix weight for {} must be positive", m.model));
            }
            if m.deadline_ms.is_nan() || m.deadline_ms < 0.0 {
                return Err(format!(
                    "mix deadline for {} must be >= 0 (0 = auto, inf = none)",
                    m.model
                ));
            }
        }
        Ok(())
    }

    /// Normalized arrival shares, in model order.
    pub fn shares(&self) -> Vec<f64> {
        let total: f64 = self.models.iter().map(|m| m.weight).sum();
        self.models.iter().map(|m| m.weight / total).collect()
    }

    /// Model names, in model order.
    pub fn names(&self) -> Vec<String> {
        self.models.iter().map(|m| m.model.clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_and_defaulted_fields() {
        let mix = WorkloadMix::parse("VGG-19:1:40, SqueezeNet:4:10").unwrap();
        assert_eq!(mix.models.len(), 2);
        assert_eq!(mix.models[0].model, "VGG-19");
        assert_eq!(mix.models[0].weight, 1.0);
        assert_eq!(mix.models[0].deadline_ms, 40.0);
        assert_eq!(mix.models[1].weight, 4.0);
        // Weight and deadline default to 1 and 0 (auto).
        let short = WorkloadMix::parse("MLP,LeNet-5:2").unwrap();
        assert_eq!(short.models[0].weight, 1.0);
        assert_eq!(short.models[0].deadline_ms, 0.0);
        assert_eq!(short.models[1].weight, 2.0);
        // "inf" = no deadline.
        let none = WorkloadMix::parse("MLP:1:inf").unwrap();
        assert!(none.models[0].deadline_ms.is_infinite());
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        assert!(WorkloadMix::parse("").is_err());
        assert!(WorkloadMix::parse("MLP:x").is_err());
        assert!(WorkloadMix::parse("MLP:1:y").is_err());
        assert!(WorkloadMix::parse("MLP:1:2:3").is_err());
        assert!(WorkloadMix::parse(":1:2").is_err());
        assert!(WorkloadMix::parse("MLP:0").is_err());
        assert!(WorkloadMix::parse("MLP:1:-5").is_err());
    }

    #[test]
    fn spec_string_roundtrips() {
        for spec in [
            "VGG-19:1:0,SqueezeNet:1:0",
            "MLP:2.5:12.5",
            "MLP:1:inf,LeNet-5:3:0",
        ] {
            let mix = WorkloadMix::parse(spec).unwrap();
            let back = WorkloadMix::parse(&mix.spec_string()).unwrap();
            assert_eq!(back, mix, "{spec}");
        }
        let mix = WorkloadMix::default_mix();
        assert_eq!(WorkloadMix::parse(&mix.spec_string()).unwrap(), mix);
    }

    #[test]
    fn shares_normalize() {
        let mix = WorkloadMix::parse("A:1,B:3").unwrap();
        let s = mix.shares();
        assert!((s[0] - 0.25).abs() < 1e-12);
        assert!((s[1] - 0.75).abs() < 1e-12);
        assert_eq!(mix.names(), vec!["A".to_string(), "B".to_string()]);
    }
}
