//! Record/replay traces: a text format freezing one generated workload.
//!
//! A trace is self-contained: it carries the mix spec (names, weights,
//! deadlines), the offered rate the generator targeted, and every request
//! event. Times are written with Rust's shortest-round-trip float
//! formatting, so `parse(to_text())` reproduces the events *bit-exactly* —
//! replaying a recorded trace yields byte-for-byte identical serving
//! reports (see the replay-determinism test in `tests/properties.rs`).
//!
//! Format (`#` lines are comments):
//!
//! ```text
//! # imcnoc-trace v1
//! mix VGG-19:1:0,SqueezeNet:1:0
//! rate 1234.5
//! # t_s model frames
//! 0.00081 0 1
//! 0.00095 1 2
//! ```

use super::arrival::Event;
use super::mix::WorkloadMix;

/// First line of every trace file.
pub const TRACE_HEADER: &str = "# imcnoc-trace v1";

/// A recorded workload: the mix it indexes into plus the event sequence.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// The mix the events' model indices refer to.
    pub mix: WorkloadMix,
    /// Offered arrival rate the generator targeted, requests/s (stamped
    /// into replayed reports so they match the recorded run).
    pub offered_rps: f64,
    /// The recorded arrivals, in time order.
    pub events: Vec<Event>,
}

impl Trace {
    /// Assemble a trace from its parts.
    pub fn new(mix: WorkloadMix, offered_rps: f64, events: Vec<Event>) -> Self {
        Self {
            mix,
            offered_rps,
            events,
        }
    }

    /// Serialize to the text format ([`Trace::parse`] round-trips it).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str(TRACE_HEADER);
        out.push('\n');
        out.push_str(&format!("mix {}\n", self.mix.spec_string()));
        out.push_str(&format!("rate {}\n", self.offered_rps));
        out.push_str("# t_s model frames\n");
        for e in &self.events {
            out.push_str(&format!("{} {} {}\n", e.t_s, e.model, e.frames));
        }
        out
    }

    /// Parse the text format, validating model indices and time ordering.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut mix: Option<WorkloadMix> = None;
        let mut offered_rps = 0.0f64;
        let mut events = Vec::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(spec) = line.strip_prefix("mix ") {
                mix = Some(WorkloadMix::parse(spec)?);
                continue;
            }
            if let Some(rate) = line.strip_prefix("rate ") {
                offered_rps = rate
                    .trim()
                    .parse::<f64>()
                    .ok()
                    .filter(|r| r.is_finite() && *r >= 0.0)
                    .ok_or_else(|| format!("trace line {}: bad rate '{rate}'", ln + 1))?;
                continue;
            }
            let mut fields = line.split_whitespace();
            let t_s: f64 = fields
                .next()
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| format!("trace line {}: bad event '{line}'", ln + 1))?;
            let model: usize = fields
                .next()
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| format!("trace line {}: bad event '{line}'", ln + 1))?;
            let frames: u32 = fields
                .next()
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| format!("trace line {}: bad event '{line}'", ln + 1))?;
            if fields.next().is_some() {
                return Err(format!("trace line {}: trailing fields in '{line}'", ln + 1));
            }
            if frames == 0 {
                return Err(format!("trace line {}: zero frames", ln + 1));
            }
            if !t_s.is_finite() || t_s < 0.0 {
                // NaN would also slip through the ordering check below.
                return Err(format!("trace line {}: bad time {t_s}", ln + 1));
            }
            events.push(Event { t_s, model, frames });
        }
        let mix = mix.ok_or_else(|| "trace is missing its 'mix' line".to_string())?;
        for (i, e) in events.iter().enumerate() {
            if e.model >= mix.models.len() {
                return Err(format!(
                    "trace event {i} names model {} but the mix has {}",
                    e.model,
                    mix.models.len()
                ));
            }
            if i > 0 && e.t_s < events[i - 1].t_s {
                return Err(format!("trace event {i} goes back in time"));
            }
        }
        Ok(Self {
            mix,
            offered_rps,
            events,
        })
    }

    /// Write the trace to a file.
    pub fn save(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.to_text()).map_err(|e| format!("write trace {path}: {e}"))
    }

    /// Load a trace from a file.
    pub fn load(path: &str) -> Result<Self, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read trace {path}: {e}"))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::arrival::ArrivalProcess;

    fn sample_trace() -> Trace {
        let mix = WorkloadMix::parse("MLP:1:0,LeNet-5:3:12.5").unwrap();
        let proc = ArrivalProcess {
            frames_alpha: 1.5,
            ..ArrivalProcess::default()
        };
        let events = proc.generate(&mix, 750.0, 64, 0xFEED);
        Trace::new(mix, 750.0, events)
    }

    #[test]
    fn text_roundtrip_is_bit_exact() {
        let trace = sample_trace();
        let text = trace.to_text();
        assert!(text.starts_with(TRACE_HEADER));
        let parsed = Trace::parse(&text).unwrap();
        // PartialEq on f64 fields: bit-exact times survive the text form.
        assert_eq!(parsed, trace);
        // And the round trip is a fixed point.
        assert_eq!(parsed.to_text(), text);
    }

    #[test]
    fn save_and_load() {
        let trace = sample_trace();
        let path = std::env::temp_dir().join("imcnoc_trace_roundtrip.trace");
        let path = path.to_str().unwrap().to_string();
        trace.save(&path).unwrap();
        let loaded = Trace::load(&path).unwrap();
        assert_eq!(loaded, trace);
        assert!(Trace::load("/nonexistent/trace.txt").is_err());
    }

    #[test]
    fn parse_rejects_malformed_traces() {
        assert!(Trace::parse("0.1 0 1\n").is_err()); // no mix line
        assert!(Trace::parse("mix MLP:1:0\n0.1 zero 1\n").is_err());
        assert!(Trace::parse("mix MLP:1:0\n0.1 0 1 9\n").is_err());
        assert!(Trace::parse("mix MLP:1:0\n0.1 0 0\n").is_err());
        assert!(Trace::parse("mix MLP:1:0\n0.1 5 1\n").is_err()); // model out of range
        assert!(Trace::parse("mix MLP:1:0\n0.2 0 1\n0.1 0 1\n").is_err()); // time reversal
        assert!(Trace::parse("mix MLP:1:0\nrate banana\n").is_err());
        assert!(Trace::parse("mix MLP:1:0\nrate -2\n").is_err());
        assert!(Trace::parse("mix MLP:1:0\nrate inf\n").is_err());
        assert!(Trace::parse("mix MLP:1:0\nnan 0 1\n").is_err());
        assert!(Trace::parse("mix MLP:1:0\n-0.5 0 1\n").is_err());
        assert!(Trace::parse("mix MLP:1:0\ninf 0 1\n").is_err());
        // Comments and blank lines are fine; rate is optional.
        let ok = Trace::parse("# c\nmix MLP:1:0\n\n0.1 0 2\n# tail\n").unwrap();
        assert_eq!(ok.events.len(), 1);
        assert_eq!(ok.offered_rps, 0.0);
        assert_eq!(ok.events[0].frames, 2);
    }
}
