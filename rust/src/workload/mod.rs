//! Workload modeling for multi-model serving: which DNNs a package serves,
//! how their requests arrive, and where their replicas live.
//!
//! The serving scheduler of PR 3 ([`crate::coordinator::scheduler`]) drives
//! exactly one model at a fixed-rate Poisson arrival process — the one
//! regime where the paper's model-dependent interconnect choice is static.
//! This module supplies everything needed to serve a *mix* of DNNs on one
//! 2.5D package under realistic traffic:
//!
//! * [`mix`] — a [`WorkloadMix`]: named zoo DNNs with per-model arrival
//!   weights and latency deadlines (`"VGG-19:1:0,SqueezeNet:1:0"`).
//! * [`arrival`] — arrival-process generators beyond fixed-rate Poisson:
//!   MMPP-style bursty on/off sources, diurnal rate curves, and
//!   heavy-tailed frames-per-request batches.
//! * [`trace`] — a text trace format with record/replay so an experiment's
//!   exact request sequence can be rerun across schedulers and policies.
//! * [`placement`] — replica placement: pin each model of the mix to a
//!   chiplet subset, either naively (round-robin striping) or via a
//!   NoP-aware greedy + swap-refinement search that sizes replica sets by
//!   demand and keeps high-traffic models close to the package gateway.
//!
//! The multi-model scheduler that consumes all of this lives in
//! [`crate::coordinator::mix`].

pub mod arrival;
pub mod mix;
pub mod placement;
pub mod trace;

pub use arrival::{ArrivalKind, ArrivalProcess, Event};
pub use mix::{ModelSpec, WorkloadMix};
pub use placement::{place_replicas, Placement, PlacementPolicy};
pub use trace::Trace;
