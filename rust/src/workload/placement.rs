//! Replica placement: pin each model of a mix to a chiplet subset.
//!
//! IMC crossbars are weight-stationary, so a chiplet serves exactly one
//! model (its weights are programmed once); a *placement* is therefore a
//! chiplet → model assignment. What makes the assignment matter is the
//! package interconnect: request inputs enter at the gateway and ride NoP
//! SerDes links to their replica, so the chiplets differ in ingress cost
//! and share links — the paper's interconnect-dominates argument applied
//! to serving.
//!
//! Two policies:
//!
//! * [`PlacementPolicy::RoundRobin`] — the naive baseline: stripe chiplets
//!   across models in id order, ignoring demand and the NoP entirely.
//! * [`PlacementPolicy::NopAware`] — (1) size each model's replica set by
//!   minimax waterfilling on its service demand (repeatedly granting the
//!   next chiplet to the model with the highest per-replica load), then
//!   (2) hand the cheapest-ingress chiplets to the models injecting the
//!   most NoP traffic, and (3) refine by pairwise swaps scored on expected
//!   flit-hops plus worst-link contention.

use crate::nop::topology::NopNetwork;
use std::collections::HashMap;

/// How replicas are assigned to chiplets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlacementPolicy {
    /// Stripe chiplets across models in id order (naive baseline).
    RoundRobin,
    /// Demand-sized replica sets, gateway-proximate high-traffic models,
    /// swap refinement on the NoP contention score.
    NopAware,
}

impl PlacementPolicy {
    /// Display name (the canonical `parse` spelling).
    pub fn name(self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "round-robin",
            PlacementPolicy::NopAware => "nop-aware",
        }
    }

    /// Parse a case-insensitive policy name (aliases: rr, nop, …).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "roundrobin" | "rr" | "naive" => Some(PlacementPolicy::RoundRobin),
            "nop-aware" | "nopaware" | "nop" | "aware" => Some(PlacementPolicy::NopAware),
            _ => None,
        }
    }

    /// Every placement policy, in sweep order.
    pub fn all() -> [PlacementPolicy; 2] {
        [PlacementPolicy::RoundRobin, PlacementPolicy::NopAware]
    }

    /// The valid `parse` spellings, for CLI error messages.
    pub fn valid_names() -> &'static str {
        "round-robin, nop-aware"
    }
}

/// A chiplet → model assignment for one package.
#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    /// Package size the placement covers.
    pub chiplets: usize,
    /// `model_of[c]` = mix model index served by chiplet `c`.
    pub model_of: Vec<usize>,
}

impl Placement {
    /// Chiplets hosting a replica of `model`, in id order.
    pub fn replicas(&self, model: usize) -> Vec<usize> {
        (0..self.chiplets)
            .filter(|&c| self.model_of[c] == model)
            .collect()
    }

    /// Number of replicas of `model`.
    pub fn replica_count(&self, model: usize) -> usize {
        self.model_of.iter().filter(|&&m| m == model).count()
    }

    /// Invariants: every chiplet assigned, every model hosted at least once.
    pub fn validate(&self, n_models: usize) -> Result<(), String> {
        if self.model_of.len() != self.chiplets {
            return Err("placement length != chiplet count".into());
        }
        for (c, &m) in self.model_of.iter().enumerate() {
            if m >= n_models {
                return Err(format!("chiplet {c} assigned to out-of-range model {m}"));
            }
        }
        for m in 0..n_models {
            if self.replica_count(m) == 0 {
                return Err(format!("model {m} has no replica"));
            }
        }
        Ok(())
    }
}

/// Place one replica set per model over `net`'s chiplets.
///
/// * `loads[m]` — service demand of model `m` in replica-seconds per
///   second (arrival share × per-request occupancy); sizes the replica
///   sets under [`PlacementPolicy::NopAware`].
/// * `ingress_rate[m]` — relative NoP ingress traffic of model `m`
///   (arrival share × flits per request); orders models for gateway
///   proximity and weights the contention score.
pub fn place_replicas(
    policy: PlacementPolicy,
    net: &NopNetwork,
    gateway: usize,
    loads: &[f64],
    ingress_rate: &[f64],
) -> Result<Placement, String> {
    let k = net.chiplets;
    let n = loads.len();
    if n == 0 || n != ingress_rate.len() {
        return Err("placement needs one load and one ingress rate per model".into());
    }
    if k < n {
        return Err(format!(
            "{k} chiplet(s) cannot host {n} model(s) (one model per chiplet)"
        ));
    }
    let model_of = match policy {
        PlacementPolicy::RoundRobin => (0..k).map(|c| c % n).collect(),
        PlacementPolicy::NopAware => {
            let counts = waterfill_counts(k, loads);
            let routes = ingress_routes(net, gateway);
            let mut model_of = assign_by_ingress_cost(net, gateway, &counts, ingress_rate);
            refine_by_swaps(&routes, k, &mut model_of, &counts, ingress_rate);
            model_of
        }
    };
    let placement = Placement {
        chiplets: k,
        model_of,
    };
    placement.validate(n)?;
    Ok(placement)
}

/// Minimax waterfilling: start with one replica per model, then repeatedly
/// grant the next chiplet to the model with the highest per-replica load.
fn waterfill_counts(k: usize, loads: &[f64]) -> Vec<usize> {
    let n = loads.len();
    let mut counts = vec![1usize; n];
    for _ in n..k {
        let mut best = 0usize;
        let mut best_load = f64::NEG_INFINITY;
        for (m, &load) in loads.iter().enumerate() {
            let per = load / counts[m] as f64;
            if per > best_load {
                best_load = per;
                best = m;
            }
        }
        counts[best] += 1;
    }
    counts
}

/// Order chiplets by ingress cost (hops from the gateway, then id) and
/// grant the cheapest runs to the models injecting the most NoP traffic.
fn assign_by_ingress_cost(
    net: &NopNetwork,
    gateway: usize,
    counts: &[usize],
    ingress_rate: &[f64],
) -> Vec<usize> {
    let k = net.chiplets;
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by_key(|&c| (net.hops(gateway, c), c));
    // Models by per-replica ingress traffic, heaviest first (stable on id).
    let mut models: Vec<usize> = (0..counts.len()).collect();
    models.sort_by(|&a, &b| {
        let ra = ingress_rate[a] / counts[a] as f64;
        let rb = ingress_rate[b] / counts[b] as f64;
        rb.partial_cmp(&ra).unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut model_of = vec![0usize; k];
    let mut next = 0usize;
    for &m in &models {
        for _ in 0..counts[m] {
            model_of[order[next]] = m;
            next += 1;
        }
    }
    model_of
}

/// Per-chiplet ingress route from the gateway, precomputed once for the
/// swap search: (directed links of the route, hop count). The gateway's
/// own entry is empty.
fn ingress_routes(net: &NopNetwork, gateway: usize) -> Vec<(Vec<(usize, usize)>, usize)> {
    (0..net.chiplets)
        .map(|c| (net.route_links(gateway, c), net.hops(gateway, c)))
        .collect()
}

/// Contention score of a placement: expected ingress flit-hops per unit
/// time plus a worst-link term (weighted by the package size so a single
/// hot SerDes lane dominates ties). Lower is better.
fn placement_score(
    routes: &[(Vec<(usize, usize)>, usize)],
    chiplets: usize,
    model_of: &[usize],
    counts: &[usize],
    ingress_rate: &[f64],
) -> f64 {
    let mut link_load: HashMap<(usize, usize), f64> = HashMap::new();
    let mut hop_cost = 0.0f64;
    for (c, &m) in model_of.iter().enumerate() {
        let (links, hops) = &routes[c];
        let r = ingress_rate[m] / counts[m] as f64;
        for &link in links {
            *link_load.entry(link).or_insert(0.0) += r;
        }
        hop_cost += r * *hops as f64;
    }
    let worst = link_load.values().fold(0.0f64, |a, &b| a.max(b));
    hop_cost + chiplets as f64 * worst
}

/// Pairwise swap refinement: exchange two chiplets' models whenever that
/// strictly lowers the contention score (replica counts are preserved by
/// construction).
fn refine_by_swaps(
    routes: &[(Vec<(usize, usize)>, usize)],
    chiplets: usize,
    model_of: &mut [usize],
    counts: &[usize],
    ingress_rate: &[f64],
) {
    let k = model_of.len();
    let mut current = placement_score(routes, chiplets, model_of, counts, ingress_rate);
    let mut improved = true;
    let mut guard = 0usize;
    while improved && guard < 4 * k {
        improved = false;
        guard += 1;
        for a in 0..k {
            for b in (a + 1)..k {
                if model_of[a] == model_of[b] {
                    continue;
                }
                model_of.swap(a, b);
                let after = placement_score(routes, chiplets, model_of, counts, ingress_rate);
                if after < current {
                    current = after;
                    improved = true;
                } else {
                    model_of.swap(a, b); // revert
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nop::topology::NopTopology;

    #[test]
    fn round_robin_stripes_ignoring_demand() {
        let net = NopNetwork::build(NopTopology::Mesh, 8);
        let p = place_replicas(
            PlacementPolicy::RoundRobin,
            &net,
            0,
            &[10.0, 1.0],
            &[5.0, 1.0],
        )
        .unwrap();
        p.validate(2).unwrap();
        assert_eq!(p.replica_count(0), 4);
        assert_eq!(p.replica_count(1), 4);
        assert_eq!(p.model_of, vec![0, 1, 0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn waterfilling_sizes_replicas_by_demand() {
        // Loads 10:1 over 16 chiplets: the minimax greedy lands on (14, 2)
        // — never starving the small model down to an overloaded single
        // replica (the largest-remainder failure mode).
        assert_eq!(waterfill_counts(16, &[10.0, 1.0]), vec![14, 2]);
        assert_eq!(waterfill_counts(4, &[1.0, 1.0]), vec![2, 2]);
        assert_eq!(waterfill_counts(3, &[1.0, 100.0]), vec![1, 2]);
        // Equal demands split evenly regardless of order.
        assert_eq!(waterfill_counts(6, &[2.0, 2.0, 2.0]), vec![2, 2, 2]);
    }

    #[test]
    fn nop_aware_puts_heavy_traffic_near_the_gateway() {
        // Mesh of 16, gateway at corner 0. Model 0 carries 10x the ingress
        // traffic per replica: its chiplets must sit strictly closer to the
        // gateway on average than model 1's.
        let net = NopNetwork::build(NopTopology::Mesh, 16);
        let p = place_replicas(
            PlacementPolicy::NopAware,
            &net,
            0,
            &[1.0, 1.0],
            &[10.0, 1.0],
        )
        .unwrap();
        p.validate(2).unwrap();
        assert_eq!(p.replica_count(0), 8);
        assert_eq!(p.replica_count(1), 8);
        let mean_hops = |m: usize| {
            let reps = p.replicas(m);
            reps.iter().map(|&c| net.hops(0, c)).sum::<usize>() as f64 / reps.len() as f64
        };
        assert!(
            mean_hops(0) < mean_hops(1),
            "heavy model at {} hops, light at {}",
            mean_hops(0),
            mean_hops(1)
        );
    }

    #[test]
    fn nop_aware_beats_round_robin_on_its_own_score() {
        // Equal service demands so both policies land on 8+8 replicas and
        // the scores compare the *arrangement* alone.
        let net = NopNetwork::build(NopTopology::Mesh, 16);
        let loads = [1.0, 1.0];
        let ingress = [8.0, 1.0];
        let rr = place_replicas(PlacementPolicy::RoundRobin, &net, 0, &loads, &ingress).unwrap();
        let aware = place_replicas(PlacementPolicy::NopAware, &net, 0, &loads, &ingress).unwrap();
        let counts = [8usize, 8];
        assert_eq!(aware.replica_count(0), 8);
        let routes = ingress_routes(&net, 0);
        let s_rr = placement_score(&routes, 16, &rr.model_of, &counts, &ingress);
        let s_aware = placement_score(&routes, 16, &aware.model_of, &counts, &ingress);
        assert!(
            s_aware < s_rr,
            "nop-aware score {s_aware} vs round-robin {s_rr}"
        );
    }

    #[test]
    fn placement_is_deterministic() {
        let net = NopNetwork::build(NopTopology::Ring, 12);
        let a = place_replicas(PlacementPolicy::NopAware, &net, 0, &[3.0, 1.0], &[2.0, 5.0])
            .unwrap();
        let b = place_replicas(PlacementPolicy::NopAware, &net, 0, &[3.0, 1.0], &[2.0, 5.0])
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn errors_on_impossible_packages() {
        let net = NopNetwork::build(NopTopology::Ring, 2);
        assert!(place_replicas(
            PlacementPolicy::NopAware,
            &net,
            0,
            &[1.0, 1.0, 1.0],
            &[1.0, 1.0, 1.0]
        )
        .is_err());
        assert!(place_replicas(PlacementPolicy::RoundRobin, &net, 0, &[], &[]).is_err());
        assert!(place_replicas(PlacementPolicy::RoundRobin, &net, 0, &[1.0], &[1.0, 2.0]).is_err());
    }

    #[test]
    fn parse_roundtrip_and_errors() {
        for p in PlacementPolicy::all() {
            assert_eq!(PlacementPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(
            PlacementPolicy::parse("RR"),
            Some(PlacementPolicy::RoundRobin)
        );
        assert_eq!(PlacementPolicy::parse("nop"), Some(PlacementPolicy::NopAware));
        assert_eq!(PlacementPolicy::parse("magic"), None);
        assert!(PlacementPolicy::valid_names().contains("nop-aware"));
    }
}
