//! Arrival-process generators beyond fixed-rate Poisson.
//!
//! Every generator emits a deterministic, time-sorted [`Event`] sequence
//! from a seed, so a generated workload can be recorded once
//! ([`super::trace`]) and replayed bit-exactly against any scheduler
//! configuration. The long-run average rate of every process equals the
//! requested `total_rps`; only the *shape* of the arrivals differs:
//!
//! * [`ArrivalKind::Poisson`] — the PR 3 baseline: memoryless fixed-rate
//!   arrivals.
//! * [`ArrivalKind::Bursty`] — MMPP-style two-state on/off source:
//!   exponential ON/OFF residence times, ON-state rate inflated by
//!   `burst_factor` (with the OFF rate chosen to preserve the mean).
//! * [`ArrivalKind::Diurnal`] — a sinusoidal rate curve (peak/trough
//!   ±[`DIURNAL_AMPLITUDE`]) sampled by thinning, the classic
//!   non-homogeneous-Poisson recipe for daily load cycles.
//!
//! Independently of the kind, `frames_alpha > 0` gives every request a
//! heavy-tailed (bounded-Pareto) frame count — client-side batches whose
//! occasional fat requests stress the pipeline amortization.

use super::mix::WorkloadMix;
use crate::util::Pcg32;

/// Peak-to-mean amplitude of the diurnal rate curve.
pub const DIURNAL_AMPLITUDE: f64 = 0.8;

/// Shape of the request arrival process.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArrivalKind {
    /// Fixed-rate memoryless arrivals.
    Poisson,
    /// MMPP-style two-state on/off bursts.
    Bursty,
    /// Sinusoidal (day/night) rate curve via thinning.
    Diurnal,
}

impl ArrivalKind {
    /// Display name (the canonical `parse` spelling).
    pub fn name(self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Bursty => "bursty",
            ArrivalKind::Diurnal => "diurnal",
        }
    }

    /// Parse a case-insensitive shape name (aliases: mmpp, daily, …).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "poisson" | "fixed" => Some(ArrivalKind::Poisson),
            "bursty" | "burst" | "mmpp" | "onoff" | "on-off" => Some(ArrivalKind::Bursty),
            "diurnal" | "daily" | "sinusoidal" => Some(ArrivalKind::Diurnal),
            _ => None,
        }
    }

    /// Every arrival shape, in sweep order.
    pub fn all() -> [ArrivalKind; 3] {
        [
            ArrivalKind::Poisson,
            ArrivalKind::Bursty,
            ArrivalKind::Diurnal,
        ]
    }

    /// The valid `parse` spellings, for CLI error messages.
    pub fn valid_names() -> &'static str {
        "poisson, bursty, diurnal"
    }
}

/// One request of a generated (or recorded) workload.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Event {
    /// Arrival time at the package gateway, seconds.
    pub t_s: f64,
    /// Index into the mix's model list.
    pub model: usize,
    /// Frames bundled into this request (client-side batch), >= 1.
    pub frames: u32,
}

/// Arrival-process shape knobs. Rates come from the caller so one process
/// description can drive any load point.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrivalProcess {
    /// Which arrival shape to generate.
    pub kind: ArrivalKind,
    /// Bursty: ON-state rate multiplier, >= 1. The OFF rate is derived so
    /// the long-run mean stays at the requested rate (`burst_factor *
    /// on_fraction <= 1`; equality means the OFF state is silent).
    pub burst_factor: f64,
    /// Bursty: long-run fraction of time in the ON state, in (0, 1).
    pub on_fraction: f64,
    /// Bursty: mean ON+OFF cycle length, seconds. Diurnal: the period of
    /// the rate curve.
    pub cycle_s: f64,
    /// Heavy-tailed frames-per-request tail exponent (bounded Pareto);
    /// 0 disables (every request is a single frame).
    pub frames_alpha: f64,
    /// Frames-per-request cap, >= 1.
    pub frames_max: u32,
}

impl Default for ArrivalProcess {
    fn default() -> Self {
        Self {
            kind: ArrivalKind::Poisson,
            burst_factor: 4.0,
            on_fraction: 0.25,
            cycle_s: 0.02,
            frames_alpha: 0.0,
            frames_max: 8,
        }
    }
}

impl ArrivalProcess {
    /// Range-check the shape knobs; `Err` carries the offending one.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.burst_factor.is_finite() && self.burst_factor >= 1.0) {
            return Err("burst_factor must be >= 1".into());
        }
        if !(self.on_fraction > 0.0 && self.on_fraction < 1.0) {
            return Err("on_fraction must be in (0, 1)".into());
        }
        if self.burst_factor * self.on_fraction > 1.0 + 1e-9 {
            return Err("burst_factor * on_fraction must be <= 1 (mean-preserving)".into());
        }
        if !(self.cycle_s.is_finite() && self.cycle_s > 0.0) {
            return Err("cycle_s must be positive".into());
        }
        if self.frames_alpha.is_nan() || self.frames_alpha < 0.0 {
            return Err("frames_alpha must be >= 0 (0 = single-frame requests)".into());
        }
        if self.frames_max == 0 {
            return Err("frames_max must be >= 1".into());
        }
        Ok(())
    }

    /// Generate `requests` arrivals averaging `total_rps` over the mix's
    /// traffic shares. Deterministic for a given seed; events come out
    /// sorted by time.
    pub fn generate(
        &self,
        mix: &WorkloadMix,
        total_rps: f64,
        requests: usize,
        seed: u64,
    ) -> Vec<Event> {
        assert!(total_rps > 0.0, "total_rps must be positive");
        let mut rng = Pcg32::seeded(seed);
        let shares = mix.shares();
        let mut cum = Vec::with_capacity(shares.len());
        let mut acc = 0.0;
        for s in &shares {
            acc += s;
            cum.push(acc);
        }

        // Bursty state machine: ON-rate = burst_factor * base; OFF-rate
        // derived so the time-average equals base.
        let f = self.on_fraction;
        let on_rate = self.burst_factor * total_rps;
        let off_rate = (total_rps * (1.0 - self.burst_factor * f).max(0.0)) / (1.0 - f);
        let mut on = true;
        let mut state_end = exp_draw(&mut rng, 1.0 / (f * self.cycle_s));

        // Diurnal thinning bound.
        let peak = total_rps * (1.0 + DIURNAL_AMPLITUDE);

        let mut events = Vec::with_capacity(requests);
        let mut t = 0.0f64;
        for _ in 0..requests {
            match self.kind {
                ArrivalKind::Poisson => {
                    t += exp_draw(&mut rng, total_rps);
                }
                ArrivalKind::Bursty => loop {
                    let rate = if on { on_rate } else { off_rate };
                    if rate > 0.0 {
                        let dt = exp_draw(&mut rng, rate);
                        if t + dt <= state_end {
                            t += dt;
                            break;
                        }
                    }
                    // No arrival before the state flips: jump to the flip
                    // and draw the next residence time.
                    t = state_end;
                    on = !on;
                    let mean_s = if on {
                        f * self.cycle_s
                    } else {
                        (1.0 - f) * self.cycle_s
                    };
                    state_end = t + exp_draw(&mut rng, 1.0 / mean_s);
                },
                ArrivalKind::Diurnal => loop {
                    t += exp_draw(&mut rng, peak);
                    let phase = 2.0 * std::f64::consts::PI * t / self.cycle_s;
                    let rate = total_rps * (1.0 + DIURNAL_AMPLITUDE * phase.sin());
                    if rng.next_f64() < rate / peak {
                        break;
                    }
                },
            }
            let u = rng.next_f64();
            let model = cum.iter().position(|&c| u < c).unwrap_or(cum.len() - 1);
            events.push(Event {
                t_s: t,
                model,
                frames: self.draw_frames(&mut rng),
            });
        }
        events
    }

    /// Bounded-Pareto frames-per-request draw (`P(X >= n) = n^-alpha`,
    /// capped at `frames_max`); 1 when the tail is disabled.
    fn draw_frames(&self, rng: &mut Pcg32) -> u32 {
        if self.frames_alpha <= 0.0 || self.frames_max <= 1 {
            return 1;
        }
        let u = 1.0 - rng.next_f64(); // (0, 1]
        let x = u.powf(-1.0 / self.frames_alpha);
        x.min(self.frames_max as f64) as u32
    }

    /// Expected frames per request of this process:
    /// `E[X] = Σ_{n=1..frames_max} P(X >= n) = Σ n^-alpha` for the
    /// capped-Pareto draw, 1 when the tail is disabled. Lets capacity
    /// planning hold *utilization* constant across tail shapes instead of
    /// conflating extra load with burstiness.
    pub fn mean_frames(&self) -> f64 {
        if self.frames_alpha <= 0.0 || self.frames_max <= 1 {
            return 1.0;
        }
        (1..=self.frames_max)
            .map(|n| (n as f64).powf(-self.frames_alpha))
            .sum()
    }
}

/// One exponential inter-event draw at `rate` events/s.
fn exp_draw(rng: &mut Pcg32, rate: f64) -> f64 {
    -(1.0 - rng.next_f64()).ln() / rate
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_model_mix() -> WorkloadMix {
        WorkloadMix::parse("A:1:0,B:3:0").unwrap()
    }

    #[test]
    fn generators_are_deterministic_and_sorted() {
        let mix = two_model_mix();
        for kind in ArrivalKind::all() {
            let proc = ArrivalProcess {
                kind,
                frames_alpha: 1.5,
                ..ArrivalProcess::default()
            };
            proc.validate().unwrap();
            let a = proc.generate(&mix, 1000.0, 300, 7);
            let b = proc.generate(&mix, 1000.0, 300, 7);
            assert_eq!(a, b, "{kind:?} not deterministic");
            assert_eq!(a.len(), 300);
            for w in a.windows(2) {
                assert!(w[1].t_s >= w[0].t_s, "{kind:?} not sorted");
            }
            for e in &a {
                assert!(e.model < 2);
                assert!(e.frames >= 1 && e.frames <= 8);
            }
            let c = proc.generate(&mix, 1000.0, 300, 8);
            assert_ne!(a, c, "{kind:?} ignores the seed");
        }
    }

    #[test]
    fn mean_rate_is_preserved_within_tolerance() {
        // All three shapes must average out to the requested rate over a
        // long run (the thinning/MMPP bookkeeping is mean-preserving).
        let mix = two_model_mix();
        for kind in ArrivalKind::all() {
            let proc = ArrivalProcess {
                kind,
                ..ArrivalProcess::default()
            };
            let n = 6000;
            let events = proc.generate(&mix, 500.0, n, 11);
            let span = events.last().unwrap().t_s;
            let rate = n as f64 / span;
            assert!(
                (rate - 500.0).abs() / 500.0 < 0.15,
                "{kind:?}: measured {rate} vs 500"
            );
        }
    }

    #[test]
    fn bursty_clumps_more_than_poisson() {
        // Squared coefficient of variation of inter-arrivals: ~1 for
        // Poisson, clearly above 1 for the on/off source.
        let mix = two_model_mix();
        let cv2 = |kind: ArrivalKind| {
            let proc = ArrivalProcess {
                kind,
                ..ArrivalProcess::default()
            };
            let events = proc.generate(&mix, 2000.0, 4000, 3);
            let gaps: Vec<f64> = events.windows(2).map(|w| w[1].t_s - w[0].t_s).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var =
                gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
            var / (mean * mean)
        };
        let poisson = cv2(ArrivalKind::Poisson);
        let bursty = cv2(ArrivalKind::Bursty);
        assert!((0.8..1.3).contains(&poisson), "poisson cv2 {poisson}");
        assert!(bursty > 1.5 * poisson, "bursty cv2 {bursty} vs {poisson}");
    }

    #[test]
    fn model_shares_follow_weights() {
        let mix = two_model_mix(); // B has 3x A's weight
        let proc = ArrivalProcess::default();
        let events = proc.generate(&mix, 100.0, 4000, 5);
        let b = events.iter().filter(|e| e.model == 1).count();
        let frac = b as f64 / events.len() as f64;
        assert!((frac - 0.75).abs() < 0.05, "B share {frac}");
    }

    #[test]
    fn heavy_tail_produces_multi_frame_requests() {
        let mix = two_model_mix();
        let proc = ArrivalProcess {
            frames_alpha: 1.2,
            frames_max: 8,
            ..ArrivalProcess::default()
        };
        let events = proc.generate(&mix, 100.0, 2000, 9);
        let multi = events.iter().filter(|e| e.frames > 1).count();
        let capped = events.iter().filter(|e| e.frames == 8).count();
        assert!(multi > 200, "only {multi} multi-frame requests");
        assert!(capped > 0, "tail never reached the cap");
        // The closed-form mean matches the empirical mean.
        let expect = proc.mean_frames();
        assert!(expect > 1.0);
        let measured =
            events.iter().map(|e| e.frames as f64).sum::<f64>() / events.len() as f64;
        assert!(
            (measured - expect).abs() / expect < 0.1,
            "mean frames {measured} vs closed-form {expect}"
        );
        // Disabled tail: always exactly one frame, mean 1.
        let flat = ArrivalProcess::default().generate(&mix, 100.0, 500, 9);
        assert!(flat.iter().all(|e| e.frames == 1));
        assert_eq!(ArrivalProcess::default().mean_frames(), 1.0);
    }

    #[test]
    fn validate_rejects_bad_shapes() {
        let ok = ArrivalProcess::default();
        assert!(ok.validate().is_ok());
        let bad = |f: &dyn Fn(&mut ArrivalProcess)| {
            let mut p = ArrivalProcess::default();
            f(&mut p);
            p.validate().is_err()
        };
        assert!(bad(&|p| p.burst_factor = 0.5));
        assert!(bad(&|p| p.on_fraction = 0.0));
        assert!(bad(&|p| p.on_fraction = 1.0));
        assert!(bad(&|p| {
            p.burst_factor = 3.0;
            p.on_fraction = 0.5;
        }));
        assert!(bad(&|p| p.cycle_s = 0.0));
        assert!(bad(&|p| p.frames_alpha = -1.0));
        assert!(bad(&|p| p.frames_max = 0));
    }
}
