//! Fixed-width windowed time series for the serving planes.
//!
//! [`TimeSeries`] is the time-resolved layer on top of PR 5's end-of-run
//! aggregates: both serving schedulers feed it per-event recorders
//! (arrival / admission depth / drop / shed / completion / NoP link busy
//! time), and it buckets them into fixed-width windows of `window_s`
//! seconds. Each window holds global and per-model counters, a
//! queue-depth histogram, a per-model latency [`QuantileSketch`] (so
//! per-window p50/p99 are bounded-memory), and per-link busy seconds (a
//! link-utilization heatmap over time). [`TimeSeries::finalize`] freezes
//! the scalars and runs per-model EWMA drift detectors over the arrival
//! rate and the window p99, emitting typed [`DriftEvent`]s — the signal a
//! future online re-placement controller subscribes to.
//!
//! Export surfaces: deterministic JSON ([`TimeSeries::to_json`]),
//! Prometheus-style text exposition ([`TimeSeries::to_prom`]), Chrome
//! trace counter tracks ([`TimeSeries::counter_tracks`], rendered by
//! Perfetto as queue-depth and link-utilization timelines next to the
//! lifecycle spans), and a [`SimTelemetry`] synthesis
//! ([`TimeSeries::to_sim_telemetry`]) that reuses the PR 5 heatmap
//! renderers for `repro serve --heatmap`.
//!
//! Memory is proportional to windows x models + links — independent of
//! the request count. All recorders are O(1).

use std::collections::HashMap;

use super::registry::{escape, Histogram, SimTelemetry};
use super::sketch::QuantileSketch;
use super::trace::ChromeTrace;

/// EWMA smoothing factor for the drift detectors' mean/variance.
pub const DRIFT_ALPHA: f64 = 0.25;

/// Drift triggers when a window deviates from the EWMA mean by more than
/// `DRIFT_SIGMA` EWMA standard deviations...
pub const DRIFT_SIGMA: f64 = 3.0;

/// ...and by more than this fraction of the mean (absolute floor, so a
/// near-constant series with tiny variance does not page on noise).
pub const DRIFT_MIN_REL: f64 = 0.2;

/// Windows observed before a detector may fire (EWMA settle time).
pub const DRIFT_WARMUP: u64 = 8;

/// Auto-sizing target: when `[telemetry] window_ms = 0`, schedulers size
/// the window so a run spans about this many windows.
pub const AUTO_WINDOWS: f64 = 32.0;

/// Hard cap on the window vector, so a wild timestamp cannot OOM the
/// collector (~2 weeks at the default auto window of a 1 s run).
const MAX_WINDOWS: usize = 1 << 20;

/// Which per-model signal a drift detector watched.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftMetric {
    /// Per-window arrivals divided by the window width (req/s).
    ArrivalRate,
    /// Per-window p99 latency (ms), windows with completions only.
    P99,
}

impl DriftMetric {
    /// Stable export label.
    pub fn name(&self) -> &'static str {
        match self {
            DriftMetric::ArrivalRate => "arrival_rate",
            DriftMetric::P99 => "p99_ms",
        }
    }
}

/// Direction of a detected shift.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftDirection {
    /// The window value jumped above the EWMA baseline.
    Up,
    /// The window value fell below the EWMA baseline.
    Down,
}

impl DriftDirection {
    /// Stable export label.
    pub fn name(&self) -> &'static str {
        match self {
            DriftDirection::Up => "up",
            DriftDirection::Down => "down",
        }
    }
}

/// A typed drift event emitted by [`TimeSeries::finalize`].
#[derive(Clone, Debug)]
pub struct DriftEvent {
    /// Window index the deviating sample came from.
    pub window: usize,
    /// Start time of that window (seconds).
    pub t_s: f64,
    /// Model index (into [`TimeSeries::model_names`]).
    pub model: usize,
    /// Signal that drifted.
    pub metric: DriftMetric,
    /// Direction of the shift.
    pub direction: DriftDirection,
    /// The deviating window value.
    pub value: f64,
    /// EWMA mean just before the deviating window.
    pub baseline: f64,
    /// EWMA standard deviation just before the deviating window.
    pub sigma: f64,
}

/// Online EWMA mean/variance change detector (one per model per metric).
#[derive(Clone, Debug, Default)]
struct EwmaDetector {
    mean: f64,
    var: f64,
    n: u64,
}

impl EwmaDetector {
    /// Feed one sample; returns `(baseline, sigma, went_up)` when the
    /// sample deviates from the pre-update EWMA by more than
    /// `max(DRIFT_SIGMA * sigma, DRIFT_MIN_REL * |mean|)` after warmup.
    fn observe(&mut self, x: f64) -> Option<(f64, f64, bool)> {
        self.n += 1;
        if self.n == 1 {
            self.mean = x;
            self.var = 0.0;
            return None;
        }
        let baseline = self.mean;
        let sigma = self.var.max(0.0).sqrt();
        let diff = x - self.mean;
        let incr = DRIFT_ALPHA * diff;
        self.mean += incr;
        self.var = (1.0 - DRIFT_ALPHA) * (self.var + diff * incr);
        if self.n <= DRIFT_WARMUP {
            return None;
        }
        let threshold = (DRIFT_SIGMA * sigma).max(DRIFT_MIN_REL * baseline.abs());
        if (x - baseline).abs() > threshold {
            Some((baseline, sigma, x > baseline))
        } else {
            None
        }
    }
}

/// Per-model slice of one window.
#[derive(Clone, Debug, Default)]
pub struct ModelWindow {
    /// Requests of this model that arrived in the window.
    pub arrivals: u64,
    /// Requests of this model that completed in the window.
    pub completions: u64,
    /// Live latency sketch; frozen into the scalars by `finalize`.
    sketch: QuantileSketch,
    /// Window p50 latency (ms); 0 until `finalize`, 0 when empty.
    pub p50_ms: f64,
    /// Window p99 latency (ms); 0 until `finalize`, 0 when empty.
    pub p99_ms: f64,
    /// Window mean latency (ms, exact); 0 until `finalize`.
    pub mean_ms: f64,
}

/// One fixed-width collection window.
#[derive(Clone, Debug, Default)]
pub struct Window {
    /// Requests that arrived in the window (all models).
    pub arrivals: u64,
    /// Requests that completed in the window (by completion time).
    pub completions: u64,
    /// Requests dropped at admission in the window.
    pub drops: u64,
    /// Requests shed by deadline-aware admission in the window.
    pub sheds: u64,
    /// Queue depth observed at each admission in the window.
    pub depth: Histogram,
    /// Per-model slices (index-aligned with `TimeSeries::model_names`).
    pub models: Vec<ModelWindow>,
    /// Busy seconds per NoP link (index-aligned with `TimeSeries::links`).
    pub link_busy_s: Vec<f64>,
    /// Window p50 over all models (ms); set by `finalize`.
    pub p50_ms: f64,
    /// Window p99 over all models (ms); set by `finalize`.
    pub p99_ms: f64,
}

/// Sorted, deduplicated union of per-chiplet NoP paths — the link axis of
/// the time series.
pub fn link_union(paths: &[Vec<(usize, usize)>]) -> Vec<(usize, usize)> {
    let mut links: Vec<(usize, usize)> = paths.iter().flatten().copied().collect();
    links.sort_unstable();
    links.dedup();
    links
}

/// Windowed serving metrics collector. `Default` is a disabled collector
/// (every recorder is a no-op) so scheduler `reset()` stays cheap; `run()`
/// installs a live one via [`TimeSeries::new`] once the horizon is known.
#[derive(Clone, Debug, Default)]
pub struct TimeSeries {
    window_s: f64,
    model_names: Vec<String>,
    links: Vec<(usize, usize)>,
    link_index: HashMap<(usize, usize), usize>,
    chiplets: usize,
    gateway: usize,
    windows: Vec<Window>,
    // Cumulative totals (kept in lock-step with the window sums).
    arrivals: u64,
    completions: u64,
    drops: u64,
    sheds: u64,
    link_busy_s: Vec<f64>,
    link_flits: Vec<u64>,
    chiplet_flits: Vec<u64>,
    end_s: f64,
    drift: Vec<DriftEvent>,
    finalized: bool,
}

impl TimeSeries {
    /// A live collector with `window_s`-second windows over the given
    /// model names, NoP links (see [`link_union`]) and package shape.
    /// `window_s` must be positive; non-positive widths fall back to 1 s.
    pub fn new(
        window_s: f64,
        model_names: Vec<String>,
        links: Vec<(usize, usize)>,
        chiplets: usize,
        gateway: usize,
    ) -> Self {
        let n_links = links.len();
        let link_index = links.iter().enumerate().map(|(i, &l)| (l, i)).collect();
        Self {
            window_s: if window_s > 0.0 { window_s } else { 1.0 },
            model_names,
            links,
            link_index,
            chiplets,
            gateway,
            link_busy_s: vec![0.0; n_links],
            link_flits: vec![0; n_links],
            chiplet_flits: vec![0; chiplets],
            ..Self::default()
        }
    }

    /// True when constructed via [`TimeSeries::new`] (recorders are live).
    pub fn is_enabled(&self) -> bool {
        self.window_s > 0.0
    }

    /// Window width in seconds (0 when disabled).
    pub fn window_s(&self) -> f64 {
        self.window_s
    }

    /// The collected windows (empty until the first recorded event).
    pub fn windows(&self) -> &[Window] {
        &self.windows
    }

    /// Model display names (window model slices align with this).
    pub fn model_names(&self) -> &[String] {
        &self.model_names
    }

    /// The NoP link axis (window `link_busy_s` aligns with this).
    pub fn links(&self) -> &[(usize, usize)] {
        &self.links
    }

    /// Drift events (populated by [`TimeSeries::finalize`]).
    pub fn drift_events(&self) -> &[DriftEvent] {
        &self.drift
    }

    /// Cumulative `(arrivals, completions, drops, sheds)`.
    pub fn totals(&self) -> (u64, u64, u64, u64) {
        (self.arrivals, self.completions, self.drops, self.sheds)
    }

    /// End-of-run time in seconds (set by [`TimeSeries::finalize`]).
    pub fn end_s(&self) -> f64 {
        self.end_s
    }

    fn window_mut(&mut self, t: f64) -> &mut Window {
        let idx = if t > 0.0 {
            ((t / self.window_s) as usize).min(MAX_WINDOWS - 1)
        } else {
            0
        };
        if idx >= self.windows.len() {
            let (models, links) = (self.model_names.len(), self.links.len());
            self.windows.resize_with(idx + 1, || Window {
                models: vec![ModelWindow::default(); models],
                link_busy_s: vec![0.0; links],
                ..Window::default()
            });
        }
        &mut self.windows[idx]
    }

    /// A request of `model` arrived at `t`.
    pub fn record_arrival(&mut self, t: f64, model: usize) {
        if !self.is_enabled() {
            return;
        }
        self.arrivals += 1;
        let w = self.window_mut(t);
        w.arrivals += 1;
        if let Some(m) = w.models.get_mut(model) {
            m.arrivals += 1;
        }
    }

    /// Queue depth observed when admitting a request at `t`.
    pub fn record_depth(&mut self, t: f64, depth: usize) {
        if !self.is_enabled() {
            return;
        }
        self.window_mut(t).depth.record(depth as f64);
    }

    /// A request of `model` was dropped at admission at `t`.
    pub fn record_drop(&mut self, t: f64, model: usize) {
        if !self.is_enabled() {
            return;
        }
        self.drops += 1;
        self.window_mut(t).drops += 1;
        let _ = model;
    }

    /// A request of `model` was shed by admission control at `t`.
    pub fn record_shed(&mut self, t: f64, model: usize) {
        if !self.is_enabled() {
            return;
        }
        self.sheds += 1;
        self.window_mut(t).sheds += 1;
        let _ = model;
    }

    /// A request of `model` completed at `t` with the given latency.
    pub fn record_completion(&mut self, t: f64, model: usize, latency_ms: f64) {
        if !self.is_enabled() {
            return;
        }
        self.completions += 1;
        let w = self.window_mut(t);
        w.completions += 1;
        if let Some(m) = w.models.get_mut(model) {
            m.completions += 1;
            m.sketch.record(latency_ms);
        }
    }

    /// NoP link `link` was busy for `busy_s` seconds serializing `flits`
    /// flits, starting at `t` (attributed whole to `t`'s window).
    pub fn record_link_busy(&mut self, t: f64, link: (usize, usize), busy_s: f64, flits: u64) {
        if !self.is_enabled() {
            return;
        }
        if let Some(&i) = self.link_index.get(&link) {
            self.link_busy_s[i] += busy_s;
            self.link_flits[i] += flits;
            self.window_mut(t).link_busy_s[i] += busy_s;
        }
    }

    /// `flits` flits were delivered to `chiplet` (heatmap endpoints).
    pub fn record_ejected(&mut self, chiplet: usize, flits: u64) {
        if !self.is_enabled() {
            return;
        }
        if let Some(c) = self.chiplet_flits.get_mut(chiplet) {
            *c += flits;
        }
    }

    /// Freeze the per-window quantile scalars and run the drift
    /// detectors. Idempotent; recorders called afterwards are ignored by
    /// the exports' contract (the schedulers finalize after draining).
    pub fn finalize(&mut self, end_s: f64) {
        if !self.is_enabled() || self.finalized {
            return;
        }
        self.finalized = true;
        self.end_s = end_s.max(0.0);
        for w in &mut self.windows {
            let mut all = QuantileSketch::new();
            for m in &mut w.models {
                if !m.sketch.is_empty() {
                    m.p50_ms = m.sketch.quantile(50.0);
                    m.p99_ms = m.sketch.quantile(99.0);
                    m.mean_ms = m.sketch.mean();
                    all.merge(&m.sketch);
                }
            }
            if !all.is_empty() {
                w.p50_ms = all.quantile(50.0);
                w.p99_ms = all.quantile(99.0);
            }
        }
        // Per-model drift: arrival rate over every window, p99 over
        // windows that completed at least one request of the model.
        for m in 0..self.model_names.len() {
            let mut rate = EwmaDetector::default();
            let mut p99 = EwmaDetector::default();
            for (wi, w) in self.windows.iter().enumerate() {
                let mw = &w.models[m];
                let t_s = wi as f64 * self.window_s;
                if let Some((baseline, sigma, up)) =
                    rate.observe(mw.arrivals as f64 / self.window_s)
                {
                    self.drift.push(DriftEvent {
                        window: wi,
                        t_s,
                        model: m,
                        metric: DriftMetric::ArrivalRate,
                        direction: if up {
                            DriftDirection::Up
                        } else {
                            DriftDirection::Down
                        },
                        value: mw.arrivals as f64 / self.window_s,
                        baseline,
                        sigma,
                    });
                }
                if mw.completions > 0 {
                    if let Some((baseline, sigma, up)) = p99.observe(mw.p99_ms) {
                        self.drift.push(DriftEvent {
                            window: wi,
                            t_s,
                            model: m,
                            metric: DriftMetric::P99,
                            direction: if up {
                                DriftDirection::Up
                            } else {
                                DriftDirection::Down
                            },
                            value: mw.p99_ms,
                            baseline,
                            sigma,
                        });
                    }
                }
            }
        }
        // Deterministic export order: by window, then model, then metric.
        self.drift.sort_by(|a, b| {
            (a.window, a.model, a.metric.name()).cmp(&(b.window, b.model, b.metric.name()))
        });
    }

    /// Deterministic JSON time series. The caller passes the
    /// `ServeReport` totals so the export carries its own reconciliation
    /// block (`totals` must mirror `report`; `scripts/check_metrics.py`
    /// and a property test gate this).
    pub fn to_json(&self, requests: usize, completed: usize, dropped: usize, shed: usize) -> String {
        let mut windows = Vec::with_capacity(self.windows.len());
        for (wi, w) in self.windows.iter().enumerate() {
            let models: Vec<String> = self
                .model_names
                .iter()
                .zip(&w.models)
                .map(|(name, m)| {
                    format!(
                        "{{\"name\":\"{}\",\"arrivals\":{},\"completions\":{},\
                         \"p50_ms\":{:.6},\"p99_ms\":{:.6},\"mean_ms\":{:.6}}}",
                        escape(name),
                        m.arrivals,
                        m.completions,
                        m.p50_ms,
                        m.p99_ms,
                        m.mean_ms
                    )
                })
                .collect();
            let links: Vec<String> = self
                .links
                .iter()
                .enumerate()
                .filter(|&(i, _)| self.link_busy_s[i] > 0.0)
                .map(|(i, &(a, b))| {
                    format!(
                        "{{\"src\":{a},\"dst\":{b},\"utilization\":{:.6}}}",
                        w.link_busy_s[i] / self.window_s
                    )
                })
                .collect();
            windows.push(format!(
                "{{\"t_s\":{:.6},\"arrivals\":{},\"completions\":{},\"drops\":{},\
                 \"sheds\":{},\"queue_depth\":{{\"mean\":{:.6},\"max\":{:.6},\"p99\":{:.6}}},\
                 \"p50_ms\":{:.6},\"p99_ms\":{:.6},\"models\":[{}],\"links\":[{}]}}",
                wi as f64 * self.window_s,
                w.arrivals,
                w.completions,
                w.drops,
                w.sheds,
                w.depth.mean(),
                w.depth.max_sample(),
                w.depth.quantile(99.0),
                w.p50_ms,
                w.p99_ms,
                models.join(","),
                links.join(",")
            ));
        }
        let drift: Vec<String> = self
            .drift
            .iter()
            .map(|d| {
                format!(
                    "{{\"window\":{},\"t_s\":{:.6},\"model\":\"{}\",\"metric\":\"{}\",\
                     \"direction\":\"{}\",\"value\":{:.6},\"baseline\":{:.6},\"sigma\":{:.6}}}",
                    d.window,
                    d.t_s,
                    escape(self.model_name(d.model)),
                    d.metric.name(),
                    d.direction.name(),
                    d.value,
                    d.baseline,
                    d.sigma
                )
            })
            .collect();
        format!(
            "{{\"window_s\":{:.6},\"end_s\":{:.6},\"windows\":[\n{}\n],\
             \"totals\":{{\"arrivals\":{},\"completions\":{},\"drops\":{},\"sheds\":{}}},\
             \"report\":{{\"requests\":{},\"completed\":{},\"dropped\":{},\"shed\":{}}},\
             \"drift_events\":[{}]}}\n",
            self.window_s,
            self.end_s,
            windows.join(",\n"),
            self.arrivals,
            self.completions,
            self.drops,
            self.sheds,
            requests,
            completed,
            dropped,
            shed,
            drift.join(",")
        )
    }

    fn model_name(&self, m: usize) -> &str {
        self.model_names.get(m).map_or("?", |s| s.as_str())
    }

    /// Prometheus-style text exposition of the run's totals, latency
    /// quantiles (from the merged window sketches), drift-event count and
    /// per-link NoP utilization. Deterministic for a given run.
    pub fn to_prom(&self, requests: usize, completed: usize, dropped: usize, shed: usize) -> String {
        let mut out = String::new();
        out.push_str("# TYPE imcnoc_requests_total counter\n");
        out.push_str(&format!("imcnoc_requests_total {requests}\n"));
        out.push_str("# TYPE imcnoc_requests_outcome_total counter\n");
        for (outcome, v) in [("completed", completed), ("dropped", dropped), ("shed", shed)] {
            out.push_str(&format!(
                "imcnoc_requests_outcome_total{{outcome=\"{outcome}\"}} {v}\n"
            ));
        }
        // Global and per-model latency quantiles from the merged sketches.
        let mut global = QuantileSketch::new();
        let mut per_model: Vec<QuantileSketch> =
            vec![QuantileSketch::new(); self.model_names.len()];
        for w in &self.windows {
            for (m, mw) in w.models.iter().enumerate() {
                global.merge(&mw.sketch);
                per_model[m].merge(&mw.sketch);
            }
        }
        out.push_str("# TYPE imcnoc_latency_ms summary\n");
        for (q, p) in [("0.5", 50.0), ("0.99", 99.0)] {
            out.push_str(&format!(
                "imcnoc_latency_ms{{quantile=\"{q}\"}} {:.6}\n",
                global.quantile(p)
            ));
        }
        out.push_str(&format!("imcnoc_latency_ms_sum {:.6}\n", global.sum()));
        out.push_str(&format!("imcnoc_latency_ms_count {}\n", global.count()));
        out.push_str("# TYPE imcnoc_model_latency_ms summary\n");
        for (name, s) in self.model_names.iter().zip(&per_model) {
            for (q, p) in [("0.5", 50.0), ("0.99", 99.0)] {
                out.push_str(&format!(
                    "imcnoc_model_latency_ms{{model=\"{}\",quantile=\"{q}\"}} {:.6}\n",
                    escape(name),
                    s.quantile(p)
                ));
            }
        }
        out.push_str("# TYPE imcnoc_windows_total counter\n");
        out.push_str(&format!("imcnoc_windows_total {}\n", self.windows.len()));
        out.push_str("# TYPE imcnoc_drift_events_total counter\n");
        out.push_str(&format!("imcnoc_drift_events_total {}\n", self.drift.len()));
        out.push_str("# TYPE imcnoc_nop_link_utilization gauge\n");
        let denom = if self.end_s > 0.0 { self.end_s } else { 1.0 };
        for (i, &(a, b)) in self.links.iter().enumerate() {
            if self.link_busy_s[i] > 0.0 {
                out.push_str(&format!(
                    "imcnoc_nop_link_utilization{{link=\"{a}->{b}\"}} {:.6}\n",
                    self.link_busy_s[i] / denom
                ));
            }
        }
        out
    }

    /// Append counter tracks to a Chrome trace: one cumulative
    /// `serving totals` track (its final values reconcile with the
    /// `otherData` report totals — gated by `scripts/check_trace.py`),
    /// one `queue depth` track (per-window mean/max), and one
    /// `nop link a-b` utilization track per link that saw traffic. Each
    /// window emits at its end time, so every track's timestamps are
    /// strictly increasing.
    pub fn counter_tracks(&self, trace: &mut ChromeTrace) {
        if !self.is_enabled() {
            return;
        }
        let (mut completed, mut dropped, mut shed) = (0u64, 0u64, 0u64);
        for (wi, w) in self.windows.iter().enumerate() {
            let ts = (wi as f64 + 1.0) * self.window_s * 1e6;
            completed += w.completions;
            dropped += w.drops;
            shed += w.sheds;
            trace.counter_int(
                "serving totals",
                ts,
                &[("completed", completed), ("dropped", dropped), ("shed", shed)],
            );
            trace.counter(
                "queue depth",
                ts,
                &[("mean", w.depth.mean()), ("max", w.depth.max_sample())],
            );
            for (i, &(a, b)) in self.links.iter().enumerate() {
                if self.link_busy_s[i] > 0.0 {
                    trace.counter(
                        &format!("nop link {a}-{b}"),
                        ts,
                        &[("utilization", w.link_busy_s[i] / self.window_s)],
                    );
                }
            }
        }
    }

    /// Synthesize a [`SimTelemetry`] from the cumulative totals so the
    /// PR 5 heatmap renderers work on serving runs. Link flits are the
    /// real recorded counts when the scheduler knew them (cycles derived
    /// from the implied per-flit serialization time); otherwise busy
    /// fractions are scaled onto a synthetic 10^6-cycle clock. Either
    /// way `link_utilization(i) == busy_s[i] / end_s` up to rounding.
    pub fn to_sim_telemetry(&self) -> SimTelemetry {
        let mut t = SimTelemetry::sized(self.links.clone(), self.chiplets.max(1));
        let total_flits: u64 = self.link_flits.iter().sum();
        let total_busy: f64 = self.link_busy_s.iter().sum();
        let end = if self.end_s > 0.0 { self.end_s } else { 1.0 };
        if total_flits > 0 {
            let cycle_s = total_busy / total_flits as f64;
            t.cycles = if cycle_s > 0.0 {
                (end / cycle_s).round() as u64
            } else {
                0
            };
            t.link_flits.copy_from_slice(&self.link_flits);
        } else if total_busy > 0.0 {
            const SCALE: f64 = 1e6;
            t.cycles = SCALE as u64;
            for (i, f) in t.link_flits.iter_mut().enumerate() {
                *f = ((self.link_busy_s[i] / end) * SCALE).round() as u64;
            }
        }
        for (c, &f) in self.chiplet_flits.iter().enumerate() {
            t.ejected[c] = f;
        }
        let delivered: u64 = self.chiplet_flits.iter().sum();
        if let Some(g) = t.injected.get_mut(self.gateway) {
            *g = delivered;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collector() -> TimeSeries {
        TimeSeries::new(
            0.1,
            vec!["A".into(), "B".into()],
            vec![(0, 1), (1, 2)],
            3,
            0,
        )
    }

    #[test]
    fn disabled_default_ignores_recorders() {
        let mut ts = TimeSeries::default();
        assert!(!ts.is_enabled());
        ts.record_arrival(0.0, 0);
        ts.record_completion(0.1, 0, 1.0);
        ts.finalize(1.0);
        assert!(ts.windows().is_empty());
        assert_eq!(ts.totals(), (0, 0, 0, 0));
    }

    #[test]
    fn events_land_in_the_right_windows_and_totals_reconcile() {
        let mut ts = collector();
        ts.record_arrival(0.01, 0); // window 0
        ts.record_depth(0.01, 1);
        ts.record_arrival(0.15, 1); // window 1
        ts.record_drop(0.15, 1);
        ts.record_arrival(0.31, 0); // window 3
        ts.record_shed(0.31, 0);
        ts.record_completion(0.09, 0, 2.0); // window 0
        ts.finalize(0.4);
        assert_eq!(ts.windows().len(), 4);
        assert_eq!(ts.windows()[0].arrivals, 1);
        assert_eq!(ts.windows()[0].completions, 1);
        assert_eq!(ts.windows()[1].drops, 1);
        assert_eq!(ts.windows()[3].sheds, 1);
        assert_eq!(ts.windows()[0].models[0].arrivals, 1);
        assert_eq!(ts.windows()[1].models[1].arrivals, 1);
        let (a, c, d, s) = ts.totals();
        assert_eq!((a, c, d, s), (3, 1, 1, 1));
        let wa: u64 = ts.windows().iter().map(|w| w.arrivals).sum();
        assert_eq!(wa, a);
        // Per-window quantiles frozen by finalize (single sample: exact).
        assert_eq!(ts.windows()[0].models[0].p50_ms, 2.0);
        assert_eq!(ts.windows()[0].p99_ms, 2.0);
    }

    #[test]
    fn link_busy_feeds_windows_totals_and_sim_telemetry() {
        let mut ts = collector();
        ts.record_link_busy(0.05, (0, 1), 0.02, 10);
        ts.record_link_busy(0.15, (0, 1), 0.04, 20);
        ts.record_link_busy(0.15, (9, 9), 1.0, 5); // unknown link ignored
        ts.record_ejected(1, 30);
        ts.finalize(0.2);
        assert_eq!(ts.windows().len(), 2);
        assert!((ts.windows()[0].link_busy_s[0] - 0.02).abs() < 1e-12);
        assert!((ts.windows()[1].link_busy_s[0] - 0.04).abs() < 1e-12);
        let telem = ts.to_sim_telemetry();
        assert_eq!(telem.link_flits[0], 30);
        assert_eq!(telem.ejected[1], 30);
        assert_eq!(telem.injected[0], 30); // gateway injects all
        // utilization == busy / end: 0.06 / 0.2 = 0.3.
        assert!((telem.link_utilization(0) - 0.3).abs() < 1e-3);
    }

    #[test]
    fn sim_telemetry_falls_back_to_synthetic_cycles_without_flits() {
        let mut ts = collector();
        ts.record_link_busy(0.0, (1, 2), 0.05, 0);
        ts.finalize(0.2);
        let telem = ts.to_sim_telemetry();
        assert_eq!(telem.cycles, 1_000_000);
        assert!((telem.link_utilization(1) - 0.25).abs() < 1e-5);
    }

    #[test]
    fn drift_detector_fires_on_a_step_change() {
        let mut ts = TimeSeries::new(1.0, vec!["A".into()], vec![], 1, 0);
        // 12 calm windows at 10 req/s, then a 5x burst.
        for w in 0..12 {
            for i in 0..10 {
                ts.record_arrival(w as f64 + i as f64 / 10.0 + 0.01, 0);
            }
        }
        for i in 0..50 {
            ts.record_arrival(12.0 + i as f64 / 50.0 + 0.001, 0);
        }
        ts.finalize(13.0);
        let events = ts.drift_events();
        assert!(
            events
                .iter()
                .any(|d| d.metric == DriftMetric::ArrivalRate
                    && d.direction == DriftDirection::Up
                    && d.window == 12),
            "no up-drift at the burst window: {events:?}"
        );
        // No event during the calm warmup plateau.
        assert!(events.iter().all(|d| d.window >= 12), "{events:?}");
    }

    #[test]
    fn constant_series_never_drifts() {
        let mut ts = TimeSeries::new(1.0, vec!["A".into()], vec![], 1, 0);
        for w in 0..40 {
            for i in 0..8 {
                ts.record_arrival(w as f64 + i as f64 / 8.0 + 0.01, 0);
                ts.record_completion(w as f64 + i as f64 / 8.0 + 0.02, 0, 5.0);
            }
        }
        ts.finalize(40.0);
        assert!(ts.drift_events().is_empty(), "{:?}", ts.drift_events());
    }

    #[test]
    fn json_export_is_deterministic_and_reconciles() {
        let mut ts = collector();
        ts.record_arrival(0.01, 0);
        ts.record_completion(0.05, 0, 1.5);
        ts.record_link_busy(0.01, (0, 1), 0.01, 4);
        ts.finalize(0.1);
        let j1 = ts.to_json(1, 1, 0, 0);
        let j2 = ts.to_json(1, 1, 0, 0);
        assert_eq!(j1, j2);
        assert!(j1.contains("\"totals\":{\"arrivals\":1,\"completions\":1"), "{j1}");
        assert!(j1.contains("\"report\":{\"requests\":1,\"completed\":1"), "{j1}");
        assert!(j1.contains("\"window_s\":0.100000"), "{j1}");
        assert!(j1.contains("\"name\":\"A\""), "{j1}");
        assert!(j1.contains("\"src\":0,\"dst\":1"), "{j1}");
    }

    #[test]
    fn prom_export_has_totals_quantiles_and_links() {
        let mut ts = collector();
        ts.record_arrival(0.01, 0);
        ts.record_completion(0.05, 0, 1.5);
        ts.record_link_busy(0.01, (0, 1), 0.01, 4);
        ts.finalize(0.1);
        let prom = ts.to_prom(1, 1, 0, 0);
        assert!(prom.contains("imcnoc_requests_total 1"), "{prom}");
        assert!(
            prom.contains("imcnoc_requests_outcome_total{outcome=\"completed\"} 1"),
            "{prom}"
        );
        assert!(
            prom.contains("imcnoc_latency_ms{quantile=\"0.99\"} 1.500000"),
            "{prom}"
        );
        assert!(
            prom.contains("imcnoc_model_latency_ms{model=\"A\",quantile=\"0.5\"} 1.500000"),
            "{prom}"
        );
        assert!(
            prom.contains("imcnoc_nop_link_utilization{link=\"0->1\"} 0.100000"),
            "{prom}"
        );
    }

    #[test]
    fn counter_tracks_are_cumulative_and_monotonic() {
        let mut ts = collector();
        ts.record_arrival(0.01, 0);
        ts.record_completion(0.05, 0, 1.0);
        ts.record_arrival(0.15, 1);
        ts.record_completion(0.18, 1, 1.0);
        ts.record_drop(0.15, 0);
        ts.record_link_busy(0.01, (0, 1), 0.01, 2);
        ts.finalize(0.2);
        let mut trace = ChromeTrace::new();
        ts.counter_tracks(&mut trace);
        let json = trace.to_json();
        // Final cumulative totals: 2 completed, 1 dropped, 0 shed.
        assert!(
            json.contains("\"completed\":2,\"dropped\":1,\"shed\":0"),
            "{json}"
        );
        assert!(json.contains("\"name\":\"queue depth\""), "{json}");
        assert!(json.contains("\"name\":\"nop link 0-1\""), "{json}");
    }
}
