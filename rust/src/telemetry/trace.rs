//! Chrome trace-event JSON export (the `chrome://tracing` / Perfetto
//! format): serving spans become per-chiplet tracks of `ingress` / `queue`
//! / `service` slices, rejected requests become instants, per-request flow
//! arrows ("s"/"f" pairs keyed by request index) connect admission to
//! service start, and per-chiplet queue depths become counter series. All
//! floats are emitted with fixed
//! precision so the same run always serializes to the identical byte
//! string (the determinism contract extends PR 4's replay guarantee to the
//! telemetry layer).

use super::registry::escape;
use super::span::{RequestSpan, SpanOutcome, NO_CHIPLET};

/// Microsecond timestamp with fixed sub-microsecond precision
/// (deterministic across runs, unlike shortest-round-trip floats combined
/// with accumulated state).
fn us(v: f64) -> String {
    format!("{v:.3}")
}

/// An append-only Chrome trace-event log. Events are serialized eagerly to
/// JSON fragments; [`ChromeTrace::to_json`] wraps them in the object form
/// (`traceEvents` + `otherData`) Perfetto accepts.
#[derive(Clone, Debug, Default)]
pub struct ChromeTrace {
    events: Vec<String>,
    meta: Vec<(String, u64)>,
}

impl ChromeTrace {
    /// Empty trace.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of trace events recorded.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when no event has been recorded.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    fn args_json(args: &[(&str, String)]) -> String {
        let parts: Vec<String> = args
            .iter()
            .map(|(k, v)| format!("\"{}\":\"{}\"", escape(k), escape(v)))
            .collect();
        format!("{{{}}}", parts.join(","))
    }

    /// A complete ("X") event: a slice of `dur_us` on thread `tid`.
    pub fn complete(
        &mut self,
        name: &str,
        cat: &str,
        tid: u64,
        ts_us: f64,
        dur_us: f64,
        args: &[(&str, String)],
    ) {
        self.events.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
             \"pid\":1,\"tid\":{tid},\"args\":{}}}",
            escape(name),
            escape(cat),
            us(ts_us),
            us(dur_us),
            Self::args_json(args)
        ));
    }

    /// An instant ("i") event on thread `tid`.
    pub fn instant(
        &mut self,
        name: &str,
        cat: &str,
        tid: u64,
        ts_us: f64,
        args: &[(&str, String)],
    ) {
        self.events.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{},\
             \"pid\":1,\"tid\":{tid},\"args\":{}}}",
            escape(name),
            escape(cat),
            us(ts_us),
            Self::args_json(args)
        ));
    }

    /// A counter ("C") event: one sample of each named series.
    pub fn counter(&mut self, name: &str, ts_us: f64, series: &[(&str, f64)]) {
        let parts: Vec<String> = series
            .iter()
            .map(|(k, v)| format!("\"{}\":{}", escape(k), us(*v)))
            .collect();
        self.events.push(format!(
            "{{\"name\":\"{}\",\"ph\":\"C\",\"ts\":{},\"pid\":1,\"tid\":0,\"args\":{{{}}}}}",
            escape(name),
            us(ts_us),
            parts.join(",")
        ));
    }

    /// A counter ("C") event with integer series values (no decimal
    /// point), for counts whose exports must reconcile exactly — e.g.
    /// the cumulative `serving totals` track checked by
    /// `scripts/check_trace.py` against the `otherData` report totals.
    pub fn counter_int(&mut self, name: &str, ts_us: f64, series: &[(&str, u64)]) {
        let parts: Vec<String> = series
            .iter()
            .map(|(k, v)| format!("\"{}\":{v}", escape(k)))
            .collect();
        self.events.push(format!(
            "{{\"name\":\"{}\",\"ph\":\"C\",\"ts\":{},\"pid\":1,\"tid\":0,\"args\":{{{}}}}}",
            escape(name),
            us(ts_us),
            parts.join(",")
        ));
    }

    /// A flow-start ("s") event: the tail of a causal arrow `id`, anchored
    /// inside the slice that encloses `ts_us` on `tid`.
    pub fn flow_start(&mut self, name: &str, cat: &str, id: u64, tid: u64, ts_us: f64) {
        self.events.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"s\",\"id\":{id},\"ts\":{},\
             \"pid\":1,\"tid\":{tid}}}",
            escape(name),
            escape(cat),
            us(ts_us),
        ));
    }

    /// A flow-finish ("f") event: the head of causal arrow `id`.
    /// `bp: "e"` binds it to the enclosing slice (Perfetto's recommended
    /// binding point for next-slice arrows).
    pub fn flow_finish(&mut self, name: &str, cat: &str, id: u64, tid: u64, ts_us: f64) {
        self.events.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"f\",\"bp\":\"e\",\"id\":{id},\"ts\":{},\
             \"pid\":1,\"tid\":{tid}}}",
            escape(name),
            escape(cat),
            us(ts_us),
        ));
    }

    /// A metadata ("M") event: `kind` is `process_name` or `thread_name`.
    pub fn name_track(&mut self, kind: &str, tid: u64, name: &str) {
        self.events.push(format!(
            "{{\"name\":\"{}\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
             \"args\":{{\"name\":\"{}\"}}}}",
            escape(kind),
            escape(name)
        ));
    }

    /// Attach a reconciliation total to the export's `otherData` object
    /// (e.g. `completed`, `dropped`, `shed` from the `ServeReport`).
    pub fn set_meta(&mut self, key: &str, value: u64) {
        if let Some(e) = self.meta.iter_mut().find(|(k, _)| k == key) {
            e.1 = value;
        } else {
            self.meta.push((key.to_string(), value));
        }
    }

    /// Serialize to the Chrome trace object form. Event order is exactly
    /// insertion order; `otherData` keys are sorted.
    pub fn to_json(&self) -> String {
        let mut meta: Vec<&(String, u64)> = self.meta.iter().collect();
        meta.sort_by(|a, b| a.0.cmp(&b.0));
        let other: Vec<String> = meta
            .iter()
            .map(|(k, v)| format!("\"{}\":{v}", escape(k)))
            .collect();
        format!(
            "{{\"traceEvents\":[\n{}\n],\"displayTimeUnit\":\"ms\",\"otherData\":{{{}}}}}\n",
            self.events.join(",\n"),
            other.join(",")
        )
    }
}

/// Convert serving spans into a Chrome trace: track 0 carries admission
/// instants (`dropped`/`shed`), track `c + 1` carries chiplet `c`'s
/// `ingress` → `queue` → `service` slices, and a `queue c` counter series
/// tracks each chiplet's queue depth. `model_names` maps span model
/// indices to display names.
pub fn spans_to_trace(spans: &[RequestSpan], model_names: &[&str]) -> ChromeTrace {
    let mut t = ChromeTrace::new();
    t.name_track("process_name", 0, "imcnoc serving");
    t.name_track("thread_name", 0, "admission");
    let mut chiplets: Vec<usize> = spans
        .iter()
        .filter(|s| s.chiplet != NO_CHIPLET)
        .map(|s| s.chiplet)
        .collect();
    chiplets.sort_unstable();
    chiplets.dedup();
    for &c in &chiplets {
        t.name_track("thread_name", c as u64 + 1, &format!("chiplet {c}"));
    }
    let name_of = |m: usize| -> String {
        model_names
            .get(m)
            .map_or_else(|| format!("model{m}"), |n| n.to_string())
    };
    for (req, s) in spans.iter().enumerate() {
        let args = [("model", name_of(s.model)), ("req", req.to_string())];
        match s.outcome {
            SpanOutcome::Completed => {
                let tid = s.chiplet as u64 + 1;
                t.complete(
                    "ingress",
                    "serve",
                    tid,
                    s.arrival * 1e6,
                    s.ingress_s() * 1e6,
                    &args,
                );
                t.complete(
                    "queue",
                    "serve",
                    tid,
                    s.ready * 1e6,
                    s.queue_s() * 1e6,
                    &args,
                );
                t.complete(
                    "service",
                    "serve",
                    tid,
                    s.service_start * 1e6,
                    s.service_s() * 1e6,
                    &args,
                );
                // Causal flow arrow: admission ("s", anchored in the
                // ingress slice) → service start ("f", anchored in the
                // service slice), so Perfetto draws each request's path
                // through the pipeline. `id` is the request index —
                // unique per arrow within one export.
                t.flow_start("request", "serve", req as u64, tid, s.arrival * 1e6);
                t.flow_finish("request", "serve", req as u64, tid, s.service_start * 1e6);
            }
            SpanOutcome::Dropped => t.instant("dropped", "admission", 0, s.arrival * 1e6, &args),
            SpanOutcome::Shed => t.instant("shed", "admission", 0, s.arrival * 1e6, &args),
        }
    }
    // Queue-depth counters: +1 at admission, -1 at service start.
    for &c in &chiplets {
        let mut deltas: Vec<(f64, i64)> = Vec::new();
        for s in spans {
            if s.chiplet == c && s.outcome == SpanOutcome::Completed {
                deltas.push((s.arrival, 1));
                deltas.push((s.service_start, -1));
            }
        }
        deltas.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut depth = 0i64;
        let name = format!("queue c{c}");
        for (at, d) in deltas {
            depth += d;
            t.counter(&name, at * 1e6, &[("depth", depth as f64)]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::span::RequestSpan;

    fn sample_spans() -> Vec<RequestSpan> {
        let mut a = RequestSpan::admitted(0, 0, 0.0, 0.1);
        a.service_start = 0.2;
        a.complete = 0.5;
        let mut b = RequestSpan::admitted(1, 2, 0.1, 0.15);
        b.service_start = 0.3;
        b.complete = 0.9;
        vec![
            a,
            b,
            RequestSpan::rejected(0, 0.2, SpanOutcome::Dropped),
            RequestSpan::rejected(1, 0.3, SpanOutcome::Shed),
        ]
    }

    #[test]
    fn trace_shape_and_reconciliation_counts() {
        let spans = sample_spans();
        let mut trace = spans_to_trace(&spans, &["MLP", "LeNet-5"]);
        trace.set_meta("completed", 2);
        trace.set_meta("dropped", 1);
        trace.set_meta("shed", 1);
        let json = trace.to_json();
        assert_eq!(json.matches("\"name\":\"service\"").count(), 2, "{json}");
        assert_eq!(json.matches("\"name\":\"dropped\"").count(), 1);
        assert_eq!(json.matches("\"name\":\"shed\"").count(), 1);
        assert!(json.contains("\"thread_name\""));
        assert!(json.contains("chiplet 2"));
        assert!(json.contains("\"otherData\":{\"completed\":2,\"dropped\":1,\"shed\":1}"));
        assert!(json.contains("\"model\":\"LeNet-5\""));
        // Counter events track the queue depth.
        assert!(json.contains("queue c0"), "{json}");
    }

    #[test]
    fn flow_events_pair_up_per_completed_request() {
        let spans = sample_spans();
        let json = spans_to_trace(&spans, &["MLP", "LeNet-5"]).to_json();
        // Two completed requests → two "s"/"f" pairs; rejected requests
        // get none.
        assert_eq!(json.matches("\"ph\":\"s\"").count(), 2, "{json}");
        assert_eq!(json.matches("\"ph\":\"f\"").count(), 2, "{json}");
        assert_eq!(json.matches("\"bp\":\"e\"").count(), 2, "{json}");
        // Ids are the request indices (0 and 1), present on both ends.
        assert_eq!(json.matches("\"id\":0").count(), 2, "{json}");
        assert_eq!(json.matches("\"id\":1").count(), 2, "{json}");
        // Flow timestamps reuse the slice formatter, so the "s" anchor is
        // byte-equal to the ingress slice's ts.
        assert!(json.contains("\"ph\":\"s\",\"id\":0,\"ts\":0.000"), "{json}");
        assert!(
            json.contains("\"ph\":\"f\",\"bp\":\"e\",\"id\":0,\"ts\":200000.000"),
            "{json}"
        );
    }

    #[test]
    fn export_is_byte_deterministic() {
        let spans = sample_spans();
        let j1 = spans_to_trace(&spans, &["A", "B"]).to_json();
        let j2 = spans_to_trace(&spans, &["A", "B"]).to_json();
        assert_eq!(j1, j2);
    }

    #[test]
    fn counter_int_emits_integer_args() {
        let mut t = ChromeTrace::new();
        t.counter_int("serving totals", 1000.0, &[("completed", 5), ("dropped", 0)]);
        let json = t.to_json();
        assert!(
            json.contains("\"args\":{\"completed\":5,\"dropped\":0}"),
            "{json}"
        );
        assert!(json.contains("\"ph\":\"C\""), "{json}");
    }

    #[test]
    fn names_are_escaped() {
        let mut t = ChromeTrace::new();
        t.complete("a\"b", "c\\d", 0, 1.0, 2.0, &[("k", "v\n".to_string())]);
        let json = t.to_json();
        assert!(json.contains("a\\\"b"), "{json}");
        assert!(json.contains("c\\\\d"), "{json}");
        assert!(json.contains("\\n"), "{json}");
        assert_eq!(t.len(), 1);
        assert!(!t.is_empty());
    }

    #[test]
    fn unknown_model_index_falls_back() {
        let spans = vec![RequestSpan::rejected(7, 0.0, SpanOutcome::Dropped)];
        let json = spans_to_trace(&spans, &[]).to_json();
        assert!(json.contains("model7"), "{json}");
    }
}
