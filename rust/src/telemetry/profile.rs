//! Simulator self-profiling: process-wide memo-cache hit rates, engine
//! throughput counters and wall-clock phase timers, dumped by
//! `repro … --profile`.
//!
//! The counters exist so bench regressions are diagnosable: a slow sweep
//! with a near-zero drain-cache hit rate points at cache-key churn; a high
//! engine cycle count with few runs points at saturation budgets. All
//! counters are lock-free relaxed atomics (the hot paths pay one
//! `fetch_add` per *run*, never per cycle); phase timers take a mutex only
//! on scope exit.
//!
//! Wall-clock numbers never feed a deterministic export (Chrome traces,
//! explain reports, experiment tables) — they surface only through the
//! human-facing `--profile` dump, so timer jitter cannot break golden
//! tests.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::registry::Registry;

static DRAIN_HITS: AtomicU64 = AtomicU64::new(0);
static DRAIN_MISSES: AtomicU64 = AtomicU64::new(0);
static DRAIN_EVICTIONS: AtomicU64 = AtomicU64::new(0);
static SAT_HITS: AtomicU64 = AtomicU64::new(0);
static SAT_MISSES: AtomicU64 = AtomicU64::new(0);
static SAT_EVICTIONS: AtomicU64 = AtomicU64::new(0);
static SURR_HITS: AtomicU64 = AtomicU64::new(0);
static SURR_MISSES: AtomicU64 = AtomicU64::new(0);
static SURR_FITS: AtomicU64 = AtomicU64::new(0);
static SURR_FALLBACKS: AtomicU64 = AtomicU64::new(0);
static ENGINE_RUNS: AtomicU64 = AtomicU64::new(0);
static ENGINE_CYCLES: AtomicU64 = AtomicU64::new(0);

/// Accumulated wall-clock phases: name → (calls, total seconds).
fn phases() -> &'static Mutex<Vec<(String, u64, f64)>> {
    static PHASES: OnceLock<Mutex<Vec<(String, u64, f64)>>> = OnceLock::new();
    PHASES.get_or_init(|| Mutex::new(Vec::new()))
}

/// Record one drain-cache lookup ([`crate::sim::memo::drain_makespan`]).
pub(crate) fn note_drain(hit: bool) {
    let c = if hit { &DRAIN_HITS } else { &DRAIN_MISSES };
    c.fetch_add(1, Ordering::Relaxed);
}

/// Record one drain-cache eviction (cache at capacity).
pub(crate) fn note_drain_eviction() {
    DRAIN_EVICTIONS.fetch_add(1, Ordering::Relaxed);
}

/// Record one saturation-cache lookup ([`crate::sim::memo`]).
pub(crate) fn note_sat(hit: bool) {
    let c = if hit { &SAT_HITS } else { &SAT_MISSES };
    c.fetch_add(1, Ordering::Relaxed);
}

/// Record one saturation-cache eviction.
pub(crate) fn note_sat_eviction() {
    SAT_EVICTIONS.fetch_add(1, Ordering::Relaxed);
}

/// Record one surrogate-cache lookup ([`crate::sim::surrogate`]).
pub(crate) fn note_surrogate(hit: bool) {
    let c = if hit { &SURR_HITS } else { &SURR_MISSES };
    c.fetch_add(1, Ordering::Relaxed);
}

/// Record one successful surrogate anchor fit (the sims behind it run
/// under the `surrogate.fit` phase timer).
pub(crate) fn note_surrogate_fit() {
    SURR_FITS.fetch_add(1, Ordering::Relaxed);
}

/// Record one surrogate refusal: a query the fitted curve could not
/// answer, sending the caller back to the full simulator.
pub(crate) fn note_surrogate_fallback() {
    SURR_FALLBACKS.fetch_add(1, Ordering::Relaxed);
}

/// Record one completed engine run and the cycles it simulated
/// (called once per [`crate::sim::engine::run_engine`]).
pub(crate) fn note_engine_run(cycles: u64) {
    ENGINE_RUNS.fetch_add(1, Ordering::Relaxed);
    ENGINE_CYCLES.fetch_add(cycles, Ordering::Relaxed);
}

/// RAII wall-clock timer for one named phase; the elapsed time is folded
/// into the process-wide profile on drop. Create via [`phase`].
pub struct PhaseTimer {
    name: String,
    start: Instant,
}

impl Drop for PhaseTimer {
    fn drop(&mut self) {
        let dt = self.start.elapsed().as_secs_f64();
        let mut ph = phases().lock().expect("profile phase lock");
        if let Some(p) = ph.iter_mut().find(|(n, _, _)| n == &self.name) {
            p.1 += 1;
            p.2 += dt;
        } else {
            ph.push((self.name.clone(), 1, dt));
        }
    }
}

/// Start timing a named phase; hold the returned guard for the phase's
/// extent (e.g. `let _t = profile::phase("serve.run");`).
pub fn phase(name: &str) -> PhaseTimer {
    PhaseTimer {
        name: name.to_string(),
        start: Instant::now(),
    }
}

/// Snapshot every profile metric into a [`Registry`]
/// (`profile.memo.*`, `profile.engine.*`, `profile.phase.*`).
pub fn snapshot() -> Registry {
    let mut reg = Registry::default();
    reg.add("profile.memo.drain.hits", DRAIN_HITS.load(Ordering::Relaxed));
    reg.add("profile.memo.drain.misses", DRAIN_MISSES.load(Ordering::Relaxed));
    reg.add(
        "profile.memo.drain.evictions",
        DRAIN_EVICTIONS.load(Ordering::Relaxed),
    );
    reg.add("profile.memo.sat.hits", SAT_HITS.load(Ordering::Relaxed));
    reg.add("profile.memo.sat.misses", SAT_MISSES.load(Ordering::Relaxed));
    reg.add(
        "profile.memo.sat.evictions",
        SAT_EVICTIONS.load(Ordering::Relaxed),
    );
    reg.add(
        "profile.memo.surrogate.hits",
        SURR_HITS.load(Ordering::Relaxed),
    );
    reg.add(
        "profile.memo.surrogate.misses",
        SURR_MISSES.load(Ordering::Relaxed),
    );
    reg.add(
        "profile.memo.surrogate.fits",
        SURR_FITS.load(Ordering::Relaxed),
    );
    reg.add(
        "profile.memo.surrogate.fallbacks",
        SURR_FALLBACKS.load(Ordering::Relaxed),
    );
    reg.add("profile.engine.runs", ENGINE_RUNS.load(Ordering::Relaxed));
    reg.add("profile.engine.cycles", ENGINE_CYCLES.load(Ordering::Relaxed));
    let ph = phases().lock().expect("profile phase lock");
    for (name, calls, secs) in ph.iter() {
        reg.add(&format!("profile.phase.{name}.calls"), *calls);
        reg.add(
            &format!("profile.phase.{name}.us"),
            (secs * 1e6).round() as u64,
        );
    }
    reg
}

/// Human-readable profile dump, the `--profile` stdout report.
pub fn text() -> String {
    let rate = |h: u64, m: u64| {
        let total = h + m;
        if total == 0 {
            0.0
        } else {
            100.0 * h as f64 / total as f64
        }
    };
    let dh = DRAIN_HITS.load(Ordering::Relaxed);
    let dm = DRAIN_MISSES.load(Ordering::Relaxed);
    let sh = SAT_HITS.load(Ordering::Relaxed);
    let sm = SAT_MISSES.load(Ordering::Relaxed);
    let mut out = String::with_capacity(512);
    out.push_str("simulator profile\n");
    out.push_str(&format!(
        "  memo drain: {dh} hits / {dm} misses ({:.1}% hit), {} evictions\n",
        rate(dh, dm),
        DRAIN_EVICTIONS.load(Ordering::Relaxed)
    ));
    out.push_str(&format!(
        "  memo sat:   {sh} hits / {sm} misses ({:.1}% hit), {} evictions\n",
        rate(sh, sm),
        SAT_EVICTIONS.load(Ordering::Relaxed)
    ));
    let uh = SURR_HITS.load(Ordering::Relaxed);
    let um = SURR_MISSES.load(Ordering::Relaxed);
    out.push_str(&format!(
        "  memo surr:  {uh} hits / {um} misses ({:.1}% hit), {} fits, {} fallbacks\n",
        rate(uh, um),
        SURR_FITS.load(Ordering::Relaxed),
        SURR_FALLBACKS.load(Ordering::Relaxed)
    ));
    out.push_str(&format!(
        "  engine:     {} runs, {} cycles simulated\n",
        ENGINE_RUNS.load(Ordering::Relaxed),
        ENGINE_CYCLES.load(Ordering::Relaxed)
    ));
    let ph = phases().lock().expect("profile phase lock");
    if !ph.is_empty() {
        out.push_str("  phases:\n");
        let mut sorted: Vec<_> = ph.iter().collect();
        sorted.sort_by(|a, b| a.0.cmp(&b.0));
        for (name, calls, secs) in sorted {
            out.push_str(&format!(
                "    {name:<24} {calls:>6} calls  {:>10.3} ms total\n",
                secs * 1e3
            ));
        }
    }
    out
}

/// Zero every profile metric (test isolation; the counters are
/// process-wide, so concurrent tests may re-bump them immediately).
pub fn reset() {
    for c in [
        &DRAIN_HITS,
        &DRAIN_MISSES,
        &DRAIN_EVICTIONS,
        &SAT_HITS,
        &SAT_MISSES,
        &SAT_EVICTIONS,
        &SURR_HITS,
        &SURR_MISSES,
        &SURR_FITS,
        &SURR_FALLBACKS,
        &ENGINE_RUNS,
        &ENGINE_CYCLES,
    ] {
        c.store(0, Ordering::Relaxed);
    }
    phases().lock().expect("profile phase lock").clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot() {
        // Deltas only: other tests share the process-wide counters.
        let before = snapshot();
        let b = |n: &str| before.counter(n).unwrap_or(0);
        note_drain(true);
        note_drain(false);
        note_drain_eviction();
        note_sat(true);
        note_sat(false);
        note_sat_eviction();
        note_surrogate(true);
        note_surrogate(false);
        note_surrogate_fit();
        note_surrogate_fallback();
        note_engine_run(123);
        let after = snapshot();
        let a = |n: &str| after.counter(n).unwrap_or(0);
        assert!(a("profile.memo.drain.hits") >= b("profile.memo.drain.hits") + 1);
        assert!(a("profile.memo.drain.misses") >= b("profile.memo.drain.misses") + 1);
        assert!(a("profile.memo.drain.evictions") >= b("profile.memo.drain.evictions") + 1);
        assert!(a("profile.memo.sat.hits") >= b("profile.memo.sat.hits") + 1);
        assert!(a("profile.memo.sat.evictions") >= b("profile.memo.sat.evictions") + 1);
        assert!(a("profile.memo.surrogate.hits") >= b("profile.memo.surrogate.hits") + 1);
        assert!(a("profile.memo.surrogate.misses") >= b("profile.memo.surrogate.misses") + 1);
        assert!(a("profile.memo.surrogate.fits") >= b("profile.memo.surrogate.fits") + 1);
        assert!(a("profile.memo.surrogate.fallbacks") >= b("profile.memo.surrogate.fallbacks") + 1);
        assert!(a("profile.engine.runs") >= b("profile.engine.runs") + 1);
        assert!(a("profile.engine.cycles") >= b("profile.engine.cycles") + 123);
        let dump = text();
        assert!(dump.contains("memo drain:"));
        assert!(dump.contains("memo surr:"));
        assert!(dump.contains("engine:"));
    }

    #[test]
    fn phase_timer_records_on_drop() {
        {
            let _t = phase("test.unique.phase");
            std::hint::black_box(0u64);
        }
        let reg = snapshot();
        assert!(reg.counter("profile.phase.test.unique.phase.calls").unwrap_or(0) >= 1);
        assert!(reg.counter("profile.phase.test.unique.phase.us").is_some());
        let dump = text();
        assert!(dump.contains("test.unique.phase"));
    }
}
