//! Zero-cost-when-disabled instrumentation for the flit simulators and the
//! serving schedulers (DESIGN.md §5).
//!
//! Three pillars, no external dependencies (consistent with the offline
//! vendored-shim policy):
//!
//! * [`registry`] — named counters and log2-bucket histograms
//!   ([`Registry`], [`Histogram`]) plus [`SimTelemetry`], the dense
//!   per-link flit counters both [`crate::noc::sim::NocSim`] and
//!   [`crate::nop::sim::NopSim`] fill in when built with
//!   `.instrument(true)`. Disabled (the default) the simulators pay one
//!   branch per hook site and allocate nothing.
//! * [`span`] — request lifecycle spans ([`RequestSpan`]): admission →
//!   NoP ingress → queue wait → chiplet service → completion/drop/shed
//!   timestamps recorded by both serving schedulers and rolled up into the
//!   per-model latency breakdown on
//!   [`crate::coordinator::server::ServeReport`].
//! * [`heatmap`] + [`trace`] — exporters: per-topology link-utilization
//!   heatmaps (text grid + JSON, `repro chiplet --heatmap`) and a Chrome
//!   trace-event JSON writer ([`ChromeTrace`], loadable in Perfetto /
//!   `chrome://tracing`, `repro serve --trace-out <path>`).

pub mod heatmap;
pub mod registry;
pub mod span;
pub mod trace;

pub use heatmap::{heatmap_json, heatmap_text};
pub use registry::{Histogram, Registry, SimTelemetry};
pub use span::{RequestSpan, SpanOutcome};
pub use trace::{spans_to_trace, ChromeTrace};
