//! Zero-cost-when-disabled instrumentation for the flit simulators and the
//! serving schedulers (DESIGN.md §5).
//!
//! Seven pillars, no external dependencies (consistent with the offline
//! vendored-shim policy):
//!
//! * [`registry`] — named counters and log2-bucket histograms
//!   ([`Registry`], [`Histogram`]) plus [`SimTelemetry`], the dense
//!   per-link flit counters both [`crate::noc::sim::NocSim`] and
//!   [`crate::nop::sim::NopSim`] fill in when built with
//!   `.instrument(true)`. Disabled (the default) the simulators pay one
//!   branch per hook site and allocate nothing.
//! * [`span`] — request lifecycle spans ([`RequestSpan`]): admission →
//!   NoP ingress → queue wait → chiplet service → completion/drop/shed
//!   timestamps recorded by both serving schedulers and rolled up into the
//!   per-model latency breakdown on
//!   [`crate::coordinator::server::ServeReport`].
//! * [`sketch`] — a bounded-memory streaming quantile sketch
//!   ([`QuantileSketch`], log-bucket with 16 sub-buckets per octave) that
//!   replaces unbounded latency vectors in the serving planes: O(1)
//!   memory per stream, percentiles within a documented relative-error
//!   bound.
//! * [`timeseries`] — fixed-width windowed serving metrics
//!   ([`TimeSeries`]): per-window arrival/completion/drop/shed counters,
//!   queue-depth samples, per-model p50/p99 from sketches, per-link NoP
//!   busy time (heatmap over time), and per-model EWMA drift detectors
//!   emitting typed [`DriftEvent`]s. Exported as deterministic JSON or
//!   Prometheus text (`repro serve --metrics-out`), and as Chrome trace
//!   counter tracks.
//! * [`heatmap`] + [`trace`] — exporters: per-topology link-utilization
//!   heatmaps (text grid + JSON, `repro chiplet --heatmap` and
//!   `repro serve --heatmap`) and a Chrome trace-event JSON writer
//!   ([`ChromeTrace`], loadable in Perfetto / `chrome://tracing`,
//!   `repro serve --trace-out <path>`) with flow events linking each
//!   request's lifecycle slices.
//! * [`attribution`] — causal critical-path attribution: per-request
//!   hop-by-hop [`IngressTrace`]s recorded by the serving schedulers,
//!   folded into a ranked [`BlameReport`] (top links / chiplets / layers
//!   by critical-path ms, deadline-miss attribution) behind
//!   `repro serve … --explain[-out]`.
//! * [`profile`] — simulator self-profiling: process-wide memo-cache
//!   hit/miss/eviction counters, engine run/cycle totals and wall-clock
//!   phase timers, dumped by `repro … --profile`.

pub mod attribution;
pub mod heatmap;
pub mod profile;
pub mod registry;
pub mod sketch;
pub mod span;
pub mod timeseries;
pub mod trace;

pub use attribution::{BlameReport, IngressTrace, LayerBlame};
pub use heatmap::{heatmap_json, heatmap_text};
pub use registry::{Histogram, Registry, SimTelemetry};
pub use sketch::QuantileSketch;
pub use span::{RequestSpan, SpanOutcome};
pub use timeseries::{link_union, DriftEvent, DriftMetric, TimeSeries};
pub use trace::{spans_to_trace, ChromeTrace};
