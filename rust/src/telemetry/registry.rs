//! Named counters, log2-bucket histograms, and the dense per-link flit
//! telemetry the simulators collect when instrumented.
//!
//! The registry is deliberately tiny: insertion-ordered `Vec`s (metric
//! counts are small, and deterministic export order matters more than O(1)
//! lookup) and hand-rolled JSON export (no serde in the offline build).

/// Number of log2 buckets: bucket 0 is `[0, 1)`, bucket `i >= 1` is
/// `[2^(i-1), 2^i)`, the last bucket absorbs everything larger.
const BUCKETS: usize = 24;

/// Fixed-shape log2 histogram for occupancies, queue depths and span
/// durations. Recording is O(1) and allocation-free after the first sample.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    max: f64,
}

impl Histogram {
    /// Record one sample. Negative/NaN samples land in bucket 0.
    pub fn record(&mut self, v: f64) {
        if self.counts.is_empty() {
            self.counts = vec![0; BUCKETS];
        }
        let idx = if v >= 1.0 {
            ((v.log2().floor() as usize) + 1).min(BUCKETS - 1)
        } else {
            0
        };
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean of all samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Largest sample recorded (0 when empty).
    pub fn max_sample(&self) -> f64 {
        self.max
    }

    /// Per-bucket counts (empty until the first sample).
    pub fn buckets(&self) -> &[u64] {
        &self.counts
    }

    /// Lower edge of bucket `i` (0, then powers of two).
    pub fn bucket_floor(i: usize) -> f64 {
        if i == 0 {
            0.0
        } else {
            2f64.powi(i as i32 - 1)
        }
    }

    /// Estimate of the `p`-th percentile (`p` in 0..=100) from the log2
    /// buckets: the bucket holding the target rank is read back at its
    /// arithmetic midpoint (bucket 0 as 0), clamped to the exact tracked
    /// maximum. Coarse by design — one-octave buckets — which is enough
    /// for the queue-depth tails the serving time series reports; the
    /// fine-grained latency path uses
    /// [`crate::telemetry::sketch::QuantileSketch`] instead.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = (p / 100.0).clamp(0.0, 1.0) * (self.total - 1) as f64;
        let target = rank.floor() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > target {
                let est = if i == 0 {
                    0.0
                } else {
                    1.5 * Self::bucket_floor(i)
                };
                return est.min(self.max);
            }
        }
        self.max
    }

    /// `{"count":..,"mean":..,"max":..,"buckets":[..]}` with trailing empty
    /// buckets trimmed. Fixed-precision floats keep the export
    /// byte-deterministic.
    pub fn to_json(&self) -> String {
        let last = match self.counts.iter().rposition(|&c| c != 0) {
            Some(i) => i + 1,
            None => 0,
        };
        let buckets: Vec<String> = self.counts[..last].iter().map(|c| c.to_string()).collect();
        format!(
            "{{\"count\":{},\"mean\":{:.6},\"max\":{:.6},\"buckets\":[{}]}}",
            self.total,
            self.mean(),
            self.max,
            buckets.join(",")
        )
    }
}

/// Insertion-ordered registry of named counters and histograms.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    counters: Vec<(String, u64)>,
    histograms: Vec<(String, Histogram)>,
}

impl Registry {
    /// Add `delta` to counter `name`, creating it at zero first.
    pub fn add(&mut self, name: &str, delta: u64) {
        if let Some(i) = self.counters.iter().position(|(n, _)| n == name) {
            self.counters[i].1 += delta;
        } else {
            self.counters.push((name.to_string(), delta));
        }
    }

    /// Current value of counter `name`.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    /// Mutable access to histogram `name`, creating it empty first.
    pub fn histogram(&mut self, name: &str) -> &mut Histogram {
        if let Some(i) = self.histograms.iter().position(|(n, _)| n == name) {
            return &mut self.histograms[i].1;
        }
        self.histograms.push((name.to_string(), Histogram::default()));
        &mut self.histograms.last_mut().unwrap().1
    }

    /// Read-only lookup of histogram `name`.
    pub fn get_histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// True when no metric has been touched.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.histograms.is_empty()
    }

    /// `{"counters":{..},"histograms":{..}}`, keys sorted for determinism.
    pub fn to_json(&self) -> String {
        let mut counters: Vec<&(String, u64)> = self.counters.iter().collect();
        counters.sort_by(|a, b| a.0.cmp(&b.0));
        let c: Vec<String> = counters
            .iter()
            .map(|(n, v)| format!("\"{}\":{v}", escape(n)))
            .collect();
        let mut hists: Vec<&(String, Histogram)> = self.histograms.iter().collect();
        hists.sort_by(|a, b| a.0.cmp(&b.0));
        let h: Vec<String> = hists
            .iter()
            .map(|(n, hist)| format!("\"{}\":{}", escape(n), hist.to_json()))
            .collect();
        format!(
            "{{\"counters\":{{{}}},\"histograms\":{{{}}}}}",
            c.join(","),
            h.join(",")
        )
    }
}

/// Minimal JSON string escape (metric names are ASCII identifiers, but a
/// stray quote must never corrupt the export).
pub(crate) fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Dense per-link flit counters a flit simulator fills in while running
/// instrumented (`.instrument(true)`). `links`/`link_flits` align by index;
/// `injected`/`ejected` are per terminal (NoC) or per chiplet (NoP) and sum
/// to the run's `SimStats` totals (property-tested).
#[derive(Clone, Debug, Default)]
pub struct SimTelemetry {
    /// Directed links `(from, to)` in the simulator's deterministic order.
    pub links: Vec<(usize, usize)>,
    /// Flits that traversed each link (index-aligned with `links`).
    pub link_flits: Vec<u64>,
    /// Flits generated per source terminal/chiplet.
    pub injected: Vec<u64>,
    /// Flits delivered per destination terminal/chiplet.
    pub ejected: Vec<u64>,
    /// Receive-buffer occupancy observed at flit arrival.
    pub occupancy: Histogram,
    /// Cycles the run simulated (denominator for link utilization).
    pub cycles: u64,
}

impl SimTelemetry {
    /// Empty telemetry sized for `links` and `terminals` endpoints.
    pub fn sized(links: Vec<(usize, usize)>, terminals: usize) -> Self {
        let n = links.len();
        Self {
            links,
            link_flits: vec![0; n],
            injected: vec![0; terminals],
            ejected: vec![0; terminals],
            occupancy: Histogram::default(),
            cycles: 0,
        }
    }

    /// Sum of per-source injected flits (== `SimStats::injected`).
    pub fn injected_total(&self) -> u64 {
        self.injected.iter().sum()
    }

    /// Sum of per-destination delivered flits (== `SimStats::delivered`).
    pub fn ejected_total(&self) -> u64 {
        self.ejected.iter().sum()
    }

    /// Total link traversals (every flit crosses >= 1 link).
    pub fn transit_total(&self) -> u64 {
        self.link_flits.iter().sum()
    }

    /// Fraction of cycles link `i` carried a flit (each directed link
    /// starts at most one flit per cycle, so this is in `[0, 1]`).
    pub fn link_utilization(&self, i: usize) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.link_flits[i] as f64 / self.cycles as f64
        }
    }

    /// Index of the busiest link, by flit count (None when linkless).
    pub fn peak_link(&self) -> Option<usize> {
        (0..self.links.len()).max_by_key(|&i| (self.link_flits[i], std::cmp::Reverse(i)))
    }

    /// Fold the dense counters into a named [`Registry`] under `prefix`
    /// (e.g. `nop.link.0->1`).
    pub fn registry(&self, prefix: &str) -> Registry {
        let mut reg = Registry::default();
        reg.add(&format!("{prefix}.injected"), self.injected_total());
        reg.add(&format!("{prefix}.ejected"), self.ejected_total());
        for (i, &(a, b)) in self.links.iter().enumerate() {
            reg.add(&format!("{prefix}.link.{a}->{b}"), self.link_flits[i]);
        }
        *reg.histogram(&format!("{prefix}.occupancy")) = self.occupancy.clone();
        reg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_moments() {
        let mut h = Histogram::default();
        for v in [0.0, 0.5, 1.0, 1.9, 2.0, 7.9, 8.0, 1e9] {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.buckets()[0], 2); // 0.0, 0.5
        assert_eq!(h.buckets()[1], 2); // [1, 2)
        assert_eq!(h.buckets()[2], 1); // [2, 4)
        assert_eq!(h.buckets()[4], 1); // [8, 16)
        assert_eq!(h.buckets()[BUCKETS - 1], 1); // 1e9 clamps to the top
        assert!(h.mean() > 0.0 && h.max_sample() == 1e9);
        assert_eq!(Histogram::bucket_floor(0), 0.0);
        assert_eq!(Histogram::bucket_floor(3), 4.0);
        let json = h.to_json();
        assert!(json.starts_with("{\"count\":8,"), "{json}");
    }

    #[test]
    fn histogram_quantile_tracks_bucket_tails() {
        let mut h = Histogram::default();
        assert_eq!(h.quantile(99.0), 0.0);
        for _ in 0..90 {
            h.record(0.0);
        }
        for _ in 0..10 {
            h.record(9.0); // bucket [8, 16), midpoint 12, clamped to 9
        }
        assert_eq!(h.quantile(50.0), 0.0);
        assert_eq!(h.quantile(99.0), 9.0);
        let mut one = Histogram::default();
        one.record(3.0);
        assert_eq!(one.quantile(50.0), 3.0);
    }

    #[test]
    fn registry_counters_and_json_sorted() {
        let mut r = Registry::default();
        r.add("b.flits", 2);
        r.add("a.flits", 1);
        r.add("b.flits", 3);
        r.histogram("occ").record(4.0);
        assert_eq!(r.counter("b.flits"), Some(5));
        assert_eq!(r.counter("a.flits"), Some(1));
        assert_eq!(r.counter("missing"), None);
        assert_eq!(r.get_histogram("occ").unwrap().count(), 1);
        let json = r.to_json();
        // Sorted keys: a.flits before b.flits.
        let a = json.find("a.flits").unwrap();
        let b = json.find("b.flits").unwrap();
        assert!(a < b, "{json}");
        assert!(!r.is_empty());
    }

    #[test]
    fn sim_telemetry_totals_and_utilization() {
        let mut t = SimTelemetry::sized(vec![(0, 1), (1, 0)], 2);
        t.injected[0] = 10;
        t.ejected[1] = 10;
        t.link_flits[0] = 10;
        t.cycles = 40;
        assert_eq!(t.injected_total(), 10);
        assert_eq!(t.ejected_total(), 10);
        assert_eq!(t.transit_total(), 10);
        assert_eq!(t.peak_link(), Some(0));
        assert!((t.link_utilization(0) - 0.25).abs() < 1e-12);
        assert_eq!(t.link_utilization(1), 0.0);
        let reg = t.registry("nop");
        assert_eq!(reg.counter("nop.link.0->1"), Some(10));
        assert_eq!(reg.counter("nop.injected"), Some(10));
    }

    #[test]
    fn escape_handles_specials() {
        assert_eq!(escape("a\"b\\c\n"), "a\\\"b\\\\c\\n");
        assert_eq!(escape("plain"), "plain");
    }
}
