//! Causal request tracing with critical-path attribution.
//!
//! The serving schedulers record *where each request's time went*: the
//! hop-by-hop NoP link occupancy waits of the ingress walk (an
//! [`IngressTrace`] per offered request, index-aligned with the lifecycle
//! [`RequestSpan`]s), the queue wait and the chiplet service. This module
//! folds those per-request decompositions into a ranked **blame report**:
//! which package links, chiplets and layers account for the most
//! critical-path milliseconds, and — for deadline-carrying mixes — which
//! component each deadline miss is attributable to.
//!
//! The decomposition is exact by construction. The ingress walk computes
//! `ready = arrival + Σ link_waits + hops · hop_s + ser_s` (each wait is
//! `max(0, link_free − head)`, recorded as the walk runs), so per-request
//! component sums reconcile with
//! [`ServeReport`](crate::coordinator::server::ServeReport)'s
//! `mean_{ingress,queue,service}_ms` breakdown — property-tested in both
//! schedulers and gated in CI by `scripts/check_explain.py`.
//!
//! Everything here is deterministic: aggregation is keyed by ordered maps,
//! ties break on link/chiplet ids, and the JSON export uses fixed-precision
//! formatting, so `--explain-out` files are byte-identical per
//! `[serving] seed` (golden-tested).

use std::collections::BTreeMap;

use crate::telemetry::span::{RequestSpan, SpanOutcome};

/// Per-request NoP ingress decomposition, recorded by the scheduler's
/// ingress walk. One trace per *offered* request, index-aligned with the
/// scheduler's [`RequestSpan`]s; rejected requests keep an empty default
/// so the alignment survives drops and sheds.
#[derive(Clone, Debug, Default)]
pub struct IngressTrace {
    /// Occupancy wait on each directed link of the gateway route, in walk
    /// order, seconds (`max(0, link_free − head)` at that hop).
    pub waits: Vec<((usize, usize), f64)>,
    /// Payload serialization occupancy of one link, seconds (0 when the
    /// request served on the gateway chiplet itself).
    pub ser_s: f64,
    /// Total fixed per-hop SerDes propagation, seconds.
    pub prop_s: f64,
}

impl IngressTrace {
    /// Sum of every component, seconds — equals the span's
    /// `ingress_s()` (`ready − arrival`) up to floating-point rounding.
    pub fn total_s(&self) -> f64 {
        self.waits.iter().map(|&(_, w)| w).sum::<f64>() + self.ser_s + self.prop_s
    }

    /// The final link of the route (where serialization completes), if the
    /// request left the gateway at all.
    pub fn last_link(&self) -> Option<(usize, usize)> {
        self.waits.last().map(|&(l, _)| l)
    }
}

/// Per-layer replica cost breakdown (one frame through one chiplet
/// replica), for the layer section of the blame report.
#[derive(Clone, Debug)]
pub struct LayerBlame {
    /// Zoo model the layer belongs to.
    pub model: String,
    /// Layer name within the model.
    pub layer: String,
    /// Compute cycles of the layer, milliseconds at the core clock.
    pub compute_ms: f64,
    /// On-chiplet communication cycles of the layer, milliseconds.
    pub comm_ms: f64,
    /// Communication time not hidden behind compute, milliseconds —
    /// `max(0, comm − compute)`, the paper's exposed-latency notion.
    pub exposed_ms: f64,
}

/// Aggregate blame carried by one directed package link.
#[derive(Clone, Debug)]
pub struct LinkBlame {
    /// Directed NoP link `(from, to)`.
    pub link: (usize, usize),
    /// Total occupancy wait charged to this link, milliseconds.
    pub wait_ms: f64,
    /// Total payload serialization charged to this link (the final hop of
    /// each route serializes the payload), milliseconds.
    pub serialization_ms: f64,
    /// Completed requests that waited (> 0 s) on this link.
    pub blocked_requests: usize,
    /// Deadline misses whose dominant component was this link.
    pub miss_count: usize,
}

impl LinkBlame {
    /// Total critical-path milliseconds charged to this link.
    pub fn critical_ms(&self) -> f64 {
        self.wait_ms + self.serialization_ms
    }

    /// `"from-to"` label, as used in reports and experiment tables.
    pub fn label(&self) -> String {
        format!("{}-{}", self.link.0, self.link.1)
    }
}

/// Aggregate blame carried by one serving chiplet.
#[derive(Clone, Debug)]
pub struct ChipletBlame {
    /// Chiplet id.
    pub chiplet: usize,
    /// Total queue wait of requests served here, milliseconds.
    pub queue_ms: f64,
    /// Total service (incl. egress) of requests served here, milliseconds.
    pub service_ms: f64,
    /// Completed requests served on this chiplet.
    pub requests: usize,
    /// Deadline misses whose dominant component was this chiplet's queue
    /// or service.
    pub miss_count: usize,
}

/// Per-model roll-up with deadline-miss attribution.
#[derive(Clone, Debug)]
pub struct ModelBlame {
    /// Model name.
    pub model: String,
    /// Requests offered for this model.
    pub requests: usize,
    /// Requests completed.
    pub completed: usize,
    /// Completed requests that exceeded their deadline.
    pub missed: usize,
    /// Total ingress (waits + serialization + propagation), milliseconds.
    pub ingress_ms: f64,
    /// Total queue wait, milliseconds.
    pub queue_ms: f64,
    /// Total service, milliseconds.
    pub service_ms: f64,
    /// The component holding the most of this model's time: `"queue"`,
    /// `"service"`, `"link from-to"`, or `"ingress"` (gateway-local).
    pub top_component: String,
}

/// Ranked critical-path blame report over one serving run.
#[derive(Clone, Debug)]
pub struct BlameReport {
    /// Run span (first arrival to last completion), milliseconds.
    pub horizon_ms: f64,
    /// Requests offered.
    pub requests: usize,
    /// Requests completed.
    pub completed: usize,
    /// Completed requests that exceeded their deadline.
    pub missed: usize,
    /// Total link occupancy wait over completed requests, milliseconds.
    pub wait_ms: f64,
    /// Total payload serialization, milliseconds.
    pub serialization_ms: f64,
    /// Total fixed hop propagation, milliseconds.
    pub propagation_ms: f64,
    /// Total queue wait, milliseconds.
    pub queue_ms: f64,
    /// Total service (incl. egress), milliseconds.
    pub service_ms: f64,
    /// Links ranked by critical-path milliseconds (descending, ties by
    /// link id).
    pub links: Vec<LinkBlame>,
    /// Chiplets ranked by queue + service milliseconds.
    pub chiplets: Vec<ChipletBlame>,
    /// Per-model roll-ups, in model-index order.
    pub models: Vec<ModelBlame>,
    /// Per-layer replica cost breakdown, ranked by exposed milliseconds.
    pub layers: Vec<LayerBlame>,
}

/// The dominant (largest) component of one request's critical path.
enum Dominant {
    Link((usize, usize)),
    Chiplet(usize),
    Other,
}

impl BlameReport {
    /// Build the report from a run's spans and ingress traces.
    ///
    /// `spans` and `traces` are index-aligned (one per offered request);
    /// `names[m]` / `deadline_s[m]` describe model index `m`
    /// (`f64::INFINITY` = no deadline); `layers` is the per-layer replica
    /// breakdown of every served model.
    pub fn build(
        spans: &[RequestSpan],
        traces: &[IngressTrace],
        names: &[String],
        deadline_s: &[f64],
        layers: &[LayerBlame],
    ) -> Self {
        let mut links: BTreeMap<(usize, usize), LinkBlame> = BTreeMap::new();
        let mut chiplets: BTreeMap<usize, ChipletBlame> = BTreeMap::new();
        let mut models: Vec<ModelBlame> = names
            .iter()
            .map(|n| ModelBlame {
                model: n.clone(),
                requests: 0,
                completed: 0,
                missed: 0,
                ingress_ms: 0.0,
                queue_ms: 0.0,
                service_ms: 0.0,
                top_component: "-".to_string(),
            })
            .collect();
        // Per-model per-link critical ms, for the top_component labels.
        let mut model_links: Vec<BTreeMap<(usize, usize), f64>> =
            vec![BTreeMap::new(); names.len()];

        let empty = IngressTrace::default();
        let mut totals = [0.0f64; 5]; // wait, ser, prop, queue, service
        let mut horizon_s = 0.0f64;
        let mut completed = 0usize;
        let mut missed = 0usize;
        for (i, span) in spans.iter().enumerate() {
            horizon_s = horizon_s.max(span.arrival);
            if span.model < models.len() {
                models[span.model].requests += 1;
            }
            if span.outcome != SpanOutcome::Completed {
                continue;
            }
            completed += 1;
            horizon_s = horizon_s.max(span.complete);
            let trace = traces.get(i).unwrap_or(&empty);
            let queue_s = span.queue_s();
            let service_s = span.service_s();
            let miss = deadline_s
                .get(span.model)
                .is_some_and(|&d| d.is_finite() && span.latency_s() > d);
            if miss {
                missed += 1;
            }

            // Per-link waits + serialization on the final hop.
            let mut wait_sum = 0.0f64;
            let mut dominant = Dominant::Other;
            let mut dominant_v = f64::NEG_INFINITY;
            for &(link, w) in &trace.waits {
                wait_sum += w;
                let lb = links.entry(link).or_insert_with(|| LinkBlame {
                    link,
                    wait_ms: 0.0,
                    serialization_ms: 0.0,
                    blocked_requests: 0,
                    miss_count: 0,
                });
                lb.wait_ms += w * 1e3;
                if w > 0.0 {
                    lb.blocked_requests += 1;
                }
                if w > dominant_v {
                    dominant_v = w;
                    dominant = Dominant::Link(link);
                }
            }
            if let Some(last) = trace.last_link() {
                links
                    .get_mut(&last)
                    .expect("last link was inserted by the wait loop")
                    .serialization_ms += trace.ser_s * 1e3;
                if trace.ser_s > dominant_v {
                    dominant_v = trace.ser_s;
                    dominant = Dominant::Link(last);
                }
            }
            if trace.prop_s > dominant_v {
                dominant_v = trace.prop_s;
                dominant = Dominant::Other;
            }
            if queue_s > dominant_v {
                dominant_v = queue_s;
                dominant = Dominant::Chiplet(span.chiplet);
            }
            if service_s > dominant_v {
                dominant = Dominant::Chiplet(span.chiplet);
            }

            let cb = chiplets.entry(span.chiplet).or_insert_with(|| ChipletBlame {
                chiplet: span.chiplet,
                queue_ms: 0.0,
                service_ms: 0.0,
                requests: 0,
                miss_count: 0,
            });
            cb.queue_ms += queue_s * 1e3;
            cb.service_ms += service_s * 1e3;
            cb.requests += 1;

            totals[0] += wait_sum * 1e3;
            totals[1] += trace.ser_s * 1e3;
            totals[2] += trace.prop_s * 1e3;
            totals[3] += queue_s * 1e3;
            totals[4] += service_s * 1e3;

            if span.model < models.len() {
                let mb = &mut models[span.model];
                mb.completed += 1;
                mb.ingress_ms += trace.total_s() * 1e3;
                mb.queue_ms += queue_s * 1e3;
                mb.service_ms += service_s * 1e3;
                for &(link, w) in &trace.waits {
                    *model_links[span.model].entry(link).or_insert(0.0) += w * 1e3;
                }
                if let Some(last) = trace.last_link() {
                    *model_links[span.model].entry(last).or_insert(0.0) += trace.ser_s * 1e3;
                }
                if miss {
                    mb.missed += 1;
                }
            }
            if miss {
                match dominant {
                    Dominant::Link(l) => {
                        links
                            .get_mut(&l)
                            .expect("dominant link was aggregated above")
                            .miss_count += 1;
                    }
                    Dominant::Chiplet(c) => {
                        chiplets
                            .get_mut(&c)
                            .expect("dominant chiplet was aggregated above")
                            .miss_count += 1;
                    }
                    Dominant::Other => {}
                }
            }
        }

        for (m, mb) in models.iter_mut().enumerate() {
            if mb.completed == 0 {
                continue;
            }
            mb.top_component = if mb.queue_ms >= mb.service_ms && mb.queue_ms >= mb.ingress_ms {
                "queue".to_string()
            } else if mb.service_ms >= mb.ingress_ms {
                "service".to_string()
            } else {
                match model_links[m]
                    .iter()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                {
                    Some((&(a, b), _)) => format!("link {a}-{b}"),
                    None => "ingress".to_string(),
                }
            };
        }

        let mut links: Vec<LinkBlame> = links.into_values().collect();
        links.sort_by(|a, b| {
            b.critical_ms()
                .partial_cmp(&a.critical_ms())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.link.cmp(&b.link))
        });
        let mut chiplets: Vec<ChipletBlame> = chiplets.into_values().collect();
        chiplets.sort_by(|a, b| {
            (b.queue_ms + b.service_ms)
                .partial_cmp(&(a.queue_ms + a.service_ms))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.chiplet.cmp(&b.chiplet))
        });
        let mut layers = layers.to_vec();
        layers.sort_by(|a, b| {
            b.exposed_ms
                .partial_cmp(&a.exposed_ms)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.model.cmp(&b.model))
                .then(a.layer.cmp(&b.layer))
        });

        Self {
            horizon_ms: horizon_s * 1e3,
            requests: spans.len(),
            completed,
            missed,
            wait_ms: totals[0],
            serialization_ms: totals[1],
            propagation_ms: totals[2],
            queue_ms: totals[3],
            service_ms: totals[4],
            links,
            chiplets,
            models,
            layers,
        }
    }

    /// Label of the most-blamed link (`"from-to"`), or `"-"` when the run
    /// never left the gateway — the experiments' `explain` column.
    pub fn top_link(&self) -> String {
        match self.links.first() {
            Some(l) if l.critical_ms() > 0.0 => l.label(),
            _ => "-".to_string(),
        }
    }

    /// Byte-deterministic JSON export (schema `imcnoc-explain-v1`),
    /// the `--explain-out` artifact gated by `scripts/check_explain.py`.
    pub fn to_json(&self) -> String {
        let ms = |v: f64| format!("{v:.6}");
        let mut out = String::with_capacity(4096);
        out.push_str("{\n\"schema\": \"imcnoc-explain-v1\",\n");
        out.push_str(&format!("\"horizon_ms\": {},\n", ms(self.horizon_ms)));
        out.push_str(&format!(
            "\"requests\": {}, \"completed\": {}, \"missed\": {},\n",
            self.requests, self.completed, self.missed
        ));
        out.push_str(&format!(
            "\"components_ms\": {{\"wait\": {}, \"serialization\": {}, \"propagation\": {}, \
             \"queue\": {}, \"service\": {}}},\n",
            ms(self.wait_ms),
            ms(self.serialization_ms),
            ms(self.propagation_ms),
            ms(self.queue_ms),
            ms(self.service_ms)
        ));
        out.push_str("\"links\": [");
        for (i, l) in self.links.iter().enumerate() {
            let sep = if i + 1 == self.links.len() { "" } else { "," };
            out.push_str(&format!(
                "\n  {{\"link\": \"{}\", \"wait_ms\": {}, \"serialization_ms\": {}, \
                 \"critical_ms\": {}, \"blocked_requests\": {}, \"miss_count\": {}}}{}",
                l.label(),
                ms(l.wait_ms),
                ms(l.serialization_ms),
                ms(l.critical_ms()),
                l.blocked_requests,
                l.miss_count,
                sep
            ));
        }
        out.push_str("],\n\"chiplets\": [");
        for (i, c) in self.chiplets.iter().enumerate() {
            let sep = if i + 1 == self.chiplets.len() { "" } else { "," };
            out.push_str(&format!(
                "\n  {{\"chiplet\": {}, \"queue_ms\": {}, \"service_ms\": {}, \
                 \"requests\": {}, \"miss_count\": {}}}{}",
                c.chiplet,
                ms(c.queue_ms),
                ms(c.service_ms),
                c.requests,
                c.miss_count,
                sep
            ));
        }
        out.push_str("],\n\"models\": [");
        for (i, m) in self.models.iter().enumerate() {
            let sep = if i + 1 == self.models.len() { "" } else { "," };
            out.push_str(&format!(
                "\n  {{\"model\": \"{}\", \"requests\": {}, \"completed\": {}, \"missed\": {}, \
                 \"ingress_ms\": {}, \"queue_ms\": {}, \"service_ms\": {}, \
                 \"top_component\": \"{}\"}}{}",
                super::registry::escape(&m.model),
                m.requests,
                m.completed,
                m.missed,
                ms(m.ingress_ms),
                ms(m.queue_ms),
                ms(m.service_ms),
                super::registry::escape(&m.top_component),
                sep
            ));
        }
        out.push_str("],\n\"layers\": [");
        for (i, l) in self.layers.iter().enumerate() {
            let sep = if i + 1 == self.layers.len() { "" } else { "," };
            out.push_str(&format!(
                "\n  {{\"model\": \"{}\", \"layer\": \"{}\", \"compute_ms\": {}, \
                 \"comm_ms\": {}, \"exposed_ms\": {}}}{}",
                super::registry::escape(&l.model),
                super::registry::escape(&l.layer),
                ms(l.compute_ms),
                ms(l.comm_ms),
                ms(l.exposed_ms),
                sep
            ));
        }
        out.push_str("]\n}\n");
        out
    }

    /// Human-readable blame table, the `--explain` stdout report.
    pub fn to_text(&self) -> String {
        let mut out = String::with_capacity(2048);
        out.push_str(&format!(
            "critical-path blame: {} requests, {} completed, {} deadline misses, \
             horizon {:.3} ms\n",
            self.requests, self.completed, self.missed, self.horizon_ms
        ));
        out.push_str(&format!(
            "  totals (ms): wait {:.3} | serialization {:.3} | propagation {:.3} | \
             queue {:.3} | service {:.3}\n",
            self.wait_ms, self.serialization_ms, self.propagation_ms, self.queue_ms,
            self.service_ms
        ));
        out.push_str("  top links by critical-path ms:\n");
        out.push_str("    link       wait_ms      ser_ms  critical_ms  blocked  misses\n");
        for l in self.links.iter().take(8) {
            out.push_str(&format!(
                "    {:<8} {:>9.3} {:>11.3} {:>12.3} {:>8} {:>7}\n",
                l.label(),
                l.wait_ms,
                l.serialization_ms,
                l.critical_ms(),
                l.blocked_requests,
                l.miss_count
            ));
        }
        out.push_str("  chiplets by queue+service ms:\n");
        out.push_str("    chiplet   queue_ms  service_ms  requests  misses\n");
        for c in self.chiplets.iter().take(8) {
            out.push_str(&format!(
                "    {:<7} {:>10.3} {:>11.3} {:>9} {:>7}\n",
                c.chiplet, c.queue_ms, c.service_ms, c.requests, c.miss_count
            ));
        }
        out.push_str("  models:\n");
        for m in &self.models {
            out.push_str(&format!(
                "    {:<12} {:>4}/{:<4} done, {} missed; ingress {:.3} ms, queue {:.3} ms, \
                 service {:.3} ms; top: {}\n",
                m.model,
                m.completed,
                m.requests,
                m.missed,
                m.ingress_ms,
                m.queue_ms,
                m.service_ms,
                m.top_component
            ));
        }
        if !self.layers.is_empty() {
            out.push_str("  layers by exposed comm ms (per frame):\n");
            for l in self.layers.iter().take(5) {
                out.push_str(&format!(
                    "    {:<12} {:<16} compute {:.6} | comm {:.6} | exposed {:.6}\n",
                    l.model, l.layer, l.compute_ms, l.comm_ms, l.exposed_ms
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::span::mean_breakdown_ms;

    fn span(model: usize, c: usize, arrival: f64, ready: f64, start: f64, done: f64) -> RequestSpan {
        let mut s = RequestSpan::admitted(model, c, arrival, ready);
        s.service_start = start;
        s.complete = done;
        s
    }

    /// Two completed requests + one drop with hand-built traces.
    fn fixture() -> (Vec<RequestSpan>, Vec<IngressTrace>) {
        let spans = vec![
            // req 0: waits 2 ms on (0,1); ready = 0 + .002 + .001 + .003.
            span(0, 1, 0.0, 0.006, 0.010, 0.020),
            RequestSpan::rejected(0, 0.001, SpanOutcome::Dropped),
            // req 2: no waits, pure serialization + propagation.
            span(1, 2, 0.002, 0.006, 0.006, 0.011),
        ];
        let traces = vec![
            IngressTrace {
                waits: vec![((0, 1), 0.002)],
                ser_s: 0.003,
                prop_s: 0.001,
            },
            IngressTrace::default(),
            IngressTrace {
                waits: vec![((0, 2), 0.0)],
                ser_s: 0.003,
                prop_s: 0.001,
            },
        ];
        (spans, traces)
    }

    #[test]
    fn build_aggregates_components_and_ranks_links() {
        let (spans, traces) = fixture();
        let names = vec!["a".to_string(), "b".to_string()];
        let r = BlameReport::build(&spans, &traces, &names, &[f64::INFINITY; 2], &[]);
        assert_eq!(r.requests, 3);
        assert_eq!(r.completed, 2);
        assert_eq!(r.missed, 0);
        assert!((r.wait_ms - 2.0).abs() < 1e-9);
        assert!((r.serialization_ms - 6.0).abs() < 1e-9);
        assert!((r.propagation_ms - 2.0).abs() < 1e-9);
        // Link (0,1): 2 ms wait + 3 ms ser beats (0,2): 3 ms ser.
        assert_eq!(r.links.len(), 2);
        assert_eq!(r.links[0].link, (0, 1));
        assert!((r.links[0].critical_ms() - 5.0).abs() < 1e-9);
        assert_eq!(r.links[0].blocked_requests, 1);
        assert_eq!(r.links[1].blocked_requests, 0);
        assert_eq!(r.top_link(), "0-1");
        // Chiplet roll-up: req 0 queued 4 ms on chiplet 1.
        let c1 = r.chiplets.iter().find(|c| c.chiplet == 1).unwrap();
        assert!((c1.queue_ms - 4.0).abs() < 1e-9);
        assert_eq!(r.models[0].requests, 2);
        assert_eq!(r.models[0].completed, 1);
        assert_eq!(r.models[1].top_component, "service");
    }

    #[test]
    fn component_sums_reconcile_with_mean_breakdown() {
        let (spans, traces) = fixture();
        let (ing, que, ser) = mean_breakdown_ms(&spans, None);
        let names = vec!["a".to_string(), "b".to_string()];
        let r = BlameReport::build(&spans, &traces, &names, &[f64::INFINITY; 2], &[]);
        let n = r.completed as f64;
        let ingress_total = r.wait_ms + r.serialization_ms + r.propagation_ms;
        assert!((ingress_total / n - ing).abs() < 1e-9);
        assert!((r.queue_ms / n - que).abs() < 1e-9);
        assert!((r.service_ms / n - ser).abs() < 1e-9);
    }

    #[test]
    fn deadline_misses_attribute_to_dominant_component() {
        let (spans, traces) = fixture();
        // Req 0 (latency 20 ms) misses a 15 ms deadline; its dominant
        // component is the 4 ms queue wait on chiplet 1. Req 2 (9 ms) hits.
        let names = vec!["a".to_string(), "b".to_string()];
        let r = BlameReport::build(&spans, &traces, &names, &[0.015, 0.015], &[]);
        assert_eq!(r.missed, 1);
        assert_eq!(r.models[0].missed, 1);
        assert_eq!(r.models[1].missed, 0);
        let c1 = r.chiplets.iter().find(|c| c.chiplet == 1).unwrap();
        assert_eq!(c1.miss_count, 1);
        assert_eq!(r.links.iter().map(|l| l.miss_count).sum::<usize>(), 0);
    }

    #[test]
    fn json_is_byte_deterministic_and_schema_tagged() {
        let (spans, traces) = fixture();
        let names = vec!["a".to_string(), "b".to_string()];
        let layers = vec![LayerBlame {
            model: "a".to_string(),
            layer: "fc1".to_string(),
            compute_ms: 1.0,
            comm_ms: 2.0,
            exposed_ms: 1.0,
        }];
        let build = || BlameReport::build(&spans, &traces, &names, &[f64::INFINITY; 2], &layers);
        let a = build().to_json();
        let b = build().to_json();
        assert_eq!(a, b);
        assert!(a.starts_with("{\n\"schema\": \"imcnoc-explain-v1\","));
        assert!(a.contains("\"links\": ["));
        assert!(a.contains("\"layer\": \"fc1\""));
        assert!(a.ends_with("}\n"));
        let text = build().to_text();
        assert!(text.contains("critical-path blame"));
        assert!(text.contains("0-1"));
    }

    #[test]
    fn empty_run_produces_empty_but_valid_report() {
        let r = BlameReport::build(&[], &[], &[], &[], &[]);
        assert_eq!(r.requests, 0);
        assert_eq!(r.top_link(), "-");
        assert!(r.to_json().contains("\"requests\": 0"));
        // A span that never left the gateway blames no link.
        let spans = vec![span(0, 0, 0.0, 0.0, 0.001, 0.002)];
        let traces = vec![IngressTrace::default()];
        let names = vec!["a".to_string()];
        let r = BlameReport::build(&spans, &traces, &names, &[f64::INFINITY], &[]);
        assert!(r.links.is_empty());
        assert_eq!(r.top_link(), "-");
    }
}
