//! Request lifecycle spans: every request a serving scheduler sees carries
//! the timestamps of its phases — admission, NoP ingress, queue wait,
//! chiplet service — or the instant it was dropped/shed. Spans are the raw
//! material for the per-model latency breakdown on
//! [`crate::coordinator::server::ServeReport`] and for the Chrome trace
//! export ([`super::trace::spans_to_trace`]).

/// Marker for spans that never reached a chiplet (dropped/shed requests).
pub const NO_CHIPLET: usize = usize::MAX;

/// How a request's lifecycle ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanOutcome {
    /// Served to completion.
    Completed,
    /// Rejected at admission: the routed queue was full.
    Dropped,
    /// Rejected by deadline-aware admission: it could no longer hit.
    Shed,
}

/// One request's lifecycle, in seconds on the serving clock. Phase order
/// on this scheduler is admission → NoP ingress (`arrival..ready`) → queue
/// wait (`ready..service_start`) → chiplet service incl. egress
/// (`service_start..complete`). Rejected requests collapse every timestamp
/// onto `arrival`.
#[derive(Clone, Copy, Debug)]
pub struct RequestSpan {
    /// Mix model index (0 for the single-model scheduler).
    pub model: usize,
    /// Serving chiplet, or [`NO_CHIPLET`] when never routed.
    pub chiplet: usize,
    /// Admission time (the request's arrival event).
    pub arrival: f64,
    /// NoP ingress complete: the input payload reached the chiplet.
    pub ready: f64,
    /// Service start (batch slot granted).
    pub service_start: f64,
    /// Completion (result egressed), or `arrival` when rejected.
    pub complete: f64,
    /// How the lifecycle ended.
    pub outcome: SpanOutcome,
}

impl RequestSpan {
    /// Span for a request admitted to `chiplet` whose ingress finishes at
    /// `ready`; service timestamps are filled in when the batch starts.
    pub fn admitted(model: usize, chiplet: usize, arrival: f64, ready: f64) -> Self {
        Self {
            model,
            chiplet,
            arrival,
            ready,
            service_start: ready,
            complete: ready,
            outcome: SpanOutcome::Completed,
        }
    }

    /// Zero-duration span for a rejected request.
    pub fn rejected(model: usize, arrival: f64, outcome: SpanOutcome) -> Self {
        Self {
            model,
            chiplet: NO_CHIPLET,
            arrival,
            ready: arrival,
            service_start: arrival,
            complete: arrival,
            outcome,
        }
    }

    /// NoP ingress time, seconds.
    pub fn ingress_s(&self) -> f64 {
        self.ready - self.arrival
    }

    /// Queue wait between ingress completion and service start, seconds.
    pub fn queue_s(&self) -> f64 {
        self.service_start - self.ready
    }

    /// Chiplet service (occupancy + egress), seconds.
    pub fn service_s(&self) -> f64 {
        self.complete - self.service_start
    }

    /// End-to-end latency, seconds.
    pub fn latency_s(&self) -> f64 {
        self.complete - self.arrival
    }
}

/// Mean phase durations in milliseconds over the *completed* spans of one
/// model (or all models with `model = None`): `(ingress, queue, service)`.
pub fn mean_breakdown_ms(spans: &[RequestSpan], model: Option<usize>) -> (f64, f64, f64) {
    let mut n = 0u64;
    let (mut ing, mut que, mut ser) = (0.0, 0.0, 0.0);
    for s in spans {
        if s.outcome != SpanOutcome::Completed || model.is_some_and(|m| m != s.model) {
            continue;
        }
        n += 1;
        ing += s.ingress_s();
        que += s.queue_s();
        ser += s.service_s();
    }
    if n == 0 {
        (0.0, 0.0, 0.0)
    } else {
        let k = 1e3 / n as f64;
        (ing * k, que * k, ser * k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_durations_add_up() {
        let mut s = RequestSpan::admitted(0, 2, 1.0, 1.25);
        s.service_start = 1.5;
        s.complete = 2.0;
        assert!((s.ingress_s() - 0.25).abs() < 1e-12);
        assert!((s.queue_s() - 0.25).abs() < 1e-12);
        assert!((s.service_s() - 0.5).abs() < 1e-12);
        assert!((s.latency_s() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejected_spans_are_zero_duration() {
        let s = RequestSpan::rejected(3, 7.0, SpanOutcome::Shed);
        assert_eq!(s.chiplet, NO_CHIPLET);
        assert_eq!(s.latency_s(), 0.0);
        assert_eq!(s.outcome, SpanOutcome::Shed);
    }

    #[test]
    fn breakdown_averages_completed_only() {
        let mut a = RequestSpan::admitted(0, 0, 0.0, 0.1);
        a.service_start = 0.3;
        a.complete = 0.4;
        let mut b = RequestSpan::admitted(1, 1, 0.0, 0.3);
        b.service_start = 0.5;
        b.complete = 1.0;
        let dropped = RequestSpan::rejected(0, 0.0, SpanOutcome::Dropped);
        let spans = [a, b, dropped];
        let (ing, que, ser) = mean_breakdown_ms(&spans, None);
        assert!((ing - 200.0).abs() < 1e-9, "{ing}");
        assert!((que - 200.0).abs() < 1e-9, "{que}");
        assert!((ser - 300.0).abs() < 1e-9, "{ser}");
        let (ing0, _, _) = mean_breakdown_ms(&spans, Some(0));
        assert!((ing0 - 100.0).abs() < 1e-9, "{ing0}");
        assert_eq!(mean_breakdown_ms(&spans, Some(9)), (0.0, 0.0, 0.0));
    }
}
