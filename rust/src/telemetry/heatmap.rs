//! Per-topology link-utilization heatmaps rendered from a
//! [`SimTelemetry`]: a text grid (mesh) or ranked link list (ring/P2P) for
//! the terminal, plus a machine-readable JSON export. This is how "which
//! mesh link saturates first" becomes directly visible
//! (`repro chiplet --heatmap`).

use std::collections::HashMap;

use super::registry::SimTelemetry;
use crate::nop::topology::{NopNetwork, NopTopology};

/// Utilization as an integer percent, from a `(from, to)` lookup.
fn pct(map: &HashMap<(usize, usize), u64>, a: usize, b: usize, cycles: u64) -> Option<u64> {
    if cycles == 0 {
        return None;
    }
    // A grid edge carries two directed links; show the hotter direction.
    let f = map.get(&(a, b)).copied();
    let r = map.get(&(b, a)).copied();
    match (f, r) {
        (None, None) => None,
        (f, r) => {
            let flits = f.unwrap_or(0).max(r.unwrap_or(0));
            Some((100.0 * flits as f64 / cycles as f64).round() as u64)
        }
    }
}

fn flit_map(telem: &SimTelemetry) -> HashMap<(usize, usize), u64> {
    telem
        .links
        .iter()
        .zip(&telem.link_flits)
        .map(|(&l, &f)| (l, f))
        .collect()
}

/// Ranked hottest-links summary shared by every topology.
fn hottest(telem: &SimTelemetry, top: usize) -> String {
    let mut order: Vec<usize> = (0..telem.links.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(telem.link_flits[i]), i));
    let mut out = String::new();
    for &i in order.iter().take(top) {
        let (a, b) = telem.links[i];
        out.push_str(&format!(
            "  {a:>3} -> {b:<3} {:>10} flits  {:>5.1}% util\n",
            telem.link_flits[i],
            100.0 * telem.link_utilization(i)
        ));
    }
    if telem.links.len() > top {
        out.push_str(&format!("  ({} more links)\n", telem.links.len() - top));
    }
    out
}

/// Render the heatmap as a terminal text grid. Mesh packages draw the
/// physical `cols x rows` interposer with per-edge utilization percentages
/// (hotter direction of each edge); ring/P2P packages, which have no 2-D
/// embedding, list every directed link ranked by utilization. Passive relay
/// mesh sites (no chiplet) render as `[--]`.
pub fn heatmap_text(net: &NopNetwork, telem: &SimTelemetry) -> String {
    let mut out = format!(
        "NoP {} heatmap: k={} ({} nodes), {} cycles, {} flits forwarded\n",
        net.topology.name(),
        net.chiplets,
        net.nodes,
        telem.cycles,
        telem.transit_total()
    );
    if net.topology == NopTopology::Mesh && net.dims.0 > 0 {
        let (cols, rows) = net.dims;
        let map = flit_map(telem);
        for r in 0..rows {
            // Node row: [ 0]-12%-[ 1]-...
            let mut line = String::new();
            for c in 0..cols {
                let n = r * cols + c;
                if n < net.chiplets {
                    line.push_str(&format!("[{n:>2}]"));
                } else {
                    line.push_str("[--]");
                }
                if c + 1 < cols {
                    match pct(&map, n, n + 1, telem.cycles) {
                        Some(p) => line.push_str(&format!("-{p:>3}%-")),
                        None => line.push_str("      "),
                    }
                }
            }
            out.push_str(line.trim_end());
            out.push('\n');
            // Vertical links to the next row: a bar line and a percent line.
            if r + 1 < rows {
                let mut bars = String::new();
                let mut pcts = String::new();
                for c in 0..cols {
                    let n = r * cols + c;
                    match pct(&map, n, n + cols, telem.cycles) {
                        Some(p) => {
                            bars.push_str("  |       ");
                            pcts.push_str(&format!(" {p:>3}%     "));
                        }
                        None => {
                            bars.push_str("          ");
                            pcts.push_str("          ");
                        }
                    }
                }
                out.push_str(bars.trim_end());
                out.push('\n');
                out.push_str(pcts.trim_end());
                out.push('\n');
            }
        }
        out.push_str("hottest links:\n");
        out.push_str(&hottest(telem, 5));
    } else {
        out.push_str("links by utilization:\n");
        out.push_str(&hottest(telem, 24));
    }
    if telem.occupancy.count() > 0 {
        out.push_str(&format!(
            "buffer occupancy at arrival: mean {:.2}, max {:.0} ({} samples)\n",
            telem.occupancy.mean(),
            telem.occupancy.max_sample(),
            telem.occupancy.count()
        ));
    }
    out
}

/// Machine-readable heatmap: topology, package shape, cycles, and every
/// directed link with its flit count and utilization. Fixed-precision
/// floats keep the export byte-deterministic for a given run.
pub fn heatmap_json(net: &NopNetwork, telem: &SimTelemetry) -> String {
    let links: Vec<String> = telem
        .links
        .iter()
        .enumerate()
        .map(|(i, &(a, b))| {
            format!(
                "{{\"src\":{a},\"dst\":{b},\"flits\":{},\"utilization\":{:.6}}}",
                telem.link_flits[i],
                telem.link_utilization(i)
            )
        })
        .collect();
    format!(
        "{{\"topology\":\"{}\",\"chiplets\":{},\"nodes\":{},\"cols\":{},\"rows\":{},\
         \"cycles\":{},\"injected\":{},\"delivered\":{},\"links\":[{}]}}",
        net.topology.name(),
        net.chiplets,
        net.nodes,
        net.dims.0,
        net.dims.1,
        telem.cycles,
        telem.injected_total(),
        telem.ejected_total(),
        links.join(",")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_telem(net: &NopNetwork) -> SimTelemetry {
        // One flit counter per enumerated routable link, like the sim does.
        let mut links: Vec<(usize, usize)> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for a in 0..net.nodes {
            for d in 0..net.chiplets {
                if d != a {
                    let b = net.route_next(a, d);
                    if seen.insert((a, b)) {
                        links.push((a, b));
                    }
                }
            }
        }
        links.sort_unstable();
        let mut t = SimTelemetry::sized(links, net.chiplets);
        for (i, f) in t.link_flits.iter_mut().enumerate() {
            *f = (i as u64 + 1) * 3;
        }
        t.cycles = 100;
        t.injected[0] = 7;
        t.ejected[1] = 7;
        t.occupancy.record(2.0);
        t
    }

    #[test]
    fn mesh_grid_renders_nodes_and_percentages() {
        let net = NopNetwork::build(NopTopology::Mesh, 4);
        let t = fake_telem(&net);
        let txt = heatmap_text(&net, &t);
        assert!(txt.contains("[ 0]"), "{txt}");
        assert!(txt.contains("[ 3]"), "{txt}");
        assert!(txt.contains('%'), "{txt}");
        assert!(txt.contains("hottest links"), "{txt}");
        assert!(txt.contains("buffer occupancy"), "{txt}");
    }

    #[test]
    fn relay_sites_render_as_blanks() {
        // k=7 on a 3x3 grid leaves passive relay sites.
        let net = NopNetwork::build(NopTopology::Mesh, 7);
        let t = fake_telem(&net);
        let txt = heatmap_text(&net, &t);
        assert!(txt.contains("[--]"), "{txt}");
    }

    #[test]
    fn ring_lists_links() {
        let net = NopNetwork::build(NopTopology::Ring, 6);
        let t = fake_telem(&net);
        let txt = heatmap_text(&net, &t);
        assert!(txt.contains("links by utilization"), "{txt}");
        assert!(txt.contains("->"), "{txt}");
    }

    #[test]
    fn json_contains_every_link_and_is_deterministic() {
        let net = NopNetwork::build(NopTopology::Mesh, 4);
        let t = fake_telem(&net);
        let j1 = heatmap_json(&net, &t);
        let j2 = heatmap_json(&net, &t);
        assert_eq!(j1, j2);
        assert!(j1.starts_with("{\"topology\":\"mesh\""), "{j1}");
        assert!(j1.contains("\"links\":["), "{j1}");
        assert!(j1.matches("\"src\":").count() == t.links.len(), "{j1}");
    }
}
