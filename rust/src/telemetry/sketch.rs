//! Bounded-memory streaming quantile sketch.
//!
//! [`QuantileSketch`] accepts an unbounded stream of non-negative samples
//! (serving latencies in milliseconds) in O(1) time and O(1) memory and
//! answers percentile queries to a documented relative-error bound
//! ([`RELATIVE_ERROR`]). It extends the log2 histogram idiom of
//! [`crate::telemetry::registry::Histogram`] with 16 geometric sub-buckets
//! per octave: a sample `v > 0` lands in bucket `floor(log2(v) * 16)`, so
//! adjacent bucket edges are a factor `2^(1/16) ≈ 1.0443` apart and any
//! estimate read back from a bucket midpoint is within ~4.4% of the
//! samples it summarizes. The bucket range covers `[2^-20, 2^24)`
//! (≈ 1 ns – 4.7 h when samples are milliseconds); values outside clamp
//! into the end buckets, and quantile estimates additionally clamp into
//! the exact tracked `[min, max]`, which makes single-sample and
//! constant-stream quantiles exact.
//!
//! The mean is exact (tracked running sum), merging two sketches is
//! bucket-wise exact, and all state is deterministic in record order —
//! two identical streams produce identical sketches, which the serving
//! export paths rely on for byte-identical output per seed.

/// Sub-buckets per octave (power of two). 16 gives bucket-edge ratio
/// `2^(1/16) ≈ 1.0443`.
const SUB: i32 = 16;

/// Lowest representable bucket index: values below `2^-20` clamp here.
const MIN_IDX: i32 = -20 * SUB;

/// Highest representable bucket index: values at or above `2^24` clamp.
const MAX_IDX: i32 = 24 * SUB;

/// Number of positive-value buckets (the zero bucket is tracked apart).
const NBUCKETS: usize = (MAX_IDX - MIN_IDX + 1) as usize;

/// Worst-case relative error of [`QuantileSketch::quantile`] against the
/// exact sorted-sample percentile, for in-range samples. The geometric
/// bucket width is `2^(1/16) - 1 ≈ 0.0443`; the bound is rounded up to
/// cover floating-point edge rounding. Property-tested in
/// `tests/properties.rs`.
pub const RELATIVE_ERROR: f64 = 0.045;

/// Streaming log-bucket quantile sketch over non-negative samples.
///
/// `Default` is the empty sketch; bucket storage is allocated lazily on
/// the first positive sample (~5.6 KB), so unused sketches stay tiny.
#[derive(Clone, Debug, Default)]
pub struct QuantileSketch {
    /// Samples that were zero, negative, or NaN (all recorded as 0.0).
    zeros: u64,
    /// Lazily allocated positive-value buckets, `NBUCKETS` long.
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

/// Bucket index for a strictly positive finite sample.
fn bucket_of(v: f64) -> usize {
    let idx = (v.log2() * SUB as f64).floor() as i64;
    let idx = idx.clamp(MIN_IDX as i64, MAX_IDX as i64);
    (idx - MIN_IDX as i64) as usize
}

/// Geometric midpoint of bucket `b` (estimate returned for its samples).
fn midpoint_of(b: usize) -> f64 {
    let idx = b as i32 + MIN_IDX;
    // Lower edge 2^(idx/16) times half a sub-bucket, 2^(1/32).
    ((idx as f64 + 0.5) / SUB as f64).exp2()
}

impl QuantileSketch {
    /// An empty sketch (same as `Default`).
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample. Non-positive and non-finite samples count as
    /// exact zeros (serving latencies are never negative; this keeps the
    /// sketch total in lock-step with the completion count).
    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        if v > 0.0 {
            if self.counts.is_empty() {
                self.counts = vec![0; NBUCKETS];
            }
            self.counts[bucket_of(v)] += 1;
        } else {
            self.zeros += 1;
        }
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
    }

    /// Total number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True if no sample has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean of the recorded samples; 0.0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact sum of the recorded samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact smallest recorded sample; 0.0 when empty.
    pub fn min_sample(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact largest recorded sample; 0.0 when empty.
    pub fn max_sample(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Estimate of the `p`-th percentile (`p` in 0..=100), within
    /// [`RELATIVE_ERROR`] of [`crate::util::percentile`] over the same
    /// samples. Matches its rank convention: linear interpolation at rank
    /// `p/100 * (count - 1)` between adjacent order statistics, here
    /// approximated by bucket midpoints and clamped into the exact
    /// tracked `[min, max]`.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (p / 100.0).clamp(0.0, 1.0) * (self.count - 1) as f64;
        let lo = rank.floor() as u64;
        let hi = rank.ceil() as u64;
        let est_lo = self.order_stat(lo);
        let est = if lo == hi {
            est_lo
        } else {
            est_lo + (rank - lo as f64) * (self.order_stat(hi) - est_lo)
        };
        est.clamp(self.min, self.max)
    }

    /// Midpoint estimate of the 0-indexed `k`-th smallest sample.
    fn order_stat(&self, k: u64) -> f64 {
        if k < self.zeros {
            return 0.0;
        }
        let mut seen = self.zeros;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > k {
                return midpoint_of(b);
            }
        }
        // Unreachable when k < count; fall back to the tracked max.
        self.max
    }

    /// Fold `other` into `self` (bucket-wise; exact).
    pub fn merge(&mut self, other: &QuantileSketch) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            self.min = other.min;
            self.max = other.max;
        } else {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        self.zeros += other.zeros;
        self.count += other.count;
        self.sum += other.sum;
        if !other.counts.is_empty() {
            if self.counts.is_empty() {
                self.counts = vec![0; NBUCKETS];
            }
            for (a, b) in self.counts.iter_mut().zip(&other.counts) {
                *a += b;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{percentile, Pcg32};

    #[test]
    fn empty_sketch_is_all_zeros() {
        let s = QuantileSketch::new();
        assert_eq!(s.count(), 0);
        assert!(s.is_empty());
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.quantile(50.0), 0.0);
        assert_eq!(s.min_sample(), 0.0);
        assert_eq!(s.max_sample(), 0.0);
    }

    #[test]
    fn single_sample_quantiles_are_exact() {
        let mut s = QuantileSketch::new();
        s.record(3.7);
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(s.quantile(p), 3.7, "p{p}");
        }
        assert_eq!(s.mean(), 3.7);
    }

    #[test]
    fn constant_stream_is_exact_via_min_max_clamp() {
        let mut s = QuantileSketch::new();
        for _ in 0..1000 {
            s.record(0.125);
        }
        assert_eq!(s.quantile(50.0), 0.125);
        assert_eq!(s.quantile(99.0), 0.125);
    }

    #[test]
    fn zeros_and_negatives_land_in_the_zero_bucket() {
        let mut s = QuantileSketch::new();
        s.record(0.0);
        s.record(-1.0);
        s.record(f64::NAN);
        assert_eq!(s.count(), 3);
        assert_eq!(s.quantile(99.0), 0.0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn quantiles_track_exact_percentiles_within_bound() {
        let mut rng = Pcg32::seeded(0xC0FFEE);
        let mut s = QuantileSketch::new();
        let mut exact = Vec::new();
        for _ in 0..5000 {
            // Log-uniform over ~6 decades, the serving latency regime.
            let v = 10f64.powf(rng.next_f64() * 6.0 - 3.0);
            s.record(v);
            exact.push(v);
        }
        for p in [1.0, 10.0, 50.0, 90.0, 99.0, 99.9] {
            let want = percentile(&exact, p);
            let got = s.quantile(p);
            let rel = (got - want).abs() / want;
            assert!(
                rel <= RELATIVE_ERROR,
                "p{p}: sketch {got} vs exact {want} (rel {rel})"
            );
        }
        // Mean is exact, not approximate.
        let mean = exact.iter().sum::<f64>() / exact.len() as f64;
        assert!((s.mean() - mean).abs() <= 1e-9 * mean);
    }

    #[test]
    fn quantile_is_monotone_in_p() {
        let mut rng = Pcg32::seeded(7);
        let mut s = QuantileSketch::new();
        for _ in 0..300 {
            s.record(rng.next_f64() * 50.0);
        }
        let mut prev = 0.0;
        for p in 0..=100 {
            let q = s.quantile(p as f64);
            assert!(q >= prev, "p{p}: {q} < {prev}");
            prev = q;
        }
    }

    #[test]
    fn merge_matches_single_sketch_over_concatenation() {
        let mut rng = Pcg32::seeded(42);
        let (mut a, mut b, mut all) = (
            QuantileSketch::new(),
            QuantileSketch::new(),
            QuantileSketch::new(),
        );
        for i in 0..400 {
            let v = rng.next_f64() * 100.0;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.quantile(50.0), all.quantile(50.0));
        assert_eq!(a.quantile(99.0), all.quantile(99.0));
        assert!((a.mean() - all.mean()).abs() < 1e-12 * all.mean().abs().max(1.0));
    }

    #[test]
    fn out_of_range_samples_clamp_into_end_buckets() {
        let mut s = QuantileSketch::new();
        s.record(1e-12);
        s.record(1e12);
        assert_eq!(s.count(), 2);
        // Clamped estimates still honor the exact tracked min/max.
        assert_eq!(s.quantile(0.0), 1e-12);
        assert_eq!(s.quantile(100.0), 1e12);
    }
}
