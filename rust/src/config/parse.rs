//! Hand-rolled INI parser: `[section]` headers, `key = value` pairs,
//! `#`/`;` comments, blank lines. Values keep interior whitespace; inline
//! comments are supported after a `#` or `;` preceded by whitespace.

use std::fmt;

/// Syntax error with the 1-based line it occurred on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// 1-based line number of the offending line.
    pub line: usize,
    /// What was wrong with it.
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ini parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parsed INI document: ordered (section, key, value) triples.
#[derive(Debug, Clone, Default)]
pub struct IniDoc {
    entries: Vec<(String, String, String)>,
}

impl IniDoc {
    /// Iterate entries as (&section, &key, &value).
    pub fn entries(&self) -> impl Iterator<Item = (&str, &str, &str)> {
        self.entries
            .iter()
            .map(|(s, k, v)| (s.as_str(), k.as_str(), v.as_str()))
    }

    /// Look up a key in a section (last occurrence wins).
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.entries
            .iter()
            .rev()
            .find(|(s, k, _)| s == section && k == key)
            .map(|(_, _, v)| v.as_str())
    }

    /// Number of parsed entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the document has no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn strip_inline_comment(s: &str) -> &str {
    // A comment starts at '#' or ';' that is at the start or preceded by
    // whitespace (so values like "a#b" survive).
    let bytes = s.as_bytes();
    for (i, &b) in bytes.iter().enumerate() {
        if (b == b'#' || b == b';') && (i == 0 || bytes[i - 1].is_ascii_whitespace()) {
            return &s[..i];
        }
    }
    s
}

/// Parse INI text into an [`IniDoc`].
pub fn parse_ini(text: &str) -> Result<IniDoc, ParseError> {
    let mut doc = IniDoc::default();
    let mut section = String::new();
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_inline_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or(ParseError {
                line: lineno,
                message: "unterminated section header".into(),
            })?;
            let name = name.trim();
            if name.is_empty() {
                return Err(ParseError {
                    line: lineno,
                    message: "empty section name".into(),
                });
            }
            section = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or(ParseError {
            line: lineno,
            message: format!("expected 'key = value', got '{line}'"),
        })?;
        let key = line[..eq].trim();
        let value = line[eq + 1..].trim();
        if key.is_empty() {
            return Err(ParseError {
                line: lineno,
                message: "empty key".into(),
            });
        }
        doc.entries
            .push((section.clone(), key.to_string(), value.to_string()));
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_keys_comments() {
        let doc = parse_ini(
            "# top comment\n[arch]\npe_size = 256  # inline\n\n; semicolon comment\n[noc]\ntopology = mesh\n",
        )
        .unwrap();
        assert_eq!(doc.len(), 2);
        assert_eq!(doc.get("arch", "pe_size"), Some("256"));
        assert_eq!(doc.get("noc", "topology"), Some("mesh"));
        assert_eq!(doc.get("noc", "missing"), None);
    }

    #[test]
    fn last_occurrence_wins() {
        let doc = parse_ini("[a]\nk = 1\nk = 2\n").unwrap();
        assert_eq!(doc.get("a", "k"), Some("2"));
    }

    #[test]
    fn keyless_section_and_errors() {
        assert!(parse_ini("[unterminated\n").is_err());
        assert!(parse_ini("[ ]\n").is_err());
        assert!(parse_ini("no-equals-here\n").is_err());
        assert!(parse_ini("= value\n").is_err());
    }

    #[test]
    fn value_with_hash_no_space_survives() {
        let doc = parse_ini("[s]\nk = a#b\n").unwrap();
        assert_eq!(doc.get("s", "k"), Some("a#b"));
    }

    #[test]
    fn error_reports_line_number() {
        let err = parse_ini("[ok]\nk = 1\nbad line\n").unwrap_err();
        assert_eq!(err.line, 3);
        assert!(err.to_string().contains("line 3"));
    }
}
