//! Configuration system: typed configs with Table-2 defaults plus a
//! hand-rolled INI-style parser/serializer (`key = value`, `[section]`
//! headers, `#`/`;` comments) — the offline build has no serde.

mod parse;

pub use parse::{parse_ini, IniDoc, ParseError};

use crate::noc::topology::Topology;
use crate::nop::topology::NopTopology;
use crate::workload::{ArrivalKind, ArrivalProcess, PlacementPolicy, WorkloadMix};

/// Memory technology of the IMC processing elements (crossbars).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemTech {
    /// 8T/capacitive-coupling SRAM bitcell macro (paper ref [12]).
    Sram,
    /// 1T1R ReRAM bitcell (paper ref [2]).
    Reram,
}

impl MemTech {
    /// Display name ("SRAM" / "ReRAM").
    pub fn name(self) -> &'static str {
        match self {
            MemTech::Sram => "SRAM",
            MemTech::Reram => "ReRAM",
        }
    }

    /// Parse a case-insensitive technology name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sram" => Some(MemTech::Sram),
            "reram" | "rram" => Some(MemTech::Reram),
            _ => None,
        }
    }
}

/// Architecture parameters (paper Table 2 defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct ArchConfig {
    /// Crossbar (PE) array rows = cols. Paper default: 256.
    pub pe_size: usize,
    /// Bits stored per IMC cell. Paper default: 1.
    pub cell_bits: usize,
    /// Weight/activation data precision in bits. Paper default: 8.
    pub n_bits: usize,
    /// Flash-ADC resolution in bits. Paper default: 4.
    pub adc_bits: usize,
    /// Technology node in nm. Paper default: 32.
    pub tech_nm: f64,
    /// Operating frequency in Hz. Paper default: 1 GHz.
    pub freq_hz: f64,
    /// PEs (crossbars) per computing element. Paper §5.2: 4.
    pub pes_per_ce: usize,
    /// CEs per tile. Paper §5.2: 4.
    pub ces_per_tile: usize,
    /// Memory technology of the PEs.
    pub tech: MemTech,
    /// Target throughput in frames/s used for injection-rate calculation.
    pub fps: f64,
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self {
            pe_size: 256,
            cell_bits: 1,
            n_bits: 8,
            adc_bits: 4,
            tech_nm: 32.0,
            freq_hz: 1.0e9,
            pes_per_ce: 4,
            ces_per_tile: 4,
            tech: MemTech::Reram,
            fps: 60.0,
        }
    }
}

impl ArchConfig {
    /// Table-2 defaults with SRAM PEs.
    pub fn sram() -> Self {
        Self {
            tech: MemTech::Sram,
            ..Self::default()
        }
    }

    /// Table-2 defaults with ReRAM PEs (same as `default()`).
    pub fn reram() -> Self {
        Self::default()
    }

    /// Crossbars per tile (paper §5.2: 4 CEs × 4 PEs = 16).
    pub fn pes_per_tile(&self) -> usize {
        self.pes_per_ce * self.ces_per_tile
    }

    /// Range-check all fields; `Err` carries the offending knob.
    pub fn validate(&self) -> Result<(), String> {
        if !self.pe_size.is_power_of_two() || !(64..=512).contains(&self.pe_size) {
            return Err(format!(
                "pe_size must be a power of two in [64, 512], got {}",
                self.pe_size
            ));
        }
        if self.cell_bits == 0 || self.cell_bits > self.n_bits {
            return Err("cell_bits must be in [1, n_bits]".into());
        }
        if self.n_bits == 0 || self.n_bits > 32 {
            return Err("n_bits must be in [1, 32]".into());
        }
        if self.adc_bits == 0 || self.adc_bits > 12 {
            return Err("adc_bits must be in [1, 12]".into());
        }
        if self.freq_hz <= 0.0 || self.fps <= 0.0 {
            return Err("freq_hz and fps must be positive".into());
        }
        if self.pes_per_ce == 0 || self.ces_per_tile == 0 {
            return Err("pes_per_ce / ces_per_tile must be positive".into());
        }
        Ok(())
    }
}

/// NoC parameters (paper Table 2 + §2.3 defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct NocConfig {
    /// Tile-level NoC topology.
    pub topology: Topology,
    /// Link/bus width in bits. Paper default: 32.
    pub bus_width: usize,
    /// Virtual channels per port. Paper default: 1.
    pub virtual_channels: usize,
    /// Buffer depth in flits (per input port, all VCs). Paper default: 8.
    pub buffer_depth: usize,
    /// Router pipeline stages. Paper default: 3.
    pub pipeline_stages: usize,
    /// Flits per packet (header + payload); latency stats are flit-level
    /// like BookSim's default single-flit packets.
    pub flits_per_packet: usize,
}

impl Default for NocConfig {
    fn default() -> Self {
        Self {
            topology: Topology::Mesh,
            bus_width: 32,
            virtual_channels: 1,
            buffer_depth: 8,
            pipeline_stages: 3,
            flits_per_packet: 1,
        }
    }
}

impl NocConfig {
    /// Defaults with the given topology.
    pub fn with_topology(topology: Topology) -> Self {
        Self {
            topology,
            ..Self::default()
        }
    }

    /// Range-check all fields; `Err` carries the offending knob.
    pub fn validate(&self) -> Result<(), String> {
        if self.bus_width == 0 || self.bus_width > 1024 {
            return Err("bus_width must be in [1, 1024]".into());
        }
        if self.virtual_channels == 0 || self.virtual_channels > 16 {
            return Err("virtual_channels must be in [1, 16]".into());
        }
        if self.buffer_depth == 0 {
            return Err("buffer_depth must be positive".into());
        }
        if self.pipeline_stages == 0 || self.pipeline_stages > 8 {
            return Err("pipeline_stages must be in [1, 8]".into());
        }
        if self.flits_per_packet == 0 {
            return Err("flits_per_packet must be positive".into());
        }
        Ok(())
    }
}

/// Package-leg evaluation engine for [`crate::nop::evaluator`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NopMode {
    /// Bandwidth + fixed-latency estimate (`nop_transfer_cycles`): fast,
    /// load-independent — blind to SerDes congestion.
    Analytical,
    /// Flit-level event-driven NoP simulation ([`crate::nop::sim::NopSim`])
    /// with credit-based flow control: sees queueing and saturation.
    Sim,
    /// Sim-anchored surrogate ([`crate::sim::surrogate`]): latency curves
    /// fit from a handful of sim anchors answer sweep queries at
    /// near-analytical cost, falling back to the full simulator outside
    /// the fitted range.
    Surrogate,
}

impl NopMode {
    /// Display name ("analytical" / "sim" / "surrogate").
    pub fn name(self) -> &'static str {
        match self {
            NopMode::Analytical => "analytical",
            NopMode::Sim => "sim",
            NopMode::Surrogate => "surrogate",
        }
    }

    /// Parse a case-insensitive mode name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "analytical" | "ana" => Some(NopMode::Analytical),
            "sim" | "simulate" | "cycle-accurate" => Some(NopMode::Sim),
            "surrogate" | "sur" => Some(NopMode::Surrogate),
            _ => None,
        }
    }

    /// The valid `parse` spellings, for CLI error messages.
    pub fn valid_names() -> &'static str {
        "analytical, sim, surrogate"
    }
}

/// Network-on-Package parameters for multi-chiplet scale-out.
///
/// Package links are SerDes lanes over the interposer: compared to on-chip
/// wires they are narrower, clocked slower (effective parallel rate after
/// serialization), have a large fixed per-hop latency (TX + trace + RX),
/// and cost an order of magnitude more energy per bit — SIMBA-class 2.5D
/// numbers.
#[derive(Clone, Debug, PartialEq)]
pub struct NopConfig {
    /// Package-level topology.
    pub topology: NopTopology,
    /// Package-leg engine: analytical estimate or flit-level simulation.
    pub mode: NopMode,
    /// IMC chiplets in the package.
    pub chiplets: usize,
    /// Bits per NoP flit (parallel lane-bundle width). Default: 32.
    pub link_width: usize,
    /// Effective per-link flit clock in Hz (post-SerDes). Default: 0.5 GHz
    /// — half the on-chip clock.
    pub freq_hz: f64,
    /// Fixed per-hop latency in NoP cycles (SerDes TX + package trace +
    /// RX + relay). Default: 20.
    pub hop_latency_cycles: u64,
    /// Receive-buffer depth per directed package link (and per injection
    /// lane) in NoP flits — the credit count of the simulated flow
    /// control. Must cover the credit round-trip
    /// (~`hop_latency_cycles` + 2) or links starve below their
    /// serialization rate, as in real SerDes RX FIFOs. Default: 64.
    pub buffer_flits: usize,
    /// Transfer energy per bit per hop, pJ. Default: 1.5 (vs ~0.1 pJ/bit
    /// for an on-chip link traversal).
    pub energy_pj_per_bit: f64,
    /// SerDes PHY area per chiplet port bundle, mm². Default: 0.3.
    pub phy_area_mm2: f64,
}

impl Default for NopConfig {
    fn default() -> Self {
        Self {
            topology: NopTopology::Mesh,
            mode: NopMode::Analytical,
            chiplets: 4,
            link_width: 32,
            freq_hz: 0.5e9,
            hop_latency_cycles: 20,
            buffer_flits: 64,
            energy_pj_per_bit: 1.5,
            phy_area_mm2: 0.3,
        }
    }
}

impl NopConfig {
    /// Defaults with the given package topology.
    pub fn with_topology(topology: NopTopology) -> Self {
        Self {
            topology,
            ..Self::default()
        }
    }

    /// Defaults with the given chiplet count.
    pub fn with_chiplets(chiplets: usize) -> Self {
        Self {
            chiplets,
            ..Self::default()
        }
    }

    /// Range-check all fields; `Err` carries the offending knob.
    pub fn validate(&self) -> Result<(), String> {
        if self.chiplets == 0 || self.chiplets > 256 {
            return Err("chiplets must be in [1, 256]".into());
        }
        if self.link_width == 0 || self.link_width > 1024 {
            return Err("link_width must be in [1, 1024]".into());
        }
        if self.freq_hz <= 0.0 {
            return Err("nop freq_hz must be positive".into());
        }
        if !(2..=1024).contains(&self.buffer_flits) {
            // The simulator's bubble flow control keeps one slot free per
            // receive buffer, so a depth of 1 could never accept traffic.
            return Err("nop buffer_flits must be in [2, 1024]".into());
        }
        if self.energy_pj_per_bit < 0.0 || self.phy_area_mm2 < 0.0 {
            return Err("nop energy/area must be non-negative".into());
        }
        Ok(())
    }
}

/// Request-routing policy of the chiplet-aware serving scheduler
/// ([`crate::coordinator::scheduler::ChipletScheduler`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Policy {
    /// Cycle through the chiplets in id order, skipping full queues.
    RoundRobin,
    /// Route to the chiplet with the lowest modeled completion time
    /// (queue backlog + NoP ingress + service + egress).
    LeastLatency,
    /// [`Policy::LeastLatency`], but chiplets whose ingress path contains
    /// a package link running near the measured saturation utilization
    /// ([`crate::coordinator::scheduler::SATURATION_BACKOFF`] ×
    /// [`crate::coordinator::scheduler::ServingModel::sat_link_util`])
    /// are backed off — considered only when every chiplet is congested.
    CongestionAware,
}

impl Policy {
    /// Display name (the canonical `parse` spelling).
    pub fn name(self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::LeastLatency => "least-latency",
            Policy::CongestionAware => "congestion-aware",
        }
    }

    /// Parse a case-insensitive policy name (aliases: rr, ll, ca).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "roundrobin" | "rr" => Some(Policy::RoundRobin),
            "least-latency" | "least" | "ll" => Some(Policy::LeastLatency),
            "congestion-aware" | "congestion" | "ca" => Some(Policy::CongestionAware),
            _ => None,
        }
    }

    /// Every policy, in sweep order.
    pub fn all() -> [Policy; 3] {
        [
            Policy::RoundRobin,
            Policy::LeastLatency,
            Policy::CongestionAware,
        ]
    }

    /// The valid `parse` spellings, for CLI error messages.
    pub fn valid_names() -> &'static str {
        "round-robin, least-latency, congestion-aware"
    }
}

/// Admission control of the serving schedulers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Admission {
    /// Admit unless every eligible queue is at `queue_depth` (PR 3's only
    /// behavior): overload surfaces as drops and late completions.
    DropOnFull,
    /// Additionally *shed* a request at admission when its modeled
    /// completion (queue backlog + NoP ingress + service + egress) already
    /// exceeds its deadline — capacity is spent only on requests that can
    /// still hit.
    DeadlineAware,
}

impl Admission {
    /// Display name (the canonical `parse` spelling).
    pub fn name(self) -> &'static str {
        match self {
            Admission::DropOnFull => "drop-on-full",
            Admission::DeadlineAware => "deadline-aware",
        }
    }

    /// Parse a case-insensitive admission name (aliases: drop, shed).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "drop-on-full" | "drop" | "full" => Some(Admission::DropOnFull),
            "deadline-aware" | "deadline" | "shed" => Some(Admission::DeadlineAware),
            _ => None,
        }
    }

    /// Every admission mode, in sweep order.
    pub fn all() -> [Admission; 2] {
        [Admission::DropOnFull, Admission::DeadlineAware]
    }

    /// The valid `parse` spellings, for CLI error messages.
    pub fn valid_names() -> &'static str {
        "drop-on-full, deadline-aware"
    }
}

/// Serving-scheduler parameters for the chiplet-aware serving loop
/// ([`crate::coordinator::scheduler`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ServingConfig {
    /// Request-routing policy over the per-chiplet queues.
    pub policy: Policy,
    /// Per-chiplet queue capacity; admissions beyond it are dropped.
    pub queue_depth: usize,
    /// Poisson arrival rate in requests/s. 0 = auto: a fixed fraction of
    /// the modeled package capacity (`AUTO_LOAD_FACTOR`).
    pub arrival_rps: f64,
    /// Requests per serving simulation.
    pub requests: usize,
    /// Per-chiplet serving batch (frames pipelined through one replica).
    pub batch: usize,
    /// Arrival-generator PRNG seed — independent of `[sim] seed` so
    /// serving experiments reseed without disturbing the NoC/NoP
    /// simulators (and vice versa).
    pub seed: u64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            policy: Policy::CongestionAware,
            queue_depth: 16,
            arrival_rps: 0.0,
            requests: 512,
            batch: 4,
            seed: 0x1AC5_EED,
        }
    }
}

impl ServingConfig {
    /// Range-check all fields; `Err` carries the offending knob.
    pub fn validate(&self) -> Result<(), String> {
        if self.queue_depth == 0 || self.queue_depth > 4096 {
            return Err("serving queue_depth must be in [1, 4096]".into());
        }
        if self.requests == 0 || self.requests > 1_000_000 {
            return Err("serving requests must be in [1, 1000000]".into());
        }
        if self.batch == 0 || self.batch > 1024 {
            return Err("serving batch must be in [1, 1024]".into());
        }
        if !self.arrival_rps.is_finite() || self.arrival_rps < 0.0 {
            return Err("serving arrival_rps must be >= 0".into());
        }
        Ok(())
    }
}

/// Multi-model workload parameters for the mix serving scheduler
/// ([`crate::coordinator::mix`]).
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadConfig {
    /// The DNN mix: `name[:weight[:deadline_ms]],...` (deadline 0 = auto,
    /// inf = none).
    pub mix: WorkloadMix,
    /// Arrival-process shape (rates come from `[serving] arrival_rps`).
    pub arrival: ArrivalKind,
    /// Replica-placement policy over the package's chiplets.
    pub placement: PlacementPolicy,
    /// Admission control of the per-chiplet queues.
    pub admission: Admission,
    /// Bursty: ON-state rate multiplier.
    pub burst_factor: f64,
    /// Bursty: long-run ON-state time fraction.
    pub on_fraction: f64,
    /// Bursty: mean ON+OFF cycle, seconds. Diurnal: the period.
    pub cycle_s: f64,
    /// Heavy-tailed frames-per-request exponent; 0 = single-frame.
    pub frames_alpha: f64,
    /// Frames-per-request cap.
    pub frames_max: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            mix: WorkloadMix::default_mix(),
            arrival: ArrivalKind::Poisson,
            placement: PlacementPolicy::NopAware,
            admission: Admission::DeadlineAware,
            burst_factor: 4.0,
            on_fraction: 0.25,
            cycle_s: 0.02,
            frames_alpha: 0.0,
            frames_max: 8,
        }
    }
}

impl WorkloadConfig {
    /// Assemble the arrival-process description these knobs define.
    pub fn arrival_process(&self) -> ArrivalProcess {
        ArrivalProcess {
            kind: self.arrival,
            burst_factor: self.burst_factor,
            on_fraction: self.on_fraction,
            cycle_s: self.cycle_s,
            frames_alpha: self.frames_alpha,
            frames_max: self.frames_max as u32,
        }
    }

    /// Validate the mix, frame cap, and arrival-process shape.
    pub fn validate(&self) -> Result<(), String> {
        self.mix.validate()?;
        if self.frames_max == 0 || self.frames_max > 1024 {
            return Err("workload frames_max must be in [1, 1024]".into());
        }
        self.arrival_process().validate()
    }
}

/// Observability knobs for the [`crate::telemetry`] subsystem.
///
/// Request lifecycle spans on the serving schedulers are always
/// collected (their cost is one `Vec` push per request); these knobs
/// only control the *optional* instrumentation and exports.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TelemetryConfig {
    /// Instrument the cycle-accurate NoC/NoP simulators with per-link
    /// flit counters and buffer-occupancy histograms. Off by default:
    /// the simulators then carry no telemetry state at all.
    pub enabled: bool,
    /// Default Chrome-trace output path for `repro serve` (empty = no
    /// trace; the `--trace-out` flag overrides).
    pub trace_out: String,
    /// Print the NoP link-utilization heatmap after `repro chiplet`
    /// (same as passing `--heatmap`).
    pub heatmap: bool,
    /// Serving metrics window width in milliseconds (0 = auto: the run
    /// horizon divided into [`crate::telemetry::timeseries::AUTO_WINDOWS`]
    /// windows).
    pub window_ms: f64,
    /// Default windowed-metrics output path for `repro serve` (empty =
    /// no metrics file; the `--metrics-out` flag overrides).
    pub metrics_out: String,
}

/// Simulation-control parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    /// PRNG seed for the cycle-accurate simulator.
    pub seed: u64,
    /// Warm-up cycles excluded from statistics.
    pub warmup_cycles: u64,
    /// Measured cycles after warm-up.
    pub measure_cycles: u64,
    /// Cycles to wait for in-flight drain after injection stops.
    pub drain_cycles: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            seed: 0x1AC5_EED,
            warmup_cycles: 200,
            measure_cycles: 2_000,
            drain_cycles: 20_000,
        }
    }
}

/// Bundle of all configs, loadable from an INI file.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    /// Architecture (crossbar / tile) parameters.
    pub arch: ArchConfig,
    /// On-chip network parameters.
    pub noc: NocConfig,
    /// Network-on-Package parameters.
    pub nop: NopConfig,
    /// Serving-scheduler parameters.
    pub serving: ServingConfig,
    /// Multi-model workload parameters.
    pub workload: WorkloadConfig,
    /// Simulation-control parameters.
    pub sim: SimConfig,
    /// Observability knobs.
    pub telemetry: TelemetryConfig,
}

impl Config {
    /// Load from INI text. Unknown keys are rejected so typos surface.
    ///
    /// ```
    /// use imcnoc::config::{Config, MemTech};
    /// let cfg = Config::from_ini("[arch]\npe_size = 128\ntech = sram\n").unwrap();
    /// assert_eq!(cfg.arch.pe_size, 128);
    /// assert_eq!(cfg.arch.tech, MemTech::Sram);
    /// assert!(Config::from_ini("[arch]\nnot_a_key = 1\n").is_err());
    /// ```
    pub fn from_ini(text: &str) -> Result<Self, String> {
        let doc = parse_ini(text).map_err(|e| e.to_string())?;
        let mut cfg = Config::default();
        for (section, key, value) in doc.entries() {
            let v = value;
            let parse_err = |k: &str| format!("invalid value for {section}.{k}: '{v}'");
            match (section, key) {
                ("arch", "pe_size") => cfg.arch.pe_size = v.parse().map_err(|_| parse_err(key))?,
                ("arch", "cell_bits") => {
                    cfg.arch.cell_bits = v.parse().map_err(|_| parse_err(key))?
                }
                ("arch", "n_bits") => cfg.arch.n_bits = v.parse().map_err(|_| parse_err(key))?,
                ("arch", "adc_bits") => {
                    cfg.arch.adc_bits = v.parse().map_err(|_| parse_err(key))?
                }
                ("arch", "tech_nm") => cfg.arch.tech_nm = v.parse().map_err(|_| parse_err(key))?,
                ("arch", "freq_hz") => cfg.arch.freq_hz = v.parse().map_err(|_| parse_err(key))?,
                ("arch", "pes_per_ce") => {
                    cfg.arch.pes_per_ce = v.parse().map_err(|_| parse_err(key))?
                }
                ("arch", "ces_per_tile") => {
                    cfg.arch.ces_per_tile = v.parse().map_err(|_| parse_err(key))?
                }
                ("arch", "tech") => {
                    cfg.arch.tech = MemTech::parse(v).ok_or_else(|| parse_err(key))?
                }
                ("arch", "fps") => cfg.arch.fps = v.parse().map_err(|_| parse_err(key))?,
                ("noc", "topology") => {
                    cfg.noc.topology = Topology::parse(v).ok_or_else(|| parse_err(key))?
                }
                ("noc", "bus_width") => {
                    cfg.noc.bus_width = v.parse().map_err(|_| parse_err(key))?
                }
                ("noc", "virtual_channels") => {
                    cfg.noc.virtual_channels = v.parse().map_err(|_| parse_err(key))?
                }
                ("noc", "buffer_depth") => {
                    cfg.noc.buffer_depth = v.parse().map_err(|_| parse_err(key))?
                }
                ("noc", "pipeline_stages") => {
                    cfg.noc.pipeline_stages = v.parse().map_err(|_| parse_err(key))?
                }
                ("noc", "flits_per_packet") => {
                    cfg.noc.flits_per_packet = v.parse().map_err(|_| parse_err(key))?
                }
                ("nop", "topology") => {
                    cfg.nop.topology = NopTopology::parse(v).ok_or_else(|| parse_err(key))?
                }
                ("nop", "mode") => {
                    cfg.nop.mode = NopMode::parse(v).ok_or_else(|| parse_err(key))?
                }
                ("nop", "buffer_flits") => {
                    cfg.nop.buffer_flits = v.parse().map_err(|_| parse_err(key))?
                }
                ("nop", "chiplets") => {
                    cfg.nop.chiplets = v.parse().map_err(|_| parse_err(key))?
                }
                ("nop", "link_width") => {
                    cfg.nop.link_width = v.parse().map_err(|_| parse_err(key))?
                }
                ("nop", "freq_hz") => cfg.nop.freq_hz = v.parse().map_err(|_| parse_err(key))?,
                ("nop", "hop_latency_cycles") => {
                    cfg.nop.hop_latency_cycles = v.parse().map_err(|_| parse_err(key))?
                }
                ("nop", "energy_pj_per_bit") => {
                    cfg.nop.energy_pj_per_bit = v.parse().map_err(|_| parse_err(key))?
                }
                ("nop", "phy_area_mm2") => {
                    cfg.nop.phy_area_mm2 = v.parse().map_err(|_| parse_err(key))?
                }
                ("serving", "policy") => {
                    cfg.serving.policy = Policy::parse(v).ok_or_else(|| parse_err(key))?
                }
                ("serving", "queue_depth") => {
                    cfg.serving.queue_depth = v.parse().map_err(|_| parse_err(key))?
                }
                ("serving", "arrival_rps") => {
                    cfg.serving.arrival_rps = v.parse().map_err(|_| parse_err(key))?
                }
                ("serving", "requests") => {
                    cfg.serving.requests = v.parse().map_err(|_| parse_err(key))?
                }
                ("serving", "batch") => {
                    cfg.serving.batch = v.parse().map_err(|_| parse_err(key))?
                }
                ("serving", "seed") => {
                    cfg.serving.seed = v.parse().map_err(|_| parse_err(key))?
                }
                ("workload", "mix") => {
                    cfg.workload.mix =
                        WorkloadMix::parse(v).map_err(|e| format!("workload.mix: {e}"))?
                }
                ("workload", "arrival") => {
                    cfg.workload.arrival = ArrivalKind::parse(v).ok_or_else(|| parse_err(key))?
                }
                ("workload", "placement") => {
                    cfg.workload.placement =
                        PlacementPolicy::parse(v).ok_or_else(|| parse_err(key))?
                }
                ("workload", "admission") => {
                    cfg.workload.admission = Admission::parse(v).ok_or_else(|| parse_err(key))?
                }
                ("workload", "burst_factor") => {
                    cfg.workload.burst_factor = v.parse().map_err(|_| parse_err(key))?
                }
                ("workload", "on_fraction") => {
                    cfg.workload.on_fraction = v.parse().map_err(|_| parse_err(key))?
                }
                ("workload", "cycle_s") => {
                    cfg.workload.cycle_s = v.parse().map_err(|_| parse_err(key))?
                }
                ("workload", "frames_alpha") => {
                    cfg.workload.frames_alpha = v.parse().map_err(|_| parse_err(key))?
                }
                ("workload", "frames_max") => {
                    cfg.workload.frames_max = v.parse().map_err(|_| parse_err(key))?
                }
                ("sim", "seed") => cfg.sim.seed = v.parse().map_err(|_| parse_err(key))?,
                ("sim", "warmup_cycles") => {
                    cfg.sim.warmup_cycles = v.parse().map_err(|_| parse_err(key))?
                }
                ("sim", "measure_cycles") => {
                    cfg.sim.measure_cycles = v.parse().map_err(|_| parse_err(key))?
                }
                ("sim", "drain_cycles") => {
                    cfg.sim.drain_cycles = v.parse().map_err(|_| parse_err(key))?
                }
                ("telemetry", "enabled") => {
                    cfg.telemetry.enabled = v.parse().map_err(|_| parse_err(key))?
                }
                ("telemetry", "trace_out") => cfg.telemetry.trace_out = v.to_string(),
                ("telemetry", "heatmap") => {
                    cfg.telemetry.heatmap = v.parse().map_err(|_| parse_err(key))?
                }
                ("telemetry", "window_ms") => {
                    cfg.telemetry.window_ms = v.parse().map_err(|_| parse_err(key))?
                }
                ("telemetry", "metrics_out") => cfg.telemetry.metrics_out = v.to_string(),
                _ => return Err(format!("unknown config key: [{section}] {key}")),
            }
        }
        cfg.arch.validate()?;
        cfg.noc.validate()?;
        cfg.nop.validate()?;
        cfg.serving.validate()?;
        cfg.workload.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Self::from_ini(&text)
    }

    /// Serialize back to INI (round-trips through [`Config::from_ini`]).
    pub fn to_ini(&self) -> String {
        format!(
            "[arch]\npe_size = {}\ncell_bits = {}\nn_bits = {}\nadc_bits = {}\n\
             tech_nm = {}\nfreq_hz = {}\npes_per_ce = {}\nces_per_tile = {}\n\
             tech = {}\nfps = {}\n\n[noc]\ntopology = {}\nbus_width = {}\n\
             virtual_channels = {}\nbuffer_depth = {}\npipeline_stages = {}\n\
             flits_per_packet = {}\n\n[nop]\ntopology = {}\nmode = {}\n\
             chiplets = {}\nlink_width = {}\nfreq_hz = {}\n\
             hop_latency_cycles = {}\nbuffer_flits = {}\n\
             energy_pj_per_bit = {}\nphy_area_mm2 = {}\n\n[serving]\n\
             policy = {}\nqueue_depth = {}\narrival_rps = {}\n\
             requests = {}\nbatch = {}\nseed = {}\n\n[workload]\n\
             mix = {}\narrival = {}\nplacement = {}\nadmission = {}\n\
             burst_factor = {}\non_fraction = {}\ncycle_s = {}\n\
             frames_alpha = {}\nframes_max = {}\n\n[sim]\nseed = {}\n\
             warmup_cycles = {}\nmeasure_cycles = {}\ndrain_cycles = {}\n\n\
             [telemetry]\nenabled = {}\ntrace_out = {}\nheatmap = {}\n\
             window_ms = {}\nmetrics_out = {}\n",
            self.arch.pe_size,
            self.arch.cell_bits,
            self.arch.n_bits,
            self.arch.adc_bits,
            self.arch.tech_nm,
            self.arch.freq_hz,
            self.arch.pes_per_ce,
            self.arch.ces_per_tile,
            self.arch.tech.name(),
            self.arch.fps,
            self.noc.topology.name(),
            self.noc.bus_width,
            self.noc.virtual_channels,
            self.noc.buffer_depth,
            self.noc.pipeline_stages,
            self.noc.flits_per_packet,
            self.nop.topology.name(),
            self.nop.mode.name(),
            self.nop.chiplets,
            self.nop.link_width,
            self.nop.freq_hz,
            self.nop.hop_latency_cycles,
            self.nop.buffer_flits,
            self.nop.energy_pj_per_bit,
            self.nop.phy_area_mm2,
            self.serving.policy.name(),
            self.serving.queue_depth,
            self.serving.arrival_rps,
            self.serving.requests,
            self.serving.batch,
            self.serving.seed,
            self.workload.mix.spec_string(),
            self.workload.arrival.name(),
            self.workload.placement.name(),
            self.workload.admission.name(),
            self.workload.burst_factor,
            self.workload.on_fraction,
            self.workload.cycle_s,
            self.workload.frames_alpha,
            self.workload.frames_max,
            self.sim.seed,
            self.sim.warmup_cycles,
            self.sim.measure_cycles,
            self.sim.drain_cycles,
            self.telemetry.enabled,
            self.telemetry.trace_out,
            self.telemetry.heatmap,
            self.telemetry.window_ms,
            self.telemetry.metrics_out,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table2() {
        let a = ArchConfig::default();
        assert_eq!(a.pe_size, 256);
        assert_eq!(a.cell_bits, 1);
        assert_eq!(a.n_bits, 8);
        assert_eq!(a.adc_bits, 4);
        assert_eq!(a.tech_nm, 32.0);
        assert_eq!(a.freq_hz, 1.0e9);
        let n = NocConfig::default();
        assert_eq!(n.bus_width, 32);
        assert_eq!(n.virtual_channels, 1);
        assert_eq!(n.buffer_depth, 8);
        assert_eq!(n.pipeline_stages, 3);
    }

    #[test]
    fn ini_roundtrip() {
        let cfg = Config::default();
        let text = cfg.to_ini();
        let parsed = Config::from_ini(&text).unwrap();
        assert_eq!(parsed, cfg);
    }

    #[test]
    fn telemetry_section_parses_and_roundtrips() {
        let cfg = Config::from_ini(
            "[telemetry]\nenabled = true\ntrace_out = /tmp/trace.json\nheatmap = true\n\
             window_ms = 2.5\nmetrics_out = /tmp/metrics.json\n",
        )
        .unwrap();
        assert!(cfg.telemetry.enabled);
        assert_eq!(cfg.telemetry.trace_out, "/tmp/trace.json");
        assert!(cfg.telemetry.heatmap);
        assert_eq!(cfg.telemetry.window_ms, 2.5);
        assert_eq!(cfg.telemetry.metrics_out, "/tmp/metrics.json");
        assert!(Config::from_ini("[telemetry]\nenabled = yes\n").is_err());
        assert!(Config::from_ini("[telemetry]\nwindow_ms = soon\n").is_err());
        let back = Config::from_ini(&cfg.to_ini()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn ini_overrides_and_rejects_unknown() {
        let cfg = Config::from_ini("[arch]\npe_size = 128\ntech = sram\n").unwrap();
        assert_eq!(cfg.arch.pe_size, 128);
        assert_eq!(cfg.arch.tech, MemTech::Sram);
        assert!(Config::from_ini("[arch]\nnot_a_key = 1\n").is_err());
        assert!(Config::from_ini("[arch]\npe_size = banana\n").is_err());
    }

    #[test]
    fn validation_rejects_bad_values() {
        assert!(Config::from_ini("[arch]\npe_size = 100\n").is_err()); // not pow2
        assert!(Config::from_ini("[noc]\nbus_width = 0\n").is_err());
        assert!(Config::from_ini("[noc]\nvirtual_channels = 99\n").is_err());
    }

    #[test]
    fn nop_section_parses_and_validates() {
        let cfg = Config::from_ini("[nop]\ntopology = ring\nchiplets = 8\nlink_width = 16\n")
            .unwrap();
        assert_eq!(cfg.nop.topology, NopTopology::Ring);
        assert_eq!(cfg.nop.chiplets, 8);
        assert_eq!(cfg.nop.link_width, 16);
        assert!(Config::from_ini("[nop]\ntopology = star\n").is_err());
        assert!(Config::from_ini("[nop]\nchiplets = 0\n").is_err());
        assert!(Config::from_ini("[nop]\nfreq_hz = -1\n").is_err());
    }

    #[test]
    fn nop_mode_and_buffer_parse() {
        let cfg = Config::from_ini("[nop]\nmode = sim\nbuffer_flits = 16\n").unwrap();
        assert_eq!(cfg.nop.mode, NopMode::Sim);
        assert_eq!(cfg.nop.buffer_flits, 16);
        assert_eq!(Config::default().nop.mode, NopMode::Analytical);
        assert_eq!(NopMode::parse("Simulate"), Some(NopMode::Sim));
        assert_eq!(NopMode::parse("Surrogate"), Some(NopMode::Surrogate));
        assert_eq!(NopMode::Surrogate.name(), "surrogate");
        assert_eq!(NopMode::parse("guess"), None);
        // Bubble flow control needs at least two buffer slots.
        assert!(Config::from_ini("[nop]\nbuffer_flits = 1\n").is_err());
        assert!(Config::from_ini("[nop]\nmode = psychic\n").is_err());
    }

    #[test]
    fn serving_section_parses_and_validates() {
        let text = "[serving]\npolicy = round-robin\nqueue_depth = 8\n\
                    arrival_rps = 1200.5\nrequests = 64\nbatch = 2\n";
        let cfg = Config::from_ini(text).unwrap();
        assert_eq!(cfg.serving.policy, Policy::RoundRobin);
        assert_eq!(cfg.serving.queue_depth, 8);
        assert_eq!(cfg.serving.arrival_rps, 1200.5);
        assert_eq!(cfg.serving.requests, 64);
        assert_eq!(cfg.serving.batch, 2);
        assert_eq!(Config::default().serving.policy, Policy::CongestionAware);
        assert!(Config::from_ini("[serving]\npolicy = fifo\n").is_err());
        assert!(Config::from_ini("[serving]\nqueue_depth = 0\n").is_err());
        assert!(Config::from_ini("[serving]\nbatch = 0\n").is_err());
        assert!(Config::from_ini("[serving]\narrival_rps = -2\n").is_err());
    }

    #[test]
    fn serving_seed_is_independent_of_sim_seed() {
        let cfg = Config::from_ini("[serving]\nseed = 99\n").unwrap();
        assert_eq!(cfg.serving.seed, 99);
        assert_eq!(cfg.sim.seed, SimConfig::default().seed);
        let cfg = Config::from_ini("[sim]\nseed = 7\n").unwrap();
        assert_eq!(cfg.sim.seed, 7);
        assert_eq!(cfg.serving.seed, ServingConfig::default().seed);
    }

    #[test]
    fn workload_section_parses_and_validates() {
        let text = "[workload]\nmix = MLP:2:25,LeNet-5:1:inf\narrival = bursty\n\
                    placement = round-robin\nadmission = drop-on-full\n\
                    burst_factor = 2\non_fraction = 0.5\ncycle_s = 0.1\n\
                    frames_alpha = 1.5\nframes_max = 4\n";
        let cfg = Config::from_ini(text).unwrap();
        assert_eq!(cfg.workload.mix.models.len(), 2);
        assert_eq!(cfg.workload.mix.models[0].model, "MLP");
        assert_eq!(cfg.workload.mix.models[0].weight, 2.0);
        assert_eq!(cfg.workload.mix.models[0].deadline_ms, 25.0);
        assert!(cfg.workload.mix.models[1].deadline_ms.is_infinite());
        assert_eq!(cfg.workload.arrival, ArrivalKind::Bursty);
        assert_eq!(cfg.workload.placement, PlacementPolicy::RoundRobin);
        assert_eq!(cfg.workload.admission, Admission::DropOnFull);
        assert_eq!(cfg.workload.frames_max, 4);
        // Defaults: NoP-aware placement, deadline-aware admission, Poisson.
        let d = WorkloadConfig::default();
        assert_eq!(d.placement, PlacementPolicy::NopAware);
        assert_eq!(d.admission, Admission::DeadlineAware);
        assert_eq!(d.arrival, ArrivalKind::Poisson);
        // Bad values surface as errors.
        assert!(Config::from_ini("[workload]\nmix = \n").is_err());
        assert!(Config::from_ini("[workload]\narrival = chaotic\n").is_err());
        assert!(Config::from_ini("[workload]\nplacement = psychic\n").is_err());
        assert!(Config::from_ini("[workload]\nadmission = maybe\n").is_err());
        assert!(Config::from_ini("[workload]\nburst_factor = 0.5\n").is_err());
        assert!(Config::from_ini("[workload]\nframes_max = 0\n").is_err());
    }

    #[test]
    fn admission_parse_roundtrip() {
        for a in Admission::all() {
            assert_eq!(Admission::parse(a.name()), Some(a));
        }
        assert_eq!(Admission::parse("shed"), Some(Admission::DeadlineAware));
        assert_eq!(Admission::parse("always"), None);
        assert!(Admission::valid_names().contains("deadline-aware"));
    }

    #[test]
    fn memtech_parse() {
        assert_eq!(MemTech::parse("SRAM"), Some(MemTech::Sram));
        assert_eq!(MemTech::parse("rram"), Some(MemTech::Reram));
        assert_eq!(MemTech::parse("dram"), None);
    }
}
