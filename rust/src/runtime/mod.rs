//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//!
//! Python runs only at build time (`make artifacts`); this module makes the
//! rust binary self-contained afterwards. The interchange format is **HLO
//! text** — jax ≥ 0.5 serialized protos carry 64-bit instruction ids that
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids.
//!
//! The PJRT binding is optional: the offline build image has no native XLA
//! plugin, so the crate compiles by default with a stub [`Runtime`] whose
//! constructor returns a descriptive error (serving paths degrade cleanly,
//! tests skip). Build with `--features pjrt` once a real `xla` binding is
//! installed (see DESIGN.md §Runtime).

use std::path::{Path, PathBuf};

/// True when this binary was built with the `pjrt` feature. Tests and
/// benches use this (together with [`artifact_available`]) to skip PJRT
/// paths on stub builds instead of failing.
pub fn pjrt_enabled() -> bool {
    cfg!(feature = "pjrt")
}

/// Conventional artifact locations (`make artifacts` output).
pub fn artifact_path(name: &str) -> PathBuf {
    let base = std::env::var("IMCNOC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    Path::new(&base).join(format!("{name}.hlo.txt"))
}

/// True when the artifact exists (tests skip PJRT paths when artifacts have
/// not been built yet).
pub fn artifact_available(name: &str) -> bool {
    artifact_path(name).exists()
}

#[cfg(feature = "pjrt")]
mod backend {
    //! Real PJRT backend (compiled with `--features pjrt`).

    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use anyhow::{bail, Context, Result};

    /// A PJRT CPU client plus a cache of compiled executables.
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: HashMap<PathBuf, LoadedModel>,
    }

    /// One compiled model artifact.
    pub struct LoadedModel {
        exe: xla::PjRtLoadedExecutable,
        /// Artifact path (for reporting).
        pub path: PathBuf,
    }

    impl Runtime {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self {
                client,
                cache: HashMap::new(),
            })
        }

        /// PJRT platform name reported by the client.
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Number of PJRT devices on the client.
        pub fn device_count(&self) -> usize {
            self.client.device_count()
        }

        /// Load + compile an HLO-text artifact (cached by path).
        pub fn load(&mut self, path: impl AsRef<Path>) -> Result<&LoadedModel> {
            let path = path.as_ref().to_path_buf();
            if !self.cache.contains_key(&path) {
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .with_context(|| format!("parsing HLO text {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .with_context(|| format!("compiling {}", path.display()))?;
                self.cache.insert(
                    path.clone(),
                    LoadedModel {
                        exe,
                        path: path.clone(),
                    },
                );
            }
            Ok(&self.cache[&path])
        }
    }

    impl LoadedModel {
        /// Execute with f32 tensor inputs `(data, dims)`. The jax lowering
        /// uses `return_tuple=True`, so the single output literal is a
        /// tuple; all tuple elements are returned as flat f32 vectors.
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    let expected: i64 = dims.iter().product();
                    if expected as usize != data.len() {
                        bail!("input length {} != shape {:?}", data.len(), dims);
                    }
                    Ok(xla::Literal::vec1(data).reshape(dims)?)
                })
                .collect::<Result<_>>()?;
            let result = self.exe.execute::<xla::Literal>(&literals)?;
            let out = result[0][0].to_literal_sync()?;
            let parts = out.to_tuple()?;
            parts
                .into_iter()
                .map(|l| l.to_vec::<f32>().map_err(Into::into))
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    //! Stub backend (default offline build): identical API, constructor
    //! fails with an actionable message.

    use std::path::{Path, PathBuf};

    use anyhow::{bail, Result};

    const UNAVAILABLE: &str = "PJRT runtime unavailable: this binary was built without the \
         `pjrt` feature (offline stub). Rebuild with `cargo build --features pjrt` \
         after installing an xla-rs binding.";

    /// Stub runtime: carries no client; [`Runtime::cpu`] always errors.
    pub struct Runtime {
        _private: (),
    }

    /// Stub loaded model (never constructed; kept so signatures match).
    pub struct LoadedModel {
        /// Artifact path (for reporting).
        pub path: PathBuf,
    }

    impl Runtime {
        /// Always fails: the stub has no PJRT client.
        pub fn cpu() -> Result<Self> {
            bail!(UNAVAILABLE);
        }

        /// Stub platform name (`"stub"`).
        pub fn platform(&self) -> String {
            "stub".into()
        }

        /// Always 0 on the stub.
        pub fn device_count(&self) -> usize {
            0
        }

        /// Always fails: the stub cannot load artifacts.
        pub fn load(&mut self, path: impl AsRef<Path>) -> Result<&LoadedModel> {
            let _ = path.as_ref();
            bail!(UNAVAILABLE);
        }
    }

    impl LoadedModel {
        /// Always fails (never constructed on the stub).
        pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            bail!(UNAVAILABLE);
        }
    }
}

pub use backend::{LoadedModel, Runtime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_path_layout() {
        let p = artifact_path("mlp");
        assert!(p.to_string_lossy().ends_with("artifacts/mlp.hlo.txt"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        assert!(!pjrt_enabled());
        let err = Runtime::cpu().err().expect("stub must error");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    /// Requires a real xla binding + native PJRT CPU plugin; the vendored
    /// stub crate intentionally fails here, so the test is ignored by
    /// default even under `--features pjrt`.
    #[cfg(feature = "pjrt")]
    #[test]
    #[ignore = "requires a native PJRT plugin (vendor/xla is an API stub)"]
    fn client_boots() {
        let rt = Runtime::cpu().expect("PJRT CPU client");
        assert!(rt.device_count() >= 1);
        assert!(!rt.platform().is_empty());
    }
}
