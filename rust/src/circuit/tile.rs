//! Computing elements and tiles (paper Fig. 10): a CE groups 4 PEs behind a
//! local bus; a tile groups 4 CEs behind an H-tree P2P network plus the
//! tile-level buffers, accumulators and activation units. This is the
//! *intra-tile* part of the heterogeneous interconnect — deliberately
//! simple links, because intra-tile data volume is low (paper §5.2).

use super::crossbar::PeCost;
use super::device::LogicParams;
use super::Cost;
use crate::config::ArchConfig;

/// One computing element: `pes_per_ce` PEs + bus + partial-sum accumulator.
#[derive(Clone, Copy, Debug)]
pub struct CeCost {
    /// Cost of one constituent PE.
    pub pe: PeCost,
    /// CE area incl. bus and accumulator, mm².
    pub area_mm2: f64,
    /// Bus + accumulator energy per PE read routed through the CE, J.
    pub overhead_per_read_j: f64,
}

impl CeCost {
    /// Price one CE under `cfg`.
    pub fn new(cfg: &ArchConfig) -> Self {
        let pe = PeCost::new(cfg);
        let logic = LogicParams::new(cfg.tech_nm);
        // Bus wiring ≈ perimeter of the PE block; accumulator per column.
        let pe_edge_mm = pe.area_mm2.sqrt();
        let bus_area = 0.02 * cfg.pes_per_ce as f64 * pe.area_mm2; // 2% wiring overhead per PE
        let accum_area = cfg.pes_per_ce as f64 * logic.shift_add_area_um2 * 4.0 / 1e6;
        let area_mm2 = cfg.pes_per_ce as f64 * pe.area_mm2 + bus_area + accum_area;
        // Moving one read's outputs (pe_size/n_bits words × n_bits bits)
        // over ~one PE edge of wire, plus accumulation.
        let out_bits = cfg.pe_size as f64; // (pe_size/n_bits) words × n_bits
        let overhead_per_read_j = out_bits * pe_edge_mm * logic.wire_energy_per_bit_mm_j
            + out_bits * logic.shift_add_energy_per_bit_j;
        Self {
            pe,
            area_mm2,
            overhead_per_read_j,
        }
    }
}

/// One tile: `ces_per_tile` CEs + H-tree + I/O buffer + activation unit.
#[derive(Clone, Copy, Debug)]
pub struct TileCost {
    /// Cost of one constituent CE.
    pub ce: CeCost,
    /// Tile area incl. H-tree, buffer, and activation unit, mm².
    pub area_mm2: f64,
    /// Buffer bits provisioned per tile.
    pub buffer_bits: usize,
    /// H-tree + buffer + activation energy per PE read, J.
    pub overhead_per_read_j: f64,
    /// Tile leakage, W.
    pub leakage_w: f64,
}

impl TileCost {
    /// Price one tile under `cfg`.
    pub fn new(cfg: &ArchConfig) -> Self {
        let ce = CeCost::new(cfg);
        let logic = LogicParams::new(cfg.tech_nm);
        // I/O buffer sized to double-buffer one full tile of input vectors:
        // pes_per_tile × pe_size elements × n_bits × 2.
        let buffer_bits = 2 * cfg.pes_per_tile() * cfg.pe_size * cfg.n_bits;
        let buffer_area = buffer_bits as f64 * logic.buffer_area_per_bit_um2 / 1e6;
        let htree_area = 0.03 * cfg.ces_per_tile as f64 * ce.area_mm2; // 3% wiring
        let activation_area = 0.01 * ce.area_mm2;
        let area_mm2 =
            cfg.ces_per_tile as f64 * ce.area_mm2 + buffer_area + htree_area + activation_area;

        let tile_edge_mm = area_mm2.sqrt();
        let out_bits = cfg.pe_size as f64;
        // Per read: H-tree traversal (≈ half tile edge) + buffer write+read
        // + ReLU (negligible, folded into shift-add constant).
        let overhead_per_read_j = out_bits * 0.5 * tile_edge_mm * logic.wire_energy_per_bit_mm_j
            + 2.0 * out_bits * logic.buffer_energy_per_bit_j;

        Self {
            ce,
            area_mm2,
            buffer_bits,
            overhead_per_read_j,
            leakage_w: ce.pe.leakage_w * cfg.pes_per_tile() as f64,
        }
    }

    /// Full per-read energy at tile level: PE read + CE bus + tile overhead.
    pub fn energy_per_read_j(&self) -> f64 {
        self.ce.pe.energy_per_read_j + self.ce.overhead_per_read_j + self.overhead_per_read_j
    }

    /// Cost of one tile performing `reads` PE reads with `parallel_pes`
    /// PEs active concurrently.
    pub fn read_cost(&self, cfg: &ArchConfig, reads: usize, parallel_pes: usize) -> Cost {
        let parallel = parallel_pes.clamp(1, cfg.pes_per_tile());
        let rounds = reads.div_ceil(parallel);
        Cost {
            area_mm2: self.area_mm2,
            energy_j: self.energy_per_read_j() * reads as f64,
            latency_s: (self.ce.pe.cycles_per_read * rounds) as f64 / cfg.freq_hz,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hierarchy_areas_nest() {
        let cfg = ArchConfig::default();
        let ce = CeCost::new(&cfg);
        let tile = TileCost::new(&cfg);
        assert!(ce.area_mm2 > cfg.pes_per_ce as f64 * ce.pe.area_mm2);
        assert!(tile.area_mm2 > cfg.ces_per_tile as f64 * ce.area_mm2);
        // Overheads must stay overheads: < 20% on top of raw arrays.
        let raw = cfg.pes_per_tile() as f64 * ce.pe.area_mm2;
        assert!(tile.area_mm2 < 1.2 * raw + 0.5, "tile {}", tile.area_mm2);
    }

    #[test]
    fn tile_energy_exceeds_pe_energy() {
        let cfg = ArchConfig::default();
        let tile = TileCost::new(&cfg);
        assert!(tile.energy_per_read_j() > tile.ce.pe.energy_per_read_j);
        // ...but interconnect/buffer overhead is bounded (< 50%).
        assert!(tile.energy_per_read_j() < 1.5 * tile.ce.pe.energy_per_read_j);
    }

    #[test]
    fn parallel_reads_cut_latency_not_energy() {
        let cfg = ArchConfig::default();
        let tile = TileCost::new(&cfg);
        let serial = tile.read_cost(&cfg, 16, 1);
        let parallel = tile.read_cost(&cfg, 16, 16);
        assert!((serial.energy_j - parallel.energy_j).abs() < 1e-18);
        assert!((serial.latency_s / parallel.latency_s - 16.0).abs() < 1e-9);
    }

    #[test]
    fn buffer_sized_for_double_buffering() {
        let cfg = ArchConfig::default();
        let tile = TileCost::new(&cfg);
        assert_eq!(tile.buffer_bits, 2 * 16 * 256 * 8);
    }
}
