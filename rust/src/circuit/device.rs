//! Technology/device constants. Values are calibrated at 32 nm against the
//! component budgets of the silicon macros the paper cites (SRAM: Khwa et
//! al. ISSCC'18 [12]; ReRAM 1T1R: NeuroSim [2]) and ISAAC's published
//! breakdowns, then scaled to other nodes with standard F² (area) / F
//! (energy, delay) rules. Absolute numbers are *model* numbers — all paper
//! claims we reproduce are relative (see DESIGN.md §2).

use crate::config::{ArchConfig, MemTech};

/// Per-technology device parameters at the configured node.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceParams {
    /// Bitcell area in µm².
    pub cell_area_um2: f64,
    /// Energy to read one cell onto the bitline during an analog MAC, in J.
    pub cell_read_energy_j: f64,
    /// Array read cycles per input bit-plane (sensing speed; SRAM resolves
    /// in one cycle at 1 GHz, ReRAM needs two).
    pub read_cycles_per_bitplane: usize,
    /// Write energy per cell in J (weight loading; excluded from inference
    /// energy per the paper's §5 assumption, reported separately).
    pub cell_write_energy_j: f64,
    /// Leakage power per cell in W (SRAM only; ReRAM is non-volatile).
    pub cell_leakage_w: f64,
}

/// Feature size scaling helper: area ∝ F², energy/delay ∝ F (to first order).
fn scale(base_32nm: f64, tech_nm: f64, exponent: f64) -> f64 {
    base_32nm * (tech_nm / 32.0).powf(exponent)
}

impl DeviceParams {
    /// Bitcell constants for `tech`, scaled to `tech_nm`.
    pub fn new(tech: MemTech, tech_nm: f64) -> Self {
        match tech {
            MemTech::Sram => Self {
                // 8T compute-SRAM bitcell ≈ 190 F² -> 0.195 µm² at 32 nm.
                cell_area_um2: scale(0.195, tech_nm, 2.0),
                // Bitline discharge per cell per bit-plane MAC.
                cell_read_energy_j: scale(0.28e-15, tech_nm, 1.0),
                read_cycles_per_bitplane: 1,
                cell_write_energy_j: scale(5.0e-15, tech_nm, 1.0),
                cell_leakage_w: scale(2.0e-12, tech_nm, 1.0),
            },
            MemTech::Reram => Self {
                // 1T1R cell ≈ 12 F² -> 0.0123 µm² at 32 nm.
                cell_area_um2: scale(0.0123, tech_nm, 2.0),
                // Current through the RRAM device per bit-plane MAC.
                cell_read_energy_j: scale(0.04e-15, tech_nm, 1.0),
                read_cycles_per_bitplane: 2,
                cell_write_energy_j: scale(1.0e-12, tech_nm, 1.0),
                cell_leakage_w: 0.0,
            },
        }
    }

    /// Bitcell constants from an [`ArchConfig`].
    pub fn from_arch(cfg: &ArchConfig) -> Self {
        Self::new(cfg.tech, cfg.tech_nm)
    }
}

/// Digital-logic constants shared by both technologies (32 nm base).
#[derive(Clone, Copy, Debug)]
pub struct LogicParams {
    /// Energy per bit of shift-and-add, J.
    pub shift_add_energy_per_bit_j: f64,
    /// Shift-and-add area per output column, µm².
    pub shift_add_area_um2: f64,
    /// SRAM buffer: area per bit, µm².
    pub buffer_area_per_bit_um2: f64,
    /// SRAM buffer: access energy per bit, J.
    pub buffer_energy_per_bit_j: f64,
    /// Router-less local wire energy per bit per mm, J.
    pub wire_energy_per_bit_mm_j: f64,
}

impl LogicParams {
    /// Logic constants scaled to `tech_nm`.
    pub fn new(tech_nm: f64) -> Self {
        Self {
            shift_add_energy_per_bit_j: scale(2.0e-15, tech_nm, 1.0),
            shift_add_area_um2: scale(60.0, tech_nm, 2.0),
            buffer_area_per_bit_um2: scale(0.35, tech_nm, 2.0),
            buffer_energy_per_bit_j: scale(10.0e-15, tech_nm, 1.0),
            wire_energy_per_bit_mm_j: scale(60.0e-15, tech_nm, 1.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reram_denser_than_sram() {
        let s = DeviceParams::new(MemTech::Sram, 32.0);
        let r = DeviceParams::new(MemTech::Reram, 32.0);
        assert!(r.cell_area_um2 < s.cell_area_um2 / 10.0);
        assert!(r.cell_read_energy_j < s.cell_read_energy_j);
        assert!(r.read_cycles_per_bitplane > s.read_cycles_per_bitplane);
        assert_eq!(r.cell_leakage_w, 0.0);
    }

    #[test]
    fn scaling_laws() {
        let a32 = DeviceParams::new(MemTech::Sram, 32.0);
        let a64 = DeviceParams::new(MemTech::Sram, 64.0);
        assert!((a64.cell_area_um2 / a32.cell_area_um2 - 4.0).abs() < 1e-9);
        assert!((a64.cell_read_energy_j / a32.cell_read_energy_j - 2.0).abs() < 1e-9);
    }
}
