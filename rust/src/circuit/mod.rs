//! Circuit-level performance estimator for the IMC compute fabric — the
//! NeuroSim-class substrate of the paper's simulator (Fig. 6, left half).
//!
//! The estimator is a hierarchy of parametric macro-models:
//!
//! * [`device`] — technology constants (32 nm default) for SRAM-8T and
//!   ReRAM-1T1R bitcells, calibrated against the silicon macros the paper
//!   cites ([12] SRAM, [2] ReRAM) and ISAAC-class component budgets,
//! * [`adc`] — 4-bit flash ADC + sample-and-hold + column mux,
//! * [`crossbar`] — one PE: cell array + column periphery + shift-add,
//! * [`tile`] — CE (4 PEs + local bus) and tile (4 CEs + H-tree + buffers +
//!   activation/accumulation units), matching Fig. 10,
//! * [`chip`] — per-layer and whole-DNN compute latency / energy / area
//!   (interconnect cost is *excluded* here; the paper replaces NeuroSim's
//!   interconnect with BookSim, and so do we — see [`crate::noc`]).

pub mod adc;
pub mod chip;
pub mod crossbar;
pub mod device;
pub mod tile;

pub use chip::{ChipCost, LayerCost};
pub use crossbar::PeCost;
pub use device::DeviceParams;
pub use tile::{CeCost, TileCost};

/// Area/energy/latency triple every level of the hierarchy reports.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    /// Silicon area in mm².
    pub area_mm2: f64,
    /// Energy in joules (for whatever operation the context defines).
    pub energy_j: f64,
    /// Latency in seconds.
    pub latency_s: f64,
}

impl Cost {
    /// The all-zero cost (additive identity).
    pub fn zero() -> Self {
        Self::default()
    }

    /// Energy-delay-area product in J·ms·mm² (the paper's EDAP unit).
    pub fn edap(&self) -> f64 {
        self.energy_j * (self.latency_s * 1e3) * self.area_mm2
    }

    /// Average power in watts over the operation.
    pub fn power_w(&self) -> f64 {
        if self.latency_s > 0.0 {
            self.energy_j / self.latency_s
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edap_units() {
        let c = Cost {
            area_mm2: 100.0,
            energy_j: 1e-3,
            latency_s: 2e-3,
        };
        // 1e-3 J * 2 ms * 100 mm^2 = 0.2 J.ms.mm^2
        assert!((c.edap() - 0.2).abs() < 1e-12);
        assert!((c.power_w() - 0.5).abs() < 1e-12);
    }
}
