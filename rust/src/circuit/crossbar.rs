//! One IMC processing element (PE): a `pe_size × pe_size` crossbar with its
//! column periphery and shift-and-add recombination logic.
//!
//! Operation model (paper §5.2 / §6.1, parallel read-out): all rows are
//! asserted together; inputs arrive bit-serially over `n_bits` planes; each
//! bit-plane's bitline result is digitized by the 4-bit flash ADCs and
//! recombined by shift-and-add. One "read" therefore produces, for every
//! weight column, the full dot product of a `pe_size`-long input vector.

use super::adc::AdcParams;
use super::device::{DeviceParams, LogicParams};
use super::Cost;
use crate::config::ArchConfig;

/// Static and per-operation costs of one PE.
#[derive(Clone, Copy, Debug)]
pub struct PeCost {
    /// Total PE area (array + periphery), mm².
    pub area_mm2: f64,
    /// Energy of one full read (all bit-planes, all columns), J.
    pub energy_per_read_j: f64,
    /// Cycles of one full read at the configured frequency.
    pub cycles_per_read: usize,
    /// Leakage power, W.
    pub leakage_w: f64,
    /// One-time weight-programming energy for a full array, J.
    pub program_energy_j: f64,
}

impl PeCost {
    /// Price one crossbar PE under `cfg`.
    pub fn new(cfg: &ArchConfig) -> Self {
        let dev = DeviceParams::from_arch(cfg);
        let logic = LogicParams::new(cfg.tech_nm);
        let adc = AdcParams::flash(cfg.adc_bits, cfg.tech_nm);
        let n = cfg.pe_size;
        let cells = n * n;

        // --- Area ---
        let array_um2 = cells as f64 * dev.cell_area_um2;
        let n_adcs = adc.adcs_per_array(n);
        let periph_um2 = n_adcs as f64 * adc.area_um2
            + n as f64 * adc.sh_area_um2
            + (n / cfg.n_bits.max(1)) as f64 * logic.shift_add_area_um2;
        let area_mm2 = (array_um2 + periph_um2) / 1e6;

        // --- One full read ---
        // Cycles: n_bits bit-planes × device sensing cycles. The column-mux
        // conversions of bit-plane k are pipelined with the array read of
        // bit-plane k+1 (flash ADCs convert in well under a cycle), so the
        // mux fill does not extend the read.
        let cycles_per_read = cfg.n_bits * dev.read_cycles_per_bitplane;
        // Energy: every cell contributes per bit-plane; every column is
        // converted per bit-plane; shift-add merges n_bits planes per column.
        let cell_e = cells as f64 * dev.cell_read_energy_j * cfg.n_bits as f64;
        let adc_e =
            adc.conversions_per_bitplane(n) as f64 * cfg.n_bits as f64 * adc.energy_per_conv_j;
        let sh_e = n as f64 * cfg.n_bits as f64 * adc.sh_energy_j;
        let sa_e = n as f64 * cfg.n_bits as f64 * logic.shift_add_energy_per_bit_j;
        let energy_per_read_j = cell_e + adc_e + sh_e + sa_e;

        Self {
            area_mm2,
            energy_per_read_j,
            cycles_per_read,
            leakage_w: cells as f64 * dev.cell_leakage_w,
            program_energy_j: cells as f64 * dev.cell_write_energy_j,
        }
    }

    /// Useful MACs per full read when the array is fully occupied:
    /// `pe_size` rows × (`pe_size`/`n_bits`) weight columns.
    pub fn macs_per_read(&self, cfg: &ArchConfig) -> usize {
        cfg.pe_size * (cfg.pe_size / cfg.n_bits.max(1))
    }

    /// Cost of `reads` sequential reads on one PE.
    pub fn read_cost(&self, cfg: &ArchConfig, reads: usize) -> Cost {
        Cost {
            area_mm2: self.area_mm2,
            energy_j: self.energy_per_read_j * reads as f64,
            latency_s: (self.cycles_per_read * reads) as f64 / cfg.freq_hz,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemTech;

    #[test]
    fn energy_per_mac_in_calibrated_band() {
        // DESIGN.md calibration targets: ReRAM ≈ 20–50 fJ/MAC,
        // SRAM ≈ 1.5–3× ReRAM (paper Table 4 power ratio).
        let reram = ArchConfig::reram();
        let sram = ArchConfig::sram();
        let pr = PeCost::new(&reram);
        let ps = PeCost::new(&sram);
        let fj = |p: &PeCost, c: &ArchConfig| {
            p.energy_per_read_j / p.macs_per_read(c) as f64 * 1e15
        };
        let r = fj(&pr, &reram);
        let s = fj(&ps, &sram);
        assert!((15.0..60.0).contains(&r), "ReRAM {r} fJ/MAC");
        assert!(s > 1.3 * r && s < 4.0 * r, "SRAM {s} vs ReRAM {r} fJ/MAC");
    }

    #[test]
    fn sram_reads_faster_reram_denser() {
        let pr = PeCost::new(&ArchConfig::reram());
        let ps = PeCost::new(&ArchConfig::sram());
        assert!(ps.cycles_per_read < pr.cycles_per_read);
        // ReRAM PE area is dominated by periphery, SRAM by cells; the SRAM
        // PE must still be bigger overall.
        assert!(ps.area_mm2 > pr.area_mm2);
    }

    #[test]
    fn read_cost_scales_linearly() {
        let cfg = ArchConfig::default();
        let p = PeCost::new(&cfg);
        let one = p.read_cost(&cfg, 1);
        let ten = p.read_cost(&cfg, 10);
        assert!((ten.energy_j - 10.0 * one.energy_j).abs() < 1e-18);
        assert!((ten.latency_s - 10.0 * one.latency_s).abs() < 1e-15);
        assert_eq!(one.area_mm2, ten.area_mm2);
    }

    #[test]
    fn macs_per_read_default() {
        let cfg = ArchConfig::default();
        let p = PeCost::new(&cfg);
        // 256 rows x 32 8-bit weight columns.
        assert_eq!(p.macs_per_read(&cfg), 256 * 32);
        assert_eq!(cfg.tech, MemTech::Reram);
    }
}
