//! Per-layer and whole-chip compute costs (interconnect excluded — the
//! paper replaces NeuroSim's interconnect with BookSim; ours lives in
//! [`crate::noc`]).
//!
//! Execution model (paper §5): layer-by-layer, all weights resident
//! on-chip, no DRAM traffic, no pipelining across layers. Within a layer,
//! every crossbar holding a slice of that layer works in parallel on the
//! same input vector; successive input vectors (conv output pixels) are
//! processed sequentially through the bit-serial read pipeline.

use super::tile::TileCost;
use super::Cost;
use crate::config::ArchConfig;
use crate::dnn::{DnnGraph, LayerKind};
use crate::mapping::Mapping;

/// Compute cost of one weight layer.
#[derive(Clone, Debug)]
pub struct LayerCost {
    /// Graph index of the layer.
    pub layer: usize,
    /// Crossbar reads per crossbar (conv: one per output pixel; FC: one).
    pub reads: usize,
    /// Compute cycles for the layer.
    pub cycles: u64,
    /// Compute energy for the layer (all crossbars, all reads), J.
    pub energy_j: f64,
}

/// Whole-chip compute rollup for one DNN.
#[derive(Clone, Debug)]
pub struct ChipCost {
    /// Per-layer costs, in mapping order.
    pub per_layer: Vec<LayerCost>,
    /// Total compute latency, s (layer-by-layer sum).
    pub latency_s: f64,
    /// Total compute energy incl. leakage, J.
    pub energy_j: f64,
    /// Chip area (tiles only; NoC area is added by the arch evaluator), mm².
    pub area_mm2: f64,
    /// One-time weight-programming energy (reported, not charged to
    /// inference — paper §5).
    pub program_energy_j: f64,
}

impl ChipCost {
    /// Evaluate the compute fabric for `graph` under `cfg` and `mapping`.
    pub fn evaluate(graph: &DnnGraph, mapping: &Mapping, cfg: &ArchConfig) -> Self {
        let tile = TileCost::new(cfg);
        let mut per_layer = Vec::with_capacity(mapping.layers.len());
        let mut total_cycles: u64 = 0;
        let mut energy = 0.0f64;

        for lt in &mapping.layers {
            let layer = &graph.layers[lt.layer];
            let reads = match layer.kind {
                LayerKind::Conv { .. } => layer.out_x * layer.out_y,
                LayerKind::Fc { .. } => 1,
                _ => 0,
            };
            let cycles = (reads * tile.ce.pe.cycles_per_read) as u64;
            // Every allocated crossbar fires on every read; tile-level
            // overhead is charged per read per crossbar.
            let e = lt.crossbars as f64 * reads as f64 * tile.energy_per_read_j();
            per_layer.push(LayerCost {
                layer: lt.layer,
                reads,
                cycles,
                energy_j: e,
            });
            total_cycles += cycles;
            energy += e;
        }

        let latency_s = total_cycles as f64 / cfg.freq_hz;
        let area_mm2 = mapping.total_tiles as f64 * tile.area_mm2;
        let leakage = tile.leakage_w * mapping.total_tiles as f64 * latency_s;
        let program_energy_j =
            mapping.total_crossbars as f64 * tile.ce.pe.program_energy_j;

        Self {
            per_layer,
            latency_s,
            energy_j: energy + leakage,
            area_mm2,
            program_energy_j,
        }
    }

    /// Aggregate compute cost triple.
    pub fn cost(&self) -> Cost {
        Cost {
            area_mm2: self.area_mm2,
            energy_j: self.energy_j,
            latency_s: self.latency_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;

    fn chip(g: &DnnGraph, cfg: &ArchConfig) -> ChipCost {
        let m = Mapping::build(g, cfg);
        ChipCost::evaluate(g, &m, cfg)
    }

    #[test]
    fn vgg19_reram_in_calibrated_band() {
        // DESIGN.md calibration: ReRAM VGG-19 latency O(1) ms, power O(0.1–1) W,
        // area O(100) mm² — same order as the paper's Table 4 row.
        let g = models::vgg(19);
        let c = chip(&g, &ArchConfig::reram());
        assert!(
            (0.5e-3..8e-3).contains(&c.latency_s),
            "latency {}",
            c.latency_s
        );
        let p = c.cost().power_w();
        assert!((0.1..3.0).contains(&p), "power {p}");
        assert!((50.0..900.0).contains(&c.area_mm2), "area {}", c.area_mm2);
    }

    #[test]
    fn sram_faster_but_bigger_than_reram() {
        let g = models::vgg(19);
        let s = chip(&g, &ArchConfig::sram());
        let r = chip(&g, &ArchConfig::reram());
        assert!(s.latency_s < r.latency_s, "SRAM must be faster");
        assert!(s.area_mm2 > r.area_mm2, "SRAM must be bigger");
        // Paper Table 4: SRAM latency ~2.2x lower.
        let ratio = r.latency_s / s.latency_s;
        assert!((1.2..3.0).contains(&ratio), "latency ratio {ratio}");
    }

    #[test]
    fn fc_layers_read_once() {
        let g = models::mlp();
        let cfg = ArchConfig::default();
        let c = chip(&g, &cfg);
        assert!(c.per_layer.iter().all(|l| l.reads == 1));
    }

    #[test]
    fn conv_reads_match_output_pixels() {
        let g = models::lenet5();
        let cfg = ArchConfig::default();
        let c = chip(&g, &cfg);
        // conv1 emits 28x28.
        assert_eq!(c.per_layer[0].reads, 28 * 28);
    }

    #[test]
    fn energy_monotone_in_model_size() {
        let cfg = ArchConfig::default();
        let small = chip(&models::lenet5(), &cfg);
        let big = chip(&models::vgg(19), &cfg);
        assert!(big.energy_j > 100.0 * small.energy_j);
        assert!(big.area_mm2 > small.area_mm2);
    }

    #[test]
    fn program_energy_reported_separately(){
        let cfg = ArchConfig::default();
        let c = chip(&models::lenet5(), &cfg);
        assert!(c.program_energy_j > 0.0);
    }
}
