//! Column periphery: flash ADC, sample-and-hold, column multiplexer.
//!
//! The paper's architecture (§5.2) digitizes each bitline with a 4-bit
//! flash ADC behind an 8:1 column mux, with no DAC (inputs are bit-serial).

/// Column-periphery macro-model.
#[derive(Clone, Copy, Debug)]
pub struct AdcParams {
    /// Resolution in bits.
    pub bits: usize,
    /// Columns sharing one ADC through the mux.
    pub mux_share: usize,
    /// ADC area in µm² (flash: ~2^bits comparators + thermometer decode).
    pub area_um2: f64,
    /// Energy per conversion in J.
    pub energy_per_conv_j: f64,
    /// Sample-and-hold area per column, µm².
    pub sh_area_um2: f64,
    /// Sample-and-hold energy per sample, J.
    pub sh_energy_j: f64,
}

impl AdcParams {
    /// Flash-ADC model: area and energy grow with 2^bits (comparator count),
    /// scaled from a 4-bit/32 nm calibration point (~150 µm², ~90 fJ/conv).
    pub fn flash(bits: usize, tech_nm: f64) -> Self {
        let comparators = (1usize << bits) - 1;
        let base_comparators = 15.0; // 4-bit reference
        let f2 = (tech_nm / 32.0) * (tech_nm / 32.0);
        let f1 = tech_nm / 32.0;
        Self {
            bits,
            mux_share: 8,
            area_um2: 150.0 * (comparators as f64 / base_comparators) * f2,
            energy_per_conv_j: 90.0e-15 * (comparators as f64 / base_comparators) * f1,
            sh_area_um2: 2.0 * f2,
            sh_energy_j: 1.0e-15 * f1,
        }
    }

    /// ADCs needed to serve `columns` bitlines.
    pub fn adcs_per_array(&self, columns: usize) -> usize {
        columns.div_ceil(self.mux_share)
    }

    /// Conversions to digitize all `columns` once (one bit-plane).
    pub fn conversions_per_bitplane(&self, columns: usize) -> usize {
        columns
    }

    /// Extra cycles serialized by the mux per bit-plane (the `mux_share`
    /// conversions behind each ADC are pipelined with array reads after the
    /// first, so only the fill cost is exposed).
    pub fn mux_fill_cycles(&self) -> usize {
        self.mux_share - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_bit_reference_point() {
        let a = AdcParams::flash(4, 32.0);
        assert_eq!(a.bits, 4);
        assert!((a.area_um2 - 150.0).abs() < 1e-9);
        assert!((a.energy_per_conv_j - 90.0e-15).abs() < 1e-24);
    }

    #[test]
    fn higher_resolution_costs_exponentially() {
        let a4 = AdcParams::flash(4, 32.0);
        let a8 = AdcParams::flash(8, 32.0);
        // 255/15 = 17x comparators.
        assert!(a8.area_um2 / a4.area_um2 > 16.0);
        assert!(a8.energy_per_conv_j / a4.energy_per_conv_j > 16.0);
    }

    #[test]
    fn sharing_math() {
        let a = AdcParams::flash(4, 32.0);
        assert_eq!(a.adcs_per_array(256), 32);
        assert_eq!(a.conversions_per_bitplane(256), 256);
        assert_eq!(a.mux_fill_cycles(), 7);
    }
}
