//! Inference serving: one report type for two serving paths.
//!
//! * [`InferenceServer`] batches requests through a PJRT-compiled artifact
//!   and *measures* wall-clock latency (the functional end of the stack —
//!   the AOT artifacts compute the quantized IMC forward pass, Layer 1/2,
//!   and Python is never on this path).
//! * [`crate::coordinator::scheduler::ChipletScheduler`] serves the same
//!   workload against the *modeled* chiplet package (no PJRT needed).
//!
//! Both emit a [`ServeReport`]: requests/batches/drops, latency
//! percentiles, throughput — plus per-chiplet queue statistics on the
//! modeled path and raw output vectors on the measured path.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::{LoadedModel, Runtime};
use crate::telemetry::QuantileSketch;
use crate::util::Pcg32;

/// Queue statistics for one chiplet of a modeled serving run.
#[derive(Clone, Debug)]
pub struct ChipletQueueStats {
    /// Chiplet id the stats describe.
    pub chiplet: usize,
    /// Requests this chiplet served.
    pub served: usize,
    /// Busy fraction over the whole run.
    pub utilization: f64,
    /// Deepest backlog its queue reached.
    pub peak_queue: usize,
}

/// Per-model statistics of a multi-model (mix) serving run
/// ([`crate::coordinator::mix::MixScheduler`]).
#[derive(Clone, Debug)]
pub struct ModelServeStats {
    /// Model name within the mix.
    pub model: String,
    /// Replica chiplets this model was pinned to.
    pub replicas: usize,
    /// Requests offered to this model.
    pub offered: usize,
    /// Requests that produced a result.
    pub completed: usize,
    /// Requests dropped on full queues.
    pub dropped: usize,
    /// Requests declined by deadline-aware admission.
    pub shed: usize,
    /// Offered requests carrying a finite deadline.
    pub deadline_offered: usize,
    /// Deadline-carrying requests completed within it
    /// (dropped/shed/late ones are misses).
    pub deadline_hits: usize,
    /// Mean latency over this model's completed requests, ms.
    pub mean_ms: f64,
    /// Median latency, ms.
    pub p50_ms: f64,
    /// 99th-percentile latency, ms.
    pub p99_ms: f64,
    /// Mean NoP ingress duration, ms (phases sum to `mean_ms`).
    pub mean_ingress_ms: f64,
    /// Mean queue wait, ms.
    pub mean_queue_ms: f64,
    /// Mean chiplet service incl. egress, ms.
    pub mean_service_ms: f64,
}

impl ModelServeStats {
    /// Deadline hit-rate: hits over deadline-carrying offered requests
    /// (1.0 when the model has no deadline).
    pub fn hit_rate(&self) -> f64 {
        if self.deadline_offered == 0 {
            1.0
        } else {
            self.deadline_hits as f64 / self.deadline_offered as f64
        }
    }
}

/// Serving statistics for one run (measured or modeled).
///
/// On the PJRT path the latency samples are per-*batch* wall-clock times;
/// on the modeled path they are per-*request* modeled latencies. Fields
/// that only one path produces are empty on the other.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Total requests offered to the run.
    pub requests: usize,
    /// Requests that produced a result (modeled runs can drop on full
    /// queues; the PJRT path always completes everything).
    pub completed: usize,
    /// Requests dropped on full queues.
    pub dropped: usize,
    /// Requests declined by deadline-aware admission (their modeled
    /// completion already exceeded the deadline). Always 0 under
    /// drop-on-full admission and on the PJRT path. Conservation:
    /// `completed + dropped + shed == requests`.
    pub shed: usize,
    /// Offered requests carrying a finite deadline (multi-model runs
    /// only; 0 elsewhere).
    pub deadline_offered: usize,
    /// Deadline-carrying requests completed within their deadline.
    pub deadline_hits: usize,
    /// Requests per batch the run was driven at.
    pub batch_size: usize,
    /// Number of batches executed.
    pub batches: usize,
    /// Mean latency over the run's samples, ms.
    pub mean_ms: f64,
    /// Median latency, ms.
    pub p50_ms: f64,
    /// 99th-percentile latency, ms.
    pub p99_ms: f64,
    /// Mean NoP ingress duration over completed requests, ms. The three
    /// phase means sum to `mean_ms` on the modeled paths; all 0 on the
    /// PJRT path, which has no modeled phases.
    pub mean_ingress_ms: f64,
    /// Mean queue wait, ms.
    pub mean_queue_ms: f64,
    /// Mean chiplet service incl. egress, ms.
    pub mean_service_ms: f64,
    /// Completed requests per second end to end.
    pub throughput_rps: f64,
    /// Arrival rate the run was driven at (modeled path only; the
    /// scheduler records the auto-derived rate here so reports never
    /// re-derive it). 0 on the PJRT path.
    pub offered_rps: f64,
    /// Per-chiplet queue statistics (modeled path only).
    pub per_chiplet: Vec<ChipletQueueStats>,
    /// Per-model statistics (multi-model runs only).
    pub per_model: Vec<ModelServeStats>,
    /// Output vectors per request (PJRT path only).
    pub outputs: Vec<Vec<f32>>,
}

impl ServeReport {
    /// Assemble a report from latency samples (ms) and the wall-clock /
    /// modeled horizon of the whole run. Thin wrapper over
    /// [`ServeReport::from_sketch`]: the samples are folded into a
    /// [`QuantileSketch`] first, so both serving paths share the same
    /// bounded-memory percentile estimator (mean and throughput stay
    /// exact; p50/p99 carry the sketch's documented relative-error
    /// bound, [`crate::telemetry::sketch::RELATIVE_ERROR`]).
    pub fn from_latencies_ms(
        requests: usize,
        completed: usize,
        dropped: usize,
        batch_size: usize,
        batches: usize,
        latencies_ms: &[f64],
        horizon_s: f64,
    ) -> Self {
        let mut sketch = QuantileSketch::new();
        for &v in latencies_ms {
            sketch.record(v);
        }
        Self::from_sketch(
            requests, completed, dropped, batch_size, batches, &sketch, horizon_s,
        )
    }

    /// Assemble a report from a latency [`QuantileSketch`] (ms) — the O(1)
    /// memory path the serving schedulers stream into, so million-request
    /// runs never materialize a latency vector.
    pub fn from_sketch(
        requests: usize,
        completed: usize,
        dropped: usize,
        batch_size: usize,
        batches: usize,
        latency_ms: &QuantileSketch,
        horizon_s: f64,
    ) -> Self {
        Self {
            requests,
            completed,
            dropped,
            shed: 0,
            deadline_offered: 0,
            deadline_hits: 0,
            batch_size,
            batches,
            mean_ms: latency_ms.mean(),
            p50_ms: latency_ms.quantile(50.0),
            p99_ms: latency_ms.quantile(99.0),
            mean_ingress_ms: 0.0,
            mean_queue_ms: 0.0,
            mean_service_ms: 0.0,
            throughput_rps: completed as f64 / horizon_s.max(1e-12),
            offered_rps: 0.0,
            per_chiplet: Vec::new(),
            per_model: Vec::new(),
            outputs: Vec::new(),
        }
    }

    /// Deadline hit-rate over every deadline-carrying offered request
    /// (1.0 when none carried a deadline).
    pub fn hit_rate(&self) -> f64 {
        if self.deadline_offered == 0 {
            1.0
        } else {
            self.deadline_hits as f64 / self.deadline_offered as f64
        }
    }
}

/// Flatten `chunk` into one `[bs, in_dim]` batch, zero-padding the tail.
/// `base` is the index of the chunk's first request in the full request
/// list, so shape errors name the offending request.
pub fn pad_batch(chunk: &[Vec<f32>], bs: usize, in_dim: usize, base: usize) -> Result<Vec<f32>> {
    let mut flat = Vec::with_capacity(bs * in_dim);
    for (i, r) in chunk.iter().enumerate() {
        if r.len() != in_dim {
            bail!(
                "request {} has {} features, expected in_dim = {}",
                base + i,
                r.len(),
                in_dim
            );
        }
        flat.extend_from_slice(r);
    }
    flat.resize(bs * in_dim, 0.0);
    Ok(flat)
}

/// A batched single-model inference server (the PJRT-measured path).
pub struct InferenceServer {
    runtime: Runtime,
    batch_size: usize,
}

impl InferenceServer {
    /// A server backed by a CPU PJRT client (errors on stub builds).
    pub fn new(batch_size: usize) -> Result<Self> {
        Ok(Self {
            runtime: Runtime::cpu()?,
            batch_size: batch_size.max(1),
        })
    }

    /// PJRT platform name (e.g. "cpu"; "stub" on stub builds).
    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Load a model artifact.
    pub fn load(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        self.runtime.load(path)?;
        Ok(())
    }

    /// Serve `requests` feature vectors of length `in_dim` through the
    /// loaded artifact at `path`. The artifact must accept a single
    /// `[batch, in_dim]` f32 input (the AOT models are lowered at a fixed
    /// batch; requests are padded into full batches). A request whose
    /// feature length is not `in_dim` fails the run with an error naming
    /// its index.
    pub fn serve(
        &mut self,
        path: impl AsRef<std::path::Path>,
        requests: &[Vec<f32>],
        in_dim: usize,
    ) -> Result<ServeReport> {
        let model: &LoadedModel = self.runtime.load(path)?;
        let bs = self.batch_size;
        let mut batch_times = Vec::new();
        let mut outputs = Vec::with_capacity(requests.len());
        let t0 = Instant::now();
        for (chunk_idx, chunk) in requests.chunks(bs).enumerate() {
            let flat = pad_batch(chunk, bs, in_dim, chunk_idx * bs)?;
            let tb = Instant::now();
            let result = model.run_f32(&[(&flat, &[bs as i64, in_dim as i64])])?;
            batch_times.push(tb.elapsed().as_secs_f64() * 1e3);
            // First tuple element is the logits tensor [bs, classes].
            let logits = &result[0];
            let classes = logits.len() / bs;
            for i in 0..chunk.len() {
                outputs.push(logits[i * classes..(i + 1) * classes].to_vec());
            }
        }
        let total_s = t0.elapsed().as_secs_f64();
        let mut report = ServeReport::from_latencies_ms(
            requests.len(),
            requests.len(),
            0,
            bs,
            batch_times.len(),
            &batch_times,
            total_s,
        );
        report.outputs = outputs;
        Ok(report)
    }
}

/// Generate a synthetic digit-like workload: `n` feature vectors in [0, 1)
/// with a deterministic seed (the e2e example and benches share this).
pub fn synthetic_requests(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.next_f64() as f32).collect())
        .collect()
}

/// Argmax helper for classifier outputs.
pub fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_requests_deterministic() {
        let a = synthetic_requests(4, 8, 7);
        let b = synthetic_requests(4, 8, 7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        assert!(a.iter().all(|r| r.len() == 8));
        assert!(a.iter().flatten().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn pad_batch_zero_fills_partial_batches() {
        let reqs = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let flat = pad_batch(&reqs, 4, 2, 0).unwrap();
        assert_eq!(flat.len(), 8);
        assert_eq!(&flat[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert!(flat[4..].iter().all(|&x| x == 0.0));
        // A full batch is passed through unchanged.
        let full = pad_batch(&reqs, 2, 2, 0).unwrap();
        assert_eq!(full, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn pad_batch_names_the_offending_request() {
        // The mismatch sits at global index base + local offset; the error
        // must say so instead of panicking (regression for the old
        // assert_eq! in `serve`).
        let reqs = vec![vec![0.0f32; 8], vec![0.0f32; 5]];
        let err = pad_batch(&reqs, 8, 8, 16).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("request 17"), "{msg}");
        assert!(msg.contains("5 features"), "{msg}");
        assert!(msg.contains("in_dim = 8"), "{msg}");
    }

    #[test]
    fn report_statistics_from_small_sample_counts() {
        // One sample: every percentile is that sample.
        let one = ServeReport::from_latencies_ms(1, 1, 0, 1, 1, &[4.0], 2.0);
        assert_eq!(one.mean_ms, 4.0);
        assert_eq!(one.p50_ms, 4.0);
        assert_eq!(one.p99_ms, 4.0);
        assert_eq!(one.throughput_rps, 0.5);
        // Four samples: p50 interpolates (within the sketch's documented
        // relative-error bound of the exact 2.5), p99 approaches the max.
        let xs = [1.0, 2.0, 3.0, 4.0];
        let four = ServeReport::from_latencies_ms(5, 4, 1, 2, 2, &xs, 8.0);
        assert_eq!(four.completed, 4);
        assert_eq!(four.dropped, 1);
        let bound = crate::telemetry::sketch::RELATIVE_ERROR;
        assert!(
            (four.p50_ms - 2.5).abs() <= bound * 2.5,
            "p50 {} vs exact 2.5",
            four.p50_ms
        );
        assert!(four.p99_ms > 3.8 && four.p99_ms <= 4.0, "{}", four.p99_ms);
        // Mean and throughput stay exact through the sketch.
        assert!((four.mean_ms - 2.5).abs() < 1e-12);
        assert_eq!(four.throughput_rps, 0.5);
        // Empty samples degrade to zeros, not NaNs.
        let none = ServeReport::from_latencies_ms(3, 0, 3, 1, 0, &[], 1.0);
        assert_eq!(none.mean_ms, 0.0);
        assert_eq!(none.p99_ms, 0.0);
        assert_eq!(none.throughput_rps, 0.0);
    }
}
