//! Inference serving loop: batches requests through a PJRT-compiled
//! artifact and reports measured latency/throughput alongside what the
//! modeled IMC chip would deliver for the same network.
//!
//! This is the functional end of the stack — the AOT artifacts compute the
//! *quantized* IMC forward pass (bit-serial inputs + 4-bit ADC, Layer 1/2),
//! while the architecture simulator prices the same computation on the
//! modeled hardware. Python is never on this path.

use std::time::Instant;

use anyhow::Result;

use crate::runtime::{LoadedModel, Runtime};
use crate::util::{percentile, Pcg32};

/// Serving statistics for one run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub batch_size: usize,
    pub batches: usize,
    /// Wall-clock per batch, ms.
    pub mean_batch_ms: f64,
    pub p50_batch_ms: f64,
    pub p99_batch_ms: f64,
    /// Requests per second end to end.
    pub throughput_rps: f64,
    /// Output vectors per request (argmax class for classifiers).
    pub outputs: Vec<Vec<f32>>,
}

/// A batched single-model inference server.
pub struct InferenceServer {
    runtime: Runtime,
    batch_size: usize,
}

impl InferenceServer {
    pub fn new(batch_size: usize) -> Result<Self> {
        Ok(Self {
            runtime: Runtime::cpu()?,
            batch_size: batch_size.max(1),
        })
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Load a model artifact.
    pub fn load(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        self.runtime.load(path)?;
        Ok(())
    }

    /// Serve `requests` feature vectors of length `in_dim` through the
    /// loaded artifact at `path`. The artifact must accept a single
    /// `[batch, in_dim]` f32 input (the AOT models are lowered at a fixed
    /// batch; requests are padded into full batches).
    pub fn serve(
        &mut self,
        path: impl AsRef<std::path::Path>,
        requests: &[Vec<f32>],
        in_dim: usize,
    ) -> Result<ServeReport> {
        let model: &LoadedModel = self.runtime.load(path)?;
        let bs = self.batch_size;
        let mut batch_times = Vec::new();
        let mut outputs = Vec::with_capacity(requests.len());
        let t0 = Instant::now();
        for chunk in requests.chunks(bs) {
            // Pad the final partial batch.
            let mut flat = Vec::with_capacity(bs * in_dim);
            for r in chunk {
                assert_eq!(r.len(), in_dim, "request feature length mismatch");
                flat.extend_from_slice(r);
            }
            flat.resize(bs * in_dim, 0.0);
            let tb = Instant::now();
            let result = model.run_f32(&[(&flat, &[bs as i64, in_dim as i64])])?;
            batch_times.push(tb.elapsed().as_secs_f64() * 1e3);
            // First tuple element is the logits tensor [bs, classes].
            let logits = &result[0];
            let classes = logits.len() / bs;
            for i in 0..chunk.len() {
                outputs.push(logits[i * classes..(i + 1) * classes].to_vec());
            }
        }
        let total_s = t0.elapsed().as_secs_f64();
        Ok(ServeReport {
            requests: requests.len(),
            batch_size: bs,
            batches: batch_times.len(),
            mean_batch_ms: crate::util::mean(&batch_times),
            p50_batch_ms: percentile(&batch_times, 50.0),
            p99_batch_ms: percentile(&batch_times, 99.0),
            throughput_rps: requests.len() as f64 / total_s.max(1e-12),
            outputs,
        })
    }
}

/// Generate a synthetic digit-like workload: `n` feature vectors in [0, 1)
/// with a deterministic seed (the e2e example and benches share this).
pub fn synthetic_requests(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.next_f64() as f32).collect())
        .collect()
}

/// Argmax helper for classifier outputs.
pub fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_requests_deterministic() {
        let a = synthetic_requests(4, 8, 7);
        let b = synthetic_requests(4, 8, 7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        assert!(a.iter().all(|r| r.len() == 8));
        assert!(a.iter().flatten().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0]), 0);
        assert_eq!(argmax(&[]), 0);
    }
}
