//! Layer-3 coordination: the simulation driver that orchestrates
//! circuit-estimator + NoC-simulator runs across DNNs/topologies/configs
//! in parallel (the paper's "simulation framework", Fig. 6), the inference
//! serving loop that batches requests through the PJRT-compiled artifacts,
//! the chiplet-aware serving scheduler that routes requests to per-chiplet
//! queues priced by the NoP cost model, and its multi-model lift — mixes
//! of DNNs with deadline-aware admission and NoP-co-optimized placement.

pub mod driver;
pub mod mix;
pub mod scheduler;
pub mod server;

pub use driver::{par_map, Driver, EvalKey};
pub use mix::{replay_mix, serve_mix, MixScheduler, MixServingModel};
pub use scheduler::{serve_modeled, ChipletScheduler, Policy, ServingModel};
pub use server::{ChipletQueueStats, InferenceServer, ModelServeStats, ServeReport};
