//! Layer-3 coordination: the simulation driver that orchestrates
//! circuit-estimator + NoC-simulator runs across DNNs/topologies/configs
//! in parallel (the paper's "simulation framework", Fig. 6), the inference
//! serving loop that batches requests through the PJRT-compiled artifacts,
//! and the chiplet-aware serving scheduler that routes requests to
//! per-chiplet queues priced by the NoP cost model.

pub mod driver;
pub mod scheduler;
pub mod server;

pub use driver::{par_map, Driver, EvalKey};
pub use scheduler::{serve_modeled, ChipletScheduler, Policy, ServingModel};
pub use server::{ChipletQueueStats, InferenceServer, ServeReport};
