//! Layer-3 coordination: the simulation driver that orchestrates
//! circuit-estimator + NoC-simulator runs across DNNs/topologies/configs
//! in parallel (the paper's "simulation framework", Fig. 6), and the
//! inference serving loop that batches requests through the PJRT-compiled
//! artifacts.

pub mod driver;
pub mod server;

pub use driver::{par_map, Driver, EvalKey};
pub use server::{InferenceServer, ServeReport};
