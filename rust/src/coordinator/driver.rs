//! Parallel sweep driver with result caching.
//!
//! Experiments evaluate many (DNN × technology × topology × NoC-config)
//! points; cycle-accurate points are expensive (the paper: up to 80% of
//! total analysis time), so the driver fans evaluations out over OS threads
//! and memoizes completed points for the lifetime of the process.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::arch::{evaluate, ArchEvaluation, CommBackend};
use crate::config::{ArchConfig, MemTech, NocConfig, SimConfig};
use crate::dnn::{by_name, DnnGraph};
use crate::noc::topology::Topology;

/// Order-preserving parallel map over OS threads: every item is handed to
/// `f` on one of up to `threads` workers (default `available_parallelism`)
/// and the results come back in input order. This is the fan-out primitive
/// behind [`Driver::evaluate_many`] and the driver-parallelized experiment
/// sweeps (e.g. `fig_nop_congestion`, and `fig_serving`'s per-point
/// serving-model builds).
pub fn par_map<T, R, F>(items: &[T], threads: Option<usize>, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        })
        .clamp(1, items.len().max(1));
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> = items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                *results[i].lock().unwrap() = Some(f(&items[i]));
            });
        }
    });
    results
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .unwrap()
                .expect("par_map worker skipped an item")
        })
        .collect()
}

/// Cache key for one evaluation point.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct EvalKey {
    /// Zoo model name.
    pub dnn: String,
    /// Memory technology (SRAM / ReRAM).
    pub tech: MemTech,
    /// Tile-level NoC topology.
    pub topology: Topology,
    /// Distinguishing NoC parameters (bus width, VCs) and backend.
    pub bus_width: usize,
    /// NoC virtual channels.
    pub virtual_channels: usize,
    /// True when the analytical comm backend priced the point.
    pub analytical: bool,
    /// PE size (for the §5.2 crossbar-size study).
    pub pe_size: usize,
}

impl EvalKey {
    /// Extract the cache key of one (model, arch, noc, backend) point.
    pub fn new(
        graph: &DnnGraph,
        arch: &ArchConfig,
        noc: &NocConfig,
        backend: CommBackend,
    ) -> Self {
        Self {
            dnn: graph.name.clone(),
            tech: arch.tech,
            topology: noc.topology,
            bus_width: noc.bus_width,
            virtual_channels: noc.virtual_channels,
            analytical: backend == CommBackend::Analytical,
            pe_size: arch.pe_size,
        }
    }
}

/// The sweep driver.
#[derive(Clone, Default)]
pub struct Driver {
    cache: Arc<Mutex<HashMap<EvalKey, ArchEvaluation>>>,
    /// Worker threads for [`Driver::evaluate_many`]; defaults to
    /// `available_parallelism`.
    pub threads: Option<usize>,
}

impl Driver {
    /// A driver with an empty cache and default thread count.
    pub fn new() -> Self {
        Self::default()
    }

    /// Evaluate one point (memoized).
    pub fn evaluate(
        &self,
        graph: &DnnGraph,
        arch: &ArchConfig,
        noc: &NocConfig,
        sim: &SimConfig,
        backend: CommBackend,
    ) -> ArchEvaluation {
        let key = EvalKey::new(graph, arch, noc, backend);
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            return hit.clone();
        }
        let result = evaluate(graph, noc.topology, arch, noc, sim, backend);
        self.cache
            .lock()
            .unwrap()
            .insert(key, result.clone());
        result
    }

    /// Evaluate a batch of points in parallel ([`par_map`] underneath).
    /// Points are specified by DNN name so they can cross thread boundaries
    /// cheaply; an unknown name fails the whole sweep with an error listing
    /// the valid model names (no worker panics).
    pub fn evaluate_many(
        &self,
        points: &[(String, ArchConfig, NocConfig, CommBackend)],
    ) -> Result<Vec<ArchEvaluation>, String> {
        let sim = SimConfig::default();
        par_map(points, self.threads, |(dnn, arch, noc, backend)| {
            let graph = by_name(dnn).ok_or_else(|| {
                format!(
                    "unknown DNN in sweep: '{dnn}' (valid: {})",
                    crate::dnn::valid_names()
                )
            })?;
            Ok(self.evaluate(&graph, arch, noc, &sim, *backend))
        })
        .into_iter()
        .collect()
    }

    /// Number of memoized evaluation points (test observability).
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;

    #[test]
    fn cache_hits_are_stable() {
        let d = Driver::new();
        let g = models::mlp();
        let arch = ArchConfig::default();
        let noc = NocConfig::default();
        let sim = SimConfig::default();
        let a = d.evaluate(&g, &arch, &noc, &sim, CommBackend::Analytical);
        assert_eq!(d.cache_len(), 1);
        let b = d.evaluate(&g, &arch, &noc, &sim, CommBackend::Analytical);
        assert_eq!(d.cache_len(), 1);
        assert_eq!(a.comm_cycles, b.comm_cycles);
    }

    #[test]
    fn parallel_sweep_preserves_order() {
        let d = Driver::new();
        let points: Vec<_> = ["MLP", "LeNet-5", "NiN"]
            .iter()
            .flat_map(|name| {
                [Topology::Tree, Topology::Mesh].into_iter().map(|t| {
                    (
                        name.to_string(),
                        ArchConfig::default(),
                        NocConfig::with_topology(t),
                        CommBackend::Analytical,
                    )
                })
            })
            .collect();
        let results = d.evaluate_many(&points).unwrap();
        assert_eq!(results.len(), 6);
        for (r, (name, _, noc, _)) in results.iter().zip(&points) {
            assert_eq!(&r.dnn, name);
            assert_eq!(r.topology, noc.topology);
        }
        assert_eq!(d.cache_len(), 6);
    }

    #[test]
    fn unknown_dnn_errors_with_valid_names() {
        let d = Driver { threads: Some(1), ..Driver::new() };
        let err = d
            .evaluate_many(&[(
                "NotANet".into(),
                ArchConfig::default(),
                NocConfig::default(),
                CommBackend::Analytical,
            )])
            .unwrap_err();
        assert!(err.contains("NotANet"), "{err}");
        assert!(err.contains("VGG-19"), "error must list valid names: {err}");
    }

    #[test]
    fn par_map_preserves_order_and_covers_all_items() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_map(&items, Some(7), |&x| x * x);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
        // Degenerate shapes: empty input and a single worker.
        assert!(par_map(&Vec::<usize>::new(), None, |&x| x).is_empty());
        assert_eq!(par_map(&[3usize], Some(1), |&x| x + 1), vec![4]);
    }
}
