//! Parallel sweep driver with result caching.
//!
//! Experiments evaluate many (DNN × technology × topology × NoC-config)
//! points; cycle-accurate points are expensive (the paper: up to 80% of
//! total analysis time), so the driver fans evaluations out over OS threads
//! and memoizes completed points for the lifetime of the process.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::arch::{evaluate, ArchEvaluation, CommBackend};
use crate::config::{ArchConfig, MemTech, NocConfig, SimConfig};
use crate::dnn::{by_name, DnnGraph};
use crate::noc::topology::Topology;

/// Cache key for one evaluation point.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct EvalKey {
    pub dnn: String,
    pub tech: MemTech,
    pub topology: Topology,
    /// Distinguishing NoC parameters (bus width, VCs) and backend.
    pub bus_width: usize,
    pub virtual_channels: usize,
    pub analytical: bool,
    /// PE size (for the §5.2 crossbar-size study).
    pub pe_size: usize,
}

impl EvalKey {
    pub fn new(
        graph: &DnnGraph,
        arch: &ArchConfig,
        noc: &NocConfig,
        backend: CommBackend,
    ) -> Self {
        Self {
            dnn: graph.name.clone(),
            tech: arch.tech,
            topology: noc.topology,
            bus_width: noc.bus_width,
            virtual_channels: noc.virtual_channels,
            analytical: backend == CommBackend::Analytical,
            pe_size: arch.pe_size,
        }
    }
}

/// The sweep driver.
#[derive(Clone, Default)]
pub struct Driver {
    cache: Arc<Mutex<HashMap<EvalKey, ArchEvaluation>>>,
    /// Worker threads for [`Driver::evaluate_many`]; defaults to
    /// `available_parallelism`.
    pub threads: Option<usize>,
}

impl Driver {
    pub fn new() -> Self {
        Self::default()
    }

    /// Evaluate one point (memoized).
    pub fn evaluate(
        &self,
        graph: &DnnGraph,
        arch: &ArchConfig,
        noc: &NocConfig,
        sim: &SimConfig,
        backend: CommBackend,
    ) -> ArchEvaluation {
        let key = EvalKey::new(graph, arch, noc, backend);
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            return hit.clone();
        }
        let result = evaluate(graph, noc.topology, arch, noc, sim, backend);
        self.cache
            .lock()
            .unwrap()
            .insert(key, result.clone());
        result
    }

    /// Evaluate a batch of points in parallel. Points are specified by DNN
    /// name so they can cross thread boundaries cheaply; unknown names
    /// panic (they indicate an experiment bug, not user input).
    pub fn evaluate_many(
        &self,
        points: &[(String, ArchConfig, NocConfig, CommBackend)],
    ) -> Vec<ArchEvaluation> {
        let threads = self
            .threads
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(4)
            })
            .max(1);
        let sim = SimConfig::default();
        let work: Vec<(usize, (String, ArchConfig, NocConfig, CommBackend))> =
            points.iter().cloned().enumerate().collect();
        let work = Arc::new(Mutex::new(work));
        let results: Arc<Mutex<Vec<Option<ArchEvaluation>>>> =
            Arc::new(Mutex::new(vec![None; points.len()]));

        std::thread::scope(|scope| {
            for _ in 0..threads.min(points.len().max(1)) {
                let work = Arc::clone(&work);
                let results = Arc::clone(&results);
                let driver = self.clone();
                let sim = sim.clone();
                scope.spawn(move || loop {
                    let item = work.lock().unwrap().pop();
                    let Some((idx, (dnn, arch, noc, backend))) = item else {
                        break;
                    };
                    let graph = by_name(&dnn)
                        .unwrap_or_else(|| panic!("unknown DNN in sweep: {dnn}"));
                    let eval = driver.evaluate(&graph, &arch, &noc, &sim, backend);
                    results.lock().unwrap()[idx] = Some(eval);
                });
            }
        });
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("worker leaked results handle"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("sweep point not evaluated"))
            .collect()
    }

    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;

    #[test]
    fn cache_hits_are_stable() {
        let d = Driver::new();
        let g = models::mlp();
        let arch = ArchConfig::default();
        let noc = NocConfig::default();
        let sim = SimConfig::default();
        let a = d.evaluate(&g, &arch, &noc, &sim, CommBackend::Analytical);
        assert_eq!(d.cache_len(), 1);
        let b = d.evaluate(&g, &arch, &noc, &sim, CommBackend::Analytical);
        assert_eq!(d.cache_len(), 1);
        assert_eq!(a.comm_cycles, b.comm_cycles);
    }

    #[test]
    fn parallel_sweep_preserves_order() {
        let d = Driver::new();
        let points: Vec<_> = ["MLP", "LeNet-5", "NiN"]
            .iter()
            .flat_map(|name| {
                [Topology::Tree, Topology::Mesh].into_iter().map(|t| {
                    (
                        name.to_string(),
                        ArchConfig::default(),
                        NocConfig::with_topology(t),
                        CommBackend::Analytical,
                    )
                })
            })
            .collect();
        let results = d.evaluate_many(&points);
        assert_eq!(results.len(), 6);
        for (r, (name, _, noc, _)) in results.iter().zip(&points) {
            assert_eq!(&r.dnn, name);
            assert_eq!(r.topology, noc.topology);
        }
        assert_eq!(d.cache_len(), 6);
    }

    #[test]
    #[should_panic(expected = "a scoped thread panicked")]
    fn unknown_dnn_panics() {
        let d = Driver { threads: Some(1), ..Driver::new() };
        d.evaluate_many(&[(
            "NotANet".into(),
            ArchConfig::default(),
            NocConfig::default(),
            CommBackend::Analytical,
        )]);
    }
}
