//! Multi-model chiplet serving: one package, a mix of DNNs, deadline-aware
//! admission, and NoP-co-optimized replica placement.
//!
//! [`super::scheduler`] serves one DNN replicated on every chiplet — the
//! regime where the paper's model-dependent interconnect choice is static.
//! This module lifts it to a [`WorkloadMix`]: each chiplet is programmed
//! with *one* model's weights (crossbars are weight-stationary), so a
//! placement assigns every chiplet to a model, and requests ride the NoP
//! from the package gateway to a replica of *their* model. The pieces:
//!
//! * [`MixModelCosts`] — per-replica modeled costs of one mix member
//!   (service, pipeline stage, ingress/egress payload, deadline).
//! * [`MixServingModel`] — the package-level cost model: a
//!   [`Placement`] from [`crate::workload::place_replicas`], zero-load
//!   ingress/egress per (model, chiplet), shared-link serialization costs,
//!   and the measured saturation utilization of the package.
//! * [`MixScheduler`] — the discrete-event simulation: trace- or
//!   generator-driven arrivals ([`Event`]), policy routing among a model's
//!   replicas, per-link ingress serialization over *shared* link state (so
//!   the mix's models contend for the same SerDes lanes), and admission
//!   control — [`Admission::DropOnFull`] or [`Admission::DeadlineAware`]
//!   shedding. Emits the same [`ServeReport`] type as every other serving
//!   path, extended with per-model deadline statistics.
//!
//! The scheduler itself is RNG-free: all randomness lives in the arrival
//! generator, which is why replaying a recorded [`Trace`] reproduces a
//! report byte-for-byte.

use std::collections::{HashMap, VecDeque};

use crate::config::{
    Admission, ArchConfig, NocConfig, NopConfig, NopMode, Policy, ServingConfig, SimConfig,
    WorkloadConfig,
};
use crate::coordinator::scheduler::{
    measured_sat_link_util, replica_costs, LinkWindow, AUTO_LOAD_FACTOR, SATURATION_BACKOFF,
};
use crate::coordinator::server::{ChipletQueueStats, ModelServeStats, ServeReport};
use crate::dnn::by_name;
use crate::mapping::Mapping;
use crate::nop::evaluator::nop_transfer_cycles;
use crate::nop::topology::{NopNetwork, NopTopology};
use crate::sim::FlowSpec;
use crate::telemetry::span::{mean_breakdown_ms, RequestSpan, SpanOutcome};
use crate::telemetry::timeseries::AUTO_WINDOWS;
use crate::telemetry::{link_union, IngressTrace, LayerBlame, QuantileSketch, TimeSeries};
use crate::util::log;
use crate::workload::{place_replicas, Event, Placement, PlacementPolicy, Trace, WorkloadMix};

/// Auto deadline (`deadline_ms = 0` in a mix spec): this multiple of the
/// model's modeled replica service time.
pub const DEADLINE_AUTO_FACTOR: f64 = 5.0;

/// Per-replica modeled costs of one mix member.
#[derive(Clone, Debug)]
pub struct MixModelCosts {
    /// Canonical zoo name.
    pub name: String,
    /// Normalized arrival share of the mix's traffic.
    pub share: f64,
    /// Latency deadline, seconds (`f64::INFINITY` = none).
    pub deadline_s: f64,
    /// One frame through one replica chiplet, seconds.
    pub service_s: f64,
    /// Steady-state layer-pipeline inter-frame interval, seconds.
    pub stage_s: f64,
    /// NoP flits of one request's input payload.
    pub ingress_flits: u64,
    /// NoP flits of one request's output payload.
    pub egress_flits: u64,
    /// Per-layer compute/communication blame rows (NoC drain vs. compute
    /// overlap), in mapped-layer order — the explain report's layer table.
    pub layers: Vec<LayerBlame>,
}

impl MixModelCosts {
    /// Replica occupancy of one `frames`-frame request, seconds (frames
    /// pipeline through the replica's layers like a batch).
    pub fn occupancy_s(&self, frames: u32) -> f64 {
        self.service_s + (frames.max(1) - 1) as f64 * self.stage_s
    }

    /// Occupancy at a (possibly fractional) expected frame count — the
    /// capacity-planning form of [`MixModelCosts::occupancy_s`].
    pub fn mean_occupancy_s(&self, mean_frames: f64) -> f64 {
        self.service_s + (mean_frames.max(1.0) - 1.0) * self.stage_s
    }
}

/// All modeled costs for serving a [`WorkloadMix`] on one package, plus
/// the replica placement the queues sit over.
#[derive(Clone, Debug)]
pub struct MixServingModel {
    /// Package size the mix is served on.
    pub chiplets: usize,
    /// Package topology the transfers were priced on.
    pub topology: NopTopology,
    /// Per-model costs, in mix order.
    pub models: Vec<MixModelCosts>,
    /// Replica chiplet assignment per model.
    pub placement: Placement,
    /// Policy that produced `placement`.
    pub placement_policy: PlacementPolicy,
    /// Package I/O entry chiplet (0 by convention; the NoP-aware placement
    /// optimizes proximity to it).
    pub gateway: usize,
    /// SerDes port bundles on the gateway (its injection bandwidth).
    pub gateway_ports: usize,
    /// Directed package links of the gateway→chiplet route, per chiplet.
    pub paths: Vec<Vec<(usize, usize)>>,
    /// Zero-load input transfer time, `ingress_s[model][chiplet]`, seconds.
    pub ingress_s: Vec<Vec<f64>>,
    /// Zero-load result return time, `egress_s[model][chiplet]`, seconds.
    pub egress_s: Vec<Vec<f64>>,
    /// Seconds one package link is busy serializing one request's input,
    /// per model.
    pub link_busy_s: Vec<f64>,
    /// Fixed per-hop SerDes latency, seconds.
    pub hop_s: f64,
    /// Measured per-link saturation busy fraction (see
    /// [`super::scheduler::ServingModel::sat_link_util`]).
    pub sat_link_util: f64,
}

impl MixServingModel {
    /// Price every mix member on a `nop.chiplets`-chiplet package and run
    /// the `policy` placement search. Fails on unknown DNN names or a
    /// package smaller than the mix. Ingress legs honor `nop.mode` like
    /// [`super::scheduler::ServingModel::build`]: analytical transfer
    /// cycles, a memoized flit-level drain, or the fitted
    /// [`crate::sim::surrogate`] curve with sim fallback; egress stays
    /// analytical (result payloads are small and zero-load).
    pub fn build(
        mix: &WorkloadMix,
        policy: PlacementPolicy,
        arch: &ArchConfig,
        noc: &NocConfig,
        nop: &NopConfig,
        sim: &SimConfig,
    ) -> Result<Self, String> {
        mix.validate()?;
        let k = nop.chiplets;
        if k < mix.models.len() {
            // Fail before the (expensive) per-model pricing.
            return Err(format!(
                "{k} chiplet(s) cannot host {} model(s) (one model per chiplet)",
                mix.models.len()
            ));
        }
        let net = NopNetwork::build(nop.topology, k);
        let gateway = 0usize;
        let shares = mix.shares();

        let mut models = Vec::with_capacity(mix.models.len());
        let mut in_bits = Vec::with_capacity(mix.models.len());
        let mut out_bits = Vec::with_capacity(mix.models.len());
        for (spec, share) in mix.models.iter().zip(&shares) {
            let g = by_name(&spec.model).ok_or_else(|| {
                format!(
                    "unknown DNN '{}' in workload mix (valid: {})",
                    spec.model,
                    crate::dnn::valid_names()
                )
            })?;
            let mapping = Mapping::build(&g, arch);
            let (service_s, stage_s, layers) = replica_costs(&g, &mapping, arch, noc, nop, sim);
            let ib = g.input_bits(arch.n_bits);
            let ob = g.output_bits(arch.n_bits);
            let deadline_s = if spec.deadline_ms == 0.0 {
                DEADLINE_AUTO_FACTOR * service_s
            } else {
                spec.deadline_ms * 1e-3
            };
            models.push(MixModelCosts {
                name: g.name.clone(),
                share: *share,
                deadline_s,
                service_s,
                stage_s,
                ingress_flits: ib.div_ceil(nop.link_width as u64).max(1),
                egress_flits: ob.div_ceil(nop.link_width as u64).max(1),
                layers,
            });
            in_bits.push(ib);
            out_bits.push(ob);
        }

        // Placement: service demand sizes the replica sets, ingress traffic
        // orders models for gateway proximity.
        let (loads, ingress_rate) = placement_inputs(&models);
        let placement = place_replicas(policy, &net, gateway, &loads, &ingress_rate)?;

        let nop_cycle_s = 1.0 / nop.freq_hz;
        let paths: Vec<Vec<(usize, usize)>> =
            (0..k).map(|c| net.route_links(gateway, c)).collect();
        let n = models.len();
        let mut ingress_s = vec![vec![0.0f64; k]; n];
        let mut egress_s = vec![vec![0.0f64; k]; n];
        for m in 0..n {
            for c in 0..k {
                if c == gateway {
                    continue;
                }
                let hops = net.hops(gateway, c);
                ingress_s[m][c] = match nop.mode {
                    NopMode::Analytical => {
                        nop_transfer_cycles(in_bits[m], hops, nop, arch.freq_hz) / arch.freq_hz
                    }
                    NopMode::Sim | NopMode::Surrogate => {
                        let flits = models[m].ingress_flits;
                        let flows = [FlowSpec {
                            src: gateway,
                            dst: c,
                            rate: 0.0,
                            flits,
                        }];
                        let budget = 10_000
                            + flits
                                .saturating_mul(4)
                                .saturating_mul(nop.hop_latency_cycles + 2);
                        // Surrogate: one fitted curve (base seed) prices
                        // every (model, chiplet) leg; `None` falls back to
                        // the memoized drain the Sim arm runs.
                        let estimate = if nop.mode == NopMode::Surrogate {
                            crate::sim::surrogate::drain_estimate(
                                nop.topology,
                                k,
                                nop,
                                &flows,
                                sim.seed,
                            )
                            .map(|cy| cy.min(budget))
                        } else {
                            None
                        };
                        let cycles = match estimate {
                            Some(makespan) => makespan,
                            None => {
                                let stats = crate::sim::memo::drain_makespan(
                                    nop.topology,
                                    k,
                                    nop,
                                    &flows,
                                    budget,
                                    sim.seed ^ c as u64,
                                );
                                if stats.drained { stats.makespan } else { budget }
                            }
                        };
                        cycles as f64 * nop_cycle_s
                    }
                };
                egress_s[m][c] =
                    nop_transfer_cycles(out_bits[m], hops, nop, arch.freq_hz) / arch.freq_hz;
            }
        }
        let link_busy_s: Vec<f64> = models
            .iter()
            .map(|m| m.ingress_flits as f64 * nop_cycle_s)
            .collect();
        let sat_link_util = measured_sat_link_util(&net, nop, sim.seed);

        Ok(Self {
            chiplets: k,
            topology: nop.topology,
            models,
            placement,
            placement_policy: policy,
            gateway,
            gateway_ports: net.ports(gateway),
            paths,
            ingress_s,
            egress_s,
            link_busy_s,
            hop_s: nop.hop_latency_cycles as f64 * nop_cycle_s,
            sat_link_util,
        })
    }

    /// Re-run only the placement search on an already-priced model: the
    /// expensive per-model pricing and the saturation sweep are reused, so
    /// comparing placements on one package costs one build plus this.
    pub fn with_placement(&self, policy: PlacementPolicy) -> Result<Self, String> {
        let net = NopNetwork::build(self.topology, self.chiplets);
        let (loads, ingress_rate) = placement_inputs(&self.models);
        let placement = place_replicas(policy, &net, self.gateway, &loads, &ingress_rate)?;
        Ok(Self {
            placement,
            placement_policy: policy,
            ..self.clone()
        })
    }

    /// Aggregate modeled request capacity of the mix at its traffic
    /// shares: the smaller of the ideal (demand-proportional,
    /// placement-independent) replica bandwidth and the gateway's NoP
    /// injection bandwidth. `mean_frames` is the arrival process's
    /// expected frames per request
    /// ([`crate::workload::ArrivalProcess::mean_frames`]) so heavy-tailed
    /// batches are billed as the extra service and ingress they occupy —
    /// the auto arrival rate then holds *utilization* constant across
    /// tail shapes. Deliberately placement-independent so different
    /// placements can be compared at the same offered load.
    pub fn capacity_rps(&self, mean_frames: f64) -> f64 {
        let mf = mean_frames.max(1.0);
        let mean_occ: f64 = self
            .models
            .iter()
            .map(|m| m.share * m.mean_occupancy_s(mf))
            .sum();
        let svc = self.chiplets as f64 / mean_occ;
        if self.chiplets == 1 {
            return svc;
        }
        let mean_busy: f64 = self
            .models
            .iter()
            .zip(&self.link_busy_s)
            .map(|(m, b)| m.share * b * mf)
            .sum();
        let net_cap = self.gateway_ports as f64 / mean_busy.max(1e-18);
        svc.min(net_cap)
    }
}

/// Placement-search inputs at the mix's traffic shares: per-model service
/// demand (replica-seconds per second) and NoP ingress traffic — the one
/// place these weightings are defined, shared by `build` and
/// `with_placement`.
fn placement_inputs(models: &[MixModelCosts]) -> (Vec<f64>, Vec<f64>) {
    let loads = models.iter().map(|m| m.share * m.service_s).collect();
    let ingress = models
        .iter()
        .map(|m| m.share * m.ingress_flits as f64)
        .collect();
    (loads, ingress)
}

/// A request admitted to a replica queue.
#[derive(Clone, Copy, Debug)]
struct MixPending {
    arrival: f64,
    /// When the input payload is resident on the replica chiplet.
    ready: f64,
    model: usize,
    frames: u32,
    /// Lifecycle span index.
    span: usize,
}

/// Per-chiplet request queues over a [`Placement`], plus the
/// discrete-event multi-model serving simulation that drives them.
pub struct MixScheduler {
    /// The priced serving model the queues run over.
    pub model: MixServingModel,
    policy: Policy,
    admission: Admission,
    queue_depth: usize,
    /// Replica chiplets per model (from the placement), in id order.
    replicas: Vec<Vec<usize>>,
    // Dynamic state, owned by one `run`.
    free_at: Vec<f64>,
    queues: Vec<VecDeque<MixPending>>,
    /// Total occupancy of the requests queued on each chiplet, seconds
    /// (keeps admission pricing O(1)).
    queued_s: Vec<f64>,
    link_free: HashMap<(usize, usize), f64>,
    link_util: HashMap<(usize, usize), LinkWindow>,
    window_s: f64,
    rr_next: Vec<usize>,
    busy_s: Vec<f64>,
    served: Vec<usize>,
    peak_queue: Vec<usize>,
    offered: Vec<usize>,
    completed: Vec<usize>,
    dropped: Vec<usize>,
    shed: Vec<usize>,
    deadline_offered: Vec<usize>,
    deadline_hits: Vec<usize>,
    /// Per-model streaming latency sketches (bounded memory; the global
    /// report merges them).
    latency: Vec<QuantileSketch>,
    batches: usize,
    /// One lifecycle span per offered request, in event order.
    spans: Vec<RequestSpan>,
    /// One causal ingress trace per offered request, index-aligned with
    /// `spans` (default/empty for rejected requests).
    ingress_traces: Vec<IngressTrace>,
    /// Windowed serving metrics of the most recent run.
    timeseries: TimeSeries,
    /// Metrics window override, seconds (0 = auto: event span / 32).
    metrics_window_s: f64,
}

impl MixScheduler {
    /// A scheduler over `model`'s placement with empty queues.
    pub fn new(model: MixServingModel, cfg: &ServingConfig, admission: Admission) -> Self {
        let n = model.models.len();
        let replicas: Vec<Vec<usize>> = (0..n).map(|m| model.placement.replicas(m)).collect();
        // Utilization window: long enough to smooth tens of payloads on a
        // link, short enough to track saturation as it builds.
        let max_busy = model.link_busy_s.iter().copied().fold(0.0f64, f64::max);
        let max_stage = model.models.iter().map(|m| m.stage_s).fold(0.0f64, f64::max);
        let window_s = (32.0 * max_busy).max(16.0 * max_stage);
        // `reset` is the single initializer of every per-run accumulator
        // (run() calls it again, so new state added there stays in sync).
        let mut sched = Self {
            model,
            policy: cfg.policy,
            admission,
            queue_depth: cfg.queue_depth.max(1),
            replicas,
            free_at: Vec::new(),
            queues: Vec::new(),
            queued_s: Vec::new(),
            link_free: HashMap::new(),
            link_util: HashMap::new(),
            window_s,
            rr_next: Vec::new(),
            busy_s: Vec::new(),
            served: Vec::new(),
            peak_queue: Vec::new(),
            offered: Vec::new(),
            completed: Vec::new(),
            dropped: Vec::new(),
            shed: Vec::new(),
            deadline_offered: Vec::new(),
            deadline_hits: Vec::new(),
            latency: Vec::new(),
            batches: 0,
            spans: Vec::new(),
            ingress_traces: Vec::new(),
            timeseries: TimeSeries::default(),
            metrics_window_s: 0.0,
        };
        sched.reset();
        sched
    }

    /// Lifecycle spans of the most recent run, in event order (one per
    /// offered request — completed, dropped and shed alike).
    pub fn spans(&self) -> &[RequestSpan] {
        &self.spans
    }

    /// Causal ingress traces of the most recent run, index-aligned with
    /// [`MixScheduler::spans`] (default/empty for rejected requests).
    pub fn ingress_traces(&self) -> &[IngressTrace] {
        &self.ingress_traces
    }

    /// Windowed serving metrics of the most recent run.
    pub fn timeseries(&self) -> &TimeSeries {
        &self.timeseries
    }

    /// Override the metrics window width (`[telemetry] window_ms` /
    /// `--metrics-window-ms`); `0` restores the auto width (the event
    /// span divided by [`AUTO_WINDOWS`]). Survives [`MixScheduler::run`]'s
    /// reset — it is configuration, not per-run state.
    pub fn set_metrics_window_s(&mut self, window_s: f64) {
        self.metrics_window_s = window_s.max(0.0);
    }

    /// Reset every per-run accumulator so one scheduler can host several
    /// independent runs.
    fn reset(&mut self) {
        let k = self.model.chiplets;
        let n = self.model.models.len();
        self.free_at = vec![0.0; k];
        self.queues = (0..k).map(|_| VecDeque::new()).collect();
        self.queued_s = vec![0.0; k];
        self.link_free.clear();
        self.link_util.clear();
        self.rr_next = vec![0; n];
        self.busy_s = vec![0.0; k];
        self.served = vec![0; k];
        self.peak_queue = vec![0; k];
        self.offered = vec![0; n];
        self.completed = vec![0; n];
        self.dropped = vec![0; n];
        self.shed = vec![0; n];
        self.deadline_offered = vec![0; n];
        self.deadline_hits = vec![0; n];
        self.latency = (0..n).map(|_| QuantileSketch::new()).collect();
        self.batches = 0;
        self.spans.clear();
        self.ingress_traces.clear();
        // Disabled placeholder; `run` installs the sized instance once the
        // event span (and thus the auto window width) is known.
        self.timeseries = TimeSeries::default();
    }

    /// Modeled completion delta of a `frames`-frame request of `m`
    /// admitted to chiplet `c` at `t` — what the least-latency policies
    /// minimize and what deadline-aware admission compares to the
    /// deadline.
    fn price(&self, c: usize, m: usize, frames: u32, t: f64) -> f64 {
        let costs = &self.model.models[m];
        let backlog = (self.free_at[c] - t).max(0.0) + self.queued_s[c];
        // A multi-frame request streams one input payload per frame; the
        // extra payloads pipeline behind the first at the serialization
        // rate.
        let extra_ingress = (frames.max(1) - 1) as f64 * self.model.link_busy_s[m];
        backlog
            + self.model.ingress_s[m][c]
            + extra_ingress
            + costs.occupancy_s(frames)
            + self.model.egress_s[m][c]
    }

    /// Worst busy fraction among the links of chiplet `c`'s ingress path.
    fn path_utilization(&mut self, c: usize, t: f64) -> f64 {
        let window_s = self.window_s;
        let mut worst = 0.0f64;
        for link in &self.model.paths[c] {
            let win = self.link_util.entry(*link).or_default();
            worst = worst.max(win.utilization(t, window_s));
        }
        worst
    }

    /// Pick a replica of model `m` for a request arriving at `t`, or
    /// `None` when every replica queue is full.
    fn pick(&mut self, m: usize, frames: u32, t: f64) -> Option<usize> {
        match self.policy {
            Policy::RoundRobin => {
                let count = self.replicas[m].len();
                for i in 0..count {
                    let slot = (self.rr_next[m] + i) % count;
                    let c = self.replicas[m][slot];
                    if self.queues[c].len() < self.queue_depth {
                        self.rr_next[m] = (slot + 1) % count;
                        return Some(c);
                    }
                }
                None
            }
            Policy::LeastLatency | Policy::CongestionAware => {
                let aware = self.policy == Policy::CongestionAware;
                let threshold = SATURATION_BACKOFF * self.model.sat_link_util;
                let mut best: Option<(bool, f64, usize)> = None;
                // Indexed loop: iterating `&self.replicas[m]` would hold a
                // borrow across the `&mut self` utilization probe below.
                #[allow(clippy::needless_range_loop)]
                for i in 0..self.replicas[m].len() {
                    let c = self.replicas[m][i];
                    if self.queues[c].len() >= self.queue_depth {
                        continue;
                    }
                    let backed_off = aware && self.path_utilization(c, t) >= threshold;
                    let price = self.price(c, m, frames, t);
                    let better = match &best {
                        None => true,
                        Some((bo, p, _)) => (backed_off, price) < (*bo, *p),
                    };
                    if better {
                        best = Some((backed_off, price, c));
                    }
                }
                best.map(|(_, _, c)| c)
            }
        }
    }

    /// Cheapest non-full replica of model `m` for a request at `t`, with
    /// its price — the deadline-aware fallback when the policy's pick
    /// would miss (round-robin rotation can land on a backlogged replica
    /// while an idle one could still hit the deadline).
    fn cheapest(&self, m: usize, frames: u32, t: f64) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        for &c in &self.replicas[m] {
            if self.queues[c].len() >= self.queue_depth {
                continue;
            }
            let price = self.price(c, m, frames, t);
            if best.map_or(true, |(_, p)| price < p) {
                best = Some((c, price));
            }
        }
        best
    }

    /// Stream one request's input over the gateway→`c` package route
    /// starting at `t` (links serialize over shared state, the head
    /// pipelines hop by hop); returns when the payload is resident on `c`.
    fn ingress(&mut self, c: usize, m: usize, frames: u32, t: f64) -> f64 {
        // One input payload per frame, streamed back to back.
        let ser_s = self.model.link_busy_s[m] * frames.max(1) as f64;
        let flits = self.model.models[m].ingress_flits * frames.max(1) as u64;
        let hop_s = self.model.hop_s;
        let window_s = self.window_s;
        let n_hops = self.model.paths[c].len();
        let mut waits = Vec::with_capacity(n_hops);
        let mut head = t;
        let mut done = t;
        for &link in &self.model.paths[c] {
            let free = *self.link_free.get(&link).unwrap_or(&0.0);
            let start = head.max(free);
            let wait = start - head;
            waits.push((link, wait));
            if wait > 0.0 {
                log::trace!(
                    "mix ingress hop {}-{}: waited {:.3} us on busy link",
                    link.0,
                    link.1,
                    wait * 1e6
                );
            }
            let finish = (start + ser_s).max(done);
            self.link_free.insert(link, finish);
            let win = self.link_util.entry(link).or_default();
            win.add(start, finish - start, window_s);
            // Bill the true serialization time, not `finish - start`: the
            // `.max(done)` pipelining stretch would double-count tail hops.
            self.timeseries.record_link_busy(start, link, ser_s, flits);
            head = start + hop_s;
            done = finish + hop_s;
        }
        if n_hops > 0 {
            self.timeseries.record_ejected(c, flits);
        }
        self.ingress_traces.push(IngressTrace {
            waits,
            ser_s: if n_hops > 0 { ser_s } else { 0.0 },
            prop_s: n_hops as f64 * hop_s,
        });
        done
    }

    /// Serve every input-resident request that can start by `t`
    /// (work-conserving: a free replica takes its queue head as soon as
    /// the payload has landed).
    fn advance(&mut self, t: f64) {
        for c in 0..self.model.chiplets {
            loop {
                let head = match self.queues[c].front() {
                    None => break,
                    Some(p) => *p,
                };
                let start = self.free_at[c].max(head.ready);
                if start > t {
                    break;
                }
                self.queues[c].pop_front();
                let costs = &self.model.models[head.model];
                let occupied = costs.occupancy_s(head.frames);
                self.queued_s[c] = (self.queued_s[c] - occupied).max(0.0);
                let complete = start + occupied + self.model.egress_s[head.model][c];
                let latency_s = complete - head.arrival;
                self.latency[head.model].record(latency_s * 1e3);
                self.timeseries.record_completion(complete, head.model, latency_s * 1e3);
                let sp = &mut self.spans[head.span];
                sp.service_start = start;
                sp.complete = complete;
                // Hits only count toward deadline-carrying requests (an
                // infinite deadline was never "offered" a deadline).
                if costs.deadline_s.is_finite() && latency_s <= costs.deadline_s {
                    self.deadline_hits[head.model] += 1;
                }
                self.free_at[c] = start + occupied;
                self.busy_s[c] += occupied;
                self.served[c] += 1;
                self.completed[head.model] += 1;
                self.batches += 1;
            }
        }
    }

    /// Run the multi-model serving simulation over a time-sorted event
    /// sequence (generated or replayed from a trace). Deterministic: the
    /// scheduler draws no random numbers of its own.
    pub fn run(&mut self, events: &[Event]) -> ServeReport {
        self.reset();
        let n = self.model.models.len();
        // Metrics windows: explicit override, else the arrival span split
        // into AUTO_WINDOWS windows (events are time-sorted).
        let last_t = events.last().map_or(0.0, |e| e.t_s);
        let window_s = if self.metrics_window_s > 0.0 {
            self.metrics_window_s
        } else {
            (last_t / AUTO_WINDOWS).max(1e-9)
        };
        self.timeseries = TimeSeries::new(
            window_s,
            self.model.models.iter().map(|m| m.name.clone()).collect(),
            link_union(&self.model.paths),
            self.model.chiplets,
            self.model.gateway,
        );
        let mut t = 0.0f64;
        for (i, e) in events.iter().enumerate() {
            assert!(
                e.model < n,
                "event {i} names model {} but the mix has {n} (trace/mix mismatch)",
                e.model
            );
            t = t.max(e.t_s);
            let m = e.model;
            self.advance(t);
            self.offered[m] += 1;
            self.timeseries.record_arrival(t, m);
            let costs = &self.model.models[m];
            let deadline_s = costs.deadline_s;
            let has_deadline = deadline_s.is_finite();
            if has_deadline {
                self.deadline_offered[m] += 1;
            }
            match self.pick(m, e.frames, t) {
                None => {
                    self.dropped[m] += 1;
                    self.timeseries.record_drop(t, m);
                    self.spans.push(RequestSpan::rejected(m, t, SpanOutcome::Dropped));
                    self.ingress_traces.push(IngressTrace::default());
                }
                Some(mut c) => {
                    if self.admission == Admission::DeadlineAware
                        && has_deadline
                        && self.price(c, m, e.frames, t) > deadline_s
                    {
                        // The routed replica would miss; shed only if the
                        // cheapest replica would miss too, else reroute.
                        match self.cheapest(m, e.frames, t) {
                            Some((c2, p2)) if p2 <= deadline_s => c = c2,
                            _ => {
                                self.shed[m] += 1;
                                self.timeseries.record_shed(t, m);
                                self.spans.push(RequestSpan::rejected(m, t, SpanOutcome::Shed));
                                self.ingress_traces.push(IngressTrace::default());
                                continue;
                            }
                        }
                    }
                    let ready = self.ingress(c, m, e.frames, t);
                    let occupied = self.model.models[m].occupancy_s(e.frames);
                    let span = self.spans.len();
                    self.spans.push(RequestSpan::admitted(m, c, t, ready));
                    self.queues[c].push_back(MixPending {
                        arrival: t,
                        ready,
                        model: m,
                        frames: e.frames,
                        span,
                    });
                    self.queued_s[c] += occupied;
                    self.peak_queue[c] = self.peak_queue[c].max(self.queues[c].len());
                    self.timeseries.record_depth(t, self.queues[c].len());
                }
            }
        }
        // Drain: jump past every outstanding ready/free horizon until the
        // queues empty (each pass starts at least the head requests).
        let max_service = self
            .model
            .models
            .iter()
            .map(|m| m.service_s)
            .fold(0.0f64, f64::max);
        let mut horizon = t;
        loop {
            let pending: usize = self.queues.iter().map(|q| q.len()).sum();
            if pending == 0 {
                break;
            }
            for q in &self.queues {
                for p in q {
                    horizon = horizon.max(p.ready);
                }
            }
            for &f in &self.free_at {
                horizon = horizon.max(f);
            }
            horizon += max_service;
            self.advance(horizon);
        }

        let end = self.free_at.iter().copied().fold(t, f64::max).max(1e-12);
        self.timeseries.finalize(end);
        let mut per_chiplet = Vec::with_capacity(self.model.chiplets);
        for c in 0..self.model.chiplets {
            per_chiplet.push(ChipletQueueStats {
                chiplet: c,
                served: self.served[c],
                utilization: (self.busy_s[c] / end).min(1.0),
                peak_queue: self.peak_queue[c],
            });
        }
        let mut per_model = Vec::with_capacity(n);
        let mut all = QuantileSketch::new();
        for m in 0..n {
            let lat = &self.latency[m];
            let (ing, que, ser) = mean_breakdown_ms(&self.spans, Some(m));
            per_model.push(ModelServeStats {
                model: self.model.models[m].name.clone(),
                replicas: self.replicas[m].len(),
                offered: self.offered[m],
                completed: self.completed[m],
                dropped: self.dropped[m],
                shed: self.shed[m],
                deadline_offered: self.deadline_offered[m],
                deadline_hits: self.deadline_hits[m],
                mean_ms: lat.mean(),
                p50_ms: lat.quantile(50.0),
                p99_ms: lat.quantile(99.0),
                mean_ingress_ms: ing,
                mean_queue_ms: que,
                mean_service_ms: ser,
            });
            all.merge(lat);
        }
        let mut report = ServeReport::from_sketch(
            events.len(),
            self.completed.iter().sum(),
            self.dropped.iter().sum(),
            1,
            self.batches,
            &all,
            end,
        );
        report.shed = self.shed.iter().sum();
        report.deadline_offered = self.deadline_offered.iter().sum();
        report.deadline_hits = self.deadline_hits.iter().sum();
        report.per_chiplet = per_chiplet;
        report.per_model = per_model;
        let (ing, que, ser) = mean_breakdown_ms(&self.spans, None);
        report.mean_ingress_ms = ing;
        report.mean_queue_ms = que;
        report.mean_service_ms = ser;
        report
    }
}

/// Build the mix model, generate the workload from `[serving]` +
/// `[workload]`, and run one multi-model serving simulation — the CLI /
/// experiment entry point. Returns the priced model, the generated trace
/// (ready to record), and the report.
pub fn serve_mix(
    arch: &ArchConfig,
    noc: &NocConfig,
    nop: &NopConfig,
    sim: &SimConfig,
    serving: &ServingConfig,
    workload: &WorkloadConfig,
) -> Result<(MixServingModel, Trace, ServeReport), String> {
    let (model, trace, report, _) = serve_mix_traced(arch, noc, nop, sim, serving, workload)?;
    Ok((model, trace, report))
}

/// [`serve_mix`] variant that also returns the per-request lifecycle
/// spans for trace export (`repro serve --mix … --trace-out`).
pub fn serve_mix_traced(
    arch: &ArchConfig,
    noc: &NocConfig,
    nop: &NopConfig,
    sim: &SimConfig,
    serving: &ServingConfig,
    workload: &WorkloadConfig,
) -> Result<(MixServingModel, Trace, ServeReport, Vec<RequestSpan>), String> {
    let (model, trace, report, spans, _, _) =
        serve_mix_metrics(arch, noc, nop, sim, serving, workload, 0.0)?;
    Ok((model, trace, report, spans))
}

/// [`serve_mix_traced`] variant that also returns the causal per-request
/// [`IngressTrace`]s (index-aligned with the spans; the explain report's
/// input) and the windowed [`TimeSeries`] (`repro serve --mix …
/// --metrics-out`). `window_ms > 0` overrides the auto metrics window
/// width.
#[allow(clippy::type_complexity)]
pub fn serve_mix_metrics(
    arch: &ArchConfig,
    noc: &NocConfig,
    nop: &NopConfig,
    sim: &SimConfig,
    serving: &ServingConfig,
    workload: &WorkloadConfig,
    window_ms: f64,
) -> Result<
    (
        MixServingModel,
        Trace,
        ServeReport,
        Vec<RequestSpan>,
        Vec<IngressTrace>,
        TimeSeries,
    ),
    String,
> {
    workload.validate()?;
    serving.validate()?;
    let model = MixServingModel::build(&workload.mix, workload.placement, arch, noc, nop, sim)?;
    let rate = if serving.arrival_rps > 0.0 {
        serving.arrival_rps
    } else {
        AUTO_LOAD_FACTOR * model.capacity_rps(workload.arrival_process().mean_frames())
    };
    let events = workload
        .arrival_process()
        .generate(&workload.mix, rate, serving.requests, serving.seed);
    let trace = Trace::new(workload.mix.clone(), rate, events);
    let mut sched = MixScheduler::new(model, serving, workload.admission);
    sched.set_metrics_window_s(window_ms * 1e-3);
    let mut report = sched.run(&trace.events);
    report.offered_rps = rate;
    let spans = std::mem::take(&mut sched.spans);
    let traces = std::mem::take(&mut sched.ingress_traces);
    let ts = std::mem::take(&mut sched.timeseries);
    Ok((sched.model, trace, report, spans, traces, ts))
}

/// Replay a recorded trace: rebuild the mix model from the trace's own mix
/// spec and rerun the exact event sequence. With identical configuration
/// this reproduces the recorded run's report byte-for-byte.
pub fn replay_mix(
    trace: &Trace,
    arch: &ArchConfig,
    noc: &NocConfig,
    nop: &NopConfig,
    sim: &SimConfig,
    serving: &ServingConfig,
    workload: &WorkloadConfig,
) -> Result<(MixServingModel, ServeReport), String> {
    let (model, report, _) = replay_mix_traced(trace, arch, noc, nop, sim, serving, workload)?;
    Ok((model, report))
}

/// [`replay_mix`] variant that also returns the per-request lifecycle
/// spans for trace export.
pub fn replay_mix_traced(
    trace: &Trace,
    arch: &ArchConfig,
    noc: &NocConfig,
    nop: &NopConfig,
    sim: &SimConfig,
    serving: &ServingConfig,
    workload: &WorkloadConfig,
) -> Result<(MixServingModel, ServeReport, Vec<RequestSpan>), String> {
    let (model, report, spans, _, _) =
        replay_mix_metrics(trace, arch, noc, nop, sim, serving, workload, 0.0)?;
    Ok((model, report, spans))
}

/// [`replay_mix_traced`] variant that also returns the causal per-request
/// [`IngressTrace`]s and the windowed [`TimeSeries`]. Identical
/// configuration and trace reproduce the metrics export byte-for-byte,
/// like the report.
#[allow(clippy::type_complexity)]
pub fn replay_mix_metrics(
    trace: &Trace,
    arch: &ArchConfig,
    noc: &NocConfig,
    nop: &NopConfig,
    sim: &SimConfig,
    serving: &ServingConfig,
    workload: &WorkloadConfig,
    window_ms: f64,
) -> Result<
    (
        MixServingModel,
        ServeReport,
        Vec<RequestSpan>,
        Vec<IngressTrace>,
        TimeSeries,
    ),
    String,
> {
    let model = MixServingModel::build(&trace.mix, workload.placement, arch, noc, nop, sim)?;
    let mut sched = MixScheduler::new(model, serving, workload.admission);
    sched.set_metrics_window_s(window_ms * 1e-3);
    let mut report = sched.run(&trace.events);
    report.offered_rps = trace.offered_rps;
    let spans = std::mem::take(&mut sched.spans);
    let traces = std::mem::take(&mut sched.ingress_traces);
    let ts = std::mem::take(&mut sched.timeseries);
    Ok((sched.model, report, spans, traces, ts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ArrivalProcess;

    fn defaults() -> (ArchConfig, NocConfig, SimConfig) {
        (
            ArchConfig::default(),
            NocConfig::default(),
            SimConfig::default(),
        )
    }

    fn small_mix() -> WorkloadMix {
        WorkloadMix::parse("MLP:1:0,LeNet-5:1:0").unwrap()
    }

    #[test]
    fn build_prices_every_model_and_places_all_chiplets() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Mesh,
            chiplets: 6,
            ..NopConfig::default()
        };
        let model =
            MixServingModel::build(&small_mix(), PlacementPolicy::NopAware, &arch, &noc, &nop, &sim)
                .unwrap();
        assert_eq!(model.models.len(), 2);
        assert_eq!(model.placement.model_of.len(), 6);
        model.placement.validate(2).unwrap();
        for m in &model.models {
            assert!(m.service_s > 0.0 && m.stage_s > 0.0);
            assert!(m.stage_s <= m.service_s);
            assert!(m.deadline_s.is_finite() && m.deadline_s > m.service_s);
            assert!(m.ingress_flits >= 1 && m.egress_flits >= 1);
            assert!(!m.layers.is_empty(), "layer blame rows priced per model");
        }
        // Ingress costs grow with distance from the gateway, per model.
        assert_eq!(model.ingress_s[0][0], 0.0);
        assert!(model.ingress_s[0][5] > model.ingress_s[0][1]);
        assert!(model.capacity_rps(1.0) > 0.0);
        assert!(model.sat_link_util > 0.0 && model.sat_link_util <= 1.0);
    }

    #[test]
    fn surrogate_ingress_pricing_tracks_sim() {
        // `[nop] mode = surrogate` must price the gateway→chiplet legs in
        // a tight band of the full drain sim it stands in for, with the
        // same structure (zero at the gateway, growing with distance).
        let (arch, noc, sim) = defaults();
        let build = |mode: NopMode| {
            let nop = NopConfig {
                topology: NopTopology::Mesh,
                chiplets: 6,
                mode,
                ..NopConfig::default()
            };
            MixServingModel::build(&small_mix(), PlacementPolicy::NopAware, &arch, &noc, &nop, &sim)
                .unwrap()
        };
        let cyc = build(NopMode::Sim);
        let sur = build(NopMode::Surrogate);
        assert_eq!(sur.ingress_s[0][0], 0.0);
        assert!(sur.ingress_s[0][5] > sur.ingress_s[0][1]);
        for m in 0..2 {
            for c in 1..6 {
                let ratio = sur.ingress_s[m][c] / cyc.ingress_s[m][c];
                assert!(
                    (0.5..=2.0).contains(&ratio),
                    "model {m} chiplet {c}: surrogate/sim ingress ratio {ratio}"
                );
            }
        }
        // Egress is analytical in both modes — identical by construction.
        assert_eq!(cyc.egress_s, sur.egress_s);
    }

    #[test]
    fn build_rejects_bad_mixes() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            chiplets: 1,
            ..NopConfig::default()
        };
        // Two models cannot share one chiplet.
        let err = MixServingModel::build(
            &small_mix(),
            PlacementPolicy::RoundRobin,
            &arch,
            &noc,
            &nop,
            &sim,
        )
        .unwrap_err();
        assert!(err.contains("cannot host"), "{err}");
        // Unknown names list the zoo.
        let bad = WorkloadMix::parse("NoSuchNet:1:0").unwrap();
        let nop4 = NopConfig {
            chiplets: 4,
            ..NopConfig::default()
        };
        let err =
            MixServingModel::build(&bad, PlacementPolicy::RoundRobin, &arch, &noc, &nop4, &sim)
                .unwrap_err();
        assert!(err.contains("unknown DNN"), "{err}");
        assert!(err.contains("SqueezeNet"), "{err}");
    }

    #[test]
    fn explicit_and_auto_deadlines() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            chiplets: 4,
            ..NopConfig::default()
        };
        let mix = WorkloadMix::parse("MLP:1:2.5,LeNet-5:1:inf").unwrap();
        let model =
            MixServingModel::build(&mix, PlacementPolicy::NopAware, &arch, &noc, &nop, &sim)
                .unwrap();
        assert!((model.models[0].deadline_s - 2.5e-3).abs() < 1e-12);
        assert!(model.models[1].deadline_s.is_infinite());
        let auto = MixServingModel::build(
            &small_mix(),
            PlacementPolicy::NopAware,
            &arch,
            &noc,
            &nop,
            &sim,
        )
        .unwrap();
        let m0 = &auto.models[0];
        assert!((m0.deadline_s - DEADLINE_AUTO_FACTOR * m0.service_s).abs() < 1e-15);
    }

    #[test]
    fn light_load_completes_everything_within_deadline() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Ring,
            chiplets: 4,
            ..NopConfig::default()
        };
        let model =
            MixServingModel::build(&small_mix(), PlacementPolicy::NopAware, &arch, &noc, &nop, &sim)
                .unwrap();
        let rate = 0.2 * model.capacity_rps(1.0);
        let events = ArrivalProcess::default().generate(&small_mix(), rate, 200, 11);
        let cfg = ServingConfig::default();
        let mut sched = MixScheduler::new(model, &cfg, Admission::DeadlineAware);
        let report = sched.run(&events);
        assert_eq!(report.requests, 200);
        assert_eq!(report.completed, 200);
        assert_eq!(report.dropped, 0);
        assert_eq!(report.shed, 0);
        assert_eq!(report.deadline_offered, 200);
        // At 20% load nothing should queue long enough to miss an auto
        // (5x service) deadline; allow a hair of slack for rare pile-ups.
        assert!(report.deadline_hits >= 196, "hits {}", report.deadline_hits);
        assert!(report.hit_rate() > 0.97);
        assert_eq!(report.per_model.len(), 2);
        let served: usize = report.per_chiplet.iter().map(|s| s.served).sum();
        assert_eq!(served, 200);
        for pm in &report.per_model {
            assert_eq!(pm.offered, pm.completed + pm.dropped + pm.shed);
            assert!(pm.p99_ms >= pm.p50_ms);
        }
    }

    #[test]
    fn overload_sheds_under_deadline_aware_and_drops_under_drop_on_full() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Ring,
            chiplets: 2,
            ..NopConfig::default()
        };
        let model =
            MixServingModel::build(&small_mix(), PlacementPolicy::NopAware, &arch, &noc, &nop, &sim)
                .unwrap();
        let rate = 5.0 * model.capacity_rps(1.0);
        let events = ArrivalProcess::default().generate(&small_mix(), rate, 400, 3);
        // Queue depth deep enough that drop-on-full admits requests whose
        // wait (up to ~12 services) blows the 5x-service auto deadline —
        // the regime where shedding visibly wins.
        let cfg = ServingConfig {
            queue_depth: 12,
            ..ServingConfig::default()
        };
        let mut sched = MixScheduler::new(model.clone(), &cfg, Admission::DeadlineAware);
        let da = sched.run(&events);
        assert!(da.shed > 0, "overload must shed under deadline-aware");
        assert_eq!(da.completed + da.dropped + da.shed, da.requests);
        let mut sched = MixScheduler::new(model, &cfg, Admission::DropOnFull);
        let drop = sched.run(&events);
        assert_eq!(drop.shed, 0, "drop-on-full never sheds");
        assert!(drop.dropped > 0);
        assert_eq!(drop.completed + drop.dropped, drop.requests);
        // Same offered workload: deadline-aware turns late completions and
        // drops into early sheds, and strictly wins on hit-rate.
        assert!(
            da.hit_rate() > drop.hit_rate(),
            "deadline-aware hit-rate {} must beat drop-on-full {}",
            da.hit_rate(),
            drop.hit_rate()
        );
    }

    #[test]
    fn serve_mix_and_replay_roundtrip() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Ring,
            chiplets: 4,
            ..NopConfig::default()
        };
        let serving = ServingConfig {
            requests: 120,
            ..ServingConfig::default()
        };
        let workload = WorkloadConfig {
            mix: small_mix(),
            ..WorkloadConfig::default()
        };
        let (_, trace, report) =
            serve_mix(&arch, &noc, &nop, &sim, &serving, &workload).unwrap();
        assert_eq!(trace.events.len(), 120);
        assert!(report.offered_rps > 0.0);
        // Replaying the just-recorded trace reproduces the identical report.
        let parsed = Trace::parse(&trace.to_text()).unwrap();
        let (_, replayed) =
            replay_mix(&parsed, &arch, &noc, &nop, &sim, &serving, &workload).unwrap();
        assert_eq!(format!("{report:?}"), format!("{replayed:?}"));
    }

    #[test]
    fn mix_timeseries_reconciles_with_report() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Mesh,
            chiplets: 4,
            ..NopConfig::default()
        };
        let serving = ServingConfig {
            requests: 200,
            ..ServingConfig::default()
        };
        let workload = WorkloadConfig {
            mix: small_mix(),
            ..WorkloadConfig::default()
        };
        let (_, _, report, _, _, ts) =
            serve_mix_metrics(&arch, &noc, &nop, &sim, &serving, &workload, 0.0).unwrap();
        assert!(ts.is_enabled());
        let (arr, comp, drop, shed) = ts.totals();
        assert_eq!(arr as usize, report.requests);
        assert_eq!(comp as usize, report.completed);
        assert_eq!(drop as usize, report.dropped);
        assert_eq!(shed as usize, report.shed);
        // Window sums reconcile exactly with the cumulative totals, and
        // per-model window slices with the per-model report rows.
        let (mut a, mut c, mut d, mut s) = (0u64, 0u64, 0u64, 0u64);
        let mut model_done = vec![0u64; report.per_model.len()];
        for w in ts.windows() {
            a += w.arrivals;
            c += w.completions;
            d += w.drops;
            s += w.sheds;
            for (m, mw) in w.models.iter().enumerate() {
                model_done[m] += mw.completions;
            }
        }
        assert_eq!((a, c, d, s), (arr, comp, drop, shed));
        for (m, pm) in report.per_model.iter().enumerate() {
            assert_eq!(model_done[m] as usize, pm.completed, "model {}", pm.model);
        }
        // Off-gateway replicas pulled payloads over real NoP links.
        assert!(!ts.links().is_empty());
        assert!(ts.to_sim_telemetry().transit_total() > 0);
        // An explicit window override reshapes the axis deterministically.
        let (_, _, _, _, _, ts2) =
            serve_mix_metrics(&arch, &noc, &nop, &sim, &serving, &workload, 0.0).unwrap();
        let json = ts.to_json(report.requests, report.completed, report.dropped, report.shed);
        let json2 = ts2.to_json(report.requests, report.completed, report.dropped, report.shed);
        assert_eq!(json, json2, "same seed must export byte-identical metrics");
    }

    #[test]
    fn mix_spans_reconcile_with_report() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Ring,
            chiplets: 4,
            ..NopConfig::default()
        };
        let serving = ServingConfig {
            requests: 200,
            arrival_rps: 1.0e6, // overload: force drops alongside completions
            queue_depth: 1,
            ..ServingConfig::default()
        };
        let workload = WorkloadConfig {
            mix: small_mix(),
            ..WorkloadConfig::default()
        };
        let (_, trace, report, spans) =
            serve_mix_traced(&arch, &noc, &nop, &sim, &serving, &workload).unwrap();
        assert_eq!(spans.len(), trace.events.len());
        let done = spans
            .iter()
            .filter(|s| s.outcome == SpanOutcome::Completed)
            .count();
        let dropped = spans
            .iter()
            .filter(|s| s.outcome == SpanOutcome::Dropped)
            .count();
        let shed = spans.iter().filter(|s| s.outcome == SpanOutcome::Shed).count();
        assert_eq!(done, report.completed);
        assert_eq!(dropped, report.dropped);
        assert_eq!(shed, report.shed);
        assert!(report.dropped > 0, "overload must drop requests");
        // Phase means decompose the end-to-end mean exactly.
        let total = report.mean_ingress_ms + report.mean_queue_ms + report.mean_service_ms;
        assert!((total - report.mean_ms).abs() < 1e-9);
        for st in &report.per_model {
            let t = st.mean_ingress_ms + st.mean_queue_ms + st.mean_service_ms;
            assert!((t - st.mean_ms).abs() < 1e-9, "model {}", st.model);
        }
        for s in &spans {
            assert!(s.ready >= s.arrival);
            assert!(s.service_start >= s.ready);
            assert!(s.complete >= s.service_start);
        }
    }

    #[test]
    fn mix_ingress_traces_reconcile_with_spans() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Mesh,
            chiplets: 4,
            ..NopConfig::default()
        };
        let serving = ServingConfig {
            requests: 200,
            ..ServingConfig::default()
        };
        let workload = WorkloadConfig {
            mix: small_mix(),
            ..WorkloadConfig::default()
        };
        let (_, trace, _, spans, traces, _) =
            serve_mix_metrics(&arch, &noc, &nop, &sim, &serving, &workload, 0.0).unwrap();
        assert_eq!(traces.len(), spans.len());
        assert_eq!(traces.len(), trace.events.len());
        let mut checked = 0usize;
        for (s, tr) in spans.iter().zip(&traces) {
            if s.outcome != SpanOutcome::Completed {
                // Rejected requests never touched a link.
                assert!(tr.waits.is_empty() && tr.total_s() == 0.0);
                continue;
            }
            // The causal decomposition reproduces the span's ingress phase
            // (tolerance: summing in a different order can differ by ulps).
            let ingress_s = s.ready - s.arrival;
            let tol = 1e-9 * ingress_s.max(1.0);
            assert!(
                (tr.total_s() - ingress_s).abs() <= tol,
                "trace total {} vs span ingress {}",
                tr.total_s(),
                ingress_s
            );
            checked += 1;
        }
        assert!(checked > 0, "at least one completed request expected");
    }

    #[test]
    fn mix_explain_report_is_byte_deterministic() {
        use crate::telemetry::BlameReport;
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Ring,
            chiplets: 4,
            ..NopConfig::default()
        };
        let serving = ServingConfig {
            requests: 150,
            ..ServingConfig::default()
        };
        let workload = WorkloadConfig {
            mix: small_mix(),
            ..WorkloadConfig::default()
        };
        let explain = || {
            let (model, _, _, spans, traces, _) =
                serve_mix_metrics(&arch, &noc, &nop, &sim, &serving, &workload, 0.0).unwrap();
            let names: Vec<String> = model.models.iter().map(|m| m.name.clone()).collect();
            let deadlines: Vec<f64> = model.models.iter().map(|m| m.deadline_s).collect();
            let layers: Vec<LayerBlame> = model
                .models
                .iter()
                .flat_map(|m| m.layers.iter().cloned())
                .collect();
            BlameReport::build(&spans, &traces, &names, &deadlines, &layers).to_json()
        };
        let a = explain();
        let b = explain();
        assert!(a.contains("imcnoc-explain-v1"));
        assert_eq!(a, b, "same [serving] seed must export byte-identical blame");
    }
}
