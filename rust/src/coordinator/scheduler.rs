//! Chiplet-aware batched serving: per-chiplet request queues priced by the
//! NoP cost model.
//!
//! The PJRT serving loop ([`super::server`]) measures wall-clock latency of
//! one compiled artifact on the host CPU. This module is its *modeled*
//! counterpart for a 2.5D package of IMC chiplets: IMC crossbars are
//! weight-stationary, so serving scale-out is data-parallel — every chiplet
//! holds a replica of the DNN and whole requests are routed to per-chiplet
//! queues. What makes routing non-trivial is the package interconnect:
//! request inputs enter at the package I/O gateway and ride NoP SerDes
//! links to the serving chiplet, so distant chiplets cost more per request
//! and the gateway's few links congest first — the paper's
//! interconnect-dominates argument, one hierarchy level up.
//!
//! The pieces:
//!
//! * [`ServingModel`] — all modeled costs for one (DNN, package) point:
//!   per-replica service time from [`crate::nop::evaluate_package`] on a
//!   1-chiplet package (regression-tested equal to the flat single-chip
//!   evaluator), the layer-pipeline interval that batching amortizes
//!   against, per-chiplet ingress/egress transfer times over the
//!   [`NopNetwork`] route (analytical `nop_transfer_cycles`, or a
//!   flit-level [`NopSim`](crate::nop::sim::NopSim) drain under
//!   `[nop] mode = sim`), the
//!   model-parallel alternative (the same DNN partitioned over all
//!   chiplets), and the per-link busy fraction at the package saturation
//!   rate measured by [`crate::nop::sim::saturation_rate`].
//! * [`ChipletScheduler`] — per-chiplet queues over a
//!   [`ChipletPartition`] plus a discrete-event serving simulation:
//!   Poisson arrivals, policy-driven admission, per-link ingress
//!   serialization over shared link state (so congestion is real),
//!   batched service, drop accounting. Emits a [`ServeReport`] — the same
//!   report type the PJRT path produces.
//! * [`Policy`] — round-robin, least-modeled-latency, and the
//!   NoP-congestion-aware policy that backs off chiplets whose ingress
//!   path runs near the measured saturation utilization.

use std::collections::{HashMap, VecDeque};

use crate::arch::evaluator::{evaluate, CommBackend};
use crate::circuit::ChipCost;
use crate::config::{ArchConfig, NocConfig, NopConfig, NopMode, ServingConfig, SimConfig};
use crate::coordinator::server::{ChipletQueueStats, ServeReport};
use crate::dnn::DnnGraph;
use crate::mapping::{ChipletPartition, Mapping};
use crate::noc::sim::FlowSpec;
use crate::nop::evaluator::{evaluate_package, nop_transfer_cycles};
use crate::nop::sim::saturation_rate;
use crate::nop::topology::{NopNetwork, NopTopology};
use crate::telemetry::span::{mean_breakdown_ms, RequestSpan, SpanOutcome};
use crate::telemetry::timeseries::AUTO_WINDOWS;
use crate::telemetry::{link_union, Histogram, IngressTrace, LayerBlame, QuantileSketch, TimeSeries};
use crate::util::{log, Pcg32};

pub use crate::config::Policy;

/// Fraction of the measured saturation utilization at which the
/// congestion-aware policy backs off a chiplet's ingress path.
pub const SATURATION_BACKOFF: f64 = 0.9;

/// Fraction of the modeled capacity offered when `[serving] arrival_rps`
/// is 0 (auto): close enough to saturation that queueing is visible, far
/// enough that the package stays stable under a balanced policy.
pub const AUTO_LOAD_FACTOR: f64 = 0.85;

/// Modeled serving costs for one (DNN, package) configuration.
#[derive(Clone, Debug)]
pub struct ServingModel {
    /// Zoo model name being served.
    pub dnn: String,
    /// Package size (replica count upper bound).
    pub chiplets: usize,
    /// Package topology the transfers were priced on.
    pub topology: NopTopology,
    /// How the package legs were priced (analytical vs flit-level sim).
    pub mode: NopMode,
    /// One frame through one chiplet replica, seconds (the single-chip
    /// modeled latency, via `evaluate_package` on a 1-chiplet package).
    pub service_s: f64,
    /// Steady-state inter-frame interval when the frames of a batch
    /// pipeline through the replica's layers, seconds (slowest stage).
    pub stage_s: f64,
    /// NoP flits of one request's input payload.
    pub ingress_flits: u64,
    /// NoP flits of one request's output payload.
    pub egress_flits: u64,
    /// Directed package links of the gateway→chiplet route, per chiplet.
    pub paths: Vec<Vec<(usize, usize)>>,
    /// Zero-load input transfer time gateway→chiplet, seconds.
    pub ingress_s: Vec<f64>,
    /// Zero-load result return time chiplet→gateway, seconds.
    pub egress_s: Vec<f64>,
    /// Seconds one package link is busy serializing one ingress payload.
    pub link_busy_s: f64,
    /// Fixed per-hop SerDes latency, seconds.
    pub hop_s: f64,
    /// Per-link busy fraction at the package saturation rate measured by
    /// [`crate::nop::sim::saturation_rate`]; 1.0 when the topology
    /// sustains full injection (or when k = 1).
    pub sat_link_util: f64,
    /// Package I/O entry chiplet (owns the first mapped layer).
    pub gateway: usize,
    /// SerDes port bundles on the gateway (its injection bandwidth).
    pub gateway_ports: usize,
    /// The model-parallel alternative: per-frame latency of the same DNN
    /// partitioned over all `chiplets` (for context in reports).
    pub partitioned_latency_s: f64,
    /// Populated chiplets / cut bits of that partition.
    pub partition_populated: usize,
    /// Activation bits crossing chiplet boundaries in that partition.
    pub partition_cut_bits: u64,
    /// Per-layer compute/communication blame for one replica frame (the
    /// per-layer rows of the `--explain` report), exposed-comm ranked.
    pub layer_blame: Vec<LayerBlame>,
}

impl ServingModel {
    /// Price every serving cost for `graph` on a `nop.chiplets`-chiplet
    /// package, returning the model plus the [`ChipletPartition`] the
    /// scheduler's queues sit over. The per-chiplet legs stay analytical
    /// (the scheduler prices thousands of admissions); the *package* legs
    /// honor `nop.mode` — ingress transfers are priced by
    /// `nop_transfer_cycles`, by a memoized flit-level
    /// [`NopSim`](crate::nop::sim::NopSim) drain
    /// ([`crate::sim::memo::drain_makespan`]), or by the fitted
    /// [`crate::sim::surrogate`] drain curve with sim fallback.
    pub fn build(
        graph: &DnnGraph,
        arch: &ArchConfig,
        noc: &NocConfig,
        nop: &NopConfig,
        sim: &SimConfig,
    ) -> (Self, ChipletPartition) {
        let k = nop.chiplets;
        let mapping = Mapping::build(graph, arch);
        let (service_s, stage_s, layer_blame) =
            replica_costs(graph, &mapping, arch, noc, nop, sim);

        // The model-parallel alternative and the partition the queues sit
        // over (which also fixes the package I/O gateway).
        let part = ChipletPartition::build(graph, &mapping, arch, k);
        let pkg = evaluate_package(graph, arch, noc, nop, sim, CommBackend::Analytical);
        let gateway = part.gateway_chiplet();

        let net = NopNetwork::build(nop.topology, k);
        let ingress_bits = graph.input_bits(arch.n_bits);
        let egress_bits = graph.output_bits(arch.n_bits);
        let ingress_flits = ingress_bits.div_ceil(nop.link_width as u64).max(1);
        let egress_flits = egress_bits.div_ceil(nop.link_width as u64).max(1);
        let nop_cycle_s = 1.0 / nop.freq_hz;

        let mut paths: Vec<Vec<(usize, usize)>> = Vec::with_capacity(k);
        let mut ingress_s = Vec::with_capacity(k);
        let mut egress_s = Vec::with_capacity(k);
        for c in 0..k {
            if c == gateway {
                paths.push(Vec::new());
                ingress_s.push(0.0);
                egress_s.push(0.0);
                continue;
            }
            paths.push(net.route_links(gateway, c));
            let hops = net.hops(gateway, c);
            let ing = match nop.mode {
                NopMode::Analytical => {
                    nop_transfer_cycles(ingress_bits, hops, nop, arch.freq_hz) / arch.freq_hz
                }
                NopMode::Sim | NopMode::Surrogate => {
                    let flows = [FlowSpec {
                        src: gateway,
                        dst: c,
                        rate: 0.0,
                        flits: ingress_flits,
                    }];
                    let budget = 10_000
                        + ingress_flits
                            .saturating_mul(4)
                            .saturating_mul(nop.hop_latency_cycles + 2);
                    // Surrogate: one fitted curve (base seed) prices every
                    // gateway→chiplet transfer; `None` falls back to sim.
                    let estimate = if nop.mode == NopMode::Surrogate {
                        crate::sim::surrogate::drain_estimate(
                            nop.topology,
                            k,
                            nop,
                            &flows,
                            sim.seed,
                        )
                        .map(|m| m.min(budget))
                    } else {
                        None
                    };
                    let cycles = match estimate {
                        Some(makespan) => makespan,
                        None => {
                            // Memoized: single- and multi-model serving
                            // builds price the same gateway→chiplet
                            // transfers repeatedly.
                            let stats = crate::sim::memo::drain_makespan(
                                nop.topology,
                                k,
                                nop,
                                &flows,
                                budget,
                                sim.seed ^ c as u64,
                            );
                            if stats.drained { stats.makespan } else { budget }
                        }
                    };
                    cycles as f64 * nop_cycle_s
                }
            };
            ingress_s.push(ing);
            let egr = nop_transfer_cycles(egress_bits, hops, nop, arch.freq_hz);
            egress_s.push(egr / arch.freq_hz);
        }

        let sat_link_util = measured_sat_link_util(&net, nop, sim.seed);

        let model = Self {
            dnn: graph.name.clone(),
            chiplets: k,
            topology: nop.topology,
            mode: nop.mode,
            service_s,
            stage_s,
            ingress_flits,
            egress_flits,
            paths,
            ingress_s,
            egress_s,
            link_busy_s: ingress_flits as f64 * nop_cycle_s,
            hop_s: nop.hop_latency_cycles as f64 * nop_cycle_s,
            sat_link_util,
            gateway,
            gateway_ports: net.ports(gateway),
            partitioned_latency_s: pkg.latency_s(),
            partition_populated: pkg.populated,
            partition_cut_bits: pkg.cross_bits,
            layer_blame,
        };
        (model, part)
    }

    /// Chiplet occupancy per request at full batches, seconds.
    pub fn per_request_s(&self, batch: usize) -> f64 {
        let b = batch.max(1) as f64;
        (self.service_s + (b - 1.0) * self.stage_s) / b
    }

    /// Aggregate modeled request capacity: the smaller of the replicas'
    /// service bandwidth and the gateway's NoP injection bandwidth.
    pub fn capacity_rps(&self, batch: usize) -> f64 {
        let svc = self.chiplets as f64 / self.per_request_s(batch);
        if self.chiplets == 1 {
            return svc;
        }
        let net = self.gateway_ports as f64 / self.link_busy_s;
        svc.min(net)
    }
}

/// Per-replica modeled costs shared by the single-model and multi-model
/// ([`crate::coordinator::mix`]) schedulers: one-frame service time through
/// a 1-chiplet replica (regression-tested equal to the flat single-chip
/// evaluator) and the steady-state layer-pipeline interval that batching
/// amortizes against.
///
/// The pipeline interval: consecutive frames of a batch stream through the
/// replica layer by layer, so the steady-state inter-frame gap is the
/// slowest per-layer stage. `comm_per_layer` is sparse (layers with no
/// inbound on-chip flows are skipped) and keyed by graph layer id, so the
/// join is on that id rather than a zip.
///
/// The third element is the per-layer blame table the `--explain` report
/// surfaces: compute vs NoC-communication milliseconds per mapped layer,
/// with the comm time *exposed* beyond compute (the layer's contribution
/// to a frame's critical path under compute/communication overlap).
pub(crate) fn replica_costs(
    graph: &DnnGraph,
    mapping: &Mapping,
    arch: &ArchConfig,
    noc: &NocConfig,
    nop: &NopConfig,
    sim: &SimConfig,
) -> (f64, f64, Vec<LayerBlame>) {
    let solo = NopConfig {
        chiplets: 1,
        ..nop.clone()
    };
    let replica = evaluate_package(graph, arch, noc, &solo, sim, CommBackend::Analytical);
    let service_s = replica.latency_s();
    let flat = evaluate(graph, noc.topology, arch, noc, sim, CommBackend::Analytical);
    let chip = ChipCost::evaluate(graph, mapping, arch);
    let comm_of: HashMap<usize, u64> = flat.comm_per_layer.iter().copied().collect();
    let ms = 1e3 / arch.freq_hz;
    let mut stage_cycles = 1.0f64;
    let mut layers = Vec::with_capacity(mapping.layers.len());
    for (i, lt) in mapping.layers.iter().enumerate() {
        let compute = chip.per_layer[i].cycles as f64;
        let comm = comm_of.get(&lt.layer).copied().unwrap_or(0) as f64;
        stage_cycles = stage_cycles.max(compute.max(comm));
        layers.push(LayerBlame {
            model: graph.name.clone(),
            layer: graph.layers[lt.layer].name.clone(),
            compute_ms: compute * ms,
            comm_ms: comm * ms,
            exposed_ms: (comm - compute).max(0.0) * ms,
        });
    }
    let stage_s = (stage_cycles / arch.freq_hz).min(service_s);
    (service_s, stage_s, layers)
}

/// Convert the measured package saturation rate (uniform flits per chiplet
/// per NoP cycle, from [`crate::nop::sim::saturation_rate`]) into the
/// per-link busy fraction it implies: rate × k flit-hops spread over the
/// link graph. 1.0 when the topology sustains full injection (or k = 1).
pub(crate) fn measured_sat_link_util(net: &NopNetwork, nop: &NopConfig, seed: u64) -> f64 {
    let k = net.chiplets;
    match saturation_rate(nop.topology, k, nop, seed) {
        None => 1.0,
        Some(rate) => {
            let mut hop_sum = 0usize;
            let mut pairs = 0usize;
            for s in 0..k {
                for d in 0..k {
                    if s != d {
                        hop_sum += net.hops(s, d);
                        pairs += 1;
                    }
                }
            }
            let avg_hops = hop_sum as f64 / pairs.max(1) as f64;
            let load = rate * k as f64 * avg_hops / net.link_count().max(1) as f64;
            load.min(1.0)
        }
    }
}

/// Two-bucket sliding estimate of a package link's busy fraction.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct LinkWindow {
    bucket_start: f64,
    cur: f64,
    prev: f64,
}

impl LinkWindow {
    pub(crate) fn add(&mut self, t: f64, busy_s: f64, window_s: f64) {
        self.roll(t, window_s);
        self.cur += busy_s;
    }

    fn roll(&mut self, t: f64, window_s: f64) {
        if t >= self.bucket_start + 2.0 * window_s {
            self.bucket_start = t;
            self.prev = 0.0;
            self.cur = 0.0;
        } else if t >= self.bucket_start + window_s {
            self.bucket_start += window_s;
            self.prev = self.cur;
            self.cur = 0.0;
        }
    }

    pub(crate) fn utilization(&mut self, t: f64, window_s: f64) -> f64 {
        self.roll(t, window_s);
        let span = window_s + (t - self.bucket_start).max(0.0);
        ((self.prev + self.cur) / span.max(1e-12)).min(1.0)
    }
}

/// A request admitted to a chiplet queue: arrival time at the gateway, the
/// time its input finishes streaming to the chiplet, and its lifecycle
/// span index.
#[derive(Clone, Copy, Debug)]
struct Pending {
    arrival: f64,
    ready: f64,
    span: usize,
}

/// Per-chiplet request queues over a [`ChipletPartition`], plus the
/// discrete-event serving simulation that drives them.
pub struct ChipletScheduler {
    /// The priced serving model the queues run over.
    pub model: ServingModel,
    /// Layer→chiplet partition the replicas host.
    pub partition: ChipletPartition,
    policy: Policy,
    queue_depth: usize,
    batch: usize,
    // Dynamic state, owned by one `run`.
    free_at: Vec<f64>,
    queues: Vec<VecDeque<Pending>>,
    link_free: HashMap<(usize, usize), f64>,
    link_util: HashMap<(usize, usize), LinkWindow>,
    window_s: f64,
    rr_next: usize,
    busy_s: Vec<f64>,
    served: Vec<usize>,
    peak_queue: Vec<usize>,
    batches: usize,
    /// Streaming latency sketch over completed requests, ms — O(1)
    /// memory however many requests the run serves.
    latency: QuantileSketch,
    /// One lifecycle span per offered request, in admission order.
    spans: Vec<RequestSpan>,
    /// One hop-by-hop ingress trace per offered request, index-aligned
    /// with `spans` (default/empty for rejected requests).
    ingress_traces: Vec<IngressTrace>,
    /// Queue depth observed at each admission.
    depth_hist: Histogram,
    /// Windowed serving metrics (installed by `run`, sized from the
    /// arrival horizon unless `set_metrics_window_s` pinned a width).
    timeseries: TimeSeries,
    /// `[telemetry] window_ms` override, seconds (0 = auto).
    metrics_window_s: f64,
}

impl ChipletScheduler {
    /// A scheduler over `partition` with empty queues.
    pub fn new(model: ServingModel, partition: ChipletPartition, cfg: &ServingConfig) -> Self {
        let k = model.chiplets;
        // Utilization window: long enough to smooth tens of payloads on a
        // link, short enough to track saturation as it builds.
        let window_s = (32.0 * model.link_busy_s).max(16.0 * model.stage_s);
        Self {
            model,
            partition,
            policy: cfg.policy,
            queue_depth: cfg.queue_depth.max(1),
            batch: cfg.batch.max(1),
            free_at: vec![0.0; k],
            queues: (0..k).map(|_| VecDeque::new()).collect(),
            link_free: HashMap::new(),
            link_util: HashMap::new(),
            window_s,
            rr_next: 0,
            busy_s: vec![0.0; k],
            served: vec![0; k],
            peak_queue: vec![0; k],
            batches: 0,
            latency: QuantileSketch::new(),
            spans: Vec::new(),
            ingress_traces: Vec::new(),
            depth_hist: Histogram::default(),
            timeseries: TimeSeries::default(),
            metrics_window_s: 0.0,
        }
    }

    /// Pin the time-series window width (seconds). 0 (the default) sizes
    /// the window automatically so a run spans about
    /// [`AUTO_WINDOWS`](crate::telemetry::timeseries::AUTO_WINDOWS)
    /// windows; the CLI threads `[telemetry] window_ms` through here.
    pub fn set_metrics_window_s(&mut self, window_s: f64) {
        self.metrics_window_s = window_s.max(0.0);
    }

    /// Reset every per-run accumulator so one scheduler can host several
    /// independent runs.
    fn reset(&mut self) {
        let k = self.model.chiplets;
        self.free_at = vec![0.0; k];
        self.queues = (0..k).map(|_| VecDeque::new()).collect();
        self.link_free.clear();
        self.link_util.clear();
        self.rr_next = 0;
        self.busy_s = vec![0.0; k];
        self.served = vec![0; k];
        self.peak_queue = vec![0; k];
        self.batches = 0;
        self.latency = QuantileSketch::new();
        self.spans.clear();
        self.ingress_traces.clear();
        self.depth_hist = Histogram::default();
        self.timeseries = TimeSeries::default();
    }

    /// Lifecycle spans of the most recent run, in admission order (one per
    /// offered request — completed and dropped alike).
    pub fn spans(&self) -> &[RequestSpan] {
        &self.spans
    }

    /// Hop-by-hop ingress traces of the most recent run, index-aligned
    /// with [`spans`](Self::spans) (default/empty for rejected requests).
    pub fn ingress_traces(&self) -> &[IngressTrace] {
        &self.ingress_traces
    }

    /// Queue depth observed at each admission of the most recent run.
    pub fn queue_depth_hist(&self) -> &Histogram {
        &self.depth_hist
    }

    /// Windowed serving metrics of the most recent run.
    pub fn timeseries(&self) -> &TimeSeries {
        &self.timeseries
    }

    /// Modeled completion time of a request admitted to chiplet `c` at
    /// `t` — the price the least-latency policies minimize.
    fn price(&self, c: usize, t: f64) -> f64 {
        let m = &self.model;
        let backlog = (self.free_at[c] - t).max(0.0)
            + self.queues[c].len() as f64 * m.per_request_s(self.batch);
        backlog + m.ingress_s[c] + m.service_s + m.egress_s[c]
    }

    /// Worst busy fraction among the links of chiplet `c`'s ingress path.
    fn path_utilization(&mut self, c: usize, t: f64) -> f64 {
        let window_s = self.window_s;
        let mut worst = 0.0f64;
        for link in &self.model.paths[c] {
            let win = self.link_util.entry(*link).or_default();
            worst = worst.max(win.utilization(t, window_s));
        }
        worst
    }

    /// Pick the chiplet for a request arriving at `t`, or `None` when
    /// every queue is at `queue_depth` (the request is dropped).
    fn pick(&mut self, t: f64) -> Option<usize> {
        let k = self.model.chiplets;
        match self.policy {
            Policy::RoundRobin => {
                for i in 0..k {
                    let c = (self.rr_next + i) % k;
                    if self.queues[c].len() < self.queue_depth {
                        self.rr_next = (c + 1) % k;
                        return Some(c);
                    }
                }
                None
            }
            Policy::LeastLatency | Policy::CongestionAware => {
                let aware = self.policy == Policy::CongestionAware;
                let threshold = SATURATION_BACKOFF * self.model.sat_link_util;
                let mut best: Option<(bool, f64, usize)> = None;
                for c in 0..k {
                    if self.queues[c].len() >= self.queue_depth {
                        continue;
                    }
                    let backed_off = aware && self.path_utilization(c, t) >= threshold;
                    let price = self.price(c, t);
                    let better = match &best {
                        None => true,
                        Some((bo, p, _)) => (backed_off, price) < (*bo, *p),
                    };
                    if better {
                        best = Some((backed_off, price, c));
                    }
                }
                best.map(|(_, _, c)| c)
            }
        }
    }

    /// Stream one request's input over the gateway→`c` package route
    /// starting at `t`; returns when the payload is resident on `c`.
    /// Links serialize (shared `link_free` state) and the head pipelines
    /// hop by hop, matching `nop_transfer_cycles` at zero load.
    fn ingress(&mut self, c: usize, t: f64) -> f64 {
        let ser_s = self.model.link_busy_s;
        let flits = self.model.ingress_flits;
        let hop_s = self.model.hop_s;
        let window_s = self.window_s;
        let n_hops = self.model.paths[c].len();
        let mut waits = Vec::with_capacity(n_hops);
        let mut head = t;
        let mut done = t;
        for &link in &self.model.paths[c] {
            let free = *self.link_free.get(&link).unwrap_or(&0.0);
            let start = head.max(free);
            let wait = start - head;
            waits.push((link, wait));
            if wait > 0.0 {
                log::trace!(
                    "ingress hop {}-{}: waited {:.3} us on busy link",
                    link.0,
                    link.1,
                    wait * 1e6
                );
            }
            let finish = (start + ser_s).max(done);
            self.link_free.insert(link, finish);
            let win = self.link_util.entry(link).or_default();
            win.add(start, finish - start, window_s);
            // The time series records the true serialization occupancy
            // (ser_s), not finish - start, which the pipelining `.max`
            // can inflate past the link's own busy time.
            self.timeseries.record_link_busy(start, link, ser_s, flits);
            head = start + hop_s;
            done = finish + hop_s;
        }
        if n_hops > 0 {
            self.timeseries.record_ejected(c, flits);
        }
        // Decomposition of `done - t`: per-link occupancy waits, one
        // payload serialization (links pipeline, so it counts once) and
        // the fixed per-hop propagation. The critical-path extractor
        // ([`crate::telemetry::BlameReport`]) consumes these components.
        self.ingress_traces.push(IngressTrace {
            waits,
            ser_s: if n_hops > 0 { ser_s } else { 0.0 },
            prop_s: n_hops as f64 * hop_s,
        });
        done
    }

    /// Start every batch that can begin by `t` (work-conserving service:
    /// a free chiplet takes up to `batch` input-resident requests).
    fn advance(&mut self, t: f64) {
        let service_s = self.model.service_s;
        let stage_s = self.model.stage_s;
        for c in 0..self.model.chiplets {
            loop {
                let head_ready = match self.queues[c].front() {
                    None => break,
                    Some(p) => p.ready,
                };
                let start = self.free_at[c].max(head_ready);
                if start > t {
                    break;
                }
                let mut taken = Vec::with_capacity(self.batch);
                while taken.len() < self.batch {
                    let ready = self.queues[c].front().is_some_and(|p| p.ready <= start);
                    if !ready {
                        break;
                    }
                    taken.push(self.queues[c].pop_front().unwrap());
                }
                self.batches += 1;
                let egress = self.model.egress_s[c];
                for (j, p) in taken.iter().enumerate() {
                    let complete = start + service_s + j as f64 * stage_s + egress;
                    let latency_ms = (complete - p.arrival) * 1e3;
                    self.latency.record(latency_ms);
                    self.timeseries.record_completion(complete, 0, latency_ms);
                    let sp = &mut self.spans[p.span];
                    sp.service_start = start;
                    sp.complete = complete;
                }
                let occupied = service_s + (taken.len() - 1) as f64 * stage_s;
                self.free_at[c] = start + occupied;
                self.busy_s[c] += occupied;
                self.served[c] += taken.len();
            }
        }
    }

    /// Run the serving simulation: `cfg.requests` Poisson arrivals at
    /// `cfg.arrival_rps` (0 = [`AUTO_LOAD_FACTOR`] × modeled capacity),
    /// routed by the configured policy. Deterministic for a given seed.
    pub fn run(&mut self, cfg: &ServingConfig, seed: u64) -> ServeReport {
        self.reset();
        let rate = if cfg.arrival_rps > 0.0 {
            cfg.arrival_rps
        } else {
            AUTO_LOAD_FACTOR * self.model.capacity_rps(self.batch)
        };
        // Windowed metrics are always on (every recorder is O(1)); the
        // window width defaults to the expected arrival horizon split
        // into AUTO_WINDOWS windows.
        let window_s = if self.metrics_window_s > 0.0 {
            self.metrics_window_s
        } else {
            (cfg.requests as f64 / rate / AUTO_WINDOWS).max(1e-9)
        };
        self.timeseries = TimeSeries::new(
            window_s,
            vec![self.model.dnn.clone()],
            link_union(&self.model.paths),
            self.model.chiplets,
            self.model.gateway,
        );
        let mut rng = Pcg32::seeded(seed);
        let mut t = 0.0f64;
        let mut dropped = 0usize;
        for _ in 0..cfg.requests {
            t += -(1.0 - rng.next_f64()).ln() / rate;
            self.advance(t);
            self.timeseries.record_arrival(t, 0);
            match self.pick(t) {
                None => {
                    dropped += 1;
                    self.timeseries.record_drop(t, 0);
                    self.spans.push(RequestSpan::rejected(0, t, SpanOutcome::Dropped));
                    self.ingress_traces.push(IngressTrace::default());
                }
                Some(c) => {
                    let ready = self.ingress(c, t);
                    let span = self.spans.len();
                    self.spans.push(RequestSpan::admitted(0, c, t, ready));
                    self.queues[c].push_back(Pending {
                        arrival: t,
                        ready,
                        span,
                    });
                    self.peak_queue[c] = self.peak_queue[c].max(self.queues[c].len());
                    self.depth_hist.record(self.queues[c].len() as f64);
                    self.timeseries.record_depth(t, self.queues[c].len());
                }
            }
        }
        // Drain: jump past every outstanding ready/free horizon until the
        // queues empty (each pass starts at least the head batches).
        let mut horizon = t;
        loop {
            let pending: usize = self.queues.iter().map(|q| q.len()).sum();
            if pending == 0 {
                break;
            }
            for q in &self.queues {
                for p in q {
                    horizon = horizon.max(p.ready);
                }
            }
            for &f in &self.free_at {
                horizon = horizon.max(f);
            }
            horizon += self.model.service_s;
            self.advance(horizon);
        }
        let end = self.free_at.iter().copied().fold(t, f64::max).max(1e-12);
        let mut per_chiplet = Vec::with_capacity(self.model.chiplets);
        for c in 0..self.model.chiplets {
            per_chiplet.push(ChipletQueueStats {
                chiplet: c,
                served: self.served[c],
                utilization: (self.busy_s[c] / end).min(1.0),
                peak_queue: self.peak_queue[c],
            });
        }
        self.timeseries.finalize(end);
        let mut report = ServeReport::from_sketch(
            cfg.requests,
            self.latency.count() as usize,
            dropped,
            self.batch,
            self.batches,
            &self.latency,
            end,
        );
        report.per_chiplet = per_chiplet;
        report.offered_rps = rate;
        let (ing, que, ser) = mean_breakdown_ms(&self.spans, None);
        report.mean_ingress_ms = ing;
        report.mean_queue_ms = que;
        report.mean_service_ms = ser;
        report
    }
}

/// Build the model and run one serving simulation in a single call (the
/// CLI / experiment entry point).
pub fn serve_modeled(
    graph: &DnnGraph,
    arch: &ArchConfig,
    noc: &NocConfig,
    nop: &NopConfig,
    sim: &SimConfig,
    cfg: &ServingConfig,
) -> (ServingModel, ServeReport) {
    let (model, report, _) = serve_modeled_traced(graph, arch, noc, nop, sim, cfg);
    (model, report)
}

/// Like [`serve_modeled`], also returning the per-request lifecycle spans
/// (the raw material for `repro serve --trace-out`).
pub fn serve_modeled_traced(
    graph: &DnnGraph,
    arch: &ArchConfig,
    noc: &NocConfig,
    nop: &NopConfig,
    sim: &SimConfig,
    cfg: &ServingConfig,
) -> (ServingModel, ServeReport, Vec<RequestSpan>) {
    let (model, report, spans, _, _) =
        serve_modeled_metrics(graph, arch, noc, nop, sim, cfg, 0.0);
    (model, report, spans)
}

/// Like [`serve_modeled_traced`], also returning the per-request
/// [`IngressTrace`]s (index-aligned with the spans — the raw material for
/// `repro serve --explain`) and the windowed [`TimeSeries`] (the raw
/// material for `repro serve --metrics-out` and `--heatmap`).
/// `window_ms` pins the window width; 0 sizes it automatically from the
/// arrival horizon.
#[allow(clippy::type_complexity)]
pub fn serve_modeled_metrics(
    graph: &DnnGraph,
    arch: &ArchConfig,
    noc: &NocConfig,
    nop: &NopConfig,
    sim: &SimConfig,
    cfg: &ServingConfig,
    window_ms: f64,
) -> (
    ServingModel,
    ServeReport,
    Vec<RequestSpan>,
    Vec<IngressTrace>,
    TimeSeries,
) {
    let (model, part) = ServingModel::build(graph, arch, noc, nop, sim);
    let mut sched = ChipletScheduler::new(model, part, cfg);
    sched.set_metrics_window_s(window_ms * 1e-3);
    // Arrivals are seeded by `[serving] seed`, not `[sim] seed`, so serving
    // runs reseed independently of the NoC/NoP simulators.
    let report = sched.run(cfg, cfg.seed);
    let spans = std::mem::take(&mut sched.spans);
    let traces = std::mem::take(&mut sched.ingress_traces);
    let timeseries = std::mem::take(&mut sched.timeseries);
    (sched.model, report, spans, traces, timeseries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;

    fn defaults() -> (ArchConfig, NocConfig, SimConfig) {
        (
            ArchConfig::default(),
            NocConfig::default(),
            SimConfig::default(),
        )
    }

    fn serving(policy: Policy, requests: usize) -> ServingConfig {
        ServingConfig {
            policy,
            requests,
            ..ServingConfig::default()
        }
    }

    #[test]
    fn policy_parse_roundtrip_and_errors() {
        for p in Policy::all() {
            assert_eq!(Policy::parse(p.name()), Some(p));
        }
        assert_eq!(Policy::parse("RR"), Some(Policy::RoundRobin));
        assert_eq!(Policy::parse("congestion"), Some(Policy::CongestionAware));
        assert_eq!(Policy::parse("fifo"), None);
        assert!(Policy::valid_names().contains("congestion-aware"));
    }

    #[test]
    fn model_prices_far_chiplets_higher() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Mesh,
            chiplets: 16,
            ..NopConfig::default()
        };
        let g = models::squeezenet();
        let (m, part) = ServingModel::build(&g, &arch, &noc, &nop, &sim);
        let mapping = Mapping::build(&g, &arch);
        part.validate(&mapping).unwrap();
        assert_eq!(m.gateway, 0);
        assert_eq!(m.ingress_s[0], 0.0);
        // Chiplet 15 sits 6 mesh hops from the corner gateway; chiplet 1
        // is adjacent — the NoP cost model must see the difference.
        assert!(m.ingress_s[15] > m.ingress_s[1]);
        assert!(m.ingress_s[1] > 0.0);
        assert!(m.service_s > 0.0 && m.stage_s > 0.0);
        assert!(m.stage_s <= m.service_s);
        assert!(m.sat_link_util > 0.0 && m.sat_link_util <= 1.0);
        assert!(m.partitioned_latency_s > 0.0);
    }

    #[test]
    fn one_chiplet_run_matches_flat_single_chip_throughput() {
        // A 1-chiplet scheduler is the flat single-chip server: saturate
        // it (batch 1) and the modeled throughput must converge to the
        // single-chip frame rate.
        let (arch, noc, sim) = defaults();
        let g = models::mlp();
        let nop = NopConfig {
            chiplets: 1,
            ..NopConfig::default()
        };
        let flat = evaluate(&g, noc.topology, &arch, &noc, &sim, CommBackend::Analytical);
        let (model, part) = ServingModel::build(&g, &arch, &noc, &nop, &sim);
        let cfg = ServingConfig {
            policy: Policy::RoundRobin,
            queue_depth: 64,
            arrival_rps: 10.0 * flat.fps(),
            requests: 400,
            batch: 1,
            ..ServingConfig::default()
        };
        let mut sched = ChipletScheduler::new(model, part, &cfg);
        let report = sched.run(&cfg, 7);
        assert!(report.completed > 80);
        assert!(report.dropped > 0);
        assert_eq!(report.completed + report.dropped, report.requests);
        let rel = (report.throughput_rps - flat.fps()).abs() / flat.fps();
        assert!(
            rel < 0.03,
            "modeled serving throughput {} vs flat fps {}",
            report.throughput_rps,
            flat.fps()
        );
        assert_eq!(report.per_chiplet.len(), 1);
        assert!(report.per_chiplet[0].utilization > 0.9);
    }

    #[test]
    fn round_robin_spreads_requests_evenly() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Ring,
            chiplets: 4,
            ..NopConfig::default()
        };
        let (model, part) = ServingModel::build(&models::lenet5(), &arch, &noc, &nop, &sim);
        let cfg = ServingConfig {
            arrival_rps: 0.2 * model.capacity_rps(1),
            batch: 1,
            ..serving(Policy::RoundRobin, 200)
        };
        let mut sched = ChipletScheduler::new(model, part, &cfg);
        let report = sched.run(&cfg, 11);
        assert_eq!(report.dropped, 0);
        let served: Vec<usize> = report.per_chiplet.iter().map(|s| s.served).collect();
        assert_eq!(served.iter().sum::<usize>(), 200);
        assert_eq!(served.iter().max(), served.iter().min());
    }

    #[test]
    fn queue_depth_bounds_backlog_and_drops_surface() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Ring,
            chiplets: 2,
            ..NopConfig::default()
        };
        let (model, part) = ServingModel::build(&models::mlp(), &arch, &noc, &nop, &sim);
        let cfg = ServingConfig {
            policy: Policy::LeastLatency,
            queue_depth: 1,
            arrival_rps: 50.0 * model.capacity_rps(1),
            requests: 300,
            batch: 1,
            ..ServingConfig::default()
        };
        let mut sched = ChipletScheduler::new(model, part, &cfg);
        let report = sched.run(&cfg, 3);
        assert!(report.dropped > 0, "overload must surface as drops");
        assert_eq!(report.completed + report.dropped, report.requests);
        for s in &report.per_chiplet {
            assert!(s.peak_queue <= 1, "peak {}", s.peak_queue);
        }
        assert!(report.p99_ms >= report.p50_ms);
    }

    #[test]
    fn spans_reconcile_with_report() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Ring,
            chiplets: 2,
            ..NopConfig::default()
        };
        let (model, part) = ServingModel::build(&models::mlp(), &arch, &noc, &nop, &sim);
        let cfg = ServingConfig {
            policy: Policy::LeastLatency,
            queue_depth: 1,
            arrival_rps: 50.0 * model.capacity_rps(1),
            requests: 300,
            batch: 1,
            ..ServingConfig::default()
        };
        let mut sched = ChipletScheduler::new(model, part, &cfg);
        let report = sched.run(&cfg, 3);
        // One span per offered request; outcomes match the report exactly.
        assert_eq!(sched.spans().len(), report.requests);
        let done = sched
            .spans()
            .iter()
            .filter(|s| s.outcome == SpanOutcome::Completed)
            .count();
        let dropped = sched
            .spans()
            .iter()
            .filter(|s| s.outcome == SpanOutcome::Dropped)
            .count();
        assert_eq!(done, report.completed);
        assert_eq!(dropped, report.dropped);
        // Phase means sum to the mean latency (same underlying samples).
        let sum = report.mean_ingress_ms + report.mean_queue_ms + report.mean_service_ms;
        assert!((sum - report.mean_ms).abs() < 1e-9, "{sum} vs {}", report.mean_ms);
        assert!(report.mean_queue_ms > 0.0, "overload must show queue wait");
        assert_eq!(sched.queue_depth_hist().count(), done as u64);
        // Every completed span is internally ordered.
        for s in sched.spans() {
            if s.outcome == SpanOutcome::Completed {
                assert!(s.ready >= s.arrival);
                assert!(s.service_start >= s.ready);
                assert!(s.complete >= s.service_start);
            }
        }
    }

    #[test]
    fn ingress_traces_reconcile_with_spans_and_report() {
        // Critical-path property: for every offered request the trace's
        // component sum (waits + serialization + propagation) equals the
        // span's ingress phase, and the per-request sums average to the
        // report's mean_ingress_ms. Overload on a 4-chiplet mesh makes
        // link waits real, so the reconciliation is non-trivial.
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Mesh,
            chiplets: 4,
            ..NopConfig::default()
        };
        let (model, part) = ServingModel::build(&models::lenet5(), &arch, &noc, &nop, &sim);
        let cfg = ServingConfig {
            policy: Policy::LeastLatency,
            queue_depth: 4,
            arrival_rps: 2.0 * model.capacity_rps(1),
            requests: 250,
            batch: 1,
            ..ServingConfig::default()
        };
        let mut sched = ChipletScheduler::new(model, part, &cfg);
        let report = sched.run(&cfg, 9);
        assert_eq!(sched.ingress_traces().len(), sched.spans().len());
        let mut sum_ms = 0.0f64;
        let mut n = 0usize;
        for (span, trace) in sched.spans().iter().zip(sched.ingress_traces()) {
            if span.outcome == SpanOutcome::Dropped {
                assert!(trace.waits.is_empty() && trace.total_s() == 0.0);
                continue;
            }
            let ingress = span.ready - span.arrival;
            assert!(
                (trace.total_s() - ingress).abs() <= 1e-9 * ingress.max(1.0),
                "trace components {} vs span ingress {ingress}",
                trace.total_s()
            );
            if span.outcome == SpanOutcome::Completed {
                sum_ms += trace.total_s() * 1e3;
                n += 1;
            }
        }
        let mean = sum_ms / n.max(1) as f64;
        assert!(
            (mean - report.mean_ingress_ms).abs() <= 1e-9 * mean.max(1.0),
            "trace mean {mean} vs report {}",
            report.mean_ingress_ms
        );
        // Congested mesh: at least one request waited on a busy link.
        assert!(sched
            .ingress_traces()
            .iter()
            .any(|tr| tr.waits.iter().any(|&(_, w)| w > 0.0)));
    }

    #[test]
    fn layer_blame_rows_cover_the_mapped_layers() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            chiplets: 4,
            ..NopConfig::default()
        };
        let g = models::squeezenet();
        let (model, _) = ServingModel::build(&g, &arch, &noc, &nop, &sim);
        assert!(!model.layer_blame.is_empty());
        for lb in &model.layer_blame {
            assert_eq!(lb.model, g.name);
            assert!(lb.compute_ms >= 0.0 && lb.comm_ms >= 0.0);
            assert!(lb.exposed_ms <= lb.comm_ms + 1e-12);
        }
        // The slowest stage the pipeline interval is built from appears in
        // the blame rows: max(compute, comm) over rows >= stage interval.
        let worst = model
            .layer_blame
            .iter()
            .map(|l| l.compute_ms.max(l.comm_ms))
            .fold(0.0f64, f64::max);
        assert!(worst * 1e-3 >= model.stage_s - 1e-12);
    }

    #[test]
    fn timeseries_windows_reconcile_with_report() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Mesh,
            chiplets: 4,
            ..NopConfig::default()
        };
        let (model, part) = ServingModel::build(&models::lenet5(), &arch, &noc, &nop, &sim);
        let cfg = ServingConfig {
            policy: Policy::LeastLatency,
            queue_depth: 2,
            arrival_rps: 2.0 * model.capacity_rps(1),
            requests: 250,
            batch: 1,
            ..ServingConfig::default()
        };
        let mut sched = ChipletScheduler::new(model, part, &cfg);
        let report = sched.run(&cfg, 9);
        let ts = sched.timeseries();
        assert!(ts.is_enabled());
        let (arrivals, completions, drops, sheds) = ts.totals();
        assert_eq!(arrivals as usize, report.requests);
        assert_eq!(completions as usize, report.completed);
        assert_eq!(drops as usize, report.dropped);
        assert_eq!(sheds, 0);
        // Window sums equal the cumulative totals, exactly.
        let wsum: u64 = ts.windows().iter().map(|w| w.arrivals).sum();
        assert_eq!(wsum, arrivals);
        let csum: u64 = ts.windows().iter().map(|w| w.completions).sum();
        assert_eq!(csum, completions);
        // Links saw ingress traffic (k = 4 mesh, non-gateway chiplets).
        assert!(!ts.links().is_empty());
        let telem = ts.to_sim_telemetry();
        assert!(telem.transit_total() > 0);
        // Overloaded at 2x capacity: queue depth samples exist.
        assert!(ts.windows().iter().any(|w| w.depth.count() > 0));
    }

    #[test]
    fn metrics_window_override_controls_window_count() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            chiplets: 2,
            ..NopConfig::default()
        };
        let (model, part) = ServingModel::build(&models::mlp(), &arch, &noc, &nop, &sim);
        let cfg = ServingConfig {
            arrival_rps: 0.5 * model.capacity_rps(1),
            requests: 100,
            batch: 1,
            ..ServingConfig::default()
        };
        let mut sched = ChipletScheduler::new(model, part, &cfg);
        sched.run(&cfg, 5);
        let auto_windows = sched.timeseries().windows().len();
        // Halve the auto width: about twice the windows.
        let half = sched.timeseries().window_s() / 2.0;
        sched.set_metrics_window_s(half);
        sched.run(&cfg, 5);
        let fine = sched.timeseries().windows().len();
        assert!(
            fine > auto_windows,
            "halving the window must add windows: {fine} vs {auto_windows}"
        );
        assert!((sched.timeseries().window_s() - half).abs() < 1e-15);
    }

    #[test]
    fn batching_amortizes_the_pipeline_stage() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            chiplets: 1,
            ..NopConfig::default()
        };
        let (model, _) = ServingModel::build(&models::vgg(19), &arch, &noc, &nop, &sim);
        // Per-request occupancy shrinks toward the stage interval as the
        // batch grows, and capacity grows accordingly.
        assert!(model.per_request_s(8) < model.per_request_s(1));
        assert!(model.per_request_s(8) >= model.stage_s);
        assert!(model.capacity_rps(8) > model.capacity_rps(1));
    }
}
