//! Layer descriptors. A layer is a node of the DNN DAG; edges are recorded
//! as predecessor indices on each node (see [`crate::dnn::graph`]).

/// What a layer computes. Only the shape-relevant structure is captured.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerKind {
    /// Network input (one per graph, index 0).
    Input,
    /// 2-D convolution, `c_in -> c_out` channels with a `kx × ky` kernel.
    Conv {
        kx: usize,
        ky: usize,
        c_in: usize,
        c_out: usize,
        stride: usize,
    },
    /// Fully-connected layer.
    Fc { inputs: usize, outputs: usize },
    /// Pooling (max or average — identical for our purposes).
    Pool { k: usize, stride: usize },
    /// Elementwise addition of predecessors (residual join).
    Add,
    /// Channel concatenation of predecessors (dense join).
    Concat,
    /// Global average pool to 1×1.
    GlobalPool,
}

impl LayerKind {
    /// Does this layer hold weights (and therefore map onto crossbars)?
    pub fn has_weights(&self) -> bool {
        matches!(self, LayerKind::Conv { .. } | LayerKind::Fc { .. })
    }

    /// Short kind label for printing ("conv", "fc", …).
    pub fn name(&self) -> &'static str {
        match self {
            LayerKind::Input => "input",
            LayerKind::Conv { .. } => "conv",
            LayerKind::Fc { .. } => "fc",
            LayerKind::Pool { .. } => "pool",
            LayerKind::Add => "add",
            LayerKind::Concat => "concat",
            LayerKind::GlobalPool => "gap",
        }
    }
}

/// One node of the DNN graph.
#[derive(Clone, Debug, PartialEq)]
pub struct Layer {
    /// Human-readable name, e.g. "conv3_2".
    pub name: String,
    /// What the layer computes (conv, FC, pool, add, …).
    pub kind: LayerKind,
    /// Indices (into `DnnGraph::layers`) of the layers feeding this one.
    pub inputs: Vec<usize>,
    /// Output spatial width.
    pub out_x: usize,
    /// Output spatial height.
    pub out_y: usize,
    /// Output channel count.
    pub out_c: usize,
}

impl Layer {
    /// Number of output activation elements (`x·y·c`).
    pub fn output_elems(&self) -> usize {
        self.out_x * self.out_y * self.out_c
    }

    /// Paper definition of "neurons": output feature maps for conv, units
    /// for FC. Non-weight layers contribute no neurons of their own.
    pub fn neurons(&self) -> usize {
        match self.kind {
            LayerKind::Conv { c_out, .. } => c_out,
            LayerKind::Fc { outputs, .. } => outputs,
            _ => 0,
        }
    }

    /// Fan-in per neuron (synaptic connections): `c_in·kx·ky` for conv,
    /// `inputs` for FC. Zero for weight-less layers.
    pub fn fan_in(&self) -> usize {
        match self.kind {
            LayerKind::Conv { kx, ky, c_in, .. } => kx * ky * c_in,
            LayerKind::Fc { inputs, .. } => inputs,
            _ => 0,
        }
    }

    /// Weight count (for storage accounting).
    pub fn weights(&self) -> usize {
        self.neurons() * self.fan_in()
    }

    /// Multiply–accumulate operations to evaluate this layer once.
    pub fn macs(&self) -> usize {
        match self.kind {
            LayerKind::Conv { .. } => self.out_x * self.out_y * self.out_c * self.fan_in(),
            LayerKind::Fc { .. } => self.weights(),
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv3x3() -> Layer {
        Layer {
            name: "c".into(),
            kind: LayerKind::Conv {
                kx: 3,
                ky: 3,
                c_in: 64,
                c_out: 128,
                stride: 1,
            },
            inputs: vec![0],
            out_x: 56,
            out_y: 56,
            out_c: 128,
        }
    }

    #[test]
    fn conv_accounting() {
        let l = conv3x3();
        assert_eq!(l.neurons(), 128);
        assert_eq!(l.fan_in(), 3 * 3 * 64);
        assert_eq!(l.weights(), 128 * 576);
        assert_eq!(l.macs(), 56 * 56 * 128 * 576);
        assert!(l.kind.has_weights());
    }

    #[test]
    fn fc_accounting() {
        let l = Layer {
            name: "fc".into(),
            kind: LayerKind::Fc {
                inputs: 4096,
                outputs: 1000,
            },
            inputs: vec![1],
            out_x: 1,
            out_y: 1,
            out_c: 1000,
        };
        assert_eq!(l.neurons(), 1000);
        assert_eq!(l.fan_in(), 4096);
        assert_eq!(l.macs(), 4096 * 1000);
    }

    #[test]
    fn weightless_layers() {
        let l = Layer {
            name: "p".into(),
            kind: LayerKind::Pool { k: 2, stride: 2 },
            inputs: vec![0],
            out_x: 14,
            out_y: 14,
            out_c: 64,
        };
        assert_eq!(l.neurons(), 0);
        assert_eq!(l.macs(), 0);
        assert!(!l.kind.has_weights());
        assert_eq!(l.output_elems(), 14 * 14 * 64);
    }
}
