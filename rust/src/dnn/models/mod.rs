//! Model zoo generators. Layer shapes follow the original papers; graphs
//! are generated programmatically (no giant hand-written tables).

mod alexnet;
mod classic;
mod densenet;
mod resnet;
mod squeezenet;
mod vgg;

pub use alexnet::{alexnet, mobilenet};
pub use classic::{lenet5, mlp, nin};
pub use densenet::densenet;
pub use resnet::resnet;
pub use squeezenet::squeezenet;
pub use vgg::vgg;
