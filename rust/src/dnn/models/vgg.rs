//! VGG-16 / VGG-19 (Simonyan & Zisserman 2014) on ImageNet — the paper's
//! headline comparison network (Table 4 uses VGG-19).

use crate::dnn::{Dataset, DnnGraph};

/// Build VGG-`depth` (11, 13, 16 or 19).
pub fn vgg(depth: usize) -> DnnGraph {
    // convs-per-stage for each variant; channels double per stage.
    let stages: &[usize] = match depth {
        11 => &[1, 1, 2, 2, 2],
        13 => &[2, 2, 2, 2, 2],
        16 => &[2, 2, 3, 3, 3],
        19 => &[2, 2, 4, 4, 4],
        _ => panic!("unsupported VGG depth {depth} (use 11, 13, 16 or 19)"),
    };
    let channels = [64usize, 128, 256, 512, 512];
    let mut g = DnnGraph::new(format!("VGG-{depth}"), Dataset::ImageNet);
    let mut prev = 0;
    for (s, (&reps, &ch)) in stages.iter().zip(&channels).enumerate() {
        for r in 0..reps {
            prev = g.conv(format!("conv{}_{}", s + 1, r + 1), prev, 3, ch, 1);
        }
        prev = g.pool(format!("pool{}", s + 1), prev, 2, 2);
    }
    // 224 / 2^5 = 7 -> 7*7*512 = 25088 into the classifier.
    let f1 = g.fc("fc6", prev, 4096);
    let f2 = g.fc("fc7", f1, 4096);
    g.fc("fc8", f2, 1000);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg19_reference_counts() {
        let g = vgg(19);
        g.validate().unwrap();
        assert_eq!(g.num_weight_layers(), 19);
        // Published VGG-19 parameter count: ~143.7M.
        let w = g.total_weights() as f64 / 1e6;
        assert!((143.0..145.0).contains(&w), "weights {w}M");
        // Published MACs ~19.6 GMAC.
        let m = g.total_macs() as f64 / 1e9;
        assert!((19.0..20.5).contains(&m), "MACs {m}G");
        // fc6 consumes 7*7*512 activations.
        let wl = g.weight_layers();
        assert_eq!(g.input_activations(wl[16]), 25088);
    }

    #[test]
    fn vgg16_reference_counts() {
        let g = vgg(16);
        g.validate().unwrap();
        assert_eq!(g.num_weight_layers(), 16);
        let w = g.total_weights() as f64 / 1e6;
        assert!((138.0..139.5).contains(&w), "weights {w}M");
    }

    #[test]
    fn vgg11_and_13_build() {
        for d in [11, 13] {
            let g = vgg(d);
            g.validate().unwrap();
            assert_eq!(g.num_weight_layers(), d);
        }
    }

    #[test]
    #[should_panic]
    fn unsupported_depth_panics() {
        vgg(10);
    }
}
