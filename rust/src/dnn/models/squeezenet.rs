//! SqueezeNet v1.1 (Iandola et al. 2016) on ImageNet — a compact edge model
//! used in the paper's crossbar-size study (§5.2).

use crate::dnn::{Dataset, DnnGraph};

/// A fire module: squeeze 1×1 then parallel expand 1×1 / 3×3, concatenated.
fn fire(g: &mut DnnGraph, name: &str, from: usize, squeeze: usize, expand: usize) -> usize {
    let s = g.conv(format!("{name}_sq1x1"), from, 1, squeeze, 1);
    let e1 = g.conv(format!("{name}_ex1x1"), s, 1, expand, 1);
    let e3 = g.conv(format!("{name}_ex3x3"), s, 3, expand, 1);
    g.concat(format!("{name}_cat"), &[e1, e3])
}

/// Build SqueezeNet v1.1.
pub fn squeezenet() -> DnnGraph {
    let mut g = DnnGraph::new("SqueezeNet", Dataset::ImageNet);
    let c1 = g.conv("conv1", 0, 3, 64, 2); // 112
    let p1 = g.pool("pool1", c1, 3, 2); // 56
    let f2 = fire(&mut g, "fire2", p1, 16, 64);
    let f3 = fire(&mut g, "fire3", f2, 16, 64);
    let p3 = g.pool("pool3", f3, 3, 2); // 28
    let f4 = fire(&mut g, "fire4", p3, 32, 128);
    let f5 = fire(&mut g, "fire5", f4, 32, 128);
    let p5 = g.pool("pool5", f5, 3, 2); // 14
    let f6 = fire(&mut g, "fire6", p5, 48, 192);
    let f7 = fire(&mut g, "fire7", f6, 48, 192);
    let f8 = fire(&mut g, "fire8", f7, 64, 256);
    let f9 = fire(&mut g, "fire9", f8, 64, 256);
    let c10 = g.conv("conv10", f9, 1, 1000, 1);
    g.global_pool("gap", c10);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn squeezenet_reference_counts() {
        let g = squeezenet();
        g.validate().unwrap();
        // conv1 + 8 fires × 3 convs + conv10 = 26 weight layers.
        assert_eq!(g.num_weight_layers(), 26);
        // Published v1.1 params ~1.23M.
        let w = g.total_weights() as f64 / 1e6;
        assert!((1.1..1.4).contains(&w), "weights {w}M");
    }

    #[test]
    fn fire_module_branches() {
        let g = squeezenet();
        // The squeeze conv feeds two expand convs -> structural density > 1.
        let d = g.density_report();
        assert!(d.structural_density > 1.0, "{}", d.structural_density);
    }
}
