//! Compact / classic networks: MLP, LeNet-5, Network-in-Network.

use crate::dnn::{Dataset, DnnGraph};

/// 3-layer MLP on MNIST (784–512–256–10) — the paper's lowest-density model.
pub fn mlp() -> DnnGraph {
    let mut g = DnnGraph::new("MLP", Dataset::Mnist);
    let f1 = g.fc("fc1", 0, 512);
    let f2 = g.fc("fc2", f1, 256);
    g.fc("fc3", f2, 10);
    g
}

/// LeNet-5 (LeCun et al. 1998) on MNIST.
pub fn lenet5() -> DnnGraph {
    let mut g = DnnGraph::new("LeNet-5", Dataset::Mnist);
    let c1 = g.conv("conv1", 0, 5, 6, 1); // 28x28x6 ('same' padding)
    let p1 = g.pool("pool1", c1, 2, 2); // 14x14x6
    let c2 = g.conv("conv2", p1, 5, 16, 1); // 14x14x16
    let p2 = g.pool("pool2", c2, 2, 2); // 7x7x16
    let f1 = g.fc("fc1", p2, 120);
    let f2 = g.fc("fc2", f1, 84);
    g.fc("fc3", f2, 10);
    g
}

/// Network-in-Network (Lin et al. 2013) on CIFAR: three mlpconv stacks of
/// one spatial conv followed by two 1×1 convs.
pub fn nin() -> DnnGraph {
    let mut g = DnnGraph::new("NiN", Dataset::Cifar);
    // Block 1
    let c = g.conv("conv1", 0, 5, 192, 1);
    let c = g.conv("cccp1", c, 1, 160, 1);
    let c = g.conv("cccp2", c, 1, 96, 1);
    let p = g.pool("pool1", c, 3, 2); // 32 -> 16
    // Block 2
    let c = g.conv("conv2", p, 5, 192, 1);
    let c = g.conv("cccp3", c, 1, 192, 1);
    let c = g.conv("cccp4", c, 1, 192, 1);
    let p = g.pool("pool2", c, 3, 2); // 16 -> 8
    // Block 3
    let c = g.conv("conv3", p, 3, 192, 1);
    let c = g.conv("cccp5", c, 1, 192, 1);
    let c = g.conv("cccp6", c, 1, 100, 1); // CIFAR-100 head
    g.global_pool("gap", c);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_shapes() {
        let g = mlp();
        g.validate().unwrap();
        assert_eq!(g.num_weight_layers(), 3);
        assert_eq!(g.neurons(), 512 + 256 + 10);
        assert_eq!(g.total_weights(), 784 * 512 + 512 * 256 + 256 * 10);
    }

    #[test]
    fn lenet_shapes() {
        let g = lenet5();
        g.validate().unwrap();
        assert_eq!(g.num_weight_layers(), 5);
        // fc1 consumes 7*7*16 = 784 flattened activations.
        let wl = g.weight_layers();
        assert_eq!(g.input_activations(wl[2]), 7 * 7 * 16);
        assert_eq!(g.neurons(), 6 + 16 + 120 + 84 + 10);
    }

    #[test]
    fn nin_shapes() {
        let g = nin();
        g.validate().unwrap();
        assert_eq!(g.num_weight_layers(), 9);
        // Final conv emits 8x8x100 before global pooling.
        let last_conv = g
            .layers
            .iter()
            .rev()
            .find(|l| l.kind.has_weights())
            .unwrap();
        assert_eq!((last_conv.out_x, last_conv.out_c), (8, 100));
    }
}
