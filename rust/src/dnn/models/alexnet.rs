//! AlexNet (Krizhevsky et al. 2012) and MobileNetV1 (Howard et al. 2017) —
//! additional Fig. 1 density points: AlexNet is an early linear ImageNet
//! CNN; MobileNet's depthwise-separable convolutions give the lowest
//! synaptic density of any ImageNet model (an edge design point).

use crate::dnn::{Dataset, DnnGraph, Layer, LayerKind};

/// Build AlexNet (the single-tower variant).
pub fn alexnet() -> DnnGraph {
    let mut g = DnnGraph::new("AlexNet", Dataset::ImageNet);
    let c1 = g.conv("conv1", 0, 11, 96, 4); // 224/4 = 56
    let p1 = g.pool("pool1", c1, 3, 2); // 28
    let c2 = g.conv("conv2", p1, 5, 256, 1);
    let p2 = g.pool("pool2", c2, 3, 2); // 14
    let c3 = g.conv("conv3", p2, 3, 384, 1);
    let c4 = g.conv("conv4", c3, 3, 384, 1);
    let c5 = g.conv("conv5", c4, 3, 256, 1);
    let p5 = g.pool("pool5", c5, 3, 2); // 7
    let f6 = g.fc("fc6", p5, 4096);
    let f7 = g.fc("fc7", f6, 4096);
    g.fc("fc8", f7, 1000);
    g
}

/// A depthwise conv: one k×k filter per channel (fan-in k²).
fn depthwise(g: &mut DnnGraph, name: &str, from: usize, k: usize, stride: usize) -> usize {
    let src = &g.layers[from];
    let (ix, iy, c) = (src.out_x, src.out_y, src.out_c);
    let ox = ix.div_ceil(stride);
    let oy = iy.div_ceil(stride);
    g.push(Layer {
        name: name.into(),
        kind: LayerKind::Conv {
            kx: k,
            ky: k,
            c_in: 1, // per-channel filter: fan-in k*k
            c_out: c,
            stride,
        },
        inputs: vec![from],
        out_x: ox,
        out_y: oy,
        out_c: c,
    })
}

/// Build MobileNetV1 (width 1.0).
pub fn mobilenet() -> DnnGraph {
    let mut g = DnnGraph::new("MobileNetV1", Dataset::ImageNet);
    let mut prev = g.conv("conv0", 0, 3, 32, 2); // 112
    // (pointwise out channels, stride of the depthwise stage)
    let stages: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (i, &(c_out, stride)) in stages.iter().enumerate() {
        let dw = depthwise(&mut g, &format!("dw{}", i + 1), prev, 3, stride);
        prev = g.conv(format!("pw{}", i + 1), dw, 1, c_out, 1);
    }
    let gp = g.global_pool("gap", prev);
    g.fc("fc", gp, 1000);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_reference_counts() {
        let g = alexnet();
        g.validate().unwrap();
        assert_eq!(g.num_weight_layers(), 8);
        // Published single-tower AlexNet params ~61M; our 'same'-padding
        // bookkeeping keeps 7x7 (vs 6x6) into fc6, giving ~76M — same
        // order, same layer structure.
        let w = g.total_weights() as f64 / 1e6;
        assert!((55.0..85.0).contains(&w), "weights {w}M");
    }

    #[test]
    fn mobilenet_reference_counts() {
        let g = mobilenet();
        g.validate().unwrap();
        // conv0 + 13x(dw+pw) + fc = 28 weight layers.
        assert_eq!(g.num_weight_layers(), 28);
        // Published MobileNetV1 params ~4.2M.
        let w = g.total_weights() as f64 / 1e6;
        assert!((3.5..5.0).contains(&w), "weights {w}M");
    }

    #[test]
    fn mobilenet_lowest_imagenet_density() {
        // Depthwise separability slashes fan-in: MobileNet's synaptic
        // density must be far below AlexNet/VGG.
        let m = mobilenet().density_report().synaptic_density;
        let a = alexnet().density_report().synaptic_density;
        assert!(m < a / 3.0, "mobilenet {m} vs alexnet {a}");
    }
}
