//! ResNets (He et al. 2016) on ImageNet — the paper's representative
//! residual-connectivity networks (ResNet-50 in the eval set, ResNet-152 in
//! the crossbar-size study, ResNet-18 for density scatter coverage).

use crate::dnn::{Dataset, DnnGraph};

/// Build ResNet-`depth` (18 = basic blocks; 50/101/152 = bottleneck).
pub fn resnet(depth: usize) -> DnnGraph {
    let (bottleneck, blocks): (bool, [usize; 4]) = match depth {
        18 => (false, [2, 2, 2, 2]),
        34 => (false, [3, 4, 6, 3]),
        50 => (true, [3, 4, 6, 3]),
        101 => (true, [3, 4, 23, 3]),
        152 => (true, [3, 8, 36, 3]),
        _ => panic!("unsupported ResNet depth {depth}"),
    };
    let mut g = DnnGraph::new(format!("ResNet-{depth}"), Dataset::ImageNet);
    // Stem: 7x7/2 conv + 3x3/2 maxpool -> 56x56x64.
    let stem = g.conv("conv1", 0, 7, 64, 2);
    let mut prev = g.pool("pool1", stem, 3, 2);

    let widths = [64usize, 128, 256, 512];
    for (stage, (&reps, &w)) in blocks.iter().zip(&widths).enumerate() {
        for b in 0..reps {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            let tag = |part: &str| format!("s{}b{}_{part}", stage + 1, b + 1);
            let out_c = if bottleneck { w * 4 } else { w };
            // Main branch.
            let main = if bottleneck {
                let c1 = g.conv(tag("c1"), prev, 1, w, stride);
                let c2 = g.conv(tag("c2"), c1, 3, w, 1);
                g.conv(tag("c3"), c2, 1, out_c, 1)
            } else {
                let c1 = g.conv(tag("c1"), prev, 3, w, stride);
                g.conv(tag("c2"), c1, 3, w, 1)
            };
            // Shortcut branch: 1x1 projection whenever shape changes.
            let shortcut = if g.layers[prev].out_c != out_c || stride != 1 {
                g.conv(tag("proj"), prev, 1, out_c, stride)
            } else {
                prev
            };
            prev = g.add(tag("add"), main, shortcut);
        }
    }
    let gp = g.global_pool("gap", prev);
    g.fc("fc", gp, 1000);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_reference_counts() {
        let g = resnet(50);
        g.validate().unwrap();
        // 53 convs + 1 fc (49 main/stem + 4 projections).
        assert_eq!(g.num_weight_layers(), 54);
        let w = g.total_weights() as f64 / 1e6;
        assert!((25.0..26.0).contains(&w), "weights {w}M");
        let m = g.total_macs() as f64 / 1e9;
        assert!((3.8..4.3).contains(&m), "MACs {m}G");
    }

    #[test]
    fn resnet152_reference_counts() {
        let g = resnet(152);
        g.validate().unwrap();
        let w = g.total_weights() as f64 / 1e6;
        assert!((59.0..61.0).contains(&w), "weights {w}M");
        let m = g.total_macs() as f64 / 1e9;
        assert!((11.0..12.0).contains(&m), "MACs {m}G");
    }

    #[test]
    fn resnet18_reference_counts() {
        let g = resnet(18);
        g.validate().unwrap();
        let w = g.total_weights() as f64 / 1e6;
        assert!((11.0..12.0).contains(&w), "weights {w}M");
    }

    #[test]
    fn density_above_one() {
        let r = resnet(50).density_report();
        assert!(
            r.structural_density > 1.0,
            "residual nets must exceed density 1, got {}",
            r.structural_density
        );
    }

    #[test]
    fn final_stage_shape() {
        let g = resnet(50);
        // Last add before gap is 7x7x2048.
        let gap = g.layers.iter().find(|l| l.name == "gap").unwrap();
        assert_eq!(gap.out_c, 2048);
    }
}
