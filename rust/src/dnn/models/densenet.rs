//! DenseNets (Huang et al. 2017) on CIFAR — the paper's representative
//! dense-connectivity networks.
//!
//! DenseNet-40 and DenseNet-100 are the *plain* variants from the DenseNet
//! paper's CIFAR table (k = 12, no bottleneck, no compression; 1.0M and
//! 7.0M parameters) — these carry the high structural connection density
//! the paper's Fig. 1/20 placement relies on. DenseNet-121 is the BC
//! variant (bottleneck + 0.5 compression, k = 32).

use crate::dnn::{Dataset, DnnGraph};

/// Build DenseNet-`depth` for CIFAR (depth ∈ {40, 100, 121}).
pub fn densenet(depth: usize) -> DnnGraph {
    let (growth, bottleneck, layers_per_block, compression): (usize, bool, usize, f64) =
        match depth {
            // DenseNet-40: 3 blocks × 12 convs, k=12, plain.
            40 => (12, false, 12, 1.0),
            // DenseNet-100: 3 blocks × 32 convs, k=12, plain (7.0M params).
            100 => (12, false, 32, 1.0),
            // DenseNet-BC-121-style on CIFAR: k=32, 3 blocks × 13, θ=0.5.
            121 => (32, true, 13, 0.5),
            _ => panic!("unsupported DenseNet depth {depth}"),
        };
    let mut g = DnnGraph::new(format!("DenseNet-{depth}"), Dataset::Cifar);
    let mut prev = g.conv("conv0", 0, 3, 2 * growth, 1);

    for block in 0..3 {
        // Every layer in the block consumes the concat of ALL previous
        // outputs in the block (this is what drives connection density up).
        let mut feeds: Vec<usize> = vec![prev];
        for l in 0..layers_per_block {
            let tag = |part: &str| format!("b{}l{}_{part}", block + 1, l + 1);
            let cat = if feeds.len() == 1 {
                feeds[0]
            } else {
                g.concat(tag("cat"), &feeds)
            };
            let new = if bottleneck {
                let b = g.conv(tag("bn1x1"), cat, 1, 4 * growth, 1);
                g.conv(tag("conv"), b, 3, growth, 1)
            } else {
                g.conv(tag("conv"), cat, 3, growth, 1)
            };
            feeds.push(new);
        }
        let cat = g.concat(format!("b{}_out", block + 1), &feeds);
        prev = cat;
        if block < 2 {
            // Transition: 1x1 conv (+ compression for BC) + 2x2 avg pool.
            let c = (g.layers[cat].out_c as f64 * compression).floor() as usize;
            let t = g.conv(format!("trans{}_conv", block + 1), cat, 1, c, 1);
            prev = g.pool(format!("trans{}_pool", block + 1), t, 2, 2);
        }
    }
    let gp = g.global_pool("gap", prev);
    g.fc("fc", gp, 100);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn densenet100_reference_counts() {
        let g = densenet(100);
        g.validate().unwrap();
        // 1 stem + 3*32 block convs + 2 transition convs + 1 fc = 100.
        assert_eq!(g.num_weight_layers(), 100);
        // Published plain DenseNet-100 (k=12) params ~7.0M.
        let w = g.total_weights() as f64 / 1e6;
        assert!((6.0..8.0).contains(&w), "weights {w}M");
    }

    #[test]
    fn densenet40_reference_counts() {
        let g = densenet(40);
        g.validate().unwrap();
        // 1 stem + 3*12 + 2 transitions + 1 fc = 40.
        assert_eq!(g.num_weight_layers(), 40);
        // Published DenseNet-40 (k=12) params ~1.0M.
        let w = g.total_weights() as f64 / 1e6;
        assert!((0.8..1.3).contains(&w), "weights {w}M");
    }

    #[test]
    fn densenet121_bc_builds() {
        let g = densenet(121);
        g.validate().unwrap();
        assert_eq!(g.num_weight_layers(), 1 + 3 * 13 * 2 + 2 + 1);
    }

    #[test]
    fn dense_density_dominates() {
        let d = densenet(100).density_report();
        // Each block layer fans out to every later layer in the block: the
        // structural density must far exceed residual nets.
        assert!(
            d.structural_density > 8.0,
            "DenseNet-100 structural density {}",
            d.structural_density
        );
        // Fig. 20: DenseNet-100 must land in the mesh region (> 2e3).
        assert!(
            d.connection_density() > 2.0e3,
            "connection density {}",
            d.connection_density()
        );
    }

    #[test]
    fn channel_growth_within_block() {
        let g = densenet(40);
        // After block 1 (12 layers of growth 12 on a 24-ch stem):
        let b1 = g.layers.iter().find(|l| l.name == "b1_out").unwrap();
        assert_eq!(b1.out_c, 24 + 12 * 12);
    }
}
