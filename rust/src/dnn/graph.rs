//! The DNN DAG plus the neuron / connection-density accounting that drives
//! the whole study (paper Fig. 1, Fig. 2, Eq. 14–16).

use super::layer::{Layer, LayerKind};
use super::Dataset;

/// A DNN as a DAG of layers. `layers[0]` is always the [`LayerKind::Input`]
/// node; edges point from producer to consumer via `Layer::inputs`.
#[derive(Clone, Debug)]
pub struct DnnGraph {
    /// Display name (zoo key), e.g. "VGG-19".
    pub name: String,
    /// Dataset the model is defined for (fixes input resolution).
    pub dataset: Dataset,
    /// All layers in insertion order; index 0 is the input node.
    pub layers: Vec<Layer>,
}

/// Density metrics. The paper uses "connection density" loosely; we compute
/// both readings (see DESIGN.md §2):
///
/// * `structural_density` — average outgoing layer-level connections per
///   neuron (linear nets = 1.0, Fig. 2's definition).
/// * `synaptic_density` — average fan-in per neuron (the magnitude used by
///   the Fig. 20 guidance rule and Eq. 16).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DensityReport {
    /// Total neurons over all weight layers.
    pub neurons: usize,
    /// Layer-level producer→consumer edges, neuron-weighted.
    pub structural_connections: usize,
    /// Outgoing layer-level connections per neuron (linear nets = 1.0).
    pub structural_density: f64,
    /// Average synaptic fan-in per neuron.
    pub synaptic_density: f64,
}

impl DensityReport {
    /// The paper's "connection density" at Fig. 20 magnitude (10³-scale
    /// thresholds): effective connections per neuron *including reuse* —
    /// each neuron's synaptic fan-in is re-read once per structural
    /// consumer, so ρ = structural × synaptic. Linear ImageNet CNNs land
    /// at ~2–4 × 10³ (mesh region), compact edge nets at ~10²
    /// (tree region), matching the paper's placement of each DNN.
    pub fn connection_density(&self) -> f64 {
        self.structural_density * self.synaptic_density
    }
}

impl DnnGraph {
    /// An empty graph holding only the dataset's input node.
    pub fn new(name: impl Into<String>, dataset: Dataset) -> Self {
        let (h, w, c) = dataset.input_dims();
        Self {
            name: name.into(),
            dataset,
            layers: vec![Layer {
                name: "input".into(),
                kind: LayerKind::Input,
                inputs: vec![],
                out_x: w,
                out_y: h,
                out_c: c,
            }],
        }
    }

    /// Append a layer; returns its index.
    pub fn push(&mut self, layer: Layer) -> usize {
        self.layers.push(layer);
        self.layers.len() - 1
    }

    /// Convenience: conv + implicit ReLU consuming `from`, 'same' padding.
    pub fn conv(
        &mut self,
        name: impl Into<String>,
        from: usize,
        k: usize,
        c_out: usize,
        stride: usize,
    ) -> usize {
        let src = &self.layers[from];
        let (ix, iy, c_in) = (src.out_x, src.out_y, src.out_c);
        // 'same' padding: out = ceil(in / stride).
        let ox = ix.div_ceil(stride);
        let oy = iy.div_ceil(stride);
        self.push(Layer {
            name: name.into(),
            kind: LayerKind::Conv {
                kx: k,
                ky: k,
                c_in,
                c_out,
                stride,
            },
            inputs: vec![from],
            out_x: ox,
            out_y: oy,
            out_c: c_out,
        })
    }

    /// Max/avg pool consuming `from`.
    pub fn pool(&mut self, name: impl Into<String>, from: usize, k: usize, stride: usize) -> usize {
        let src = &self.layers[from];
        let (ix, iy, c) = (src.out_x, src.out_y, src.out_c);
        self.push(Layer {
            name: name.into(),
            kind: LayerKind::Pool { k, stride },
            inputs: vec![from],
            out_x: ix / stride,
            out_y: iy / stride,
            out_c: c,
        })
    }

    /// Global average pool to 1×1.
    pub fn global_pool(&mut self, name: impl Into<String>, from: usize) -> usize {
        let c = self.layers[from].out_c;
        self.push(Layer {
            name: name.into(),
            kind: LayerKind::GlobalPool,
            inputs: vec![from],
            out_x: 1,
            out_y: 1,
            out_c: c,
        })
    }

    /// Fully-connected layer consuming the flattened output of `from`.
    pub fn fc(&mut self, name: impl Into<String>, from: usize, outputs: usize) -> usize {
        let inputs = self.layers[from].output_elems();
        self.push(Layer {
            name: name.into(),
            kind: LayerKind::Fc { inputs, outputs },
            inputs: vec![from],
            out_x: 1,
            out_y: 1,
            out_c: outputs,
        })
    }

    /// Residual elementwise add of two branches (shapes must match).
    pub fn add(&mut self, name: impl Into<String>, a: usize, b: usize) -> usize {
        let (la, lb) = (&self.layers[a], &self.layers[b]);
        assert_eq!(
            (la.out_x, la.out_y, la.out_c),
            (lb.out_x, lb.out_y, lb.out_c),
            "residual add shape mismatch in {}",
            self.name
        );
        let (x, y, c) = (la.out_x, la.out_y, la.out_c);
        self.push(Layer {
            name: name.into(),
            kind: LayerKind::Add,
            inputs: vec![a, b],
            out_x: x,
            out_y: y,
            out_c: c,
        })
    }

    /// Channel concat of several branches (spatial dims must match).
    pub fn concat(&mut self, name: impl Into<String>, parts: &[usize]) -> usize {
        assert!(!parts.is_empty());
        let (x, y) = (self.layers[parts[0]].out_x, self.layers[parts[0]].out_y);
        let mut c = 0;
        for &p in parts {
            assert_eq!(
                (self.layers[p].out_x, self.layers[p].out_y),
                (x, y),
                "concat spatial mismatch in {}",
                self.name
            );
            c += self.layers[p].out_c;
        }
        self.push(Layer {
            name: name.into(),
            kind: LayerKind::Concat,
            inputs: parts.to_vec(),
            out_x: x,
            out_y: y,
            out_c: c,
        })
    }

    /// Indices of weight-bearing layers (conv/FC) in topological (insertion)
    /// order. These are the layers that map onto crossbar tiles.
    pub fn weight_layers(&self) -> Vec<usize> {
        (0..self.layers.len())
            .filter(|&i| self.layers[i].kind.has_weights())
            .collect()
    }

    /// Count of weight-bearing layers.
    pub fn num_weight_layers(&self) -> usize {
        self.weight_layers().len()
    }

    /// Total neurons (paper Fig. 1 x-axis).
    pub fn neurons(&self) -> usize {
        self.layers.iter().map(|l| l.neurons()).sum()
    }

    /// Total weights across the network.
    pub fn total_weights(&self) -> usize {
        self.layers.iter().map(|l| l.weights()).sum()
    }

    /// Total MACs for one inference.
    pub fn total_macs(&self) -> usize {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Bits of one inference request's input frame at `n_bits` precision
    /// (the payload a serving gateway must ship to the chiplet that runs
    /// the first layer).
    pub fn input_bits(&self, n_bits: usize) -> u64 {
        self.layers[0].output_elems() as u64 * n_bits as u64
    }

    /// Bits of one request's result (the last layer's activations) at
    /// `n_bits` precision.
    pub fn output_bits(&self, n_bits: usize) -> u64 {
        let last = self.layers.last().expect("graph always has an input layer");
        last.output_elems() as u64 * n_bits as u64
    }

    /// Input activations consumed by weight layer `li` (paper `A_i`): the
    /// number of activation *elements* that must arrive at layer `li`'s
    /// tiles, i.e. the flattened outputs of its predecessors (transitively
    /// resolving weight-less nodes like pool/add/concat to their source
    /// volume).
    pub fn input_activations(&self, li: usize) -> usize {
        self.layers[li]
            .inputs
            .iter()
            .map(|&p| self.layers[p].output_elems())
            .sum()
    }

    /// Number of structural (layer-level) connections each producer neuron
    /// of layer `li` fans out to, used for the density report: the count of
    /// weight-layer consumers reachable through weight-less nodes.
    fn weight_consumers(&self, li: usize) -> usize {
        let mut count = 0;
        for (j, layer) in self.layers.iter().enumerate() {
            if j == li {
                continue;
            }
            if layer.inputs.contains(&li) {
                if layer.kind.has_weights() {
                    count += 1;
                } else {
                    count += self.weight_consumers(j);
                }
            }
        }
        count
    }

    /// Density metrics (see [`DensityReport`]).
    pub fn density_report(&self) -> DensityReport {
        let neurons = self.neurons();
        let mut structural = 0usize;
        let mut synapse_weighted = 0.0f64;
        for (i, layer) in self.layers.iter().enumerate() {
            let n = layer.neurons();
            if n > 0 {
                // Terminal layers feed the network output: one connection
                // (this is what makes a strictly linear net density 1.0,
                // Fig. 2).
                structural += n * self.weight_consumers(i).max(1);
                synapse_weighted += (n * layer.fan_in()) as f64;
            }
        }
        DensityReport {
            neurons,
            structural_connections: structural,
            structural_density: if neurons == 0 {
                0.0
            } else {
                structural as f64 / neurons as f64
            },
            synaptic_density: if neurons == 0 {
                0.0
            } else {
                synapse_weighted / neurons as f64
            },
        }
    }

    /// Structural sanity checks: DAG order, edge validity, single input.
    pub fn validate(&self) -> Result<(), String> {
        if self.layers.is_empty() || self.layers[0].kind != LayerKind::Input {
            return Err("layer 0 must be the Input node".into());
        }
        for (i, layer) in self.layers.iter().enumerate() {
            if i == 0 {
                if !layer.inputs.is_empty() {
                    return Err("input node must have no predecessors".into());
                }
                continue;
            }
            if layer.inputs.is_empty() {
                return Err(format!("layer {} '{}' has no inputs", i, layer.name));
            }
            for &p in &layer.inputs {
                if p >= i {
                    return Err(format!(
                        "layer {} '{}' references non-topological input {}",
                        i, layer.name, p
                    ));
                }
            }
            if layer.out_x == 0 || layer.out_y == 0 || layer.out_c == 0 {
                return Err(format!("layer {} '{}' has empty output", i, layer.name));
            }
            if let LayerKind::Conv { c_in, c_out, .. } = layer.kind {
                let got: usize = layer.inputs.iter().map(|&p| self.layers[p].out_c).sum();
                // Depthwise convolutions carry c_in = 1 (per-channel filter)
                // with c_out equal to the input channel count.
                let depthwise = c_in == 1 && c_out == got;
                if got != c_in && !depthwise {
                    return Err(format!(
                        "layer {} '{}': c_in {} != sum of input channels {}",
                        i, layer.name, c_in, got
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// input -> conv(16) -> conv(32) -> fc(10): a strictly linear net.
    fn tiny_linear() -> DnnGraph {
        let mut g = DnnGraph::new("tiny", Dataset::Mnist);
        let c1 = g.conv("c1", 0, 3, 16, 1);
        let c2 = g.conv("c2", c1, 3, 32, 2);
        g.fc("fc", c2, 10);
        g
    }

    #[test]
    fn linear_density_is_exactly_one() {
        let g = tiny_linear();
        g.validate().unwrap();
        let r = g.density_report();
        assert_eq!(r.neurons, 16 + 32 + 10);
        // c1 feeds c2; c2 feeds fc; fc feeds the network output.
        assert_eq!(r.structural_connections, 16 + 32 + 10);
        assert!((r.structural_density - 1.0).abs() < 1e-12);
        assert!(r.connection_density() > r.synaptic_density * 0.99);
    }

    #[test]
    fn residual_raises_density() {
        let mut g = DnnGraph::new("res", Dataset::Cifar);
        let c1 = g.conv("c1", 0, 3, 16, 1);
        let c2 = g.conv("c2", c1, 3, 16, 1);
        let add = g.add("add", c1, c2);
        g.conv("c3", add, 3, 16, 1);
        g.validate().unwrap();
        // c1 feeds c2 directly AND c3 through the add -> 2 consumers.
        let r = g.density_report();
        let lin = tiny_linear().density_report();
        assert!(r.structural_density > lin.structural_density);
    }

    #[test]
    fn concat_propagates_channels() {
        let mut g = DnnGraph::new("cat", Dataset::Cifar);
        let a = g.conv("a", 0, 3, 8, 1);
        let b = g.conv("b", a, 3, 8, 1);
        let cat = g.concat("cat", &[a, b]);
        assert_eq!(g.layers[cat].out_c, 16);
        let c = g.conv("c", cat, 1, 4, 1);
        assert_eq!(g.layers[c].out_c, 4);
        g.validate().unwrap();
    }

    #[test]
    fn input_activations_resolve_predecessors() {
        let g = tiny_linear();
        let wl = g.weight_layers();
        // First conv consumes the 28*28*1 input image.
        assert_eq!(g.input_activations(wl[0]), 28 * 28);
        // Second conv consumes c1's 28*28*16 output.
        assert_eq!(g.input_activations(wl[1]), 28 * 28 * 16);
    }

    #[test]
    fn request_payload_bits_hand_computed() {
        let g = tiny_linear();
        // MNIST input frame: 28*28*1 activations at 8 bits each.
        assert_eq!(g.input_bits(8), 28 * 28 * 8);
        // Result payload: the last layer's output activations.
        let last = g.layers.last().unwrap().output_elems() as u64;
        assert_eq!(g.output_bits(8), last * 8);
        assert!(g.output_bits(8) > 0);
    }

    #[test]
    fn validate_catches_bad_graphs() {
        let mut g = tiny_linear();
        g.layers[2].inputs = vec![5]; // forward reference
        assert!(g.validate().is_err());

        let mut g2 = tiny_linear();
        if let LayerKind::Conv { ref mut c_in, .. } = g2.layers[2].kind {
            *c_in = 999;
        }
        assert!(g2.validate().is_err());
    }

    #[test]
    fn stride_and_pool_shapes() {
        let mut g = DnnGraph::new("s", Dataset::ImageNet);
        let c = g.conv("c", 0, 7, 64, 2); // 224 -> 112
        assert_eq!(g.layers[c].out_x, 112);
        let p = g.pool("p", c, 3, 2); // 112 -> 56
        assert_eq!(g.layers[p].out_x, 56);
        let gp = g.global_pool("gp", p);
        assert_eq!(
            (g.layers[gp].out_x, g.layers[gp].out_y, g.layers[gp].out_c),
            (1, 1, 64)
        );
    }
}
