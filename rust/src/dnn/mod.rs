//! DNN workload specification: layer graphs (DAGs), neuron and
//! connection-density accounting (paper Fig. 1/2), and a model zoo covering
//! every network the paper evaluates (MLP, LeNet-5, NiN, SqueezeNet,
//! VGG-16/19, ResNet-50/152, DenseNet-40/100/121).
//!
//! Only quantities that drive the hardware study are modeled: layer shapes,
//! kernel sizes, channel counts, and inter-layer connectivity (including
//! residual skips and dense concatenations). Weights/pixel values never
//! matter here — the interconnect study depends on data *volumes* (Eq. 3).

pub mod graph;
pub mod layer;
pub mod models;

pub use graph::{DensityReport, DnnGraph};
pub use layer::{Layer, LayerKind};

/// Dataset a model is defined for (sets the input resolution; Fig. 1 groups
/// models by dataset).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// 28×28×1 grayscale digits.
    Mnist,
    /// 32×32×3 natural images.
    Cifar,
    /// 224×224×3 natural images.
    ImageNet,
}

impl Dataset {
    /// (height, width, channels) of one input frame.
    pub fn input_dims(self) -> (usize, usize, usize) {
        match self {
            Dataset::Mnist => (28, 28, 1),
            Dataset::Cifar => (32, 32, 3),
            Dataset::ImageNet => (224, 224, 3),
        }
    }

    /// Canonical display name.
    pub fn name(self) -> &'static str {
        match self {
            Dataset::Mnist => "MNIST",
            Dataset::Cifar => "CIFAR",
            Dataset::ImageNet => "ImageNet",
        }
    }
}

/// The six representative DNNs of the paper's evaluation (§6.4), in the
/// order every figure reports them: three low-connection-density networks
/// (MLP, LeNet-5, NiN) then three high-density ones (ResNet-50, VGG-19,
/// DenseNet-100).
pub fn eval_set() -> Vec<DnnGraph> {
    vec![
        models::mlp(),
        models::lenet5(),
        models::nin(),
        models::resnet(50),
        models::vgg(19),
        models::densenet(100),
    ]
}

/// The full zoo (Fig. 1 scatter + §5.2 crossbar-size study set).
pub fn model_zoo() -> Vec<DnnGraph> {
    vec![
        models::mlp(),
        models::lenet5(),
        models::nin(),
        models::squeezenet(),
        models::mobilenet(),
        models::alexnet(),
        models::vgg(11),
        models::vgg(13),
        models::vgg(16),
        models::vgg(19),
        models::resnet(18),
        models::resnet(34),
        models::resnet(50),
        models::resnet(101),
        models::resnet(152),
        models::densenet(40),
        models::densenet(100),
        models::densenet(121),
    ]
}

/// The zoo model names, comma-joined, for "unknown DNN" error messages
/// (mirrors `Topology::valid_names` for topologies).
pub fn valid_names() -> String {
    model_zoo()
        .iter()
        .map(|m| m.name.as_str())
        .collect::<Vec<_>>()
        .join(", ")
}

/// Look a zoo model up by name, ignoring case and separators — "VGG-19",
/// "vgg_19" and "vgg19" all resolve.
pub fn by_name(name: &str) -> Option<DnnGraph> {
    let canon = |s: &str| -> String {
        s.chars()
            .filter(|c| c.is_ascii_alphanumeric())
            .map(|c| c.to_ascii_lowercase())
            .collect()
    };
    let want = canon(name);
    model_zoo().into_iter().find(|m| canon(&m.name) == want)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_builds_and_validates() {
        for m in model_zoo() {
            m.validate().unwrap_or_else(|e| panic!("{}: {e}", m.name));
            assert!(m.num_weight_layers() > 0, "{} has no weight layers", m.name);
        }
    }

    #[test]
    fn eval_set_order_matches_paper() {
        let names: Vec<_> = eval_set().iter().map(|m| m.name.clone()).collect();
        assert_eq!(
            names,
            vec!["MLP", "LeNet-5", "NiN", "ResNet-50", "VGG-19", "DenseNet-100"]
        );
    }

    #[test]
    fn valid_names_lists_whole_zoo() {
        let names = valid_names();
        for m in model_zoo() {
            assert!(names.contains(&m.name), "{} missing from {names}", m.name);
        }
    }

    #[test]
    fn by_name_variants() {
        assert!(by_name("VGG-19").is_some());
        assert!(by_name("vgg_19").is_some());
        assert!(by_name("vgg19").is_some());
        assert!(by_name("DenseNet100").is_some());
        assert!(by_name("densenet-100").is_some());
        assert!(by_name("nonexistent-net").is_none());
    }

    #[test]
    fn density_ordering_matches_fig1() {
        // Linear nets have structural density 1.0; residual slightly above;
        // dense structures well above (paper Fig. 2).
        let lin = models::vgg(19).density_report().structural_density;
        let res = models::resnet(50).density_report().structural_density;
        let den = models::densenet(100).density_report().structural_density;
        assert!((lin - 1.0).abs() < 1e-9, "VGG-19 structural density {lin}");
        assert!(res > 1.0 && res < 4.0, "ResNet-50 {res}");
        assert!(den > res, "DenseNet-100 {den} should exceed ResNet {res}");
    }
}
