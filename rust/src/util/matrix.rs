//! Minimal dense f64 matrix with the operations the analytical NoC model
//! needs: multiply, add/sub, scalar scale, LU decomposition with partial
//! pivoting, inverse, and linear solve. Row-major storage.
//!
//! Eq. 8 of the paper, `N = (I - tΛC)^{-1} Λ R`, requires a 5×5 inverse per
//! router; we keep the implementation general (n×n) so the same code backs
//! unit tests and larger aggregate systems.

use std::fmt;
use std::ops::{Add, Index, IndexMut, Mul, Sub};

/// Dense row-major f64 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zero `rows` × `cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// The n × n identity.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from row slices; panics on ragged input.
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut m = Self::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    /// Diagonal matrix from a vector.
    pub fn diag(d: &[f64]) -> Self {
        let mut m = Self::zeros(d.len(), d.len());
        for (i, &v) in d.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Scalar multiple of the matrix.
    pub fn scale(&self, s: f64) -> Matrix {
        let mut out = self.clone();
        for v in &mut out.data {
            *v *= s;
        }
        out
    }

    /// Matrix transpose.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// LU decomposition with partial pivoting. Returns (LU, perm, sign) or
    /// `None` if the matrix is singular to working precision.
    fn lu(&self) -> Option<(Matrix, Vec<usize>, f64)> {
        assert_eq!(self.rows, self.cols, "LU requires square matrix");
        let n = self.rows;
        let mut lu = self.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // Pivot selection.
            let mut pivot = k;
            let mut maxval = lu[(k, k)].abs();
            for i in (k + 1)..n {
                let v = lu[(i, k)].abs();
                if v > maxval {
                    maxval = v;
                    pivot = i;
                }
            }
            if maxval < 1e-300 {
                return None;
            }
            if pivot != k {
                for j in 0..n {
                    let tmp = lu[(k, j)];
                    lu[(k, j)] = lu[(pivot, j)];
                    lu[(pivot, j)] = tmp;
                }
                perm.swap(k, pivot);
                sign = -sign;
            }
            let pivval = lu[(k, k)];
            for i in (k + 1)..n {
                let f = lu[(i, k)] / pivval;
                lu[(i, k)] = f;
                for j in (k + 1)..n {
                    lu[(i, j)] -= f * lu[(k, j)];
                }
            }
        }
        Some((lu, perm, sign))
    }

    /// Solve `self * x = b` for x. `None` if singular.
    pub fn solve(&self, b: &[f64]) -> Option<Vec<f64>> {
        let n = self.rows;
        assert_eq!(b.len(), n);
        let (lu, perm, _) = self.lu()?;
        // Forward substitution with permuted b.
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut acc = b[perm[i]];
            for j in 0..i {
                acc -= lu[(i, j)] * y[j];
            }
            y[i] = acc;
        }
        // Back substitution.
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut acc = y[i];
            for j in (i + 1)..n {
                acc -= lu[(i, j)] * x[j];
            }
            x[i] = acc / lu[(i, i)];
        }
        Some(x)
    }

    /// Matrix inverse via LU. `None` if singular.
    pub fn inverse(&self) -> Option<Matrix> {
        let n = self.rows;
        let (lu, perm, _) = self.lu()?;
        let mut inv = Matrix::zeros(n, n);
        let mut col = vec![0.0; n];
        for c in 0..n {
            // Solve A x = e_c reusing the factorization.
            for i in 0..n {
                let mut acc = if perm[i] == c { 1.0 } else { 0.0 };
                for j in 0..i {
                    acc -= lu[(i, j)] * col[j];
                }
                col[i] = acc;
            }
            for i in (0..n).rev() {
                let mut acc = col[i];
                for j in (i + 1)..n {
                    acc -= lu[(i, j)] * inv[(j, c)];
                }
                inv[(i, c)] = acc / lu[(i, i)];
            }
        }
        Some(inv)
    }

    /// Determinant via LU (0 for singular matrices).
    pub fn determinant(&self) -> f64 {
        match self.lu() {
            None => 0.0,
            Some((lu, _, sign)) => {
                let mut det = sign;
                for i in 0..self.rows {
                    det *= lu[(i, i)];
                }
                det
            }
        }
    }

    /// Max absolute entry — convenient for convergence/validity checks.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }

    /// Spectral-radius upper bound via the infinity norm (max row sum).
    /// Used to check the stability condition of the queueing fixed point.
    pub fn inf_norm(&self) -> f64 {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|v| v.abs()).sum::<f64>())
            .fold(0.0f64, f64::max)
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl Mul<&Matrix> for &Matrix {
    type Output = Matrix;
    fn mul(self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.cols, rhs.rows, "dimension mismatch");
        let mut out = Matrix::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..rhs.cols {
                    out[(i, j)] += a * rhs[(k, j)];
                }
            }
        }
        out
    }
}

impl Add<&Matrix> for &Matrix {
    type Output = Matrix;
    fn add(self, rhs: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&rhs.data) {
            *a += b;
        }
        out
    }
}

impl Sub<&Matrix> for &Matrix {
    type Output = Matrix;
    fn sub(self, rhs: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&rhs.data) {
            *a -= b;
        }
        out
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows {
            writeln!(f, "  {:?}", self.row(i))?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: &Matrix, b: &Matrix, tol: f64) -> bool {
        (a - b).max_abs() < tol
    }

    #[test]
    fn multiply_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = &a * &b;
        assert_eq!(c, Matrix::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn inverse_roundtrip() {
        let a = Matrix::from_rows(&[&[4.0, 7.0, 2.0], &[2.0, 6.0, 1.0], &[1.0, 1.0, 9.0]]);
        let inv = a.inverse().unwrap();
        assert!(approx(&(&a * &inv), &Matrix::identity(3), 1e-10));
        assert!(approx(&(&inv * &a), &Matrix::identity(3), 1e-10));
    }

    #[test]
    fn inverse_with_pivoting_needed() {
        // Leading zero forces a row swap.
        let a = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let inv = a.inverse().unwrap();
        assert!(approx(&inv, &a, 1e-12));
    }

    #[test]
    fn singular_detected() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(a.inverse().is_none());
        assert_eq!(a.determinant(), 0.0);
    }

    #[test]
    fn solve_matches_inverse() {
        let a = Matrix::from_rows(&[&[3.0, 1.0], &[1.0, 2.0]]);
        let x = a.solve(&[9.0, 8.0]).unwrap();
        assert!((x[0] - 2.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn determinant_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert!((a.determinant() + 2.0).abs() < 1e-12);
    }

    #[test]
    fn matvec_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
    }

    #[test]
    fn diag_and_norms() {
        let d = Matrix::diag(&[1.0, -3.0]);
        assert_eq!(d.max_abs(), 3.0);
        assert_eq!(d.inf_norm(), 3.0);
        assert_eq!(d.transpose(), d);
    }
}
