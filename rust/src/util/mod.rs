//! Small self-contained utilities: deterministic PRNGs, statistics helpers,
//! a dense-matrix type with LU inversion (needed by the analytical NoC model,
//! Eq. 8 of the paper), table rendering for experiment output, a leveled
//! stderr logger, and a tiny hand-rolled property-testing harness (no
//! external crates are available in the offline build environment).

pub mod log;
pub mod matrix;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod table;

pub use matrix::Matrix;
pub use prng::{Pcg32, SplitMix64};
pub use stats::{geomean, mean, percentile, stddev};
pub use table::Table;

/// Format a float with engineering-friendly precision for experiment tables.
pub fn fmt_sig(v: f64, sig: usize) -> String {
    if v == 0.0 || !v.is_finite() {
        return format!("{v}");
    }
    let mag = v.abs().log10().floor() as i32;
    let dec = (sig as i32 - 1 - mag).max(0) as usize;
    if mag >= 6 || mag <= -4 {
        format!("{v:.prec$e}", prec = sig.saturating_sub(1))
    } else {
        format!("{v:.dec$}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_sig_magnitudes() {
        assert_eq!(fmt_sig(0.0, 3), "0");
        assert_eq!(fmt_sig(1234.5, 3), "1234"); // mag 3 < 6 -> fixed, 0 decimals
        assert_eq!(fmt_sig(0.0123, 3), "0.0123");
        assert!(fmt_sig(1.0e9, 3).contains('e'));
        assert!(fmt_sig(1.0e-7, 3).contains('e'));
    }
}
