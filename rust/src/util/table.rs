//! ASCII table + CSV rendering for experiment output. Every `repro figure N`
//! / `repro table N` command prints one of these, matching the rows/series
//! the paper reports.

/// One titled result table: fixed headers, string cells.
#[derive(Clone, Debug)]
pub struct Table {
    /// Caption printed above the table.
    pub title: String,
    /// Column names; every row must match this width.
    pub headers: Vec<String>,
    /// Row-major cells, already formatted.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with the given title and column headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row; panics if its width does not match the headers.
    pub fn add_row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width must match headers"
        );
        self.rows.push(cells);
    }

    /// Convenience: row from display-able values.
    pub fn row<D: std::fmt::Display>(&mut self, cells: &[D]) {
        self.add_row(cells.iter().map(|c| c.to_string()).collect());
    }

    /// Render headers + rows as plain CSV (no quoting — cells are numeric
    /// or simple names).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Render as a boxed ASCII table with column-width alignment.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(widths[i] - cells[i].len() + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_csv() {
        let mut t = Table::new("demo", &["dnn", "edap"]);
        t.row(&["VGG-19", "0.28"]);
        t.row(&["MLP", "0.01"]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("VGG-19"));
        assert!(r.lines().all(|l| l.starts_with('+') || l.starts_with('|') || l.starts_with("==")));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("dnn,edap"));
    }

    #[test]
    #[should_panic]
    fn ragged_row_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.add_row(vec!["only-one".into()]);
    }
}
