//! Deterministic pseudo-random number generators.
//!
//! The offline build has no `rand` crate, so we implement two small,
//! well-known generators ourselves:
//!
//! * [`SplitMix64`] — used for seeding and for cheap stateless hashing.
//! * [`Pcg32`] — the simulation workhorse (PCG-XSH-RR 64/32). Every
//!   cycle-accurate NoC run is seeded explicitly so experiments are
//!   bit-reproducible.

/// SplitMix64 (Steele, Lea, Flood 2014). Passes BigCrush; primarily used
/// here to expand a single `u64` seed into independent stream seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Start a generator at `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32 (O'Neill 2014): 64-bit state, 32-bit output.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// The LCG multiplier of the reference PCG implementation.
    pub const MULT: u64 = 6364136223846793005;

    /// Construct from a seed and a stream id (any values are fine).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed via SplitMix64 so nearby seeds yield unrelated streams.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self::new(sm.next_u64(), sm.next_u64())
    }

    /// Next 32-bit output (the generator's native step).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 bits (two native steps).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) via Lemire's multiply-shift rejection.
    #[inline]
    pub fn next_below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let low = m as u32;
            if low >= bound || low >= (bound.wrapping_neg() % bound) {
                return (m >> 32) as u32;
            }
        }
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 (from the public-domain C code).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn pcg_determinism_and_range() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::seeded(43);
        let same = (0..100).filter(|_| a.next_u32() == c.next_u32()).count();
        assert!(same < 5, "different seeds should diverge");
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut rng = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn next_below_is_uniform_enough() {
        let mut rng = Pcg32::seeded(9);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[rng.next_below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn bernoulli_matches_p() {
        let mut rng = Pcg32::seeded(11);
        let hits = (0..100_000).filter(|_| rng.bernoulli(0.3)).count();
        assert!((28_000..32_000).contains(&hits));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
