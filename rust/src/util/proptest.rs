//! Tiny hand-rolled property-testing harness (the offline environment ships
//! no `proptest`/`quickcheck`). A property is a closure over a [`Gen`]
//! source; we run it for a configurable number of deterministic cases and,
//! on failure, report the case index and seed so it can be replayed exactly.
//!
//! There is no shrinking — cases are seeded independently, so re-running a
//! single failing seed is cheap and deterministic.

use super::prng::Pcg32;

/// Random value source handed to each property case.
pub struct Gen {
    rng: Pcg32,
    /// Index of the current case within the property run.
    pub case: usize,
    /// Exact seed of this case — quote it to replay a failure.
    pub seed: u64,
}

impl Gen {
    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u32) as usize
    }

    /// Uniform 64-bit value.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// Uniformly pick one element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// `len` uniform floats in `[lo, hi)`.
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Direct access to the underlying generator for ad-hoc draws.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `cases` deterministic property cases. The property returns
/// `Err(message)` to fail. Panics with a replayable report on failure.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check_seeded(name, 0xC0FFEE, cases, &mut prop);
}

/// Like [`check`] but with an explicit base seed (used to replay failures).
pub fn check_seeded<F>(name: &str, base_seed: u64, cases: usize, prop: &mut F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut gen = Gen {
            rng: Pcg32::seeded(seed),
            case,
            seed,
        };
        if let Err(msg) = prop(&mut gen) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay: check_seeded(\"{name}\", {seed:#x}, 1, ..)"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("sum-commutes", 64, |g| {
            n += 1;
            let a = g.f64_in(-1e3, 1e3);
            let b = g.f64_in(-1e3, 1e3);
            if (a + b - (b + a)).abs() < 1e-12 {
                Ok(())
            } else {
                Err("not commutative".into())
            }
        });
        assert_eq!(n, 64);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 8, |_| Err("boom".into()));
    }

    #[test]
    fn gen_ranges_hold() {
        check("gen-ranges", 128, |g| {
            let u = g.usize_in(3, 9);
            if !(3..=9).contains(&u) {
                return Err(format!("usize_in out of range: {u}"));
            }
            let f = g.f64_in(-2.0, 2.0);
            if !(-2.0..2.0).contains(&f) {
                return Err(format!("f64_in out of range: {f}"));
            }
            let v = g.vec_f64(4, 0.0, 1.0);
            if v.len() != 4 || v.iter().any(|x| !(0.0..1.0).contains(x)) {
                return Err("vec_f64 broken".into());
            }
            Ok(())
        });
    }
}
