//! Tiny leveled stderr logger — no external crates in the offline build.
//!
//! Diagnostics that previously went through ad-hoc `eprintln!` calls now
//! route through the `log_warn!`/`log_info!`/`log_debug!`/`log_trace!`
//! macros (exported at the crate root, as `#[macro_export]` requires, and
//! re-exported here as `log::warn!` etc.), filtered by a global
//! level. The level comes from the `REPRO_LOG` environment variable
//! (`warn`, `info`, `debug` or `trace`; read once, lazily) and composes
//! with the CLI: `--verbose` raises the level to at least
//! [`Level::Debug`] via [`set_level`] but never *lowers* a more verbose
//! `REPRO_LOG=trace`. Messages print to stderr as `[   1.234s warn] …` —
//! seconds elapsed since the first log call plus the level — so
//! long-running serving sweeps can be read as a timeline while machine
//! output on stdout (tables, JSON) stays clean.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ordered: `Warn < Info < Debug < Trace`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Something is off but the run continues (fallbacks, clamps).
    Warn = 1,
    /// High-level progress worth seeing by default.
    Info = 2,
    /// Per-step detail for debugging runs.
    Debug = 3,
    /// Per-hop firehose (e.g. the attribution hook's ingress-wait lines);
    /// only via `REPRO_LOG=trace` — `--verbose` stops at Debug.
    Trace = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }

    fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "warn" | "warning" | "error" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

/// 0 = uninitialized (read `REPRO_LOG` on first use), else a `Level`.
static LEVEL: AtomicU8 = AtomicU8::new(0);

fn env_level() -> Level {
    std::env::var("REPRO_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info)
}

/// Current filter level, initializing from `REPRO_LOG` on first call.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        4 => Level::Trace,
        _ => {
            let l = env_level();
            LEVEL.store(l as u8, Ordering::Relaxed);
            l
        }
    }
}

/// Override the filter level (e.g. `--verbose` → [`Level::Debug`]).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// `true` if a message at `l` would print — lets callers skip building
/// expensive log strings.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Process-relative clock epoch: set by the first log call (not process
/// start — a `OnceLock<Instant>` is the only portable zero-dependency
/// anchor), so the first line reads `0.000s` and later lines measure
/// elapsed wall time from there.
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Seconds elapsed since the first log call.
pub fn elapsed_s() -> f64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Print one formatted line to stderr; prefer the level macros.
pub fn emit(l: Level, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{:>8.3}s {}] {}", elapsed_s(), l.tag(), msg);
    }
}

/// Log at [`Level::Warn`] with `format!` syntax.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at [`Level::Info`] with `format!` syntax.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Info, format_args!($($arg)*))
    };
}

/// Log at [`Level::Debug`] with `format!` syntax.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Debug, format_args!($($arg)*))
    };
}

/// Log at [`Level::Trace`] with `format!` syntax.
#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Trace, format_args!($($arg)*))
    };
}

pub use crate::{log_debug as debug, log_info as info, log_trace as trace, log_warn as warn};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_parse() {
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("trace"), Some(Level::Trace));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn verbose_composition_never_downgrades() {
        // The CLI composes `--verbose` as max(current, Debug): a more
        // verbose REPRO_LOG=trace must survive the flag.
        set_level(Level::Trace);
        set_level(level().max(Level::Debug));
        assert_eq!(level(), Level::Trace);
        // And a quieter default is raised to Debug.
        set_level(Level::Info);
        set_level(level().max(Level::Debug));
        assert_eq!(level(), Level::Debug);
        set_level(Level::Info);
    }

    #[test]
    fn set_level_filters() {
        set_level(Level::Warn);
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        // Restore the default so other tests see stock behavior.
        set_level(Level::Info);
    }

    #[test]
    fn elapsed_clock_is_monotonic() {
        let a = elapsed_s();
        let b = elapsed_s();
        assert!(a >= 0.0);
        assert!(b >= a);
    }
}
