//! Descriptive statistics used by the simulators and experiment harness.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Geometric mean of strictly positive samples; 0.0 for empty input.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Linear-interpolated percentile, `p` in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Mean absolute percentage deviation of `worst` from `avg` over pairs with
/// non-zero `avg` — Eq. 12 of the paper (used by Table 3).
pub fn mapd(avg: &[f64], worst: &[f64]) -> f64 {
    assert_eq!(avg.len(), worst.len());
    let mut n = 0usize;
    let mut acc = 0.0;
    for (&a, &w) in avg.iter().zip(worst) {
        if a > 0.0 {
            acc += (w - a) / a;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        100.0 * acc / n as f64
    }
}

/// Online accumulator for mean/max/count without storing samples.
#[derive(Clone, Debug, Default)]
pub struct Accum {
    /// Number of samples added.
    pub count: u64,
    /// Running sum of all samples.
    pub sum: f64,
    /// Largest sample seen (−∞ when empty).
    pub max: f64,
    /// Smallest sample seen (+∞ when empty).
    pub min: f64,
}

impl Accum {
    /// An empty accumulator.
    pub fn new() -> Self {
        Self {
            count: 0,
            sum: 0.0,
            max: f64::NEG_INFINITY,
            min: f64::INFINITY,
        }
    }

    /// Fold one sample into the running statistics.
    #[inline]
    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
        if x < self.min {
            self.min = x;
        }
    }

    /// Sample mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn mapd_matches_eq12() {
        // avg = [2, 4], worst = [3, 4] -> deviations 50% and 0% -> 25%.
        let v = mapd(&[2.0, 4.0], &[3.0, 4.0]);
        assert!((v - 25.0).abs() < 1e-12);
        // zero-average pairs are excluded.
        let v = mapd(&[0.0, 4.0], &[9.0, 5.0]);
        assert!((v - 25.0).abs() < 1e-12);
    }

    #[test]
    fn accum_tracks_extremes() {
        let mut a = Accum::new();
        for x in [3.0, 1.0, 2.0] {
            a.add(x);
        }
        assert_eq!(a.count, 3);
        assert_eq!(a.max, 3.0);
        assert_eq!(a.min, 1.0);
        assert!((a.mean() - 2.0).abs() < 1e-12);
    }
}
