//! `repro` — the Layer-3 coordinator binary. See `repro help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = imcnoc::cli::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
