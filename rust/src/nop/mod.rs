//! Network-on-Package (NoP): the second interconnect hierarchy level.
//!
//! The paper studies the *on-chip* interconnect of a single IMC chip. Its
//! own scaling argument — connection density drives communication cost —
//! bites hardest when a DNN no longer fits on one chip: 2.5D packages of
//! IMC chiplets (SIMBA-class) move the bottleneck to the package-level
//! links. This subsystem models exactly that:
//!
//! * [`topology`] — chiplet-level link graphs (dedicated P2P links, ring,
//!   2-D mesh on the interposer) with deterministic routing, mirroring
//!   [`crate::noc::topology`] one level up.
//! * [`sim`] — an event-driven, flit-level simulator for the package graph
//!   (SerDes serialization, fixed hop latency, credit-based flow control),
//!   sharing the [`crate::noc::sim`] vocabulary so both levels compose.
//! * [`evaluator`] — hierarchical evaluation: every chiplet runs the
//!   *existing* per-chip NoC machinery (analytical model or cycle-accurate
//!   simulator, unchanged) over its local tiles, and cross-chiplet traffic
//!   — derived from [`crate::mapping::ChipletPartition`] — rides the NoP
//!   analytically, through the flit-level simulator (`[nop] mode = sim`,
//!   [`crate::config::NopConfig`]), or through the sim-anchored surrogate
//!   curves of [`crate::sim::surrogate`] (`[nop] mode = surrogate`).
//!
//! The joint (chiplet count, NoP topology, per-chiplet NoC topology)
//! advisor lives in [`crate::arch::optimizer`].

pub mod evaluator;
pub mod sim;
pub mod topology;

pub use evaluator::{evaluate_package, nop_transfer_cycles, NopEvaluation};
pub use sim::{saturation_rate, saturation_rate_scan, uniform_nop_flows, NopAudit, NopSim};
pub use topology::{NopNetwork, NopTopology};
