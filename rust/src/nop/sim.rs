//! Event-driven, flit-level Network-on-Package simulation — the package
//! mirror of [`crate::noc::sim`], specialized for SerDes-class channels.
//! Like the NoC simulator it is a thin fabric adapter over the shared
//! [`crate::sim::engine`] event core, which owns traffic generation, the
//! run loops and all statistics.
//!
//! Package links differ from on-chip NoC links in three ways the analytical
//! model of [`crate::nop::evaluator`] cannot see under load:
//!
//! * **Serialization** — a link moves one `link_width`-bit NoP flit per NoP
//!   cycle, so a bundle of `F` flits occupies its first link for `F` cycles
//!   and competing bundles queue behind it.
//! * **Fixed hop latency** — every traversal adds `hop_latency_cycles`
//!   (SerDes TX + package trace + RX). The engine is event-driven: when all
//!   traffic is mid-flight the drain clock jumps straight to the next
//!   arrival instead of stepping through the latency gap cycle by cycle
//!   (the fabric reports [`queued_work`](crate::sim::engine) /
//!   `next_arrival` to the shared run loop).
//! * **Credit-based flow control** — every directed link owns a
//!   `buffer_flits`-deep virtual receive buffer at its downstream node
//!   (plus one injection buffer per chiplet). A sender consumes one
//!   downstream credit per flit — returned when the flit leaves that
//!   buffer, so credits also cover in-flight traffic — and stalls at zero.
//!   Flits *entering* a directional chain (injection, X→Y turns) must
//!   leave one slot free in the target buffer; straight-through transit
//!   needs a single credit. This is bubble flow control: each directional
//!   ring/row/column keeps a circulating bubble, which makes
//!   shortest-direction rings and X-Y meshes deadlock-free without
//!   virtual channels.
//!
//! The simulator shares the [`FlowSpec`]/[`Mode`]/[`SimStats`] vocabulary
//! with the per-chip simulator so `nop::evaluator` can compose the two
//! engines into one hierarchical co-simulation: per-chiplet `NocSim` runs
//! below, `NopSim` runs the package graph above, fed by the inter-chiplet
//! injection matrix of [`crate::mapping::ChipletPartition`]. All times are
//! **NoP cycles**; callers convert with the clock ratio.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::config::NopConfig;
use crate::nop::topology::{NopNetwork, NopTopology};
use crate::sim::engine::{run_engine, EngineCore, Fabric};
use crate::sim::memo::memo_saturation;
use crate::telemetry::SimTelemetry;

pub use crate::sim::engine::{FlowSpec, Mode, SimStats};

/// Upstream marker for injection buffers (no inbound link).
const LOCAL: usize = usize::MAX;

/// One NoP flit in flight. `born` is the NoP cycle the flit was generated
/// at its source chiplet (source-queue wait counts toward latency).
#[derive(Clone, Copy, Debug)]
struct NopFlit {
    src: u32,
    dst: u32,
    born: u64,
}

/// Post-run flow-control audit, for the credit-invariant property tests.
#[derive(Clone, Debug)]
pub struct NopAudit {
    /// Credits each virtual receive buffer started with (`buffer_flits`).
    pub capacity: i64,
    /// Credits left per buffer after the run (== `capacity` after a drain).
    pub credits: Vec<i64>,
    /// Lowest credit count observed anywhere at any time (never < 0).
    pub min_credit: i64,
}

/// The package fabric: SerDes links, virtual receive buffers, credits and
/// the in-flight arrival queue — everything the shared engine core knows
/// nothing about.
struct NopFabric {
    net: NopNetwork,
    cfg: NopConfig,
    /// Virtual receive buffers: one per directed link, then one injection
    /// buffer per node (id = `injection_base + node`).
    bufs: Vec<VecDeque<NopFlit>>,
    /// Free slots per buffer. Signed so the audit can prove non-negativity
    /// instead of relying on unsigned wrap-around panics.
    credits: Vec<i64>,
    min_credit: i64,
    /// Directed link (from, to) → its buffer id. Lookup only — iteration
    /// always goes through the deterministic `in_bufs` lists.
    link_buf: HashMap<(usize, usize), usize>,
    /// (upstream, node) per buffer; upstream == LOCAL for injection bufs.
    buf_edge: Vec<(usize, usize)>,
    /// Buffers feeding each node, in deterministic order.
    in_bufs: Vec<Vec<usize>>,
    /// Round-robin scan offset per node (arbitration fairness).
    rr: Vec<usize>,
    /// Earliest cycle each link buffer may start another flit (per-link
    /// serialization; unused for injection buffers).
    link_free: Vec<u64>,
    /// Earliest cycle each node's local SerDes RX may eject another flit.
    eject_free: Vec<u64>,
    /// In-flight flits as (arrival cycle, buffer id, flit). Hop latency is
    /// uniform, so send order == arrival order and a FIFO replaces a heap.
    arrivals: VecDeque<(u64, usize, NopFlit)>,
}

/// The flit-level package simulator: a shared [`EngineCore`] plus the
/// package [`NopFabric`].
pub struct NopSim {
    core: EngineCore,
    fab: NopFabric,
}

impl NopSim {
    /// Build a simulator for `k` chiplets on `topology`. Flow endpoints are
    /// chiplet ids (`< k`); self-flows never enter the package network.
    pub fn new(
        topology: NopTopology,
        k: usize,
        cfg: &NopConfig,
        flows: &[FlowSpec],
        mode: Mode,
        seed: u64,
    ) -> Self {
        let net = NopNetwork::build(topology, k);

        // Enumerate every directed link deterministic routing can use, in
        // sorted order (deterministic buffer ids).
        let mut links: Vec<(usize, usize)> = Vec::new();
        let mut seen: HashSet<(usize, usize)> = HashSet::new();
        for a in 0..net.nodes {
            for d in 0..net.chiplets {
                if d == a {
                    continue;
                }
                let b = net.route_next(a, d);
                if seen.insert((a, b)) {
                    links.push((a, b));
                }
            }
        }
        links.sort_unstable();
        let injection_base = links.len();
        let n_bufs = links.len() + net.nodes;

        let mut link_buf = HashMap::new();
        let mut buf_edge = vec![(LOCAL, 0usize); n_bufs];
        let mut in_bufs: Vec<Vec<usize>> = vec![Vec::new(); net.nodes];
        for (id, &(a, b)) in links.iter().enumerate() {
            link_buf.insert((a, b), id);
            buf_edge[id] = (a, b);
            in_bufs[b].push(id);
        }
        for n in 0..net.nodes {
            buf_edge[injection_base + n] = (LOCAL, n);
            in_bufs[n].push(injection_base + n);
        }

        let core = EngineCore::new(k, flows, mode, seed);
        let nodes = net.nodes;
        Self {
            core,
            fab: NopFabric {
                net,
                cfg: cfg.clone(),
                bufs: vec![VecDeque::new(); n_bufs],
                credits: vec![cfg.buffer_flits as i64; n_bufs],
                min_credit: cfg.buffer_flits as i64,
                link_buf,
                buf_edge,
                in_bufs,
                rr: vec![0; nodes],
                link_free: vec![0; n_bufs],
                eject_free: vec![0; nodes],
                arrivals: VecDeque::new(),
            },
        }
    }

    /// Enable per-pair latency tracking.
    pub fn track_pairs(mut self, on: bool) -> Self {
        self.core.track_pairs = on;
        self
    }

    /// Arm the per-flow attribution hook: count head-of-line blocked
    /// flit-cycles per (src, dst) flow into
    /// [`SimStats::flow_waits`]. Purely observational — simulated
    /// outcomes (makespan, latency, delivery) are identical either way.
    pub fn attribute(mut self, on: bool) -> Self {
        self.core.attrib = on;
        self
    }

    /// Collect per-link flit counters, per-chiplet injection/ejection
    /// counters and buffer-occupancy telemetry while running (returned by
    /// [`NopSim::run_instrumented`]). Off by default: the disabled path
    /// costs one branch per hook site and allocates nothing.
    pub fn instrument(mut self, on: bool) -> Self {
        if !on {
            self.core.telem = None;
            return self;
        }
        // Link buffer id == telemetry link index: both follow the sorted
        // link enumeration of `new`, so `forward` can index directly.
        let injection_base = self.fab.bufs.len() - self.fab.net.nodes;
        let links: Vec<(usize, usize)> = self.fab.buf_edge[..injection_base].to_vec();
        self.core.telem = Some(Box::new(SimTelemetry::sized(
            links,
            self.core.sources.len(),
        )));
        self
    }

    /// Run to completion per the configured mode.
    pub fn run(self) -> SimStats {
        self.run_all().0
    }

    /// Like [`run`](Self::run), also returning the flow-control audit.
    pub fn run_audited(self) -> (SimStats, NopAudit) {
        let (stats, audit, _) = self.run_all();
        (stats, audit)
    }

    /// Like [`run`](Self::run), also returning the collected telemetry
    /// (empty unless built with [`NopSim::instrument`]).
    pub fn run_instrumented(self) -> (SimStats, SimTelemetry) {
        let (stats, _, telem) = self.run_all();
        (stats, telem)
    }

    fn run_all(mut self) -> (SimStats, NopAudit, SimTelemetry) {
        run_engine(&mut self.core, &mut self.fab);
        let telem = self.core.take_telem();
        let audit = NopAudit {
            capacity: self.fab.cfg.buffer_flits as i64,
            credits: self.fab.credits,
            min_credit: self.fab.min_credit,
        };
        (self.core.stats, audit, telem)
    }
}

impl Fabric for NopFabric {
    fn step(&mut self, core: &mut EngineCore) {
        self.process_arrivals(core);
        self.inject(core);
        self.forward(core);
    }

    /// Is any flit sitting in a buffer or source queue (i.e. work may be
    /// possible next cycle, as opposed to everything being mid-flight)?
    fn queued_work(&self, core: &EngineCore) -> bool {
        self.bufs.iter().any(|q| !q.is_empty())
            || core
                .sources
                .iter()
                .any(|s| !s.fifo.is_empty() || !s.pending.is_empty())
    }

    fn next_arrival(&self) -> Option<u64> {
        self.arrivals.front().map(|&(t, _, _)| t)
    }
}

impl NopFabric {
    /// Does a flit that entered `node` from `upstream` keep its direction
    /// when forwarded to `next`? Straight-through transit rides an existing
    /// directional chain and needs a single credit; everything else
    /// (injection, turns) enters a chain and must preserve its bubble.
    fn same_direction(&self, upstream: usize, node: usize, next: usize) -> bool {
        match self.net.topology {
            NopTopology::P2p => false, // single-hop: transit never happens
            NopTopology::Ring => {
                let k = self.net.chiplets;
                (node + k - upstream) % k == (next + k - node) % k
            }
            NopTopology::Mesh => {
                // X-Y routing never wraps a row/column, so the node-index
                // displacement (±1 in-row, ±cols in-column) is the direction.
                (node as i64 - upstream as i64) == (next as i64 - node as i64)
            }
        }
    }

    /// Move due arrivals into their receive buffers (credits were reserved
    /// at send time, so the push can never overflow). Occupancy is sampled
    /// here, matching the NoC simulator's arrival statistics.
    fn process_arrivals(&mut self, core: &mut EngineCore) {
        while let Some(&(t, buf, flit)) = self.arrivals.front() {
            if t > core.now {
                break;
            }
            self.arrivals.pop_front();
            let occ = self.bufs[buf].len();
            core.sample_occupancy(occ);
            self.bufs[buf].push_back(flit);
        }
    }

    /// Generate per-mode traffic (delegated to the engine core) and move
    /// one source-FIFO head per chiplet into its injection buffer when a
    /// credit is available.
    fn inject(&mut self, core: &mut EngineCore) {
        let steady = core.mode.is_steady();
        let injection_base = self.bufs.len() - self.net.nodes;
        for t in 0..core.sources.len() {
            if steady {
                core.generate_steady(t);
            } else {
                core.generate_drain(t);
            }
            // The injection buffer is a dedicated lane into the network:
            // nothing routes through it, so one free slot suffices.
            let ib = injection_base + t;
            if self.credits[ib] >= 1 {
                if let Some((dst, born)) = core.sources[t].fifo.pop_front() {
                    self.credits[ib] -= 1;
                    self.min_credit = self.min_credit.min(self.credits[ib]);
                    self.bufs[ib].push_back(NopFlit {
                        src: t as u32,
                        dst,
                        born,
                    });
                }
            }
        }
    }

    /// One switching cycle: every node scans its input buffers (round-robin
    /// start) and moves each flit whose output resource is free — at most
    /// one flit per directed link and one local ejection per node per
    /// cycle, bubble rule on chain entry.
    fn forward(&mut self, core: &mut EngineCore) {
        for b in 0..self.net.nodes {
            let n_in = self.in_bufs[b].len();
            let start = self.rr[b] % n_in;
            self.rr[b] = self.rr[b].wrapping_add(1);
            for i in 0..n_in {
                let buf = self.in_bufs[b][(start + i) % n_in];
                if self.bufs[buf].is_empty() {
                    continue;
                }
                let q = std::mem::take(&mut self.bufs[buf]);
                let mut kept: VecDeque<NopFlit> = VecDeque::with_capacity(q.len());
                let upstream = self.buf_edge[buf].0;
                for flit in q {
                    let dst = flit.dst as usize;
                    if dst == b {
                        if self.eject_free[b] <= core.now {
                            self.eject_free[b] = core.now + 1;
                            self.credits[buf] += 1;
                            core.deliver(flit.src, flit.dst, flit.born);
                        } else {
                            kept.push_back(flit);
                        }
                        continue;
                    }
                    let next = self.net.route_next(b, dst);
                    let target = self.link_buf[&(b, next)];
                    // Bubble rule: a flit that will leave `next`'s buffer
                    // independently (ejection there) or that continues its
                    // directional chain needs one credit; a flit entering a
                    // chain (injection, turn) must leave a slot free.
                    let needed = if dst == next
                        || (upstream != LOCAL && self.same_direction(upstream, b, next))
                    {
                        1
                    } else {
                        2
                    };
                    if self.link_free[target] <= core.now && self.credits[target] >= needed {
                        self.link_free[target] = core.now + 1;
                        self.credits[target] -= 1;
                        self.min_credit = self.min_credit.min(self.credits[target]);
                        self.credits[buf] += 1;
                        self.arrivals.push_back((
                            core.now + 1 + self.cfg.hop_latency_cycles,
                            target,
                            flit,
                        ));
                        if let Some(tm) = &mut core.telem {
                            tm.link_flits[target] += 1;
                        }
                    } else {
                        kept.push_back(flit);
                    }
                }
                // Attribution: the head of the kept queue is the flit that
                // blocks this buffer next cycle (busy link, exhausted
                // credits or a busy ejection port).
                if let Some(&NopFlit { src, dst, .. }) = kept.front() {
                    self.note_blocked(core, src, dst);
                }
                self.bufs[buf] = kept;
            }
        }
    }
}

/// Uniform-random chiplet-to-chiplet traffic at `rate_per_chiplet`
/// flits/chiplet/cycle — the package analogue of
/// [`crate::noc::sim::uniform_random_flows`].
pub fn uniform_nop_flows(k: usize, rate_per_chiplet: f64) -> Vec<FlowSpec> {
    crate::sim::engine::uniform_flows(k, rate_per_chiplet)
}

/// Zero-load NoP latency of one flit from `src` to `dst`, in NoP cycles:
/// each of the `h` hops costs one serialization cycle plus the fixed SerDes
/// latency, and ejection adds one cycle. The simulator reproduces this
/// exactly on an otherwise idle package (unit-tested below), which anchors
/// the sim-vs-analytical agreement checks.
pub fn zero_load_cycles(net: &NopNetwork, cfg: &NopConfig, src: usize, dst: usize) -> f64 {
    if src == dst {
        return 0.0;
    }
    net.hops(src, dst) as f64 * (1.0 + cfg.hop_latency_cycles as f64) + 1.0
}

/// The analytical (load-independent) average latency for a flow set: the
/// rate-weighted zero-load latency. This is exactly what the bandwidth +
/// fixed-latency package model predicts at any injection rate — comparing
/// it against [`NopSim`] steady measurements is what exposes SerDes
/// congestion.
pub fn analytical_latency(net: &NopNetwork, cfg: &NopConfig, flows: &[FlowSpec]) -> f64 {
    let mut weighted = 0.0;
    let mut weight = 0.0;
    for f in flows {
        if f.src == f.dst {
            continue;
        }
        // Steady flows weight by rate; drain flows by flit count.
        let w = if f.rate > 0.0 { f.rate } else { f.flits as f64 };
        weighted += w * zero_load_cycles(net, cfg, f.src, f.dst);
        weight += w;
    }
    if weight > 0.0 {
        weighted / weight
    } else {
        0.0
    }
}

/// Average latency exceeding this multiple of zero-load marks saturation.
pub const SATURATION_FACTOR: f64 = 3.0;

/// The rate grid both saturation searches walk: steps of 0.04 up to 1.0.
const SAT_STEP: f64 = 0.04;
const SAT_MAX_STEP: usize = 25;

/// One saturation probe: does uniform traffic at `step` × 0.04
/// flits/chiplet/cycle saturate the package? Saturation means the measured
/// average latency exceeds [`SATURATION_FACTOR`] × the zero-load average,
/// or the network stops delivering entirely.
fn saturated_at(
    topology: NopTopology,
    k: usize,
    cfg: &NopConfig,
    net: &NopNetwork,
    seed: u64,
    step: usize,
) -> bool {
    let rate = step as f64 * SAT_STEP;
    let flows = uniform_nop_flows(k, rate);
    let zero_load = analytical_latency(net, cfg, &flows).max(1.0);
    let stats = NopSim::new(
        topology,
        k,
        cfg,
        &flows,
        Mode::Steady {
            warmup: 500,
            measure: 2_000,
        },
        seed,
    )
    .run();
    stats.delivered == 0 || stats.avg_latency > SATURATION_FACTOR * zero_load
}

/// Smallest uniform injection rate (flits/chiplet/cycle, on a 0.04-step
/// grid up to 1.0) at which the package saturates (see
/// [`SATURATION_FACTOR`]). `None` means no saturation up to rate 1.0 — the
/// topology sustains full per-chiplet injection bandwidth.
///
/// The search bisects the rate grid (latency is monotone in offered load,
/// so the saturated region is an upper interval): one probe at the top of
/// the grid decides saturated-vs-not, then ~⌈log₂ 25⌉ probes pin the
/// boundary — ≤6 simulations where the linear reference scan
/// ([`saturation_rate_scan`]) needs up to 25. Results are additionally
/// memoized process-wide, so sweeps and serving-model builds that revisit
/// a (topology, k, cfg, seed) point pay nothing.
pub fn saturation_rate(
    topology: NopTopology,
    k: usize,
    cfg: &NopConfig,
    seed: u64,
) -> Option<f64> {
    if k < 2 {
        return None;
    }
    memo_saturation(topology, k, cfg, seed, || {
        let net = NopNetwork::build(topology, k);
        if !saturated_at(topology, k, cfg, &net, seed, SAT_MAX_STEP) {
            return None;
        }
        // Invariant: `hi` is saturated, everything below `lo` is not.
        let (mut lo, mut hi) = (1usize, SAT_MAX_STEP);
        while lo < hi {
            let mid = (lo + hi) / 2;
            if saturated_at(topology, k, cfg, &net, seed, mid) {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        Some(hi as f64 * SAT_STEP)
    })
}

/// Linear-scan reference implementation of [`saturation_rate`]: probe every
/// grid step from the bottom until one saturates. Unmemoized and O(grid);
/// kept as the behavioral reference the bisection search is tested against
/// (they agree to ±1 grid step — exact equality whenever the saturation
/// indicator is monotone in rate, which sampling noise can locally break).
pub fn saturation_rate_scan(
    topology: NopTopology,
    k: usize,
    cfg: &NopConfig,
    seed: u64,
) -> Option<f64> {
    if k < 2 {
        return None;
    }
    let net = NopNetwork::build(topology, k);
    (1..=SAT_MAX_STEP)
        .find(|&step| saturated_at(topology, k, cfg, &net, seed, step))
        .map(|step| step as f64 * SAT_STEP)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> NopConfig {
        NopConfig::default() // link 32 bits, 20-cycle hops, 64-flit buffers
    }

    fn drain(flows: &[FlowSpec], topology: NopTopology, k: usize, seed: u64) -> SimStats {
        NopSim::new(
            topology,
            k,
            &cfg(),
            flows,
            Mode::Drain {
                max_cycles: 1_000_000,
            },
            seed,
        )
        .run()
    }

    #[test]
    fn zero_load_latency_matches_formula_exactly() {
        // One lone flit on an idle package must hit the closed form on
        // every topology: hops x (1 + hop_latency) + 1.
        for topo in NopTopology::all() {
            let net = NopNetwork::build(topo, 6);
            for dst in 1..6 {
                let flows = [FlowSpec {
                    src: 0,
                    dst,
                    rate: 0.0,
                    flits: 1,
                }];
                let stats = drain(&flows, topo, 6, 1);
                assert!(stats.drained, "{topo:?} 0->{dst}");
                assert_eq!(stats.delivered, 1);
                let want = zero_load_cycles(&net, &cfg(), 0, dst);
                assert_eq!(
                    stats.avg_latency, want,
                    "{topo:?} 0->{dst}: {} vs {want}",
                    stats.avg_latency
                );
            }
        }
    }

    #[test]
    fn drain_conserves_flits() {
        let flows = [
            FlowSpec {
                src: 0,
                dst: 5,
                rate: 0.0,
                flits: 300,
            },
            FlowSpec {
                src: 3,
                dst: 1,
                rate: 0.0,
                flits: 170,
            },
            FlowSpec {
                src: 5,
                dst: 0,
                rate: 0.0,
                flits: 44,
            },
        ];
        for topo in NopTopology::all() {
            let s = drain(&flows, topo, 8, 7);
            assert!(s.drained, "{topo:?}");
            assert_eq!(s.injected, 514, "{topo:?}");
            assert_eq!(s.delivered, 514, "{topo:?}");
            assert!(s.makespan >= 300, "{topo:?} makespan {}", s.makespan);
        }
    }

    #[test]
    fn link_serialization_bounds_makespan() {
        // 200 flits over the single 1-hop P2P link: the link moves one flit
        // per cycle, so the makespan is ~200 plus pipeline fill, far below
        // what 200 independent zero-load flits would suggest if the link
        // were parallel.
        let flows = [FlowSpec {
            src: 0,
            dst: 1,
            rate: 0.0,
            flits: 200,
        }];
        let s = drain(&flows, NopTopology::P2p, 2, 3);
        assert!(s.drained);
        assert!(
            (200..=280).contains(&(s.makespan as i64)),
            "makespan {}",
            s.makespan
        );
    }

    #[test]
    fn ejection_serializes_hotspot() {
        // P2P all-to-one: every flit is one dedicated link away, but the
        // destination's RX ejects one flit per cycle — the drain cannot
        // beat the 4 x 50 = 200-cycle ejection bound.
        let flows: Vec<FlowSpec> = (1..5)
            .map(|s| FlowSpec {
                src: s,
                dst: 0,
                rate: 0.0,
                flits: 50,
            })
            .collect();
        let s = drain(&flows, NopTopology::P2p, 5, 9);
        assert!(s.drained);
        assert_eq!(s.delivered, 200);
        assert!(s.makespan >= 200, "makespan {}", s.makespan);
    }

    #[test]
    fn heavy_opposed_transit_drains_on_ring_and_mesh() {
        // Saturating bidirectional transit through shared middles — the
        // pattern that deadlocks naive credit flow control. The bubble rule
        // must keep both directional chains moving.
        let mut flows = Vec::new();
        for (s, d) in [(0usize, 7usize), (7, 0), (1, 6), (6, 1), (2, 5), (5, 2)] {
            flows.push(FlowSpec {
                src: s,
                dst: d,
                rate: 0.0,
                flits: 400,
            });
        }
        for topo in [NopTopology::Ring, NopTopology::Mesh] {
            let s = drain(&flows, topo, 8, 21);
            assert!(s.drained, "{topo:?} wedged");
            assert_eq!(s.delivered, 2_400, "{topo:?}");
        }
    }

    #[test]
    fn steady_latency_grows_with_load() {
        let run = |rate: f64| {
            let flows = uniform_nop_flows(16, rate);
            NopSim::new(
                NopTopology::Ring,
                16,
                &cfg(),
                &flows,
                Mode::Steady {
                    warmup: 500,
                    measure: 3_000,
                },
                42,
            )
            .run()
        };
        let lo = run(0.02);
        let hi = run(0.8);
        assert!(lo.delivered > 0 && hi.delivered > lo.delivered);
        assert!(
            hi.avg_latency > lo.avg_latency,
            "latency must grow with load: {} vs {}",
            lo.avg_latency,
            hi.avg_latency
        );
    }

    #[test]
    fn low_load_sim_matches_analytical_within_15pct() {
        for topo in NopTopology::all() {
            let k = 8;
            let net = NopNetwork::build(topo, k);
            let flows = uniform_nop_flows(k, 0.02);
            let ana = analytical_latency(&net, &cfg(), &flows);
            let sim = NopSim::new(
                topo,
                k,
                &cfg(),
                &flows,
                Mode::Steady {
                    warmup: 500,
                    measure: 6_000,
                },
                11,
            )
            .run();
            assert!(sim.delivered > 0, "{topo:?}");
            let err = (sim.avg_latency - ana).abs() / ana;
            assert!(
                err < 0.15,
                "{topo:?}: sim {} vs analytical {ana} ({:.1}% off)",
                sim.avg_latency,
                100.0 * err
            );
        }
    }

    #[test]
    fn ring_saturates_before_mesh_at_16_chiplets() {
        // The k >= 16 congestion story: a 16-chiplet ring has a 2-link
        // bisection vs the 4x4 mesh's 4 — uniform traffic saturates the
        // ring at a visibly lower injection rate. The analytical model is
        // load-independent and can never show this gap.
        let ring = saturation_rate(NopTopology::Ring, 16, &cfg(), 5);
        let mesh = saturation_rate(NopTopology::Mesh, 16, &cfg(), 5);
        let ring_rate = ring.expect("16-chiplet ring must saturate below rate 1.0");
        let mesh_rate = mesh.unwrap_or(1.04);
        assert!(
            ring_rate < mesh_rate,
            "ring saturates at {ring_rate}, mesh at {mesh_rate}"
        );
    }

    #[test]
    fn bisection_agrees_with_linear_scan_within_one_step() {
        // The accelerated search against its reference: exact agreement
        // under a monotone saturation indicator, ±1 grid step when
        // sampling noise blurs the boundary.
        for (topo, k) in [(NopTopology::Ring, 16), (NopTopology::Mesh, 16)] {
            let fast = saturation_rate(topo, k, &cfg(), 5);
            let slow = saturation_rate_scan(topo, k, &cfg(), 5);
            match (fast, slow) {
                (None, None) => {}
                (Some(a), Some(b)) => assert!(
                    (a - b).abs() <= SAT_STEP + 1e-9,
                    "{topo:?} k={k}: bisection {a} vs scan {b}"
                ),
                other => panic!("{topo:?} k={k}: bisection/scan disagree: {other:?}"),
            }
        }
    }

    #[test]
    fn credits_restored_and_never_negative_after_drain() {
        let flows = [
            FlowSpec {
                src: 0,
                dst: 3,
                rate: 0.0,
                flits: 120,
            },
            FlowSpec {
                src: 2,
                dst: 5,
                rate: 0.0,
                flits: 77,
            },
        ];
        for topo in NopTopology::all() {
            let (stats, audit) = NopSim::new(
                topo,
                7,
                &cfg(),
                &flows,
                Mode::Drain {
                    max_cycles: 1_000_000,
                },
                13,
            )
            .run_audited();
            assert!(stats.drained, "{topo:?}");
            assert!(audit.min_credit >= 0, "{topo:?}: {}", audit.min_credit);
            for (n, &c) in audit.credits.iter().enumerate() {
                assert_eq!(c, audit.capacity, "{topo:?}: buffer {n} leaked credits");
            }
        }
    }

    #[test]
    fn mesh_relay_sites_forward_traffic() {
        // 7 chiplets on a 3x3 grid: routes may pass the passive relay
        // sites 7/8; traffic must still drain and conserve.
        let flows = [
            FlowSpec {
                src: 6,
                dst: 2,
                rate: 0.0,
                flits: 40,
            },
            FlowSpec {
                src: 1,
                dst: 6,
                rate: 0.0,
                flits: 25,
            },
        ];
        let s = drain(&flows, NopTopology::Mesh, 7, 17);
        assert!(s.drained);
        assert_eq!(s.delivered, 65);
    }

    #[test]
    fn self_flows_are_ignored() {
        let flows = [FlowSpec {
            src: 2,
            dst: 2,
            rate: 0.5,
            flits: 10,
        }];
        let s = drain(&flows, NopTopology::Ring, 4, 1);
        assert_eq!(s.injected, 0);
        assert!(s.drained);
    }

    #[test]
    fn per_pair_tracking_counts_flits() {
        let flows = [
            FlowSpec {
                src: 0,
                dst: 3,
                rate: 0.0,
                flits: 10,
            },
            FlowSpec {
                src: 1,
                dst: 2,
                rate: 0.0,
                flits: 5,
            },
        ];
        let s = NopSim::new(
            NopTopology::Mesh,
            4,
            &cfg(),
            &flows,
            Mode::Drain {
                max_cycles: 100_000,
            },
            5,
        )
        .track_pairs(true)
        .run();
        assert_eq!(s.per_pair.len(), 2);
        assert_eq!(s.per_pair[&3u64].count, 10);
        assert_eq!(s.per_pair[&((1u64 << 32) | 2)].count, 5);
    }

    #[test]
    fn attribution_records_waits_without_changing_outcomes() {
        // Two flows contending for the ring link into chiplet 2: someone
        // must block, so the armed run records waits — and every simulated
        // outcome matches the disarmed run exactly.
        let flows = [
            FlowSpec {
                src: 0,
                dst: 2,
                rate: 0.0,
                flits: 40,
            },
            FlowSpec {
                src: 1,
                dst: 2,
                rate: 0.0,
                flits: 40,
            },
        ];
        let build = || {
            NopSim::new(
                NopTopology::Ring,
                4,
                &cfg(),
                &flows,
                Mode::Drain {
                    max_cycles: 500_000,
                },
                33,
            )
        };
        let off = build().run();
        let on = build().attribute(true).run();
        assert!(off.drained && on.drained);
        assert_eq!(off.makespan, on.makespan);
        assert_eq!(off.delivered, on.delivered);
        assert_eq!(off.avg_latency, on.avg_latency);
        assert!(off.flow_waits.is_empty(), "disarmed run must not allocate");
        assert!(!on.flow_waits.is_empty(), "contention must record waits");
        // Every recorded key is one of the two offered flows.
        for key in on.flow_waits.keys() {
            assert!(
                *key == 2 || *key == ((1u64 << 32) | 2),
                "unexpected flow key {key:#x}"
            );
        }
    }

    #[test]
    fn golden_determinism_same_seed_same_stats() {
        // Golden equivalence anchor for the engine refactor: a fixed seed
        // must reproduce every statistic bit-for-bit across repeats and
        // across the run()/run_audited()/run_instrumented() paths.
        let run_steady = || {
            NopSim::new(
                NopTopology::Mesh,
                9,
                &cfg(),
                &uniform_nop_flows(9, 0.3),
                Mode::Steady {
                    warmup: 400,
                    measure: 2_500,
                },
                0x901D,
            )
            .run()
        };
        let a = run_steady();
        let b = run_steady();
        assert!(a.delivered > 0);
        assert_eq!(a.injected, b.injected);
        assert_eq!(a.delivered, b.delivered);
        assert_eq!(a.avg_latency, b.avg_latency);
        assert_eq!(a.max_latency, b.max_latency);
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.arrivals_zero, b.arrivals_zero);
        assert_eq!(a.nonzero_occ_sum, b.nonzero_occ_sum);

        let flows = [
            FlowSpec {
                src: 0,
                dst: 4,
                rate: 0.0,
                flits: 80,
            },
            FlowSpec {
                src: 3,
                dst: 1,
                rate: 0.0,
                flits: 21,
            },
        ];
        let build = || {
            NopSim::new(
                NopTopology::Ring,
                5,
                &cfg(),
                &flows,
                Mode::Drain {
                    max_cycles: 500_000,
                },
                0xFEED,
            )
        };
        let plain = build().run();
        let (audited, audit) = build().run_audited();
        let (instrumented, telem) = build().instrument(true).run_instrumented();
        assert!(plain.drained);
        for other in [&audited, &instrumented] {
            assert_eq!(plain.makespan, other.makespan);
            assert_eq!(plain.cycles, other.cycles);
            assert_eq!(plain.avg_latency, other.avg_latency);
            assert_eq!(plain.delivered, other.delivered);
        }
        assert!(audit.min_credit >= 0);
        assert_eq!(telem.ejected_total(), plain.delivered);
    }

    #[test]
    fn instrumented_totals_match_stats() {
        let flows = [
            FlowSpec {
                src: 6,
                dst: 2,
                rate: 0.0,
                flits: 40,
            },
            FlowSpec {
                src: 1,
                dst: 6,
                rate: 0.0,
                flits: 25,
            },
        ];
        // k=7 mesh exercises the passive relay sites too.
        let (s, t) = NopSim::new(
            NopTopology::Mesh,
            7,
            &cfg(),
            &flows,
            Mode::Drain {
                max_cycles: 1_000_000,
            },
            17,
        )
        .instrument(true)
        .run_instrumented();
        assert!(s.drained);
        assert_eq!(t.injected_total(), s.injected);
        assert_eq!(t.ejected_total(), s.delivered);
        assert_eq!(t.injected[6], 40);
        assert_eq!(t.ejected[6], 25);
        assert_eq!(t.cycles, s.cycles);
        // Every delivered flit crossed at least one package link.
        assert!(t.transit_total() >= s.delivered);
        // Links are the sorted enumeration `new` built buffers from.
        let mut sorted = t.links.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, t.links);

        // Uninstrumented runs return empty telemetry and identical stats.
        let (s2, empty) = NopSim::new(
            NopTopology::Mesh,
            7,
            &cfg(),
            &flows,
            Mode::Drain {
                max_cycles: 1_000_000,
            },
            17,
        )
        .run_instrumented();
        assert_eq!(s2.makespan, s.makespan);
        assert!(empty.links.is_empty());
        assert_eq!(empty.injected_total(), 0);
    }
}
