//! Event-driven, flit-level Network-on-Package simulation — the package
//! mirror of [`crate::noc::sim`], specialized for SerDes-class channels.
//!
//! Package links differ from on-chip NoC links in three ways the analytical
//! model of [`crate::nop::evaluator`] cannot see under load:
//!
//! * **Serialization** — a link moves one `link_width`-bit NoP flit per NoP
//!   cycle, so a bundle of `F` flits occupies its first link for `F` cycles
//!   and competing bundles queue behind it.
//! * **Fixed hop latency** — every traversal adds `hop_latency_cycles`
//!   (SerDes TX + package trace + RX). The engine is event-driven: when all
//!   traffic is mid-flight the clock jumps straight to the next arrival
//!   instead of stepping through the latency gap cycle by cycle.
//! * **Credit-based flow control** — every directed link owns a
//!   `buffer_flits`-deep virtual receive buffer at its downstream node
//!   (plus one injection buffer per chiplet). A sender consumes one
//!   downstream credit per flit — returned when the flit leaves that
//!   buffer, so credits also cover in-flight traffic — and stalls at zero.
//!   Flits *entering* a directional chain (injection, X→Y turns) must
//!   leave one slot free in the target buffer; straight-through transit
//!   needs a single credit. This is bubble flow control: each directional
//!   ring/row/column keeps a circulating bubble, which makes
//!   shortest-direction rings and X-Y meshes deadlock-free without
//!   virtual channels.
//!
//! The simulator deliberately reuses the [`FlowSpec`]/[`Mode`]/[`SimStats`]
//! vocabulary of the per-chip simulator so `nop::evaluator` can compose the
//! two engines into one hierarchical co-simulation: per-chiplet `NocSim`
//! runs below, `NopSim` runs the package graph above, fed by the
//! inter-chiplet injection matrix of [`crate::mapping::ChipletPartition`].
//! All times are **NoP cycles**; callers convert with the clock ratio.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::config::NopConfig;
use crate::noc::sim::{FlowSpec, Mode, SimStats};
use crate::nop::topology::{NopNetwork, NopTopology};
use crate::telemetry::SimTelemetry;
use crate::util::Pcg32;

/// Upstream marker for injection buffers (no inbound link).
const LOCAL: usize = usize::MAX;

/// One NoP flit in flight. `born` is the NoP cycle the flit was generated
/// at its source chiplet (source-queue wait counts toward latency).
#[derive(Clone, Copy, Debug)]
struct NopFlit {
    src: u32,
    dst: u32,
    born: u64,
}

/// Per-chiplet traffic generator (same shape as the NoC simulator's).
struct SourceState {
    /// Aggregate injection rate in flits/cycle (steady mode).
    rate: f64,
    /// Destination CDF for steady mode: (cumulative rate, dst).
    dst_cdf: Vec<(f64, u32)>,
    /// Remaining (dst, count) entries for drain mode, drawn round-robin.
    pending: Vec<(u32, u64)>,
    next_pending: usize,
    /// Generated-but-not-yet-injected flits (unbounded source FIFO).
    fifo: VecDeque<(u32, u64)>,
}

/// Post-run flow-control audit, for the credit-invariant property tests.
#[derive(Clone, Debug)]
pub struct NopAudit {
    /// Credits each virtual receive buffer started with (`buffer_flits`).
    pub capacity: i64,
    /// Credits left per buffer after the run (== `capacity` after a drain).
    pub credits: Vec<i64>,
    /// Lowest credit count observed anywhere at any time (never < 0).
    pub min_credit: i64,
}

/// The flit-level package simulator.
pub struct NopSim {
    net: NopNetwork,
    cfg: NopConfig,
    mode: Mode,
    /// Virtual receive buffers: one per directed link, then one injection
    /// buffer per node (id = `injection_base + node`).
    bufs: Vec<VecDeque<NopFlit>>,
    /// Free slots per buffer. Signed so the audit can prove non-negativity
    /// instead of relying on unsigned wrap-around panics.
    credits: Vec<i64>,
    min_credit: i64,
    /// Directed link (from, to) → its buffer id. Lookup only — iteration
    /// always goes through the deterministic `in_bufs` lists.
    link_buf: HashMap<(usize, usize), usize>,
    /// (upstream, node) per buffer; upstream == LOCAL for injection bufs.
    buf_edge: Vec<(usize, usize)>,
    /// Buffers feeding each node, in deterministic order.
    in_bufs: Vec<Vec<usize>>,
    /// Round-robin scan offset per node (arbitration fairness).
    rr: Vec<usize>,
    /// Earliest cycle each link buffer may start another flit (per-link
    /// serialization; unused for injection buffers).
    link_free: Vec<u64>,
    /// Earliest cycle each node's local SerDes RX may eject another flit.
    eject_free: Vec<u64>,
    /// In-flight flits as (arrival cycle, buffer id, flit). Hop latency is
    /// uniform, so send order == arrival order and a FIFO replaces a heap.
    arrivals: VecDeque<(u64, usize, NopFlit)>,
    sources: Vec<SourceState>,
    rng: Pcg32,
    track_pairs: bool,
    stats: SimStats,
    now: u64,
    in_warmup: bool,
    /// Flits generated but not yet delivered.
    in_flight: u64,
    /// Drain mode: flits not yet generated.
    ungenerated: u64,
    /// Per-link telemetry, collected only when built with `instrument(true)`
    /// (boxed so the disabled path stays one pointer wide).
    telem: Option<Box<SimTelemetry>>,
}

impl NopSim {
    /// Build a simulator for `k` chiplets on `topology`. Flow endpoints are
    /// chiplet ids (`< k`); self-flows never enter the package network.
    pub fn new(
        topology: NopTopology,
        k: usize,
        cfg: &NopConfig,
        flows: &[FlowSpec],
        mode: Mode,
        seed: u64,
    ) -> Self {
        let net = NopNetwork::build(topology, k);

        // Enumerate every directed link deterministic routing can use, in
        // sorted order (deterministic buffer ids).
        let mut links: Vec<(usize, usize)> = Vec::new();
        let mut seen: HashSet<(usize, usize)> = HashSet::new();
        for a in 0..net.nodes {
            for d in 0..net.chiplets {
                if d == a {
                    continue;
                }
                let b = net.route_next(a, d);
                if seen.insert((a, b)) {
                    links.push((a, b));
                }
            }
        }
        links.sort_unstable();
        let injection_base = links.len();
        let n_bufs = links.len() + net.nodes;

        let mut link_buf = HashMap::new();
        let mut buf_edge = vec![(LOCAL, 0usize); n_bufs];
        let mut in_bufs: Vec<Vec<usize>> = vec![Vec::new(); net.nodes];
        for (id, &(a, b)) in links.iter().enumerate() {
            link_buf.insert((a, b), id);
            buf_edge[id] = (a, b);
            in_bufs[b].push(id);
        }
        for n in 0..net.nodes {
            buf_edge[injection_base + n] = (LOCAL, n);
            in_bufs[n].push(injection_base + n);
        }

        let mut sources: Vec<SourceState> = (0..k)
            .map(|_| SourceState {
                rate: 0.0,
                dst_cdf: Vec::new(),
                pending: Vec::new(),
                next_pending: 0,
                fifo: VecDeque::new(),
            })
            .collect();
        for f in flows {
            assert!(f.src < k && f.dst < k, "NoP flow endpoint out of range");
            if f.src == f.dst {
                continue; // intra-chiplet traffic rides the local NoC
            }
            let s = &mut sources[f.src];
            s.rate += f.rate;
            s.dst_cdf.push((s.rate, f.dst as u32));
            if f.flits > 0 {
                s.pending.push((f.dst as u32, f.flits));
            }
        }
        // Saturation guard: a chiplet injects at most one flit per cycle.
        for s in &mut sources {
            if s.rate > 1.0 {
                let scale = 1.0 / s.rate;
                for e in &mut s.dst_cdf {
                    e.0 *= scale;
                }
                s.rate = 1.0;
            }
        }
        let ungenerated: u64 = sources
            .iter()
            .flat_map(|s| s.pending.iter().map(|&(_, c)| c))
            .sum();
        let steady = matches!(mode, Mode::Steady { .. });
        let nodes = net.nodes;
        Self {
            net,
            cfg: cfg.clone(),
            mode,
            bufs: vec![VecDeque::new(); n_bufs],
            credits: vec![cfg.buffer_flits as i64; n_bufs],
            min_credit: cfg.buffer_flits as i64,
            link_buf,
            buf_edge,
            in_bufs,
            rr: vec![0; nodes],
            link_free: vec![0; n_bufs],
            eject_free: vec![0; nodes],
            arrivals: VecDeque::new(),
            sources,
            rng: Pcg32::seeded(seed),
            track_pairs: false,
            stats: SimStats::default(),
            now: 0,
            in_warmup: steady,
            in_flight: 0,
            ungenerated,
            telem: None,
        }
    }

    /// Enable per-pair latency tracking.
    pub fn track_pairs(mut self, on: bool) -> Self {
        self.track_pairs = on;
        self
    }

    /// Collect per-link flit counters, per-chiplet injection/ejection
    /// counters and buffer-occupancy telemetry while running (returned by
    /// [`NopSim::run_instrumented`]). Off by default: the disabled path
    /// costs one branch per hook site and allocates nothing.
    pub fn instrument(mut self, on: bool) -> Self {
        if !on {
            self.telem = None;
            return self;
        }
        // Link buffer id == telemetry link index: both follow the sorted
        // link enumeration of `new`, so `forward` can index directly.
        let injection_base = self.bufs.len() - self.net.nodes;
        let links: Vec<(usize, usize)> = self.buf_edge[..injection_base].to_vec();
        self.telem = Some(Box::new(SimTelemetry::sized(links, self.sources.len())));
        self
    }

    /// Does a flit that entered `node` from `upstream` keep its direction
    /// when forwarded to `next`? Straight-through transit rides an existing
    /// directional chain and needs a single credit; everything else
    /// (injection, turns) enters a chain and must preserve its bubble.
    fn same_direction(&self, upstream: usize, node: usize, next: usize) -> bool {
        match self.net.topology {
            NopTopology::P2p => false, // single-hop: transit never happens
            NopTopology::Ring => {
                let k = self.net.chiplets;
                (node + k - upstream) % k == (next + k - node) % k
            }
            NopTopology::Mesh => {
                // X-Y routing never wraps a row/column, so the node-index
                // displacement (±1 in-row, ±cols in-column) is the direction.
                (node as i64 - upstream as i64) == (next as i64 - node as i64)
            }
        }
    }

    /// Move due arrivals into their receive buffers (credits were reserved
    /// at send time, so the push can never overflow). Occupancy is sampled
    /// here, matching the NoC simulator's arrival statistics.
    fn process_arrivals(&mut self) {
        while let Some(&(t, buf, flit)) = self.arrivals.front() {
            if t > self.now {
                break;
            }
            self.arrivals.pop_front();
            let occ = self.bufs[buf].len();
            if !self.in_warmup {
                self.stats.arrivals += 1;
                if occ == 0 {
                    self.stats.arrivals_zero += 1;
                } else {
                    self.stats.nonzero_occ_sum += occ as f64;
                    self.stats.nonzero_occ_count += 1;
                }
                if let Some(tm) = &mut self.telem {
                    tm.occupancy.record(occ as f64);
                }
            }
            self.bufs[buf].push_back(flit);
        }
    }

    /// Generate per-mode traffic and move one source-FIFO head per chiplet
    /// into its injection buffer when a credit is available.
    fn inject(&mut self) {
        let steady = matches!(self.mode, Mode::Steady { .. });
        let injection_base = self.bufs.len() - self.net.nodes;
        for t in 0..self.sources.len() {
            if steady {
                let s = &mut self.sources[t];
                if s.rate > 0.0 && self.rng.bernoulli(s.rate) {
                    let u = self.rng.next_f64() * s.rate;
                    let dst = match s
                        .dst_cdf
                        .binary_search_by(|probe| probe.0.partial_cmp(&u).unwrap())
                    {
                        Ok(i) => s.dst_cdf[(i + 1).min(s.dst_cdf.len() - 1)].1,
                        Err(i) => s.dst_cdf[i.min(s.dst_cdf.len() - 1)].1,
                    };
                    s.fifo.push_back((dst, self.now));
                    self.stats.injected += 1;
                    self.in_flight += 1;
                    if let Some(tm) = &mut self.telem {
                        tm.injected[t] += 1;
                    }
                }
            } else if self.sources[t].fifo.is_empty() && !self.sources[t].pending.is_empty() {
                // Drain mode: keep the FIFO primed, round-robin over the
                // destination entries.
                let s = &mut self.sources[t];
                let idx = s.next_pending % s.pending.len();
                let (dst, remaining) = s.pending[idx];
                s.fifo.push_back((dst, self.now));
                self.stats.injected += 1;
                self.in_flight += 1;
                self.ungenerated -= 1;
                if let Some(tm) = &mut self.telem {
                    tm.injected[t] += 1;
                }
                if remaining <= 1 {
                    s.pending.swap_remove(idx);
                } else {
                    s.pending[idx].1 = remaining - 1;
                }
                s.next_pending = s.next_pending.wrapping_add(1);
            }
            // The injection buffer is a dedicated lane into the network:
            // nothing routes through it, so one free slot suffices.
            let ib = injection_base + t;
            if self.credits[ib] >= 1 {
                if let Some((dst, born)) = self.sources[t].fifo.pop_front() {
                    self.credits[ib] -= 1;
                    self.min_credit = self.min_credit.min(self.credits[ib]);
                    self.bufs[ib].push_back(NopFlit {
                        src: t as u32,
                        dst,
                        born,
                    });
                }
            }
        }
    }

    /// One switching cycle: every node scans its input buffers (round-robin
    /// start) and moves each flit whose output resource is free — at most
    /// one flit per directed link and one local ejection per node per
    /// cycle, bubble rule on chain entry.
    fn forward(&mut self) {
        for b in 0..self.net.nodes {
            let n_in = self.in_bufs[b].len();
            let start = self.rr[b] % n_in;
            self.rr[b] = self.rr[b].wrapping_add(1);
            for i in 0..n_in {
                let buf = self.in_bufs[b][(start + i) % n_in];
                if self.bufs[buf].is_empty() {
                    continue;
                }
                let q = std::mem::take(&mut self.bufs[buf]);
                let mut kept: VecDeque<NopFlit> = VecDeque::with_capacity(q.len());
                let upstream = self.buf_edge[buf].0;
                for flit in q {
                    let dst = flit.dst as usize;
                    if dst == b {
                        if self.eject_free[b] <= self.now {
                            self.eject_free[b] = self.now + 1;
                            self.credits[buf] += 1;
                            self.deliver(flit);
                        } else {
                            kept.push_back(flit);
                        }
                        continue;
                    }
                    let next = self.net.route_next(b, dst);
                    let target = self.link_buf[&(b, next)];
                    // Bubble rule: a flit that will leave `next`'s buffer
                    // independently (ejection there) or that continues its
                    // directional chain needs one credit; a flit entering a
                    // chain (injection, turn) must leave a slot free.
                    let needed = if dst == next
                        || (upstream != LOCAL && self.same_direction(upstream, b, next))
                    {
                        1
                    } else {
                        2
                    };
                    if self.link_free[target] <= self.now && self.credits[target] >= needed {
                        self.link_free[target] = self.now + 1;
                        self.credits[target] -= 1;
                        self.min_credit = self.min_credit.min(self.credits[target]);
                        self.credits[buf] += 1;
                        self.arrivals.push_back((
                            self.now + 1 + self.cfg.hop_latency_cycles,
                            target,
                            flit,
                        ));
                        if let Some(tm) = &mut self.telem {
                            tm.link_flits[target] += 1;
                        }
                    } else {
                        kept.push_back(flit);
                    }
                }
                self.bufs[buf] = kept;
            }
        }
    }

    fn deliver(&mut self, flit: NopFlit) {
        let latency = self.now - flit.born + 1;
        self.in_flight -= 1;
        if self.in_warmup {
            return;
        }
        self.stats.delivered += 1;
        if let Some(tm) = &mut self.telem {
            tm.ejected[flit.dst as usize] += 1;
        }
        self.stats.avg_latency += latency as f64; // running sum; divided at end
        self.stats.max_latency = self.stats.max_latency.max(latency);
        self.stats.makespan = self.now + 1;
        if self.track_pairs {
            let key = ((flit.src as u64) << 32) | flit.dst as u64;
            let p = self.stats.per_pair.entry(key).or_default();
            p.count += 1;
            p.sum_latency += latency;
            p.max_latency = p.max_latency.max(latency);
        }
    }

    #[inline]
    fn busy(&self) -> bool {
        self.in_flight > 0 || self.ungenerated > 0
    }

    /// Is any flit sitting in a buffer or source queue (i.e. work may be
    /// possible next cycle, as opposed to everything being mid-flight)?
    fn queued_work(&self) -> bool {
        self.bufs.iter().any(|q| !q.is_empty())
            || self
                .sources
                .iter()
                .any(|s| !s.fifo.is_empty() || !s.pending.is_empty())
    }

    /// Run to completion per the configured mode.
    pub fn run(self) -> SimStats {
        self.run_all().0
    }

    /// Like [`run`](Self::run), also returning the flow-control audit.
    pub fn run_audited(self) -> (SimStats, NopAudit) {
        let (stats, audit, _) = self.run_all();
        (stats, audit)
    }

    /// Like [`run`](Self::run), also returning the collected telemetry
    /// (empty unless built with [`NopSim::instrument`]).
    pub fn run_instrumented(self) -> (SimStats, SimTelemetry) {
        let (stats, _, telem) = self.run_all();
        (stats, telem)
    }

    fn run_all(mut self) -> (SimStats, NopAudit, SimTelemetry) {
        match self.mode {
            Mode::Steady { warmup, measure } => {
                let end = warmup + measure;
                while self.now < end {
                    if self.now >= warmup {
                        self.in_warmup = false;
                    }
                    self.process_arrivals();
                    self.inject();
                    self.forward();
                    self.now += 1;
                }
            }
            Mode::Drain { max_cycles } => {
                self.in_warmup = false;
                while self.busy() && self.now < max_cycles {
                    self.process_arrivals();
                    self.inject();
                    self.forward();
                    if self.queued_work() {
                        self.now += 1;
                    } else if let Some(&(t, _, _)) = self.arrivals.front() {
                        // Everything is mid-flight: jump to the next event.
                        self.now = t.max(self.now + 1);
                    } else {
                        break;
                    }
                }
                self.stats.drained = !self.busy();
            }
        }
        self.stats.cycles = self.now;
        if self.stats.delivered > 0 {
            self.stats.avg_latency /= self.stats.delivered as f64;
        }
        let mut telem = match self.telem.take() {
            Some(b) => *b,
            None => SimTelemetry::default(),
        };
        telem.cycles = self.stats.cycles;
        let audit = NopAudit {
            capacity: self.cfg.buffer_flits as i64,
            credits: self.credits,
            min_credit: self.min_credit,
        };
        (self.stats, audit, telem)
    }
}

/// Uniform-random chiplet-to-chiplet traffic at `rate_per_chiplet`
/// flits/chiplet/cycle — the package analogue of
/// [`crate::noc::sim::uniform_random_flows`].
pub fn uniform_nop_flows(k: usize, rate_per_chiplet: f64) -> Vec<FlowSpec> {
    let mut flows = Vec::new();
    if k < 2 {
        return flows;
    }
    let pair_rate = rate_per_chiplet / (k - 1) as f64;
    for s in 0..k {
        for d in 0..k {
            if s != d {
                flows.push(FlowSpec {
                    src: s,
                    dst: d,
                    rate: pair_rate,
                    flits: 0,
                });
            }
        }
    }
    flows
}

/// Zero-load NoP latency of one flit from `src` to `dst`, in NoP cycles:
/// each of the `h` hops costs one serialization cycle plus the fixed SerDes
/// latency, and ejection adds one cycle. The simulator reproduces this
/// exactly on an otherwise idle package (unit-tested below), which anchors
/// the sim-vs-analytical agreement checks.
pub fn zero_load_cycles(net: &NopNetwork, cfg: &NopConfig, src: usize, dst: usize) -> f64 {
    if src == dst {
        return 0.0;
    }
    net.hops(src, dst) as f64 * (1.0 + cfg.hop_latency_cycles as f64) + 1.0
}

/// The analytical (load-independent) average latency for a flow set: the
/// rate-weighted zero-load latency. This is exactly what the bandwidth +
/// fixed-latency package model predicts at any injection rate — comparing
/// it against [`NopSim`] steady measurements is what exposes SerDes
/// congestion.
pub fn analytical_latency(net: &NopNetwork, cfg: &NopConfig, flows: &[FlowSpec]) -> f64 {
    let mut weighted = 0.0;
    let mut weight = 0.0;
    for f in flows {
        if f.src == f.dst {
            continue;
        }
        // Steady flows weight by rate; drain flows by flit count.
        let w = if f.rate > 0.0 { f.rate } else { f.flits as f64 };
        weighted += w * zero_load_cycles(net, cfg, f.src, f.dst);
        weight += w;
    }
    if weight > 0.0 {
        weighted / weight
    } else {
        0.0
    }
}

/// Average latency exceeding this multiple of zero-load marks saturation.
pub const SATURATION_FACTOR: f64 = 3.0;

/// Smallest uniform injection rate (flits/chiplet/cycle, swept in 0.04
/// steps up to 1.0) at which the package saturates: measured average
/// latency exceeds [`SATURATION_FACTOR`] × the zero-load average (or the
/// network stops delivering). `None` means no saturation up to rate 1.0 —
/// the topology sustains full per-chiplet injection bandwidth.
pub fn saturation_rate(
    topology: NopTopology,
    k: usize,
    cfg: &NopConfig,
    seed: u64,
) -> Option<f64> {
    if k < 2 {
        return None;
    }
    let net = NopNetwork::build(topology, k);
    for step in 1..=25usize {
        let rate = step as f64 * 0.04;
        let flows = uniform_nop_flows(k, rate);
        let zero_load = analytical_latency(&net, cfg, &flows).max(1.0);
        let stats = NopSim::new(
            topology,
            k,
            cfg,
            &flows,
            Mode::Steady {
                warmup: 500,
                measure: 2_000,
            },
            seed,
        )
        .run();
        if stats.delivered == 0 || stats.avg_latency > SATURATION_FACTOR * zero_load {
            return Some(rate);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> NopConfig {
        NopConfig::default() // link 32 bits, 20-cycle hops, 64-flit buffers
    }

    fn drain(flows: &[FlowSpec], topology: NopTopology, k: usize, seed: u64) -> SimStats {
        NopSim::new(
            topology,
            k,
            &cfg(),
            flows,
            Mode::Drain {
                max_cycles: 1_000_000,
            },
            seed,
        )
        .run()
    }

    #[test]
    fn zero_load_latency_matches_formula_exactly() {
        // One lone flit on an idle package must hit the closed form on
        // every topology: hops x (1 + hop_latency) + 1.
        for topo in NopTopology::all() {
            let net = NopNetwork::build(topo, 6);
            for dst in 1..6 {
                let flows = [FlowSpec {
                    src: 0,
                    dst,
                    rate: 0.0,
                    flits: 1,
                }];
                let stats = drain(&flows, topo, 6, 1);
                assert!(stats.drained, "{topo:?} 0->{dst}");
                assert_eq!(stats.delivered, 1);
                let want = zero_load_cycles(&net, &cfg(), 0, dst);
                assert_eq!(
                    stats.avg_latency, want,
                    "{topo:?} 0->{dst}: {} vs {want}",
                    stats.avg_latency
                );
            }
        }
    }

    #[test]
    fn drain_conserves_flits() {
        let flows = [
            FlowSpec {
                src: 0,
                dst: 5,
                rate: 0.0,
                flits: 300,
            },
            FlowSpec {
                src: 3,
                dst: 1,
                rate: 0.0,
                flits: 170,
            },
            FlowSpec {
                src: 5,
                dst: 0,
                rate: 0.0,
                flits: 44,
            },
        ];
        for topo in NopTopology::all() {
            let s = drain(&flows, topo, 8, 7);
            assert!(s.drained, "{topo:?}");
            assert_eq!(s.injected, 514, "{topo:?}");
            assert_eq!(s.delivered, 514, "{topo:?}");
            assert!(s.makespan >= 300, "{topo:?} makespan {}", s.makespan);
        }
    }

    #[test]
    fn link_serialization_bounds_makespan() {
        // 200 flits over the single 1-hop P2P link: the link moves one flit
        // per cycle, so the makespan is ~200 plus pipeline fill, far below
        // what 200 independent zero-load flits would suggest if the link
        // were parallel.
        let flows = [FlowSpec {
            src: 0,
            dst: 1,
            rate: 0.0,
            flits: 200,
        }];
        let s = drain(&flows, NopTopology::P2p, 2, 3);
        assert!(s.drained);
        assert!(
            (200..=280).contains(&(s.makespan as i64)),
            "makespan {}",
            s.makespan
        );
    }

    #[test]
    fn ejection_serializes_hotspot() {
        // P2P all-to-one: every flit is one dedicated link away, but the
        // destination's RX ejects one flit per cycle — the drain cannot
        // beat the 4 x 50 = 200-cycle ejection bound.
        let flows: Vec<FlowSpec> = (1..5)
            .map(|s| FlowSpec {
                src: s,
                dst: 0,
                rate: 0.0,
                flits: 50,
            })
            .collect();
        let s = drain(&flows, NopTopology::P2p, 5, 9);
        assert!(s.drained);
        assert_eq!(s.delivered, 200);
        assert!(s.makespan >= 200, "makespan {}", s.makespan);
    }

    #[test]
    fn heavy_opposed_transit_drains_on_ring_and_mesh() {
        // Saturating bidirectional transit through shared middles — the
        // pattern that deadlocks naive credit flow control. The bubble rule
        // must keep both directional chains moving.
        let mut flows = Vec::new();
        for (s, d) in [(0usize, 7usize), (7, 0), (1, 6), (6, 1), (2, 5), (5, 2)] {
            flows.push(FlowSpec {
                src: s,
                dst: d,
                rate: 0.0,
                flits: 400,
            });
        }
        for topo in [NopTopology::Ring, NopTopology::Mesh] {
            let s = drain(&flows, topo, 8, 21);
            assert!(s.drained, "{topo:?} wedged");
            assert_eq!(s.delivered, 2_400, "{topo:?}");
        }
    }

    #[test]
    fn steady_latency_grows_with_load() {
        let run = |rate: f64| {
            let flows = uniform_nop_flows(16, rate);
            NopSim::new(
                NopTopology::Ring,
                16,
                &cfg(),
                &flows,
                Mode::Steady {
                    warmup: 500,
                    measure: 3_000,
                },
                42,
            )
            .run()
        };
        let lo = run(0.02);
        let hi = run(0.8);
        assert!(lo.delivered > 0 && hi.delivered > lo.delivered);
        assert!(
            hi.avg_latency > lo.avg_latency,
            "latency must grow with load: {} vs {}",
            lo.avg_latency,
            hi.avg_latency
        );
    }

    #[test]
    fn low_load_sim_matches_analytical_within_15pct() {
        for topo in NopTopology::all() {
            let k = 8;
            let net = NopNetwork::build(topo, k);
            let flows = uniform_nop_flows(k, 0.02);
            let ana = analytical_latency(&net, &cfg(), &flows);
            let sim = NopSim::new(
                topo,
                k,
                &cfg(),
                &flows,
                Mode::Steady {
                    warmup: 500,
                    measure: 6_000,
                },
                11,
            )
            .run();
            assert!(sim.delivered > 0, "{topo:?}");
            let err = (sim.avg_latency - ana).abs() / ana;
            assert!(
                err < 0.15,
                "{topo:?}: sim {} vs analytical {ana} ({:.1}% off)",
                sim.avg_latency,
                100.0 * err
            );
        }
    }

    #[test]
    fn ring_saturates_before_mesh_at_16_chiplets() {
        // The k >= 16 congestion story: a 16-chiplet ring has a 2-link
        // bisection vs the 4x4 mesh's 4 — uniform traffic saturates the
        // ring at a visibly lower injection rate. The analytical model is
        // load-independent and can never show this gap.
        let ring = saturation_rate(NopTopology::Ring, 16, &cfg(), 5);
        let mesh = saturation_rate(NopTopology::Mesh, 16, &cfg(), 5);
        let ring_rate = ring.expect("16-chiplet ring must saturate below rate 1.0");
        let mesh_rate = mesh.unwrap_or(1.04);
        assert!(
            ring_rate < mesh_rate,
            "ring saturates at {ring_rate}, mesh at {mesh_rate}"
        );
    }

    #[test]
    fn credits_restored_and_never_negative_after_drain() {
        let flows = [
            FlowSpec {
                src: 0,
                dst: 3,
                rate: 0.0,
                flits: 120,
            },
            FlowSpec {
                src: 2,
                dst: 5,
                rate: 0.0,
                flits: 77,
            },
        ];
        for topo in NopTopology::all() {
            let (stats, audit) = NopSim::new(
                topo,
                7,
                &cfg(),
                &flows,
                Mode::Drain {
                    max_cycles: 1_000_000,
                },
                13,
            )
            .run_audited();
            assert!(stats.drained, "{topo:?}");
            assert!(audit.min_credit >= 0, "{topo:?}: {}", audit.min_credit);
            for (n, &c) in audit.credits.iter().enumerate() {
                assert_eq!(c, audit.capacity, "{topo:?}: buffer {n} leaked credits");
            }
        }
    }

    #[test]
    fn mesh_relay_sites_forward_traffic() {
        // 7 chiplets on a 3x3 grid: routes may pass the passive relay
        // sites 7/8; traffic must still drain and conserve.
        let flows = [
            FlowSpec {
                src: 6,
                dst: 2,
                rate: 0.0,
                flits: 40,
            },
            FlowSpec {
                src: 1,
                dst: 6,
                rate: 0.0,
                flits: 25,
            },
        ];
        let s = drain(&flows, NopTopology::Mesh, 7, 17);
        assert!(s.drained);
        assert_eq!(s.delivered, 65);
    }

    #[test]
    fn self_flows_are_ignored() {
        let flows = [FlowSpec {
            src: 2,
            dst: 2,
            rate: 0.5,
            flits: 10,
        }];
        let s = drain(&flows, NopTopology::Ring, 4, 1);
        assert_eq!(s.injected, 0);
        assert!(s.drained);
    }

    #[test]
    fn per_pair_tracking_counts_flits() {
        let flows = [
            FlowSpec {
                src: 0,
                dst: 3,
                rate: 0.0,
                flits: 10,
            },
            FlowSpec {
                src: 1,
                dst: 2,
                rate: 0.0,
                flits: 5,
            },
        ];
        let s = NopSim::new(
            NopTopology::Mesh,
            4,
            &cfg(),
            &flows,
            Mode::Drain {
                max_cycles: 100_000,
            },
            5,
        )
        .track_pairs(true)
        .run();
        assert_eq!(s.per_pair.len(), 2);
        assert_eq!(s.per_pair[&3u64].count, 10);
        assert_eq!(s.per_pair[&((1u64 << 32) | 2)].count, 5);
    }

    #[test]
    fn instrumented_totals_match_stats() {
        let flows = [
            FlowSpec {
                src: 6,
                dst: 2,
                rate: 0.0,
                flits: 40,
            },
            FlowSpec {
                src: 1,
                dst: 6,
                rate: 0.0,
                flits: 25,
            },
        ];
        // k=7 mesh exercises the passive relay sites too.
        let (s, t) = NopSim::new(
            NopTopology::Mesh,
            7,
            &cfg(),
            &flows,
            Mode::Drain {
                max_cycles: 1_000_000,
            },
            17,
        )
        .instrument(true)
        .run_instrumented();
        assert!(s.drained);
        assert_eq!(t.injected_total(), s.injected);
        assert_eq!(t.ejected_total(), s.delivered);
        assert_eq!(t.injected[6], 40);
        assert_eq!(t.ejected[6], 25);
        assert_eq!(t.cycles, s.cycles);
        // Every delivered flit crossed at least one package link.
        assert!(t.transit_total() >= s.delivered);
        // Links are the sorted enumeration `new` built buffers from.
        let mut sorted = t.links.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, t.links);

        // Uninstrumented runs return empty telemetry and identical stats.
        let (s2, empty) = NopSim::new(
            NopTopology::Mesh,
            7,
            &cfg(),
            &flows,
            Mode::Drain {
                max_cycles: 1_000_000,
            },
            17,
        )
        .run_instrumented();
        assert_eq!(s2.makespan, s.makespan);
        assert!(empty.links.is_empty());
        assert_eq!(empty.injected_total(), 0);
    }
}
