//! Hierarchical package evaluation: per-chiplet NoC + package-level NoP.
//!
//! The single-chip evaluator ([`crate::arch::evaluator`]) rolls a DNN's
//! mapping, compute fabric and one flat interconnect into latency / energy
//! / area / EDAP. This module is the same composition one level up:
//!
//! * every populated chiplet runs the **unchanged** per-chip machinery —
//!   [`AnalyticalModel`] or [`NocSim`] — over its *local* tiles,
//! * traffic whose producer and consumer layers live on different chiplets
//!   crosses the [`NopNetwork`] at SerDes cost ([`NopConfig`]) and is then
//!   distributed from the consumer chiplet's gateway tile (local tile 0)
//!   over the local NoC,
//! * a layer's frame contribution is `max(compute, local_comm + nop_comm)`:
//!   both interconnect levels overlap compute (outputs stream), but package
//!   transit and local distribution serialize with each other.

use crate::arch::evaluator::CommBackend;
use crate::circuit::ChipCost;
use crate::config::{ArchConfig, NocConfig, NopConfig, NopMode, SimConfig};
use crate::dnn::DnnGraph;
use crate::mapping::{ChipletPartition, InjectionMatrix, Mapping};
use crate::noc::analytical::AnalyticalModel;
use crate::noc::latency::flits_per_pair;
use crate::noc::sim::{FlowSpec, Mode, NocSim};
use crate::noc::topology::{Network, Topology};
use crate::noc::NocPower;
use crate::nop::topology::{NopNetwork, NopTopology};

/// Full evaluation result for one (DNN, chiplet count, NoP, NoC) point.
#[derive(Clone, Debug)]
pub struct NopEvaluation {
    /// Zoo model name.
    pub dnn: String,
    /// Tile-level topology inside each chiplet.
    pub noc_topology: Topology,
    /// Package-level topology.
    pub nop_topology: NopTopology,
    /// Package size (requested chiplets).
    pub chiplets: usize,
    /// Chiplets that actually hold layers.
    pub populated: usize,
    /// Total tiles across the package.
    pub tiles: usize,
    /// Tiles mapped onto each chiplet, by chiplet id.
    pub tiles_per_chiplet: Vec<usize>,
    /// Bits/frame crossing chiplet boundaries (the NoP load).
    pub cross_bits: u64,
    /// Compute latency per frame, seconds (circuit model, identical to
    /// the single-chip path).
    pub compute_latency_s: f64,
    /// Compute energy per frame, joules.
    pub compute_energy_j: f64,
    /// Compute area, mm².
    pub compute_area_mm2: f64,
    /// Exposed (non-overlapped) latency of the on-chiplet NoCs, seconds.
    pub noc_latency_s: f64,
    /// On-chiplet NoC energy per frame, joules.
    pub noc_energy_j: f64,
    /// On-chiplet NoC area, mm².
    pub noc_area_mm2: f64,
    /// Exposed latency of the package NoP, seconds.
    pub nop_latency_s: f64,
    /// NoP transfer energy per frame, joules.
    pub nop_energy_j: f64,
    /// SerDes PHY area, mm².
    pub nop_area_mm2: f64,
}

impl NopEvaluation {
    /// End-to-end inference latency per frame, seconds.
    pub fn latency_s(&self) -> f64 {
        self.compute_latency_s + self.noc_latency_s + self.nop_latency_s
    }

    /// Total energy per frame, J.
    pub fn energy_j(&self) -> f64 {
        self.compute_energy_j + self.noc_energy_j + self.nop_energy_j
    }

    /// Total package silicon area, mm² (chiplets + NoCs + SerDes PHYs).
    pub fn area_mm2(&self) -> f64 {
        self.compute_area_mm2 + self.noc_area_mm2 + self.nop_area_mm2
    }

    /// Throughput in frames/s (1 / latency).
    pub fn fps(&self) -> f64 {
        1.0 / self.latency_s()
    }

    /// Average power draw, watts.
    pub fn power_w(&self) -> f64 {
        self.energy_j() / self.latency_s()
    }

    /// Energy-delay-area product, J·ms·mm² (the paper's headline metric).
    pub fn edap(&self) -> f64 {
        self.edap_with_latency(self.latency_s())
    }

    /// EDAP at a substituted frame latency — keeps derated rankings (e.g.
    /// the sim-calibrated scale-out advisor) on the same formula as
    /// [`NopEvaluation::edap`].
    pub fn edap_with_latency(&self, latency_s: f64) -> f64 {
        self.energy_j() * (latency_s * 1e3) * self.area_mm2()
    }

    /// Communication (NoC + NoP) share of end-to-end latency.
    pub fn comm_fraction(&self) -> f64 {
        (self.noc_latency_s + self.nop_latency_s) / self.latency_s()
    }
}

/// Core-clock cycles to move `bits` across `hops` package links.
///
/// The transfer serializes into `ceil(bits / link_width)` NoP flits at one
/// flit per NoP cycle, plus a fixed SerDes/trace latency per hop; NoP
/// cycles are converted to core cycles by the clock ratio. This is the
/// hand-checkable kernel of the hierarchical composition.
pub fn nop_transfer_cycles(bits: u64, hops: usize, nop: &NopConfig, core_freq_hz: f64) -> f64 {
    if bits == 0 || hops == 0 {
        return 0.0;
    }
    nop_flit_cycles(
        bits.div_ceil(nop.link_width as u64),
        hops,
        nop,
        core_freq_hz,
    )
}

/// Flit-level form of [`nop_transfer_cycles`]: `flits` is the load on the
/// busiest package link (already serialized into NoP flits). The evaluator
/// uses this directly so the per-layer package term and the hand-checked
/// kernel cannot drift apart.
fn nop_flit_cycles(flits: u64, hops: usize, nop: &NopConfig, core_freq_hz: f64) -> f64 {
    if flits == 0 {
        return 0.0;
    }
    let nop_cycles = flits as f64 + (hops as u64 * nop.hop_latency_cycles) as f64;
    nop_cycles * (core_freq_hz / nop.freq_hz)
}

/// Evaluate `graph` on a package of `nop.chiplets` IMC chiplets.
///
/// Each chiplet runs `noc.topology` over its local tiles; the package runs
/// `nop.topology`. `backend` selects the per-chiplet interconnect engine
/// exactly as in the single-chip path.
pub fn evaluate_package(
    graph: &DnnGraph,
    arch: &ArchConfig,
    noc: &NocConfig,
    nop: &NopConfig,
    sim: &SimConfig,
    backend: CommBackend,
) -> NopEvaluation {
    let mapping = Mapping::build(graph, arch);
    let chip = ChipCost::evaluate(graph, &mapping, arch);
    let inj = InjectionMatrix::build(graph, &mapping, arch, noc);
    let part = ChipletPartition::build(graph, &mapping, arch, nop.chiplets);
    let nop_net = NopNetwork::build(nop.topology, nop.chiplets);

    // Per-chiplet local networks (None for unpopulated chiplets).
    let nets: Vec<Option<Network>> = part
        .tiles_per_chiplet
        .iter()
        .map(|&t| (t > 0).then(|| Network::build(noc.topology, t)))
        .collect();

    // graph layer index -> mapping index (for producer chiplet lookups).
    let mut midx = vec![usize::MAX; graph.layers.len()];
    for (i, lt) in mapping.layers.iter().enumerate() {
        midx[lt.layer] = i;
    }

    let eject_cap = if noc.topology.has_routers() {
        arch.ces_per_tile as f64
    } else {
        0.5
    };

    let mut frame_cycles = 0.0f64;
    let mut noc_exposed_cycles = 0.0f64;
    let mut nop_exposed_cycles = 0.0f64;

    for (i, lt) in mapping.layers.iter().enumerate() {
        let compute_cycles = chip.per_layer[i].cycles as f64;
        let c = part.chiplet_of_layer(i);
        let net = nets[c].as_ref().expect("consumer chiplet is populated");
        let model = AnalyticalModel::new(net, noc);

        // Split this layer's inbound traffic into local flows (drain-style
        // flit counts, local tile ids) and NoP transfers.
        let mut dflows: Vec<FlowSpec> = Vec::new();
        let mut nop_dflows: Vec<FlowSpec> = Vec::new();
        let mut nop_hop_max = 0usize;
        let mut nop_link_load: std::collections::HashMap<(usize, usize), u64> =
            std::collections::HashMap::new();
        for f in inj.flows_into(lt.layer) {
            let src_chiplet = part.chiplet_of_layer(midx[f.src_layer]);
            let dst_count = f.dst_tiles.len();
            if src_chiplet == c {
                // Intra-chiplet: the usual all-pairs bundle, relocalized.
                let pairs = f.src_tiles.len() * dst_count;
                let flits = flits_per_pair(f.activations, arch.n_bits, pairs, noc.bus_width);
                for s in f.src_tiles.clone() {
                    for d in f.dst_tiles.clone() {
                        dflows.push(FlowSpec {
                            src: part.local_tile(s),
                            dst: part.local_tile(d),
                            rate: 0.0,
                            flits,
                        });
                    }
                }
            } else {
                // Cross-chiplet: the whole bundle crosses the NoP, then
                // fans out from the gateway (local tile 0) over the NoC.
                let bits = f.activations as u64 * arch.n_bits as u64;
                let flits_nop = bits.div_ceil(nop.link_width as u64);
                match nop.mode {
                    NopMode::Analytical => {
                        // Link-load/hop bookkeeping feeds only the
                        // analytical package term; the simulator routes
                        // for itself.
                        let path = nop_net.route_path(src_chiplet, c);
                        for w in path.windows(2) {
                            *nop_link_load.entry((w[0], w[1])).or_default() += flits_nop;
                        }
                        nop_hop_max = nop_hop_max.max(path.len() - 1);
                    }
                    // Surrogate prices the same flow set the simulator
                    // would see, so it collects flows like Sim.
                    NopMode::Sim | NopMode::Surrogate => nop_dflows.push(FlowSpec {
                        src: src_chiplet,
                        dst: c,
                        rate: 0.0,
                        flits: flits_nop,
                    }),
                }
                let flits_gw = flits_per_pair(f.activations, arch.n_bits, dst_count, noc.bus_width);
                for d in f.dst_tiles.clone() {
                    dflows.push(FlowSpec {
                        src: 0,
                        dst: part.local_tile(d),
                        rate: 0.0,
                        flits: flits_gw,
                    });
                }
            }
        }
        // Drop degenerate self-flows (e.g. gateway -> gateway).
        dflows.retain(|f| f.src != f.dst);

        // Package transit in core cycles. Analytical: bandwidth bound on
        // the busiest NoP link plus the per-hop SerDes latency. Sim: the
        // measured drain makespan of this layer's package flows through
        // the flit-level simulator (credit stalls and link contention
        // included), converted by the clock ratio. Surrogate: the fitted
        // drain curve stands in for the simulator, with sim fallback.
        let nop_cycles = match nop.mode {
            NopMode::Analytical => {
                let nop_bottleneck = nop_link_load.values().copied().max().unwrap_or(0);
                nop_flit_cycles(nop_bottleneck, nop_hop_max, nop, arch.freq_hz)
            }
            NopMode::Sim | NopMode::Surrogate => {
                if nop_dflows.is_empty() {
                    0.0
                } else {
                    let total: u64 = nop_dflows.iter().map(|f| f.flits).sum();
                    // Generous budget: full serialization of every flit over
                    // the worst route would still fit; saturation is
                    // reported via the budget, not a hang.
                    let budget = 10_000
                        + total
                            .saturating_mul(4)
                            .saturating_mul(nop.hop_latency_cycles + 2);
                    // Surrogate: the fitted drain curve prices the flow set
                    // without simulating. Keyed on the base seed (not the
                    // per-layer xor) so one fit serves every layer; `None`
                    // falls through to the full memoized drain.
                    let estimate = if nop.mode == NopMode::Surrogate {
                        crate::sim::surrogate::drain_estimate(
                            nop.topology,
                            nop.chiplets,
                            nop,
                            &nop_dflows,
                            sim.seed,
                        )
                        .map(|m| m.min(budget))
                    } else {
                        None
                    };
                    let nop_native = match estimate {
                        Some(makespan) => makespan,
                        None => {
                            // Memoized: repeated evaluations of the same
                            // layer's package flows (sweeps, the advisor,
                            // serving-model builds) simulate once.
                            let stats = crate::sim::memo::drain_makespan(
                                nop.topology,
                                nop.chiplets,
                                nop,
                                &nop_dflows,
                                budget,
                                sim.seed ^ lt.layer as u64,
                            );
                            if stats.drained { stats.makespan } else { budget }
                        }
                    };
                    nop_native as f64 * (arch.freq_hz / nop.freq_hz)
                }
            }
        };

        // Local distribution: identical model to the single-chip path.
        let noc_cycles = if dflows.is_empty() {
            0.0
        } else {
            let (bottleneck, _) = model.layer_bottleneck_with_eject(&dflows, eject_cap);
            let zero_load = model.zero_load(&dflows).max(1.0);
            let window = compute_cycles.max(1.0);
            let pflows: Vec<FlowSpec> = dflows
                .iter()
                .map(|f| FlowSpec {
                    src: f.src,
                    dst: f.dst,
                    rate: (f.flits as f64 / window).min(1.0),
                    flits: 0,
                })
                .collect();
            let avg_latency = match backend {
                CommBackend::Analytical => model.layer_latency(&pflows).avg_latency,
                CommBackend::Simulate => {
                    NocSim::new(
                        noc.topology,
                        part.tiles_per_chiplet[c],
                        noc,
                        &pflows,
                        Mode::Steady {
                            warmup: sim.warmup_cycles,
                            measure: sim.measure_cycles,
                        },
                        sim.seed ^ lt.layer as u64,
                    )
                    .run()
                    .avg_latency
                }
            };
            bottleneck + avg_latency.max(zero_load).min(zero_load * 100.0)
        };

        let comm = noc_cycles + nop_cycles;
        frame_cycles += compute_cycles.max(comm);
        let exposed = (comm - compute_cycles).max(0.0);
        if comm > 0.0 {
            noc_exposed_cycles += exposed * (noc_cycles / comm);
            nop_exposed_cycles += exposed * (nop_cycles / comm);
        }
    }

    let noc_latency_s = noc_exposed_cycles / arch.freq_hz;
    let nop_latency_s = nop_exposed_cycles / arch.freq_hz;

    // --- Energy & area ---------------------------------------------------
    let tile_edge_mm = (chip.area_mm2 / mapping.total_tiles.max(1) as f64)
        .sqrt()
        .max(0.1);
    let powers: Vec<Option<NocPower>> = nets
        .iter()
        .map(|n| {
            n.as_ref()
                .map(|net| NocPower::new(net, noc, arch.tech_nm, tile_edge_mm))
        })
        .collect();

    let mut noc_energy_j = 0.0f64;
    let mut nop_energy_j = 0.0f64;
    for f in &inj.flows {
        let src_chiplet = part.chiplet_of_layer(midx[f.src_layer]);
        let dst_chiplet = part.chiplet_of_layer(midx[f.dst_layer]);
        let dst_count = f.dst_tiles.len();
        if src_chiplet == dst_chiplet {
            let net = nets[src_chiplet].as_ref().unwrap();
            let power = powers[src_chiplet].as_ref().unwrap();
            let pairs = f.src_tiles.len() * dst_count;
            let flits = flits_per_pair(f.activations, arch.n_bits, pairs, noc.bus_width) as f64;
            for s in f.src_tiles.clone() {
                for d in f.dst_tiles.clone() {
                    if s == d {
                        continue;
                    }
                    let hops = net.hops(part.local_tile(s), part.local_tile(d));
                    noc_energy_j += flits * power.flit_energy_j(hops);
                }
            }
        } else {
            // Package crossing + gateway fan-out on the destination chiplet.
            let bits = f.activations as f64 * arch.n_bits as f64;
            let hops = nop_net.hops(src_chiplet, dst_chiplet);
            nop_energy_j += bits * hops as f64 * nop.energy_pj_per_bit * 1e-12;
            let net = nets[dst_chiplet].as_ref().unwrap();
            let power = powers[dst_chiplet].as_ref().unwrap();
            let flits_gw =
                flits_per_pair(f.activations, arch.n_bits, dst_count, noc.bus_width) as f64;
            for d in f.dst_tiles.clone() {
                let ld = part.local_tile(d);
                if ld == 0 {
                    continue; // destination is the gateway itself
                }
                noc_energy_j += flits_gw * power.flit_energy_j(net.hops(0, ld));
            }
        }
    }
    let comm_latency_s = noc_latency_s + nop_latency_s;
    let noc_leakage_w: f64 = powers
        .iter()
        .flatten()
        .map(|p| p.leakage_w)
        .sum();
    noc_energy_j += noc_leakage_w * comm_latency_s;

    let noc_area_mm2: f64 = powers.iter().flatten().map(|p| p.area_mm2).sum();
    let nop_area_mm2: f64 = (0..nop.chiplets)
        .filter(|&c| part.tiles_per_chiplet[c] > 0)
        .map(|c| nop_net.ports(c) as f64 * nop.phy_area_mm2)
        .sum();

    NopEvaluation {
        dnn: graph.name.clone(),
        noc_topology: noc.topology,
        nop_topology: nop.topology,
        chiplets: nop.chiplets,
        populated: part.populated_chiplets(),
        tiles: mapping.total_tiles,
        tiles_per_chiplet: part.tiles_per_chiplet.clone(),
        cross_bits: part.cut_bits(),
        compute_latency_s: chip.latency_s,
        compute_energy_j: chip.energy_j,
        compute_area_mm2: chip.area_mm2,
        noc_latency_s,
        noc_energy_j,
        noc_area_mm2,
        nop_latency_s,
        nop_energy_j,
        nop_area_mm2,
    }
}

/// Aggregate a model's cross-chiplet transfers into one package flow set:
/// total NoP flits per (producer chiplet, consumer chiplet) pair over all
/// layers, in sorted pair order. This is the traffic the telemetry link
/// heatmap visualizes (`repro chiplet --heatmap`); running it through an
/// instrumented [`NopSim`](crate::nop::sim::NopSim) drain shows which
/// package links the partition
/// actually loads.
pub fn package_flows(
    graph: &DnnGraph,
    arch: &ArchConfig,
    noc: &NocConfig,
    nop: &NopConfig,
) -> Vec<FlowSpec> {
    let mapping = Mapping::build(graph, arch);
    let inj = InjectionMatrix::build(graph, &mapping, arch, noc);
    let part = ChipletPartition::build(graph, &mapping, arch, nop.chiplets);
    let mut midx = vec![usize::MAX; graph.layers.len()];
    for (i, lt) in mapping.layers.iter().enumerate() {
        midx[lt.layer] = i;
    }
    let mut per_pair: std::collections::HashMap<(usize, usize), u64> =
        std::collections::HashMap::new();
    for (i, lt) in mapping.layers.iter().enumerate() {
        let c = part.chiplet_of_layer(i);
        for f in inj.flows_into(lt.layer) {
            let src_chiplet = part.chiplet_of_layer(midx[f.src_layer]);
            if src_chiplet != c {
                let bits = f.activations as u64 * arch.n_bits as u64;
                *per_pair.entry((src_chiplet, c)).or_default() +=
                    bits.div_ceil(nop.link_width as u64);
            }
        }
    }
    let mut pairs: Vec<_> = per_pair.into_iter().collect();
    pairs.sort_unstable();
    pairs
        .into_iter()
        .map(|((src, dst), flits)| FlowSpec {
            src,
            dst,
            rate: 0.0,
            flits,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::evaluator::evaluate;
    use crate::dnn::{models, Dataset, DnnGraph};
    use crate::nop::topology::NopTopology;

    fn defaults() -> (ArchConfig, NocConfig, SimConfig) {
        (
            ArchConfig::default(),
            NocConfig::default(),
            SimConfig::default(),
        )
    }

    #[test]
    fn transfer_cycles_hand_computed() {
        let nop = NopConfig::default(); // width 32, hop 20 cycles, 0.5 GHz
        // 4096 bits / 32 = 128 flits; 2 hops -> 128 + 40 = 168 NoP cycles;
        // core at 1 GHz = 2x the NoP clock -> 336 core cycles.
        assert_eq!(nop_transfer_cycles(4096, 2, &nop, 1.0e9), 336.0);
        // Zero traffic or zero hops cost nothing.
        assert_eq!(nop_transfer_cycles(0, 3, &nop, 1.0e9), 0.0);
        assert_eq!(nop_transfer_cycles(4096, 0, &nop, 1.0e9), 0.0);
        // Partial flits round up: 33 bits -> 2 flits.
        let one_hop = nop_transfer_cycles(33, 1, &nop, 1.0e9);
        assert_eq!(one_hop, (2.0 + 20.0) * 2.0);
    }

    #[test]
    fn single_chiplet_matches_single_chip_evaluator() {
        // A 1-chiplet package is exactly the single-chip architecture: the
        // hierarchical path must reproduce the flat evaluator's numbers.
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            chiplets: 1,
            ..NopConfig::default()
        };
        for g in [models::lenet5(), models::mlp()] {
            let pkg = evaluate_package(&g, &arch, &noc, &nop, &sim, CommBackend::Analytical);
            let flat = evaluate(
                &g,
                noc.topology,
                &arch,
                &noc,
                &sim,
                CommBackend::Analytical,
            );
            assert_eq!(pkg.cross_bits, 0, "{}", g.name);
            assert_eq!(pkg.nop_latency_s, 0.0);
            assert_eq!(pkg.nop_energy_j, 0.0);
            let rel = |a: f64, b: f64| (a - b).abs() / b.max(1e-30);
            assert!(
                rel(pkg.latency_s(), flat.latency_s()) < 1e-9,
                "{}: {} vs {}",
                g.name,
                pkg.latency_s(),
                flat.latency_s()
            );
            assert!(rel(pkg.compute_energy_j, flat.compute_energy_j) < 1e-12);
            assert!(rel(pkg.noc_area_mm2, flat.noc_area_mm2) < 1e-9);
        }
    }

    #[test]
    fn two_chiplet_composition_hand_computed() {
        // fc1 784->128 (1 tile, chiplet 0) feeds fc2 128->64 (1 tile,
        // chiplet 1). The only traffic is the 128x8 = 1024-bit package
        // transfer: 32 NoP flits + 20 hop cycles = 52 NoP cycles = 104 core
        // cycles (2x clock ratio). The gateway IS the destination tile, so
        // local NoC cost is zero.
        let mut g = DnnGraph::new("two-fc-2chiplet", Dataset::Mnist);
        let f1 = g.fc("fc1", 0, 128);
        g.fc("fc2", f1, 64);
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Ring,
            chiplets: 2,
            ..NopConfig::default()
        };
        let pkg = evaluate_package(&g, &arch, &noc, &nop, &sim, CommBackend::Analytical);
        assert_eq!(pkg.tiles_per_chiplet, vec![1, 1]);
        assert_eq!(pkg.cross_bits, 128 * 8);

        let mapping = Mapping::build(&g, &arch);
        let chip = ChipCost::evaluate(&g, &mapping, &arch);
        let c1 = chip.per_layer[0].cycles as f64;
        let c2 = chip.per_layer[1].cycles as f64;
        let nop_cycles = nop_transfer_cycles(128 * 8, 1, &nop, arch.freq_hz);
        assert_eq!(nop_cycles, (32.0 + 20.0) * 2.0);
        let expected_frame = c1 + c2.max(nop_cycles);
        let expected_latency_s = expected_frame / arch.freq_hz;
        assert!(
            (pkg.latency_s() - expected_latency_s).abs() < 1e-15,
            "latency {} vs expected {}",
            pkg.latency_s(),
            expected_latency_s
        );
        assert_eq!(pkg.noc_latency_s, 0.0, "gateway==dst means no local leg");
        // NoP energy: 1024 bits x 1 hop x 1.5 pJ/bit.
        let expected_nop_j = 1024.0 * 1.5e-12;
        assert!((pkg.nop_energy_j - expected_nop_j).abs() < 1e-20);
    }

    #[test]
    fn package_flows_aggregate_cross_traffic() {
        // Same two-chiplet graph as the hand-computed composition: the
        // only cross-chiplet transfer is fc1 -> fc2, 128 x 8 bits over
        // 32-bit NoP links = 32 flits.
        let mut g = DnnGraph::new("two-fc-flows", Dataset::Mnist);
        let f1 = g.fc("fc1", 0, 128);
        g.fc("fc2", f1, 64);
        let (arch, noc, _) = defaults();
        let nop = NopConfig {
            topology: NopTopology::Ring,
            chiplets: 2,
            ..NopConfig::default()
        };
        let flows = package_flows(&g, &arch, &noc, &nop);
        assert_eq!(flows.len(), 1);
        assert_eq!((flows[0].src, flows[0].dst), (0, 1));
        assert_eq!(flows[0].flits, 32);
        // A single-chiplet package carries no cross traffic at all.
        let one = NopConfig {
            chiplets: 1,
            ..NopConfig::default()
        };
        assert!(package_flows(&models::mlp(), &arch, &noc, &one).is_empty());
    }

    #[test]
    fn vgg_package_reports_all_nop_topologies() {
        let (arch, noc, sim) = defaults();
        for topo in NopTopology::all() {
            let nop = NopConfig {
                topology: topo,
                chiplets: 4,
                ..NopConfig::default()
            };
            let e = evaluate_package(
                &models::vgg(19),
                &arch,
                &noc,
                &nop,
                &sim,
                CommBackend::Analytical,
            );
            assert_eq!(e.populated, 4);
            assert!(e.cross_bits > 0);
            assert!(e.latency_s() > 0.0 && e.latency_s().is_finite());
            assert!(e.energy_j() > 0.0 && e.edap() > 0.0);
            assert!(e.nop_area_mm2 > 0.0);
            assert!(e.comm_fraction() >= 0.0 && e.comm_fraction() < 1.0);
        }
    }

    #[test]
    fn more_chiplets_add_package_cost() {
        // Same DNN, same NoC: an 8-chiplet package must carry at least as
        // much NoP energy as a 2-chiplet one (more cut edges), and a
        // 1-chiplet package carries none.
        let (arch, noc, sim) = defaults();
        let g = models::resnet(50);
        let e = |k: usize| {
            let nop = NopConfig {
                chiplets: k,
                ..NopConfig::default()
            };
            evaluate_package(&g, &arch, &noc, &nop, &sim, CommBackend::Analytical)
        };
        let e1 = e(1);
        let e2 = e(2);
        let e8 = e(8);
        assert_eq!(e1.nop_energy_j, 0.0);
        assert!(e2.nop_energy_j > 0.0);
        assert!(e8.cross_bits >= e2.cross_bits);
        assert!(e8.nop_area_mm2 > e2.nop_area_mm2);
    }

    #[test]
    fn single_chiplet_sim_mode_matches_flat_simulator() {
        // Extends the 1-chiplet equivalence to the fully simulated path: a
        // 1-chiplet package has no package flows, so `mode = sim` with the
        // cycle-accurate per-chiplet backend must reproduce the flat
        // single-chip NocSim numbers exactly (same seeds, same flows).
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            chiplets: 1,
            mode: NopMode::Sim,
            ..NopConfig::default()
        };
        for g in [models::lenet5(), models::mlp()] {
            let pkg = evaluate_package(&g, &arch, &noc, &nop, &sim, CommBackend::Simulate);
            let flat = evaluate(&g, noc.topology, &arch, &noc, &sim, CommBackend::Simulate);
            assert_eq!(pkg.cross_bits, 0, "{}", g.name);
            assert_eq!(pkg.nop_latency_s, 0.0);
            assert_eq!(pkg.nop_energy_j, 0.0);
            let rel = |a: f64, b: f64| (a - b).abs() / b.max(1e-30);
            assert!(
                rel(pkg.latency_s(), flat.latency_s()) < 1e-12,
                "{}: {} vs {}",
                g.name,
                pkg.latency_s(),
                flat.latency_s()
            );
            assert!(rel(pkg.noc_energy_j, flat.comm_energy_j) < 1e-12, "{}", g.name);
            assert!(rel(pkg.noc_area_mm2, flat.noc_area_mm2) < 1e-12, "{}", g.name);
        }
    }

    #[test]
    fn sim_mode_stays_in_band_of_analytical_at_low_chiplet_count() {
        // With only two chiplets the package carries one thin cut: the
        // flit-level NoP makespan must land within a loose band of the
        // analytical bandwidth+latency estimate (it adds credit stalls and
        // per-flit pipelining the closed form ignores).
        let (arch, noc, sim) = defaults();
        let g = models::nin();
        let run = |mode: NopMode| {
            let nop = NopConfig {
                topology: NopTopology::Ring,
                chiplets: 2,
                mode,
                ..NopConfig::default()
            };
            evaluate_package(&g, &arch, &noc, &nop, &sim, CommBackend::Analytical)
        };
        let ana = run(NopMode::Analytical);
        let cyc = run(NopMode::Sim);
        assert_eq!(ana.cross_bits, cyc.cross_bits);
        assert_eq!(ana.compute_latency_s, cyc.compute_latency_s);
        assert!(cyc.nop_latency_s >= 0.0);
        let ratio = cyc.latency_s() / ana.latency_s();
        assert!((0.5..2.0).contains(&ratio), "sim/analytical ratio {ratio}");
    }

    #[test]
    fn surrogate_mode_stays_in_band_of_sim() {
        // The fitted drain curve must track the simulator it stands in
        // for: same flow collection, same budget clamp, loose band on the
        // end-to-end latency (the surrogate smooths per-layer seed noise
        // the sim path keeps).
        let (arch, noc, sim) = defaults();
        let g = models::nin();
        let run = |mode: NopMode| {
            let nop = NopConfig {
                topology: NopTopology::Mesh,
                chiplets: 4,
                mode,
                ..NopConfig::default()
            };
            evaluate_package(&g, &arch, &noc, &nop, &sim, CommBackend::Analytical)
        };
        let cyc = run(NopMode::Sim);
        let sur = run(NopMode::Surrogate);
        assert_eq!(cyc.cross_bits, sur.cross_bits);
        assert_eq!(cyc.compute_latency_s, sur.compute_latency_s);
        assert!(sur.nop_latency_s >= 0.0);
        let ratio = sur.latency_s() / cyc.latency_s();
        assert!((0.5..2.0).contains(&ratio), "surrogate/sim ratio {ratio}");
    }

    #[test]
    fn cycle_accurate_backend_agrees_roughly() {
        let (arch, noc, sim) = defaults();
        let nop = NopConfig {
            chiplets: 2,
            ..NopConfig::default()
        };
        let g = models::lenet5();
        let ana = evaluate_package(&g, &arch, &noc, &nop, &sim, CommBackend::Analytical);
        let cyc = evaluate_package(&g, &arch, &noc, &nop, &sim, CommBackend::Simulate);
        // Same structure and compute; comm within a loose band.
        assert_eq!(ana.cross_bits, cyc.cross_bits);
        assert_eq!(ana.compute_latency_s, cyc.compute_latency_s);
        let ratio = ana.latency_s() / cyc.latency_s();
        assert!((0.3..3.0).contains(&ratio), "ratio {ratio}");
    }
}
