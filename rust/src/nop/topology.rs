//! Package-level (chiplet-to-chiplet) interconnect topologies with
//! deterministic routing — the NoP mirror of [`crate::noc::topology`].
//!
//! A [`NopNetwork`] connects `k` chiplets sitting on a 2.5D interposer.
//! Unlike on-chip wires, package links are SerDes lanes: few, narrow,
//! higher-latency and costlier per bit ([`crate::config::NopConfig`]), so
//! the interesting topologies are sparse ones.

/// Topology of the package-level (chiplet) interconnect.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NopTopology {
    /// A dedicated link between every chiplet pair (full point-to-point
    /// crossbar of package traces). One hop everywhere, but the lane count
    /// grows as k·(k−1) — viable only for small packages.
    P2p,
    /// Bidirectional ring around the package perimeter; shortest-direction
    /// routing. Two lanes per chiplet regardless of k.
    Ring,
    /// 2-D mesh of chiplets on the interposer, X-Y routing — the NoP used
    /// by SIMBA-class 2.5D packages. Grid sites without a chiplet carry a
    /// passive relay (redistribution-layer switch).
    Mesh,
}

impl NopTopology {
    /// Display name as printed in tables.
    pub fn name(self) -> &'static str {
        match self {
            NopTopology::P2p => "P2P",
            NopTopology::Ring => "ring",
            NopTopology::Mesh => "mesh",
        }
    }

    /// Parse a case-insensitive topology name (`nop-` prefix optional).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().replace("nop-", "").as_str() {
            "p2p" => Some(NopTopology::P2p),
            "ring" => Some(NopTopology::Ring),
            "mesh" => Some(NopTopology::Mesh),
            _ => None,
        }
    }

    /// Every package topology, in sweep order.
    pub fn all() -> [NopTopology; 3] {
        [NopTopology::P2p, NopTopology::Ring, NopTopology::Mesh]
    }

    /// The valid `parse` spellings, for CLI error messages.
    pub fn valid_names() -> &'static str {
        "P2P, ring, mesh"
    }
}

/// A built package network over `k` chiplets (chiplet ids are router ids;
/// mesh grids may contain passive relay sites beyond `k - 1`).
#[derive(Clone, Debug)]
pub struct NopNetwork {
    /// The topology this package was built as.
    pub topology: NopTopology,
    /// Chiplets in the package.
    pub chiplets: usize,
    /// Routing nodes (== chiplets, except mesh grids with relay sites).
    pub nodes: usize,
    /// Mesh dimensions (cols, rows); (0, 0) otherwise.
    pub dims: (usize, usize),
}

impl NopNetwork {
    /// Build a package network over `k` chiplets.
    pub fn build(topology: NopTopology, k: usize) -> Self {
        assert!(k > 0, "package needs at least one chiplet");
        let (nodes, dims) = match topology {
            NopTopology::P2p | NopTopology::Ring => (k, (0, 0)),
            NopTopology::Mesh => {
                let cols = (k as f64).sqrt().ceil() as usize;
                let rows = k.div_ceil(cols);
                (cols * rows, (cols, rows))
            }
        };
        Self {
            topology,
            chiplets: k,
            nodes,
            dims,
        }
    }

    /// Deterministic next node from `cur` toward chiplet `dst`.
    /// `cur == dst` is a caller error (no self-route).
    pub fn route_next(&self, cur: usize, dst: usize) -> usize {
        debug_assert_ne!(cur, dst);
        match self.topology {
            NopTopology::P2p => dst,
            NopTopology::Ring => {
                let k = self.chiplets;
                let cw = (dst + k - cur) % k;
                let ccw = (cur + k - dst) % k;
                if cw <= ccw {
                    (cur + 1) % k
                } else {
                    (cur + k - 1) % k
                }
            }
            NopTopology::Mesh => {
                let cols = self.dims.0;
                let (x, y) = (cur % cols, cur / cols);
                let (dx, dy) = (dst % cols, dst / cols);
                if x < dx {
                    cur + 1
                } else if x > dx {
                    cur - 1
                } else if y < dy {
                    cur + cols
                } else {
                    cur - cols
                }
            }
        }
    }

    /// Full deterministic route as a node list, inclusive of both ends.
    pub fn route_path(&self, src: usize, dst: usize) -> Vec<usize> {
        assert!(src < self.chiplets && dst < self.chiplets);
        let mut path = vec![src];
        while *path.last().unwrap() != dst {
            let next = self.route_next(*path.last().unwrap(), dst);
            path.push(next);
            assert!(
                path.len() <= self.nodes + 1,
                "NoP routing loop {src}->{dst} on {:?}",
                self.topology
            );
        }
        path
    }

    /// The directed links of the deterministic `src`→`dst` route, as
    /// (from, to) node pairs — the shared route→links convention of the
    /// serving schedulers and the placement search. Empty for `src == dst`.
    pub fn route_links(&self, src: usize, dst: usize) -> Vec<(usize, usize)> {
        if src == dst {
            return Vec::new();
        }
        self.route_path(src, dst)
            .windows(2)
            .map(|w| (w[0], w[1]))
            .collect()
    }

    /// Package hops (links traversed) between two chiplets.
    pub fn hops(&self, src: usize, dst: usize) -> usize {
        if src == dst {
            return 0;
        }
        match self.topology {
            NopTopology::P2p => 1,
            NopTopology::Ring => {
                let k = self.chiplets;
                let cw = (dst + k - src) % k;
                cw.min(k - cw)
            }
            NopTopology::Mesh => {
                let cols = self.dims.0;
                let (x, y) = (src % cols, src / cols);
                let (dx, dy) = (dst % cols, dst / cols);
                x.abs_diff(dx) + y.abs_diff(dy)
            }
        }
    }

    /// Worst-case hop count — the bound the property tests assert.
    pub fn hop_bound(&self) -> usize {
        match self.topology {
            NopTopology::P2p => 1,
            NopTopology::Ring => self.chiplets / 2,
            NopTopology::Mesh => {
                let (cols, rows) = self.dims;
                cols.saturating_sub(1) + rows.saturating_sub(1)
            }
        }
        .max(1)
    }

    /// Unidirectional package links (SerDes lane bundles).
    pub fn link_count(&self) -> usize {
        let k = self.chiplets;
        match self.topology {
            NopTopology::P2p => k * (k - 1),
            NopTopology::Ring => {
                if k > 2 {
                    2 * k
                } else {
                    // 1 or 2 chiplets: a single (pair of) link(s), no cycle.
                    2 * (k - 1)
                }
            }
            NopTopology::Mesh => {
                let (cols, rows) = self.dims;
                // Horizontal + vertical grid links, both directions.
                2 * (rows * cols.saturating_sub(1) + cols * rows.saturating_sub(1))
            }
        }
    }

    /// SerDes port bundles on chiplet `c` (for PHY area accounting).
    pub fn ports(&self, c: usize) -> usize {
        let k = self.chiplets;
        match self.topology {
            NopTopology::P2p => k - 1,
            NopTopology::Ring => 2.min(k - 1),
            NopTopology::Mesh => {
                let (cols, rows) = self.dims;
                let (x, y) = (c % cols, c / cols);
                let mut p = 0;
                if x > 0 {
                    p += 1;
                }
                if x + 1 < cols {
                    p += 1;
                }
                if y > 0 {
                    p += 1;
                }
                if y + 1 < rows {
                    p += 1;
                }
                p
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names_roundtrip() {
        for t in NopTopology::all() {
            assert_eq!(NopTopology::parse(t.name()), Some(t), "{t:?}");
        }
        assert_eq!(NopTopology::parse("NoP-mesh"), Some(NopTopology::Mesh));
        assert_eq!(NopTopology::parse("hypertorus"), None);
    }

    #[test]
    fn p2p_is_single_hop() {
        let net = NopNetwork::build(NopTopology::P2p, 8);
        for s in 0..8 {
            for d in 0..8 {
                if s != d {
                    assert_eq!(net.hops(s, d), 1);
                    assert_eq!(net.route_path(s, d), vec![s, d]);
                }
            }
        }
        assert_eq!(net.link_count(), 8 * 7);
    }

    #[test]
    fn ring_takes_shortest_direction() {
        let net = NopNetwork::build(NopTopology::Ring, 6);
        assert_eq!(net.hops(0, 1), 1);
        assert_eq!(net.hops(0, 5), 1); // wrap
        assert_eq!(net.hops(0, 3), 3); // diameter
        assert_eq!(net.route_path(0, 5), vec![0, 5]);
        assert_eq!(net.route_path(1, 4), vec![1, 2, 3, 4]);
        assert!(net.hops(2, 5) <= net.hop_bound());
    }

    #[test]
    fn mesh_xy_routes() {
        let net = NopNetwork::build(NopTopology::Mesh, 4); // 2x2
        assert_eq!(net.dims, (2, 2));
        assert_eq!(net.hops(0, 3), 2);
        assert_eq!(net.route_path(0, 3), vec![0, 1, 3]); // X then Y
        assert_eq!(net.link_count(), 2 * (2 + 2));
    }

    #[test]
    fn mesh_partial_grid_routes_through_relays() {
        // 7 chiplets on a 3x3 grid: sites 7, 8 are passive relays.
        let net = NopNetwork::build(NopTopology::Mesh, 7);
        assert_eq!(net.dims, (3, 3));
        for s in 0..7 {
            for d in 0..7 {
                let path = net.route_path(s, d);
                assert_eq!(*path.first().unwrap(), s);
                assert_eq!(*path.last().unwrap(), d);
                assert_eq!(path.len() - 1, net.hops(s, d));
                assert!(net.hops(s, d) <= net.hop_bound());
            }
        }
    }

    #[test]
    fn tiny_packages_build() {
        for t in NopTopology::all() {
            for k in [1usize, 2, 3] {
                let net = NopNetwork::build(t, k);
                assert!(net.hop_bound() >= 1);
                if k == 1 {
                    assert_eq!(net.hops(0, 0), 0);
                } else {
                    assert!(net.hops(0, k - 1) >= 1);
                }
            }
        }
    }
}
