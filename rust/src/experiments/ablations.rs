//! Ablation studies on the design choices the paper fixes (DESIGN.md §5):
//!
//! * `ablation_adc` — flash-ADC resolution sweep (the paper picks 4 bits),
//! * `ablation_buffers` — router buffer-depth sweep (the paper picks 8),
//! * `ablation_pe` — crossbar-size sweep (paper §5.2 picks 256×256),
//! * `topology_exploration` — all six topologies incl. torus/hypercube
//!   (paper §2.3 dismisses them on power; we quantify).

use super::Options;
use crate::arch::{evaluate, recommend_topology};
use crate::config::{ArchConfig, NocConfig, SimConfig};
use crate::dnn::{eval_set, models};
use crate::noc::topology::Topology;
use crate::util::{fmt_sig, Table};

fn sim(opts: &Options) -> SimConfig {
    SimConfig {
        seed: opts.seed,
        ..SimConfig::default()
    }
}

/// ADC-resolution ablation: area/energy grow exponentially with bits while
/// compute latency is unchanged — EDAP has an interior optimum.
pub fn ablation_adc(opts: &Options) -> Result<Vec<Table>, String> {
    let mut t = Table::new(
        "Ablation — flash-ADC resolution (ReRAM, advisor topology)",
        &["dnn", "adc_bits", "latency_ms", "power_W", "area_mm2", "EDAP"],
    );
    let nets = [models::lenet5(), models::nin(), models::vgg(19)];
    for g in &nets {
        if opts.fast && g.total_macs() >= 1_000_000_000 {
            continue;
        }
        for adc_bits in [2usize, 4, 6, 8] {
            let arch = ArchConfig {
                adc_bits,
                ..ArchConfig::reram()
            };
            let rec = recommend_topology(g, &arch, &NocConfig::default());
            let e = evaluate(
                g,
                rec.topology,
                &arch,
                &NocConfig::with_topology(rec.topology),
                &sim(opts),
                opts.backend,
            );
            t.add_row(vec![
                g.name.clone(),
                adc_bits.to_string(),
                fmt_sig(e.latency_s() * 1e3, 4),
                fmt_sig(e.power_w(), 3),
                fmt_sig(e.area_mm2(), 4),
                fmt_sig(e.edap(), 3),
            ]);
        }
    }
    Ok(vec![t])
}

/// Buffer-depth ablation: NoC area/leakage grow with depth; DNN traffic is
/// too sparse to use it (ties to Fig. 13's near-empty queues).
pub fn ablation_buffers(opts: &Options) -> Result<Vec<Table>, String> {
    let mut t = Table::new(
        "Ablation — router buffer depth (ReRAM, mesh)",
        &["dnn", "buffer_depth", "noc_area_mm2", "comm_cycles", "EDAP"],
    );
    for g in eval_set() {
        if opts.fast && g.total_macs() >= 1_000_000_000 {
            continue;
        }
        for depth in [2usize, 4, 8, 16] {
            let arch = ArchConfig::reram();
            let noc = NocConfig {
                buffer_depth: depth,
                ..NocConfig::default()
            };
            let e = evaluate(&g, Topology::Mesh, &arch, &noc, &sim(opts), opts.backend);
            t.add_row(vec![
                g.name.clone(),
                depth.to_string(),
                fmt_sig(e.noc_area_mm2, 4),
                e.comm_cycles.to_string(),
                fmt_sig(e.edap(), 3),
            ]);
        }
    }
    Ok(vec![t])
}

/// Crossbar-size ablation (paper §5.2): EDAP by PE size per DNN.
pub fn ablation_pe(opts: &Options) -> Result<Vec<Table>, String> {
    let mut t = Table::new(
        "Ablation — crossbar (PE) size (ReRAM, advisor topology)",
        &["dnn", "pe_size", "tiles", "latency_ms", "EDAP"],
    );
    let nets = [models::lenet5(), models::squeezenet(), models::vgg(19)];
    for g in &nets {
        if opts.fast && g.total_macs() >= 1_000_000_000 {
            continue;
        }
        for pe in [64usize, 128, 256, 512] {
            let arch = ArchConfig {
                pe_size: pe,
                ..ArchConfig::reram()
            };
            let rec = recommend_topology(g, &arch, &NocConfig::default());
            let e = evaluate(
                g,
                rec.topology,
                &arch,
                &NocConfig::with_topology(rec.topology),
                &sim(opts),
                opts.backend,
            );
            t.add_row(vec![
                g.name.clone(),
                pe.to_string(),
                e.tiles.to_string(),
                fmt_sig(e.latency_s() * 1e3, 4),
                fmt_sig(e.edap(), 3),
            ]);
        }
    }
    Ok(vec![t])
}

/// All six topologies (paper §2.3): torus/hypercube/c-mesh cost more for
/// marginal latency gains over mesh.
pub fn topology_exploration(opts: &Options) -> Result<Vec<Table>, String> {
    let mut t = Table::new(
        "Topology exploration — all interconnects (ReRAM)",
        &["dnn", "topology", "latency_ms", "noc_area_mm2", "comm_energy_mJ", "EDAP"],
    );
    let nets = [models::nin(), models::resnet(50)];
    for g in &nets {
        if opts.fast && g.total_macs() >= 1_000_000_000 {
            continue;
        }
        for topo in Topology::all() {
            let arch = ArchConfig::reram();
            let e = evaluate(
                g,
                topo,
                &arch,
                &NocConfig::with_topology(topo),
                &sim(opts),
                opts.backend,
            );
            t.add_row(vec![
                g.name.clone(),
                topo.name().into(),
                fmt_sig(e.latency_s() * 1e3, 4),
                fmt_sig(e.noc_area_mm2, 4),
                fmt_sig(e.comm_energy_j * 1e3, 3),
                fmt_sig(e.edap(), 3),
            ]);
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::CommBackend;

    fn fast_opts() -> Options {
        Options {
            fast: true,
            backend: CommBackend::Analytical,
            ..Options::default()
        }
    }

    #[test]
    fn adc_area_grows_with_bits() {
        let t = &ablation_adc(&fast_opts()).unwrap()[0];
        // For each DNN, area must be monotone non-decreasing in adc_bits.
        let mut prev: Option<(String, f64)> = None;
        for row in &t.rows {
            let area: f64 = row[4].parse().unwrap();
            if let Some((ref name, p)) = prev {
                if *name == row[0] {
                    assert!(area >= p * 0.999, "{}: area shrank {p} -> {area}", row[0]);
                }
            }
            prev = Some((row[0].clone(), area));
        }
    }

    #[test]
    fn buffers_grow_noc_area_not_latency() {
        let t = &ablation_buffers(&fast_opts()).unwrap()[0];
        // Depth 16 vs depth 2 for the same DNN: area up, comm cycles equal
        // or better (queues are near-empty, Fig. 13).
        for g in ["MLP", "LeNet-5", "NiN"] {
            let rows: Vec<_> = t.rows.iter().filter(|r| r[0] == g).collect();
            if rows.is_empty() {
                continue;
            }
            let a2: f64 = rows[0][2].parse().unwrap();
            let a16: f64 = rows[3][2].parse().unwrap();
            assert!(a16 > a2, "{g}: buffer area must grow");
        }
    }

    #[test]
    fn topology_exploration_runs_all() {
        let t = &topology_exploration(&fast_opts()).unwrap()[0];
        assert_eq!(t.rows.len() % 6, 0);
    }
}
