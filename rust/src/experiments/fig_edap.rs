//! EDAP/topology-comparison experiments: Fig. 9 (tree/mesh/c-mesh EDAP),
//! Fig. 16/17 (tree vs mesh throughput + EDAP for SRAM/ReRAM), Fig. 18
//! (virtual-channel sweep), Fig. 19 (bus-width sweep).

use super::Options;
use crate::arch::evaluate;
use crate::config::{ArchConfig, NocConfig, SimConfig};
use crate::dnn::{eval_set, DnnGraph};
use crate::noc::topology::Topology;
use crate::util::{fmt_sig, Table};

fn eval_dnns(opts: &Options) -> Vec<DnnGraph> {
    if opts.fast {
        eval_set()
            .into_iter()
            .filter(|g| g.total_macs() < 1_000_000_000)
            .collect()
    } else {
        eval_set()
    }
}

fn sim_cfg(opts: &Options) -> SimConfig {
    SimConfig {
        seed: opts.seed,
        ..SimConfig::default()
    }
}

/// Fig. 9: EDAP of tree / mesh / c-mesh NoCs. Like the paper, this is the
/// EDAP of the *interconnect* (NoC energy × NoC latency × NoC area), not
/// of the whole chip — that is where c-mesh's resource overhead explodes.
pub fn fig9(opts: &Options) -> Result<Vec<Table>, String> {
    let arch = ArchConfig::reram();
    let sim = sim_cfg(opts);
    let mut t = Table::new(
        "Fig. 9 — NoC-only EDAP (J·ms·mm²) for NoC-tree / NoC-mesh / c-mesh",
        &["dnn", "NoC-tree", "NoC-mesh", "c-mesh", "cmesh/mesh"],
    );
    for g in eval_dnns(opts) {
        let edap: Vec<f64> = [Topology::Tree, Topology::Mesh, Topology::CMesh]
            .into_iter()
            .map(|topo| {
                let e = evaluate(
                    &g,
                    topo,
                    &arch,
                    &NocConfig::with_topology(topo),
                    &sim,
                    opts.backend,
                );
                let noc_latency_ms = e.comm_cycles as f64 / arch.freq_hz * 1e3;
                e.comm_energy_j * noc_latency_ms * e.noc_area_mm2
            })
            .collect();
        t.add_row(vec![
            g.name.clone(),
            fmt_sig(edap[0], 3),
            fmt_sig(edap[1], 3),
            fmt_sig(edap[2], 3),
            fmt_sig(edap[2] / edap[1], 3),
        ]);
    }
    Ok(vec![t])
}

/// Shared shape of Fig. 16/17: tree vs mesh normalized throughput & EDAP.
fn tree_vs_mesh(opts: &Options, arch: ArchConfig, fig: &str) -> Vec<Table> {
    let sim = sim_cfg(opts);
    let mut thr = Table::new(
        format!(
            "{fig}(a) — throughput normalized to NoC-tree ({})",
            arch.tech.name()
        ),
        &["dnn", "tree", "mesh", "winner"],
    );
    let mut edap = Table::new(
        format!(
            "{fig}(b) — EDAP normalized to NoC-tree ({})",
            arch.tech.name()
        ),
        &["dnn", "tree", "mesh", "winner"],
    );
    for g in eval_dnns(opts) {
        let t = evaluate(
            &g,
            Topology::Tree,
            &arch,
            &NocConfig::with_topology(Topology::Tree),
            &sim,
            opts.backend,
        );
        let m = evaluate(
            &g,
            Topology::Mesh,
            &arch,
            &NocConfig::with_topology(Topology::Mesh),
            &sim,
            opts.backend,
        );
        let thr_ratio = m.fps() / t.fps();
        let edap_ratio = m.edap() / t.edap();
        thr.add_row(vec![
            g.name.clone(),
            "1.00".into(),
            fmt_sig(thr_ratio, 3),
            if thr_ratio > 1.0 { "mesh" } else { "tree" }.into(),
        ]);
        edap.add_row(vec![
            g.name.clone(),
            "1.00".into(),
            fmt_sig(edap_ratio, 3),
            if edap_ratio < 1.0 { "mesh" } else { "tree" }.into(),
        ]);
    }
    vec![thr, edap]
}

/// Fig. 16: SRAM-based IMC, tree vs mesh.
pub fn fig16(opts: &Options) -> Result<Vec<Table>, String> {
    Ok(tree_vs_mesh(opts, ArchConfig::sram(), "Fig. 16"))
}

/// Fig. 17: ReRAM-based IMC, tree vs mesh.
pub fn fig17(opts: &Options) -> Result<Vec<Table>, String> {
    Ok(tree_vs_mesh(opts, ArchConfig::reram(), "Fig. 17"))
}

/// Fig. 18: virtual-channel sweep (ReRAM): the guidance must not change.
pub fn fig18(opts: &Options) -> Result<Vec<Table>, String> {
    Ok(sweep(
        opts,
        "Fig. 18",
        &[1usize, 2, 4],
        |noc, vcs| noc.virtual_channels = *vcs,
        "virtual_channels",
    ))
}

/// Fig. 19: bus-width sweep (ReRAM): the guidance must not change.
pub fn fig19(opts: &Options) -> Result<Vec<Table>, String> {
    Ok(sweep(
        opts,
        "Fig. 19",
        &[16usize, 32, 64],
        |noc, w| noc.bus_width = *w,
        "bus_width",
    ))
}

fn sweep(
    opts: &Options,
    fig: &str,
    values: &[usize],
    set: impl Fn(&mut NocConfig, &usize),
    param: &str,
) -> Vec<Table> {
    let arch = ArchConfig::reram();
    let sim = sim_cfg(opts);
    let mut thr = Table::new(
        format!("{fig}(a) — mesh/tree throughput ratio vs {param} (ReRAM)"),
        &["dnn", param, "thr_mesh_over_tree", "preferred"],
    );
    let mut edap = Table::new(
        format!("{fig}(b) — mesh/tree EDAP ratio vs {param} (ReRAM)"),
        &["dnn", param, "edap_mesh_over_tree", "preferred"],
    );
    for g in eval_dnns(opts) {
        for v in values {
            let mut tree_cfg = NocConfig::with_topology(Topology::Tree);
            set(&mut tree_cfg, v);
            let mut mesh_cfg = NocConfig::with_topology(Topology::Mesh);
            set(&mut mesh_cfg, v);
            let t = evaluate(&g, Topology::Tree, &arch, &tree_cfg, &sim, opts.backend);
            let m = evaluate(&g, Topology::Mesh, &arch, &mesh_cfg, &sim, opts.backend);
            let tr = m.fps() / t.fps();
            let er = m.edap() / t.edap();
            thr.add_row(vec![
                g.name.clone(),
                v.to_string(),
                fmt_sig(tr, 3),
                if tr > 1.0 { "mesh" } else { "tree" }.into(),
            ]);
            edap.add_row(vec![
                g.name.clone(),
                v.to_string(),
                fmt_sig(er, 3),
                if er < 1.0 { "mesh" } else { "tree" }.into(),
            ]);
        }
    }
    vec![thr, edap]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::CommBackend;

    fn fast_opts() -> Options {
        Options {
            fast: true,
            backend: CommBackend::Analytical,
            ..Options::default()
        }
    }

    #[test]
    fn fig9_cmesh_edap_dominates() {
        let t = &fig9(&fast_opts()).unwrap()[0];
        for row in &t.rows {
            let ratio: f64 = row[4].parse().unwrap();
            assert!(ratio > 1.0, "{}: c-mesh/mesh EDAP ratio {ratio}", row[0]);
        }
    }

    #[test]
    fn fig16_compact_nets_prefer_tree_edap() {
        let tables = fig16(&fast_opts()).unwrap();
        let edap = &tables[1];
        for row in &edap.rows {
            if row[0] == "MLP" || row[0] == "LeNet-5" {
                assert_eq!(row[3], "tree", "{}: expected tree EDAP win", row[0]);
            }
        }
    }

    #[test]
    fn fig18_guidance_consistent_across_vcs() {
        // Paper §6.4.1: the preferred topology per DNN is the same for all
        // VC counts.
        let tables = fig18(&fast_opts()).unwrap();
        let edap = &tables[1];
        use std::collections::HashMap;
        let mut pref: HashMap<&str, &str> = HashMap::new();
        for row in &edap.rows {
            let e = pref.entry(row[0].as_str()).or_insert(row[3].as_str());
            assert_eq!(*e, row[3], "{} changed preference across VCs", row[0]);
        }
    }
}
