//! Fig. 1 (connection density vs neurons) and Fig. 20 (optimal-topology
//! regions).

use super::Options;
use crate::arch::optimizer::{recommend_topology, rule_of_thumb};
use crate::config::{ArchConfig, NocConfig};
use crate::dnn::model_zoo;
use crate::util::{fmt_sig, Table};

/// Fig. 1: density/neuron scatter for the full zoo.
pub fn fig1(_opts: &Options) -> Result<Vec<Table>, String> {
    let mut t = Table::new(
        "Fig. 1 — connection density of DNNs (per dataset)",
        &[
            "dnn",
            "dataset",
            "neurons",
            "structural_density",
            "synaptic_density",
            "weights_M",
            "class",
        ],
    );
    for g in model_zoo() {
        let r = g.density_report();
        let class = if r.structural_density > 2.0 {
            "dense"
        } else if r.structural_density > 1.0 {
            "residual"
        } else {
            "linear"
        };
        t.add_row(vec![
            g.name.clone(),
            g.dataset.name().into(),
            r.neurons.to_string(),
            fmt_sig(r.structural_density, 3),
            fmt_sig(r.synaptic_density, 3),
            fmt_sig(g.total_weights() as f64 / 1e6, 3),
            class.into(),
        ]);
    }
    Ok(vec![t])
}

/// Fig. 20: advisor decision for every zoo model on the (ρ, μ) plane.
pub fn fig20(_opts: &Options) -> Result<Vec<Table>, String> {
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    let mut t = Table::new(
        "Fig. 20 — optimal NoC topology per DNN (ρ = synaptic density, μ = neurons)",
        &[
            "dnn",
            "rho",
            "mu",
            "rule_of_thumb",
            "advisor_choice",
            "edap_tree",
            "edap_mesh",
        ],
    );
    for g in model_zoo() {
        let rec = recommend_topology(&g, &arch, &noc);
        let rule = match rule_of_thumb(rec.density) {
            Some(topo) => topo.name().to_string(),
            None => "either".to_string(),
        };
        t.add_row(vec![
            g.name.clone(),
            fmt_sig(rec.density, 3),
            rec.neurons.to_string(),
            rule,
            rec.topology.name().into(),
            fmt_sig(rec.edap_tree, 3),
            fmt_sig(rec.edap_mesh, 3),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_rows_cover_zoo() {
        let t = &fig1(&Options::default()).unwrap()[0];
        assert_eq!(t.rows.len(), model_zoo().len());
        // Every class present.
        let classes: Vec<&str> = t.rows.iter().map(|r| r[6].as_str()).collect();
        assert!(classes.contains(&"linear"));
        assert!(classes.contains(&"residual"));
        assert!(classes.contains(&"dense"));
    }

    #[test]
    fn fig20_compact_vs_dense_split() {
        let t = &fig20(&Options::default()).unwrap()[0];
        let row = |name: &str| t.rows.iter().find(|r| r[0] == name).unwrap();
        assert_eq!(row("MLP")[4], "NoC-tree");
        assert_eq!(row("LeNet-5")[4], "NoC-tree");
    }
}
