//! Traffic-congestion analysis (paper §6.3): Fig. 13 (queues empty at
//! arrival), Fig. 14 (non-zero queue occupancy for NiN and VGG-19),
//! Fig. 15 (average vs worst-case latency per pair for LeNet-5 and NiN),
//! Table 3 (MAPD of worst-case vs average latency).

use super::Options;
use crate::config::{ArchConfig, NocConfig, SimConfig};
use crate::dnn::{by_name, eval_set};
use crate::mapping::{InjectionMatrix, Mapping};
use crate::noc::latency::simulate_dnn;
use crate::noc::topology::Topology;
use crate::util::{fmt_sig, Table};

fn sim_cfg(opts: &Options) -> SimConfig {
    SimConfig {
        seed: opts.seed,
        measure_cycles: if opts.fast { 5_000 } else { 50_000 },
        ..SimConfig::default()
    }
}

fn run_steady(
    name: &str,
    opts: &Options,
    track_pairs: bool,
) -> Result<crate::noc::latency::DnnCommSim, String> {
    let g = by_name(name).ok_or_else(|| {
        format!(
            "unknown DNN '{name}' (valid: {})",
            crate::dnn::valid_names()
        )
    })?;
    let arch = ArchConfig::reram();
    let noc = NocConfig::default(); // mesh, Table 2 parameters
    let mapping = Mapping::build(&g, &arch);
    let inj = InjectionMatrix::build(&g, &mapping, &arch, &noc);
    Ok(simulate_dnn(
        &inj,
        Topology::Mesh,
        &arch,
        &noc,
        &sim_cfg(opts),
        false,
        track_pairs,
    ))
}

/// Fig. 13: percentage of queues with zero occupancy when a flit arrives.
pub fn fig13(opts: &Options) -> Result<Vec<Table>, String> {
    let mut t = Table::new(
        "Fig. 13 — % of queues with zero occupancy at flit arrival (mesh)",
        &["dnn", "arrivals", "zero_occupancy_%"],
    );
    for g in eval_set() {
        if opts.fast && g.total_macs() >= 1_000_000_000 {
            continue;
        }
        let r = run_steady(&g.name, opts, false)?;
        let (mut arrivals, mut zero) = (0u64, 0u64);
        for l in &r.per_layer {
            arrivals += l.stats.arrivals;
            zero += l.stats.arrivals_zero;
        }
        let pct = if arrivals == 0 {
            100.0
        } else {
            100.0 * zero as f64 / arrivals as f64
        };
        t.add_row(vec![g.name.clone(), arrivals.to_string(), fmt_sig(pct, 3)]);
    }
    Ok(vec![t])
}

/// Fig. 14: average occupancy of non-empty queues for NiN and VGG-19.
pub fn fig14(opts: &Options) -> Result<Vec<Table>, String> {
    let mut tables = Vec::new();
    let nets: &[&str] = if opts.fast {
        &["NiN"]
    } else {
        &["NiN", "VGG-19"]
    };
    for name in nets {
        let r = run_steady(name, opts, false)?;
        let mut t = Table::new(
            format!("Fig. 14 — avg occupancy of non-empty queues, {name} (per layer)"),
            &["layer", "nonzero_arrivals", "avg_occupancy"],
        );
        for l in &r.per_layer {
            t.add_row(vec![
                l.layer.to_string(),
                l.stats.nonzero_occ_count.to_string(),
                fmt_sig(l.stats.mean_nonzero_occupancy(), 3),
            ]);
        }
        tables.push(t);
    }
    Ok(tables)
}

/// Fig. 15: average vs worst-case latency per source-destination pair for
/// LeNet-5 and NiN (pairs with non-zero traffic).
pub fn fig15(opts: &Options) -> Result<Vec<Table>, String> {
    let mut tables = Vec::new();
    for name in ["LeNet-5", "NiN"] {
        let r = run_steady(name, opts, true)?;
        let mut t = Table::new(
            format!("Fig. 15 — avg vs worst-case latency per pair, {name}"),
            &["src", "dst", "flits", "avg_cycles", "worst_cycles", "diff"],
        );
        let mut pairs: Vec<_> = r
            .per_layer
            .iter()
            .flat_map(|l| l.stats.per_pair.iter())
            .collect();
        pairs.sort_by_key(|(k, _)| **k);
        for (key, p) in pairs {
            let (src, dst) = ((key >> 32) as u32, (key & 0xFFFF_FFFF) as u32);
            t.add_row(vec![
                src.to_string(),
                dst.to_string(),
                p.count.to_string(),
                fmt_sig(p.avg(), 4),
                p.max_latency.to_string(),
                fmt_sig(p.max_latency as f64 - p.avg(), 3),
            ]);
        }
        tables.push(t);
    }
    Ok(tables)
}

/// Table 3: MAPD of worst-case latency from average latency per DNN.
pub fn table3(opts: &Options) -> Result<Vec<Table>, String> {
    let mut t = Table::new(
        "Table 3 — MAPD of worst-case vs average NoC latency (%)",
        &["dnn", "pairs", "MAPD_%"],
    );
    for g in eval_set() {
        if opts.fast && g.total_macs() >= 1_000_000_000 {
            continue;
        }
        let r = run_steady(&g.name, opts, true)?;
        let (mut avg, mut worst) = (Vec::new(), Vec::new());
        for l in &r.per_layer {
            for p in l.stats.per_pair.values() {
                if p.count > 0 {
                    avg.push(p.avg());
                    worst.push(p.max_latency as f64);
                }
            }
        }
        let mapd = crate::util::stats::mapd(&avg, &worst);
        t.add_row(vec![
            g.name.clone(),
            avg.len().to_string(),
            fmt_sig(mapd, 3),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::CommBackend;

    fn fast_opts() -> Options {
        Options {
            fast: true,
            backend: CommBackend::Analytical,
            ..Options::default()
        }
    }

    #[test]
    fn unknown_dnn_is_a_clean_error_listing_valid_names() {
        let err = run_steady("NotANet", &fast_opts(), false).unwrap_err();
        assert!(err.contains("NotANet"), "{err}");
        assert!(err.contains("LeNet-5"), "error must list valid names: {err}");
    }

    #[test]
    fn fig13_zero_occupancy_in_paper_band() {
        // Paper: 64-100% of queues empty at arrival.
        let t = &fig13(&fast_opts()).unwrap()[0];
        for row in &t.rows {
            let pct: f64 = row[2].parse().unwrap();
            assert!(pct > 50.0, "{}: only {pct}% empty", row[0]);
        }
    }

    #[test]
    fn fig14_occupancies_are_small() {
        // Paper: average non-zero queue length 0.004-0.5 (plus margin).
        for t in fig14(&fast_opts()).unwrap() {
            for row in &t.rows {
                let occ: f64 = row[2].parse().unwrap();
                assert!(occ < 8.0, "occupancy {occ} out of band");
            }
        }
    }

    #[test]
    fn table3_mapd_small() {
        // Paper Table 3: 0-21%. Allow headroom but catch blow-ups.
        let t = &table3(&fast_opts()).unwrap()[0];
        for row in &t.rows {
            let mapd: f64 = row[2].parse().unwrap();
            assert!(
                (0.0..200.0).contains(&mapd),
                "{}: MAPD {mapd}%",
                row[0]
            );
        }
    }
}
