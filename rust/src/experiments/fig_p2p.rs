//! P2P-scalability experiments: Fig. 3 (routing-latency share), Fig. 5
//! (latency vs injection bandwidth), Fig. 8 (topology throughput,
//! SRAM-normalized-to-P2P), Fig. 21 (latency vs density, P2P vs NoC).

use super::Options;
use crate::arch::evaluate;
use crate::config::{ArchConfig, NocConfig, SimConfig};
use crate::dnn::{eval_set, model_zoo};
use crate::noc::sim::{uniform_random_flows, Mode, NocSim};
use crate::noc::topology::Topology;
use crate::util::{fmt_sig, Table};

fn eval_dnns(opts: &Options) -> Vec<crate::dnn::DnnGraph> {
    if opts.fast {
        eval_set()
            .into_iter()
            .filter(|g| g.total_macs() < 1_000_000_000)
            .collect()
    } else {
        eval_set()
    }
}

/// Fig. 3: routing latency share on the P2P IMC architecture.
pub fn fig3(opts: &Options) -> Result<Vec<Table>, String> {
    let arch = ArchConfig::sram();
    let noc = NocConfig::with_topology(Topology::P2P);
    let sim = SimConfig {
        seed: opts.seed,
        ..SimConfig::default()
    };
    let mut t = Table::new(
        "Fig. 3 — contribution of routing latency to total latency (P2P IMC)",
        &["dnn", "density", "compute_ms", "routing_ms", "routing_share_%"],
    );
    for g in eval_dnns(opts) {
        let e = evaluate(&g, Topology::P2P, &arch, &noc, &sim, opts.backend);
        t.add_row(vec![
            g.name.clone(),
            fmt_sig(g.density_report().structural_density, 3),
            fmt_sig(e.compute_latency_s * 1e3, 3),
            fmt_sig(e.comm_latency_s * 1e3, 3),
            fmt_sig(100.0 * e.routing_fraction(), 3),
        ]);
    }
    Ok(vec![t])
}

/// Fig. 5: average latency vs injection bandwidth for 64-node P2P,
/// NoC-tree, and 8×8 NoC-mesh under uniform-random traffic.
pub fn fig5(opts: &Options) -> Result<Vec<Table>, String> {
    let cfg = NocConfig::default();
    let rates = if opts.fast {
        vec![0.02, 0.10, 0.25]
    } else {
        vec![0.01, 0.02, 0.05, 0.10, 0.15, 0.20, 0.25, 0.30, 0.40]
    };
    let mut t = Table::new(
        "Fig. 5 — average latency (cycles) vs injection bandwidth, 64 nodes",
        &["rate_flits_per_node_cycle", "P2P", "NoC-tree", "NoC-mesh"],
    );
    for &rate in &rates {
        let mut row = vec![fmt_sig(rate, 3)];
        for topo in [Topology::P2P, Topology::Tree, Topology::Mesh] {
            let flows = uniform_random_flows(64, rate);
            let stats = NocSim::new(
                topo,
                64,
                &cfg,
                &flows,
                Mode::Steady {
                    warmup: 1_000,
                    measure: if opts.fast { 3_000 } else { 10_000 },
                },
                opts.seed,
            )
            .run();
            // Saturated networks deliver few flits at huge latency; report
            // the (large) number rather than hiding it, like BookSim does.
            row.push(fmt_sig(stats.avg_latency, 4));
        }
        t.add_row(row);
    }
    Ok(vec![t])
}

/// Fig. 8: throughput of the SRAM IMC architecture with P2P / tree / mesh,
/// normalized to P2P.
pub fn fig8(opts: &Options) -> Result<Vec<Table>, String> {
    let arch = ArchConfig::sram();
    let sim = SimConfig {
        seed: opts.seed,
        ..SimConfig::default()
    };
    let mut t = Table::new(
        "Fig. 8 — normalized throughput (SRAM IMC), P2P / NoC-tree / NoC-mesh",
        &["dnn", "P2P", "NoC-tree", "NoC-mesh"],
    );
    for g in eval_dnns(opts) {
        let fps: Vec<f64> = [Topology::P2P, Topology::Tree, Topology::Mesh]
            .into_iter()
            .map(|topo| {
                evaluate(
                    &g,
                    topo,
                    &arch,
                    &NocConfig::with_topology(topo),
                    &sim,
                    opts.backend,
                )
                .fps()
            })
            .collect();
        t.add_row(vec![
            g.name.clone(),
            "1.00".into(),
            fmt_sig(fps[1] / fps[0], 3),
            fmt_sig(fps[2] / fps[0], 3),
        ]);
    }
    Ok(vec![t])
}

/// Fig. 21: total inference latency vs connection density for P2P vs the
/// advisor-chosen NoC, both technologies.
pub fn fig21(opts: &Options) -> Result<Vec<Table>, String> {
    let sim = SimConfig {
        seed: opts.seed,
        ..SimConfig::default()
    };
    let mut tables = Vec::new();
    for arch in [ArchConfig::sram(), ArchConfig::reram()] {
        let mut t = Table::new(
            format!(
                "Fig. 21 — total latency vs connection density ({})",
                arch.tech.name()
            ),
            &["dnn", "density", "P2P_ms", "NoC_ms", "P2P/NoC"],
        );
        let mut models: Vec<_> = if opts.fast {
            eval_dnns(opts)
        } else {
            model_zoo()
        };
        models.sort_by(|a, b| {
            a.density_report()
                .structural_density
                .partial_cmp(&b.density_report().structural_density)
                .unwrap()
        });
        for g in models {
            let p2p = evaluate(
                &g,
                Topology::P2P,
                &arch,
                &NocConfig::with_topology(Topology::P2P),
                &sim,
                opts.backend,
            );
            let rec = crate::arch::recommend_topology(&g, &arch, &NocConfig::default());
            let noc = evaluate(
                &g,
                rec.topology,
                &arch,
                &NocConfig::with_topology(rec.topology),
                &sim,
                opts.backend,
            );
            t.add_row(vec![
                g.name.clone(),
                fmt_sig(g.density_report().structural_density, 3),
                fmt_sig(p2p.latency_s() * 1e3, 4),
                fmt_sig(noc.latency_s() * 1e3, 4),
                fmt_sig(p2p.latency_s() / noc.latency_s(), 3),
            ]);
        }
        tables.push(t);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::CommBackend;

    fn fast_opts() -> Options {
        Options {
            fast: true,
            backend: CommBackend::Analytical,
            ..Options::default()
        }
    }

    #[test]
    fn fig3_routing_dominates_p2p_at_high_density() {
        // Paper: the routing share reaches up to 94% as connection density
        // grows (their own Fig. 3 is non-monotone — VGG-19 dips).
        let t = &fig3(&fast_opts()).unwrap()[0];
        assert!(t.rows.len() >= 3);
        let last: f64 = t.rows.last().unwrap()[4].parse().unwrap();
        assert!(last > 80.0, "densest DNN share {last}% too low");
        for row in &t.rows {
            let share: f64 = row[4].parse().unwrap();
            assert!((0.0..=100.0).contains(&share), "{}: {share}", row[0]);
        }
    }

    #[test]
    fn fig5_mesh_wins_at_high_rate() {
        let t = &fig5(&fast_opts()).unwrap()[0];
        let last = t.rows.last().unwrap();
        let p2p: f64 = last[1].parse().unwrap();
        let mesh: f64 = last[3].parse().unwrap();
        assert!(
            mesh < p2p,
            "mesh latency {mesh} must beat P2P {p2p} at high load"
        );
    }

    #[test]
    fn fig8_noc_never_slower_than_p2p_on_dense() {
        let t = &fig8(&fast_opts()).unwrap()[0];
        let dense_rows: Vec<_> = t
            .rows
            .iter()
            .filter(|r| r[0].starts_with("DenseNet") || r[0].starts_with("ResNet"))
            .collect();
        for r in dense_rows {
            let mesh: f64 = r[3].parse().unwrap();
            assert!(mesh >= 1.0, "{}: mesh normalized {mesh} < 1", r[0]);
        }
    }
}
