//! Beyond-the-paper experiment: chiplet-aware batched serving.
//!
//! Sweeps the serving scheduler ([`crate::coordinator::scheduler`]) over
//! routing policy × package size × NoP topology for one small and one
//! dense DNN, at the auto arrival rate (a fixed fraction of each
//! configuration's modeled capacity). The headline contrast is the tail:
//! round-robin ignores both the per-chiplet backlog and the package
//! links, so at k = 16 its modeled p99 collapses once the gateway's
//! SerDes lanes near the saturation utilization measured by
//! [`crate::nop::sim::saturation_rate`] — the congestion-aware policy
//! backs off those paths and keeps the tail bounded.
//!
//! The (DNN × k × NoP) model builds fan out over OS threads via the
//! coordinator's [`par_map`]; the per-policy serving simulations reuse
//! each built model.

use super::Options;
use crate::config::{ArchConfig, NocConfig, NopConfig, ServingConfig, SimConfig};
use crate::coordinator::par_map;
use crate::coordinator::scheduler::{ChipletScheduler, Policy, ServingModel};
use crate::dnn::by_name;
use crate::nop::topology::NopTopology;
use crate::telemetry::BlameReport;
use crate::util::{fmt_sig, Table};

/// One (DNN, chiplets, NoP) sweep point.
type Point = (String, usize, NopTopology);

fn sweep_points(fast: bool) -> Vec<Point> {
    let models: &[&str] = if fast {
        &["SqueezeNet"]
    } else {
        &["VGG-19", "SqueezeNet"]
    };
    let ks: &[usize] = if fast { &[1, 4] } else { &[1, 4, 8, 16] };
    let mut points = Vec::new();
    for m in models {
        for &k in ks {
            if k == 1 {
                // Topology is irrelevant on a single chiplet.
                points.push((m.to_string(), k, NopTopology::Ring));
                continue;
            }
            for topo in [NopTopology::Ring, NopTopology::Mesh] {
                points.push((m.to_string(), k, topo));
            }
        }
    }
    points
}

/// The `serving` experiment generator.
pub fn serving(opts: &Options) -> Result<Vec<Table>, String> {
    let arch = ArchConfig::reram();
    let noc = NocConfig::default();
    let sim = SimConfig {
        seed: opts.seed,
        ..SimConfig::default()
    };
    let requests = if opts.fast { 200 } else { 600 };

    let points = sweep_points(opts.fast);
    for (name, _, _) in &points {
        by_name(name).ok_or_else(|| {
            format!(
                "unknown DNN '{name}' (valid: {})",
                crate::dnn::valid_names()
            )
        })?;
    }
    // Build the (expensive) serving models in parallel; each includes a
    // NoP saturation sweep.
    let built = par_map(&points, None, |(name, k, topo)| {
        let g = by_name(name).expect("sweep names validated above");
        let nop = NopConfig {
            topology: *topo,
            chiplets: *k,
            mode: opts.nop_mode,
            ..NopConfig::default()
        };
        ServingModel::build(&g, &arch, &noc, &nop, &sim)
    });

    let mut sweep = Table::new(
        "Chiplet-aware serving — policy sweep at auto load (85% of modeled capacity)",
        &[
            "dnn",
            "chiplets",
            "NoP",
            "policy",
            "offered_rps",
            "tput_rps",
            "p50_ms",
            "p99_ms",
            "drop_%",
            "util_mean",
            "ingress_ms",
            "queue_ms",
            "service_ms",
            "windows",
            "drift_events",
            "explain",
        ],
    );
    let mut context = Table::new(
        "Serving model context per configuration",
        &[
            "dnn",
            "chiplets",
            "NoP",
            "service_ms",
            "stage_ms",
            "ingress_max_ms",
            "partitioned_ms",
            "sat_link_util",
        ],
    );
    for (point, built_point) in points.iter().zip(built) {
        let (name, k, topo) = point;
        let (model, part) = built_point;
        let nop_name = if *k == 1 {
            "-".to_string()
        } else {
            topo.name().to_string()
        };
        let ingress_max = model.ingress_s.iter().copied().fold(0.0f64, f64::max);
        context.add_row(vec![
            name.clone(),
            k.to_string(),
            nop_name.clone(),
            fmt_sig(model.service_s * 1e3, 4),
            fmt_sig(model.stage_s * 1e3, 4),
            fmt_sig(ingress_max * 1e3, 4),
            fmt_sig(model.partitioned_latency_s * 1e3, 4),
            fmt_sig(model.sat_link_util, 3),
        ]);
        for policy in Policy::all() {
            let cfg = ServingConfig {
                policy,
                requests,
                ..ServingConfig::default()
            };
            // One shared seed across policies: identical Poisson arrival
            // traces make the policy columns directly comparable.
            let mut sched = ChipletScheduler::new(model.clone(), part.clone(), &cfg);
            let report = sched.run(&cfg, opts.seed);
            let drop_pct = 100.0 * report.dropped as f64 / report.requests.max(1) as f64;
            let util_sum: f64 = report.per_chiplet.iter().map(|s| s.utilization).sum();
            let util_mean = util_sum / report.per_chiplet.len().max(1) as f64;
            // Critical-path attribution: the single most-blamed package
            // link of this run ("-" when no request ever waited).
            let blame = BlameReport::build(
                sched.spans(),
                sched.ingress_traces(),
                &[name.clone()],
                &[f64::INFINITY],
                &model.layer_blame,
            );
            sweep.add_row(vec![
                name.clone(),
                k.to_string(),
                nop_name.clone(),
                policy.name().to_string(),
                fmt_sig(report.offered_rps, 4),
                fmt_sig(report.throughput_rps, 4),
                fmt_sig(report.p50_ms, 4),
                fmt_sig(report.p99_ms, 4),
                fmt_sig(drop_pct, 3),
                fmt_sig(util_mean, 3),
                fmt_sig(report.mean_ingress_ms, 3),
                fmt_sig(report.mean_queue_ms, 3),
                fmt_sig(report.mean_service_ms, 3),
                sched.timeseries().windows().len().to_string(),
                sched.timeseries().drift_events().len().to_string(),
                blame.top_link(),
            ]);
        }
    }

    Ok(vec![sweep, context])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serving_experiment_fast_runs() {
        let opts = Options {
            fast: true,
            ..Options::default()
        };
        let tables = serving(&opts).unwrap();
        assert_eq!(tables.len(), 2);
        // SqueezeNet x {k=1, (k=4, ring), (k=4, mesh)} x 3 policies.
        assert_eq!(tables[0].rows.len(), 9);
        assert_eq!(tables[1].rows.len(), 3);
        for row in &tables[0].rows {
            let p50: f64 = row[6].parse().unwrap();
            let p99: f64 = row[7].parse().unwrap();
            assert!(p99 >= p50, "p99 {p99} < p50 {p50}");
            // Lifecycle breakdown columns (telemetry spans): every phase
            // mean is finite and non-negative, and service dominates on
            // these underloaded points.
            let ingress: f64 = row[10].parse().unwrap();
            let queue: f64 = row[11].parse().unwrap();
            let service: f64 = row[12].parse().unwrap();
            assert!(ingress >= 0.0 && queue >= 0.0 && service > 0.0);
            assert!(service + queue + ingress <= p99.max(p50) * 2.0 + 1e-6);
            // Time-series columns: every run collects windows.
            let windows: usize = row[13].parse().unwrap();
            assert!(windows > 0, "run collected no metric windows");
            let _drift: usize = row[14].parse().unwrap();
            // Explain column: either "-" (no waits) or a "from-to" link.
            assert!(row[15] == "-" || row[15].contains('-'), "{}", row[15]);
        }
    }

    #[test]
    fn congestion_aware_beats_round_robin_p99_vgg19_k16_mesh() {
        // The acceptance point of the serving PR: at k = 16 the mesh
        // gateway's lanes run near saturation and round-robin keeps
        // routing through them; the congestion-aware policy must deliver
        // a strictly better modeled p99.
        let g = by_name("VGG-19").unwrap();
        let arch = ArchConfig::reram();
        let noc = NocConfig::default();
        let sim = SimConfig::default();
        let nop = NopConfig {
            topology: NopTopology::Mesh,
            chiplets: 16,
            ..NopConfig::default()
        };
        let (model, part) = ServingModel::build(&g, &arch, &noc, &nop, &sim);
        let run = |policy: Policy| {
            let cfg = ServingConfig {
                policy,
                requests: 400,
                ..ServingConfig::default()
            };
            let mut sched = ChipletScheduler::new(model.clone(), part.clone(), &cfg);
            sched.run(&cfg, sim.seed)
        };
        let rr = run(Policy::RoundRobin);
        let ca = run(Policy::CongestionAware);
        assert_eq!(rr.per_chiplet.len(), 16);
        assert_eq!(ca.per_chiplet.len(), 16);
        assert!(rr.completed > 0 && ca.completed > 0);
        assert!(
            ca.p99_ms < rr.p99_ms,
            "congestion-aware p99 {} must beat round-robin p99 {}",
            ca.p99_ms,
            rr.p99_ms
        );
    }
}
