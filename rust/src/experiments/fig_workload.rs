//! Beyond-the-paper experiment: multi-model serving under trace-driven
//! traffic — placement policy × admission control × package size.
//!
//! Serves the default VGG-19 + SqueezeNet mix (dense + compact, the
//! paper's two interconnect regimes) on one package at 85% of the mix's
//! modeled capacity, sweeping replica placement (naive round-robin
//! striping vs the NoP-aware search), admission control (drop-on-full vs
//! deadline-aware shedding), k ∈ {4, 8, 16} and ring/mesh NoP. Headline
//! metric: the deadline hit-rate. Two results are encoded as tests:
//!
//! * Round-robin striping ignores that VGG-19's service demand dwarfs
//!   SqueezeNet's, so its VGG replicas overload at 85% aggregate load —
//!   the demand-sized, gateway-proximate NoP-aware placement beats it on
//!   hit-rate (k = 16 mesh acceptance point).
//! * Under drop-on-full the overloaded queues admit requests that finish
//!   far past their deadline; deadline-aware admission sheds those at
//!   arrival and spends the same capacity on requests that still hit.
//!
//! A second table contrasts arrival generators (Poisson vs MMPP-bursty vs
//! diurnal vs heavy-tailed frames) at one healthy configuration, showing
//! burstiness eroding the tail at identical utilization (each shape's
//! request rate is scaled by its expected frames per request).

use super::Options;
use crate::config::{
    Admission, ArchConfig, NocConfig, NopConfig, ServingConfig, SimConfig, WorkloadConfig,
};
use crate::coordinator::mix::{MixScheduler, MixServingModel};
use crate::coordinator::par_map;
use crate::coordinator::scheduler::AUTO_LOAD_FACTOR;
use crate::nop::topology::NopTopology;
use crate::telemetry::{BlameReport, LayerBlame};
use crate::util::{fmt_sig, Table};
use crate::workload::{ArrivalKind, PlacementPolicy};

/// One (chiplets, NoP) sweep point; placements are derived per point via
/// [`MixServingModel::with_placement`] so the expensive pricing runs once.
type Point = (usize, NopTopology);

fn sweep_points(fast: bool) -> Vec<Point> {
    let ks: &[usize] = if fast { &[4] } else { &[4, 8, 16] };
    let topos: &[NopTopology] = if fast {
        &[NopTopology::Mesh]
    } else {
        &[NopTopology::Ring, NopTopology::Mesh]
    };
    let mut points = Vec::new();
    for &k in ks {
        for &topo in topos {
            points.push((k, topo));
        }
    }
    points
}

/// The `workload` experiment generator.
pub fn workload(opts: &Options) -> Result<Vec<Table>, String> {
    let arch = ArchConfig::reram();
    let noc = NocConfig::default();
    let sim = SimConfig {
        seed: opts.seed,
        ..SimConfig::default()
    };
    let wl = WorkloadConfig::default();
    let requests = if opts.fast { 160 } else { 480 };
    let mix_name = wl.mix.names().join("+");

    // Build the (expensive) mix models in parallel; each includes two
    // replica pricings, the placement search, and a NoP saturation sweep.
    // Alternative placements reuse the priced model via `with_placement`.
    let points = sweep_points(opts.fast);
    let built = par_map(&points, None, |(k, topo)| {
        let nop = NopConfig {
            topology: *topo,
            chiplets: *k,
            mode: opts.nop_mode,
            ..NopConfig::default()
        };
        MixServingModel::build(&wl.mix, PlacementPolicy::NopAware, &arch, &noc, &nop, &sim)
    });

    let mut sweep = Table::new(
        "Multi-model serving — placement x admission at 85% of mix capacity",
        &[
            "mix",
            "chiplets",
            "NoP",
            "placement",
            "admission",
            "offered_rps",
            "tput_rps",
            "hit_rate",
            "shed_%",
            "drop_%",
            "p99_ms",
            "queue_ms",
            "service_ms",
            "windows",
            "drift_events",
            "explain",
        ],
    );
    let mut healthy: Option<MixServingModel> = None;
    for (point, built_point) in points.iter().zip(built) {
        let (k, topo) = point;
        let aware = built_point?;
        // One offered rate per (k, topo): capacity is placement-
        // independent, so both placements face identical traffic.
        let rate = AUTO_LOAD_FACTOR * aware.capacity_rps(wl.arrival_process().mean_frames());
        let events = wl
            .arrival_process()
            .generate(&wl.mix, rate, requests, opts.seed);
        // The 2 placements × 2 admissions fan out over the driver: each
        // run is an independent scheduler over the same event trace, and
        // `with_placement` only re-derives the replica layout from the
        // already-priced model.
        let combos: Vec<(PlacementPolicy, Admission)> = PlacementPolicy::all()
            .into_iter()
            .flat_map(|p| Admission::all().into_iter().map(move |a| (p, a)))
            .collect();
        let combo_rows = par_map(&combos, None, |&(placement, admission)| {
            let model = if placement == PlacementPolicy::NopAware {
                aware.clone()
            } else {
                aware.with_placement(placement)?
            };
            let cfg = ServingConfig {
                requests,
                seed: opts.seed,
                ..ServingConfig::default()
            };
            let mut sched = MixScheduler::new(model, &cfg, admission);
            let mut report = sched.run(&events);
            report.offered_rps = rate;
            let pct = |n: usize| 100.0 * n as f64 / report.requests.max(1) as f64;
            // Critical-path attribution: the single most-blamed package
            // link of this run ("-" when no request ever waited).
            let names: Vec<String> =
                sched.model.models.iter().map(|m| m.name.clone()).collect();
            let deadlines: Vec<f64> =
                sched.model.models.iter().map(|m| m.deadline_s).collect();
            let layers: Vec<LayerBlame> = sched
                .model
                .models
                .iter()
                .flat_map(|m| m.layers.iter().cloned())
                .collect();
            let blame = BlameReport::build(
                sched.spans(),
                sched.ingress_traces(),
                &names,
                &deadlines,
                &layers,
            );
            Ok::<Vec<String>, String>(vec![
                mix_name.clone(),
                k.to_string(),
                topo.name().to_string(),
                placement.name().to_string(),
                admission.name().to_string(),
                fmt_sig(report.offered_rps, 4),
                fmt_sig(report.throughput_rps, 4),
                fmt_sig(report.hit_rate(), 3),
                fmt_sig(pct(report.shed), 3),
                fmt_sig(pct(report.dropped), 3),
                fmt_sig(report.p99_ms, 4),
                fmt_sig(report.mean_queue_ms, 3),
                fmt_sig(report.mean_service_ms, 3),
                sched.timeseries().windows().len().to_string(),
                sched.timeseries().drift_events().len().to_string(),
                blame.top_link(),
            ])
        });
        for row in combo_rows {
            sweep.add_row(row?);
        }
        if healthy.is_none() {
            healthy = Some(aware);
        }
    }

    // Generator contrast at the first NoP-aware point: same utilization,
    // different arrival shapes (each shape's rate is scaled by its own
    // expected frames per request so the heavy-tail row is iso-load, not
    // just iso-request-rate).
    let model = healthy.expect("sweep contains a NoP-aware point");
    let mut gens = Table::new(
        format!(
            "Arrival-shape contrast at 85% load (k = {}, NoP-{}, deadline-aware)",
            model.chiplets,
            model.topology.name()
        ),
        &["arrival", "hit_rate", "shed_%", "p99_ms"],
    );
    let shapes: [(&str, ArrivalKind, f64); 4] = [
        ("poisson", ArrivalKind::Poisson, 0.0),
        ("bursty", ArrivalKind::Bursty, 0.0),
        ("diurnal", ArrivalKind::Diurnal, 0.0),
        ("poisson+heavy-tail", ArrivalKind::Poisson, 1.5),
    ];
    // Four independent trace generations + runs — driver-parallel too.
    let shape_rows = par_map(&shapes, None, |&(label, kind, frames_alpha)| {
        let shaped = WorkloadConfig {
            arrival: kind,
            frames_alpha,
            ..wl.clone()
        };
        let rate = AUTO_LOAD_FACTOR * model.capacity_rps(shaped.arrival_process().mean_frames());
        let events = shaped
            .arrival_process()
            .generate(&wl.mix, rate, requests, opts.seed);
        let cfg = ServingConfig {
            requests,
            seed: opts.seed,
            ..ServingConfig::default()
        };
        let mut sched = MixScheduler::new(model.clone(), &cfg, Admission::DeadlineAware);
        let report = sched.run(&events);
        vec![
            label.to_string(),
            fmt_sig(report.hit_rate(), 3),
            fmt_sig(100.0 * report.shed as f64 / report.requests.max(1) as f64, 3),
            fmt_sig(report.p99_ms, 4),
        ]
    });
    for row in shape_rows {
        gens.add_row(row);
    }

    Ok(vec![sweep, gens])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{ArrivalProcess, WorkloadMix};

    #[test]
    fn workload_experiment_fast_runs() {
        let opts = Options {
            fast: true,
            ..Options::default()
        };
        let tables = workload(&opts).unwrap();
        assert_eq!(tables.len(), 2);
        // k=4 mesh x 2 placements x 2 admissions.
        assert_eq!(tables[0].rows.len(), 4);
        assert_eq!(tables[1].rows.len(), 4);
        for row in &tables[0].rows {
            let hit: f64 = row[7].parse().unwrap();
            assert!((0.0..=1.0).contains(&hit), "hit rate {hit}");
            // Span-derived breakdown columns are present and sane.
            let queue: f64 = row[11].parse().unwrap();
            let service: f64 = row[12].parse().unwrap();
            assert!(queue >= 0.0, "queue {queue}");
            assert!(service > 0.0, "service {service}");
            // Time-series columns: every run collects windows.
            let windows: usize = row[13].parse().unwrap();
            assert!(windows > 0, "run collected no metric windows");
            let _drift: usize = row[14].parse().unwrap();
            // Explain column: either "-" (no waits) or a "from-to" link.
            assert!(row[15] == "-" || row[15].contains('-'), "{}", row[15]);
        }
    }

    #[test]
    fn placement_and_admission_acceptance_k16_mesh() {
        // The PR's acceptance point: the VGG-19 + SqueezeNet mix on a
        // k = 16 mesh package at 85% of mix capacity.
        let mix = WorkloadMix::parse("VGG-19:1:0,SqueezeNet:1:0").unwrap();
        let arch = ArchConfig::reram();
        let noc = NocConfig::default();
        let sim = SimConfig::default();
        let nop = NopConfig {
            topology: NopTopology::Mesh,
            chiplets: 16,
            ..NopConfig::default()
        };
        let aware =
            MixServingModel::build(&mix, PlacementPolicy::NopAware, &arch, &noc, &nop, &sim)
                .unwrap();
        // The round-robin contender reuses the priced model.
        let rr = aware.with_placement(PlacementPolicy::RoundRobin).unwrap();
        // Regime check the acceptance argument rests on: VGG-19's replica
        // service time clearly dominates SqueezeNet's, so the 8/8 stripe
        // overloads the VGG side at 85% aggregate load (util = 1.7R/(R+1)
        // > 1 for R > 1.43).
        let r_ratio = aware.models[0].service_s / aware.models[1].service_s;
        assert!(r_ratio > 1.5, "service ratio {r_ratio} too balanced");
        // VGG-19's service demand dominates at equal traffic shares, so
        // the demand-sized placement gives it strictly more replicas than
        // the 8/8 stripe.
        assert_eq!(rr.placement.replica_count(0), 8);
        assert!(
            aware.placement.replica_count(0) > aware.placement.replica_count(1),
            "NoP-aware replicas: {} vs {}",
            aware.placement.replica_count(0),
            aware.placement.replica_count(1)
        );
        // Same offered traffic for every run (capacity is placement-
        // independent by construction).
        let cap = aware.capacity_rps(1.0);
        assert!((rr.capacity_rps(1.0) - cap).abs() < 1e-9 * cap);
        let rate = AUTO_LOAD_FACTOR * cap;
        let events = ArrivalProcess::default().generate(&mix, rate, 400, 0x5EED);
        let cfg = ServingConfig {
            requests: 400,
            ..ServingConfig::default()
        };
        let run = |model: &MixServingModel, admission: Admission| {
            let mut sched = MixScheduler::new(model.clone(), &cfg, admission);
            sched.run(&events)
        };
        let rr_da = run(&rr, Admission::DeadlineAware);
        let aware_da = run(&aware, Admission::DeadlineAware);
        let rr_drop = run(&rr, Admission::DropOnFull);
        for r in [&rr_da, &aware_da, &rr_drop] {
            assert_eq!(r.completed + r.dropped + r.shed, r.requests);
            assert_eq!(r.deadline_offered, r.requests);
        }
        // Acceptance 1: NoP-aware placement beats naive round-robin
        // striping on deadline hit-rate.
        assert!(
            aware_da.hit_rate() > rr_da.hit_rate(),
            "NoP-aware hit-rate {} must beat round-robin {}",
            aware_da.hit_rate(),
            rr_da.hit_rate()
        );
        // Acceptance 2: deadline-aware shedding beats drop-on-full on the
        // same (mismatched) placement at 85% load.
        assert!(
            rr_da.hit_rate() > rr_drop.hit_rate(),
            "deadline-aware hit-rate {} must beat drop-on-full {}",
            rr_da.hit_rate(),
            rr_drop.hit_rate()
        );
    }
}
