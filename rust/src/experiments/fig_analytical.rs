//! Analytical-model validation: Fig. 11 (accuracy vs cycle-accurate
//! simulation) and Fig. 12 (speed-up).

use std::time::Instant;

use super::Options;
use crate::config::{ArchConfig, NocConfig, SimConfig};
use crate::dnn::eval_set;
use crate::mapping::{InjectionMatrix, Mapping};
use crate::noc::latency::{estimate_dnn, simulate_dnn};
use crate::noc::topology::Topology;
use crate::util::{fmt_sig, Table};

/// Fig. 11: per-DNN accuracy of the analytical per-flit latency against the
/// cycle-accurate simulator, for NoC-tree and NoC-mesh.
pub fn fig11(opts: &Options) -> Result<Vec<Table>, String> {
    let arch = ArchConfig::reram();
    let noc_base = NocConfig::default();
    let sim_cfg = SimConfig {
        seed: opts.seed,
        measure_cycles: if opts.fast { 2_000 } else { 20_000 },
        ..SimConfig::default()
    };
    let mut t = Table::new(
        "Fig. 11 — analytical model accuracy vs cycle-accurate simulation (%)",
        &["dnn", "mesh_sim", "mesh_ana", "mesh_acc_%", "tree_sim", "tree_ana", "tree_acc_%"],
    );
    let mut accs = Vec::new();
    for g in eval_set() {
        if opts.fast && g.total_macs() >= 1_000_000_000 {
            continue;
        }
        let mapping = Mapping::build(&g, &arch);
        let mut row = vec![g.name.clone()];
        for topo in [Topology::Mesh, Topology::Tree] {
            let noc = NocConfig {
                topology: topo,
                ..noc_base.clone()
            };
            let inj = InjectionMatrix::build(&g, &mapping, &arch, &noc);
            let sim = simulate_dnn(&inj, topo, &arch, &noc, &sim_cfg, false, false);
            let ana = estimate_dnn(&inj, topo, &arch, &noc);
            let acc = if sim.avg_flit_latency > 0.0 {
                100.0 * (1.0 - (ana.avg_flit_latency - sim.avg_flit_latency).abs()
                    / sim.avg_flit_latency)
            } else {
                100.0
            };
            accs.push(acc);
            row.push(fmt_sig(sim.avg_flit_latency, 4));
            row.push(fmt_sig(ana.avg_flit_latency, 4));
            row.push(fmt_sig(acc, 3));
        }
        // Column order in the header is mesh then tree; row already matches.
        t.add_row(row);
    }
    let mut summary = Table::new("Fig. 11 — summary", &["metric", "value"]);
    summary.add_row(vec![
        "mean_accuracy_%".into(),
        fmt_sig(crate::util::mean(&accs), 3),
    ]);
    summary.add_row(vec![
        "min_accuracy_%".into(),
        fmt_sig(accs.iter().cloned().fold(f64::INFINITY, f64::min), 3),
    ]);
    Ok(vec![t, summary])
}

/// Fig. 12: wall-clock speed-up of the analytical model over cycle-accurate
/// simulation, mesh NoC.
pub fn fig12(opts: &Options) -> Result<Vec<Table>, String> {
    let arch = ArchConfig::reram();
    let noc = NocConfig::default();
    let sim_cfg = SimConfig {
        seed: opts.seed,
        measure_cycles: if opts.fast { 2_000 } else { 20_000 },
        ..SimConfig::default()
    };
    let mut t = Table::new(
        "Fig. 12 — NoC analysis speed-up, analytical vs cycle-accurate (mesh)",
        &["dnn", "sim_ms", "analytical_ms", "speedup"],
    );
    for g in eval_set() {
        if opts.fast && g.total_macs() >= 1_000_000_000 {
            continue;
        }
        let mapping = Mapping::build(&g, &arch);
        let inj = InjectionMatrix::build(&g, &mapping, &arch, &noc);
        // The cycle-accurate side runs the full Algorithm-1 drain (one
        // frame of transfers per layer) — the cost the paper says takes up
        // to 80% of total analysis time.
        let t0 = Instant::now();
        let _ = simulate_dnn(&inj, Topology::Mesh, &arch, &noc, &sim_cfg, true, false);
        let sim_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = Instant::now();
        let _ = estimate_dnn(&inj, Topology::Mesh, &arch, &noc);
        let ana_ms = t1.elapsed().as_secs_f64() * 1e3;
        t.add_row(vec![
            g.name.clone(),
            fmt_sig(sim_ms, 4),
            fmt_sig(ana_ms, 4),
            fmt_sig(sim_ms / ana_ms.max(1e-6), 4),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::CommBackend;

    fn fast_opts() -> Options {
        Options {
            fast: true,
            backend: CommBackend::Analytical,
            ..Options::default()
        }
    }

    #[test]
    fn fig11_mean_accuracy_above_paper_floor() {
        let tables = fig11(&fast_opts()).unwrap();
        let summary = &tables[1];
        let mean: f64 = summary.rows[0][1].parse().unwrap();
        // Paper: always >85%, average 93%. Require >80% on the fast set.
        assert!(mean > 80.0, "mean analytical accuracy {mean}%");
    }

    #[test]
    fn fig12_speedup_large() {
        let t = &fig12(&fast_opts()).unwrap()[0];
        for row in &t.rows {
            let speedup: f64 = row[3].parse().unwrap();
            assert!(speedup > 2.0, "{}: speed-up only {speedup}x", row[0]);
        }
    }
}
