//! Table 2 (design parameters) and Table 4 (VGG-19 vs state-of-the-art).

use super::Options;
use crate::baselines::table4_rows;
use crate::config::Config;
use crate::util::{fmt_sig, Table};

/// Table 2: the configured design parameters.
pub fn table2(_opts: &Options) -> Result<Vec<Table>, String> {
    let cfg = Config::default();
    let mut t = Table::new("Table 2 — design parameters", &["parameter", "value"]);
    t.add_row(vec![
        "PE array size".into(),
        format!("{0}x{0}", cfg.arch.pe_size),
    ]);
    t.add_row(vec!["Technology node".into(), format!("{}nm", cfg.arch.tech_nm)]);
    t.add_row(vec![
        "Cell levels".into(),
        format!("{} bit/cell", cfg.arch.cell_bits),
    ]);
    t.add_row(vec![
        "Data precision".into(),
        format!("{} bits", cfg.arch.n_bits),
    ]);
    t.add_row(vec!["Read-out method".into(), "Parallel".into()]);
    t.add_row(vec![
        "Flash ADC resolution".into(),
        format!("{} bits", cfg.arch.adc_bits),
    ]);
    t.add_row(vec![
        "Operating frequency".into(),
        format!("{} GHz", cfg.arch.freq_hz / 1e9),
    ]);
    t.add_row(vec![
        "NoC bus width".into(),
        cfg.noc.bus_width.to_string(),
    ]);
    t.add_row(vec![
        "Virtual channels".into(),
        cfg.noc.virtual_channels.to_string(),
    ]);
    t.add_row(vec![
        "Buffer depth".into(),
        cfg.noc.buffer_depth.to_string(),
    ]);
    t.add_row(vec![
        "Router pipeline stages".into(),
        cfg.noc.pipeline_stages.to_string(),
    ]);
    Ok(vec![t])
}

/// Table 4: VGG-19 inference comparison against published accelerators.
pub fn table4(opts: &Options) -> Result<Vec<Table>, String> {
    let mut t = Table::new(
        "Table 4 — VGG-19 inference vs state-of-the-art (\"*\" = published numbers)",
        &["architecture", "latency_ms", "power_W", "FPS", "EDAP_J.ms.mm2"],
    );
    let rows = table4_rows(opts.backend);
    for r in &rows {
        let star = if r.published { "*" } else { "" };
        t.add_row(vec![
            format!("{}{star}", r.name),
            fmt_sig(r.latency_ms, 3),
            fmt_sig(r.power_w, 3),
            fmt_sig(r.fps, 4),
            fmt_sig(r.edap, 3),
        ]);
    }
    // Headline ratios (paper §6.5).
    let ours = &rows[1]; // Proposed-ReRAM
    let atom = &rows[2];
    let pipe = &rows[3];
    let isaac = &rows[4];
    let mut h = Table::new("Table 4 — headline ratios (paper §6.5)", &["claim", "paper", "measured"]);
    h.add_row(vec![
        "EDAP improvement vs AtomLayer".into(),
        "6x".into(),
        fmt_sig(atom.edap / ours.edap, 3),
    ]);
    h.add_row(vec![
        "FPS improvement vs AtomLayer".into(),
        "4.7x".into(),
        fmt_sig(ours.fps / atom.fps, 3),
    ]);
    h.add_row(vec![
        "Power reduction vs PipeLayer".into(),
        "400x".into(),
        fmt_sig(pipe.power_w / ours.power_w, 3),
    ]);
    h.add_row(vec![
        "Latency improvement vs ISAAC".into(),
        "5.4x".into(),
        fmt_sig(isaac.latency_ms / ours.latency_ms, 3),
    ]);
    h.add_row(vec![
        "SRAM vs ReRAM latency".into(),
        "2.2x".into(),
        fmt_sig(ours.latency_ms / rows[0].latency_ms, 3),
    ]);
    Ok(vec![t, h])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::CommBackend;

    #[test]
    fn table2_matches_paper_defaults() {
        let t = &table2(&Options::default()).unwrap()[0];
        let get = |k: &str| {
            t.rows
                .iter()
                .find(|r| r[0] == k)
                .map(|r| r[1].clone())
                .unwrap()
        };
        assert_eq!(get("PE array size"), "256x256");
        assert_eq!(get("Technology node"), "32nm");
        assert_eq!(get("Data precision"), "8 bits");
        assert_eq!(get("Flash ADC resolution"), "4 bits");
        assert_eq!(get("Operating frequency"), "1 GHz");
        assert_eq!(get("NoC bus width"), "32");
    }

    #[test]
    fn table4_headline_directions_hold() {
        let opts = Options {
            backend: CommBackend::Analytical,
            ..Options::default()
        };
        let tables = table4(&opts).unwrap();
        let h = &tables[1];
        for row in &h.rows {
            let measured: f64 = row[2].parse().unwrap();
            assert!(
                measured > 1.0,
                "claim '{}' direction violated: {measured}",
                row[0]
            );
        }
    }
}
