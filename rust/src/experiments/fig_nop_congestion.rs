//! Beyond-the-paper experiment: package-level (NoP) congestion.
//!
//! The analytical package model (bandwidth bound + fixed SerDes latency) is
//! load-independent, so it cannot see queueing on the interposer — exactly
//! where scale-out studies report analytical models diverging from flit
//! simulation at k ≥ 16 chiplets. This experiment quantifies both sides:
//!
//! 1. **Uniform steady sweep** — for k ∈ {4, 8, 16, 25} and each package
//!    topology, the low-load average latency of the flit-level simulator
//!    against the analytical prediction (they must agree within ~15%), and
//!    the uniform injection rate at which the package saturates (where they
//!    cannot agree — the analytical column would never move).
//! 2. **DNN-driven drain** — one frame of a real model's inter-chiplet
//!    traffic (the [`ChipletPartition`] injection matrix lowered to NoP
//!    flows) drained through the simulator per topology.
//!
//! The (k × topology) points fan out over OS threads via the coordinator's
//! [`par_map`] — the same driver primitive the evaluation sweeps use.

use super::Options;
use crate::config::{ArchConfig, NopConfig};
use crate::coordinator::par_map;
use crate::dnn::by_name;
use crate::mapping::{ChipletPartition, Mapping};
use crate::noc::sim::{FlowSpec, Mode};
use crate::nop::sim::{analytical_latency, saturation_rate, uniform_nop_flows, NopSim};
use crate::nop::topology::{NopNetwork, NopTopology};
use crate::util::{fmt_sig, Table};

/// The `nop-congestion` experiment generator.
pub fn nop_congestion(opts: &Options) -> Result<Vec<Table>, String> {
    let nop = NopConfig::default();
    let ks: Vec<usize> = if opts.fast {
        vec![4]
    } else {
        vec![4, 8, 16, 25]
    };
    let measure: u64 = if opts.fast { 3_000 } else { 6_000 };
    let seed = opts.seed;

    // --- 1. Uniform steady sweep, driver-parallelized over (k, topo) -----
    let points: Vec<(usize, NopTopology)> = ks
        .iter()
        .flat_map(|&k| NopTopology::all().into_iter().map(move |t| (k, t)))
        .collect();
    let rows = par_map(&points, None, |&(k, topo)| {
        let net = NopNetwork::build(topo, k);
        let flows = uniform_nop_flows(k, 0.02);
        let ana = analytical_latency(&net, &nop, &flows);
        let sim = NopSim::new(
            topo,
            k,
            &nop,
            &flows,
            Mode::Steady {
                warmup: 500,
                measure,
            },
            seed,
        )
        .run();
        let sat = saturation_rate(topo, k, &nop, seed);
        (k, topo, ana, sim.avg_latency, sat)
    });
    let mut sweep = Table::new(
        "NoP congestion — low-load latency (NoP cycles) and saturation rate, uniform traffic",
        &[
            "chiplets",
            "NoP",
            "analytical",
            "sim_low_load",
            "err_%",
            "sat_rate_flit/chiplet/cyc",
        ],
    );
    for (k, topo, ana, sim_lat, sat) in rows {
        let err = 100.0 * (sim_lat - ana).abs() / ana.max(1e-9);
        sweep.add_row(vec![
            k.to_string(),
            topo.name().into(),
            fmt_sig(ana, 4),
            fmt_sig(sim_lat, 4),
            fmt_sig(err, 3),
            match sat {
                Some(rate) => fmt_sig(rate, 3),
                None => ">1.0".into(),
            },
        ]);
    }

    // --- 2. DNN-driven drain: a real partition's package traffic ---------
    let model = if opts.fast { "NiN" } else { "VGG-19" };
    let g = by_name(model).ok_or_else(|| {
        format!(
            "unknown DNN '{model}' (valid: {})",
            crate::dnn::valid_names()
        )
    })?;
    let arch = ArchConfig::reram();
    let mapping = Mapping::build(&g, &arch);
    let mut drain = Table::new(
        format!("NoP drain — one frame of {model}'s inter-chiplet traffic (NoP cycles)"),
        &["chiplets", "NoP", "flows", "flits", "makespan", "drained"],
    );
    // Partition once per k (serial — cheap), then fan the (k × topology)
    // drains out over the driver. Makespans are memoized process-wide, so
    // repeat runs (benches, CLI re-invocations in one process) are free.
    let drain_points: Vec<(usize, Vec<FlowSpec>, NopTopology)> = ks
        .iter()
        .map(|&k| {
            let part = ChipletPartition::build(&g, &mapping, &arch, k);
            let flows: Vec<FlowSpec> = part
                .nop_flows(nop.link_width)
                .into_iter()
                .map(|(s, d, flits)| FlowSpec {
                    src: s,
                    dst: d,
                    rate: 0.0,
                    flits,
                })
                .collect();
            (k, flows)
        })
        .flat_map(|(k, flows)| {
            NopTopology::all()
                .into_iter()
                .map(move |t| (k, flows.clone(), t))
        })
        .collect();
    let drain_rows = par_map(&drain_points, None, |(k, flows, topo)| {
        let total: u64 = flows.iter().map(|f| f.flits).sum();
        let stats = crate::sim::memo::drain_makespan(
            *topo,
            *k,
            &nop,
            flows,
            10_000 + total.saturating_mul(64),
            seed,
        );
        vec![
            k.to_string(),
            topo.name().into(),
            flows.len().to_string(),
            total.to_string(),
            stats.makespan.to_string(),
            stats.drained.to_string(),
        ]
    });
    for row in drain_rows {
        drain.add_row(row);
    }

    Ok(vec![sweep, drain])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::CommBackend;

    fn fast_opts() -> Options {
        Options {
            fast: true,
            backend: CommBackend::Analytical,
            ..Options::default()
        }
    }

    #[test]
    fn low_load_rows_agree_with_analytical_within_15pct() {
        let tables = nop_congestion(&fast_opts()).unwrap();
        let sweep = &tables[0];
        assert_eq!(sweep.rows.len(), 3); // k = 4 x three topologies
        for row in &sweep.rows {
            let err: f64 = row[4].parse().unwrap();
            assert!(err < 15.0, "{} k={}: {err}% off analytical", row[1], row[0]);
        }
    }

    #[test]
    fn dnn_drain_terminates_on_every_topology() {
        let tables = nop_congestion(&fast_opts()).unwrap();
        let drain = &tables[1];
        assert_eq!(drain.rows.len(), 3);
        for row in &drain.rows {
            assert_eq!(row[5], "true", "{} k={} did not drain", row[1], row[0]);
            let makespan: u64 = row[4].parse().unwrap();
            let flits: u64 = row[3].parse().unwrap();
            assert!(flits > 0, "partition produced no package traffic");
            assert!(makespan > 0);
        }
    }
}
